"""Live ψ refresh: double-buffered, versioned publish from training to serving.

Training mutates factor tables every epoch; serving must keep answering
queries meanwhile. The protocol here is the classic double-buffer flip:

  1. ``publish`` builds the NEXT shard set (``cluster.shard_psi`` — slicing,
     padding, device placement) entirely off to the side, in the back
     buffer. Readers still see the old table; nothing they can reach is
     being written.
  2. The flip is ONE reference assignment of the (table, version) pair —
     atomic under the interpreter, so a reader grabbing the active table
     either gets the complete old snapshot or the complete new one, never a
     half-written mix. jax arrays are immutable, so a snapshot stays valid
     for as long as any in-flight request holds it.
  3. The version counter rides on the snapshot
     (:class:`~repro.serve.cluster.PsiShardSet.version`); the request cache
     (``serve/batcher.py``) keys on it, so a publish implicitly invalidates
     every cached result without any flush traffic.

:class:`PsiPublisher` adapts this to the models' ``fit(callback=...)`` hook:
at each epoch boundary it snapshots ``export_psi(params)`` into the cluster,
so online serving tracks training with epoch granularity ("live ψ refresh").

**Delta publish** (continual learning): a fold-in produces ONE new/updated ψ
row (``Model.fold_in_item``), and republishing the whole catalogue through a
:class:`StagedRollout` for one row would be absurd. ``publish_delta(rows,
ids)`` — on :class:`~repro.serve.cluster.ShardedRetrievalCluster`,
:class:`~repro.serve.mesh.FaultTolerantRetrievalMesh`, and
:class:`PsiPublisher` — patches existing rows and/or appends new ids onto
the authoritative table copy and flips the result live under a NORMAL
version bump: the double-buffer/atomicity story is unchanged, the batcher
cache invalidates through the version key exactly as for a full publish,
and the mesh's stale-replica refusal keeps protecting reads (every replica
is rebuilt at the new version; an old-version replica is refused before
dispatch). :func:`apply_delta` is the pure patch/append helper.

:class:`StagedRollout` is the OPERATED form of publish for the
fault-tolerant mesh (``serve/mesh.py``): instead of flipping a new ψ table
straight to every replica, it stages the table on one canary replica per
shard, health-checks it under mirrored traffic (live vs canary answers on
the same φ rows), and only then promotes — a bad table (NaNs, truncated
export, wrong geometry) rolls back with zero downtime and zero user-served
queries. See ``serve/README.md`` for the runbook.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import numpy as np

from repro.obs.metrics import next_instance_id, resolve_registry


def dense_table(shard_set) -> np.ndarray:
    """Reassemble the dense (n_items, D) ψ table from a
    :class:`~repro.serve.cluster.PsiShardSet` (drops the last shard's
    padding rows) — the authoritative base a delta patches against."""
    stacked = np.asarray(shard_set.stacked())          # (S, rows_per, D)
    return stacked.reshape(-1, stacked.shape[-1])[: shard_set.n_items]


def apply_delta(psi: np.ndarray, rows, ids) -> np.ndarray:
    """Pure delta: patch/append ψ ``rows`` at global item ``ids``.

    ``ids < n_items`` overwrite existing rows; ``ids >= n_items`` grow the
    catalogue and must cover the appended range ``[n_items, max(ids)]``
    without holes — a hole would silently serve an all-zero embedding for a
    real item id, so it raises instead. Returns a NEW dense table (the
    caller publishes it under a version bump; buffers stay immutable).
    """
    psi = np.asarray(psi)
    rows = np.asarray(rows, psi.dtype)
    ids = np.atleast_1d(np.asarray(ids, np.int64))
    if rows.ndim == 1:
        rows = rows[None, :]
    n, d = psi.shape
    if rows.shape != (ids.size, d):
        raise ValueError(
            f"delta rows must be ({ids.size}, {d}), got {rows.shape}"
        )
    if ids.size == 0:
        return psi.copy()
    if ids.min() < 0:
        raise ValueError(f"negative item id in delta: {ids.min()}")
    if np.unique(ids).size != ids.size:
        raise ValueError("duplicate item ids in one delta")
    n_new = max(int(ids.max()) + 1 - n, 0)
    if n_new:
        appended = set(int(i) for i in ids[ids >= n])
        missing = [i for i in range(n, n + n_new) if i not in appended]
        if missing:
            raise ValueError(
                f"append hole: ids {missing} in [{n}, {n + n_new}) carry no "
                "row — a hole would serve a zero embedding for a real item"
            )
    out = np.concatenate([psi, np.zeros((n_new, d), psi.dtype)], axis=0)
    out[ids] = rows
    return out


class VersionedTable:
    """Double-buffered holder of the active :class:`PsiShardSet`.

    ``publish(build)`` calls ``build(next_version)`` to construct the new
    snapshot into the back buffer, then flips it live with one atomic
    reference swap. ``active`` raises until the first publish — a serving
    path must never silently answer from an empty catalogue.
    """

    def __init__(self):
        self._buffers = [None, None]  # [back, live] payloads
        self._state = (None, 0)       # (live snapshot, version) — ONE ref

    @property
    def version(self) -> int:
        return self._state[1]

    @property
    def active(self):
        snapshot, version = self._state  # single read: consistent pair
        if snapshot is None:
            raise RuntimeError(
                "no table published yet — call publish() before serving"
            )
        return snapshot

    def publish(self, build: Callable[[int], object]) -> int:
        """Build the next snapshot with ``build(version)``, then flip."""
        _, version = self._state
        nxt = build(version + 1)
        # back buffer keeps the previous snapshot alive for stragglers that
        # grabbed it pre-flip; the flip itself is one atomic assignment
        self._buffers = [self._state[0], nxt]
        self._state = (nxt, version + 1)
        return version + 1


class PsiPublisher:
    """``fit(callback=...)`` adapter: publish ψ snapshots at epoch boundaries.

    ::

        cluster = ShardedRetrievalCluster(phi_fn, n_shards=4, k=100)
        pub = PsiPublisher(cluster, mf.export_psi, every=1)
        mf.fit(params, data, hp, n_epochs, callback=pub)
        pub.versions   # [(epoch, version), ...] — the refresh trajectory

    ``export`` maps the training params to the (n_items, D) ψ table (each
    model's ``export_psi``; close over design matrices / hyper-params where
    the model needs them). ``every`` throttles the refresh cadence.

    Registry metrics (``obs/metrics.py``; labels ``instance``):
    ``serve_psi_version`` (gauge: last published version),
    ``serve_psi_last_publish_time`` (gauge: registry-clock timestamp of the
    last publish — staleness age = ``registry.clock() - value``),
    ``serve_psi_publishes_total`` / ``serve_psi_delta_publishes_total`` /
    ``serve_psi_delta_rows_total``.
    """

    def __init__(
        self,
        cluster,
        export: Callable,
        *,
        every: int = 1,
        log: Optional[Callable[[str], None]] = None,
        registry=None,
    ):
        self.cluster = cluster
        self.export = export
        self.every = int(every)
        self.log = log
        self.versions: list = []  # [(epoch, version), ...]
        self.deltas: list = []    # [(version, n_rows), ...] delta publishes
        reg = resolve_registry(registry)
        self.registry = reg
        inst = {"instance": next_instance_id()}
        lab = ("instance",)
        self._g_version = reg.gauge(
            "serve_psi_version", "last published psi table version",
            labels=lab).labels(**inst)
        self._g_pub_time = reg.gauge(
            "serve_psi_last_publish_time",
            "registry-clock timestamp of the last publish (staleness age "
            "= clock() - value)", labels=lab).labels(**inst)
        self._c_publishes = reg.counter(
            "serve_psi_publishes_total", "full-table publishes",
            labels=lab).labels(**inst)
        self._c_deltas = reg.counter(
            "serve_psi_delta_publishes_total", "delta publishes",
            labels=lab).labels(**inst)
        self._c_delta_rows = reg.counter(
            "serve_psi_delta_rows_total",
            "psi rows patched/appended by delta publishes",
            labels=lab).labels(**inst)

    def _mark(self, version: int) -> None:
        self._g_version.set(version)
        self._g_pub_time.set(self.registry.clock())

    def __call__(self, epoch: int, params) -> None:
        if epoch % self.every:
            return
        version = self.cluster.publish(self.export(params))
        self.versions.append((epoch, version))
        self._c_publishes.inc()
        self._mark(version)
        if self.log is not None:
            self.log(f"epoch {epoch}: published psi table version {version}")

    def publish_delta(self, rows, ids) -> int:
        """Incremental publish between epochs: patch/append the fold-in
        ``rows`` at item ``ids`` (see :func:`apply_delta`) without a fresh
        ``export(params)`` full-table pass. Returns the new version and
        records it in ``deltas``."""
        version = self.cluster.publish_delta(rows, ids)
        n_rows = int(np.atleast_1d(ids).size)
        self.deltas.append((version, n_rows))
        self._c_deltas.inc()
        self._c_delta_rows.inc(n_rows)
        self._mark(version)
        if self.log is not None:
            self.log(
                f"delta: {self.deltas[-1][1]} psi row(s) -> version {version}"
            )
        return version


class StagedRollout:
    """Canary-gated ψ publish for the fault-tolerant mesh: stage → mirror →
    promote (or roll back), never a straight flip.

    ::

        rollout = StagedRollout(mesh, mirror_phi=phi_probe_rows)
        promoted, report = rollout.publish(new_psi_table)
        if not promoted:
            alert(report)          # bad table never reached a user

    Protocol (the drain-and-restart shape from the ops exemplars, applied
    to in-memory tables):

      1. ``mesh.begin_canary(table)`` — the staged table lands on ONE extra
         replica per shard, off the routing path; live traffic untouched.
      2. ``mesh.mirror_check(mirror_phi)`` — the probe φ rows run against
         BOTH the live table and the canary; built-in structural checks
         (shapes, finite scores, ids in range) plus the optional
         ``validate(live_result, canary_result)`` policy hook (e.g. demand
         rank overlap, or a quality floor from a held-out eval).
      3. healthy → ``mesh.promote_canary()``: one atomic ReplicaSet flip,
         canary slab becomes replica 0, the rest re-replicate; in-flight
         queries finish on the old snapshot (no drain needed — snapshots
         are immutable). Unhealthy → ``mesh.rollback_canary()``: the
         staged table is dropped, version unchanged, nothing served it.

    ``history`` records every attempt as ``(staged_version, promoted,
    report)`` — the rollout/rollback audit trail.
    """

    def __init__(
        self,
        mesh,
        *,
        mirror_phi: Optional[Sequence] = None,
        validate: Optional[Callable] = None,
        k: Optional[int] = None,
        log: Optional[Callable[[str], None]] = None,
        registry=None,
    ):
        self.mesh = mesh
        self.mirror_phi = mirror_phi
        self.validate = validate
        self.k = k
        self.log = log
        self.history: list = []  # [(staged_version, promoted, report), ...]
        reg = resolve_registry(registry)
        inst = {"instance": next_instance_id()}
        fam = reg.counter(
            "serve_rollout_attempts_total",
            "staged rollout attempts by outcome",
            labels=("instance", "outcome"))
        self._c_outcome = {
            out: fam.labels(**inst, outcome=out)
            for out in ("promoted", "rolled_back")
        }

    def publish(self, psi_table, *, mirror_phi=None) -> tuple:
        """Stage ``psi_table``, mirror-check it, and promote iff healthy.
        Returns ``(promoted: bool, report: dict)``."""
        phi = mirror_phi if mirror_phi is not None else self.mirror_phi
        if phi is None:
            raise ValueError(
                "StagedRollout needs mirror traffic: pass mirror_phi "
                "(probe φ rows) at construction or per publish"
            )
        staged = self.mesh.begin_canary(psi_table)
        report = self.mesh.mirror_check(phi, k=self.k, validate=self.validate)
        promoted = bool(report["healthy"])
        self._c_outcome["promoted" if promoted else "rolled_back"].inc()
        if promoted:
            version = self.mesh.promote_canary()
            report = {**report, "promoted_version": version}
            if self.log is not None:
                self.log(f"staged v{staged} healthy: promoted as v{version}")
        else:
            self.mesh.rollback_canary()
            if self.log is not None:
                self.log(f"staged v{staged} UNHEALTHY: rolled back "
                         f"({report['checks']})")
        self.history.append((staged, promoted, report))
        return promoted, report
