"""Jit'd wrapper: Pallas dense path for small/mid vocab, XLA gather path for
huge tables (which belong to SparseCore / row-sharded lookup on real pods)."""
from repro.kernels import kernel_jit
from repro.kernels.embedding_bag.kernel import embedding_bag_pallas
from repro.kernels.embedding_bag.ref import embedding_bag_ref

DENSE_VOCAB_LIMIT = 131_072


@kernel_jit(static_argnames=("block_batch", "block_vocab"))
def embedding_bag_dense(table, ids, weights, block_batch=256, block_vocab=512,
                        *, interpret=None):
    if table.shape[0] > DENSE_VOCAB_LIMIT:
        return embedding_bag_ref(table, ids, weights)
    return embedding_bag_pallas(
        table, ids, weights,
        block_batch=block_batch, block_vocab=block_vocab,
        interpret=interpret,
    )
