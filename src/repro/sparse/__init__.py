"""Sparse substrate: CSR structures, segment ops, EmbeddingBag, samplers.

JAX has no native EmbeddingBag or CSR/CSC sparse support (BCOO only) — the
gather + ``jax.ops.segment_sum`` implementations here ARE part of the system,
used by the iCD core and the data pipeline.
"""

from repro.sparse.csr import CSR, coo_to_csr, csr_row_ids
from repro.sparse.segment import (
    segment_sum,
    segment_mean,
    segment_max,
    embedding_bag,
    multi_hot_lookup,
)
from repro.sparse.interactions import Interactions, build_interactions
from repro.sparse.sampler import neighbor_sampler, build_adjacency

__all__ = [
    "CSR",
    "coo_to_csr",
    "csr_row_ids",
    "segment_sum",
    "segment_mean",
    "segment_max",
    "embedding_bag",
    "multi_hot_lookup",
    "Interactions",
    "build_interactions",
    "neighbor_sampler",
    "build_adjacency",
]
