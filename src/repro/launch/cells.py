"""(architecture × input-shape) cell builders for the multi-pod dry-run.

A cell packages everything ``dryrun.py`` needs to lower+compile one entry of
the assignment matrix: a step closure, abstract inputs (ShapeDtypeStruct —
never allocated), and in/out PartitionSpec trees for the given mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_shapes
from repro.launch import sharding as sh
from repro.launch.mesh import dp_axes


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    step_fn: Callable
    abstract_args: Tuple[Any, ...]
    in_specs: Tuple[Any, ...]
    out_specs: Any
    skip: Optional[str] = None
    notes: str = ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# ===========================================================================
# iCD cells — the paper's own model at production scale
# ===========================================================================
def _icd_cell(arch: str, shape_spec, mesh) -> Cell:
    from repro.core.models import mf
    from repro.sparse.interactions import Interactions

    cfg = get_config(arch)
    dp = dp_axes(mesh)

    if shape_spec.kind == "retrieval":
        n_cand = shape_spec.extra("n_candidates")
        bq = shape_spec.global_batch

        def step(w_users, h_items):
            scores = w_users @ h_items.T
            vals, idx = jax.lax.top_k(scores, 100)
            return vals, idx

        return Cell(
            arch, shape_spec.name, "retrieval", step,
            (_sds((bq, cfg.k), jnp.float32), _sds((n_cand, cfg.k), jnp.float32)),
            (P(dp, None), P("model", None)),
            (P(dp, None), P(dp, None)),
            notes="paper-native separable retrieval: one matvec per query",
        )

    n_ctx = shape_spec.extra("n_ctx")
    n_items = shape_spec.extra("n_items")
    nnz = shape_spec.extra("nnz")
    # unroll=True: exact HLO cost accounting (XLA counts while bodies once)
    # and better cross-column pipelining on TPU
    hp = mf.MFHyperParams(k=cfg.k, alpha0=cfg.alpha0, l2=cfg.l2, unroll=True)

    params_abs = mf.MFParams(
        w=_sds((n_ctx, cfg.k), jnp.float32),
        h=_sds((n_items, cfg.k), jnp.float32),
    )
    data_abs = Interactions(
        ctx=_sds((nnz,), jnp.int32), item=_sds((nnz,), jnp.int32),
        y=_sds((nnz,), jnp.float32), alpha=_sds((nnz,), jnp.float32),
        t_ctx=_sds((nnz,), jnp.int32), t_item=_sds((nnz,), jnp.int32),
        t_perm=_sds((nnz,), jnp.int32),
        n_ctx=n_ctx, n_items=n_items,
    )
    e_abs = _sds((nnz,), jnp.float32)

    p_specs, d_spec_dict = sh.icd_mf_specs(mesh)
    data_specs = Interactions(
        ctx=d_spec_dict["ctx"], item=d_spec_dict["item"], y=d_spec_dict["y"],
        alpha=d_spec_dict["alpha"], t_ctx=d_spec_dict["t_ctx"],
        t_item=d_spec_dict["t_item"], t_perm=d_spec_dict["t_perm"],
        n_ctx=n_ctx, n_items=n_items,
    )

    def step(params, data, e):
        return mf.epoch(params, data, e, hp)

    return Cell(
        arch, shape_spec.name, "train", step,
        (params_abs, data_abs, e_abs),
        (p_specs, data_specs, P(dp)),
        (p_specs, P(dp)),
        notes="one full iCD epoch; cross-shard traffic = k² Gram all-reduce",
    )


# ===========================================================================
# registry
# ===========================================================================
# The seed-template LM/RecSys/GNN cell builders left with the unused
# architecture zoo (PR 8 retirement); only the paper's own iCD archs exist.
ICD_ARCHS = ("icd-mf",)


def all_cell_ids(include_icd: bool = True):
    out = []
    for arch in ICD_ARCHS if include_icd else ():
        for shape_name in get_shapes(arch):
            out.append((arch, shape_name))
    return out


def build_cell(arch: str, shape_name: str, mesh, cfg_override=None,
               probe: bool = False, shape_override=None) -> Cell:
    shape_spec = shape_override or get_shapes(arch)[shape_name]
    if arch in ICD_ARCHS or arch.startswith("icd"):
        return _icd_cell(arch, shape_spec, mesh)
    raise KeyError(arch)
