"""Scan-aware cost calibration for the roofline.

XLA's ``cost_analysis()`` counts a while-loop body ONCE regardless of trip
count (verified in tests/test_hlo_analysis.py), so the scanned LM cells
under-report FLOPs/bytes/collectives by ~n_layers×. We recover exact terms
with UNROLLED probe compiles — tiny configs (≤2 layers, ≤2 microbatches)
where HLO counting is exact — and an affine cost model:

    cost(L, M) = K + M·(c0 + L·c_l) + L·δ
      K    — outside-loop work (embedding/head/optimizer)
      c0   — per-microbatch constant (non-layer collectives etc.)
      c_l  — per-(microbatch × layer) constant (FSDP param all-gathers —
             these are what make extra microbatches expensive on the wire)
      δ    — per-layer token-linear work at the FULL batch (microbatching
             splits tokens, so token-linear work is M-invariant)

Probes: train (L,M) ∈ {(1,1),(2,1),(1,2),(2,2)}; decode/prefill {(1),(2)}.
Solved per cost component (flops, bytes, each collective kind).

The full scanned cell is still lowered+compiled as the deliverable; only the
reported roofline terms come from this calibration.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import numpy as np

from repro.configs import get_config, get_shapes
from repro.launch import hlo_analysis
from repro.launch.cells import LM_ARCHS, build_cell
from repro.launch.sharding import named
from repro.models.transformer import group_size, n_dense_head_layers

COMPONENTS = ("flops", "bytes", "all-gather", "all-reduce", "reduce-scatter",
              "all-to-all", "collective-permute")


def _component_vector(compiled) -> np.ndarray:
    ca = compiled.cost_analysis() or {}
    cb = hlo_analysis.collective_bytes(compiled.as_text())
    return np.array(
        [float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))]
        + [cb[k] for k in COMPONENTS[2:]]
    )


def _compile_probe(arch, shape_name, mesh, n_scan_steps, microbatches,
                   batch_scale: float = 1.0):
    cfg = get_config(arch)
    g = group_size(cfg)
    fk = n_dense_head_layers(cfg)
    cfg_p = dataclasses.replace(
        cfg, n_layers=fk + g * n_scan_steps, scan_layers=False,
        num_microbatches=microbatches,
    )
    shape_override = None
    if batch_scale != 1.0:
        spec = get_shapes(arch)[shape_name]
        shape_override = dataclasses.replace(
            spec, global_batch=max(32, int(spec.global_batch * batch_scale))
        )
    cell = build_cell(arch, shape_name, mesh, cfg_override=cfg_p, probe=True,
                      shape_override=shape_override)
    with mesh:
        compiled = jax.jit(
            cell.step_fn,
            in_shardings=tuple(named(mesh, s) for s in cell.in_specs),
            out_shardings=named(mesh, cell.out_specs),
        ).lower(*cell.abstract_args).compile()
    return _component_vector(compiled)


def calibrated_components(arch: str, shape_name: str, mesh) -> Dict[str, float]:
    """Exact-as-possible per-device cost components for a scanned LM cell.

    Probes (all UNROLLED so HLO counting is exact): u11 (1 scan step, 1
    microbatch), u21 (2 steps), u12 (2 microbatches). A fourth (2,2) probe
    is NOT usable: XLA deduplicates the two identical two-layer microbatch
    bodies into one called computation and counts it once (measured — see
    EXPERIMENTS.md §Dry-run notes).

    Model per component:
      flops/bytes — token-linear, so microbatch-count invariant (verified:
        u12 ≈ u11 to within 8%): full = u11 + (L−1)·(u21−u11).
      collectives — per-layer collectives (FSDP param all-gathers +
        activation-grad all-reduces) recur EVERY microbatch:
        full = u11 + (M−1)·(u12−u11) + (L−1)·(u21−u11)
                   + (M−1)·(L−1)·(u21−u11)            [per-layer × per-mb]
        (the last term slightly overcounts the AR share, whose payload
        shrinks ∝1/M; treated as an upper bound, noted in the table).
    """
    assert arch in LM_ARCHS
    cfg = get_config(arch)
    shape_spec = get_shapes(arch)[shape_name]
    g = group_size(cfg)
    fk = n_dense_head_layers(cfg)
    l_full = (cfg.n_layers - fk) // g

    u11 = _compile_probe(arch, shape_name, mesh, 1, 1)
    u21 = _compile_probe(arch, shape_name, mesh, 2, 1)
    per_layer = np.maximum(u21 - u11, 0.0)

    if shape_spec.kind == "train" and cfg.num_microbatches > 1:
        # Per-layer collectives split into a TOKEN-PROPORTIONAL part `a`
        # (activation all-gathers/all-reduces — total is microbatch-count
        # invariant) and a PARAM-CONSTANT part `b` (FSDP weight gathers —
        # repeated EVERY microbatch). Separated with half-batch probes:
        #   per_layer(B)   = a(B) + b
        #   per_layer(B/2) = a(B)/2 + b   ⇒  b = 2·per_layer(B/2) − per_layer(B)
        m_full = cfg.num_microbatches
        u12 = _compile_probe(arch, shape_name, mesh, 1, 2)
        u11h = _compile_probe(arch, shape_name, mesh, 1, 1, batch_scale=0.5)
        u21h = _compile_probe(arch, shape_name, mesh, 2, 1, batch_scale=0.5)
        per_layer_h = np.maximum(u21h - u11h, 0.0)
        b_const = np.clip(2.0 * per_layer_h - per_layer, 0.0, per_layer)
        per_mb = np.maximum(u12 - u11, 0.0)
        full = u11 + (l_full - 1) * per_layer
        coll = slice(2, len(COMPONENTS))
        full[coll] = (
            u11[coll]
            + (l_full - 1) * per_layer[coll]
            + (m_full - 1) * per_mb[coll]
            + (m_full - 1) * (l_full - 1) * b_const[coll]
        )
    else:
        full = u11 + (l_full - 1) * per_layer

    full = np.maximum(full, 0.0)
    return dict(zip(COMPONENTS, full.tolist()))


def calibrated_roofline(arch: str, shape_name: str, mesh) -> Dict:
    comp = calibrated_components(arch, shape_name, mesh)
    coll = sum(comp[k] for k in COMPONENTS[2:])
    compute_s = comp["flops"] / hlo_analysis.PEAK_FLOPS
    memory_s = comp["bytes"] / hlo_analysis.HBM_BW
    collective_s = coll / hlo_analysis.LINK_BW
    dominant = max(
        [("compute", compute_s), ("memory", memory_s), ("collective", collective_s)],
        key=lambda kv: kv[1],
    )[0]
    return {
        "flops_per_device": comp["flops"],
        "bytes_per_device": comp["bytes"],
        "collective_bytes_per_device": coll,
        "collective_breakdown": {k: comp[k] for k in COMPONENTS[2:]},
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "roofline_fraction": compute_s / max(compute_s, memory_s, collective_s, 1e-30),
        "calibrated": True,
    }
