"""Architecture zoo: LM transformers, recsys rankers, GNN.

All models are config-driven pure-function modules over explicit parameter
pytrees (init / apply / train-loss / serve paths) so the same definitions
drive CPU smoke tests, the multi-pod dry-run and the roofline benches.
"""
