"""Streaming ranking-eval harness: dense-path parity, exclusion protocol,
and the per-epoch fit callback."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import ndcg_at_k, recall_at_k
from repro.core.models import mf
from repro.eval.ranking import fit_eval_callback, ranking_eval
from repro.serve.engine import exclude_mask_from_lists
from repro.sparse.interactions import build_interactions


def _setup(n_ctx=40, n_items=120, k=8, seed=0):
    rng = np.random.default_rng(seed)
    params = mf.init(jax.random.PRNGKey(seed), n_ctx, n_items, k)
    truth = rng.integers(0, n_items, size=n_ctx)
    excl = [rng.choice(n_items, size=int(rng.integers(0, 6)), replace=False)
            for _ in range(n_ctx)]
    return rng, params, truth, excl


def test_streaming_equals_dense_metrics():
    _, params, truth, excl = _setup()
    phi = mf.build_phi(params, jnp.arange(40))
    psi = mf.export_psi(params)
    res = ranking_eval(phi, psi, truth, k=10, batch_rows=13, exclude=excl,
                       block_items=32)
    mask = exclude_mask_from_lists(excl, 120)
    dense = phi @ psi.T
    r = float(recall_at_k(dense, jnp.asarray(truth), 10, mask))
    n = float(ndcg_at_k(dense, jnp.asarray(truth), 10, mask))
    np.testing.assert_allclose(res["recall@10"], r, atol=1e-6)
    np.testing.assert_allclose(res["ndcg@10"], n, atol=1e-6)
    assert res["n_eval"] == 40 and res["k"] == 10


def test_streaming_eval_never_builds_full_mask_rows():
    """The exclusion protocol rides the kernel's (B, L) id-list form: the
    harness must produce dense-parity metrics WITHOUT ever calling the
    dense mask builder (the old (B, n_items) host-side path)."""
    import repro.eval.ranking as ranking_mod

    _, params, truth, excl = _setup(seed=5)
    phi = mf.build_phi(params, jnp.arange(40))
    psi = mf.export_psi(params)
    assert not hasattr(ranking_mod, "exclude_mask_from_lists")
    res = ranking_eval(phi, psi, truth, k=10, batch_rows=16, exclude=excl,
                       block_items=32)
    mask = exclude_mask_from_lists(excl, 120)
    dense = phi @ psi.T
    r = float(recall_at_k(dense, jnp.asarray(truth), 10, mask))
    np.testing.assert_allclose(res["recall@10"], r, atol=1e-6)


def test_sharded_eval_matches_single_device():
    """cluster= streams the same batches through the sharded table; the
    merge contract makes the metrics identical at any shard count."""
    from repro.serve.cluster import ShardedRetrievalCluster

    _, params, truth, excl = _setup(seed=6)
    phi = mf.build_phi(params, jnp.arange(40))
    psi = mf.export_psi(params)
    single = ranking_eval(phi, psi, truth, k=10, batch_rows=13, exclude=excl,
                          block_items=32)
    for n_shards in (1, 3):
        cl = ShardedRetrievalCluster(n_shards=n_shards, k=10, block_items=32,
                                     psi_table=psi)
        sharded = ranking_eval(phi, None, truth, k=10, batch_rows=13,
                               exclude=excl, cluster=cl)
        np.testing.assert_allclose(sharded["recall@10"], single["recall@10"],
                                   atol=1e-6)
        np.testing.assert_allclose(sharded["ndcg@10"], single["ndcg@10"],
                                   atol=1e-6)


def test_no_exclude_and_single_batch():
    _, params, truth, _ = _setup(seed=1)
    phi = mf.build_phi(params, jnp.arange(40))
    res_a = ranking_eval(phi, mf.export_psi(params), truth, k=10, batch_rows=40)
    res_b = ranking_eval(phi, mf.export_psi(params), truth, k=10, batch_rows=7)
    np.testing.assert_allclose(res_a["recall@10"], res_b["recall@10"], atol=1e-6)
    np.testing.assert_allclose(res_a["ndcg@10"], res_b["ndcg@10"], atol=1e-6)


def test_fit_eval_callback_records_history_per_epoch():
    rng, params, truth, excl = _setup(seed=2)
    nnz = 300
    cells = rng.choice(40 * 120, size=nnz, replace=False)
    ctx, item = cells // 120, cells % 120
    y = rng.integers(1, 5, size=nnz).astype(np.float64)
    data = build_interactions(ctx, item, y, 1.0 + rng.random(nnz), 40, 120,
                              alpha0=0.3)
    cb = fit_eval_callback(
        lambda p: (mf.build_phi(p, jnp.arange(40)), mf.export_psi(p)),
        truth, k=10, exclude=excl, batch_rows=16,
    )
    hp = mf.MFHyperParams(k=8, alpha0=0.3, l2=0.05)
    mf.fit(params, data, hp, n_epochs=2, callback=cb)
    assert [h["epoch"] for h in cb.history] == [0, 1]
    for h in cb.history:
        assert 0.0 <= h["recall@10"] <= 1.0
        assert 0.0 <= h["ndcg@10"] <= 1.0


def test_every_skips_epochs():
    _, params, truth, _ = _setup(seed=3)
    cb = fit_eval_callback(
        lambda p: (mf.build_phi(p, jnp.arange(40)), mf.export_psi(p)),
        truth, k=5, every=2,
    )
    for ep in range(4):
        cb(ep, params)
    assert [h["epoch"] for h in cb.history] == [0, 2]
