"""BPR-MF baseline (Rendle et al. [13]) — the paper's main competitor.

Pairwise SGD over sampled (context, consumed item, non-consumed item)
triples: maximize σ(ŷ(c,i⁺) − ŷ(c,i⁻)). The paper contrasts iCD against
this throughout §2/§6; we need it for the experiment reproductions and the
convergence-behaviour comparisons (BPR degrades with many items unless the
negative sampler is non-uniform [7,12] — we implement uniform sampling, the
baseline the paper refers to).

Implementation: minibatched SGD with scatter-add parameter updates (one jit
step per batch). Collisions inside a batch are resolved additively — the
standard "hogwild-in-a-batch" approximation used by every vectorized BPR.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.models.mf import MFParams


@dataclasses.dataclass(frozen=True)
class BPRHyperParams:
    k: int
    lr: float = 0.05
    l2: float = 0.002
    batch: int = 4096


def init(key, n_ctx: int, n_items: int, k: int, sigma: float = 0.1) -> MFParams:
    kw, kh = jax.random.split(key)
    return MFParams(
        w=sigma * jax.random.normal(kw, (n_ctx, k), jnp.float32),
        h=sigma * jax.random.normal(kh, (n_items, k), jnp.float32),
    )


@partial(jax.jit, static_argnames=("hp",))
def step(
    params: MFParams,
    ctx: jax.Array,      # (B,) sampled contexts with ≥1 positive
    pos: jax.Array,      # (B,) consumed item per context
    neg: jax.Array,      # (B,) uniformly sampled item (not filtered)
    hp: BPRHyperParams,
) -> Tuple[MFParams, jax.Array]:
    w_c = jnp.take(params.w, ctx, axis=0)
    h_p = jnp.take(params.h, pos, axis=0)
    h_n = jnp.take(params.h, neg, axis=0)
    x = jnp.sum(w_c * (h_p - h_n), axis=1)
    sig = jax.nn.sigmoid(-x)  # dL/dx for L = -log σ(x)
    loss = jnp.mean(jax.nn.softplus(-x))

    g_w = -sig[:, None] * (h_p - h_n) + hp.l2 * w_c
    g_p = -sig[:, None] * w_c + hp.l2 * h_p
    g_n = sig[:, None] * w_c + hp.l2 * h_n

    w = params.w.at[ctx].add(-hp.lr * g_w)
    h = params.h.at[pos].add(-hp.lr * g_p)
    h = h.at[neg].add(-hp.lr * g_n)
    return MFParams(w, h), loss


def fit(
    params: MFParams,
    ctx_pos: np.ndarray,   # (nnz, 2) observed (context, item) pairs
    n_items: int,
    hp: BPRHyperParams,
    n_steps: int,
    seed: int = 0,
) -> MFParams:
    rng = np.random.default_rng(seed)
    nnz = len(ctx_pos)
    for s in range(n_steps):
        idx = rng.integers(0, nnz, hp.batch)
        neg = rng.integers(0, n_items, hp.batch)
        params, _ = step(
            params,
            jnp.asarray(ctx_pos[idx, 0]),
            jnp.asarray(ctx_pos[idx, 1]),
            jnp.asarray(neg),
            hp,
        )
    return params
