"""Pure-jnp oracle for the dense EmbeddingBag kernel."""
import jax.numpy as jnp


def embedding_bag_ref(table, ids, weights):
    gathered = jnp.take(table, ids, axis=0)  # (B, L, D)
    return jnp.sum(gathered * weights[..., None].astype(gathered.dtype), axis=1)
