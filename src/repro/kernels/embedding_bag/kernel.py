"""Pallas EmbeddingBag as one-hot × table MXU matmuls.

TPU adaptation of the recsys hot path (DESIGN.md §3): a gather + segment-sum
is scatter-bound on the VPU, but the same contraction can be phrased as

    out[b, :] = Σ_l w[b,l] · T[ids[b,l], :]  =  (Σ_l w·onehot(ids)) @ T

The one-hot matrix is built block-wise in registers (compare-with-iota per
bag slot, L static) and contracted on the MXU against vocab-tiled table
blocks. Grid (batch_blocks, vocab_blocks), output accumulated in VMEM
scratch across the vocab sweep.

Scope: per-field vocabularies up to ~10⁵ (work is O(B·V·D/MXU) — the dense
formulation trades FLOPs for bandwidth and wins while V_block fits VMEM).
Tables beyond that stay on the row-sharded XLA take+segment_sum path
(``repro.sparse.embedding_bag``); on real hardware those belong to
SparseCore. ops.py dispatches on vocab size.

VMEM per step: bv·D·4 (table tile) + bb·bv·4 (one-hot) + bb·D·4 (acc)
≈ 0.5–2 MiB at defaults (bb=256, bv=512, D≤128).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bag_kernel(bag, bb, bv, ids_ref, w_ref, table_ref, o_ref, acc_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    v_lo = j * bv
    idx = v_lo + jax.lax.broadcasted_iota(jnp.int32, (bb, bv), 1)
    onehot = jnp.zeros((bb, bv), jnp.float32)
    for l in range(bag):  # bag is static & small (≤ ~100)
        ids_l = ids_ref[:, l][:, None]
        w_l = w_ref[:, l][:, None].astype(jnp.float32)
        onehot = onehot + jnp.where(idx == ids_l, w_l, 0.0)

    acc_ref[...] += jax.lax.dot(
        onehot, table_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(j == pl.num_programs(1) - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def embedding_bag_pallas(
    table: jax.Array,    # (V, D)
    ids: jax.Array,      # (B, L) int32
    weights: jax.Array,  # (B, L) f32 (0 ⇒ padding)
    *,
    block_batch: int = 256,
    block_vocab: int = 512,
    interpret: bool = True,
) -> jax.Array:
    v, d = table.shape
    b, bag = ids.shape
    bb = min(block_batch, max(8, b))
    bv = min(block_vocab, max(128, v))
    b_pad = -(-b // bb) * bb
    v_pad = -(-v // bv) * bv
    d_pad = max(128, -(-d // 128) * 128)
    if (v_pad, d_pad) != (v, d):
        table = jnp.pad(table, ((0, v_pad - v), (0, d_pad - d)))
    if b_pad != b:
        ids = jnp.pad(ids, ((0, b_pad - b), (0, 0)))
        weights = jnp.pad(weights, ((0, b_pad - b), (0, 0)))

    out = pl.pallas_call(
        partial(_bag_kernel, bag, bb, bv),
        grid=(b_pad // bb, v_pad // bv),
        in_specs=[
            pl.BlockSpec((bb, bag), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, bag), lambda i, j: (i, 0)),
            pl.BlockSpec((bv, d_pad), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, d_pad), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b_pad, d_pad), table.dtype),
        scratch_shapes=[pltpu.VMEM((bb, d_pad), jnp.float32)],
        interpret=interpret,
    )(ids, weights, table)
    return out[:b, :d]
