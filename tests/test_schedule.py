"""SweepSchedule (``core/sweeps.py``): block-plan resolution (full /
rotating / randomized, repeats, blocks_per_sweep truncation), bit-exact
equivalence of the FULL schedule against the unscheduled ``lax.fori_loop``
path (MF and PARAFAC epochs), and subspace isolation — a partial schedule
touches ONLY the scheduled columns."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.models import mf, parafac
from repro.core.models.parafac import TensorContext
from repro.core.sweeps import FULL_SCHEDULE, SweepSchedule
from repro.sparse.interactions import build_interactions


def _mf_problem(seed=0, n_ctx=12, n_items=9, k=8, nnz=40, alpha0=0.3):
    rng = np.random.default_rng(seed)
    cells = rng.choice(n_ctx * n_items, size=nnz, replace=False)
    data = build_interactions(
        cells // n_items, cells % n_items,
        rng.integers(1, 4, nnz), alpha0 + 1.0 + rng.random(nnz),
        n_ctx, n_items, alpha0=alpha0,
    )
    hp = mf.MFHyperParams(k=k, alpha0=alpha0, l2=0.05)
    params = mf.init(jax.random.PRNGKey(0), n_ctx, n_items, k)
    return params, data, hp


# ---------------------------------------------------------------- plans
def test_full_plan_covers_everything_in_order():
    s = SweepSchedule()
    assert s.blocks(10) == ((0, 10),)
    assert s.n_column_updates(10) == 10
    b = SweepSchedule(block=4)
    assert b.blocks(10) == ((0, 4), (4, 4), (8, 2))   # tail block truncated
    assert b.n_column_updates(10) == 10


def test_rotating_plan_rotates_with_sweep_index():
    s = SweepSchedule(kind="rotating", block=4)
    assert s.blocks(12, sweep_index=0) == ((0, 4), (4, 4), (8, 4))
    assert s.blocks(12, sweep_index=1) == ((4, 4), (8, 4), (0, 4))
    assert s.blocks(12, sweep_index=3) == s.blocks(12, sweep_index=0)
    sub = SweepSchedule(kind="rotating", block=4, blocks_per_sweep=1)
    assert sub.blocks(12, sweep_index=2) == ((8, 4),)
    assert sub.n_column_updates(12, sweep_index=2) == 4


def test_randomized_plan_is_seeded_and_complete():
    s = SweepSchedule(kind="randomized", block=3, seed=7)
    p1 = s.blocks(9, sweep_index=5)
    p2 = s.blocks(9, sweep_index=5)
    assert p1 == p2                                   # deterministic
    assert sorted(p1) == [(0, 3), (3, 3), (6, 3)]     # a permutation
    assert p1 != s.blocks(9, sweep_index=6) or True   # usually differs


def test_repeats_expand_blocks():
    s = SweepSchedule(block=3, repeats=(2, 1))
    assert s.blocks(6) == ((0, 3), (0, 3), (3, 3))    # per-ordinal, cycled
    assert s.n_column_updates(6) == 9
    with pytest.raises(ValueError):
        SweepSchedule(repeats=0)
    with pytest.raises(ValueError):
        SweepSchedule(kind="bogus")


def test_schedule_is_hashable_static_arg():
    a = SweepSchedule(kind="rotating", block=4)
    assert hash(a) == hash(SweepSchedule(kind="rotating", block=4))
    assert a != FULL_SCHEDULE


# ------------------------------------------------------- bit equivalence
def test_full_schedule_bit_matches_unscheduled_mf():
    params, data, hp = _mf_problem()
    e = mf.residuals(params, data)
    p_ref, e_ref = mf.epoch(params, data, e, hp)
    p_sch, e_sch = mf.epoch(params, data, e, hp, FULL_SCHEDULE, 0)
    assert bool((p_ref.w == p_sch.w).all())
    assert bool((p_ref.h == p_sch.h).all())
    assert bool((e_ref == e_sch).all())


def test_full_schedule_bit_matches_unscheduled_parafac():
    rng = np.random.default_rng(1)
    n_c1, n_c2, n_items, n_pairs, nnz, k = 5, 4, 6, 12, 25, 6
    chosen = rng.choice(n_c1 * n_c2, size=n_pairs, replace=False)
    tc = TensorContext(
        c1=jnp.asarray(chosen // n_c2, jnp.int32),
        c2=jnp.asarray(chosen % n_c2, jnp.int32), n_c1=n_c1, n_c2=n_c2,
    )
    cells = rng.choice(n_pairs * n_items, size=nnz, replace=False)
    data = build_interactions(
        cells // n_items, cells % n_items, rng.integers(1, 4, nnz),
        1.3 + rng.random(nnz), n_pairs, n_items, alpha0=0.3,
    )
    hp = parafac.PARAFACHyperParams(k=k, alpha0=0.3, l2=0.05)
    params = parafac.init(jax.random.PRNGKey(1), n_c1, n_c2, n_items, k)
    e = parafac.residuals(params, tc, data)
    p_ref, e_ref = parafac.epoch(params, tc, data, e, hp)
    p_sch, e_sch = parafac.epoch(params, tc, data, e, hp, FULL_SCHEDULE, 0)
    for a, b in zip(p_ref, p_sch):
        assert bool((a == b).all())
    assert bool((e_ref == e_sch).all())


def test_partial_schedule_touches_only_scheduled_columns():
    params, data, hp = _mf_problem(k=8)
    e = mf.residuals(params, data)
    sched = SweepSchedule(kind="rotating", block=2, blocks_per_sweep=1)
    p1, _ = mf.epoch(params, data, e, hp, sched, 1)   # block (2, 2) → cols 2,3
    touched = ~np.all(np.asarray(p1.w) == np.asarray(params.w), axis=0)
    np.testing.assert_array_equal(np.flatnonzero(touched), [2, 3])
    touched_h = ~np.all(np.asarray(p1.h) == np.asarray(params.h), axis=0)
    np.testing.assert_array_equal(np.flatnonzero(touched_h), [2, 3])


def test_scheduled_fit_converges():
    """A rotating partial schedule still drives the objective down — the
    subspace steps are real iCD updates, just fewer per 'epoch'."""
    params, data, hp = _mf_problem(k=8)
    obj0 = float(mf.objective(params, data, hp))
    sched = SweepSchedule(kind="rotating", block=2, blocks_per_sweep=1)
    p = mf.fit(params, data, hp, n_epochs=8, schedule=sched)
    assert float(mf.objective(p, data, hp)) < obj0
