"""iCD for Tucker Decomposition (paper §5.3.2).

Model (eq. 40): ŷ(c1,c2,i) = Σ_{f1,f2,f3} b_{f1,f2,f3} u_{c1,f1} v_{c2,f2} w_{i,f3}
with core tensor B ∈ R^{k1×k2×k3}. k3-separable (paper):

    φ_f(c1,c2) = Σ_{f1,f2} b_{f1,f2,f} u_{c1,f1} v_{c2,f2},   ψ_f(i) = w_{i,f}

Unlike the other models, ∂φ_f/∂u is non-zero for EVERY f (eq. 41) — the
nested factor loops of Lemma 3 do not collapse. Our sweep keeps them as
dense k3-dimensional contractions per context row:

    U mode, dim f1*:  D(pair,f) = Σ_{f2} b_{f1*,f2,f} v_{c2,f2}
        R'/2  = segment_{c1}( Σ_f D_f · (Φ J_I)_f )
        R''/2 = segment_{c1}( Σ_f D_f · (D J_I)_f )
        L'/2  = segment_{c1}( ᾱ e s ),  s = Σ_f D_f w_{i,f}  per observation

Core coordinates b_{f1,f2,f3} all interact through Φ, so they are swept
strictly sequentially (k1·k2·k3 scalar Newton steps — each a cheap
reduction; the paper gives the same O(k1²k2²k3²·…) regime).

Context universe: the observed pair list (the paper's sparse-context case —
its dense-context einsum shortcut changes constants, not semantics; see
DESIGN.md). Item sweep is MF-like via materialized Φ.

Fused padded path (``epoch_padded``, dispatched by ``hp.block_k`` like
``mf_padded``): the U/V mode sweeps run blocked through
``sweeps.sweep_columns`` on :class:`~repro.core.models.parafac.TensorPadded`
grids with the ``cd_block_sweep_rowpatch`` kernel — the per-row patch
tensor P[r, j, f] = segment_r(Σ_g D^f_g (D^j J_I)_g) is exactly how R'
moves when mode coordinate j takes a Newton step (Φ += Δ·D^j), so the
in-kernel Gauss–Seidel patch reproduces the per-column path; Φ itself is
patched between blocks from the returned deltas. The core sweep stays
strictly sequential (flat path); the item sweep reuses PARAFAC's fused
MF-like sweep.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import sweeps
from repro.core.gram import gram
from repro.core.implicit import explicit_loss
from repro.core.models.parafac import (
    TensorContext,
    TensorPadded,
    _item_sweep,
    _item_sweep_padded,
    pad_tensor_groups,
)
from repro.core.padded import append_sentinel_row
from repro.kernels import vmem
from repro.kernels.cd_sweep.ops import (
    cd_block_sweep_rowpatch,
    cd_block_sweep_rowpatch_gather,
)
from repro.sparse.interactions import Interactions
from repro.sparse.segment import segment_sum

__all__ = ["TuckerParams", "TuckerHyperParams", "pad_tensor_groups",
           "init", "phi", "export_psi", "build_phi", "predict", "epoch",
           "epoch_padded", "residuals", "objective", "fit"]


class TuckerParams(NamedTuple):
    u: jax.Array  # (n_c1, k1)
    v: jax.Array  # (n_c2, k2)
    w: jax.Array  # (n_items, k3)
    b: jax.Array  # (k1, k2, k3) core tensor


@dataclasses.dataclass(frozen=True)
class TuckerHyperParams:
    k1: int
    k2: int
    k3: int
    alpha0: float = 1.0
    l2: float = 0.1
    l2_core: float = 0.1
    eta: float = 1.0
    implementation: str = "xla"
    block_k: int = 0  # columns per fused cd_sweep dispatch (epoch_padded):
    #                   0 = auto (min(mode k, 8)), 1 = per-column baseline
    psi_dispatch: str = "gather"  # fused-path Ψ routing: 'gather' =
    #                   in-kernel gather of the flat pseudo-ψ slab (no
    #                   (n, k_b, D_pad) scatter_blk intermediate; auto-
    #                   fallback on VMEM overflow), 'pregather' = host-side
    #                   scatter/pre-gather (the PR 2 path)

    # _item_sweep compatibility (it reads hp.k and hp.alpha0/l2/eta)
    @property
    def k(self) -> int:
        return self.k3


def init(key, n_c1, n_c2, n_items, k1, k2, k3, sigma=0.1) -> TuckerParams:
    ka, kb, kc, kd = jax.random.split(key, 4)
    return TuckerParams(
        u=sigma * jax.random.normal(ka, (n_c1, k1), jnp.float32),
        v=sigma * jax.random.normal(kb, (n_c2, k2), jnp.float32),
        w=sigma * jax.random.normal(kc, (n_items, k3), jnp.float32),
        b=sigma * jax.random.normal(kd, (k1, k2, k3), jnp.float32),
    )


def phi(params: TuckerParams, tc: TensorContext) -> jax.Array:
    """Φ (n_ctx, k3) over the observed pair list."""
    up = jnp.take(params.u, tc.c1, axis=0)  # (n, k1)
    vp = jnp.take(params.v, tc.c2, axis=0)  # (n, k2)
    return jnp.einsum("na,nb,abf->nf", up, vp, params.b)


def predict(params: TuckerParams, c1, c2, item) -> jax.Array:
    up = jnp.take(params.u, c1, axis=0)
    vp = jnp.take(params.v, c2, axis=0)
    wp = jnp.take(params.w, item, axis=0)
    return jnp.einsum("na,nb,nf,abf->n", up, vp, wp, params.b)


def export_psi(params: TuckerParams) -> jax.Array:
    """ψ table for the retrieval engine: (n_items, k3) — Tucker is
    k3-separable with ψ_f(i) = w_{i,f}."""
    return params.w


def build_phi(params: TuckerParams, c1: jax.Array, c2: jax.Array) -> jax.Array:
    """φ rows for query context pairs: the core-contracted
    φ_f = Σ_{f1,f2} b_{f1,f2,f} u_{c1,f1} v_{c2,f2} (B, k3)."""
    up = jnp.take(params.u, c1, axis=0)
    vp = jnp.take(params.v, c2, axis=0)
    return jnp.einsum("na,nb,abf->nf", up, vp, params.b)


def _mode_sweep(
    side,            # U (n_c1,k1) or V (n_c2,k2)
    b_slice_fn,      # f* -> (k_other, k3) core slice for this mode
    partner_of_pair, # c2 (U mode) or c1 (V mode) per pair
    partner,         # V or U
    group_of_pair,   # c1 or c2 per pair
    n_side, k_side,
    phi_m, j_i, data, w_items, e, hp,
    schedule=None, sweep_index=0,
):
    pair_of_nnz = data.ctx
    grp_nnz = jnp.take(group_of_pair, pair_of_nnz)

    def body(fs, carry):
        side_m, phi_m, e = carry
        bsl = b_slice_fn(fs)                                   # (k_other, k3)
        pp = jnp.take(partner, partner_of_pair, axis=0)        # (n_ctx, k_other)
        d = pp @ bsl                                           # (n_ctx, k3)
        s = jnp.sum(
            jnp.take(d, pair_of_nnz, axis=0) * jnp.take(w_items, data.item, axis=0),
            axis=1,
        )                                                      # (nnz,)
        lp = segment_sum(data.alpha * e * s, grp_nnz, n_side)
        lpp = segment_sum(data.alpha * s * s, grp_nnz, n_side)
        rp = segment_sum(jnp.sum(d * (phi_m @ j_i), axis=1), group_of_pair, n_side)
        rpp = segment_sum(jnp.sum(d * (d @ j_i), axis=1), group_of_pair, n_side)
        s_col = sweeps.take_col(side_m, fs)
        delta = sweeps.newton_delta(
            sweeps.NewtonParts(lp + hp.alpha0 * rp, lpp + hp.alpha0 * rpp),
            s_col, hp.l2, hp.eta,
        )
        phi_m = phi_m + jnp.take(delta, group_of_pair)[:, None] * d
        e = e + jnp.take(delta, grp_nnz) * s
        return sweeps.put_col(side_m, fs, s_col + delta), phi_m, e

    return sweeps.sweep_columns(
        k_side, body, (side, phi_m, e),
        schedule=schedule, sweep_index=sweep_index,
    )


def _mode_sweep_padded(
    side,            # U (n_c1,k1) or V (n_c2,k2)
    b_blk_fn,        # (f0, kb) -> (kb, k_other, k3) static core slab
    partner_of_pair, # c2 (U mode) or c1 (V mode) per pair
    partner,         # V or U
    group_of_pair,   # c1 or c2 per pair
    n_side, k_side,
    phi_m, j_i, data, w_items, pg, e_pad, hp, k_b,
):
    """Fused Tucker mode sweep: k_b columns per ``cd_block_sweep_rowpatch``
    dispatch. Per block the pseudo-ψ s^f = Σ_g D^f_g w_{i,g} is scattered
    onto the padded grid; slab state is R'/2 = segment(Σ_g D^f_g (Φ J)_g)
    and the per-row patch P[r, j, f] = segment(Σ_g D^f_g (D^j J)_g) (diag =
    R''/2). D^f is constant during the sweep (partner/core/items fixed), so
    only Φ — patched from the returned deltas — and the in-kernel e/R'
    state move. The flat pseudo-ψ ``s_nnz`` rides into the gather kernel as
    a slab (+ zero sentinel row) with ``pg.flat_ids`` by default; the
    ``scatter_blk`` tile only exists on the pregather/VMEM fallback."""
    pair_of_nnz = data.ctx
    w_nnz = jnp.take(w_items, data.item, axis=0)                 # (nnz, k3)
    use_gather, _ = vmem.resolve_cd_sweep_dispatch(
        pg.d_pad, k_b, data.nnz + 1, n_rows=n_side,
        prefer_gather=sweeps.resolve_psi_dispatch(hp.psi_dispatch),
    )

    def block_body(f0, kb, carry):
        side_m, phi_m, e_pad = carry
        blk = slice(f0, f0 + kb)
        bsl = b_blk_fn(f0, kb)                                   # (kb, k_other, k3)
        pp = jnp.take(partner, partner_of_pair, axis=0)          # (n_pairs, k_other)
        d_blk = jnp.einsum("no,jof->njf", pp, bsl)               # (n_pairs, kb, k3)
        r1_blk = segment_sum(
            jnp.einsum("njf,nf->nj", d_blk, phi_m @ j_i), group_of_pair, n_side
        )
        dj = jnp.einsum("njf,fg->njg", d_blk, j_i)
        p_blk = segment_sum(
            jnp.einsum("njg,nig->nji", dj, d_blk), group_of_pair, n_side
        )
        s_nnz = jnp.einsum(
            "njf,nf->nj", jnp.take(d_blk, pair_of_nnz, axis=0), w_nnz
        )
        if use_gather:
            w_new, e_pad = cd_block_sweep_rowpatch_gather(
                append_sentinel_row(s_nnz), pg.flat_ids, pg.alpha_pad,
                e_pad, side_m[:, blk], r1_blk, p_blk,
                alpha0=hp.alpha0, l2=hp.l2, eta=hp.eta,
            )
        else:
            psi_blk = pg.scatter_blk(s_nnz)
            w_new, e_pad = cd_block_sweep_rowpatch(
                psi_blk, pg.alpha_pad, e_pad, side_m[:, blk], r1_blk, p_blk,
                alpha0=hp.alpha0, l2=hp.l2, eta=hp.eta,
            )
        delta = w_new - side_m[:, blk]
        phi_m = phi_m + jnp.einsum(
            "nj,njf->nf", jnp.take(delta, group_of_pair, axis=0), d_blk
        )
        return side_m.at[:, blk].set(w_new), phi_m, e_pad

    return sweeps.sweep_columns(
        k_side, None, (side, phi_m, e_pad), block=k_b, block_body=block_body
    )


def core_sweep(params, phi_m, j_i, tc, data, e, hp):
    """Sequential core-tensor sweep: scalar Newton step per b_{f1,f2,f3}."""
    u, v, w, b = params
    k1, k2, k3 = b.shape
    pair_of_nnz = data.ctx
    w_nnz_cols = lambda f3: jnp.take(sweeps.take_col(w, f3), data.item)

    def body(idx, carry):
        b, phi_m, e = carry
        f1 = idx // (k2 * k3)
        f2 = (idx // k3) % k2
        f3 = idx % k3
        g = jnp.take(sweeps.take_col(u, f1), tc.c1) * jnp.take(
            sweeps.take_col(v, f2), tc.c2
        )                                                       # (n_ctx,)
        w_col = w_nnz_cols(f3)                                  # (nnz,)
        g_nnz = jnp.take(g, pair_of_nnz)
        lp = jnp.sum(data.alpha * e * g_nnz * w_col)
        lpp = jnp.sum(data.alpha * (g_nnz * w_col) ** 2)
        rp = jnp.dot(phi_m.T @ g, sweeps.take_col(j_i, f3))
        rpp = j_i[f3, f3] * jnp.sum(g * g)
        b_val = b[f1, f2, f3]
        num = lp + hp.alpha0 * rp + hp.l2_core * b_val
        den = lpp + hp.alpha0 * rpp + hp.l2_core
        delta = -hp.eta * num / jnp.maximum(den, 1e-12)
        b = b.at[f1, f2, f3].add(delta)
        phi_m = sweeps.put_col(phi_m, f3, sweeps.take_col(phi_m, f3) + delta * g)
        e = e + delta * g_nnz * w_col
        return b, phi_m, e

    b, phi_m, e = jax.lax.fori_loop(0, k1 * k2 * k3, body, (b, phi_m, e))
    return b, phi_m, e


@partial(jax.jit, static_argnames=("hp", "schedule", "sweep_index"))
def epoch(
    params: TuckerParams,
    tc: TensorContext,
    data: Interactions,
    e: jax.Array,
    hp: TuckerHyperParams,
    schedule=None,
    sweep_index: int = 0,
    weights=None,
) -> Tuple[TuckerParams, jax.Array]:
    """One iCD epoch: U sweep → V sweep → core sweep → item (W) sweep.

    A ``schedule`` restricts the FACTOR-mode sweeps (per-mode k1/k2/k3
    column plans); the scalar core sweep always runs in full.
    ``weights`` (optional, (nnz,) ctx-major) folds per-interaction
    confidence into α exactly; ``None`` traces the identical program."""
    if weights is not None:
        data = dataclasses.replace(data, alpha=data.alpha * weights)
    u, v, w, b = params
    j_i = gram(w, implementation=hp.implementation)
    phi_m = phi(params, tc)

    u, phi_m, e = _mode_sweep(
        u, lambda f1: jax.lax.dynamic_slice_in_dim(b, f1, 1, axis=0)[0],
        tc.c2, v, tc.c1, u.shape[0], hp.k1, phi_m, j_i, data, w, e, hp,
        schedule, sweep_index,
    )
    v, phi_m, e = _mode_sweep(
        v, lambda f2: jax.lax.dynamic_slice_in_dim(b, f2, 1, axis=1)[:, 0],
        tc.c1, u, tc.c2, v.shape[0], hp.k2, phi_m, j_i, data, w, e, hp,
        schedule, sweep_index,
    )
    b, phi_m, e = core_sweep(TuckerParams(u, v, w, b), phi_m, j_i, tc, data, e, hp)

    j_c = gram(phi_m)
    e_t = sweeps.to_item_major(e, data.t_perm)
    alpha_t = sweeps.to_item_major(data.alpha, data.t_perm)
    phi_cols = lambda f: jnp.take(sweeps.take_col(phi_m, f), data.t_ctx)
    w, e_t = _item_sweep(
        w, j_c, phi_cols, data, e_t, alpha_t, hp, schedule, sweep_index
    )
    e = sweeps.to_ctx_major(e_t, data.t_perm)
    return TuckerParams(u, v, w, b), e


@partial(jax.jit, static_argnames=("hp",), donate_argnums=(4,))
def epoch_padded(
    params: TuckerParams,
    tc: TensorContext,
    data: Interactions,
    padded: TensorPadded,
    e: jax.Array,
    hp: TuckerHyperParams,
    weights=None,
) -> Tuple[TuckerParams, jax.Array]:
    """Fused-kernel iCD epoch on the padded layouts; same sweep order and
    fixed point as :func:`epoch` (parity-tested). U/V mode sweeps and the
    MF-like item sweep run blocked; the core sweep is inherently sequential
    and stays on the flat path. ``weights`` rebuilds all three group α
    grids (and the flat α the core sweep reads)."""
    if weights is not None:
        a_eff = data.alpha * weights
        data = dataclasses.replace(data, alpha=a_eff)
        padded = dataclasses.replace(
            padded, g1=padded.g1.with_alpha(a_eff),
            g2=padded.g2.with_alpha(a_eff), gi=padded.gi.with_alpha(a_eff),
        )
    u, v, w, b = params
    j_i = gram(w, implementation=hp.implementation)
    phi_m = phi(params, tc)

    e_g = padded.g1.scatter(e)
    u, phi_m, e_g = _mode_sweep_padded(
        u, lambda f0, kb: b[f0:f0 + kb],
        tc.c2, v, tc.c1, u.shape[0], hp.k1,
        phi_m, j_i, data, w, padded.g1, e_g, hp,
        sweeps.resolve_block_k(hp.block_k, hp.k1),
    )
    e = padded.g1.gather(e_g)

    e_g = padded.g2.scatter(e)
    v, phi_m, e_g = _mode_sweep_padded(
        v, lambda f0, kb: jnp.moveaxis(b[:, f0:f0 + kb], 1, 0),
        tc.c1, u, tc.c2, v.shape[0], hp.k2,
        phi_m, j_i, data, w, padded.g2, e_g, hp,
        sweeps.resolve_block_k(hp.block_k, hp.k2),
    )
    e = padded.g2.gather(e_g)

    b, phi_m, e = core_sweep(TuckerParams(u, v, w, b), phi_m, j_i, tc, data, e, hp)

    j_c = gram(phi_m)
    e_g = padded.gi.scatter(e)
    w, e_g = _item_sweep_padded(
        w, j_c, phi_m, padded, e_g, hp, sweeps.resolve_block_k(hp.block_k, hp.k3)
    )
    e = padded.gi.gather(e_g)
    return TuckerParams(u, v, w, b), e


def residuals(params: TuckerParams, tc: TensorContext, data: Interactions) -> jax.Array:
    return sweeps.residuals_from_factors(
        phi(params, tc), params.w, data.ctx, data.item, data.y
    )


def objective(params: TuckerParams, tc: TensorContext, data: Interactions,
              hp: TuckerHyperParams) -> jax.Array:
    e = residuals(params, tc, data)
    reg = jnp.sum(gram(phi(params, tc)) * gram(params.w))
    sq = jnp.sum(params.u**2) + jnp.sum(params.v**2) + jnp.sum(params.w**2)
    return (
        explicit_loss(e, data.alpha)
        + hp.alpha0 * reg
        + hp.l2 * sq
        + hp.l2_core * jnp.sum(params.b**2)
    )


def fit(params, tc, data, hp, n_epochs, callback=None, schedule=None,
        weights=None):
    e = residuals(params, tc, data)
    for ep in range(n_epochs):
        params, e = epoch(params, tc, data, e, hp, schedule, ep, weights)
        if callback is not None:
            callback(ep, params)
    return params
