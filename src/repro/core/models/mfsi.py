"""iCD for Matrix Factorization with Side Information (paper §5.2.1, Alg. 3).

Model (eq. 20): ŷ(c,i) = x_c W (z_i H)ᵀ with feature embeddings
W ∈ R^{p×k}, H ∈ R^{p'×k}. k-separable via φ_f(c) = Σ_l x_{c,l} w_{l,f}
(eq. 21); gradients sparse in f (eq. 22), so

    R'(w_{l*,f*})  = 2 Σ_f J_I(f,f*) Σ_c x_{c,l*} φ_f(c)        (eq. 23)
    R''(w_{l*,f*}) = 2 J_I(f*,f*) Σ_c x_{c,l*}²                 (eq. 24)

and Φ is kept in sync with the eq. (25) incremental update. Per-epoch cost
O(k²(N_Z(X)+N_Z(Z))) for the implicit part — the paper's bound.

TPU sweep layout (DESIGN.md §3): coordinates of a one-hot field never share
a row, so a whole field × one dimension updates as a single vectorized
Newton step. The explicit part uses three per-context caches that are
patched incrementally instead of recomputed:

    q_c  = Σ_{i∈S_c} ᾱ e ψ_{f*}(i)     (patched: Δq = Δφ_{f*}·p2)
    p2_c = Σ_{i∈S_c} ᾱ ψ_{f*}(i)²      (constant during the side sweep)
    r_c  = Σ_f J(f,f*) φ_f(c)          (patched: Δr = Δφ_{f*}·J(f*,f*))

One-hot (categorical) fields update EXACTLY — no two features of such a
field share a context row, so the vectorized step equals scalar CD. Features
of a multi-hot (bag) field DO share rows; updating them in parallel is not
scalar CD. Two documented modes (the one deliberate deviation from the
paper, forced by TPU parallelism — DESIGN.md §3):

  - ``jacobi`` (default): one damped (η≈0.5) parallel Newton step per field
    with full row sums — parallel-CD à la Bradley et al.; converges in all
    our experiments and is the production mode.
  - ``slot``: sequential over bag slots; each slot update uses only the rows
    where the feature occupies that slot (fresh residuals between slots) —
    a mini-batched CD flavour that tolerates η=1.

Fused padded path (``epoch_padded`` over ``mf_padded.PaddedInteractions``,
dispatched by ``hp.block_k``): per block of ``k_b`` dimensions ONE
``cd_slab_reduce`` pass streams e/α and yields the q/p2 caches for every
block column plus the cross-dimension coupling slab P (q_f' moves by
Δφ_j·P[·,j,f'] when dimension j's features step — the same linearity as the
eq. 25 within-dimension patch), the field-level Newton steps run in XLA on
those slabs, and ONE ``cd_resid_patch`` applies the rank-k_b residual
patch. e-traffic per sweep drops from 2k streams to 2⌈k/k_b⌉.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import sweeps
from repro.core.design import Design, design_matmul, take_rows
from repro.core.gram import gram
from repro.core.implicit import implicit_objective
from repro.core.models.mf_padded import (
    PaddedInteractions,
    pad_interactions,
    reweight_padded,
    scatter_ctx_major,
    transfer_ctx_to_item,
    transfer_item_to_ctx,
)
from repro.kernels import vmem
from repro.kernels.cd_sweep.ops import (
    cd_resid_patch,
    cd_resid_patch_gather,
    cd_slab_reduce,
    cd_slab_reduce_gather,
)
from repro.sparse.interactions import Interactions
from repro.sparse.segment import segment_sum

__all__ = ["MFSIParams", "MFSIHyperParams", "pad_interactions", "init",
           "phi", "psi", "export_psi", "build_phi", "predict", "epoch",
           "epoch_padded", "residuals", "residuals_padded", "objective",
           "fit"]


class MFSIParams(NamedTuple):
    w: jax.Array  # (p_ctx, k)  stacked context-feature embeddings
    h: jax.Array  # (p_item, k) stacked item-feature embeddings


@dataclasses.dataclass(frozen=True)
class MFSIHyperParams:
    k: int
    alpha0: float = 1.0
    l2: float = 0.1
    eta: float = 1.0
    multi_hot_mode: str = "jacobi"  # 'jacobi' | 'slot'
    jacobi_eta: float = 0.5
    implementation: str = "xla"
    block_k: int = 0  # dims per fused slab-reduce/resid-patch dispatch on
    #                   the padded layout (epoch_padded): 0 = auto
    #                   (min(k, 8)), 1 = per-dimension baseline
    psi_dispatch: str = "gather"  # fused-path Ψ routing: 'gather' =
    #                   in-kernel gather (no (n, k_b, D_pad) intermediate;
    #                   auto-fallback on VMEM overflow), 'pregather' =
    #                   host-side pre-gathered tile


def init(key: jax.Array, p_ctx: int, p_item: int, k: int, sigma: float = 0.1) -> MFSIParams:
    kw, kh = jax.random.split(key)
    return MFSIParams(
        w=sigma * jax.random.normal(kw, (p_ctx, k), dtype=jnp.float32),
        h=sigma * jax.random.normal(kh, (p_item, k), dtype=jnp.float32),
    )


def phi(params: MFSIParams, x: Design) -> jax.Array:
    return design_matmul(x, params.w)


def psi(params: MFSIParams, z: Design) -> jax.Array:
    return design_matmul(z, params.h)


def export_psi(params: MFSIParams, z: Design) -> jax.Array:
    """ψ table for the retrieval engine: Ψ = Z·H (n_items, k), one row per
    catalogue item of the item design ``z``."""
    return psi(params, z)


def build_phi(params: MFSIParams, x: Design, rows: Optional[jax.Array] = None) -> jax.Array:
    """φ rows for query contexts: Φ = X·W over ``rows`` of the context
    design ``x`` (rows are gathered BEFORE the matmul — a query batch is
    O(B·k), not a full-design pass); ⟨φ, ψ_i⟩ = ŷ (eq. 20)."""
    return phi(params, x if rows is None else take_rows(x, rows))


def predict(params: MFSIParams, x: Design, z: Design, ctx, item) -> jax.Array:
    ph, ps = phi(params, x), psi(params, z)
    return jnp.sum(jnp.take(ph, ctx, axis=0) * jnp.take(ps, item, axis=0), axis=-1)


def _field_layer_update(
    table_col, phi_col, q, r_vec, p2, jff,
    ids_g, xw, rows, vocab, offset, hp, eta,
):
    """One vectorized Newton update of a one-hot layer (field or bag slot).

    ids_g:  (n,) global feature ids for this layer (offset applied)
    xw:     (n,) feature values x_{c,l} (0 ⇒ row inactive in this layer)
    rows:   (n,) context row per entry (identity for bag=1 fields)

    Patches the per-context caches (eq. 25 and DESIGN.md §3) but NOT the
    residual cache — the caller owns the e layout (flat per-nnz vs padded
    grid) and applies ``dphi_rows`` there (per layer on the flat path, one
    fused rank-k_b ``cd_resid_patch`` per block on the padded path).
    """
    w_layer = table_col[offset : offset + vocab]
    lp = segment_sum(xw * jnp.take(q, rows), ids_g - offset, vocab)
    lpp = segment_sum(xw * xw * jnp.take(p2, rows), ids_g - offset, vocab)
    rp = segment_sum(xw * jnp.take(r_vec, rows), ids_g - offset, vocab)
    rpp = jff * segment_sum(xw * xw, ids_g - offset, vocab)
    num = lp + hp.alpha0 * rp + hp.l2 * w_layer
    den = lpp + hp.alpha0 * rpp + hp.l2
    delta = -eta * num / jnp.maximum(den, 1e-12)

    # scatter the step back + incremental patches (eq. 25 and DESIGN.md §3)
    table_col = table_col.at[offset : offset + vocab].add(delta)
    dphi_rows = segment_sum(xw * jnp.take(delta, ids_g - offset), rows, q.shape[0])
    phi_col = phi_col + dphi_rows
    q = q + dphi_rows * p2
    r_vec = r_vec + dphi_rows * jff
    return table_col, phi_col, q, r_vec, dphi_rows


def _field_layers(design: Design, hp) -> list:
    """Flatten the field loop into (ids, weights, rows, vocab, offset, eta)
    layers: one-hot fields (and 'slot' mode bags) update per slot — exact
    CD; 'jacobi' bags update whole-bag in one damped parallel step."""
    n_rows = design.n_rows
    row_idx = jnp.arange(n_rows, dtype=jnp.int32)
    layers = []
    for field in design.fields:
        gids = design.global_ids(field)
        if field.one_hot or hp.multi_hot_mode == "slot":
            for j in range(field.bag):
                layers.append((gids[:, j], field.weights[:, j], row_idx,
                               field.vocab, field.offset, hp.eta))
        else:
            layers.append((gids.reshape(-1), field.weights.reshape(-1),
                           jnp.repeat(row_idx, field.bag),
                           field.vocab, field.offset, hp.jacobi_eta))
    return layers


def _side_sweep(
    table: jax.Array,       # (p, k) this side's feature embeddings
    phi_m: jax.Array,       # (n_rows, k) this side's Φ (kept in sync)
    other_psi: jax.Array,   # (n_other, k) opposite side's Ψ (fixed)
    other_j: jax.Array,     # (k, k) Gram of Ψ
    design: Design,
    rows_nnz: jax.Array,    # (nnz,) this-side row per observation
    other_nnz_ids: jax.Array,  # (nnz,) opposite-side row per observation
    alpha: jax.Array,
    e: jax.Array,
    hp: MFSIHyperParams,
    schedule=None,
    sweep_index: int = 0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    n_rows = design.n_rows
    layers = _field_layers(design, hp)

    def dim_body(f, carry):
        table, phi_m, e = carry
        psi_col = sweeps.take_col(other_psi, f)
        psi_nnz = jnp.take(psi_col, other_nnz_ids)
        p2 = segment_sum(alpha * psi_nnz * psi_nnz, rows_nnz, n_rows)
        q = segment_sum(alpha * e * psi_nnz, rows_nnz, n_rows)
        r_vec = phi_m @ sweeps.take_col(other_j, f)
        jff = other_j[f, f]
        table_col = sweeps.take_col(table, f)
        phi_col = sweeps.take_col(phi_m, f)

        # one-hot layers are EXACT (features never share a row); multi-hot
        # bags run either sequential 'slot' layers (fresh residuals) or one
        # damped 'jacobi' parallel step — see _field_layers.
        for ids_g, xw, rows, vocab, offset, eta in layers:
            table_col, phi_col, q, r_vec, dphi_rows = _field_layer_update(
                table_col, phi_col, q, r_vec, p2, jff,
                ids_g, xw, rows, vocab, offset, hp, eta,
            )
            e = e + jnp.take(dphi_rows, rows_nnz) * psi_nnz

        table = sweeps.put_col(table, f, table_col)
        phi_m = sweeps.put_col(phi_m, f, phi_col)
        return table, phi_m, e

    table, phi_m, e = sweeps.sweep_columns(
        hp.k, dim_body, (table, phi_m, e),
        schedule=schedule, sweep_index=sweep_index,
    )
    return table, phi_m, e


def _side_sweep_padded(
    table: jax.Array,       # (p, k) this side's feature embeddings
    phi_m: jax.Array,       # (n_rows, k) this side's Φ (kept in sync)
    other_psi: jax.Array,   # (n_other, k) opposite side's Ψ (fixed)
    other_j: jax.Array,     # (k, k) Gram of Ψ
    design: Design,
    ids_pad: jax.Array,     # (n_rows, d_pad) opposite-side row ids
    alpha_pad: jax.Array,   # (n_rows, d_pad), 0 on padding
    e_pad: jax.Array,       # (n_rows, d_pad) residual grid
    hp: MFSIHyperParams,
    k_b: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused side sweep: one ``cd_slab_reduce`` per block feeds the
    field-level Newton steps of all k_b dimensions (q patched across block
    columns through the coupling slab P), one ``cd_resid_patch`` closes the
    block. Same fixed point as :func:`_side_sweep` (parity-tested).

    Ψ routing: in-kernel gather by default (the ψ slab ``other_psi[:, blk]``
    rides into the kernels with the id grid; no ``(n, kb, d_pad)`` HBM
    tile), pre-gathered when ``hp.psi_dispatch='pregather'`` or the slab
    busts the VMEM budget."""
    n_rows = design.n_rows
    layers = _field_layers(design, hp)
    use_gather, _ = vmem.resolve_cd_sweep_dispatch(
        ids_pad.shape[1], k_b, other_psi.shape[0], n_rows=n_rows,
        hold_tile=True, prefer_gather=sweeps.resolve_psi_dispatch(hp.psi_dispatch),
    )

    def block_body(f0, kb, carry):
        table, phi_m, e_pad = carry
        blk = slice(f0, f0 + kb)
        if use_gather:
            psi_tab = other_psi[:, blk]                    # (n_other, kb)
            q_slab, p_slab = cd_slab_reduce_gather(
                psi_tab, ids_pad, alpha_pad, e_pad
            )
        else:
            psi_blk = jnp.moveaxis(
                jnp.take(other_psi[:, blk], ids_pad, axis=0), -1, 1
            )                                              # (n, kb, d_pad)
            q_slab, p_slab = cd_slab_reduce(psi_blk, alpha_pad, e_pad)
        dphi_cols = []
        for j in range(kb):
            f = f0 + j
            q = q_slab[:, j]
            p2 = p_slab[:, j, j]
            r_vec = phi_m @ other_j[:, f]
            jff = other_j[f, f]
            table_col = table[:, f]
            phi_col = phi_m[:, f]
            dphi_tot = jnp.zeros((n_rows,), jnp.float32)
            for ids_g, xw, rows, vocab, offset, eta in layers:
                table_col, phi_col, q, r_vec, dphi_rows = _field_layer_update(
                    table_col, phi_col, q, r_vec, p2, jff,
                    ids_g, xw, rows, vocab, offset, hp, eta,
                )
                dphi_tot = dphi_tot + dphi_rows
            table = table.at[:, f].set(table_col)
            phi_m = phi_m.at[:, f].set(phi_col)
            if j + 1 < kb:  # Δe = Δφ_j·ψ_j moves later columns' q caches
                q_slab = q_slab.at[:, j + 1:kb].add(
                    dphi_tot[:, None] * p_slab[:, j, j + 1:kb]
                )
            dphi_cols.append(dphi_tot)
        dphi_blk = jnp.stack(dphi_cols, axis=1)
        if use_gather:
            e_pad = cd_resid_patch_gather(psi_tab, ids_pad, e_pad, dphi_blk)
        else:
            e_pad = cd_resid_patch(psi_blk, e_pad, dphi_blk)
        return table, phi_m, e_pad

    return sweeps.sweep_columns(
        hp.k, None, (table, phi_m, e_pad), block=k_b, block_body=block_body
    )


@partial(jax.jit, static_argnames=("hp", "schedule", "sweep_index"))
def epoch(
    params: MFSIParams,
    x: Design,
    z: Design,
    data: Interactions,
    e: jax.Array,
    hp: MFSIHyperParams,
    schedule=None,
    sweep_index: int = 0,
    weights: Optional[jax.Array] = None,
) -> Tuple[MFSIParams, jax.Array]:
    """One iCD epoch: context-feature sweep, then item-feature sweep, over
    the scheduled columns (``schedule=None`` = full pass).

    ``weights`` (optional, (nnz,) ctx-major) folds per-interaction
    confidence into α exactly (α is purely multiplicative in the explicit
    parts); ``None`` traces the identical unweighted program."""
    if weights is not None:
        data = dataclasses.replace(data, alpha=data.alpha * weights)
    w, h = params
    phi_m = design_matmul(x, w)
    psi_m = design_matmul(z, h)

    j_i = gram(psi_m, implementation=hp.implementation)
    w, phi_m, e = _side_sweep(
        w, phi_m, psi_m, j_i, x, data.ctx, data.item, data.alpha, e, hp,
        schedule, sweep_index,
    )

    j_c = gram(phi_m, implementation=hp.implementation)
    e_t = sweeps.to_item_major(e, data.t_perm)
    alpha_t = sweeps.to_item_major(data.alpha, data.t_perm)
    h, psi_m, e_t = _side_sweep(
        h, psi_m, phi_m, j_c, z, data.t_item, data.t_ctx, alpha_t, e_t, hp,
        schedule, sweep_index,
    )
    e = sweeps.to_ctx_major(e_t, data.t_perm)
    return MFSIParams(w, h), e


@partial(jax.jit, static_argnames=("hp",), donate_argnums=(4,))
def epoch_padded(
    params: MFSIParams,
    x: Design,
    z: Design,
    pdata: PaddedInteractions,
    e_pad: jax.Array,
    hp: MFSIHyperParams,
    weights: Optional[jax.Array] = None,
) -> Tuple[MFSIParams, jax.Array]:
    """Fused iCD epoch over the dual padded layout (``mf_padded``'s
    ``PaddedInteractions``); carries the ctx-major padded residual grid.
    Same sweep order and fixed point as :func:`epoch` (parity-tested).
    ``weights`` folds into both padded α grids (see
    :func:`repro.core.models.mf_padded.reweight_padded`)."""
    if weights is not None:
        pdata = reweight_padded(pdata, weights)
    w, h = params
    k_b = sweeps.resolve_block_k(hp.block_k, hp.k)
    phi_m = design_matmul(x, w)
    psi_m = design_matmul(z, h)

    j_i = gram(psi_m, implementation=hp.implementation)
    w, phi_m, e_pad = _side_sweep_padded(
        w, phi_m, psi_m, j_i, x, pdata.item_ids, pdata.alpha_c, e_pad, hp, k_b
    )

    e_pad_i = transfer_ctx_to_item(pdata, e_pad)

    j_c = gram(phi_m, implementation=hp.implementation)
    h, psi_m, e_pad_i = _side_sweep_padded(
        h, psi_m, phi_m, j_c, z, pdata.ctx_ids, pdata.alpha_i, e_pad_i, hp, k_b
    )
    e_pad = transfer_item_to_ctx(pdata, e_pad_i)
    return MFSIParams(w, h), e_pad


def residuals_padded(
    params: MFSIParams, x: Design, z: Design, data: Interactions,
    pdata: PaddedInteractions,
) -> jax.Array:
    """ŷ−ȳ on the ctx-major padded grid (0 on padding)."""
    return scatter_ctx_major(pdata, residuals(params, x, z, data))


def residuals(params: MFSIParams, x: Design, z: Design, data: Interactions) -> jax.Array:
    return sweeps.residuals_from_factors(
        phi(params, x), psi(params, z), data.ctx, data.item, data.y
    )


def objective(params: MFSIParams, x: Design, z: Design, data: Interactions,
              hp: MFSIHyperParams) -> jax.Array:
    e = residuals(params, x, z, data)
    sq = jnp.sum(params.w**2) + jnp.sum(params.h**2)
    return implicit_objective(phi(params, x), psi(params, z), e, data, hp.alpha0, hp.l2, sq)


def fit(params, x, z, data, hp, n_epochs, callback=None, schedule=None,
        weights=None):
    e = residuals(params, x, z, data)
    for ep in range(n_epochs):
        params, e = epoch(params, x, z, data, e, hp, schedule, ep, weights)
        if callback is not None:
            callback(ep, params)
    return params
