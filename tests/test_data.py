"""Data pipeline: synthetic generator structure + hosted loaders + design."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# property tests need hypothesis (CI installs it); only they skip without it
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in bare containers
    HAVE_HYPOTHESIS = False

from repro.core.design import design_matmul, make_design, to_dense
from repro.data import loader
from repro.data.loader import (
    frequency_interactions,
    interaction_stream,
    load_movielens,
    split_by_time,
)
from repro.data.synthetic import make_implicit_dataset


def test_synthetic_dataset_structure():
    ds = make_implicit_dataset(n_users=50, n_items=40, seed=3)
    assert ds.events.shape[1] == 3
    assert ds.events[:, 0].max() < 50 and ds.events[:, 1].max() < 40
    # time-ordered
    assert np.all(np.diff(ds.events[:, 2]) > 0)
    # every user has events within the configured range
    hists = ds.user_histories()
    assert len(hists) == 50
    assert all(len(h) >= 1 for h in hists)
    # attributes in range
    assert ds.age.max() < ds.n_age and ds.country.max() < ds.n_country


def test_attribute_signal_exists():
    """Users sharing attributes must have more similar item distributions
    than random pairs — the mechanism behind the Figure-7 reproduction."""
    ds = make_implicit_dataset(n_users=300, n_items=200, attr_strength=0.95,
                               pop_strength=0.3, taste_strength=2.5, seed=0)
    hists = ds.user_histories()

    def dist(u):
        v = np.bincount(hists[u], minlength=200).astype(float)
        return v / max(v.sum(), 1)

    key = [(a, c) for a, c in zip(ds.age, ds.country)]
    same, diff = [], []
    rng = np.random.default_rng(0)
    for _ in range(3000):
        u, v = rng.integers(0, 300, 2)
        if u == v:
            continue
        sim = float(dist(u) @ dist(v))
        (same if key[u] == key[v] else diff).append(sim)
    if len(same) > 10:
        assert np.mean(same) > np.mean(diff)


def test_interaction_stream_replays_event_log_in_order():
    ds = make_implicit_dataset(n_users=40, n_items=30, seed=7)
    batches = list(interaction_stream(ds, batch_events=64))
    # finite replay: every event appears exactly once, in arrival order
    assert sum(len(b["item"]) for b in batches) == len(ds.events)
    assert all(len(b["item"]) == 64 for b in batches[:-1])
    ctx = np.concatenate([b["ctx"] for b in batches])
    item = np.concatenate([b["item"] for b in batches])
    t = np.concatenate([b["t"] for b in batches])
    np.testing.assert_array_equal(ctx, ds.events[:, 0])
    np.testing.assert_array_equal(item, ds.events[:, 1])
    np.testing.assert_array_equal(t, ds.events[:, 2])
    assert np.all(np.diff(t) > 0)
    # start= resumes mid-log (the warm-start boundary of the continual loop)
    tail = list(interaction_stream(ds, batch_events=64, start=128))
    np.testing.assert_array_equal(
        np.concatenate([b["item"] for b in tail]), ds.events[128:, 1]
    )


@pytest.mark.parametrize("n_hosts,n", [(4, 10), (3, 7), (4, 3), (2, 64), (5, 5)])
def test_host_slice_partial_batches(monkeypatch, n_hosts, n):
    """Regression: the balanced host split must PARTITION every batch size —
    disjoint, in-order, nothing dropped. The old ``n // n_hosts`` truncation
    dropped the tail of final partial batches (n=10, H=4 lost 2 events) and
    emptied hosts when n < H."""
    monkeypatch.setattr(jax, "process_count", lambda: n_hosts)
    parts = []
    for i in range(n_hosts):
        monkeypatch.setattr(jax, "process_index", lambda i=i: i)
        parts.append(loader._host_slice(n))
    covered = np.concatenate([np.arange(n)[s] for s in parts])
    np.testing.assert_array_equal(covered, np.arange(n))
    sizes = [s.stop - s.start for s in parts]
    assert max(sizes) - min(sizes) <= 1


def test_interaction_stream_multihost_covers_final_partial(monkeypatch):
    """The per-host slices of every streamed batch (incl. the final partial
    one) must reassemble to the full event log."""
    ds = make_implicit_dataset(n_users=20, n_items=15, seed=11)
    n_hosts = 4
    monkeypatch.setattr(jax, "process_count", lambda: n_hosts)
    per_host = []
    for i in range(n_hosts):
        monkeypatch.setattr(jax, "process_index", lambda i=i: i)
        per_host.append(list(interaction_stream(ds, batch_events=64)))
    n_batches = len(per_host[0])
    assert all(len(b) == n_batches for b in per_host)
    items = np.concatenate(
        [np.concatenate([per_host[i][b]["item"] for i in range(n_hosts)])
         for b in range(n_batches)]
    )
    np.testing.assert_array_equal(items, ds.events[:, 1])


def test_load_movielens_synthetic_fallback_and_cache(tmp_path):
    cache = str(tmp_path / "cache")
    log = load_movielens(cache_dir=cache, n_users=30, n_items=25, seed=4)
    assert log.n_events > 0
    assert log.user.max() < log.n_users and log.item.max() < log.n_items
    assert (tmp_path / "cache" / "ml-synth.data").exists()
    # second load reads the cache file and is bit-identical
    log2 = load_movielens(cache_dir=cache)
    np.testing.assert_array_equal(log.user, log2.user)
    np.testing.assert_array_equal(log.item, log2.item)
    np.testing.assert_array_equal(log.t, log2.t)


def test_load_movielens_parses_ratings_file(tmp_path):
    # ml-100k u.data layout: 1-indexed ids, rating, timestamp
    f = tmp_path / "u.data"
    f.write_text("1\t5\t3\t100\n2\t5\t4\t50\n1\t9\t1\t75\n")
    log = load_movielens(str(f))
    assert (log.n_users, log.n_items) == (2, 2)  # ids remapped dense
    np.testing.assert_array_equal(log.user, [0, 1, 0])
    np.testing.assert_array_equal(log.item, [0, 0, 1])
    np.testing.assert_array_equal(log.value, [3.0, 4.0, 1.0])
    np.testing.assert_array_equal(log.t, [100, 50, 75])
    with pytest.raises(FileNotFoundError):
        load_movielens(str(tmp_path / "missing.data"))


def test_split_by_time_instant_protocol(tmp_path):
    log = load_movielens(cache_dir=str(tmp_path), n_users=30, n_items=25, seed=5)
    train, test = split_by_time(log, holdout_fraction=0.25)
    assert train.n_events + test.n_events == log.n_events
    assert train.t.max() <= test.t.min()        # strict global time cutoff
    assert test.n_users == log.n_users and test.n_items == log.n_items


def test_frequency_interactions_alignment(tmp_path):
    """Weights must land in data's ctx-major nnz order: training with
    (uniform α, weights=w) must equal building with α_raw directly — checked
    via the rescale identity on each cell."""
    log = load_movielens(cache_dir=str(tmp_path), n_users=25, n_items=20, seed=6)
    data, weights, counts = frequency_interactions(
        log, alpha0=0.5, base_alpha=2.0, beta=1.0, mode="linear"
    )
    assert weights.shape == (data.nnz,) == counts.shape
    # dedupe really collapsed repeats: total value mass is preserved
    assert counts.sum() == pytest.approx(float(log.value.sum()))
    # alignment: cell (ctx, item) carries the weight of ITS OWN count
    key_data = np.asarray(data.ctx).astype(np.int64) * log.n_items + np.asarray(
        data.item
    )
    key_log = log.user * log.n_items + log.item
    count_of = {}
    for k, v in zip(key_log, log.value):
        count_of[k] = count_of.get(k, 0.0) + float(v)
    expect_w = (1.0 + np.array([count_of[k] for k in key_data])) / 2.0
    np.testing.assert_allclose(weights, expect_w, rtol=1e-6)
    # and the uniform base data is Lemma-1 rescaled from α=2, α₀=0.5
    np.testing.assert_allclose(np.asarray(data.alpha), 1.5, rtol=1e-6)


def _design_matmul_case(seed, n):
    rng = np.random.default_rng(seed)
    design = make_design(
        [
            dict(name="a", ids=rng.integers(0, 5, n), vocab=5),
            dict(name="b", ids=rng.integers(0, 3, n), vocab=3,
                 weights=rng.normal(size=n).astype(np.float32)),
        ],
        n,
    )
    w = jnp.asarray(rng.normal(size=(design.p, 4)), jnp.float32)
    np.testing.assert_allclose(
        design_matmul(design, w), to_dense(design) @ w, rtol=2e-4, atol=2e-5
    )


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 500), n=st.integers(1, 12))
    def test_design_matmul_matches_dense(seed, n):
        _design_matmul_case(seed, n)
else:
    @pytest.mark.parametrize("seed,n", [(0, 1), (1, 5), (2, 12)])
    def test_design_matmul_matches_dense(seed, n):
        _design_matmul_case(seed, n)
