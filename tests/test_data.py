"""Data pipeline: synthetic generator structure + hosted loaders + design."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis; CI installs it
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.design import design_matmul, make_design, to_dense
from repro.data.loader import interaction_stream
from repro.data.synthetic import make_implicit_dataset


def test_synthetic_dataset_structure():
    ds = make_implicit_dataset(n_users=50, n_items=40, seed=3)
    assert ds.events.shape[1] == 3
    assert ds.events[:, 0].max() < 50 and ds.events[:, 1].max() < 40
    # time-ordered
    assert np.all(np.diff(ds.events[:, 2]) > 0)
    # every user has events within the configured range
    hists = ds.user_histories()
    assert len(hists) == 50
    assert all(len(h) >= 1 for h in hists)
    # attributes in range
    assert ds.age.max() < ds.n_age and ds.country.max() < ds.n_country


def test_attribute_signal_exists():
    """Users sharing attributes must have more similar item distributions
    than random pairs — the mechanism behind the Figure-7 reproduction."""
    ds = make_implicit_dataset(n_users=300, n_items=200, attr_strength=0.95,
                               pop_strength=0.3, taste_strength=2.5, seed=0)
    hists = ds.user_histories()

    def dist(u):
        v = np.bincount(hists[u], minlength=200).astype(float)
        return v / max(v.sum(), 1)

    key = [(a, c) for a, c in zip(ds.age, ds.country)]
    same, diff = [], []
    rng = np.random.default_rng(0)
    for _ in range(3000):
        u, v = rng.integers(0, 300, 2)
        if u == v:
            continue
        sim = float(dist(u) @ dist(v))
        (same if key[u] == key[v] else diff).append(sim)
    if len(same) > 10:
        assert np.mean(same) > np.mean(diff)


def test_interaction_stream_replays_event_log_in_order():
    ds = make_implicit_dataset(n_users=40, n_items=30, seed=7)
    batches = list(interaction_stream(ds, batch_events=64))
    # finite replay: every event appears exactly once, in arrival order
    assert sum(len(b["item"]) for b in batches) == len(ds.events)
    assert all(len(b["item"]) == 64 for b in batches[:-1])
    ctx = np.concatenate([b["ctx"] for b in batches])
    item = np.concatenate([b["item"] for b in batches])
    t = np.concatenate([b["t"] for b in batches])
    np.testing.assert_array_equal(ctx, ds.events[:, 0])
    np.testing.assert_array_equal(item, ds.events[:, 1])
    np.testing.assert_array_equal(t, ds.events[:, 2])
    assert np.all(np.diff(t) > 0)
    # start= resumes mid-log (the warm-start boundary of the continual loop)
    tail = list(interaction_stream(ds, batch_events=64, start=128))
    np.testing.assert_array_equal(
        np.concatenate([b["item"] for b in tail]), ds.events[128:, 1]
    )


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 500), n=st.integers(1, 12))
def test_design_matmul_matches_dense(seed, n):
    rng = np.random.default_rng(seed)
    design = make_design(
        [
            dict(name="a", ids=rng.integers(0, 5, n), vocab=5),
            dict(name="b", ids=rng.integers(0, 3, n), vocab=3,
                 weights=rng.normal(size=n).astype(np.float32)),
        ],
        n,
    )
    w = jnp.asarray(rng.normal(size=(design.p, 4)), jnp.float32)
    np.testing.assert_allclose(
        design_matmul(design, w), to_dense(design) @ w, rtol=2e-4, atol=2e-5
    )
