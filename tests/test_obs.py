"""Observability spine (repro/obs): registry + tracing under simulated
clocks, instrumentation back-compat on the serving components, and the
bit-identity guard (metrics/tracing must never change results).

Everything runs on injected clocks — no sleeps, no wall-time flakiness.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs.costs import KernelCostRecorder, cd_sweep_cost, topk_score_cost
from repro.obs.export import (
    chrome_trace,
    metrics_jsonl,
    prometheus_text,
    write_metrics,
    write_trace,
)
from repro.obs.metrics import (
    DEFAULT_BUCKETS,
    NULL_REGISTRY,
    MetricsRegistry,
    StatsView,
    default_registry,
    resolve_registry,
)
from repro.obs.trace import Tracer, trace_for_ticket
from repro.kernels.vmem import psi_row_bytes
from repro.serve.batcher import MicroBatcher
from repro.serve.engine import RetrievalEngine
from repro.serve.mesh import (
    FaultInjector,
    FaultTolerantRetrievalMesh,
    RetryPolicy,
)


# ------------------------------------------------------------------ registry
class TestRegistry:
    def test_counter_gauge_basics(self):
        reg = MetricsRegistry()
        c = reg.counter("x_total", "help", labels=("who",))
        c.labels(who="a").inc()
        c.labels(who="a").inc(2.5)
        c.labels(who="b").inc()
        assert reg.get("x_total", who="a") == 3.5
        assert reg.get("x_total", who="b") == 1.0
        g = reg.gauge("depth")
        g.set(7)
        g.dec(2)
        assert reg.get("depth") == 5.0

    def test_counter_rejects_negative(self):
        reg = MetricsRegistry()
        with pytest.raises(ValueError):
            reg.counter("c_total").inc(-1)

    def test_family_reregistration_must_match(self):
        reg = MetricsRegistry()
        reg.counter("n_total", labels=("a",))
        # same shape: returns the same family
        assert reg.counter("n_total", labels=("a",)) is reg.counter(
            "n_total", labels=("a",))
        with pytest.raises(ValueError):
            reg.gauge("n_total", labels=("a",))          # kind mismatch
        with pytest.raises(ValueError):
            reg.counter("n_total", labels=("b",))        # label mismatch

    def test_label_validation(self):
        reg = MetricsRegistry()
        fam = reg.counter("y_total", labels=("who",))
        with pytest.raises(ValueError):
            fam.labels(nope="x")

    def test_histogram_bucket_edges(self):
        # observations land in the FIRST bucket whose edge >= v (le
        # semantics); one implicit overflow bucket past the last edge
        reg = MetricsRegistry()
        h = reg.histogram("lat_seconds", buckets=(0.1, 1.0, 10.0)).labels()
        for v in (0.05, 0.1, 0.10001, 1.0, 5.0, 11.0, 1e9):
            h.observe(v)
        assert h.counts == [2, 2, 1, 2]   # le edges are inclusive;
        # 0.05/0.1 -> le-0.1, 0.10001/1.0 -> le-1, 5.0 -> le-10,
        # 11.0/1e9 -> the implicit overflow bucket
        assert h.count == 7
        assert h.sum == pytest.approx(0.05 + 0.1 + 0.10001 + 1.0 + 5.0
                                      + 11.0 + 1e9)

    def test_histogram_rejects_unsorted_edges(self):
        reg = MetricsRegistry()
        with pytest.raises(ValueError):
            reg.histogram("bad_seconds", buckets=(1.0, 0.5)).labels()

    def test_quantile_interpolation(self):
        # 10 observations uniform in the (0, 1] bucket: the Prometheus
        # linear-interpolation rule puts p50 at rank 5 of 10 -> 0.5
        reg = MetricsRegistry()
        h = reg.histogram("q_seconds", buckets=(1.0, 2.0)).labels()
        for _ in range(10):
            h.observe(0.7)
        assert h.quantile(0.5) == pytest.approx(0.5)
        assert h.quantile(1.0) == pytest.approx(1.0)

    def test_p99_small_samples_and_overflow_clamp(self):
        reg = MetricsRegistry()
        h = reg.histogram("p_seconds", buckets=(1e-3, 1e-2)).labels()
        assert np.isnan(h.quantile(0.99))            # empty -> NaN
        h.observe(5e-4)
        # single sample: every quantile interpolates inside its bucket
        assert 0.0 < h.quantile(0.99) <= 1e-3
        h.observe(1.0)                               # overflow bucket
        assert h.quantile(0.99) == 1e-2              # clamps to last edge
        with pytest.raises(ValueError):
            h.quantile(1.5)

    def test_percentiles_keys(self):
        reg = MetricsRegistry()
        h = reg.histogram("pp_seconds").labels()
        h.observe(1e-4)
        assert set(h.percentiles()) == {"p50", "p90", "p99"}

    def test_simulated_clock_timer(self):
        clock = {"t": 100.0}
        reg = MetricsRegistry(clock=lambda: clock["t"])
        h = reg.histogram("t_seconds", buckets=DEFAULT_BUCKETS).labels()
        with reg.timer(h):
            clock["t"] += 0.25
        assert h.count == 1
        assert h.sum == pytest.approx(0.25)

    def test_default_and_null_registry(self):
        assert resolve_registry(None) is default_registry()
        reg = MetricsRegistry()
        assert resolve_registry(reg) is reg
        # NULL is falsy (components use truthiness to skip recording)
        # and absorbs the whole API as no-ops
        assert not NULL_REGISTRY
        NULL_REGISTRY.counter("whatever_total").labels(a=1).inc()
        NULL_REGISTRY.histogram("h_seconds").observe(3.0)

    def test_stats_view_is_live_mapping(self):
        reg = MetricsRegistry()
        c = reg.counter("sv_total").labels()
        view = StatsView({"n": lambda: int(c.value)})
        assert dict(view) == {"n": 0}
        c.inc(3)
        assert view["n"] == 3 and len(view) == 1


# -------------------------------------------------------------------- tracing
class TestTracing:
    def test_span_nesting_auto_parent(self):
        clock = {"t": 0.0}
        tr = Tracer(clock=lambda: clock["t"])
        with tr.span("outer") as outer:
            clock["t"] = 1.0
            with tr.span("inner", detail=7) as inner:
                clock["t"] = 2.0
        assert inner.parent_id == outer.span_id
        assert outer.parent_id is None
        assert outer.t0 == 0.0 and outer.t1 == 2.0
        assert inner.duration == pytest.approx(1.0)
        assert inner.attrs["detail"] == 7
        assert tr.current is None

    def test_begin_end_and_activate(self):
        tr = Tracer(clock=lambda: 0.0)
        fs = tr.begin("flush", parent=None)
        with tr.activate(fs):
            with tr.span("dispatch") as d:
                pass
        tr.end(fs, coverage=1.0)
        assert d.parent_id == fs.span_id
        assert fs.attrs["coverage"] == 1.0
        assert [s.name for s in tr.subtree(fs)] == ["flush", "dispatch"]

    def test_ticket_correlation_out_of_order(self):
        # two tickets whose flushes interleave: each ticket's trace pulls
        # its own request/queue spans PLUS the flush subtree it references
        tr = Tracer(clock=lambda: 0.0)
        rq1 = tr.begin("request", parent=None, ticket=1)
        rq2 = tr.begin("request", parent=None, ticket=2)
        fs2 = tr.begin("flush", parent=None)       # ticket 2 flushes FIRST
        with tr.activate(fs2):
            tr.end(tr.begin("dispatch", shard=0))
        tr.end(fs2)
        tr.end(rq2, flush_span=fs2.span_id)
        fs1 = tr.begin("flush", parent=None)
        with tr.activate(fs1):
            tr.end(tr.begin("failover", shard=0))
        tr.end(fs1)
        tr.end(rq1, flush_span=fs1.span_id)
        names1 = {s.name for s in trace_for_ticket(tr, 1)}
        names2 = {s.name for s in trace_for_ticket(tr, 2)}
        assert names1 == {"request", "flush", "failover"}
        assert names2 == {"request", "flush", "dispatch"}
        # and the shared-flush case: both tickets see the shared spans
        assert fs1.span_id in {s.span_id for s in trace_for_ticket(tr, 1)}
        assert trace_for_ticket(tr, 99) == []


# ------------------------------------------------------------- kernel costs
class TestKernelCosts:
    def test_topk_cost_matches_vmem_byte_model(self):
        b, n, d, k = 32, 4096, 64, 100
        cost = topk_score_cost(b, n, d, k)
        k_pad = -(-k // 128) * 128
        assert cost["hbm_bytes"] == (n * psi_row_bytes(d) + 4.0 * b * d
                                     + 2 * 4.0 * b * k_pad)
        assert cost["flops"] == 2.0 * b * n * d
        # quantized ψ stream: bf16 halves, int8 quarters + a scale column
        assert (topk_score_cost(b, n, d, k, psi_bytes=2)["hbm_bytes"]
                < cost["hbm_bytes"])

    def test_recorder_accumulates_per_kernel(self):
        reg = MetricsRegistry()
        rec = KernelCostRecorder(reg)
        rec.record_topk(8, 1024, 16, 10)
        rec.record_topk(8, 1024, 16, 10)
        rec.record_cd_sweep(100, 256, 16, 4)
        assert reg.get("kernel_calls_total", kernel="topk_score") == 2
        assert reg.get("kernel_calls_total", kernel="cd_sweep") == 1
        one = topk_score_cost(8, 1024, 16, 10)
        assert reg.get("kernel_hbm_bytes_total",
                       kernel="topk_score") == 2 * one["hbm_bytes"]
        assert reg.get("kernel_flops_total",
                       kernel="topk_score") == 2 * one["flops"]
        sweep = cd_sweep_cost(100, 256, 16, 4)
        assert reg.get("kernel_hbm_bytes_total",
                       kernel="cd_sweep") == sweep["hbm_bytes"]

    def test_engine_dispatch_site_records_costs(self):
        rng = np.random.default_rng(3)
        phi = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
        psi = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
        reg = MetricsRegistry()
        eng = RetrievalEngine(psi, lambda p=phi: p, k=5, block_items=32,
                              registry=reg)
        eng.topk_phi(phi)
        assert reg.get("kernel_calls_total", kernel="topk_score") == 1
        assert reg.get("kernel_hbm_bytes_total", kernel="topk_score") == (
            topk_score_cost(4, 64, 16, 5)["hbm_bytes"])


# ------------------------------------------- component instrumentation
def _fake_topk(rows, eids):
    b = int(rows.shape[0])
    scores = np.tile(np.arange(3, 0, -1, dtype=np.float32), (b, 1))
    ids = np.tile(np.arange(3, dtype=np.int32), (b, 1))
    return scores, ids


class TestBatcherInstrumentation:
    def _batcher(self, clock, registry=None, tracer=None, **kw):
        kw.setdefault("max_batch", 4)
        kw.setdefault("max_delay", 1.0)
        return MicroBatcher(
            _fake_topk, clock=lambda: clock["t"],
            version_fn=lambda: 0, registry=registry, tracer=tracer, **kw)

    def test_stats_backcompat_keys_and_types(self):
        clock = {"t": 0.0}
        b = self._batcher(clock, registry=MetricsRegistry())
        for _ in range(4):
            b.submit(np.ones(8, np.float32))
        assert b.stats["submitted"] == 4 and b.stats["flushes"] == 1
        assert b.stats["flush_by_size"] == 1
        # the old dict exposed ints; the registry-backed view must too
        assert all(isinstance(v, int) for v in dict(b.stats).values())

    def test_drained_counter(self):
        clock = {"t": 0.0}
        b = self._batcher(clock, registry=MetricsRegistry())
        b.submit(np.ones(8, np.float32))
        leftovers = b.drain()
        assert len(leftovers) == 1 and b.closed
        assert b.stats["drained"] == 1
        assert b.stats["flushes"] == 1   # drained flushes count as flushes

    def test_registry_series_behind_stats(self):
        clock = {"t": 0.0}
        reg = MetricsRegistry(clock=lambda: clock["t"])
        b = self._batcher(clock, registry=reg)
        b.submit(np.ones(8, np.float32))
        clock["t"] = 5.0
        b.flush()
        # queue latency observed under the simulated clock: exactly 5s
        fam = reg.counter("serve_batcher_submitted_total",
                          labels=("instance",))
        assert sum(ch.value for ch in fam.children()) == 1
        hist = next(iter(
            reg.histogram("serve_batcher_queue_latency_seconds",
                          labels=("instance",)).children()))
        assert hist.count == 1 and hist.sum == pytest.approx(5.0)

    def test_ticket_correlated_trace(self):
        clock = {"t": 0.0}
        tr = Tracer(clock=lambda: clock["t"])
        b = self._batcher(clock, registry=MetricsRegistry(), tracer=tr)
        t1 = b.submit(np.ones(8, np.float32))
        t2 = b.submit(np.ones(8, np.float32))
        clock["t"] = 2.0
        b.flush()
        for t in (t1, t2):
            names = [s.name for s in trace_for_ticket(tr, t)]
            assert names.count("request") == 1
            assert {"request", "queue", "flush"} <= set(names)
        rq = next(s for s in tr.spans
                  if s.name == "request" and s.attrs["ticket"] == t1)
        assert rq.attrs["coverage"] == 1.0 and rq.t1 == 2.0


def _mesh_pair(n_shards=2, n_replicas=2, k=7, **kw):
    rng = np.random.default_rng(11)
    phi = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    psi = jnp.asarray(rng.normal(size=(96, 16)), jnp.float32)
    mesh = FaultTolerantRetrievalMesh(
        lambda p=phi: p, n_shards=n_shards, n_replicas=n_replicas, k=k,
        block_items=32, **kw)
    mesh.publish(psi)
    return phi, psi, mesh


class TestMeshInstrumentation:
    def test_stats_backcompat_and_counter_names(self):
        reg = MetricsRegistry()
        phi, _, mesh = _mesh_pair(registry=reg)
        mesh.topk_phi(phi)
        assert mesh.stats["queries"] == 1
        assert mesh.stats["dispatches"] == 2          # one per shard
        assert isinstance(mesh.stats["queries"], int)
        assert isinstance(mesh.stats["backoff_slept_s"], float)
        fam = reg.counter("serve_mesh_queries_total", labels=("instance",))
        assert sum(ch.value for ch in fam.children()) == 1

    def test_fault_burned_latency_recorded(self):
        # an injected timeout carries burned deadline budget; the retry
        # loop must aggregate it into fault_burned_s (satellite #2)
        reg = MetricsRegistry()
        inj = FaultInjector()
        clock = {"t": 0.0}
        phi, _, mesh = _mesh_pair(
            registry=reg, injector=inj, clock=lambda: clock["t"],
            retry=RetryPolicy(max_attempts=2, backoff_base=1e-4))
        inj.fail(0, 0, "timeout", latency=0.125, count=1)
        res = mesh.topk_phi(phi)
        assert res.coverage == 1.0                    # failover covered it
        assert mesh.stats["faults"] == 1
        assert mesh.stats["fault_burned_s"] >= 0.125
        fam = reg.counter("serve_mesh_fault_burned_seconds_total",
                          labels=("instance",))
        assert sum(ch.value for ch in fam.children()) >= 0.125

    def test_degraded_counting_through_batcher(self):
        # kill BOTH replicas of shard 0: the mesh serves degraded, the
        # batcher counts every routed row as degraded, nothing is cached
        reg = MetricsRegistry()
        inj = FaultInjector()
        phi, _, mesh = _mesh_pair(
            registry=reg, injector=inj,
            retry=RetryPolicy(max_attempts=1))
        inj.fail(0, 0, "error")
        inj.fail(0, 1, "error")
        clock = {"t": 0.0}
        b = MicroBatcher(
            lambda rows, eids: mesh.topk_phi(rows, exclude_ids=eids),
            max_batch=4, max_delay=1.0, clock=lambda: clock["t"],
            version_fn=lambda: mesh.version, registry=reg)
        tickets = [b.submit(np.ones(16, np.float32), key=("u", i))
                   for i in range(3)]
        b.flush()
        res = b.result(tickets[0])
        assert res.coverage < 1.0
        assert mesh.stats["degraded_queries"] == 1
        assert b.stats["degraded_results"] == 3
        assert b.stats["cache_hits"] == 0

    def test_bit_identity_guard(self):
        # the whole point of opt-in observability: a fully instrumented
        # mesh returns bit-identical results to a bare one
        phi, _, bare = _mesh_pair(registry=NULL_REGISTRY)
        _, _, instr = _mesh_pair(registry=MetricsRegistry(),
                                 tracer=Tracer())
        r0, r1 = bare.topk_phi(phi), instr.topk_phi(phi)
        np.testing.assert_array_equal(np.asarray(r0.ids),
                                      np.asarray(r1.ids))
        np.testing.assert_array_equal(np.asarray(r0.scores),
                                      np.asarray(r1.scores))

    def test_replica_latency_histogram_exists(self):
        reg = MetricsRegistry()
        phi, _, mesh = _mesh_pair(registry=reg)
        mesh.topk_phi(phi)
        fam = reg.histogram("serve_mesh_replica_latency_seconds",
                            labels=("instance", "shard", "replica"))
        assert sum(ch.count for ch in fam.children()) == 2


# ------------------------------------------------------------------- export
class TestExport:
    def _populated(self):
        clock = {"t": 0.0}
        reg = MetricsRegistry(clock=lambda: clock["t"])
        reg.counter("a_total", "a help", labels=("who",)).labels(
            who="x").inc(2)
        h = reg.histogram("lat_seconds", buckets=(0.1, 1.0)).labels()
        h.observe(0.05)
        h.observe(0.5)
        return reg

    def test_jsonl_schema(self):
        recs = [json.loads(line)
                for line in metrics_jsonl(self._populated()).splitlines()]
        by_name = {r["name"]: r for r in recs}
        a = by_name["a_total"]
        assert a["type"] == "counter" and a["value"] == 2.0
        assert a["labels"] == {"who": "x"}
        lat = by_name["lat_seconds"]
        assert lat["count"] == 2 and lat["buckets"]["+Inf"] == 2
        assert lat["buckets"]["0.1"] == 1
        assert {"p50", "p90", "p99"} <= set(lat)

    def test_jsonl_empty_histogram_is_strict_json(self):
        reg = MetricsRegistry()
        reg.histogram("empty_seconds").labels()
        rec = json.loads(metrics_jsonl(reg))
        assert rec["p99"] is None         # NaN must not leak into JSON

    def test_prometheus_text(self):
        text = prometheus_text(self._populated())
        assert "# TYPE a_total counter" in text
        assert 'a_total{who="x"} 2.0' in text
        assert 'lat_seconds_bucket{le="0.1"} 1' in text
        assert 'lat_seconds_bucket{le="+Inf"} 2' in text
        assert "lat_seconds_count 2" in text

    def test_write_metrics_picks_format(self, tmp_path):
        reg = self._populated()
        p1 = write_metrics(str(tmp_path / "m.jsonl"), reg)
        p2 = write_metrics(str(tmp_path / "m.prom"), reg)
        assert json.loads(open(p1).readline())["name"]
        assert open(p2).read().startswith("# HELP")

    def test_chrome_trace_schema(self, tmp_path):
        clock = {"t": 0.0}
        tr = Tracer(clock=lambda: clock["t"])
        with tr.span("outer", ticket=3):
            clock["t"] = 0.002
            with tr.span("inner"):
                clock["t"] = 0.003
        doc = chrome_trace(tr)
        events = [e for e in doc["traceEvents"] if e["ph"] == "X"]
        assert [e["name"] for e in events] == ["outer", "inner"]
        outer, inner = events
        assert outer["ts"] == 0.0 and outer["dur"] == pytest.approx(3000.0)
        assert inner["args"]["parent_id"] == outer["args"]["span_id"]
        assert events[0]["args"]["ticket"] == 3
        path = write_trace(str(tmp_path / "t.json"), tr)
        assert json.load(open(path))["displayTimeUnit"] == "ms"
