"""shard_map distributed iCD-MF == reference epoch (8 forced host devices)."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    import sys
    sys.path.insert(0, "src")

    from repro.core.models import mf, mf_dist
    from repro.sparse.interactions import build_interactions

    rng = np.random.default_rng(0)
    n_ctx, n_items, nnz, k = 53, 37, 300, 6   # deliberately non-divisible
    cells = rng.choice(n_ctx * n_items, nnz, replace=False)
    ctx, item = cells // n_items, cells % n_items
    data = build_interactions(ctx, item, rng.integers(1, 4, nnz),
                              1.5 + rng.random(nnz), n_ctx, n_items, alpha0=0.5)
    hp = mf.MFHyperParams(k=k, alpha0=0.5, l2=0.05)
    params = mf.init(jax.random.PRNGKey(1), n_ctx, n_items, k)

    # reference
    e = mf.residuals(params, data)
    ref_p, ref_e = params, e
    for _ in range(2):
        ref_p, ref_e = mf.epoch(ref_p, data, ref_e, hp)

    # distributed — both variants must match the reference exactly (fp32
    # wire); the bf16 wire variant must stay close
    sd = mf_dist.shard_interactions(data, 8)
    pb = mf_dist.shard_params(params, sd)
    mesh = mf_dist.make_shard_mesh(8)
    ref_obj = float(mf.objective(ref_p, data, hp))
    for variant, wire, exact in (("gather", jnp.float32, True),
                                 ("route", jnp.float32, True),
                                 ("route", jnp.bfloat16, False)):
        epoch = mf_dist.build_epoch(mesh, hp, sd, variant=variant,
                                    wire_dtype=wire)
        w, h, eb2 = pb.w, pb.h, mf_dist.residuals_blocked(pb, sd)
        for _ in range(2):
            w, h, eb2 = epoch(w, h, sd, eb2)
        got = mf_dist.unshard_params(mf.MFParams(w, h), n_ctx, n_items)
        if exact:  # fp32 wire: trajectory-identical to the reference
            np.testing.assert_allclose(np.asarray(got.w), np.asarray(ref_p.w),
                                       rtol=5e-4, atol=5e-5)
            np.testing.assert_allclose(np.asarray(got.h), np.asarray(ref_p.h),
                                       rtol=5e-4, atol=5e-5)
        else:      # bf16 wire perturbs the CD trajectory (coordinates may
                   # differ) but must reach an equally good optimum
            obj = float(mf.objective(got, data, hp))
            assert abs(obj - ref_obj) / ref_obj < 0.01, (obj, ref_obj)
        print(f"variant={variant} wire={wire.__name__} OK")
    print("MF-DIST-OK")
    """
)


@pytest.mark.slow
def test_mf_dist_matches_reference():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(__file__)),
        env={**env, "PYTHONPATH": "src"}, timeout=600,
    )
    assert "MF-DIST-OK" in proc.stdout, proc.stdout[-2000:] + proc.stderr[-3000:]


# The sweep_columns/newton_delta routing must keep the denominator clamp:
# with l2=0 an empty context row has L''=R''=0 and an unclamped Newton step
# NaNs (the drift the mf_dist refactor fixed).
CLAMP_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    import sys
    sys.path.insert(0, "src")

    from repro.core.models import mf, mf_dist
    from repro.sparse.interactions import build_interactions

    rng = np.random.default_rng(7)
    n_ctx, n_items, nnz, k = 21, 17, 90, 4
    cells = rng.choice((n_ctx - 1) * n_items, nnz, replace=False)
    ctx, item = cells // n_items, cells % n_items   # ctx n_ctx-1 is EMPTY
    assert (n_ctx - 1) not in set(ctx.tolist())
    data = build_interactions(ctx, item, rng.integers(1, 4, nnz),
                              1.5 + rng.random(nnz), n_ctx, n_items, alpha0=0.5)
    hp = mf.MFHyperParams(k=k, alpha0=0.5, l2=0.0)
    params = mf.init(jax.random.PRNGKey(3), n_ctx, n_items, k)

    ref_p, ref_e = params, mf.residuals(params, data)
    for _ in range(2):
        ref_p, ref_e = mf.epoch(ref_p, data, ref_e, hp)
    assert bool(jnp.isfinite(ref_p.w).all())

    sd = mf_dist.shard_interactions(data, 4)
    pb = mf_dist.shard_params(params, sd)
    mesh = mf_dist.make_shard_mesh(4)
    for variant in ("gather", "route"):
        epoch = mf_dist.build_epoch(mesh, hp, sd, variant=variant)
        w, h, eb = pb.w, pb.h, mf_dist.residuals_blocked(pb, sd)
        for _ in range(2):
            w, h, eb = epoch(w, h, sd, eb)
        got = mf_dist.unshard_params(mf.MFParams(w, h), n_ctx, n_items)
        assert bool(jnp.isfinite(got.w).all()) and bool(jnp.isfinite(got.h).all())
        np.testing.assert_allclose(np.asarray(got.w), np.asarray(ref_p.w),
                                   rtol=5e-4, atol=5e-5)
        np.testing.assert_allclose(np.asarray(got.h), np.asarray(ref_p.h),
                                   rtol=5e-4, atol=5e-5)
        print(f"variant={variant} clamp OK")
    print("MF-DIST-CLAMP-OK")
    """
)


@pytest.mark.slow
def test_mf_dist_empty_context_l2_zero_clamp():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", CLAMP_SCRIPT],
        capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(__file__)),
        env={**env, "PYTHONPATH": "src"}, timeout=600,
    )
    assert "MF-DIST-CLAMP-OK" in proc.stdout, (
        proc.stdout[-2000:] + proc.stderr[-3000:]
    )
