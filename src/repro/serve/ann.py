"""IVF-tiered approximate retrieval: centroid pruning + exact fused re-rank.

The exact serving stack (engine → cluster → mesh) streams the ENTIRE ψ
catalogue through the fused ``kernels/topk_score`` kernel per query — the
right oracle, and the serving wall at 10⁸+ items (ROADMAP item 4; Rendle
2021 frames large-catalogue implicit retrieval as exactly this
approximate-then-exact regime). Because every zoo model is k-separable
(score = ⟨φ, ψ_i⟩), indexing the ψ SIDE once speeds up serving for the
whole zoo: this module adds the approximate tier.

:class:`PsiIndex` — an inverted-file (IVF) index over one ψ table (or one
row-range shard of it):

  build     ``kmeans`` (JAX Lloyd's) clusters the rows; the table is
            PERMUTED into cluster-contiguous blocks, each padded to the
            uniform ``block_rows`` so every block dispatch runs ONE
            compiled kernel program. Within a block, rows keep ascending
            global id (stable argsort), which is what preserves the
            kernel's ascending-id tie policy through the permutation.
  storage   fp32, bf16, or int8 with per-row scales
            (``core.quant.int8_quantize_rows`` — per-tensor would crush
            tail-item rows); the kernel dequantizes tiles in-VMEM with
            fp32 accumulate, so int8 multiplies HBM rows-per-shard by
            ``≈ 4D/(D+4)`` (:func:`repro.kernels.vmem.psi_row_bytes`).
  query     φ·centroidᵀ scores pick each row's top ``n_probe`` clusters;
            only the selected blocks run the EXACT fused kernel — reusing
            the traced ``(id_offset, n_valid)`` meta with ``id_offset =
            block start`` so emitted candidate ids address the permuted
            table, then one ``ids_global`` gather maps them back to global
            catalogue ids before the cross-block two-key merge
            (``ops.topk_merge_shards``) restores the exact (−score,
            ascending-global-id) policy.
  oracle    ``n_probe ≥ n_clusters`` is HARD-GATED to probe everything —
            no pruning step at all — and is then bit-identical (ids AND
            scores) to the exact path: per-block fp32 dots equal the
            full-table dots, blocks partition the catalogue, and any
            global top-K element is its own block's top-K element under
            the same total order. The CI bench gate pins this.
  delta     ``apply_delta`` folds published fold-in rows in place: patched
            ids re-quantize in their existing slot, appended ids join
            their nearest centroid's block (id order within the block is
            preserved — appends carry the largest ids). Every folded row
            bumps ``staleness``; past ``AnnConfig.reindex_after`` the
            owner rebuilds the index from the authoritative table
            (``needs_reindex`` — centroids drift as the catalogue moves).

Exclusion: callers pass GLOBAL ``exclude_ids``; the index maps them to
permuted positions through its ``inv_pos`` table so the kernel's in-VMEM
membership compare works unchanged. An excluded id living in a pruned
(unprobed) block simply never surfaces — same observable result.

Sharding: each shard of a ``PsiShardSet`` gets its own index over its
row range (:func:`build_shard_indexes`); per-shard candidates carry global
ids, so the existing cross-shard merge works untouched
(:func:`ivf_cluster_topk`), including the coverage/degradation contract.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import int8_quantize_rows
from repro.kernels.topk_score.ops import topk_merge_shards, topk_score
from repro.serve.cluster import (
    PsiShardSet,
    TopKResult,
    colocate_parts,
    coverage_fraction,
    dead_item_ranges,
    empty_topk,
)

_QUANTS = ("none", "bf16", "int8")


@dataclasses.dataclass(frozen=True)
class AnnConfig:
    """Knobs for the IVF tier (engine/cluster/mesh take one of these).

    ``n_clusters=0`` auto-sizes to ≈√n (the classic IVF balance point:
    centroid scan cost ≈ probed-block cost). ``n_probe=0`` auto-sizes to
    ``max(1, n_clusters // 4)``. ``quant`` picks the ψ storage form;
    ``reindex_after`` is the staleness budget: after that many folded-in
    delta rows the owner rebuilds the index (fresh k-means) instead of
    folding further."""

    n_clusters: int = 0
    n_probe: int = 0
    quant: str = "none"
    kmeans_iters: int = 8
    seed: int = 0
    reindex_after: int = 64

    def __post_init__(self):
        if self.quant not in _QUANTS:
            raise ValueError(f"quant must be one of {_QUANTS}, got {self.quant!r}")

    def resolve_clusters(self, n_rows: int) -> int:
        c = self.n_clusters or max(1, int(round(float(n_rows) ** 0.5)))
        return max(1, min(c, n_rows))

    def resolve_probe(self, n_clusters: int) -> int:
        p = self.n_probe or max(1, n_clusters // 4)
        return max(1, min(p, n_clusters))


def kmeans(
    psi: jax.Array, n_clusters: int, *, n_iters: int = 8, seed: int = 0
) -> Tuple[jax.Array, jax.Array]:
    """Lloyd's k-means in JAX: ``(centroids (C, D) f32, assign (n,) i32)``.

    Deterministic (PRNGKey-seeded init from distinct data rows, argmin
    ties take the lowest cluster). A cluster that loses all members keeps
    its previous centroid — empty clusters are legal downstream: their
    blocks hold zero valid rows and the kernel's ``n_valid`` meta keeps
    them inadmissible."""
    psi = jnp.asarray(psi, jnp.float32)
    n, _ = psi.shape
    if not 1 <= n_clusters <= n:
        raise ValueError(f"need 1 <= n_clusters <= {n}, got {n_clusters}")
    init = jax.random.choice(
        jax.random.PRNGKey(seed), n, (n_clusters,), replace=False
    )
    centroids = psi[init]
    x_sq = jnp.sum(psi * psi, axis=1)                       # (n,)

    def assign_to(c):
        d2 = x_sq[:, None] - 2.0 * psi @ c.T + jnp.sum(c * c, axis=1)[None]
        return jnp.argmin(d2, axis=1).astype(jnp.int32)

    def step(c, _):
        a = assign_to(c)
        sums = jax.ops.segment_sum(psi, a, num_segments=n_clusters)
        cnt = jax.ops.segment_sum(jnp.ones((n,), jnp.float32), a,
                                  num_segments=n_clusters)
        new = jnp.where(cnt[:, None] > 0,
                        sums / jnp.maximum(cnt, 1.0)[:, None], c)
        return new, None

    centroids, _ = jax.lax.scan(step, centroids, None, length=n_iters)
    return centroids, assign_to(centroids)


class PsiIndex:
    """IVF index over one ψ table / shard: cluster-permuted quantized
    storage + centroid pruning + exact fused re-rank. Construct with
    :meth:`build`; treat instances as immutable (``apply_delta`` returns a
    new index)."""

    def __init__(self, *, cfg, centroids, psi_q, scales, ids_global,
                 inv_pos, counts, block_rows, id_offset, n_rows, staleness):
        self.cfg = cfg
        self.centroids = centroids        # (C, D) f32
        self.psi_q = psi_q                # (C·block_rows, D) stored dtype
        self.scales = scales              # (C·block_rows,) f32 | None (int8)
        self.ids_global = ids_global      # (C·block_rows,) i32, −1 on pads
        self.inv_pos = inv_pos            # (n_rows,) i32: local id → position
        self.counts = counts              # np (C,) valid rows per cluster
        self.block_rows = block_rows      # uniform padded block size
        self.id_offset = id_offset        # global id of local row 0
        self.n_rows = n_rows              # valid rows indexed
        self.staleness = staleness        # delta rows folded since build

    # -------------------------------------------------------------- build
    @classmethod
    def build(cls, psi: jax.Array, cfg: AnnConfig = AnnConfig(), *,
              id_offset: int = 0) -> "PsiIndex":
        psi = np.asarray(jnp.asarray(psi, jnp.float32))
        n, d = psi.shape
        if n < 1:
            raise ValueError("cannot index an empty ψ table")
        c = cfg.resolve_clusters(n)
        centroids, assign = kmeans(
            psi, c, n_iters=cfg.kmeans_iters, seed=cfg.seed
        )
        assign = np.asarray(assign)
        counts = np.bincount(assign, minlength=c)
        block_rows = -(-max(int(counts.max()), 1) // 8) * 8
        perm = np.zeros((c * block_rows, d), np.float32)
        ids_global = np.full(c * block_rows, -1, np.int32)
        inv_pos = np.full(n, -1, np.int32)
        # stable argsort: within a cluster, rows stay in ascending global id
        # — the invariant that carries the kernel's tie policy through the
        # permutation
        order = np.argsort(assign, kind="stable")
        cursor = np.zeros(c, np.int64)
        for local in order:
            cl = assign[local]
            pos = cl * block_rows + cursor[cl]
            cursor[cl] += 1
            perm[pos] = psi[local]
            ids_global[pos] = id_offset + local
            inv_pos[local] = pos
        psi_q, scales = cls._quantize(perm, cfg.quant)
        return cls(
            cfg=cfg, centroids=centroids, psi_q=psi_q, scales=scales,
            ids_global=jnp.asarray(ids_global), inv_pos=jnp.asarray(inv_pos),
            counts=counts, block_rows=block_rows, id_offset=int(id_offset),
            n_rows=n, staleness=0,
        )

    @staticmethod
    def _quantize(perm: np.ndarray, quant: str):
        if quant == "int8":
            q, s = int8_quantize_rows(jnp.asarray(perm))
            return q, s
        if quant == "bf16":
            return jnp.asarray(perm).astype(jnp.bfloat16), None
        return jnp.asarray(perm), None

    # --------------------------------------------------------- properties
    @property
    def n_clusters(self) -> int:
        return int(self.centroids.shape[0])

    @property
    def d(self) -> int:
        return int(self.centroids.shape[1])

    @property
    def quant(self) -> str:
        return self.cfg.quant

    def needs_reindex(self) -> bool:
        """Staleness budget exhausted: folded-in deltas have drifted the
        catalogue past what frozen centroids index well — rebuild."""
        return self.staleness > self.cfg.reindex_after

    # -------------------------------------------------------------- query
    def _map_exclude(self, exclude_ids):
        """GLOBAL excluded ids → permuted positions (−1 when out of this
        index's range or padding): the kernel's membership compare then
        runs unchanged in position space."""
        if exclude_ids is None:
            return None
        ex = jnp.asarray(exclude_ids, jnp.int32)
        loc = ex - self.id_offset
        ok = (ex >= 0) & (loc >= 0) & (loc < self.n_rows)
        pos = self.inv_pos[jnp.clip(loc, 0, max(self.n_rows - 1, 0))]
        return jnp.where(ok, pos, -1)

    def topk(
        self,
        phi_rows: jax.Array,
        k: int,
        *,
        n_probe: Optional[int] = None,
        exclude_ids: Optional[jax.Array] = None,
        block_items: Optional[int] = None,
        interpret: Optional[bool] = None,
        registry=None,
    ) -> Tuple[jax.Array, jax.Array]:
        """Approximate top-K: ``(scores (B, k), ids (B, k))``, ids GLOBAL.

        Each φ row probes its own top-``n_probe`` clusters; the dispatch
        loop runs each probed block once for the whole batch and masks the
        rows that did not select it, so per-query pruning semantics hold
        at any batch size. ``n_probe ≥ n_clusters`` skips pruning entirely
        (the bit-exact oracle path).

        ``registry`` (an ``obs.metrics`` registry) opts into query/probe
        counters and per-block kernel cost accounting at the stored quant
        width. Unlike the serving components, ``None`` here means NO
        recording — a hot library function must not reach for process
        globals behind its caller's back (the engine/mesh thread their own
        registries through)."""
        phi_rows = jnp.asarray(phi_rows, jnp.float32)
        b = int(phi_rows.shape[0])
        c = self.n_clusters
        n_probe = self.cfg.resolve_probe(c) if n_probe is None else n_probe
        costs = None
        if registry is not None and registry:   # NULL_REGISTRY is falsy
            from repro.obs.costs import KernelCostRecorder

            registry.counter(
                "ann_queries_total", "PsiIndex.topk dispatches").inc()
            costs = KernelCostRecorder(registry)
        if n_probe >= c:
            probe_mask = np.ones((b, c), bool)       # oracle: prune nothing
        else:
            cscores = phi_rows @ self.centroids.T    # (B, C): C ≪ n_items
            sel = np.asarray(jax.lax.top_k(cscores, n_probe)[1])
            probe_mask = np.zeros((b, c), bool)
            np.put_along_axis(probe_mask, sel, True, axis=1)
        excl_pos = self._map_exclude(exclude_ids)
        excl_l = 0 if excl_pos is None else int(excl_pos.shape[1])
        psi_bytes = {"none": 4, "bf16": 2, "int8": 1}[self.cfg.quant]
        probed = 0
        parts_s, parts_i = [], []
        for cl in np.nonzero(probe_mask.any(axis=0))[0]:
            if self.counts[cl] == 0:
                continue                             # empty block: no rows
            lo = int(cl) * self.block_rows
            ss, ii = topk_score(
                phi_rows, self.psi_q[lo : lo + self.block_rows], k,
                exclude_ids=excl_pos,
                psi_scale=None if self.scales is None
                else self.scales[lo : lo + self.block_rows],
                id_offset=lo, n_valid=int(self.counts[cl]),
                block_items=block_items, interpret=interpret,
            )
            probed += 1
            if costs is not None:
                costs.record_topk(
                    b, self.block_rows, self.d, k,
                    kernel="topk_score_ivf", psi_bytes=psi_bytes,
                    per_row_scale=self.cfg.quant == "int8", excl_l=excl_l,
                )
            mask = jnp.asarray(probe_mask[:, cl])
            ss = jnp.where(mask[:, None], ss, -jnp.inf)
            ii = jnp.where(mask[:, None], ii, -1)
            # permuted positions → global catalogue ids BEFORE the merge:
            # the two-key sort must tie-break on GLOBAL ascending id
            ii = jnp.where(
                ii >= 0, self.ids_global[jnp.clip(ii, 0, None)], -1
            )
            parts_s.append(ss)
            parts_i.append(ii)
        if registry is not None and registry:
            registry.counter(
                "ann_probed_blocks_total",
                "IVF blocks actually dispatched (post-pruning)").inc(probed)
        if not parts_s:
            return empty_topk(b, k)
        if len(parts_s) == 1:
            return parts_s[0], parts_i[0]
        return topk_merge_shards(
            jnp.stack(parts_s), jnp.stack(parts_i), k
        )

    # -------------------------------------------------------------- delta
    def apply_delta(self, rows, ids) -> "PsiIndex":
        """Fold published delta rows into the index without re-clustering.

        Patched ids (already indexed) re-quantize in their existing slot —
        position, hence tie order, is unchanged. Appended ids (must extend
        the local range contiguously, the ``publish.apply_delta`` hole
        rule) join their NEAREST centroid's block; a full block grows by a
        row-multiple repack (no re-quantization of untouched rows). Every
        folded row bumps ``staleness``; the owner checks
        :meth:`needs_reindex` and rebuilds from the authoritative table
        when the budget is spent."""
        rows = np.asarray(jnp.asarray(rows, jnp.float32))
        ids = np.asarray(ids, np.int64).reshape(-1)
        if rows.shape[0] != ids.shape[0]:
            raise ValueError(f"{rows.shape[0]} rows vs {ids.shape[0]} ids")
        order = np.argsort(ids, kind="stable")
        rows, ids = rows[order], ids[order]

        counts = self.counts.copy()
        block_rows = self.block_rows
        c = self.n_clusters
        psi_q = np.asarray(self.psi_q).copy()
        scales = None if self.scales is None else np.asarray(self.scales).copy()
        ids_global = np.asarray(self.ids_global).copy()
        inv_pos = np.asarray(self.inv_pos).copy()
        centroids = np.asarray(self.centroids)
        n_rows = self.n_rows

        def grow(new_block_rows):
            nonlocal psi_q, scales, ids_global, inv_pos, block_rows
            nq = np.zeros((c * new_block_rows,) + psi_q.shape[1:], psi_q.dtype)
            ng = np.full(c * new_block_rows, -1, np.int32)
            ns = None if scales is None else np.zeros(
                c * new_block_rows, np.float32
            )
            for cl in range(c):
                src, dst = cl * block_rows, cl * new_block_rows
                nq[dst : dst + block_rows] = psi_q[src : src + block_rows]
                ng[dst : dst + block_rows] = ids_global[src : src + block_rows]
                if ns is not None:
                    ns[dst : dst + block_rows] = scales[src : src + block_rows]
            psi_q, ids_global, scales = nq, ng, ns
            valid = inv_pos >= 0
            inv_pos = np.where(
                valid,
                (inv_pos // block_rows) * new_block_rows
                + (inv_pos % block_rows),
                -1,
            ).astype(np.int32)
            block_rows = new_block_rows

        for row, gid in zip(rows, ids):
            local = int(gid) - self.id_offset
            if 0 <= local < n_rows:                       # patch in place
                pos = int(inv_pos[local])
                self._store_row(psi_q, scales, pos, row)
            elif local == n_rows:                         # contiguous append
                d2 = np.sum((centroids - row[None]) ** 2, axis=1)
                cl = int(np.argmin(d2))
                if counts[cl] >= block_rows:
                    grow(block_rows + 8)
                pos = cl * block_rows + int(counts[cl])
                counts[cl] += 1
                self._store_row(psi_q, scales, pos, row)
                ids_global[pos] = int(gid)
                inv_pos = np.append(inv_pos, np.int32(pos))
                n_rows += 1
            else:
                raise ValueError(
                    f"delta id {int(gid)} is outside [{self.id_offset}, "
                    f"{self.id_offset + n_rows}] — appends must be "
                    "contiguous (publish.apply_delta's hole rule)"
                )
        return PsiIndex(
            cfg=self.cfg, centroids=self.centroids,
            psi_q=jnp.asarray(psi_q),
            scales=None if scales is None else jnp.asarray(scales),
            ids_global=jnp.asarray(ids_global), inv_pos=jnp.asarray(inv_pos),
            counts=counts, block_rows=block_rows, id_offset=self.id_offset,
            n_rows=n_rows, staleness=self.staleness + len(ids),
        )

    def _store_row(self, psi_q, scales, pos, row):
        """Quantize ONE row into storage slot ``pos`` (delta fold-in)."""
        if self.cfg.quant == "int8":
            absmax = max(float(np.max(np.abs(row))), 1e-12)
            scale = absmax / 127.0
            psi_q[pos] = np.clip(
                np.round(row / scale), -127, 127
            ).astype(psi_q.dtype)
            scales[pos] = scale
        else:
            psi_q[pos] = row.astype(psi_q.dtype)


# ---------------------------------------------------------------- sharding
def build_shard_indexes(
    table: PsiShardSet, cfg: AnnConfig
) -> Tuple[Optional[PsiIndex], ...]:
    """One :class:`PsiIndex` per shard of ``table``, each over its VALID
    rows with ``id_offset`` = the shard's row-range start — per-shard
    candidates come out with global ids, so the existing cross-shard merge
    applies unchanged. A shard with zero valid rows gets ``None``."""
    out = []
    for s in range(table.n_shards):
        valid = table.valid_rows(s)
        if valid <= 0:
            out.append(None)
            continue
        out.append(PsiIndex.build(
            table.shards[s][:valid], cfg, id_offset=s * table.rows_per
        ))
    return tuple(out)


def fold_delta_indexes(
    indexes: Sequence[Optional[PsiIndex]],
    new_table: PsiShardSet,
    rows,
    ids,
    cfg: AnnConfig,
    *,
    registry=None,
) -> Tuple[Optional[PsiIndex], ...]:
    """Per-shard delta fold-in after a ``publish_delta``: route each
    changed/appended row to its owning shard's index, fold it in, and
    REBUILD any index whose staleness budget is spent (or whose shard just
    materialized) from the authoritative ``new_table`` slab. Callers must
    have checked the shard geometry (``rows_per``/``n_shards``) is
    unchanged — a geometry change means re-sharding, not folding.
    ``registry`` opts into the reindex-trigger counter (same convention as
    :meth:`PsiIndex.topk`: ``None`` records nothing)."""
    rows = np.asarray(jnp.asarray(rows, jnp.float32))
    ids = np.asarray(ids, np.int64).reshape(-1)
    shard_of = ids // new_table.rows_per
    out = []
    rebuilt = 0
    for s in range(new_table.n_shards):
        idx = indexes[s] if s < len(indexes) else None
        hit = shard_of == s
        if hit.any() and idx is not None:
            idx = idx.apply_delta(rows[hit], ids[hit])
        # idx None with hits: the shard just gained its first rows — the
        # rebuild below indexes it from the authoritative table
        if (idx is None or idx.needs_reindex()) and new_table.valid_rows(s) > 0:
            idx = PsiIndex.build(
                new_table.shards[s][: new_table.valid_rows(s)], cfg,
                id_offset=s * new_table.rows_per,
            )
            rebuilt += 1
        out.append(idx)
    if registry is not None and registry and rebuilt:
        registry.counter(
            "ann_reindexes_total",
            "per-shard IVF index rebuilds triggered by the staleness "
            "budget (needs_reindex) or a newly materialized shard",
        ).inc(rebuilt)
    return tuple(out)


def ivf_cluster_topk(
    table: PsiShardSet,
    indexes: Sequence[Optional[PsiIndex]],
    phi_rows: jax.Array,
    k: int,
    *,
    n_probe: Optional[int] = None,
    exclude_ids: Optional[jax.Array] = None,
    interpret: Optional[bool] = None,
    dead_shards: Sequence[int] = (),
    registry=None,
) -> TopKResult:
    """Sharded IVF top-K: per-shard :meth:`PsiIndex.topk` candidates (each
    shard prunes to its own ``n_probe`` blocks) + the same cross-shard
    merge and coverage/degradation contract as ``cluster.cluster_topk``."""
    phi_rows = jnp.asarray(phi_rows, jnp.float32)
    b = int(phi_rows.shape[0])
    dead = set(dead_shards)
    parts_s, parts_i = [], []
    for s in range(table.n_shards):
        if s in dead or indexes[s] is None:
            continue
        ss, ii = indexes[s].topk(
            phi_rows, k, n_probe=n_probe, exclude_ids=exclude_ids,
            interpret=interpret, registry=registry,
        )
        parts_s.append(ss)
        parts_i.append(ii)
    coverage = coverage_fraction(table, dead)
    ranges = dead_item_ranges(table, dead)
    if not parts_s:
        es, ei = empty_topk(b, k)
        return TopKResult(es, ei, coverage, ranges)
    if len(parts_s) == 1:
        return TopKResult(parts_s[0], parts_i[0], coverage, ranges)
    ms, mi = topk_merge_shards(
        jnp.stack(colocate_parts(parts_s)),
        jnp.stack(colocate_parts(parts_i)), k,
    )
    return TopKResult(ms, mi, coverage, ranges)
