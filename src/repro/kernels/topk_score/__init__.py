from repro.kernels.topk_score.ops import topk_merge_shards, topk_score  # noqa: F401
from repro.kernels.topk_score.ref import topk_score_ref  # noqa: F401
