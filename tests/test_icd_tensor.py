"""PARAFAC + Tucker iCD: exactness vs autodiff-Newton on the dense implicit
objective, dense-context decomposition (eq. 39), convergence, and
fused-block (``epoch_padded``) vs per-column parity — incl. non-divisible
k/block_k splits and empty-context rows (the newton_delta clamp path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.models import parafac, tucker
from repro.core.models.parafac import TensorContext
from repro.sparse.interactions import build_interactions


def make_problem(seed=0, n_c1=5, n_c2=4, n_items=6, n_pairs=12, nnz=25,
                 alpha0=0.3, dense_ctx=False):
    rng = np.random.default_rng(seed)
    if dense_ctx:
        n_pairs = n_c1 * n_c2
        pair_list = np.stack(
            [np.repeat(np.arange(n_c1), n_c2), np.tile(np.arange(n_c2), n_c1)], 1
        )
    else:
        chosen = rng.choice(n_c1 * n_c2, size=n_pairs, replace=False)
        pair_list = np.stack([chosen // n_c2, chosen % n_c2], 1)
    tc = TensorContext(
        c1=jnp.asarray(pair_list[:, 0], jnp.int32),
        c2=jnp.asarray(pair_list[:, 1], jnp.int32),
        n_c1=n_c1, n_c2=n_c2,
    )
    cells = rng.choice(n_pairs * n_items, size=nnz, replace=False)
    ctx, item = cells // n_items, cells % n_items
    y = rng.integers(1, 4, size=nnz).astype(np.float64)
    alpha = alpha0 + 1.0 + rng.random(nnz)
    data = build_interactions(ctx, item, y, alpha, n_pairs, n_items, alpha0=alpha0)
    # dense grids over the (pair, item) universe for the oracle
    y_dense = np.zeros((n_pairs, n_items), np.float32)
    a_dense = np.full((n_pairs, n_items), alpha0, np.float32)
    y_dense[ctx, item] = y
    a_dense[ctx, item] = alpha
    return tc, data, jnp.asarray(y_dense), jnp.asarray(a_dense)


def _newton_layer(loss_fn, params, path, mask, eta=1.0):
    theta = getattr(params, path)

    def f(t):
        return loss_fn(params._replace(**{path: t}))

    g = jax.grad(f)(theta)
    basis = jnp.eye(theta.size, dtype=theta.dtype).reshape((theta.size,) + theta.shape)
    diag = jax.vmap(lambda v: jnp.vdot(v, jax.jvp(jax.grad(f), (theta,), (v,))[1]))(basis)
    step = jnp.where(mask, -eta * g / jnp.maximum(diag.reshape(theta.shape), 1e-12), 0.0)
    return params._replace(**{path: theta + step})


@pytest.mark.parametrize("dense_ctx", [False, True])
@pytest.mark.parametrize("fused", [False, True])
def test_parafac_matches_autodiff_newton(dense_ctx, fused):
    """Both the per-column epoch and the fused-block ``epoch_padded`` (at a
    non-divisible k=3, block_k=2 split) must match the autodiff oracle."""
    tc, data, y_dense, a_dense = make_problem(seed=1, dense_ctx=dense_ctx)
    k = 3
    hp = parafac.PARAFACHyperParams(k=k, alpha0=0.3, l2=0.05, dense_context=dense_ctx,
                                    block_k=2)
    params = parafac.init(jax.random.PRNGKey(0), tc.n_c1, tc.n_c2, data.n_items, k)

    def dense_loss(p):
        phi = jnp.take(p.u, tc.c1, axis=0) * jnp.take(p.v, tc.c2, axis=0)
        s = phi @ p.w.T
        reg = hp.l2 * sum(jnp.sum(q**2) for q in p)
        return jnp.sum(a_dense * (s - y_dense) ** 2) + reg

    oracle = params
    for f in range(k):
        m = jnp.zeros((tc.n_c1, k), bool).at[:, f].set(True)
        oracle = _newton_layer(dense_loss, oracle, "u", m)
    for f in range(k):
        m = jnp.zeros((tc.n_c2, k), bool).at[:, f].set(True)
        oracle = _newton_layer(dense_loss, oracle, "v", m)
    for f in range(k):
        m = jnp.zeros((data.n_items, k), bool).at[:, f].set(True)
        oracle = _newton_layer(dense_loss, oracle, "w", m)

    e = parafac.residuals(params, tc, data)
    if fused:
        padded = parafac.pad_tensor_groups(tc, data)
        got, _ = parafac.epoch_padded(params, tc, data, padded, e, hp)
    else:
        got, _ = parafac.epoch(params, tc, data, e, hp)
    np.testing.assert_allclose(got.u, oracle.u, rtol=5e-4, atol=5e-5)
    np.testing.assert_allclose(got.v, oracle.v, rtol=5e-4, atol=5e-5)
    np.testing.assert_allclose(got.w, oracle.w, rtol=5e-4, atol=5e-5)


def test_parafac_dense_context_gram_identity():
    """eq. 39: with C = C1×C2, Gram(Φ) == Gram(U) ⊙ Gram(V)."""
    tc, data, _, _ = make_problem(seed=2, dense_ctx=True)
    params = parafac.init(jax.random.PRNGKey(1), tc.n_c1, tc.n_c2, data.n_items, 4)
    from repro.core.gram import gram

    full = gram(parafac.phi(params, tc))
    fast = gram(params.u) * gram(params.v)
    np.testing.assert_allclose(full, fast, rtol=1e-4, atol=1e-5)


def test_parafac_objective_decreases():
    tc, data, _, _ = make_problem(seed=3, n_pairs=15, nnz=40)
    hp = parafac.PARAFACHyperParams(k=3, alpha0=0.3, l2=0.05)
    params = parafac.init(jax.random.PRNGKey(2), tc.n_c1, tc.n_c2, data.n_items, 3)
    start = float(parafac.objective(params, tc, data, hp))
    prev = start
    e = parafac.residuals(params, tc, data)
    for _ in range(8):
        params, e = parafac.epoch(params, tc, data, e, hp)
        cur = float(parafac.objective(params, tc, data, hp))
        assert cur <= prev + 1e-4
        prev = cur
    assert prev < 0.8 * start


@pytest.mark.parametrize("fused", [False, True])
def test_tucker_matches_autodiff_newton(fused):
    """Per-column epoch and fused ``epoch_padded`` (non-divisible mode
    k's vs block_k=2) both match the autodiff oracle."""
    tc, data, y_dense, a_dense = make_problem(seed=4)
    k1, k2, k3 = 2, 3, 2
    hp = tucker.TuckerHyperParams(k1=k1, k2=k2, k3=k3, alpha0=0.3, l2=0.05, l2_core=0.02,
                                  block_k=2)
    params = tucker.init(
        jax.random.PRNGKey(3), tc.n_c1, tc.n_c2, data.n_items, k1, k2, k3
    )

    def dense_loss(p):
        up = jnp.take(p.u, tc.c1, axis=0)
        vp = jnp.take(p.v, tc.c2, axis=0)
        phi = jnp.einsum("na,nb,abf->nf", up, vp, p.b)
        s = phi @ p.w.T
        reg = hp.l2 * (jnp.sum(p.u**2) + jnp.sum(p.v**2) + jnp.sum(p.w**2))
        reg += hp.l2_core * jnp.sum(p.b**2)
        return jnp.sum(a_dense * (s - y_dense) ** 2) + reg

    oracle = params
    for f in range(k1):
        m = jnp.zeros((tc.n_c1, k1), bool).at[:, f].set(True)
        oracle = _newton_layer(dense_loss, oracle, "u", m)
    for f in range(k2):
        m = jnp.zeros((tc.n_c2, k2), bool).at[:, f].set(True)
        oracle = _newton_layer(dense_loss, oracle, "v", m)
    for f1 in range(k1):          # core: strictly sequential scalar steps
        for f2 in range(k2):
            for f3 in range(k3):
                m = jnp.zeros((k1, k2, k3), bool).at[f1, f2, f3].set(True)
                oracle = _newton_layer(dense_loss, oracle, "b", m)
    for f in range(k3):
        m = jnp.zeros((data.n_items, k3), bool).at[:, f].set(True)
        oracle = _newton_layer(dense_loss, oracle, "w", m)

    e = tucker.residuals(params, tc, data)
    if fused:
        padded = tucker.pad_tensor_groups(tc, data)
        got, _ = tucker.epoch_padded(params, tc, data, padded, e, hp)
    else:
        got, _ = tucker.epoch(params, tc, data, e, hp)
    np.testing.assert_allclose(got.u, oracle.u, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(got.v, oracle.v, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(got.b, oracle.b, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(got.w, oracle.w, rtol=1e-3, atol=1e-4)


def test_tucker_objective_decreases():
    tc, data, _, _ = make_problem(seed=5, n_pairs=15, nnz=40)
    hp = tucker.TuckerHyperParams(k1=2, k2=2, k3=3, alpha0=0.3, l2=0.05)
    params = tucker.init(jax.random.PRNGKey(4), tc.n_c1, tc.n_c2, data.n_items, 2, 2, 3)
    start = float(tucker.objective(params, tc, data, hp))
    params = tucker.fit(params, tc, data, hp, n_epochs=8)
    assert float(tucker.objective(params, tc, data, hp)) < 0.85 * start


# ------------------------------------------ fused (padded) block parity ----
@pytest.mark.slow
@pytest.mark.parametrize("dense_ctx", [False, True])
@pytest.mark.parametrize("block_k", [1, 2, 3, 5])
def test_parafac_fused_matches_per_column(dense_ctx, block_k):
    """epoch_padded (fused cd_block_sweep_rowpatch blocks) must track the
    per-column epoch trajectory at every block size, incl. non-divisible
    k=5 / block_k ∈ {2,3} splits and block_k=1 (per-column through the
    block path)."""
    tc, data, _, _ = make_problem(seed=6, dense_ctx=dense_ctx)
    k = 5
    hp = parafac.PARAFACHyperParams(k=k, alpha0=0.3, l2=0.05,
                                    dense_context=dense_ctx, block_k=block_k)
    params = parafac.init(jax.random.PRNGKey(5), tc.n_c1, tc.n_c2, data.n_items, k)
    padded = parafac.pad_tensor_groups(tc, data)
    ref, got = params, params
    e_ref = parafac.residuals(params, tc, data)
    e_got = parafac.residuals(params, tc, data)
    for _ in range(2):
        ref, e_ref = parafac.epoch(ref, tc, data, e_ref, hp)
        got, e_got = parafac.epoch_padded(got, tc, data, padded, e_got, hp)
    np.testing.assert_allclose(got.u, ref.u, rtol=5e-4, atol=1e-5)
    np.testing.assert_allclose(got.v, ref.v, rtol=5e-4, atol=1e-5)
    np.testing.assert_allclose(got.w, ref.w, rtol=5e-4, atol=1e-5)
    np.testing.assert_allclose(e_got, e_ref, rtol=5e-4, atol=5e-5)


@pytest.mark.slow
@pytest.mark.parametrize("block_k", [1, 2, 3])
def test_tucker_fused_matches_per_column(block_k):
    """Fused Tucker mode/item sweeps track the per-column trajectory for
    non-divisible mode ranks (k1=3, k2=2, k3=4) at every block size."""
    tc, data, _, _ = make_problem(seed=7)
    k1, k2, k3 = 3, 2, 4
    hp = tucker.TuckerHyperParams(k1=k1, k2=k2, k3=k3, alpha0=0.3, l2=0.05,
                                  l2_core=0.02, block_k=block_k)
    params = tucker.init(jax.random.PRNGKey(6), tc.n_c1, tc.n_c2,
                         data.n_items, k1, k2, k3)
    padded = tucker.pad_tensor_groups(tc, data)
    ref, got = params, params
    e_ref = tucker.residuals(params, tc, data)
    e_got = tucker.residuals(params, tc, data)
    for _ in range(2):
        ref, e_ref = tucker.epoch(ref, tc, data, e_ref, hp)
        got, e_got = tucker.epoch_padded(got, tc, data, padded, e_got, hp)
    np.testing.assert_allclose(got.u, ref.u, rtol=5e-4, atol=1e-5)
    np.testing.assert_allclose(got.v, ref.v, rtol=5e-4, atol=1e-5)
    np.testing.assert_allclose(got.w, ref.w, rtol=5e-4, atol=1e-5)
    np.testing.assert_allclose(got.b, ref.b, rtol=5e-4, atol=1e-5)
    np.testing.assert_allclose(e_got, e_ref, rtol=5e-4, atol=5e-5)


@pytest.mark.parametrize("model", ["parafac", "tucker"])
def test_tensor_fused_gather_matches_pregather(model):
    """The flat-pseudo-ψ gather routing (default; slab + sentinel row +
    ``flat_ids``) must reproduce the ``scatter_blk`` pre-gathered routing to
    float roundoff — non-divisible mode ranks vs block_k=2."""
    import dataclasses

    tc, data, _, _ = make_problem(seed=9)
    if model == "parafac":
        base = parafac.PARAFACHyperParams(k=5, alpha0=0.3, l2=0.05, block_k=2)
        params = parafac.init(jax.random.PRNGKey(8), tc.n_c1, tc.n_c2,
                              data.n_items, 5)
        mod = parafac
    else:
        base = tucker.TuckerHyperParams(k1=3, k2=2, k3=4, alpha0=0.3, l2=0.05,
                                        l2_core=0.02, block_k=2)
        params = tucker.init(jax.random.PRNGKey(8), tc.n_c1, tc.n_c2,
                             data.n_items, 3, 2, 4)
        mod = tucker
    padded = mod.pad_tensor_groups(tc, data)
    finals = {}
    for disp in ("gather", "pregather"):
        hp = dataclasses.replace(base, psi_dispatch=disp)
        p, e = params, mod.residuals(params, tc, data)
        for _ in range(2):
            p, e = mod.epoch_padded(p, tc, data, padded, e, hp)
        finals[disp] = (p, e)
    for field in finals["gather"][0]._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(finals["gather"][0], field)),
            np.asarray(getattr(finals["pregather"][0], field)),
            rtol=1e-6, atol=1e-7,
        )
    np.testing.assert_allclose(finals["gather"][1], finals["pregather"][1],
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("block_k", [2, 3])
def test_parafac_fused_dense_context_sparse_pairs(block_k):
    """dense_context=True with a SPARSE pair list: the regularizer universe
    is the full C1×C2 grid while the explicit part stays on observed pairs.
    The fused R' slab must use the dense K (partner Gram) like the flat
    path — a sparse segment-sum K here solves a different objective."""
    tc, data, _, _ = make_problem(seed=10, dense_ctx=False)  # sparse pairs
    k = 3
    hp = parafac.PARAFACHyperParams(k=k, alpha0=0.3, l2=0.05,
                                    dense_context=True, block_k=block_k)
    params = parafac.init(jax.random.PRNGKey(9), tc.n_c1, tc.n_c2, data.n_items, k)
    padded = parafac.pad_tensor_groups(tc, data)
    e = parafac.residuals(params, tc, data)
    ref, _ = parafac.epoch(params, tc, data, e, hp)
    e2 = parafac.residuals(params, tc, data)
    got, _ = parafac.epoch_padded(params, tc, data, padded, e2, hp)
    np.testing.assert_allclose(got.u, ref.u, rtol=5e-4, atol=1e-5)
    np.testing.assert_allclose(got.v, ref.v, rtol=5e-4, atol=1e-5)
    np.testing.assert_allclose(got.w, ref.w, rtol=5e-4, atol=1e-5)


def make_sparse_rows_problem(seed=8, n_items=6, nnz=10):
    """A pathological universe for the clamp path: c1=0/c2=0 have pairs AND
    observations, c1=3 has a pair but NO observations (explicit parts
    vanish, implicit parts don't), c1=4/c2=3 appear in NO pair at all
    (Newton denominator is exactly l2 — 0 in the clamp test)."""
    rng = np.random.default_rng(seed)
    n_c1, n_c2 = 5, 4
    pair_list = np.array([[0, 0], [0, 1], [1, 0], [1, 2], [2, 1], [3, 2]])
    n_pairs = len(pair_list)
    tc = TensorContext(
        c1=jnp.asarray(pair_list[:, 0], jnp.int32),
        c2=jnp.asarray(pair_list[:, 1], jnp.int32),
        n_c1=n_c1, n_c2=n_c2,
    )
    # observations only on pairs 0..4 — pair 5 (c1=3) stays empty
    cells = rng.choice(5 * n_items, size=nnz, replace=False)
    ctx, item = cells // n_items, cells % n_items
    y = rng.integers(1, 4, size=nnz).astype(np.float64)
    alpha = 1.3 + rng.random(nnz)
    data = build_interactions(ctx, item, y, alpha, n_pairs, n_items, alpha0=0.3)
    return tc, data


@pytest.mark.parametrize("l2", [0.0, 0.05])
@pytest.mark.parametrize("block_k", [2, 3])
def test_parafac_fused_empty_context_rows(l2, block_k):
    """Rows with no observations (and even no pairs) must stay finite and
    match the per-column path — at l2=0 the Newton denominator of a fully
    empty row is 0 and only the newton_delta/kernel clamp prevents NaNs."""
    tc, data = make_sparse_rows_problem()
    k = 3
    hp = parafac.PARAFACHyperParams(k=k, alpha0=0.3, l2=l2, block_k=block_k)
    params = parafac.init(jax.random.PRNGKey(7), tc.n_c1, tc.n_c2, data.n_items, k)
    padded = parafac.pad_tensor_groups(tc, data)
    e = parafac.residuals(params, tc, data)
    ref, _ = parafac.epoch(params, tc, data, e, hp)
    e2 = parafac.residuals(params, tc, data)
    got, _ = parafac.epoch_padded(params, tc, data, padded, e2, hp)
    assert np.all(np.isfinite(np.asarray(got.u)))
    assert np.all(np.isfinite(np.asarray(got.v)))
    assert np.all(np.isfinite(np.asarray(got.w)))
    np.testing.assert_allclose(got.u, ref.u, rtol=5e-4, atol=1e-5)
    np.testing.assert_allclose(got.v, ref.v, rtol=5e-4, atol=1e-5)
    np.testing.assert_allclose(got.w, ref.w, rtol=5e-4, atol=1e-5)


@pytest.mark.parametrize("l2", [0.0, 0.05])
def test_tucker_fused_empty_context_rows(l2):
    tc, data = make_sparse_rows_problem(seed=9)
    hp = tucker.TuckerHyperParams(k1=2, k2=3, k3=2, alpha0=0.3, l2=l2,
                                  l2_core=0.05, block_k=2)
    params = tucker.init(jax.random.PRNGKey(8), tc.n_c1, tc.n_c2,
                         data.n_items, 2, 3, 2)
    padded = tucker.pad_tensor_groups(tc, data)
    e = tucker.residuals(params, tc, data)
    ref, _ = tucker.epoch(params, tc, data, e, hp)
    e2 = tucker.residuals(params, tc, data)
    got, _ = tucker.epoch_padded(params, tc, data, padded, e2, hp)
    for name in ("u", "v", "w", "b"):
        assert np.all(np.isfinite(np.asarray(getattr(got, name))))
        np.testing.assert_allclose(getattr(got, name), getattr(ref, name),
                                   rtol=5e-4, atol=1e-5)
