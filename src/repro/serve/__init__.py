from repro.serve.decode import generate  # noqa: F401
from repro.serve.engine import RetrievalEngine, exclude_mask_from_lists  # noqa: F401
from repro.serve.recsys_serve import bulk_score, retrieval_topk  # noqa: F401
