"""Serving paths: decode generation, chunked retrieval top-k, bulk scoring."""
import jax
import jax.numpy as jnp
import numpy as np

from _smoke_configs import QWEN_SMOKE

from repro.models import transformer as T
from repro.serve.decode import generate
from repro.serve.recsys_serve import bulk_score, mf_retrieval_score_fn, retrieval_topk


def test_generate_greedy_matches_manual_decode():
    cfg = QWEN_SMOKE
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0, cfg.vocab)
    out = generate(cfg, params, prompt, max_new_tokens=3,
                   compute_dtype=jnp.float32)
    assert out.shape == (2, 4 + 3)
    assert bool((out[:, :4] == prompt).all())
    # greedy decode is deterministic
    out2 = generate(cfg, params, prompt, max_new_tokens=3,
                    compute_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def test_retrieval_topk_exact():
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(5000, 16)), jnp.float32)
    user = jnp.asarray(rng.normal(size=16), jnp.float32)
    scores, ids = retrieval_topk(mf_retrieval_score_fn(user, table), 5000,
                                 k=50, chunk=777)
    full = np.asarray(table @ user)
    expect = set(np.argsort(-full)[:50].tolist())
    assert set(np.asarray(ids).tolist()) == expect
    np.testing.assert_allclose(np.sort(np.asarray(scores))[::-1],
                               np.sort(full[np.asarray(ids)])[::-1], rtol=1e-5)


def test_retrieval_topk_batched_matches_per_row():
    rng = np.random.default_rng(2)
    table = jnp.asarray(rng.normal(size=(3000, 8)), jnp.float32)
    users = jnp.asarray(rng.normal(size=(5, 8)), jnp.float32)
    scores, ids = retrieval_topk(mf_retrieval_score_fn(users, table), 3000,
                                 k=20, chunk=512)
    assert scores.shape == (5, 20) and ids.shape == (5, 20)
    full = np.asarray(users @ table.T)
    for r in range(5):
        s1, i1 = retrieval_topk(mf_retrieval_score_fn(users[r], table), 3000,
                                k=20, chunk=512)
        np.testing.assert_array_equal(np.asarray(ids)[r], np.asarray(i1))
        np.testing.assert_array_equal(
            np.asarray(ids)[r], np.argsort(-full[r], kind="stable")[:20])


def test_retrieval_topk_short_catalogue_no_placeholder_leak():
    table = jnp.asarray(np.random.default_rng(3).normal(size=(7, 4)), jnp.float32)
    user = jnp.asarray([1.0, 0.0, 0.0, 0.0])
    scores, ids = retrieval_topk(mf_retrieval_score_fn(user, table), 7, k=12)
    # first 7 slots are the real catalogue, exactly ranked
    np.testing.assert_array_equal(
        np.asarray(ids)[:7], np.argsort(-np.asarray(table @ user), kind="stable")[:7])
    # tail is (−inf, −1): id 0 never leaks as a fake recommendation
    assert bool((np.asarray(ids)[7:] == -1).all())
    assert bool(np.isneginf(np.asarray(scores)[7:]).all())


def test_bulk_score_chunking():
    w = jnp.asarray([0.5, -1.0, 2.0, 0.25])

    def fwd(batch):
        return batch["x"] @ w  # arbitrary linear scorer

    x = jnp.asarray(np.random.default_rng(1).normal(size=(1000, 4)), jnp.float32)
    got = bulk_score(fwd, {"x": x}, chunk=128)
    np.testing.assert_allclose(got, x @ w, rtol=1e-5)
