"""Sharding rules: parameter/optimizer/batch PartitionSpecs per arch family.

Conventions (DESIGN.md §5):
  * batch/context dims shard over ``dp`` = ("pod","data") on multi-pod,
    ("data",) on single-pod;
  * weights shard over "model" on their parallel dim and over "data" on the
    other large dim (ZeRO/FSDP via GSPMD all-gather-at-use). Parameters are
    intentionally NOT sharded over "pod": cross-pod traffic is the gradient
    all-reduce only;
  * embedding / vocab tables row-shard over "model";
  * small vectors (norms, biases) replicate.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_axes


def named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------- LM ------
MODEL_AXIS_SIZE = 16  # both production meshes use a 16-way model axis


def _drop_data(spec: P) -> P:
    """Replace every 'data'/('data',) entry with None (ZeRO-1 live params:
    replicated over data, sharded over model only)."""
    def clean(e):
        if e == "data" or e == ("data",):
            return None
        return e

    return P(*[clean(e) for e in spec])


def _lm_leaf_spec(cfg, name: str, stacked: bool, model_axis: int = MODEL_AXIS_SIZE) -> P:
    """Spec for one transformer block leaf, by parameter name.

    Attention projections are column-parallel (sharded over heads) only when
    the head count divides the model axis; otherwise ROW-parallel (sharded on
    d_model, partial-sum all-reduce of the small projection output). Naively
    head-sharding e.g. Gemma-2's 8 q / 4 kv heads 16 ways makes GSPMD emit
    f32 (S×S) score partial-sum all-reduces — catastrophic (measured in
    EXPERIMENTS.md §Dry-run notes).
    """
    lead = (None,) if stacked else ()
    q_col = cfg.n_heads % model_axis == 0
    kv_col = cfg.n_kv_heads % model_axis == 0
    table = {
        "wq": lead + ((("data",), "model") if q_col else ("model", ("data",))),
        "wk": lead + ((("data",), "model") if kv_col else ("model", ("data",))),
        "wv": lead + ((("data",), "model") if kv_col else ("model", ("data",))),
        "wo": lead + (("model", ("data",)) if q_col else (("data",), "model")),
        "bq": lead + (("model",) if q_col else (None,)),
        "bk": lead + (("model",) if kv_col else (None,)),
        "bv": lead + (("model",) if kv_col else (None,)),
        "w_gate": lead + (("data",), "model"),
        "w_up": lead + (("data",), "model"),
        "w_down": lead + ("model", ("data",)),
        "router": lead + (("data",), None),
        "e_gate": lead + ("model", ("data",), None),
        "e_up": lead + ("model", ("data",), None),
        "e_down": lead + ("model", None, ("data",)),
        "s_gate": lead + (("data",), "model"),
        "s_up": lead + (("data",), "model"),
        "s_down": lead + ("model", ("data",)),
        "pre_attn": lead + (None,),
        "pre_ffn": lead + (None,),
        "post_attn": lead + (None,),
        "post_ffn": lead + (None,),
    }
    return P(*table[name])


def lm_param_specs(cfg, params: Any, model_axis: int = MODEL_AXIS_SIZE):
    """Same-structure PartitionSpec tree for the transformer param pytree."""

    def block_specs(block, stacked):
        return {k: _lm_leaf_spec(cfg, k, stacked, model_axis) for k in block}

    specs = {
        "embed": P("model", None),
        "final_norm": P(None),
        "head_dense": [block_specs(b, stacked=False) for b in params["head_dense"]],
        "layers": tuple(block_specs(b, stacked=True) for b in params["layers"]),
    }
    if "unembed" in params:
        specs["unembed"] = P(None, "model")
    return specs


def lm_batch_specs(mesh):
    dp = dp_axes(mesh)
    return {"tokens": P(dp, None), "targets": P(dp, None)}


def lm_cache_specs(cfg, cache, mesh, shard_seq_over_dp: bool = False):
    """KV cache (n_steps, 2, B, S, Hkv, hd): batch over dp, seq over model
    (sequence-sharded cache). long-context B=1 cells shard seq over
    (dp + model) instead."""
    dp = dp_axes(mesh)
    if shard_seq_over_dp:
        seq_spec = P(None, None, None, dp + ("model",), None, None)
        one_spec = P(None, None, dp + ("model",), None, None)
    else:
        seq_spec = P(None, None, dp, "model", None, None)
        one_spec = P(None, dp, "model", None, None)
    return {
        "head_dense": [one_spec for _ in cache["head_dense"]],
        "layers": tuple(seq_spec for _ in cache["layers"]),
        "max_seq": P(),
    }


# ------------------------------------------------------------- optimizer --
def opt_state_specs(param_specs):
    """AdamW state: m/v mirror the parameters, step replicates."""
    return {"step": P(), "m": param_specs, "v": param_specs}


def train_state_specs(param_specs):
    from repro.train.train_step import TrainState

    return TrainState(params=param_specs, opt=opt_state_specs(param_specs),
                      step=P())


def zero1_state_specs(fsdp_param_specs):
    """ZeRO-1 TrainState specs: live (bf16) params lose the 'data' axis;
    the fp32 master + adam moments inside the optimizer keep it."""
    from repro.train.train_step import TrainState

    live = jax.tree_util.tree_map(
        _drop_data, fsdp_param_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
    opt = {"master": fsdp_param_specs,
           "inner": opt_state_specs(fsdp_param_specs)}
    return TrainState(params=live, opt=opt, step=P()), live


# --------------------------------------------------------------- recsys ---
def recsys_param_specs(cfg, params):
    def mlp_specs(layers):
        return [
            {k: P(*([None] * v.ndim)) for k, v in layer.items()}
            for layer in layers
        ]

    if cfg.kind in ("dlrm", "dcn"):
        specs = {"table": P("model", None)}
        if cfg.kind == "dlrm":
            specs["bot"] = mlp_specs(params["bot"])
            specs["top"] = mlp_specs(params["top"])
        else:
            specs["cross"] = [
                {"w": P(None, None), "b": P(None)} for _ in params["cross"]
            ]
            specs["deep"] = mlp_specs(params["deep"])
        return specs
    if cfg.kind == "din":
        return {
            "items": P("model", None),
            "attn": mlp_specs(params["attn"]),
            "mlp": mlp_specs(params["mlp"]),
        }
    if cfg.kind == "bst":
        return {
            "items": P("model", None),
            "pos": P(None, None),
            "blocks": [
                {k: P(*([None] * v.ndim)) for k, v in b.items()}
                for b in params["blocks"]
            ],
            "mlp": mlp_specs(params["mlp"]),
        }
    raise ValueError(cfg.kind)


def recsys_batch_specs(cfg, mesh):
    dp = dp_axes(mesh)
    if cfg.kind in ("dlrm", "dcn"):
        return {"dense": P(dp, None), "sparse": P(dp, None), "label": P(dp)}
    return {"hist": P(dp, None), "mask": P(dp, None), "target": P(dp),
            "label": P(dp)}


# ------------------------------------------------------------------ gnn ---
def gnn_param_specs(params):
    return {
        "layers": [
            {"w_self": P(None, None), "w_neigh": P(None, None), "b": P(None)}
            for _ in params["layers"]
        ],
        "cls": P(None, None),
    }


# ------------------------------------------------------------------ icd ---
def icd_mf_specs(mesh):
    """W rows (contexts) over dp; H rows (items) over model; observation
    arrays over dp. The k×k Grams replicate — Lemma 2's k² all-reduce."""
    dp = dp_axes(mesh)
    from repro.core.models.mf import MFParams

    params = MFParams(w=P(dp, None), h=P("model", None))
    data = dict(
        ctx=P(dp), item=P(dp), y=P(dp), alpha=P(dp),
        t_ctx=P(dp), t_item=P(dp), t_perm=P(dp),
    )
    return params, data
