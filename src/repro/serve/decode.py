"""LM serving: greedy/temperature decode over the KV cache."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as T


def generate(
    cfg,
    params,
    prompt: jax.Array,          # (B, S_prompt)
    max_new_tokens: int,
    max_seq: Optional[int] = None,
    temperature: float = 0.0,
    key: Optional[jax.Array] = None,
    compute_dtype=jnp.bfloat16,
):
    """Prefill token-by-token then decode ``max_new_tokens`` greedily (or
    sampled). Small-scale serving driver used by the examples; the
    production decode path is the jitted ``decode_step`` itself."""
    b, s_prompt = prompt.shape
    max_seq = max_seq or (s_prompt + max_new_tokens)
    cache = T.init_cache(cfg, b, max_seq, dtype=compute_dtype)

    step = jax.jit(
        partial(T.decode_step, cfg, compute_dtype=compute_dtype),
        static_argnames=(),
    )

    logits = None
    for t in range(s_prompt):
        logits, cache = step(params, cache, prompt[:, t : t + 1], jnp.int32(t))

    tokens = [prompt]
    cur = None
    for i in range(max_new_tokens):
        last = logits[:, -1]
        if temperature > 0 and key is not None:
            key, sub = jax.random.split(key)
            cur = jax.random.categorical(sub, last / temperature)[:, None]
        else:
            cur = jnp.argmax(last, axis=-1)[:, None]
        tokens.append(cur)
        logits, cache = step(params, cache, cur, jnp.int32(s_prompt + i))
    return jnp.concatenate(tokens, axis=1)
