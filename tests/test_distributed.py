"""Distributed semantics tests.

These run in a SUBPROCESS with ``--xla_force_host_platform_device_count=8``
so the main pytest process keeps its single-device view (the dry-run is the
only place that forces 512). Covered:

  * sharded_gram (shard_map + psum) == global gram
  * pjit'd iCD-MF epoch on a (4,2) mesh == single-device epoch
  * elastic resharding: checkpoint from an 8-device mesh restores onto a
    4-device mesh (simulated node loss) and training continues bit-exact
  * int8 EF compressed psum across shards ≈ uncompressed mean
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from functools import partial
    import sys
    sys.path.insert(0, "src")

    assert len(jax.devices()) == 8

    # ---- 1. sharded gram == global gram ---------------------------------
    from repro.core.gram import gram, sharded_gram
    mesh = jax.make_mesh((8,), ("rows",))
    m = jax.random.normal(jax.random.PRNGKey(0), (64, 6))
    f = shard_map(partial(sharded_gram, axis_name="rows"), mesh=mesh,
                  in_specs=P("rows", None), out_specs=P())
    np.testing.assert_allclose(f(m), gram(m), rtol=1e-5, atol=1e-5)
    print("sharded_gram OK")

    # ---- 2. pjit iCD-MF epoch == single-device --------------------------
    from repro.core.models import mf
    from repro.sparse.interactions import build_interactions
    rng = np.random.default_rng(0)
    n_ctx, n_items, nnz = 32, 24, 128
    cells = rng.choice(n_ctx * n_items, nnz, replace=False)
    ctx, item = cells // n_items, cells % n_items
    data = build_interactions(ctx, item, np.ones(nnz), np.full(nnz, 1.5),
                              n_ctx, n_items, alpha0=0.5)
    hp = mf.MFHyperParams(k=4, alpha0=0.5, l2=0.1)
    params = mf.init(jax.random.PRNGKey(1), n_ctx, n_items, 4)
    e = mf.residuals(params, data)
    ref_p, ref_e = mf.epoch(params, data, e, hp)

    mesh2 = jax.make_mesh((4, 2), ("data", "model"))
    dsh = lambda spec: NamedSharding(mesh2, spec)
    p_sh = mf.MFParams(w=dsh(P("data", None)), h=dsh(P("model", None)))
    import dataclasses
    d_sharded = jax.device_put(data, jax.tree_util.tree_map(
        lambda _: dsh(P("data")), data))
    p_sharded = jax.device_put(params, p_sh)
    e_sharded = jax.device_put(e, dsh(P("data")))
    with mesh2:
        got_p, got_e = jax.jit(
            lambda p, d, ee: mf.epoch(p, d, ee, hp),
            in_shardings=(p_sh, jax.tree_util.tree_map(lambda _: dsh(P("data")), data),
                          dsh(P("data"))),
            out_shardings=(p_sh, dsh(P("data"))),
        )(p_sharded, d_sharded, e_sharded)
    np.testing.assert_allclose(np.asarray(got_p.w), np.asarray(ref_p.w),
                               rtol=5e-4, atol=5e-5)
    np.testing.assert_allclose(np.asarray(got_p.h), np.asarray(ref_p.h),
                               rtol=5e-4, atol=5e-5)
    print("pjit iCD epoch OK")

    # ---- 3. elastic resharding restore ----------------------------------
    import tempfile
    from repro.checkpoint import Checkpointer
    from repro.runtime.elastic import ElasticMeshManager
    state = {"w": jax.device_put(jnp.arange(32.0).reshape(8, 4),
                                 dsh(P("data", None)))}
    tmp = tempfile.mkdtemp()
    ck = Checkpointer(tmp)
    ck.save(1, state, blocking=True)
    mgr = ElasticMeshManager(model_axis=2)
    small = mgr.on_failure([d.id for d in jax.devices()[4:]])  # lose 4 devices
    assert small.devices.size == 4
    sh2 = NamedSharding(small, P("data", None))
    restored = ck.restore(1, state, {"w": sh2})
    np.testing.assert_allclose(np.asarray(restored["w"]), np.asarray(state["w"]))
    assert restored["w"].sharding.mesh.devices.size == 4
    print("elastic reshard OK")

    # ---- 4. compressed psum ---------------------------------------------
    from repro.optim.compression import compressed_psum
    g = jax.random.normal(jax.random.PRNGKey(2), (8, 128))
    err0 = jnp.zeros((8, 128))
    f = shard_map(partial(compressed_psum, axis_name="rows"), mesh=mesh,
                  in_specs=(P("rows", None), P("rows", None)),
                  out_specs=(P(None, None), P("rows", None)))
    # note: out mean is replicated; per-shard err returned sharded
    mean_hat, err = f(g, err0)
    true_mean = jnp.mean(g, axis=0, keepdims=True)
    np.testing.assert_allclose(np.asarray(mean_hat)[0], np.asarray(true_mean)[0],
                               atol=0.05)
    print("compressed psum OK")
    print("ALL-DISTRIBUTED-OK")
    """
)


@pytest.mark.slow
def test_distributed_semantics():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, cwd=os.path.dirname(os.path.dirname(__file__)),
        env={**env, "PYTHONPATH": "src"}, timeout=600,
    )
    assert "ALL-DISTRIBUTED-OK" in proc.stdout, proc.stdout + proc.stderr
