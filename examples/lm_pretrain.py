"""End-to-end LM training driver (smoke scale): a few hundred steps on
synthetic bigram-structured tokens with checkpointing and resume.

    PYTHONPATH=src python examples/lm_pretrain.py --steps 200
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs import get_smoke_config
from repro.data.loader import lm_token_batches
from repro.models import transformer as T
from repro.optim import adamw, linear_warmup_cosine
from repro.train.train_step import build_train_step, init_state
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="gemma2-2b")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw(linear_warmup_cosine(3e-3, 20, args.steps))
    step = jax.jit(build_train_step(
        lambda p, b: T.loss_fn(cfg, p, b["tokens"], b["targets"],
                               compute_dtype=jnp.float32),
        opt,
    ))
    data = (
        {"tokens": jnp.asarray(b["tokens"]), "targets": jnp.asarray(b["targets"])}
        for b in lm_token_batches(cfg.vocab, 16, 64, seed=0)
    )
    ckdir = tempfile.mkdtemp(prefix="lm_ckpt_")
    trainer = Trainer(step, init_state(params, opt), data,
                      checkpointer=Checkpointer(ckdir), ckpt_every=50,
                      log_every=25)
    trainer.run(args.steps)

    losses = [m["loss"] for m in trainer.metrics_history]
    first, last = sum(losses[:10]) / 10, sum(losses[-10:]) / 10
    print(f"\nloss: first-10 avg {first:.3f} → last-10 avg {last:.3f}")
    assert last < first - 0.5, "model should learn the bigram structure"
    print(f"checkpoints in {ckdir}; restart this script with the same dir to "
          "resume (Trainer.maybe_resume)")


if __name__ == "__main__":
    main()
