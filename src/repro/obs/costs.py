"""Kernel cost accounting: dispatch-site shim over the ``kernels/vmem.py``
analytic models.

The benches (``benchmarks/serve_bench.py``, ``benchmarks/roofline_bench``)
have always priced the kernels analytically — HBM bytes from the declared
streaming pattern, FLOPs from the einsum shapes, VMEM from the tile fit.
This module records the SAME models into the metrics registry at every
host-level dispatch site (engine/cluster/mesh ``topk_score`` calls, IVF
probe blocks, the training fit loop's cd_sweep epochs), so live serving
and the benches report one cost model — and the serve bench hard-gates
that the counters reproduce the analytic numbers on its shapes.

Why dispatch-site, not in-kernel: the model ``epoch`` functions are
jitted, so a Python hook inside ``sweep_columns`` fires at trace time
only — it would count one epoch no matter how many run. Host call sites
execute per dispatch, and the analytic models need only the static
shapes that are in hand there.

Counters (labels: ``kernel``):

  ``kernel_calls_total``       dispatches
  ``kernel_hbm_bytes_total``   analytic HBM bytes streamed
  ``kernel_flops_total``       analytic FLOPs
  ``kernel_vmem_tile_bytes``   (gauge) last dispatch's tile footprint
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.kernels.vmem import (
    VMEM_BUDGET_BYTES,
    VmemBudgetError,
    psi_row_bytes,
    topk_block_items,
)
from repro.obs.metrics import resolve_registry


def _pad(x: int, m: int) -> int:
    return -(-int(x) // m) * m


def topk_score_cost(
    b: int,
    n_rows: int,
    d: int,
    k: int,
    *,
    psi_bytes: int = 4,
    per_row_scale: bool = False,
    excl_l: int = 0,
) -> Dict[str, float]:
    """Analytic cost of ONE fused ``topk_score`` dispatch over ``n_rows``
    stored ψ rows: the ψ stream (at its stored width —
    :func:`~repro.kernels.vmem.psi_row_bytes`), the φ read, the final
    (B, K_pad) score/id blocks (the running merge rides VMEM — matching
    ``serve_bench.topk_traffic_bytes``'s fused model), and the exclude-id
    lists when present; FLOPs are the score matmul's ``2·B·n_rows·D``."""
    k_pad = _pad(k, 128)
    hbm = (
        float(n_rows) * psi_row_bytes(
            d, psi_bytes=psi_bytes, per_row_scale=per_row_scale)
        + 4.0 * b * d
        + 2 * 4.0 * b * k_pad
        + 4.0 * b * excl_l
    )
    d_pad = _pad(max(d, 1), 128)
    block_b = _pad(max(b, 1), 8)
    try:
        block_items = topk_block_items(
            block_b, d_pad, k_pad, n_items=n_rows,
            psi_bytes=psi_bytes, per_row_scale=per_row_scale,
        )
        stored = psi_bytes * d_pad + (4 * d_pad if psi_bytes < 4 else 0)
        per_row = stored + 16 * block_b + (4 if per_row_scale else 0)
        fixed = 4 * (block_b * d_pad + 4 * block_b * k_pad)
        vmem = float(fixed + block_items * per_row)
    except VmemBudgetError:
        vmem = float(VMEM_BUDGET_BYTES)
    return {
        "hbm_bytes": hbm,
        "flops": 2.0 * b * n_rows * d,
        "vmem_tile_bytes": vmem,
    }


def cd_sweep_cost(c: int, d_pad: int, k: int, k_b: int) -> Dict[str, float]:
    """Analytic cost of ONE side's fused k-column cd_sweep over the padded
    `(C, D_pad)` layout (``benchmarks/roofline_bench.cd_sweep_sweep_bytes``
    fused model): ψ read once per column, α + 2·e streams amortized per
    k_b block, the per-column (C,) slabs, and the block's k_b² Gram
    patch. FLOPs ≈ 6·C·D_pad per column (score, gradient, residual
    patch)."""
    cd = 4.0 * c * d_pad
    col = 4.0 * c
    n_blocks = float(-(-k // k_b))
    hbm = (k * cd + 3 * n_blocks * cd + 3 * k * col
           + n_blocks * 4.0 * k_b * k_b)
    return {
        "hbm_bytes": hbm,
        "flops": 6.0 * c * d_pad * k,
        "vmem_tile_bytes": 4.0 * (k_b + 3) * d_pad * 8,  # minimal 8-row tile
    }


class KernelCostRecorder:
    """Registry-bound recorder; resolve once, record per dispatch.

    Children are cached per kernel label so the serve hot path pays a
    dict hit + three float adds per dispatch. With
    :data:`~repro.obs.metrics.NULL_REGISTRY` every record is a no-op."""

    def __init__(self, registry=None):
        reg = resolve_registry(registry)
        self._calls = reg.counter(
            "kernel_calls_total", "kernel dispatches", labels=("kernel",))
        self._hbm = reg.counter(
            "kernel_hbm_bytes_total",
            "analytic HBM bytes streamed (kernels/vmem.py model)",
            labels=("kernel",))
        self._flops = reg.counter(
            "kernel_flops_total", "analytic FLOPs", labels=("kernel",))
        self._vmem = reg.gauge(
            "kernel_vmem_tile_bytes",
            "last dispatch's analytic VMEM tile footprint",
            labels=("kernel",))
        self._children: Dict[str, tuple] = {}

    def _resolve(self, kernel: str):
        ch = self._children.get(kernel)
        if ch is None:
            ch = (
                self._calls.labels(kernel=kernel),
                self._hbm.labels(kernel=kernel),
                self._flops.labels(kernel=kernel),
                self._vmem.labels(kernel=kernel),
            )
            self._children[kernel] = ch
        return ch

    def record(self, kernel: str, cost: Dict[str, float],
               calls: int = 1) -> None:
        calls_c, hbm_c, flops_c, vmem_g = self._resolve(kernel)
        calls_c.inc(calls)
        hbm_c.inc(cost["hbm_bytes"])
        flops_c.inc(cost["flops"])
        vmem_g.set(cost.get("vmem_tile_bytes", 0.0))

    def record_topk(self, b: int, n_rows: int, d: int, k: int, *,
                    kernel: str = "topk_score",
                    psi_bytes: int = 4, per_row_scale: bool = False,
                    excl_l: int = 0) -> None:
        self.record(kernel, topk_score_cost(
            b, n_rows, d, k, psi_bytes=psi_bytes,
            per_row_scale=per_row_scale, excl_l=excl_l,
        ))

    def record_cd_sweep(self, c: int, d_pad: int, k: int, k_b: int, *,
                        kernel: str = "cd_sweep", sweeps: int = 1) -> None:
        cost = cd_sweep_cost(c, d_pad, k, k_b)
        self.record(kernel, {
            "hbm_bytes": cost["hbm_bytes"] * sweeps,
            "flops": cost["flops"] * sweeps,
            "vmem_tile_bytes": cost["vmem_tile_bytes"],
        }, calls=sweeps)


_null_recorder: Optional[KernelCostRecorder] = None


def null_recorder() -> KernelCostRecorder:
    """Shared no-op recorder (bound to NULL_REGISTRY) for bare mode."""
    global _null_recorder
    if _null_recorder is None:
        from repro.obs.metrics import NULL_REGISTRY

        _null_recorder = KernelCostRecorder(NULL_REGISTRY)
    return _null_recorder
