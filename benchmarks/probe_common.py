"""Shared probe-calibration math for the LM hillclimb scripts.

Model (mirrors repro/launch/calibrate.py):
  flops/bytes:  full = u11 + (L−1)·per_layer         (microbatch-invariant)
  collectives:  per-layer term splits into token-proportional `a` and
                param-constant `b` via half-batch probes; only `b` repeats
                per microbatch:
  full = u11 + (L−1)·(a+b) + (M−1)·per_mb + (M−1)(L−1)·b
"""
from __future__ import annotations

import numpy as np

COMPONENTS = ("flops", "bytes", "all-gather", "all-reduce", "reduce-scatter",
              "all-to-all", "collective-permute")


def combine(u11, u21, u11h, u21h, u12, l_full, m_full):
    per_layer = np.maximum(u21 - u11, 0.0)
    per_layer_h = np.maximum(u21h - u11h, 0.0)
    b_const = np.clip(2.0 * per_layer_h - per_layer, 0.0, per_layer)
    per_mb = np.maximum(u12 - u11, 0.0)
    full = u11 + (l_full - 1) * per_layer
    coll = slice(2, len(COMPONENTS))
    full[coll] = (
        u11[coll]
        + (l_full - 1) * per_layer[coll]
        + (m_full - 1) * per_mb[coll]
        + (m_full - 1) * (l_full - 1) * b_const[coll]
    )
    return np.maximum(full, 0.0), dict(
        per_layer_param_const=b_const[coll].sum(),
        per_layer_token_prop=(per_layer[coll] - b_const[coll]).sum(),
    )
