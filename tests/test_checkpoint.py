"""Checkpointer: roundtrip, atomicity, retention, corruption detection,
resume-from-latest, trainer integration."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.optim import sgd
from repro.train.train_step import build_train_step, init_state
from repro.train.trainer import Trainer


def _state():
    return {
        "params": {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones((3,))},
        "step": jnp.int32(7),
    }


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    state = _state()
    ck.save(7, state, blocking=True)
    restored = ck.restore(7, state)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_allclose(a, b)


def test_retention_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    state = _state()
    for s in (1, 2, 3, 4):
        ck.save(s, state, blocking=True)
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_corruption_detected(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    state = _state()
    ck.save(1, state, blocking=True)
    d = os.path.join(str(tmp_path), "step_0000000001")
    fname = json.load(open(os.path.join(d, "manifest.json")))["leaves"][0]["file"]
    with open(os.path.join(d, fname), "r+b") as f:
        f.seek(60)
        f.write(b"\xde\xad")
    with pytest.raises(IOError):
        ck.restore(1, state)


def test_tmp_dirs_ignored(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    os.makedirs(os.path.join(str(tmp_path), "step_0000000009.tmp"))
    assert ck.all_steps() == []
    # a step dir without manifest (crash before fsync) is also invalid
    os.makedirs(os.path.join(str(tmp_path), "step_0000000010"))
    assert ck.all_steps() == []


def test_structure_mismatch_rejected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _state(), blocking=True)
    with pytest.raises(ValueError):
        ck.restore(1, {"params": {"w": jnp.zeros((2, 3))}})


def test_trainer_resume(tmp_path):
    """Kill the trainer after 6 steps, restart, verify it resumes and the
    final state equals an uninterrupted 10-step run."""

    def loss(p, b):
        return jnp.sum((p - b["t"]) ** 2)

    opt = sgd(0.1)
    step_fn = build_train_step(loss, opt)

    def data():
        while True:
            yield {"t": jnp.asarray([1.0, 2.0])}

    def run(n_steps, ck):
        state = init_state(jnp.zeros(2), opt)
        tr = Trainer(step_fn, state, data(), checkpointer=ck, ckpt_every=2,
                     log_every=1000, log_fn=lambda s: None)
        return tr.run(n_steps)

    ck = Checkpointer(str(tmp_path / "a"), keep=5)
    run(6, ck)                         # "crash" at step 6 (checkpoint saved)
    resumed = run(10, ck)              # restart, resumes from 6

    ck2 = Checkpointer(str(tmp_path / "b"), keep=5)
    straight = run(10, ck2)

    np.testing.assert_allclose(resumed.params, straight.params, rtol=1e-6)
    assert int(resumed.step) == 10
