"""Pure-jnp oracles for the fused block-sweep kernels: sequential
per-column Newton steps with the explicit Gauss–Seidel R' patch between
columns (shared-Gram and per-row-patch variants), plus the slab-moment and
rank-k_b residual-patch reductions."""
import jax.numpy as jnp


def cd_block_sweep_ref(psi_blk, alpha, e, w_blk, r1_blk, j_blk, *, alpha0, l2,
                       eta=1.0):
    k_b = psi_blk.shape[1]
    w_cols = []
    r1 = r1_blk
    for j in range(k_b):
        psi_j = psi_blk[:, j, :]
        lp = jnp.sum(alpha * e * psi_j, axis=1)
        lpp = jnp.sum(alpha * psi_j * psi_j, axis=1)
        num = lp + alpha0 * r1[:, j] + l2 * w_blk[:, j]
        den = lpp + alpha0 * j_blk[j, j] + l2
        delta = -eta * num / jnp.maximum(den, 1e-12)
        w_cols.append(w_blk[:, j] + delta)
        e = e + delta[:, None] * psi_j
        r1 = r1 + delta[:, None] * j_blk[j, :][None, :]
    return jnp.stack(w_cols, axis=1), e


def cd_block_sweep_rowpatch_ref(psi_blk, alpha, e, w_blk, r1_blk, p_blk, *,
                                alpha0, l2, eta=1.0):
    """Per-row patch variant: P[r, j, f] patches R'_f after column j moves;
    P[r, f, f] is the per-row R''/2."""
    k_b = psi_blk.shape[1]
    w_cols = []
    r1 = r1_blk
    for j in range(k_b):
        psi_j = psi_blk[:, j, :]
        lp = jnp.sum(alpha * e * psi_j, axis=1)
        lpp = jnp.sum(alpha * psi_j * psi_j, axis=1)
        num = lp + alpha0 * r1[:, j] + l2 * w_blk[:, j]
        den = lpp + alpha0 * p_blk[:, j, j] + l2
        delta = -eta * num / jnp.maximum(den, 1e-12)
        w_cols.append(w_blk[:, j] + delta)
        e = e + delta[:, None] * psi_j
        r1 = r1 + delta[:, None] * p_blk[:, j, :]
    return jnp.stack(w_cols, axis=1), e


def cd_slab_reduce_ref(psi_blk, alpha, e):
    q = jnp.einsum("cmd,cd->cm", psi_blk, alpha * e)
    p = jnp.einsum("cmd,cnd->cmn", psi_blk * alpha[:, None, :], psi_blk)
    return q, p


def cd_resid_patch_ref(psi_blk, e, dphi_blk):
    return e + jnp.einsum("cm,cmd->cd", dphi_blk, psi_blk)


# ------------------------------------------------------------------------
# Gather-variant oracles: materialize the (C, m, D_pad) Ψ tile from the
# (n_src, m) slab + (C, D_pad) id grid (exactly what the in-kernel gather
# avoids doing in HBM), then reuse the pre-gathered oracles.
# ------------------------------------------------------------------------
def gather_psi_blk(psi_tab, ids):
    """(n_src, m) slab + (C, D_pad) ids → (C, m, D_pad) Ψ tile."""
    return jnp.moveaxis(jnp.take(psi_tab, ids, axis=0, mode="clip"), -1, 1)


def cd_block_sweep_gather_ref(psi_tab, ids, alpha, e, w_blk, r1_blk, j_blk,
                              *, alpha0, l2, eta=1.0):
    return cd_block_sweep_ref(
        gather_psi_blk(psi_tab, ids), alpha, e, w_blk, r1_blk, j_blk,
        alpha0=alpha0, l2=l2, eta=eta,
    )


def cd_block_sweep_rowpatch_gather_ref(psi_tab, ids, alpha, e, w_blk, r1_blk,
                                       p_blk, *, alpha0, l2, eta=1.0):
    return cd_block_sweep_rowpatch_ref(
        gather_psi_blk(psi_tab, ids), alpha, e, w_blk, r1_blk, p_blk,
        alpha0=alpha0, l2=l2, eta=eta,
    )


def cd_slab_reduce_gather_ref(psi_tab, ids, alpha, e):
    return cd_slab_reduce_ref(gather_psi_blk(psi_tab, ids), alpha, e)


def cd_resid_patch_gather_ref(psi_tab, ids, e, dphi_blk):
    return cd_resid_patch_ref(gather_psi_blk(psi_tab, ids), e, dphi_blk)
