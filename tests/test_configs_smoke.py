"""Model-zoo smoke tests + the iCD config registry.

The seed-template LM/RecSys/GNN CONFIG modules were removed (PR 4 — they
belonged to another paper's template); the model code they exercised stays,
so these smoke tests build reduced inline configs from the shared
``configs.base`` dataclasses instead of the registry. The registry itself
now only carries the paper's own iCD configs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _smoke_configs import GNN_SMOKE, LM_SMOKE, RECSYS_SMOKE

from repro.configs import ARCH_IDS, get_config, get_shapes, get_smoke_config


def _finite(tree) -> bool:
    return all(bool(jnp.isfinite(x).all()) for x in jax.tree_util.tree_leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


# ------------------------------------------------------------------ LM ----
@pytest.mark.parametrize("arch", sorted(LM_SMOKE))
def test_lm_smoke_forward_and_train_step(arch):
    from repro.models import transformer as T

    cfg = LM_SMOKE[arch]
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab)

    logits, aux = T.forward(cfg, params, toks, compute_dtype=jnp.float32)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())

    loss, grads = jax.value_and_grad(
        lambda p: T.loss_fn(cfg, p, toks, toks, compute_dtype=jnp.float32)
    )(params)
    assert bool(jnp.isfinite(loss))
    assert _finite(grads)


@pytest.mark.parametrize("arch", sorted(LM_SMOKE))
def test_lm_smoke_decode_step(arch):
    from repro.models import transformer as T

    cfg = LM_SMOKE[arch]
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    cache = T.init_cache(cfg, 2, 32, dtype=jnp.float32)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, cache = T.decode_step(cfg, params, cache, tok, jnp.int32(0),
                                  compute_dtype=jnp.float32)
    assert logits.shape == (2, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


# -------------------------------------------------------------- recsys ----
def _recsys_batch(cfg, rng, batch=8):
    if cfg.kind in ("dlrm", "dcn"):
        return {
            "dense": jnp.asarray(rng.normal(size=(batch, cfg.n_dense)), jnp.float32),
            "sparse": jnp.asarray(
                rng.integers(0, min(cfg.table_vocabs), size=(batch, cfg.n_sparse)),
                jnp.int32),
            "label": jnp.asarray(rng.integers(0, 2, batch), jnp.float32),
        }
    return {
        "hist": jnp.asarray(
            rng.integers(0, cfg.item_vocab, size=(batch, cfg.seq_len)), jnp.int32),
        "mask": jnp.asarray(rng.integers(0, 2, (batch, cfg.seq_len)), jnp.float32),
        "target": jnp.asarray(rng.integers(0, cfg.item_vocab, batch), jnp.int32),
        "label": jnp.asarray(rng.integers(0, 2, batch), jnp.float32),
    }


def _recsys_module(cfg):
    from repro.models import bst, dcn, din, dlrm

    return {"dlrm": dlrm, "dcn": dcn, "din": din, "bst": bst}[cfg.kind]


@pytest.mark.parametrize("kind", sorted(RECSYS_SMOKE))
def test_recsys_smoke_train_step(kind):
    cfg = RECSYS_SMOKE[kind]
    mod = _recsys_module(cfg)
    rng = np.random.default_rng(0)
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    batch = _recsys_batch(cfg, rng)
    loss, grads = jax.value_and_grad(lambda p: mod.loss_fn(cfg, p, batch))(params)
    assert bool(jnp.isfinite(loss))
    assert _finite(grads)


@pytest.mark.parametrize("kind", sorted(RECSYS_SMOKE))
def test_recsys_smoke_retrieval(kind):
    cfg = RECSYS_SMOKE[kind]
    mod = _recsys_module(cfg)
    rng = np.random.default_rng(1)
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    n_cand = 50
    if cfg.kind in ("dlrm", "dcn"):
        cand = jnp.asarray(rng.integers(0, cfg.table_vocabs[0], n_cand), jnp.int32)
        scores = mod.score_candidates(
            cfg, params,
            jnp.asarray(rng.normal(size=(1, cfg.n_dense)), jnp.float32),
            jnp.asarray(rng.integers(0, min(cfg.table_vocabs), (1, cfg.n_sparse)), jnp.int32),
            cand,
        )
    else:
        cand = jnp.asarray(rng.integers(0, cfg.item_vocab, n_cand), jnp.int32)
        scores = mod.score_candidates(
            cfg, params,
            jnp.asarray(rng.integers(0, cfg.item_vocab, (1, cfg.seq_len)), jnp.int32),
            jnp.ones((1, cfg.seq_len), jnp.float32),
            cand,
        )
    assert scores.shape == (n_cand,)
    assert bool(jnp.isfinite(scores).all())


# ----------------------------------------------------------------- gnn ----
def test_gnn_smoke_full_and_minibatch_and_batched():
    from repro.models import graphsage as G
    from repro.sparse import build_adjacency, neighbor_sampler

    cfg = GNN_SMOKE
    rng = np.random.default_rng(0)
    n, d_feat = 60, 12
    params = G.init_params(jax.random.PRNGKey(0), cfg, d_feat)
    feats = jnp.asarray(rng.normal(size=(n, d_feat)), jnp.float32)
    src = rng.integers(0, n, 240)
    dst = rng.integers(0, n, 240)
    edges = jnp.asarray(np.stack([src, dst], 1), jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.n_classes, n), jnp.int32)

    # full-batch
    logits, h = G.forward_full(cfg, params, feats, edges)
    assert logits.shape == (n, cfg.n_classes)
    loss, grads = jax.value_and_grad(
        lambda p: G.ce_loss(G.forward_full(cfg, p, feats, edges)[0], labels)
    )(params)
    assert bool(jnp.isfinite(loss)) and _finite(grads)

    # minibatch via the real sampler
    adj = build_adjacency(src, dst, n)
    seeds = jnp.asarray(rng.integers(0, n, 8), jnp.int32)
    frontiers = neighbor_sampler(jax.random.PRNGKey(1), adj, seeds,
                                 cfg.sample_sizes)
    f_feats = [jnp.take(feats, f, axis=0) for f in frontiers]
    logits_mb, _ = G.forward_minibatch(cfg, params, f_feats)
    assert logits_mb.shape == (8, cfg.n_classes)
    assert bool(jnp.isfinite(logits_mb).all())

    # batched small graphs
    bg_feats = jnp.asarray(rng.normal(size=(5, 7, d_feat)), jnp.float32)
    adj_d = jnp.asarray(rng.random((5, 7, 7)) < 0.4, jnp.float32)
    adj_d = adj_d / jnp.maximum(adj_d.sum(-1, keepdims=True), 1)
    logits_b, _ = G.forward_batched(cfg, params, bg_feats, adj_d)
    assert logits_b.shape == (5, cfg.n_classes)

    # iCD link loss (Lemma-2 exact negatives) matches brute force
    z = jnp.asarray(rng.normal(size=(n, 6)), jnp.float32)
    got = G.icd_link_loss(z, edges, alpha0=0.2)
    s = z @ z.T
    pos = jnp.sum((jnp.sum(jnp.take(z, edges[:, 0], 0) * jnp.take(z, edges[:, 1], 0), -1) - 1) ** 2)
    expect = pos + 0.2 * jnp.sum(s * s)
    np.testing.assert_allclose(got, expect, rtol=1e-4)


# ------------------------------------------------------------- iCD own ----
@pytest.mark.parametrize("arch", ["icd-mf", "icd-fm"])
def test_icd_config_smoke(arch):
    cfg = get_smoke_config(arch)
    assert cfg.model in ("mf", "fm")
    assert get_config(arch).n_ctx >= 1000 * cfg.n_ctx / 1000  # full is bigger


def test_registry_complete():
    assert len(ARCH_IDS) == 2  # only the paper's own configs remain
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        shapes = get_shapes(arch)
        assert cfg.name == arch
        assert len(shapes) >= 3
