"""The paper's own iCD-MF at the §6 scale (200k users × 68k videos)."""
import dataclasses

from repro.configs.base import ICD_SHAPES, ICDConfig

CONFIG = ICDConfig(
    name="icd-mf",
    model="mf",
    n_ctx=200_000,
    n_items=68_000,
    k=128,
    alpha0=1.0,
    l2=0.1,
)

SMOKE_CONFIG = dataclasses.replace(CONFIG, n_ctx=60, n_items=40, k=8)

SHAPES = ICD_SHAPES
