"""Pallas flash attention (online softmax), TPU-tiled.

Single-head program: q (Sq, d), k/v (Skv, d) → o (Sq, d); batch and heads
are vmapped in ops.py. Grid (q_blocks, kv_blocks) with kv innermost; the
(bq, d) output accumulator plus (bq, 1) running max / sum live in VMEM
scratch that persists across the kv sweep of one q block.

Supported masks (all composable):
  causal           — global q position ≥ kv position (q_offset shifts the
                     q positions; decode passes Sq=1, q_offset=kv_len−1)
  sliding window   — kv position > q position − window  (Gemma-2 local)
  kv_len           — kv padding mask
Logit soft-capping (Gemma-2): s ← cap·tanh(s/cap).

Fully-masked kv blocks are SKIPPED via pl.when on the block indices — for
causal self-attention this halves the FLOPs (see EXPERIMENTS.md §Perf).

VMEM per step: (bq+2·bkv)·d·4 + bq·bkv·4 ≈ 1.6 MiB at bq=bkv=512, d=128.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    causal, window, softcap, kv_len, q_offset, scale, bq, bkv,
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # --- block-level skip: any (q, kv) pair in this tile alive? ----------
    q_lo = i * bq + q_offset          # global position of first q row
    q_hi = q_lo + bq - 1
    kv_lo = j * bkv
    alive = kv_lo < kv_len
    if causal:
        alive = jnp.logical_and(alive, kv_lo <= q_hi)
    if window is not None:
        alive = jnp.logical_and(alive, (j + 1) * bkv - 1 > q_lo - window)

    @pl.when(alive)
    def _compute():
        q = q_ref[...].astype(jnp.float32) * scale
        k = k_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bkv)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)

        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kv_pos = kv_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = kv_pos < kv_len
        if causal:
            mask = jnp.logical_and(mask, q_pos >= kv_pos)
        if window is not None:
            mask = jnp.logical_and(mask, kv_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                   # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _finalize():
        denom = l_scr[...]
        o_ref[...] = (acc_scr[...] / jnp.maximum(denom, 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,        # (Sq, d)
    k: jax.Array,        # (Skv, d)
    v: jax.Array,        # (Skv, d)
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    q_offset: int = 0,
    kv_len: int | None = None,
    block_q: int = 512,
    block_kv: int = 512,
    interpret: bool = True,
) -> jax.Array:
    sq, d = q.shape
    skv = k.shape[0]
    kv_len = skv if kv_len is None else kv_len
    scale = 1.0 / math.sqrt(d)

    bq = min(block_q, max(8, sq))
    bkv = min(block_kv, max(8, skv))
    sq_pad = -(-sq // bq) * bq
    skv_pad = -(-skv // bkv) * bkv
    if sq_pad != sq:
        q = jnp.pad(q, ((0, sq_pad - sq), (0, 0)))
    if skv_pad != skv:
        k = jnp.pad(k, ((0, skv_pad - skv), (0, 0)))
        v = jnp.pad(v, ((0, skv_pad - skv), (0, 0)))

    kern = functools.partial(
        _flash_kernel, causal, window, softcap, min(kv_len, skv), q_offset,
        scale, bq, bkv,
    )
    out = pl.pallas_call(
        kern,
        grid=(sq_pad // bq, skv_pad // bkv),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bkv, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bkv, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sq_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:sq]
