"""RecSys serving paths: p99 online batches, offline bulk, retrieval top-k.

The chunked ``retrieval_topk`` oracle now lives with its dense sibling in
:mod:`repro.kernels.topk_score.ref` (one home for the kernel's reference
semantics); it is re-exported here unchanged for existing callers.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.kernels.topk_score.ref import retrieval_topk  # noqa: F401


def bulk_score(forward: Callable, batch, chunk: int = 65536):
    """Offline scoring of a huge batch in fixed-size chunks (serve_bulk)."""
    n = jax.tree_util.tree_leaves(batch)[0].shape[0]
    outs = []
    for lo in range(0, n, chunk):
        piece = jax.tree_util.tree_map(lambda x: x[lo : lo + chunk], batch)
        outs.append(forward(piece))
    return jnp.concatenate(outs, axis=0)


def mf_retrieval_score_fn(user_vec: jax.Array, item_table: jax.Array):
    """The paper-native separable retrieval: one (k)·(k,N) matvec per id
    chunk — or a (B, k)·(k, N) matmul when ``user_vec`` is a (B, k) batch."""

    def score(ids):
        s = jnp.take(item_table, ids, axis=0) @ user_vec.T  # (c,) | (c, B)
        return s.T if s.ndim == 2 else s

    return score
