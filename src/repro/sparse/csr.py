"""CSR sparse-matrix pytree.

JAX only ships BCOO (``jax.experimental.sparse``); production recsys/GNN
pipelines want CSR for row-major traversal (per-context interaction lists,
per-node adjacency). This module provides a minimal, jit-compatible CSR
container plus converters. Values are optional (pattern-only CSR is used for
adjacency structure).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CSR:
    """Compressed sparse row matrix.

    Attributes:
      indptr:  (n_rows + 1,) int32 — row start offsets into ``indices``.
      indices: (nnz,) int32 — column ids, row-major sorted.
      data:    (nnz,) float — values; may be None for pattern-only matrices.
      n_rows:  static int.
      n_cols:  static int.
    """

    indptr: jax.Array
    indices: jax.Array
    data: Optional[jax.Array]
    n_rows: int = dataclasses.field(metadata=dict(static=True))
    n_cols: int = dataclasses.field(metadata=dict(static=True))

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    def row_degrees(self) -> jax.Array:
        return self.indptr[1:] - self.indptr[:-1]

    def with_data(self, data: jax.Array) -> "CSR":
        return dataclasses.replace(self, data=data)


def coo_to_csr(
    row: np.ndarray,
    col: np.ndarray,
    data: Optional[np.ndarray],
    n_rows: int,
    n_cols: int,
) -> CSR:
    """Build a CSR from (unsorted) COO triplets. Host-side (numpy) — this is
    data-pipeline code, not a traced op."""
    row = np.asarray(row, dtype=np.int64)
    col = np.asarray(col, dtype=np.int64)
    order = np.argsort(row, kind="stable")
    row, col = row[order], col[order]
    if data is not None:
        data = np.asarray(data)[order]
    counts = np.bincount(row, minlength=n_rows)
    indptr = np.zeros(n_rows + 1, dtype=np.int32)
    np.cumsum(counts, out=indptr[1:])
    return CSR(
        indptr=jnp.asarray(indptr, dtype=jnp.int32),
        indices=jnp.asarray(col, dtype=jnp.int32),
        data=None if data is None else jnp.asarray(data),
        n_rows=int(n_rows),
        n_cols=int(n_cols),
    )


def csr_row_ids(csr: CSR) -> jax.Array:
    """Expand indptr to per-nnz row ids: the COO row vector.

    Implemented with a searchsorted over indptr so it stays O(nnz log rows)
    and jit-friendly (no data-dependent shapes).
    """
    positions = jnp.arange(csr.indices.shape[0], dtype=jnp.int32)
    # row r owns positions [indptr[r], indptr[r+1]) — find r per position.
    return (
        jnp.searchsorted(csr.indptr, positions, side="right").astype(jnp.int32) - 1
    )


def transpose_csr_host(csr: CSR) -> CSR:
    """Host-side CSR transpose (CSC view of the same matrix as CSR)."""
    row_ids = np.asarray(csr_row_ids(csr))
    col_ids = np.asarray(csr.indices)
    data = None if csr.data is None else np.asarray(csr.data)
    return coo_to_csr(col_ids, row_ids, data, csr.n_cols, csr.n_rows)
