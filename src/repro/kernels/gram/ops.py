"""Jit'd public wrapper for the gram kernel."""
import jax

from repro.kernels import kernel_jit
from repro.kernels.gram.kernel import gram_pallas


@kernel_jit(static_argnames=("block_rows",))
def gram(x: jax.Array, block_rows: int = 1024, *, weights=None,
         interpret=None) -> jax.Array:
    """J = xᵀx, or the confidence-weighted xᵀ·diag(w)·x when ``weights``
    (per-row, shape (rows,)) is given. ``weights=None`` traces the identical
    unweighted program."""
    return gram_pallas(x, weights, block_rows=block_rows, interpret=interpret)
