from repro.kernels.cd_sweep.ops import (  # noqa: F401
    cd_block_sweep,
    cd_block_sweep_rowpatch,
    cd_resid_patch,
    cd_slab_reduce,
)
