"""k-separable model catalogue (paper §5) with exact iCD sweeps.

Every module exposes the same surface:

- ``init(key, ...) -> params``            parameter pytree
- ``phi(params, ...) / psi(params, ...)`` the k-separable decomposition
- ``export_psi(params, ...) -> (I, D)``   ψ table for the retrieval engine
- ``build_phi(params, <query>) -> (B, D)`` φ rows for a query batch (the
  serve/eval contract — column conventions in ``serve/engine.py``)
- ``predict(params, ...)``                scores for (context, item) pairs
- ``epoch(params, data, hp) -> params``   one full iCD epoch (ctx + item sweep)
- ``objective(params, data, hp)``         Lemma-1 objective for monitoring

MF (eq. 15), MF with side information (eq. 20), FM ((k+2)-separable, eq. 26),
PARAFAC (eq. 34, sparse & dense context), Tucker (k₃-separable, eq. 40).
"""

from repro.core.models import fm, mf, mfsi, parafac, tucker  # noqa: F401
