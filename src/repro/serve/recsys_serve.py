"""RecSys serving paths: p99 online batches, offline bulk, retrieval top-k.

``retrieval_topk`` covers the retrieval_cand cell: 10⁶ candidates scored in
chunks (batched-dot for separable scorers, chunked forward for rankers) and
reduced with a running top-k — never materializing all scores when chunked.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp


def bulk_score(forward: Callable, batch, chunk: int = 65536):
    """Offline scoring of a huge batch in fixed-size chunks (serve_bulk)."""
    n = jax.tree_util.tree_leaves(batch)[0].shape[0]
    outs = []
    for lo in range(0, n, chunk):
        piece = jax.tree_util.tree_map(lambda x: x[lo : lo + chunk], batch)
        outs.append(forward(piece))
    return jnp.concatenate(outs, axis=0)


def retrieval_topk(
    score_fn: Callable[[jax.Array], jax.Array],  # cand_ids → scores
    n_candidates: int,
    k: int = 100,
    chunk: int = 262144,
) -> Tuple[jax.Array, jax.Array]:
    """Top-k over ``n_candidates`` scored in chunks with a running reduce."""
    best_scores = jnp.full((k,), -jnp.inf)
    best_ids = jnp.zeros((k,), jnp.int32)
    for lo in range(0, n_candidates, chunk):
        ids = jnp.arange(lo, min(lo + chunk, n_candidates), dtype=jnp.int32)
        scores = score_fn(ids)
        merged_s = jnp.concatenate([best_scores, scores])
        merged_i = jnp.concatenate([best_ids, ids])
        best_scores, idx = jax.lax.top_k(merged_s, k)
        best_ids = jnp.take(merged_i, idx)
    return best_scores, best_ids


def mf_retrieval_score_fn(user_vec: jax.Array, item_table: jax.Array):
    """The paper-native separable retrieval: one (k)·(k,N) matvec."""

    def score(ids):
        return jnp.take(item_table, ids, axis=0) @ user_vec

    return score
