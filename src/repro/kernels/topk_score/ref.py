"""Pure-jnp oracles for the fused score+top-K kernel.

Two reference paths with the kernel's exact semantics (tie-stable
ascending-id order, (−inf, −1) on inadmissible slots):

- :func:`topk_score_ref` — deliberately "memory-naive": it materializes
  the full ``(B, n_items)`` score matrix the kernel exists to avoid, so it
  doubles as the dense baseline in ``benchmarks/serve_bench``. For the
  same reason ``exclude_ids`` (the kernel's web-scale per-row id-list
  form) is expanded to the dense (B, n_items) mask here.
- :func:`retrieval_topk` — the chunked running-reduce oracle over an
  arbitrary ``score_fn`` (moved here from ``serve/recsys_serve.py``; the
  serving tier re-exports it): never materializes all scores, so it also
  serves as the huge-catalogue baseline.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp


def exclude_ids_to_mask(exclude_ids, n_items: int):
    """Dense (B, n_items) bool mask from −1-padded per-row global id lists
    (oracle/test helper — the kernel never builds this)."""
    ids = jnp.asarray(exclude_ids, jnp.int32)
    onehot = (ids[:, :, None] == jnp.arange(n_items, dtype=jnp.int32)) & (
        ids[:, :, None] >= 0
    )
    return onehot.any(axis=1)


def topk_score_ref(phi, psi, k, exclude_mask=None, *, exclude_ids=None):
    """Dense reference with the kernel's exact semantics: tie-stable
    ascending-id order (``lax.top_k`` positional stability over the
    id-ordered row) and (−inf, −1) on slots with no admissible candidate."""
    n_items = psi.shape[0]
    scores = phi.astype(jnp.float32) @ psi.astype(jnp.float32).T
    if exclude_ids is not None:
        assert exclude_mask is None, "pass exclude_mask OR exclude_ids"
        exclude_mask = exclude_ids_to_mask(exclude_ids, n_items)
    if exclude_mask is not None:
        scores = jnp.where(exclude_mask != 0, -jnp.inf, scores)
    if k > n_items:  # dense top_k cannot rank more slots than exist
        pad = k - n_items
        scores = jnp.pad(scores, ((0, 0), (0, pad)), constant_values=-jnp.inf)
    top_s, top_i = jax.lax.top_k(scores, k)
    top_i = jnp.where(jnp.isneginf(top_s), -1, top_i).astype(jnp.int32)
    return top_s, top_i


def retrieval_topk(
    score_fn: Callable[[jax.Array], jax.Array],  # cand_ids → scores
    n_candidates: int,
    k: int = 100,
    chunk: int = 262144,
) -> Tuple[jax.Array, jax.Array]:
    """Top-k over ``n_candidates`` scored in chunks with a running reduce.

    ``score_fn(ids)`` may return ``(chunk,)`` (single query) or
    ``(B, chunk)`` (batched); the reduce carries matching ``(..., k)``
    state. Slots with no real candidate (``n_candidates < k``) stay at
    id −1 / score −inf — no placeholder item id ever leaks into the
    result. Ties resolve toward the smaller candidate id (``lax.top_k``
    positional stability + ascending chunk order), the same policy as the
    fused kernel and :func:`topk_score_ref`.
    """
    best_scores = best_ids = None
    for lo in range(0, n_candidates, chunk):
        ids = jnp.arange(lo, min(lo + chunk, n_candidates), dtype=jnp.int32)
        scores = score_fn(ids)
        if best_scores is None:  # first chunk fixes the (optional) batch dim
            lead = scores.shape[:-1]
            best_scores = jnp.full(lead + (k,), -jnp.inf, scores.dtype)
            best_ids = jnp.full(lead + (k,), -1, jnp.int32)
        merged_s = jnp.concatenate([best_scores, scores], axis=-1)
        merged_i = jnp.concatenate(
            [best_ids, jnp.broadcast_to(ids, scores.shape).astype(jnp.int32)],
            axis=-1,
        )
        best_scores, idx = jax.lax.top_k(merged_s, k)
        best_ids = jnp.take_along_axis(merged_i, idx, axis=-1)
    if best_scores is None:  # n_candidates == 0
        best_scores = jnp.full((k,), -jnp.inf)
        best_ids = jnp.full((k,), -1, jnp.int32)
    return best_scores, best_ids
