"""Functional optimizer core."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

Params = Any
State = Any
Updates = Any


@dataclasses.dataclass(frozen=True)
class OptimizerDef:
    init: Callable[[Params], State]
    update: Callable[[Updates, State, Params], Tuple[Updates, State]]


def apply_updates(params: Params, updates: Updates) -> Params:
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)) if u is not None else p,
        params, updates,
    )


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))
