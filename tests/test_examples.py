"""Examples must stay runnable (subset; full set exercised in CI shell)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(__file__))


def _run(script, timeout=500):
    env = dict(os.environ, PYTHONPATH=f"src:{os.environ.get('PYTHONPATH', '')}")
    return subprocess.run(
        [sys.executable, os.path.join("examples", script)],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=timeout,
    )


@pytest.mark.slow
def test_quickstart_runs_and_beats_popularity():
    p = _run("quickstart.py")
    assert p.returncode == 0, p.stdout[-1500:] + p.stderr[-1500:]
    assert "Recall@10" in p.stdout


@pytest.mark.slow
def test_serve_retrieval_example():
    p = _run("serve_retrieval.py")
    assert p.returncode == 0, p.stdout[-1500:] + p.stderr[-1500:]
    assert "cluster top-k == engine top-k == dense top-k" in p.stdout
    assert "batcher:" in p.stdout
    assert "streaming sharded eval" in p.stdout
