"""Architecture config registry.

``get_config(arch_id)`` returns the exact published configuration;
``get_smoke_config(arch_id)`` returns a reduced same-family config for CPU
smoke tests. ``ARCH_IDS`` lists the 10 assigned architectures plus the
paper's own iCD configs.
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    # LM family
    "gemma2-2b",
    "qwen1.5-4b",
    "deepseek-67b",
    "olmoe-1b-7b",
    "deepseek-moe-16b",
    # GNN
    "graphsage-reddit",
    # RecSys
    "dlrm-rm2",
    "din",
    "dcn-v2",
    "bst",
    # the paper's own models
    "icd-mf",
    "icd-fm",
]

_MODULES = {
    "gemma2-2b": "repro.configs.gemma2_2b",
    "qwen1.5-4b": "repro.configs.qwen15_4b",
    "deepseek-67b": "repro.configs.deepseek_67b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "graphsage-reddit": "repro.configs.graphsage_reddit",
    "dlrm-rm2": "repro.configs.dlrm_rm2",
    "din": "repro.configs.din",
    "dcn-v2": "repro.configs.dcn_v2",
    "bst": "repro.configs.bst",
    "icd-mf": "repro.configs.icd_mf",
    "icd-fm": "repro.configs.icd_fm",
}


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id])


def get_config(arch_id: str):
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str):
    return _module(arch_id).SMOKE_CONFIG


def get_shapes(arch_id: str):
    """dict shape_name -> ShapeSpec for this arch."""
    return _module(arch_id).SHAPES
