"""Synthetic implicit-feedback generator mirroring the paper's §6 dataset.

The paper evaluates on a private YouTube subset (200k users, 68k videos,
side attributes: age / country / gender / device, watch sequences). We
generate a statistically matched stand-in:

  * latent taste vectors per user drawn from ATTRIBUTE-dependent cluster
    means (so attribute-based FM can genuinely generalize to cold users —
    the mechanism behind Figure 7);
  * item popularity ~ Zipf (implicit-feedback datasets are power-law);
  * watch sequences with Markov drift (so the previously-watched video `P`
    and history `H` features carry signal — §6.2.2/6.2.3);
  * timestamps for the global-cutoff Instant protocol.

Everything is seeded numpy on the host (data pipeline, not traced).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np


@dataclasses.dataclass
class SyntheticImplicitDataset:
    n_users: int
    n_items: int
    # per-user attributes
    age: np.ndarray        # (U,) bucket ids
    country: np.ndarray
    gender: np.ndarray
    device: np.ndarray
    n_age: int
    n_country: int
    n_gender: int
    n_device: int
    # interactions, time-ordered per user
    events: np.ndarray     # (nnz, 3): user, item, t (global integer time)

    def user_histories(self) -> List[np.ndarray]:
        hist = [[] for _ in range(self.n_users)]
        for u, i, _ in self.events:
            hist[u].append(i)
        return [np.asarray(h, np.int64) for h in hist]


def make_implicit_dataset(
    n_users: int = 2000,
    n_items: int = 800,
    k_latent: int = 8,
    events_per_user: Tuple[int, int] = (5, 30),
    n_age: int = 8,
    n_country: int = 16,
    n_gender: int = 3,
    n_device: int = 8,
    attr_strength: float = 0.7,
    markov_strength: float = 0.5,
    pop_strength: float = 1.5,
    taste_strength: float = 1.0,
    seed: int = 0,
) -> SyntheticImplicitDataset:
    rng = np.random.default_rng(seed)

    age = rng.integers(0, n_age, n_users)
    country = rng.integers(0, n_country, n_users)
    gender = rng.integers(0, n_gender, n_users)
    device = rng.integers(0, n_device, n_users)

    # attribute cluster means in latent space
    m_age = rng.normal(size=(n_age, k_latent))
    m_country = rng.normal(size=(n_country, k_latent))
    m_gender = rng.normal(size=(n_gender, k_latent))
    user_lat = (
        attr_strength * (m_age[age] + m_country[country] + m_gender[gender]) / 3
        + (1 - attr_strength) * rng.normal(size=(n_users, k_latent))
    )
    item_lat = rng.normal(size=(n_items, k_latent))
    pop = 1.0 / np.arange(1, n_items + 1) ** 1.1  # Zipf popularity
    pop = pop[rng.permutation(n_items)]

    # Markov drift: similar items tend to follow each other
    sim = item_lat @ item_lat.T
    events = []
    t = 0
    for u in range(n_users):
        n_ev = rng.integers(*events_per_user)
        base = taste_strength * (user_lat[u] @ item_lat.T) + np.log(pop) * pop_strength
        prev = None
        for _ in range(n_ev):
            logit = base.copy()
            if prev is not None and markov_strength > 0:
                logit = logit + markov_strength * sim[prev]
            logit = logit - logit.max()
            p = np.exp(logit)
            p /= p.sum()
            item = rng.choice(n_items, p=p)
            events.append((u, item, t))
            prev = item
            t += 1
    ev = np.asarray(events, np.int64)
    # global shuffle of time to interleave users, then re-sort by time
    ev[:, 2] = rng.permutation(len(ev))
    ev = ev[np.argsort(ev[:, 2])]
    return SyntheticImplicitDataset(
        n_users=n_users, n_items=n_items,
        age=age, country=country, gender=gender, device=device,
        n_age=n_age, n_country=n_country, n_gender=n_gender, n_device=n_device,
        events=ev,
    )
