"""Fused score+top-K kernel: oracle parity, edge cases, and the engine
contract across the whole k-separable model zoo."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _zoo import ZOO, model_phi_psi, _rand

from repro.kernels.topk_score import topk_merge_shards, topk_score, topk_score_ref
from repro.serve.engine import (
    RetrievalEngine,
    exclude_ids_from_lists,
    exclude_mask_from_lists,
)


def test_matches_ref_and_dense_topk_nondivisible_blocks():
    phi, psi = _rand((9, 24), 0), _rand((301, 24), 1)
    s, i = topk_score(phi, psi, 17, block_items=128)  # 301 % 128 != 0
    rs, ri = topk_score_ref(phi, psi, 17)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(s), np.asarray(rs), rtol=1e-6, atol=1e-6)
    ds, di = jax.lax.top_k(phi @ psi.T, 17)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(di))
    np.testing.assert_allclose(np.asarray(s), np.asarray(ds), rtol=1e-6, atol=1e-6)


def test_batch_larger_than_block_b():
    phi, psi = _rand((50, 8), 2), _rand((200, 8), 3)
    s, i = topk_score(phi, psi, 10, block_b=16, block_items=64)
    ds, di = jax.lax.top_k(phi @ psi.T, 10)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(di))
    np.testing.assert_allclose(np.asarray(s), np.asarray(ds), rtol=1e-6, atol=1e-6)


def test_tied_scores_rank_ascending_id():
    # duplicated ψ rows across different blocks ⇒ exact score ties
    base = _rand((40, 6), 4)
    psi = jnp.concatenate([base, base, base], axis=0)  # ids i, i+40, i+80 tie
    phi = _rand((5, 6), 5)
    s, i = topk_score(phi, psi, 30, block_items=64)
    rs, ri = topk_score_ref(phi, psi, 30)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    # dense lax.top_k over the id-ordered row is the documented tie policy
    ds, di = jax.lax.top_k(phi @ psi.T, 30)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(di))


def test_exclude_mask_and_fully_masked_row():
    rng = np.random.default_rng(6)
    phi, psi = _rand((7, 12), 6), _rand((90, 12), 7)
    excl = jnp.asarray(rng.random((7, 90)) < 0.4)
    excl = excl.at[2, :].set(True)  # row 2: nothing admissible
    s, i = topk_score(phi, psi, 12, excl, block_items=32)
    rs, ri = topk_score_ref(phi, psi, 12, excl)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    # excluded ids never leak; fully-masked row is all (−inf, −1)
    assert bool((np.asarray(i)[2] == -1).all())
    assert bool(np.isneginf(np.asarray(s)[2]).all())
    got = np.asarray(i)
    mask = np.asarray(excl)
    for r in range(7):
        real = got[r][got[r] >= 0]
        assert not mask[r, real].any()


def test_exclude_ids_matches_mask_path():
    """The web-scale id-list exclusion form (in-kernel block-aligned mask
    slices, no (B, n_items) array) must agree with the dense-mask form."""
    rng = np.random.default_rng(16)
    phi, psi = _rand((7, 12), 6), _rand((90, 12), 7)
    lists = [rng.choice(90, size=int(rng.integers(0, 9)), replace=False)
             for _ in range(7)]
    eids = exclude_ids_from_lists(lists)
    mask = exclude_mask_from_lists(lists, 90)
    s_ids, i_ids = topk_score(phi, psi, 12, exclude_ids=eids, block_items=32)
    s_m, i_m = topk_score(phi, psi, 12, mask, block_items=32)
    np.testing.assert_array_equal(np.asarray(i_ids), np.asarray(i_m))
    np.testing.assert_array_equal(np.asarray(s_ids), np.asarray(s_m))
    rs, ri = topk_score_ref(phi, psi, 12, exclude_ids=eids)
    np.testing.assert_array_equal(np.asarray(i_ids), np.asarray(ri))


def test_id_offset_and_n_valid_shard_semantics():
    """A row-range shard (id_offset, n_valid) emits GLOBAL ids and keeps
    pad rows inadmissible — the kernel contract serve/cluster builds on."""
    phi, psi = _rand((5, 8), 12), _rand((64, 8), 13)
    # shard owning global rows [40, 64), padded to 32 rows
    shard = jnp.pad(psi[40:], ((0, 8), (0, 0)))
    s, i = topk_score(phi, shard, 30, id_offset=40, n_valid=24, block_items=32)
    rs, ri = topk_score_ref(phi, psi[40:], 30)
    ri_global = np.where(np.asarray(ri) >= 0, np.asarray(ri) + 40, -1)
    np.testing.assert_array_equal(np.asarray(i), ri_global)
    np.testing.assert_allclose(np.asarray(s), np.asarray(rs), rtol=1e-6)
    # pad rows (global id >= 64) never surface
    assert (np.asarray(i) < 64).all()
    # traced offsets hit the same jit cache (one program serves all shards)
    s2, i2 = topk_score(phi, shard, 30, id_offset=jnp.int32(40),
                        n_valid=jnp.int32(24), block_items=32)
    np.testing.assert_array_equal(np.asarray(i2), ri_global)


def test_k_larger_than_n_items():
    phi, psi = _rand((3, 5), 8), _rand((11, 5), 9)
    s, i = topk_score(phi, psi, 20, block_items=128)
    rs, ri = topk_score_ref(phi, psi, 20)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    assert bool((np.asarray(i)[:, 11:] == -1).all())
    assert bool(np.isneginf(np.asarray(s)[:, 11:]).all())
    # the 11 real slots are the full catalogue, exactly ranked
    ds, di = jax.lax.top_k(phi @ psi.T, 11)
    np.testing.assert_array_equal(np.asarray(i)[:, :11], np.asarray(di))


def test_merge_shards_is_tie_stable_and_pads_inadmissible():
    """topk_merge_shards alone: score-ordered per-shard lists with cross-
    shard ties must come out in ascending GLOBAL id; −inf slots are −1."""
    # two shards, one row; shard 1 has a tie (score 1.0) with shard 0
    s0 = jnp.asarray([[[1.0, 0.5, -jnp.inf]]])
    i0 = jnp.asarray([[[7, 2, -1]]], jnp.int32)
    s1 = jnp.asarray([[[1.0, 0.25, -jnp.inf]]])
    i1 = jnp.asarray([[[3, 9, -1]]], jnp.int32)
    ms, mi = topk_merge_shards(jnp.concatenate([s0, s1]),
                               jnp.concatenate([i0, i1]), 5)
    # tie at 1.0: id 3 (shard 1) precedes id 7 (shard 0)
    np.testing.assert_array_equal(np.asarray(mi)[0], [3, 7, 2, 9, -1])
    np.testing.assert_array_equal(
        np.asarray(ms)[0], [1.0, 1.0, 0.5, 0.25, -np.inf])
    # k larger than the candidate pool pads with (−inf, −1)
    ms2, mi2 = topk_merge_shards(jnp.concatenate([s0, s1]),
                                 jnp.concatenate([i0, i1]), 8)
    assert bool((np.asarray(mi2)[0, 4:] == -1).all())
    assert bool(np.isneginf(np.asarray(ms2)[0, 4:]).all())


@pytest.mark.parametrize("name", ZOO)
def test_streaming_matches_dense_topk_all_models(name):
    """The acceptance check: fused kernel == dense lax.top_k for the zoo,
    with and without an exclude mask, through the RetrievalEngine."""
    rng = np.random.default_rng(42)
    phi, psi = model_phi_psi(name, rng)
    # model predict ⇔ ⟨φ, ψ⟩ consistency is covered by each model's own
    # tests; here we pin streaming top-k to the dense path over Φ·Ψᵀ
    engine = RetrievalEngine(psi, lambda p=phi: p, k=12, block_items=32)
    s, i = engine.topk()
    ds, di = jax.lax.top_k(engine.scores(phi), 12)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(di))
    np.testing.assert_allclose(np.asarray(s), np.asarray(ds), rtol=1e-5, atol=1e-6)

    excl_lists = [rng.choice(psi.shape[0], size=5, replace=False)
                  for _ in range(phi.shape[0])]
    mask = exclude_mask_from_lists(excl_lists, psi.shape[0])
    s2, i2 = engine.topk(exclude_mask=mask)
    rs2, ri2 = topk_score_ref(phi, psi, 12, mask)
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(ri2))
    got = np.asarray(i2)
    m = np.asarray(mask)
    for r in range(got.shape[0]):
        real = got[r][got[r] >= 0]
        assert not m[r, real].any()
    # the id-list exclusion form agrees with the mask form bit-for-bit
    s3, i3 = engine.topk(exclude_ids=exclude_ids_from_lists(excl_lists))
    np.testing.assert_array_equal(np.asarray(i3), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(s3), np.asarray(s2))
