"""Serving/eval bench for the fused score+top-K retrieval subsystem.

Tracks ``BENCH_topk_score.json`` at the repo root:

  * analytic HBM-traffic model — fused ``kernels/topk_score`` (ψ read once,
    scores never leave VMEM) vs the dense path (ψ read + (B, n_items)
    score matrix written AND re-read by ``lax.top_k``);
  * measured CPU comparison of the two paths (interpret-mode kernels, so
    wall-clock is emulation-bound and informational only);
  * HARD parity asserts — streaming kernel vs dense ``lax.top_k`` ids for
    every k-separable model, with and without exclude masks, plus the
    streaming ranking-eval harness vs dense metrics. A broken kernel or
    export contract fails the whole bench (the CI serve-smoke gate).

Run: ``python -m benchmarks.run --quick`` (serve section) or
``python -m benchmarks.serve_bench --smoke``.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import HBM_BW


def topk_traffic_bytes(b: int, n_items: int, d: int, k: int) -> Dict[str, float]:
    """Analytic HBM bytes for one query batch (fp32). Dense: ψ table + φ +
    score-matrix write + score-matrix re-read (top_k). Fused: ψ table + φ
    + the final (B, K_pad) score/id blocks (running state rides VMEM)."""
    k_pad = -(-k // 128) * 128
    psi = 4.0 * n_items * d
    phi = 4.0 * b * d
    dense = psi + phi + 2 * 4.0 * b * n_items
    fused = psi + phi + 2 * 4.0 * b * k_pad
    return {
        "dense_bytes": dense,
        "fused_bytes": fused,
        "bytes_ratio": dense / fused,
        "dense_memory_s": dense / HBM_BW,
        "fused_memory_s": fused / HBM_BW,
    }


def _assert_topk_parity(name, phi, psi, k, exclude_mask=None, block_items=32):
    """Streaming kernel vs dense lax.top_k/oracle: ids exact, scores close."""
    from repro.kernels.topk_score import topk_score, topk_score_ref

    s, i = topk_score(phi, psi, k, exclude_mask, block_items=block_items)
    rs, ri = topk_score_ref(phi, psi, k, exclude_mask)
    if not (np.asarray(i) == np.asarray(ri)).all():
        raise AssertionError(f"serve bench parity FAILED for {name}: top-k ids "
                             "diverge from the dense oracle")
    finite = np.isfinite(np.asarray(rs))
    if not np.allclose(np.asarray(s)[finite], np.asarray(rs)[finite],
                       rtol=1e-5, atol=1e-6):
        raise AssertionError(f"serve bench parity FAILED for {name}: top-k "
                             "scores diverge from the dense oracle")
    if exclude_mask is None:
        ds, di = jax.lax.top_k(phi @ psi.T, min(k, psi.shape[0]))
        if not (np.asarray(i)[:, : di.shape[1]] == np.asarray(di)).all():
            raise AssertionError(f"serve bench parity FAILED for {name}: ids "
                                 "diverge from dense lax.top_k")


def _zoo_parity(quick: bool) -> Dict[str, dict]:
    """Every model through its export_psi/build_phi contract, masked and
    unmasked, against the dense path."""
    from repro.core.design import make_design
    from repro.core.models import fm, mf, mfsi, parafac, tucker
    from repro.serve.engine import exclude_mask_from_lists

    rng = np.random.default_rng(0)
    n_ctx, n_items, b, k, topk = (24, 40, 8, 6, 10) if quick else (128, 512, 32, 16, 100)
    out = {}

    def check(name, phi, psi):
        excl = exclude_mask_from_lists(
            [rng.choice(psi.shape[0], size=min(5, psi.shape[0] // 2),
                        replace=False) for _ in range(phi.shape[0])],
            psi.shape[0],
        )
        kk = min(topk, psi.shape[0])
        _assert_topk_parity(name, phi, psi, kk)
        _assert_topk_parity(f"{name}+mask", phi, psi, kk, excl)
        out[name] = {"parity_ok": True, "d": int(phi.shape[1]),
                     "n_items": int(psi.shape[0]), "k": kk}

    p_mf = mf.init(jax.random.PRNGKey(0), n_ctx, n_items, 8)
    check("mf", mf.build_phi(p_mf, jnp.arange(b)), mf.export_psi(p_mf))

    x = make_design(
        [dict(name="id", ids=np.arange(n_ctx) % 11, vocab=11),
         dict(name="grp", ids=rng.integers(0, 5, n_ctx), vocab=5)], n_ctx)
    z = make_design(
        [dict(name="item_id", ids=np.arange(n_items), vocab=n_items),
         dict(name="genre", ids=rng.integers(0, 7, n_items), vocab=7)], n_items)

    p_si = mfsi.init(jax.random.PRNGKey(1), x.p, z.p, k)
    check("mfsi", mfsi.build_phi(p_si, x, jnp.arange(b)), mfsi.export_psi(p_si, z))

    hp_fm = fm.FMHyperParams(k=k)
    p_fm = fm.init(jax.random.PRNGKey(2), x.p, z.p, k)
    p_fm = p_fm._replace(
        b=jnp.asarray(0.2),
        w_lin=jnp.asarray(rng.normal(size=x.p), jnp.float32),
        h_lin=jnp.asarray(rng.normal(size=z.p), jnp.float32),
    )
    check("fm", fm.build_phi(p_fm, x, hp_fm, jnp.arange(b)),
          fm.export_psi(p_fm, z, hp_fm))

    c1 = jnp.asarray(rng.integers(0, 9, b), jnp.int32)
    c2 = jnp.asarray(rng.integers(0, 7, b), jnp.int32)
    p_pf = parafac.init(jax.random.PRNGKey(3), 9, 7, n_items, k)
    check("parafac", parafac.build_phi(p_pf, c1, c2), parafac.export_psi(p_pf))

    p_tk = tucker.init(jax.random.PRNGKey(4), 9, 7, n_items, 4, 3, k)
    check("tucker", tucker.build_phi(p_tk, c1, c2), tucker.export_psi(p_tk))
    return out


def _eval_harness_parity(quick: bool) -> dict:
    """Streaming ranking_eval (never a (n_eval, n_items) array) vs dense
    metrics over the same exclusion protocol."""
    from repro.core.metrics import ndcg_at_k, recall_at_k
    from repro.core.models import mf
    from repro.eval.ranking import ranking_eval
    from repro.serve.engine import exclude_mask_from_lists

    rng = np.random.default_rng(1)
    n_eval, n_items, k, topk = (32, 80, 8, 10) if quick else (256, 2048, 32, 100)
    params = mf.init(jax.random.PRNGKey(5), n_eval, n_items, k)
    truth = rng.integers(0, n_items, size=n_eval)
    excl = [rng.choice(n_items, size=4, replace=False) for _ in range(n_eval)]
    phi = mf.build_phi(params, jnp.arange(n_eval))
    psi = mf.export_psi(params)
    res = ranking_eval(phi, psi, truth, k=topk, batch_rows=max(8, n_eval // 3),
                       exclude=excl, block_items=32)
    mask = exclude_mask_from_lists(excl, n_items)
    dense = phi @ psi.T
    r = float(recall_at_k(dense, jnp.asarray(truth), topk, mask))
    n = float(ndcg_at_k(dense, jnp.asarray(truth), topk, mask))
    ok = (abs(res[f"recall@{topk}"] - r) < 1e-5
          and abs(res[f"ndcg@{topk}"] - n) < 1e-5)
    if not ok:
        raise AssertionError(
            f"serve bench parity FAILED for ranking_eval: streaming "
            f"({res}) vs dense (recall={r}, ndcg={n})"
        )
    return {"parity_ok": True, **res}


def _measure_cpu(quick: bool, n_rounds: int = 3) -> dict:
    """Wall-clock of dense matmul+top_k vs the streaming kernel (interpret
    mode on CPU ⇒ emulation-bound; informational, never gated)."""
    from repro.kernels.topk_score import topk_score

    rng = np.random.default_rng(2)
    b, n_items, d, k = (16, 4096, 16, 10) if quick else (64, 65536, 64, 100)
    phi = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    psi = jnp.asarray(rng.normal(size=(n_items, d)), jnp.float32)

    dense = jax.jit(lambda p, q: jax.lax.top_k(p @ q.T, k))
    jax.block_until_ready(dense(phi, psi))
    t0 = time.perf_counter()
    for _ in range(n_rounds):
        jax.block_until_ready(dense(phi, psi))
    t_dense = (time.perf_counter() - t0) / n_rounds

    jax.block_until_ready(topk_score(phi, psi, k))
    t0 = time.perf_counter()
    for _ in range(n_rounds):
        jax.block_until_ready(topk_score(phi, psi, k))
    t_fused = (time.perf_counter() - t0) / n_rounds
    return {
        "shape": dict(b=b, n_items=n_items, d=d, k=k),
        "dense_s": t_dense,
        "fused_s": t_fused,
        "note": "interpret-mode emulation; HBM advantage is the analytic row",
    }


def serve_topk_bench(quick: bool = True, out_path: Optional[str] = None) -> dict:
    """Fused retrieval vs dense baseline; writes BENCH_topk_score.json.

    The tracked repo-root JSON is always the quick-mode (CI smoke) shape;
    ``--full`` runs land in BENCH_topk_score_full.json."""
    if out_path is None:
        repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        out_path = os.path.join(
            repo_root,
            "BENCH_topk_score.json" if quick else "BENCH_topk_score_full.json",
        )
    from repro.kernels import use_interpret

    analytic = {
        f"B={b}": topk_traffic_bytes(b=b, n_items=10_000_000, d=128, k=100)
        for b in (8, 64, 256, 1024)
    }
    models = _zoo_parity(quick)
    eval_parity = _eval_harness_parity(quick)
    measured = _measure_cpu(quick)
    results = {
        "kernel": "kernels/topk_score (fused score+top-K) vs dense "
                  "(B,n_items) matmul + lax.top_k",
        "mode": "quick" if quick else "full",
        "backend": "interpret" if use_interpret() else "compiled",
        "analytic_web_scale": {
            "shape": "n_items=10M catalogue, D=128, K=100, fp32",
            **analytic,
        },
        "measured_cpu": measured,
        "models": models,
        "eval_harness": eval_parity,
        "acceptance": {
            "bytes_ratio_at_B256": analytic["B=256"]["bytes_ratio"],
            "model_parity": {m: r["parity_ok"] for m, r in models.items()},
            "eval_parity": eval_parity["parity_ok"],
            "target": ">= 2x fewer HBM bytes per retrieval batch at B >= 256 "
                      "(analytic; scores never leave VMEM); streaming top-K "
                      "== dense lax.top_k ids for every k-separable model "
                      "incl. exclude masks; streaming ranking-eval == dense "
                      "metrics without a (n_eval, n_items) array",
            "met": analytic["B=256"]["bytes_ratio"] >= 2.0
                   and all(r["parity_ok"] for r in models.values())
                   and eval_parity["parity_ok"],
        },
    }
    if out_path:
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--smoke", action="store_true",
                      help="quick shapes + hard parity gate (CI; the default)")
    mode.add_argument("--full", action="store_true")
    args = ap.parse_args()
    res = serve_topk_bench(quick=not args.full)
    print(json.dumps(res["acceptance"], indent=1))
    assert res["acceptance"]["met"], "serve bench acceptance gate not met"
