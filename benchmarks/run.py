"""Benchmark harness — one entry per paper table/figure + the roofline.

  python -m benchmarks.run              # everything (quick mode)
  python -m benchmarks.run --full       # paper-scale synthetic runs
  python -m benchmarks.run --only fig8

Prints ``name,value,derived`` CSV lines and writes JSON to
results/experiments/.
"""
from __future__ import annotations

import argparse
import json
import os
import time


def _emit(name: str, seconds: float, derived: str):
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def run_figure(name, fn, out_dir, quick, registry=None):
    t0 = time.perf_counter()
    res = fn(quick=quick)
    dt = time.perf_counter() - t0
    if registry is not None:
        registry.histogram(
            "bench_section_seconds", "wall time per benchmark section",
            ("section",),
        ).labels(section=name).observe(dt)
    if isinstance(res, dict):
        res = {**res, "bench_seconds": dt}
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{name}.json"), "w") as f:
        json.dump(res, f, indent=1, default=str)
    return res, dt


def fig7(quick):
    from benchmarks.experiments import paper_dataset, relative_to_popularity, run_cold_start

    res = run_cold_start(paper_dataset(quick), quick=quick)
    return {"absolute": res, "relative_to_popularity": relative_to_popularity(res)}


def fig6a(quick):
    from benchmarks.experiments import paper_dataset, relative_to_popularity, run_offline

    res = run_offline(paper_dataset(quick), quick=quick)
    return {"absolute": res, "relative_to_popularity": relative_to_popularity(res)}


def fig6b(quick):
    from benchmarks.experiments import paper_dataset, relative_to_popularity, run_instant

    res = run_instant(paper_dataset(quick), quick=quick)
    return {"absolute": res, "relative_to_popularity": relative_to_popularity(res)}


def fig8(quick):
    from benchmarks import fig8_cost

    return fig8_cost.run(quick=quick)


def kernels(quick):
    """Micro-bench the Pallas kernels (interpret mode ⇒ timing is not
    meaningful on CPU; we report the oracle-XLA timings + shapes covered)."""
    import jax

    from repro.kernels.gram.ref import gram_ref

    out = {}
    for rows, k in ((4096, 128), (65536, 128)):
        x = jax.random.normal(jax.random.PRNGKey(0), (rows, k))
        f = jax.jit(gram_ref)
        f(x).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(5):
            f(x).block_until_ready()
        out[f"gram_xla_{rows}x{k}"] = (time.perf_counter() - t0) / 5
    return out


def cd_sweep(quick):
    """Fused block-sweep vs per-column iCD kernel; also refreshes the
    tracked BENCH_cd_sweep.json at the repo root."""
    from benchmarks.roofline_bench import cd_sweep_bench

    return cd_sweep_bench(quick=quick)


def serve(quick):
    """Fused score+top-K retrieval vs the dense path; hard kernel-vs-oracle
    parity for the whole model zoo + the streaming eval harness; refreshes
    the tracked BENCH_topk_score.json at the repo root."""
    from benchmarks.serve_bench import serve_topk_bench

    return serve_topk_bench(quick=quick)


def roofline(quick):
    from benchmarks.roofline_bench import load_table, markdown_table

    rows = load_table()
    ok = [r for r in rows if r["status"] == "ok"]
    return {
        "n_cells": len(rows),
        "n_ok": len(ok),
        "table_single_pod": markdown_table(rows, "16x16"),
        "table_multi_pod": markdown_table(rows, "2x16x16"),
    }


def grid(quick):
    """Model × confidence × context experiments grid: trains every cell on
    the MovieLens-class log, streams Recall/NDCG through eval/ranking, and
    hard-gates weighted parity + the frequency/context quality wins;
    results merge into BENCH_cd_sweep.json under ``quality``."""
    from benchmarks.experiments import run_grid

    return run_grid(quick=quick)


def continual(quick):
    """Continual-learning gates: fold-in parity (all zoo models + the mesh
    round-trip), full-schedule bit equivalence, delta-publish semantics,
    and the subspace-scheduling updates-to-quality curve — each section
    hard-asserts; results merge into BENCH_cd_sweep.json."""
    from benchmarks.continual_bench import continual_bench

    return continual_bench(quick=quick)


FIGURES = {
    "fig7_coldstart": fig7,
    "fig6a_offline": fig6a,
    "fig6b_instant": fig6b,
    "fig8_cost": fig8,
    "kernels": kernels,
    "cd_sweep": cd_sweep,
    "serve": serve,
    "continual": continual,
    "grid": grid,
    "roofline": roofline,
}

# dataset-free, seconds-fast subset — the smoke gate for CI / pre-commit
QUICK_SET = ("kernels", "cd_sweep", "serve", "continual", "grid", "roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help=f"smoke subset only: {', '.join(QUICK_SET)}")
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="results/experiments")
    args = ap.parse_args()
    quick = not args.full

    # surface the Pallas backend so CI logs show what produced the numbers
    from repro.kernels import use_interpret

    interp = use_interpret()
    import jax

    print(f"# pallas_backend={'interpret' if interp else 'compiled'} "
          f"(use_interpret()={interp}) jax_default_backend={jax.default_backend()}")
    print("# name,seconds_us,derived")

    from repro.obs import MetricsRegistry

    registry = MetricsRegistry(clock=time.perf_counter)
    ran = []
    for name, fn in FIGURES.items():
        if args.quick and name not in QUICK_SET:
            continue
        if args.only and args.only not in name:
            continue
        res, dt = run_figure(name, fn, args.out, quick, registry=registry)
        ran.append(name)
        _emit(name, dt, json.dumps(res, default=str)[:160].replace(",", ";"))

    if args.quick and ran:
        # per-section wall time read back from the obs registry (each
        # section observed exactly once, so the histogram mean IS the
        # section's wall time)
        print("# section wall-time summary (bench_section_seconds):")
        total = 0.0
        for name in ran:
            s = registry.get("bench_section_seconds", section=name)
            total += s
            print(f"#   {name:<16s} {s:8.2f}s")
        print(f"#   {'total':<16s} {total:8.2f}s")


if __name__ == "__main__":
    main()
