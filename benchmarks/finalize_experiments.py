"""Inject generated tables into EXPERIMENTS.md (idempotent).

Replaces the marker lines:
  <!-- ROOFLINE_TABLE_SINGLE -->   with the single-pod roofline table
  <!-- HILLCLIMB_ZERO1 -->         with the measured §Perf #2 iterations
  <!-- HILLCLIMB_MOE -->           with the measured §Perf #3 iterations
"""
from __future__ import annotations

import json

from benchmarks.roofline_bench import load_table, markdown_table


def _hillclimb_block(path: str, baseline_note: str) -> str:
    try:
        r = json.load(open(path))
    except FileNotFoundError:
        return f"*(pending: {path})*"
    lines = [
        "| variant | compute s | memory s | collective s | vs baseline coll |",
        "|---|---|---|---|---|",
    ]
    base = r.get("baseline_roofline", {})
    base_coll = base.get("collective_s")
    if base_coll:
        lines.append(
            f"| baseline (dry-run table) | {base.get('compute_s', 0):.2e} "
            f"| {base.get('memory_s', 0):.2e} | {base_coll:.2e} | 1.0× |"
        )
    for it in r["iterations"]:
        rel = (f"{base_coll / it['collective_s']:.1f}×"
               if base_coll and it["collective_s"] else "—")
        lines.append(
            f"| {it['variant']} | {it['compute_s']:.2e} | {it['memory_s']:.2e} "
            f"| {it['collective_s']:.2e} | {rel} |"
        )
        split = it.get("per_layer_split")
        if split:
            lines.append(
                f"| &nbsp;&nbsp;↳ per-layer coll split | token-prop "
                f"{split['per_layer_token_prop']:.2e} B | param-const "
                f"{split['per_layer_param_const']:.2e} B | | |"
            )
    return "\n".join(lines) + f"\n\n{baseline_note}"


def main():
    md = open("EXPERIMENTS.md").read()
    rows = load_table()
    md = md.replace("<!-- ROOFLINE_TABLE_SINGLE -->",
                    markdown_table(rows, "16x16"))
    md = md.replace("<!-- ROOFLINE_TABLE_MULTI -->",
                    markdown_table(rows, "2x16x16"))
    md = md.replace(
        "<!-- HILLCLIMB_ZERO1 -->",
        _hillclimb_block("results/perf/hillclimb_zero1.json",
                         "(`results/perf/hillclimb_zero1.json`)"),
    )
    md = md.replace(
        "<!-- HILLCLIMB_MOE -->",
        _hillclimb_block("results/perf/hillclimb_moe.json",
                         "(`results/perf/hillclimb_moe.json`)"),
    )
    open("EXPERIMENTS.md", "w").write(md)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
