"""Serving driver: sharded retrieval with micro-batched online requests.

  python -m repro.launch.serve --arch icd-mf --smoke --requests 64 --shards 2

Builds the model from the registry config, publishes its ψ table into a
:class:`~repro.serve.cluster.ShardedRetrievalCluster`, and replays an
open-loop single-row request trace through the
:class:`~repro.serve.batcher.MicroBatcher` (deadline/size flush), printing
throughput and queue-latency percentiles.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else float("nan")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--topk", type=int, default=100)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-delay", type=float, default=2e-3)
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not args.arch.startswith("icd"):
        raise SystemExit(
            f"unknown serving arch {args.arch!r}: the serve driver hosts the "
            "k-separable retrieval registry (icd-*)"
        )

    from repro.core.models import mf
    from repro.serve.batcher import MicroBatcher
    from repro.serve.cluster import ShardedRetrievalCluster

    params = mf.init(jax.random.PRNGKey(0), cfg.n_ctx, cfg.n_items, cfg.k)
    k = min(args.topk, cfg.n_items)
    cluster = ShardedRetrievalCluster(
        lambda ctx: mf.build_phi(params, ctx), n_shards=args.shards, k=k
    )
    version = cluster.publish(mf.export_psi(params))
    print(f"[serve] published psi v{version}: {cfg.n_items} items over "
          f"{args.shards} shard(s), top-{k}")

    batcher = MicroBatcher(
        lambda phi, eids: cluster.topk_phi(phi, exclude_ids=eids),
        max_batch=args.max_batch, max_delay=args.max_delay,
        # same clock as t0 below: completed_at − t0 must be well-defined
        clock=time.perf_counter,
        version_fn=lambda: cluster.version,
    )
    phi_all = np.asarray(mf.build_phi(params, np.arange(cfg.n_ctx)))
    rng = np.random.default_rng(0)
    users = rng.integers(0, cfg.n_ctx, size=args.requests)
    t0 = time.perf_counter()
    tickets = []
    for u in users:
        tickets.append((u, batcher.submit(phi_all[u], key=("user", int(u)))))
        batcher.step()
    batcher.flush()
    dt = time.perf_counter() - t0
    lat, top_id = [], None
    for u, t in tickets:
        done_at = batcher.completed_at(t)
        scores, ids = batcher.result(t)
        assert ids.shape == (k,)
        lat.append(done_at - t0)
        if top_id is None:
            top_id = int(ids[0])
    print(f"[serve] {args.requests} requests in {dt:.3f}s "
          f"({args.requests / dt:.1f} req/s), "
          f"{batcher.stats['flushes']} flushes "
          f"(size={batcher.stats['flush_by_size']} "
          f"deadline={batcher.stats['flush_by_deadline']} "
          f"forced={batcher.stats['flush_forced']}), "
          f"cache_hits={batcher.stats['cache_hits']}")
    print(f"[serve] completion p50={_percentile(lat, 50):.4f}s "
          f"p99={_percentile(lat, 99):.4f}s after start; "
          f"top id for user {int(users[0])}: {top_id}")


if __name__ == "__main__":
    main()
