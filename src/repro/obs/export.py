"""Exposition: registry → JSONL / Prometheus text, tracer → Chrome trace.

Formats:

  * **JSONL** (``metrics_jsonl`` / ``write_metrics`` on a ``.jsonl``
    path): one JSON object per metric child per line — ``{"name", "type",
    "labels", "value"}``; histograms add ``count``/``sum``/``buckets``
    (cumulative, keyed by upper edge) and ``p50``/``p90``/``p99``. Line
    oriented so a long-running driver can append snapshots and ``jq``
    stays trivial.
  * **Prometheus text** (``prometheus_text`` / ``write_metrics`` on a
    ``.prom`` path): the standard ``# HELP``/``# TYPE`` + sample-line
    exposition; histograms emit the ``_bucket{le=...}`` cumulative
    series, ``_sum`` and ``_count``, so the files scrape-parse with
    stock tooling.
  * **Chrome trace** (``chrome_trace`` / ``write_trace``): the tracer's
    spans as ``ph: "X"`` complete events (ts/dur in microseconds, span
    attrs under ``args``), loadable in ``chrome://tracing`` or
    https://ui.perfetto.dev. Parent/child nesting renders by time
    containment on one track; the explicit ids ride along in ``args``
    for programmatic consumers.
"""
from __future__ import annotations

import json
from typing import List

from repro.obs.metrics import Histogram, MetricsRegistry
from repro.obs.trace import Tracer


def _label_str(labels_kv) -> str:
    if not labels_kv:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels_kv)
    return "{" + inner + "}"


def metrics_jsonl(registry: MetricsRegistry) -> str:
    """One JSON object per metric child per line."""
    lines: List[str] = []
    for fam in registry.families():
        for child in fam.children():
            rec = {
                "name": fam.name,
                "type": fam.kind,
                "labels": dict(child.labels_kv),
            }
            if isinstance(child, Histogram):
                cum = 0
                buckets = {}
                for edge, n in zip(child.edges, child.counts):
                    cum += n
                    buckets[f"{edge:g}"] = cum
                buckets["+Inf"] = child.count
                rec.update(
                    count=child.count, sum=child.sum, buckets=buckets,
                    # NaN percentiles (empty histograms) must not break
                    # strict JSON readers: NaN -> null
                    **{q: (None if v != v else v)
                       for q, v in child.percentiles().items()},
                )
            else:
                rec["value"] = child.value
            lines.append(json.dumps(rec, allow_nan=False))
    return "\n".join(lines) + ("\n" if lines else "")


def prometheus_text(registry: MetricsRegistry) -> str:
    lines: List[str] = []
    for fam in registry.families():
        if fam.help:
            lines.append(f"# HELP {fam.name} {fam.help}")
        lines.append(f"# TYPE {fam.name} {fam.kind}")
        for child in fam.children():
            base = dict(child.labels_kv)
            if isinstance(child, Histogram):
                cum = 0
                for edge, n in zip(child.edges, child.counts):
                    cum += n
                    kv = tuple({**base, "le": f"{edge:g}"}.items())
                    lines.append(f"{fam.name}_bucket{_label_str(kv)} {cum}")
                kv = tuple({**base, "le": "+Inf"}.items())
                lines.append(f"{fam.name}_bucket{_label_str(kv)} {child.count}")
                lines.append(
                    f"{fam.name}_sum{_label_str(child.labels_kv)} {child.sum}")
                lines.append(
                    f"{fam.name}_count{_label_str(child.labels_kv)} {child.count}")
            else:
                lines.append(
                    f"{fam.name}{_label_str(child.labels_kv)} {child.value}")
    return "\n".join(lines) + ("\n" if lines else "")


def write_metrics(path: str, registry: MetricsRegistry) -> str:
    """Write the registry to ``path``: Prometheus text for ``.prom``,
    JSONL otherwise. Returns the path."""
    text = (prometheus_text(registry) if path.endswith(".prom")
            else metrics_jsonl(registry))
    with open(path, "w") as f:
        f.write(text)
    return path


# ------------------------------------------------------------ chrome trace
def chrome_trace(tracer: Tracer, *, process_name: str = "repro-serve") -> dict:
    """Tracer spans as a Chrome trace event object (Perfetto-openable)."""
    t_base = min((sp.t0 for sp in tracer.spans), default=0.0)
    events = [{
        "name": "process_name", "ph": "M", "pid": 1, "tid": 0,
        "args": {"name": process_name},
    }]
    for sp in tracer.spans:
        end = sp.t1 if sp.t1 is not None else sp.t0
        args = {k: _jsonable(v) for k, v in sp.attrs.items()}
        args["span_id"] = sp.span_id
        if sp.parent_id is not None:
            args["parent_id"] = sp.parent_id
        events.append({
            "name": sp.name,
            "ph": "X",
            "ts": (sp.t0 - t_base) * 1e6,          # microseconds
            "dur": max(end - sp.t0, 0.0) * 1e6,
            "pid": 1,
            "tid": 1,
            "args": args,
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


def write_trace(path: str, tracer: Tracer, *,
                process_name: str = "repro-serve") -> str:
    with open(path, "w") as f:
        json.dump(chrome_trace(tracer, process_name=process_name), f)
    return path
