"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cd_sweep.kernel import (
    cd_block_sweep_gather_pallas,
    cd_block_sweep_pallas,
    cd_block_sweep_rowpatch_gather_pallas,
    cd_block_sweep_rowpatch_pallas,
    cd_resid_patch_gather_pallas,
    cd_resid_patch_pallas,
    cd_slab_reduce_gather_pallas,
    cd_slab_reduce_pallas,
)
from repro.kernels.cd_sweep.ref import (
    cd_block_sweep_gather_ref,
    cd_block_sweep_ref,
    cd_block_sweep_rowpatch_gather_ref,
    cd_block_sweep_rowpatch_ref,
    cd_resid_patch_ref,
    cd_slab_reduce_ref,
    gather_psi_blk,
)
from repro.kernels.cd_update.kernel import cd_column_update_pallas
from repro.kernels.cd_update.ref import cd_column_update_ref
from repro.kernels.gram.kernel import gram_pallas
from repro.kernels.gram.ref import gram_ref


# ---------------------------------------------------------------- gram ----
@pytest.mark.parametrize("rows,k", [(64, 8), (1000, 32), (2048, 128), (517, 130)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_kernel_sweep(rows, k, dtype):
    x = jax.random.normal(jax.random.PRNGKey(rows + k), (rows, k), dtype)
    got = gram_pallas(x, block_rows=256, interpret=True)
    expect = gram_ref(x)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(got, expect, rtol=tol, atol=tol * 10)


# ----------------------------------------------------------- cd_update ----
@pytest.mark.parametrize("c,d_pad", [(100, 128), (256, 256), (513, 384)])
def test_cd_update_kernel_sweep(c, d_pad):
    key = jax.random.PRNGKey(c)
    ks = jax.random.split(key, 6)
    psi = jax.random.normal(ks[0], (c, d_pad))
    alpha = jax.random.uniform(ks[1], (c, d_pad))
    alpha = alpha * (jax.random.uniform(ks[5], (c, d_pad)) > 0.3)  # padding zeros
    e = jax.random.normal(ks[2], (c, d_pad))
    w_col = jax.random.normal(ks[3], (c,))
    r1 = jax.random.normal(ks[4], (c,))
    jff = jnp.float32(1.7)
    got_w, got_e = cd_column_update_pallas(
        psi, alpha, e, w_col, r1, jff, alpha0=0.4, l2=0.05, eta=1.0,
        block_ctx=128, interpret=True,
    )
    exp_w, exp_e = cd_column_update_ref(
        psi, alpha, e, w_col, r1, jff, alpha0=0.4, l2=0.05, eta=1.0
    )
    np.testing.assert_allclose(got_w, exp_w, rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(got_e, exp_e, rtol=2e-4, atol=1e-5)


# ------------------------------------------------------------ cd_sweep ----
def _sweep_problem(c, d_pad, k, seed=0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    psi_cols = jax.random.normal(ks[0], (c, k, d_pad))     # ψ tile per column
    alpha = jax.random.uniform(ks[1], (c, d_pad))
    alpha = alpha * (jax.random.uniform(ks[5], (c, d_pad)) > 0.3)
    e = jax.random.normal(ks[2], (c, d_pad))
    w = jax.random.normal(ks[3], (c, k))
    j_full = jax.random.normal(ks[4], (k, k))
    j_full = j_full @ j_full.T + k * jnp.eye(k)            # SPD like a Gram
    return psi_cols, alpha, e, w, j_full


@pytest.mark.slow
@pytest.mark.parametrize("c,d_pad,k", [(100, 128, 8), (37, 64, 5)])
@pytest.mark.parametrize("k_b", [1, 2, 0])  # 0 → k_b = k (whole sweep fused)
def test_cd_sweep_matches_per_column(c, d_pad, k, k_b):
    """Full k-column sweep: fused block kernel ≡ the per-column cd_update
    path (R' recomputed from W before every column), any block size, and
    non-divisible C / k shapes."""
    psi_cols, alpha, e0, w0, j_full = _sweep_problem(c, d_pad, k)
    k_b = k_b or k
    args = dict(alpha0=0.4, l2=0.05, eta=1.0)

    # --- per-column baseline (existing kernel, fresh R' each column) ------
    w_ref, e_ref = w0, e0
    for f in range(k):
        r1 = w_ref @ j_full[:, f]
        w_col, e_ref = cd_column_update_pallas(
            psi_cols[:, f], alpha, e_ref, w_ref[:, f], r1, j_full[f, f],
            block_ctx=32, interpret=True, **args,
        )
        w_ref = w_ref.at[:, f].set(w_col)

    # --- fused block sweep (+ jnp oracle per block) ------------------------
    w_got, e_got = w0, e0
    w_orc, e_orc = w0, e0
    for f0 in range(0, k, k_b):
        kb = min(k_b, k - f0)
        r1_blk = w_got @ j_full[:, f0:f0 + kb]
        j_blk = j_full[f0:f0 + kb, f0:f0 + kb]
        w_blk, e_got = cd_block_sweep_pallas(
            psi_cols[:, f0:f0 + kb], alpha, e_got, w_got[:, f0:f0 + kb],
            r1_blk, j_blk, block_ctx=32, interpret=True, **args,
        )
        w_got = w_got.at[:, f0:f0 + kb].set(w_blk)
        w_oblk, e_orc = cd_block_sweep_ref(
            psi_cols[:, f0:f0 + kb], alpha, e_orc, w_orc[:, f0:f0 + kb],
            w_orc @ j_full[:, f0:f0 + kb], j_blk, **args,
        )
        w_orc = w_orc.at[:, f0:f0 + kb].set(w_oblk)

    np.testing.assert_allclose(w_got, w_ref, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(e_got, e_ref, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(w_got, w_orc, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(e_got, e_orc, rtol=2e-5, atol=2e-6)


@pytest.mark.slow
@pytest.mark.parametrize("block_k", [1, 2, 3, 8])
def test_cd_sweep_epoch_matches_naive(block_k):
    """mf_padded with the fused sweep ≡ conventional CD on the full implicit
    matrix (core/naive_cd.py), trajectory-level, for divisible and
    non-divisible k/block splits."""
    from repro.core import naive_cd
    from repro.core.models import mf, mf_padded
    from repro.sparse.interactions import build_interactions

    rng = np.random.default_rng(5)
    n_ctx, n_items, nnz, k = 13, 9, 37, 8
    cells = rng.choice(n_ctx * n_items, size=nnz, replace=False)
    ctx, item = cells // n_items, cells % n_items
    y = rng.integers(1, 5, size=nnz).astype(np.float64)
    alpha = 0.4 + 1.0 + rng.random(nnz)
    data = build_interactions(ctx, item, y, alpha, n_ctx, n_items, alpha0=0.4)
    y_dense, a_dense = naive_cd.dense_from_observed(
        jnp.asarray(ctx), jnp.asarray(item), jnp.asarray(y, jnp.float32),
        jnp.asarray(alpha, jnp.float32), n_ctx, n_items, 0.4,
    )

    hp = mf.MFHyperParams(k=k, alpha0=0.4, l2=0.05, block_k=block_k)
    params = mf.init(jax.random.PRNGKey(1), n_ctx, n_items, k)
    p_naive = params
    pdata = mf_padded.pad_interactions(data)
    e_pad = mf_padded.residuals(params, pdata)
    for _ in range(3):
        params, e_pad = mf_padded.epoch(params, pdata, e_pad, hp)
        p_naive = naive_cd.epoch_dense(p_naive, y_dense, a_dense, hp)
        np.testing.assert_allclose(params.w, p_naive.w, rtol=3e-4, atol=3e-5)
        np.testing.assert_allclose(params.h, p_naive.h, rtol=3e-4, atol=3e-5)


@pytest.mark.parametrize("c,d_pad,k_b", [(100, 128, 4), (37, 64, 3), (129, 128, 1)])
def test_cd_sweep_rowpatch_matches_ref(c, d_pad, k_b):
    """Per-row-patch block sweep ≡ jnp oracle (the tensor-mode variant:
    row-dependent R''/R' coupling), incl. non-divisible C tiles."""
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 6)
    psi = jax.random.normal(ks[0], (c, k_b, d_pad))
    alpha = jax.random.uniform(ks[1], (c, d_pad))
    alpha = alpha * (jax.random.uniform(ks[5], (c, d_pad)) > 0.3)
    e = jax.random.normal(ks[2], (c, d_pad))
    w = jax.random.normal(ks[3], (c, k_b))
    r1 = jax.random.normal(ks[4], (c, k_b))
    # per-row SPD-ish patch tensors (diag dominant like a real R'')
    p = jax.random.normal(jax.random.PRNGKey(8), (c, k_b, k_b))
    p = 0.5 * (p + jnp.swapaxes(p, 1, 2)) + 2.0 * k_b * jnp.eye(k_b)[None]
    args = dict(alpha0=0.4, l2=0.05, eta=1.0)
    w_got, e_got = cd_block_sweep_rowpatch_pallas(
        psi, alpha, e, w, r1, p, block_ctx=32, interpret=True, **args
    )
    w_ref, e_ref = cd_block_sweep_rowpatch_ref(psi, alpha, e, w, r1, p, **args)
    np.testing.assert_allclose(w_got, w_ref, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(e_got, e_ref, rtol=2e-5, atol=2e-6)


def test_cd_sweep_rowpatch_broadcast_equals_shared_gram():
    """With P broadcast from a shared Gram block, the row-patch kernel must
    reproduce the MF-style shared-Gram kernel exactly."""
    psi_cols, alpha, e0, w0, j_full = _sweep_problem(64, 128, 4, seed=3)
    j_blk = j_full[:4, :4]
    r1 = w0 @ j_blk
    args = dict(alpha0=0.4, l2=0.05, eta=1.0)
    w_a, e_a = cd_block_sweep_pallas(
        psi_cols, alpha, e0, w0, r1, j_blk, block_ctx=32, interpret=True, **args
    )
    p = jnp.broadcast_to(j_blk[None], (64, 4, 4))
    w_b, e_b = cd_block_sweep_rowpatch_pallas(
        psi_cols, alpha, e0, w0, r1, p, block_ctx=32, interpret=True, **args
    )
    np.testing.assert_allclose(w_a, w_b, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(e_a, e_b, rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("c,d_pad,m", [(100, 128, 4), (37, 64, 1), (130, 128, 6)])
def test_cd_slab_reduce_and_resid_patch_match_ref(c, d_pad, m):
    key = jax.random.PRNGKey(11)
    ks = jax.random.split(key, 4)
    psi = jax.random.normal(ks[0], (c, m, d_pad))
    alpha = jax.random.uniform(ks[1], (c, d_pad))
    e = jax.random.normal(ks[2], (c, d_pad))
    q_got, p_got = cd_slab_reduce_pallas(psi, alpha, e, block_ctx=32,
                                         interpret=True)
    q_ref, p_ref = cd_slab_reduce_ref(psi, alpha, e)
    np.testing.assert_allclose(q_got, q_ref, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(p_got, p_ref, rtol=2e-5, atol=2e-6)

    dphi = jax.random.normal(ks[3], (c, m))
    e_got = cd_resid_patch_pallas(psi, e, dphi, block_ctx=32, interpret=True)
    e_ref = cd_resid_patch_ref(psi, e, dphi)
    np.testing.assert_allclose(e_got, e_ref, rtol=2e-5, atol=2e-6)


# ----------------------------------------------------- cd_sweep gather ----
def _gather_problem(c, d_pad, m, n_src, seed=0, sentinel_rows=()):
    """ψ slab + id grid + row operands; rows in ``sentinel_rows`` point every
    slot at the zero sentinel row (an empty context in the flat-nnz layout)
    and get α=0."""
    rng = np.random.default_rng(seed)
    tab = np.r_[rng.normal(size=(n_src - 1, m)), np.zeros((1, m))]
    ids = rng.integers(0, n_src - 1, (c, d_pad))
    alpha = rng.random((c, d_pad)) * (rng.random((c, d_pad)) > 0.3)
    for r in sentinel_rows:
        ids[r] = n_src - 1
        alpha[r] = 0.0
    e = rng.normal(size=(c, d_pad))
    w = rng.normal(size=(c, m))
    r1 = rng.normal(size=(c, m))
    j_full = rng.normal(size=(m, m))
    j_full = j_full @ j_full.T + m * np.eye(m)
    return tuple(
        jnp.asarray(a, jnp.int32 if a is ids else jnp.float32)
        for a in (tab, ids, alpha, e, w, r1, j_full)
    )


@pytest.mark.parametrize("c,d_pad,m,n_src", [(100, 128, 4, 57), (37, 64, 3, 9),
                                             (129, 128, 1, 130)])
def test_cd_sweep_gather_matches_pregathered_and_ref(c, d_pad, m, n_src):
    """In-kernel gather sweep ≡ the pre-gathered kernel on the materialized
    tile ≡ the jnp oracle — incl. non-divisible C tiles, empty-context
    (all-sentinel) rows and a slab larger than the row count."""
    tab, ids, alpha, e, w, r1, j_full = _gather_problem(
        c, d_pad, m, n_src, seed=c, sentinel_rows=(0, c // 2)
    )
    args = dict(alpha0=0.4, l2=0.05, eta=1.0)
    psi_blk = gather_psi_blk(tab, ids)
    w_pre, e_pre = cd_block_sweep_pallas(
        psi_blk, alpha, e, w, r1, j_full, block_ctx=32, interpret=True, **args
    )
    w_got, e_got = cd_block_sweep_gather_pallas(
        tab, ids, alpha, e, w, r1, j_full, block_ctx=32, interpret=True, **args
    )
    w_ref, e_ref = cd_block_sweep_gather_ref(tab, ids, alpha, e, w, r1,
                                             j_full, **args)
    np.testing.assert_allclose(w_got, w_pre, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(e_got, e_pre, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(w_got, w_ref, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(e_got, e_ref, rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("c,d_pad,m,n_src", [(100, 128, 4, 41), (37, 64, 2, 300)])
def test_cd_sweep_rowpatch_gather_matches_pregathered_and_ref(c, d_pad, m, n_src):
    tab, ids, alpha, e, w, r1, _ = _gather_problem(
        c, d_pad, m, n_src, seed=7, sentinel_rows=(1,)
    )
    p = np.random.default_rng(8).normal(size=(c, m, m))
    p = 0.5 * (p + p.transpose(0, 2, 1)) + 2.0 * m * np.eye(m)[None]
    p = jnp.asarray(p, jnp.float32)
    args = dict(alpha0=0.4, l2=0.05, eta=1.0)
    psi_blk = gather_psi_blk(tab, ids)
    w_pre, e_pre = cd_block_sweep_rowpatch_pallas(
        psi_blk, alpha, e, w, r1, p, block_ctx=32, interpret=True, **args
    )
    w_got, e_got = cd_block_sweep_rowpatch_gather_pallas(
        tab, ids, alpha, e, w, r1, p, block_ctx=32, interpret=True, **args
    )
    w_ref, e_ref = cd_block_sweep_rowpatch_gather_ref(tab, ids, alpha, e, w,
                                                      r1, p, **args)
    np.testing.assert_allclose(w_got, w_pre, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(e_got, e_pre, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(w_got, w_ref, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(e_got, e_ref, rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("c,d_pad,m,n_src", [(100, 128, 4, 33), (37, 64, 1, 12),
                                             (130, 128, 6, 201)])
def test_cd_slab_reduce_and_resid_patch_gather_match(c, d_pad, m, n_src):
    tab, ids, alpha, e, _, _, _ = _gather_problem(
        c, d_pad, m, n_src, seed=11, sentinel_rows=(2,)
    )
    psi_blk = gather_psi_blk(tab, ids)
    q_pre, p_pre = cd_slab_reduce_pallas(psi_blk, alpha, e, block_ctx=32,
                                         interpret=True)
    q_got, p_got = cd_slab_reduce_gather_pallas(tab, ids, alpha, e,
                                                block_ctx=32, interpret=True)
    np.testing.assert_allclose(q_got, q_pre, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(p_got, p_pre, rtol=2e-5, atol=2e-6)
    q_ref, p_ref = cd_slab_reduce_ref(psi_blk, alpha, e)
    np.testing.assert_allclose(q_got, q_ref, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(p_got, p_ref, rtol=2e-5, atol=2e-6)

    dphi = jnp.asarray(np.random.default_rng(12).normal(size=(c, m)),
                       jnp.float32)
    e_pre = cd_resid_patch_pallas(psi_blk, e, dphi, block_ctx=32,
                                  interpret=True)
    e_got = cd_resid_patch_gather_pallas(tab, ids, e, dphi, block_ctx=32,
                                         interpret=True)
    np.testing.assert_allclose(e_got, e_pre, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(e_got, cd_resid_patch_ref(psi_blk, e, dphi),
                               rtol=2e-5, atol=2e-6)


def test_cd_sweep_gather_full_sweep_matches_per_column():
    """Full k-column sweep through the gather kernel (table slab sliced per
    block, non-divisible k/block_k) ≡ the per-column cd_update path."""
    rng = np.random.default_rng(21)
    c, d_pad, k, k_b, n_src = 60, 128, 5, 2, 19
    tab_full = jnp.asarray(rng.normal(size=(n_src, k)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, n_src, (c, d_pad)), jnp.int32)
    alpha = jnp.asarray(rng.random((c, d_pad)) * (rng.random((c, d_pad)) > 0.3),
                        jnp.float32)
    e0 = jnp.asarray(rng.normal(size=(c, d_pad)), jnp.float32)
    w0 = jnp.asarray(rng.normal(size=(c, k)), jnp.float32)
    j_full = rng.normal(size=(k, k))
    j_full = jnp.asarray(j_full @ j_full.T + k * np.eye(k), jnp.float32)
    args = dict(alpha0=0.4, l2=0.05, eta=1.0)

    w_ref, e_ref = w0, e0
    for f in range(k):
        psi_col = jnp.take(tab_full[:, f], ids, mode="clip")
        r1 = w_ref @ j_full[:, f]
        w_col, e_ref = cd_column_update_pallas(
            psi_col, alpha, e_ref, w_ref[:, f], r1, j_full[f, f],
            block_ctx=32, interpret=True, **args,
        )
        w_ref = w_ref.at[:, f].set(w_col)

    w_got, e_got = w0, e0
    for f0 in range(0, k, k_b):
        kb = min(k_b, k - f0)
        w_blk, e_got = cd_block_sweep_gather_pallas(
            tab_full[:, f0:f0 + kb], ids, alpha, e_got, w_got[:, f0:f0 + kb],
            w_got @ j_full[:, f0:f0 + kb], j_full[f0:f0 + kb, f0:f0 + kb],
            block_ctx=32, interpret=True, **args,
        )
        w_got = w_got.at[:, f0:f0 + kb].set(w_blk)

    np.testing.assert_allclose(w_got, w_ref, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(e_got, e_ref, rtol=2e-5, atol=2e-6)
