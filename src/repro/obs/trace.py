"""Lightweight request tracing: spans with parent/child links, correlated
to batcher tickets.

One request's life through the serving stack —

  submit → queue (admission wait) → flush(reason) → mesh dispatch →
  per-replica attempt/retry/failover → shard kernel call → cross-shard
  merge → result (or degraded)

— is a single trace. Two API shapes coexist because the batcher's flush
path is non-reentrant (a size-capped flush can trigger a follow-up
deadline flush from inside ``_flush``; a context manager per request
would entangle their lifetimes):

  * ``with tracer.span("merge", shard=s):`` — scoped work; the span
    auto-parents to the innermost active span and pushes itself while
    the block runs, so nested instrumented calls (mesh inside a flush)
    link up without any plumbing.
  * ``sp = tracer.begin("queue", ticket=t)`` / ``tracer.end(sp)`` —
    explicit lifetimes for spans that outlive a call frame (a request
    span lives from submit to routing; flush spans route many tickets).
    ``tracer.activate(sp)`` temporarily makes an explicitly begun span
    the parent for nested ``span()`` calls.

Ticket correlation: the batcher stamps each request span with its
``ticket`` attr and, at flush time, a ``flush_span`` attr pointing at the
flush span's id. :func:`trace_for_ticket` walks both links — the request
span's subtree plus every referenced flush subtree (which contains the
mesh's dispatch/retry/failover/merge spans) — so out-of-order and mixed
flushes still yield one coherent per-request trace. Chrome-trace JSON
export (open in ``chrome://tracing`` or https://ui.perfetto.dev) lives
in ``obs/export.py``.

Like everything in this repo's serving tier, the tracer takes an
injectable clock so tests drive it under simulated time; tracing is
OPT-IN per component (``tracer=None`` skips every span) and never
touches result values — instrumentation bit-identity is pinned in
``tests/test_obs.py``.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Callable, Dict, List, Optional


class Span:
    """One timed operation. ``t1 is None`` while still open."""

    __slots__ = ("span_id", "parent_id", "name", "t0", "t1", "attrs")

    def __init__(self, span_id: int, parent_id: Optional[int], name: str,
                 t0: float, attrs: Dict[str, object]):
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name
        self.t0 = t0
        self.t1: Optional[float] = None
        self.attrs = attrs

    @property
    def duration(self) -> float:
        return (self.t1 if self.t1 is not None else self.t0) - self.t0

    def __repr__(self) -> str:
        state = "open" if self.t1 is None else f"{self.duration:.6f}s"
        return (f"Span({self.name!r}, id={self.span_id}, "
                f"parent={self.parent_id}, {state}, {self.attrs})")


_AUTO_PARENT = object()  # sentinel: parent defaults to the active span


class Tracer:
    """Collects spans; single-threaded like the serving loop it traces."""

    def __init__(self, clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self.spans: List[Span] = []
        self._stack: List[Span] = []
        self._next_id = 0

    @property
    def current(self) -> Optional[Span]:
        """Innermost active span (``span()``/``activate()`` scope)."""
        return self._stack[-1] if self._stack else None

    def begin(self, name: str, *, parent=_AUTO_PARENT, **attrs) -> Span:
        """Open a span explicitly (the non-reentrant-flush shape). The
        caller owns its lifetime: pair with :meth:`end`. ``parent``
        overrides the default (the innermost active span); pass ``None``
        to force a root span, or a :class:`Span` to link explicitly."""
        if parent is _AUTO_PARENT:
            parent = self.current
        sp = Span(
            self._next_id,
            parent.span_id if isinstance(parent, Span) else parent,
            name, self.clock(), attrs,
        )
        self._next_id += 1
        self.spans.append(sp)
        return sp

    def end(self, span: Span, **attrs) -> Span:
        span.t1 = self.clock()
        if attrs:
            span.attrs.update(attrs)
        return span

    @contextmanager
    def span(self, name: str, *, parent=_AUTO_PARENT, **attrs):
        """Scoped span: begins, becomes the active parent, ends."""
        sp = self.begin(name, parent=parent, **attrs)
        self._stack.append(sp)
        try:
            yield sp
        finally:
            self._stack.pop()
            self.end(sp)

    @contextmanager
    def activate(self, span: Span):
        """Make an explicitly begun span the active parent for the block
        (used by the batcher so mesh spans nest under its flush span)."""
        self._stack.append(span)
        try:
            yield span
        finally:
            self._stack.pop()

    # ----------------------------------------------------------- queries
    def children_index(self) -> Dict[Optional[int], List[Span]]:
        by_parent: Dict[Optional[int], List[Span]] = {}
        for sp in self.spans:
            by_parent.setdefault(sp.parent_id, []).append(sp)
        return by_parent

    def subtree(self, root: Span) -> List[Span]:
        """``root`` plus every transitive child, in discovery order."""
        by_parent = self.children_index()
        out, frontier = [], [root]
        while frontier:
            sp = frontier.pop()
            out.append(sp)
            frontier.extend(by_parent.get(sp.span_id, ()))
        return out


def trace_for_ticket(tracer: Tracer, ticket: int) -> List[Span]:
    """Every span belonging to one batcher ticket's request, sorted by
    start time: the spans stamped with ``ticket`` (request/queue), their
    subtrees, and the full subtree of every flush span a request span
    references via ``flush_span`` — which is where the mesh's
    dispatch/attempt/retry/failover/merge spans live. Spans a flush
    shares across tickets (the flush itself, the kernel dispatches)
    appear in each of its tickets' traces: a batched request's cost IS
    shared, and the trace says so."""
    by_id = {sp.span_id: sp for sp in tracer.spans}
    seen: Dict[int, Span] = {}
    for sp in tracer.spans:
        if sp.attrs.get("ticket") != ticket:
            continue
        for member in tracer.subtree(sp):
            seen[member.span_id] = member
        flush_id = sp.attrs.get("flush_span")
        if flush_id is not None and flush_id in by_id:
            for member in tracer.subtree(by_id[flush_id]):
                seen[member.span_id] = member
    return sorted(seen.values(), key=lambda s: (s.t0, s.span_id))
