"""iCD for Tucker Decomposition (paper §5.3.2).

Model (eq. 40): ŷ(c1,c2,i) = Σ_{f1,f2,f3} b_{f1,f2,f3} u_{c1,f1} v_{c2,f2} w_{i,f3}
with core tensor B ∈ R^{k1×k2×k3}. k3-separable (paper):

    φ_f(c1,c2) = Σ_{f1,f2} b_{f1,f2,f} u_{c1,f1} v_{c2,f2},   ψ_f(i) = w_{i,f}

Unlike the other models, ∂φ_f/∂u is non-zero for EVERY f (eq. 41) — the
nested factor loops of Lemma 3 do not collapse. Our sweep keeps them as
dense k3-dimensional contractions per context row:

    U mode, dim f1*:  D(pair,f) = Σ_{f2} b_{f1*,f2,f} v_{c2,f2}
        R'/2  = segment_{c1}( Σ_f D_f · (Φ J_I)_f )
        R''/2 = segment_{c1}( Σ_f D_f · (D J_I)_f )
        L'/2  = segment_{c1}( ᾱ e s ),  s = Σ_f D_f w_{i,f}  per observation

Core coordinates b_{f1,f2,f3} all interact through Φ, so they are swept
strictly sequentially (k1·k2·k3 scalar Newton steps — each a cheap
reduction; the paper gives the same O(k1²k2²k3²·…) regime).

Context universe: the observed pair list (the paper's sparse-context case —
its dense-context einsum shortcut changes constants, not semantics; see
DESIGN.md). Item sweep is MF-like via materialized Φ.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import sweeps
from repro.core.gram import gram
from repro.core.implicit import explicit_loss
from repro.core.models.parafac import TensorContext, _item_sweep
from repro.sparse.interactions import Interactions
from repro.sparse.segment import segment_sum


class TuckerParams(NamedTuple):
    u: jax.Array  # (n_c1, k1)
    v: jax.Array  # (n_c2, k2)
    w: jax.Array  # (n_items, k3)
    b: jax.Array  # (k1, k2, k3) core tensor


@dataclasses.dataclass(frozen=True)
class TuckerHyperParams:
    k1: int
    k2: int
    k3: int
    alpha0: float = 1.0
    l2: float = 0.1
    l2_core: float = 0.1
    eta: float = 1.0
    implementation: str = "xla"

    # _item_sweep compatibility (it reads hp.k and hp.alpha0/l2/eta)
    @property
    def k(self) -> int:
        return self.k3


def init(key, n_c1, n_c2, n_items, k1, k2, k3, sigma=0.1) -> TuckerParams:
    ka, kb, kc, kd = jax.random.split(key, 4)
    return TuckerParams(
        u=sigma * jax.random.normal(ka, (n_c1, k1), jnp.float32),
        v=sigma * jax.random.normal(kb, (n_c2, k2), jnp.float32),
        w=sigma * jax.random.normal(kc, (n_items, k3), jnp.float32),
        b=sigma * jax.random.normal(kd, (k1, k2, k3), jnp.float32),
    )


def phi(params: TuckerParams, tc: TensorContext) -> jax.Array:
    """Φ (n_ctx, k3) over the observed pair list."""
    up = jnp.take(params.u, tc.c1, axis=0)  # (n, k1)
    vp = jnp.take(params.v, tc.c2, axis=0)  # (n, k2)
    return jnp.einsum("na,nb,abf->nf", up, vp, params.b)


def predict(params: TuckerParams, c1, c2, item) -> jax.Array:
    up = jnp.take(params.u, c1, axis=0)
    vp = jnp.take(params.v, c2, axis=0)
    wp = jnp.take(params.w, item, axis=0)
    return jnp.einsum("na,nb,nf,abf->n", up, vp, wp, params.b)


def _mode_sweep(
    side,            # U (n_c1,k1) or V (n_c2,k2)
    b_slice_fn,      # f* -> (k_other, k3) core slice for this mode
    partner_of_pair, # c2 (U mode) or c1 (V mode) per pair
    partner,         # V or U
    group_of_pair,   # c1 or c2 per pair
    n_side, k_side,
    phi_m, j_i, data, w_items, e, hp,
):
    pair_of_nnz = data.ctx
    grp_nnz = jnp.take(group_of_pair, pair_of_nnz)

    def body(fs, carry):
        side_m, phi_m, e = carry
        bsl = b_slice_fn(fs)                                   # (k_other, k3)
        pp = jnp.take(partner, partner_of_pair, axis=0)        # (n_ctx, k_other)
        d = pp @ bsl                                           # (n_ctx, k3)
        s = jnp.sum(
            jnp.take(d, pair_of_nnz, axis=0) * jnp.take(w_items, data.item, axis=0),
            axis=1,
        )                                                      # (nnz,)
        lp = segment_sum(data.alpha * e * s, grp_nnz, n_side)
        lpp = segment_sum(data.alpha * s * s, grp_nnz, n_side)
        rp = segment_sum(jnp.sum(d * (phi_m @ j_i), axis=1), group_of_pair, n_side)
        rpp = segment_sum(jnp.sum(d * (d @ j_i), axis=1), group_of_pair, n_side)
        s_col = sweeps.take_col(side_m, fs)
        delta = sweeps.newton_delta(
            sweeps.NewtonParts(lp + hp.alpha0 * rp, lpp + hp.alpha0 * rpp),
            s_col, hp.l2, hp.eta,
        )
        phi_m = phi_m + jnp.take(delta, group_of_pair)[:, None] * d
        e = e + jnp.take(delta, grp_nnz) * s
        return sweeps.put_col(side_m, fs, s_col + delta), phi_m, e

    return jax.lax.fori_loop(0, k_side, body, (side, phi_m, e))


def core_sweep(params, phi_m, j_i, tc, data, e, hp):
    """Sequential core-tensor sweep: scalar Newton step per b_{f1,f2,f3}."""
    u, v, w, b = params
    k1, k2, k3 = b.shape
    pair_of_nnz = data.ctx
    w_nnz_cols = lambda f3: jnp.take(sweeps.take_col(w, f3), data.item)

    def body(idx, carry):
        b, phi_m, e = carry
        f1 = idx // (k2 * k3)
        f2 = (idx // k3) % k2
        f3 = idx % k3
        g = jnp.take(sweeps.take_col(u, f1), tc.c1) * jnp.take(
            sweeps.take_col(v, f2), tc.c2
        )                                                       # (n_ctx,)
        w_col = w_nnz_cols(f3)                                  # (nnz,)
        g_nnz = jnp.take(g, pair_of_nnz)
        lp = jnp.sum(data.alpha * e * g_nnz * w_col)
        lpp = jnp.sum(data.alpha * (g_nnz * w_col) ** 2)
        rp = jnp.dot(phi_m.T @ g, sweeps.take_col(j_i, f3))
        rpp = j_i[f3, f3] * jnp.sum(g * g)
        b_val = b[f1, f2, f3]
        num = lp + hp.alpha0 * rp + hp.l2_core * b_val
        den = lpp + hp.alpha0 * rpp + hp.l2_core
        delta = -hp.eta * num / jnp.maximum(den, 1e-12)
        b = b.at[f1, f2, f3].add(delta)
        phi_m = sweeps.put_col(phi_m, f3, sweeps.take_col(phi_m, f3) + delta * g)
        e = e + delta * g_nnz * w_col
        return b, phi_m, e

    b, phi_m, e = jax.lax.fori_loop(0, k1 * k2 * k3, body, (b, phi_m, e))
    return b, phi_m, e


@partial(jax.jit, static_argnames=("hp",))
def epoch(
    params: TuckerParams,
    tc: TensorContext,
    data: Interactions,
    e: jax.Array,
    hp: TuckerHyperParams,
) -> Tuple[TuckerParams, jax.Array]:
    """One iCD epoch: U sweep → V sweep → core sweep → item (W) sweep."""
    u, v, w, b = params
    j_i = gram(w, implementation=hp.implementation)
    phi_m = phi(params, tc)

    u, phi_m, e = _mode_sweep(
        u, lambda f1: jax.lax.dynamic_slice_in_dim(b, f1, 1, axis=0)[0],
        tc.c2, v, tc.c1, u.shape[0], hp.k1, phi_m, j_i, data, w, e, hp,
    )
    v, phi_m, e = _mode_sweep(
        v, lambda f2: jax.lax.dynamic_slice_in_dim(b, f2, 1, axis=1)[:, 0],
        tc.c1, u, tc.c2, v.shape[0], hp.k2, phi_m, j_i, data, w, e, hp,
    )
    b, phi_m, e = core_sweep(TuckerParams(u, v, w, b), phi_m, j_i, tc, data, e, hp)

    j_c = gram(phi_m)
    e_t = sweeps.to_item_major(e, data.t_perm)
    alpha_t = sweeps.to_item_major(data.alpha, data.t_perm)
    phi_cols = lambda f: jnp.take(sweeps.take_col(phi_m, f), data.t_ctx)
    w, e_t = _item_sweep(w, j_c, phi_cols, data, e_t, alpha_t, hp)
    e = sweeps.to_ctx_major(e_t, data.t_perm)
    return TuckerParams(u, v, w, b), e


def residuals(params: TuckerParams, tc: TensorContext, data: Interactions) -> jax.Array:
    return sweeps.residuals_from_factors(
        phi(params, tc), params.w, data.ctx, data.item, data.y
    )


def objective(params: TuckerParams, tc: TensorContext, data: Interactions, hp: TuckerHyperParams) -> jax.Array:
    e = residuals(params, tc, data)
    reg = jnp.sum(gram(phi(params, tc)) * gram(params.w))
    sq = jnp.sum(params.u**2) + jnp.sum(params.v**2) + jnp.sum(params.w**2)
    return (
        explicit_loss(e, data.alpha)
        + hp.alpha0 * reg
        + hp.l2 * sq
        + hp.l2_core * jnp.sum(params.b**2)
    )


def fit(params, tc, data, hp, n_epochs, callback=None):
    e = residuals(params, tc, data)
    for ep in range(n_epochs):
        params, e = epoch(params, tc, data, e, hp)
        if callback is not None:
            callback(ep, params)
    return params
