"""Adafactor (Shazeer & Stern) — factored second moments.

Matrices keep row/col RMS statistics instead of the full (shape)-sized v,
cutting optimizer memory from 2× to ~1.01× of the parameters — the default
for the 67B dry-run configuration.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.base import OptimizerDef


def _factored(shape) -> bool:
    return len(shape) >= 2


def adafactor(lr=None, decay=0.8, eps=1e-30, clip_threshold=1.0,
              eps_scale=1e-3) -> OptimizerDef:
    """lr=None ⇒ canonical relative step sizing
    ``max(eps_scale, RMS(param)) · min(1e-2, 1/√t)`` (Shazeer & Stern §9) —
    Adafactor's normalized updates stay O(1) near the optimum, so a constant
    lr oscillates; the 1/√t decay is part of the algorithm."""
    if lr is None:
        lr_fn = None
    else:
        lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params):
        def state_for(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),       # row
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(p, jnp.float32)}

        return {
            "step": jnp.zeros((), jnp.int32),
            "v": jax.tree_util.tree_map(
                state_for, params, is_leaf=lambda x: isinstance(x, jax.Array)
            ),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        beta = 1.0 - (step.astype(jnp.float32) + 1) ** (-decay)

        def lr_for(p):
            if lr_fn is not None:
                return lr_fn(step)
            rms_p = jnp.sqrt(jnp.mean(jnp.square(p.astype(jnp.float32))))
            rel = jnp.minimum(1e-2, 1.0 / jnp.sqrt(step.astype(jnp.float32)))
            return jnp.maximum(eps_scale, rms_p) * rel

        def upd(g, s, p):
            lr_t = lr_for(p)
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if "vr" in s:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                v_est = (
                    vr[..., None] * vc[..., None, :] / denom[..., None]
                )
                u = g * jax.lax.rsqrt(v_est + eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + eps)
                new_s = {"v": v}
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            return -lr_t * u, new_s

        flat_g, tree = jax.tree_util.tree_flatten(grads)
        flat_s = tree.flatten_up_to(state["v"])
        flat_p = jax.tree_util.tree_leaves(params)
        outs = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        updates = tree.unflatten([o[0] for o in outs])
        new_v = tree.unflatten([o[1] for o in outs])
        return updates, {"step": step, "v": new_v}

    return OptimizerDef(init, update)
