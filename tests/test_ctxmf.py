"""Context-aware MF (ctxmf): GFF-style seasonal/session context as an extra
k-separable mode on the PARAFAC machinery — event-log plumbing
(bucket derivation + pair dedup), fused (``cd_block_sweep_rowpatch``) vs
per-column parity on a ctxmf instance, weighted-epoch exactness, and the
``build_model`` adapter surface."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.models import ctxmf
from repro.core.models.api import Dataset, build_model
from repro.sparse.interactions import build_interactions


def make_event_log(seed=0, n_users=7, n_items=9, n_events=40, n_buckets=4):
    """Synthetic implicit event log (user, item, t) with unique (user, item)
    cells so pair/item cells stay unique after bucketing."""
    rng = np.random.default_rng(seed)
    cells = rng.choice(n_users * n_items, size=n_events, replace=False)
    user, item = cells // n_items, cells % n_items
    t = rng.uniform(0.0, 1000.0, size=n_events)
    bucket = ctxmf.seasonal_buckets(t, n_buckets, period=250.0)
    return user, item, t, bucket


def make_ctx_problem(seed=0, alpha0=0.3, **kw):
    user, item, t, bucket = make_event_log(seed=seed, **kw)
    n_users = int(user.max()) + 1
    n_buckets = int(bucket.max()) + 1
    n_items = int(item.max()) + 1
    tc, pair = ctxmf.build_context(user, bucket, n_users, n_buckets)
    rng = np.random.default_rng(seed + 100)
    y = rng.integers(1, 4, size=user.size).astype(np.float64)
    alpha = alpha0 + 1.0 + rng.random(user.size)
    data = build_interactions(pair, item, y, alpha, int(tc.c1.shape[0]),
                              n_items, alpha0=alpha0)
    return tc, data


def test_seasonal_buckets_phase():
    t = np.array([0.0, 10.0, 30.0, 45.0, 100.0, 130.0])
    b = ctxmf.seasonal_buckets(t, n_buckets=4, period=100.0)
    # phase of (t - t.min()) mod 100 quantized into 4 buckets of width 25
    np.testing.assert_array_equal(b, [0, 0, 1, 1, 0, 1])
    assert b.dtype == np.int32
    assert ctxmf.seasonal_buckets([], 4).size == 0
    # explicit t0 keeps disjoint windows of one log phase-aligned
    late = t + 130.0
    np.testing.assert_array_equal(
        ctxmf.seasonal_buckets(late, 4, period=100.0, t0=0.0),
        ctxmf.seasonal_buckets(t + 30.0, 4, period=100.0, t0=0.0),
    )


def test_session_buckets_gap_split():
    # sessions split at gaps > 5; order independence via scrambled input
    t = np.array([0.0, 1.0, 2.0, 20.0, 21.0, 50.0])
    b = ctxmf.session_buckets(t, gap=5.0, n_buckets=8)
    np.testing.assert_array_equal(b, [0, 0, 0, 1, 1, 2])
    perm = np.array([3, 0, 5, 1, 4, 2])
    np.testing.assert_array_equal(
        ctxmf.session_buckets(t[perm], gap=5.0, n_buckets=8), b[perm]
    )
    # wraps into the bucket vocabulary
    assert ctxmf.session_buckets(np.arange(10) * 100.0, gap=5.0,
                                 n_buckets=3).max() == 2


def test_build_context_dedup_and_inverse():
    user = np.array([0, 1, 0, 2, 1, 0])
    bucket = np.array([1, 0, 1, 2, 0, 2])
    tc, pair = ctxmf.build_context(user, bucket, n_users=3, n_buckets=3)
    c1 = np.asarray(tc.c1)
    c2 = np.asarray(tc.c2)
    # four unique pairs, lexsorted
    np.testing.assert_array_equal(c1, [0, 0, 1, 2])
    np.testing.assert_array_equal(c2, [1, 2, 0, 2])
    # the inverse index reconstructs every event's (user, bucket)
    np.testing.assert_array_equal(c1[pair], user)
    np.testing.assert_array_equal(c2[pair], bucket)
    with pytest.raises(ValueError):
        ctxmf.build_context(user, bucket, n_users=2, n_buckets=3)
    with pytest.raises(ValueError):
        ctxmf.build_context(user, bucket, n_users=3, n_buckets=2)


@pytest.mark.parametrize("block_k", [2, 3])
def test_ctxmf_fused_matches_per_column(block_k):
    """The fused epoch (context-mode sweeps via ``cd_block_sweep_rowpatch``)
    must track the per-column epoch on a ctxmf instance built from an event
    log — incl. the non-divisible k=3 / block_k=2 split."""
    tc, data = make_ctx_problem(seed=1)
    k = 3
    hp = ctxmf.CtxMFHyperParams(k=k, alpha0=0.3, l2=0.05, block_k=block_k)
    params = ctxmf.init(jax.random.PRNGKey(0), tc.n_c1, tc.n_c2,
                        data.n_items, k)
    padded = ctxmf.pad_tensor_groups(tc, data)
    ref, got = params, params
    e_ref = ctxmf.residuals(params, tc, data)
    e_got = ctxmf.residuals(params, tc, data)
    for _ in range(2):
        ref, e_ref = ctxmf.epoch(ref, tc, data, e_ref, hp)
        got, e_got = ctxmf.epoch_padded(got, tc, data, padded, e_got, hp)
    np.testing.assert_allclose(got.u, ref.u, rtol=5e-4, atol=1e-5)
    np.testing.assert_allclose(got.v, ref.v, rtol=5e-4, atol=1e-5)
    np.testing.assert_allclose(got.w, ref.w, rtol=5e-4, atol=1e-5)
    np.testing.assert_allclose(e_got, e_ref, rtol=5e-4, atol=5e-5)


@pytest.mark.parametrize("fused", [False, True])
def test_ctxmf_weighted_epoch_exact(fused):
    """weights=w must equal training on alpha·w exactly, and weights=None
    must be bit-identical to weights=ones (both paths)."""
    tc, data = make_ctx_problem(seed=2)
    hp = ctxmf.CtxMFHyperParams(k=3, alpha0=0.3, l2=0.05, block_k=2)
    params = ctxmf.init(jax.random.PRNGKey(1), tc.n_c1, tc.n_c2,
                        data.n_items, 3)
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.uniform(0.5, 2.0, size=data.nnz), jnp.float32)
    data_pre = dataclasses.replace(data, alpha=data.alpha * w)

    def fresh():
        # epoch_padded donates the residual buffer — one per call
        return ctxmf.residuals(params, tc, data)

    if fused:
        padded = ctxmf.pad_tensor_groups(tc, data)
        got, _ = ctxmf.epoch_padded(params, tc, data, padded, fresh(), hp,
                                    weights=w)
        padded_pre = ctxmf.pad_tensor_groups(tc, data_pre)
        ref, _ = ctxmf.epoch_padded(params, tc, data_pre, padded_pre,
                                    fresh(), hp)
        ones, _ = ctxmf.epoch_padded(params, tc, data, padded, fresh(), hp,
                                     weights=jnp.ones(data.nnz, jnp.float32))
        none, _ = ctxmf.epoch_padded(params, tc, data, padded, fresh(), hp)
    else:
        got, _ = ctxmf.epoch(params, tc, data, fresh(), hp, None, 0, w)
        ref, _ = ctxmf.epoch(params, tc, data_pre, fresh(), hp)
        ones, _ = ctxmf.epoch(params, tc, data, fresh(), hp, None, 0,
                              jnp.ones(data.nnz, jnp.float32))
        none, _ = ctxmf.epoch(params, tc, data, fresh(), hp)
    for f in got._fields:
        np.testing.assert_array_equal(np.asarray(getattr(got, f)),
                                      np.asarray(getattr(ref, f)))
        np.testing.assert_array_equal(np.asarray(getattr(ones, f)),
                                      np.asarray(getattr(none, f)))


def test_ctxmf_model_adapter():
    """``build_model('ctxmf', ...)``: fit reduces the objective, the query
    address is (user_ids, bucket_ids), and fold-in rides the shared path."""
    tc, data = make_ctx_problem(seed=3)
    hp = ctxmf.CtxMFHyperParams(k=4, alpha0=0.3, l2=0.05)
    model = build_model("ctxmf", hp=hp, dataset=Dataset(data=data, tc=tc))
    assert model.name == "ctxmf"
    params = model.init(jax.random.PRNGKey(2))
    start = float(model.objective(params))
    params = model.fit(params, n_epochs=6)
    assert float(model.objective(params)) < 0.8 * start
    psi = np.asarray(model.export_psi(params))
    assert psi.shape == (data.n_items, 4)
    phi = np.asarray(model.build_phi(params, (jnp.array([0, 1]),
                                              jnp.array([1, 0]))))
    assert phi.shape == (2, 4)
    np.testing.assert_allclose(
        phi, np.asarray(params.u)[[0, 1]] * np.asarray(params.v)[[1, 0]],
        rtol=1e-6,
    )
    row = np.asarray(model.fold_in_user(params, np.arange(3), n_sweeps=64))
    assert row.shape == (4,) and np.all(np.isfinite(row))


def test_ctxmf_context_beats_uniform_context():
    """On data whose target depends on a per-event context bucket, fitting
    distinct bucket factors must beat collapsing every event into one bucket
    (the MF-shaped baseline) on explicit fit quality. (The full objectives
    are NOT comparable — the implicit-regularizer universe scales with the
    pair count — so compare the explicit residual loss on observed events.)"""
    rng = np.random.default_rng(11)
    n_users, n_items, n_buckets = 8, 10, 2
    cells = rng.choice(n_users * n_items, size=60, replace=False)
    user, item = cells // n_items, cells % n_items
    bucket = rng.integers(0, n_buckets, size=user.size)
    # y = 2 + (−1)^item·(−1)^bucket: rank-2 in (user, bucket, item), but
    # looks like noise to a model that cannot see the bucket
    y = np.where((item + bucket) % 2 == 0, 3.0, 1.0)
    alpha = np.full(user.size, 1.5)
    # near-zero α₀/λ: the zero-set universe differs between the two fits
    # (pair count changes), so keep the implicit pull negligible and let the
    # explicit part decide
    hpc = ctxmf.CtxMFHyperParams(k=3, alpha0=0.01, l2=0.01)

    def fit_explicit_loss(buckets, n_b):
        tc, pair = ctxmf.build_context(user, buckets, n_users, n_b)
        data = build_interactions(pair, item, y, alpha, int(tc.c1.shape[0]),
                                  n_items, alpha0=0.01)
        params = ctxmf.init(jax.random.PRNGKey(3), tc.n_c1, tc.n_c2,
                            n_items, 3)
        params = ctxmf.fit(params, tc, data, hpc, n_epochs=20)
        e = ctxmf.residuals(params, tc, data)
        return float(jnp.sum(data.alpha * e * e))

    ctx_loss = fit_explicit_loss(bucket, n_buckets)
    flat_loss = fit_explicit_loss(np.zeros_like(bucket), 1)
    assert ctx_loss < 0.8 * flat_loss
