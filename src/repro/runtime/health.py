"""Straggler detection: per-step timing watchdog.

On a pod each host reports step wall-times through the coordinator; hosts
whose p50 exceeds the fleet p50 by ``threshold``× for ``patience``
consecutive windows are flagged, triggering either (a) checkpoint + elastic
re-mesh without them, or (b) scheduler eviction. In this container the same
logic runs over injected timings (tests) and the trainer's real step times.
"""
from __future__ import annotations

import collections
from typing import Dict, List


class StragglerWatchdog:
    """Flag members whose median report exceeds the fleet median.

    Host ids are any hashable key — training uses int host ids, the serving
    mesh (``serve/mesh.py``) uses ``(shard, replica)`` tuples with query
    latencies as the reported "step times".

    A host whose history has gone QUIET — no report for ``window`` full
    fleet rounds (``window · n_hosts`` reports fleet-wide) — stops voting:
    its stale median is excluded from the fleet baseline, it can't be
    flagged on dead history, and its strikes reset. A crashed host is the
    failure DETECTOR's job (it stops answering at all); the watchdog's job
    is live-but-slow, which requires live data.
    """

    def __init__(self, threshold: float = 2.0, patience: int = 3, window: int = 16):
        self.threshold = threshold
        self.patience = patience
        self.histories: Dict[object, collections.deque] = {}
        self.strikes: Dict[object, int] = collections.defaultdict(int)
        self.window = window
        self._tick = 0                          # fleet-wide report counter
        self._last_seen: Dict[object, int] = {}

    def report(self, host_id, step_time: float) -> None:
        self._tick += 1
        self._last_seen[host_id] = self._tick
        self.histories.setdefault(
            host_id, collections.deque(maxlen=self.window)
        ).append(step_time)

    def _median(self, xs: List[float]) -> float:
        s = sorted(xs)
        n = len(s)
        mid = n // 2
        return s[mid] if n % 2 else 0.5 * (s[mid - 1] + s[mid])

    def _active(self) -> List[object]:
        """Hosts with recent data: reported within the last ``window`` fleet
        rounds. Quiet hosts drop out of the baseline and un-strike."""
        horizon = self.window * max(1, len(self.histories))
        active = [h for h, t in self._last_seen.items()
                  if self._tick - t < horizon]
        for h in self.histories:
            if h not in active:
                self.strikes[h] = 0
        return active

    def check(self) -> List[object]:
        """Returns host ids currently flagged as stragglers."""
        if len(self.histories) < 2:
            return []
        medians = {h: self._median(list(self.histories[h]))
                   for h in self._active() if len(self.histories[h]) >= 3}
        if len(medians) < 2:
            return []
        fleet = self._median(list(medians.values()))
        flagged = []
        for h, m in medians.items():
            if m > self.threshold * fleet:
                self.strikes[h] += 1
            else:
                self.strikes[h] = 0
            if self.strikes[h] >= self.patience:
                flagged.append(h)
        return flagged
