"""Tiny reference instances of every k-separable model.

Shared by the kernel/engine/cluster parity tests and the serve bench: build
a small instance of each zoo model through the unified
:mod:`repro.core.models.api` ``Model`` protocol, so every consumer
exercises the same five models via ONE surface (no per-model signature
branches) and a new zoo member only has to be added HERE.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.design import make_design
from repro.core.models import fm, mf, mfsi, parafac, tucker
from repro.core.models.api import Dataset, build_model
from repro.core.models.parafac import TensorContext

ZOO = ("mf", "mfsi", "fm", "parafac", "tucker")


def rand_f32(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape),
                       jnp.float32)


def zoo_model(name, rng, *, n_ctx=20, n_items=37, b=9, k=6):
    """A small instance of zoo model ``name`` through the unified API:
    returns ``(model, params, query)`` where ``model`` is the
    :class:`~repro.core.models.api.Model` adapter, ``params`` a seeded init,
    and ``query`` a B-row ``build_phi`` address in the model's own query
    space (ctx ids / design rows / a ``(c1, c2)`` pair tuple)."""
    if name == "mf":
        model = build_model("mf", hp=mf.MFHyperParams(k=k), dataset=Dataset())
        return model, mf.init(jax.random.PRNGKey(0), n_ctx, n_items, k), \
            jnp.arange(b)
    if name == "parafac":
        params = parafac.init(jax.random.PRNGKey(1), 8, 7, n_items, k)
        c1 = jnp.asarray(rng.integers(0, 8, b), jnp.int32)
        c2 = jnp.asarray(rng.integers(0, 7, b), jnp.int32)
        tc = TensorContext(c1=c1, c2=c2, n_c1=8, n_c2=7)
        model = build_model(
            "parafac", hp=parafac.PARAFACHyperParams(k=k), dataset=Dataset(tc=tc)
        )
        return model, params, (c1, c2)
    if name == "tucker":
        params = tucker.init(jax.random.PRNGKey(2), 8, 7, n_items, 4, 3, k)
        c1 = jnp.asarray(rng.integers(0, 8, b), jnp.int32)
        c2 = jnp.asarray(rng.integers(0, 7, b), jnp.int32)
        tc = TensorContext(c1=c1, c2=c2, n_c1=8, n_c2=7)
        model = build_model(
            "tucker", hp=tucker.TuckerHyperParams(k1=4, k2=3, k3=k),
            dataset=Dataset(tc=tc),
        )
        return model, params, (c1, c2)
    x = make_design(
        [dict(name="id", ids=np.arange(n_ctx) % 11, vocab=11),
         dict(name="grp", ids=rng.integers(0, 5, n_ctx), vocab=5)], n_ctx)
    z = make_design(
        [dict(name="item_id", ids=np.arange(n_items), vocab=n_items),
         dict(name="genre", ids=rng.integers(0, 7, n_items), vocab=7)], n_items)
    if name == "mfsi":
        model = build_model(
            "mfsi", hp=mfsi.MFSIHyperParams(k=k), dataset=Dataset(x=x, z=z)
        )
        return model, mfsi.init(jax.random.PRNGKey(3), x.p, z.p, k), \
            jnp.arange(b)
    if name != "fm":
        raise ValueError(f"unknown zoo model {name!r}")
    hp = fm.FMHyperParams(k=k)
    params = fm.init(jax.random.PRNGKey(4), x.p, z.p, k)
    # break the all-zero linear/bias init so ψ_spec is a real column
    params = params._replace(
        b=jnp.asarray(0.3), w_lin=rand_f32((x.p,), 10),
        h_lin=rand_f32((z.p,), 11),
    )
    model = build_model("fm", hp=hp, dataset=Dataset(x=x, z=z))
    return model, params, jnp.arange(b)


def model_phi_psi(name, rng, *, n_ctx=20, n_items=37, b=9, k=6):
    """A small instance of zoo model ``name``; returns (phi (B, D),
    psi (n_items, D)) through the model's export contract."""
    model, params, query = zoo_model(name, rng, n_ctx=n_ctx, n_items=n_items,
                                     b=b, k=k)
    return model.build_phi(params, query), model.export_psi(params)
