"""Paper §6 experiment reproductions on the synthetic YouTube-like dataset.

Protocols (paper §6.2):
  * Cold-Start  — hold out whole users; recommend from attributes only.
  * Offline     — hold out each user's LAST event (leave-one-out).
  * Instant     — global time cutoff; model frozen, features keep updating.

Models: Popularity, Coview, iCD-MF, iCD-FM with feature sets
A (age/country/gender/device), P (previous video), U (user id),
H (watch history), and combinations — exactly Figure 6/7's lineup.

Everything is sized to run on CPU in minutes; the mechanisms the paper
claims (attributes carry cold-start, P/H carry sequence signal, combined
features win) are generated into the data (see repro.data.synthetic).
"""
from __future__ import annotations

import dataclasses
import json
from collections import defaultdict
from typing import Dict, List, Sequence, Tuple

import jax
import numpy as np

from repro.core.design import Design, make_design
from repro.core.metrics import recall_ndcg_multi
from repro.core.models import fm, mf
from repro.data.synthetic import make_implicit_dataset
from repro.sparse.interactions import build_interactions

K_EVAL = 100
NO_PREV = 0  # reserved "no previous video" id (item ids shift by +1)
HIST_LEN = 10


def paper_dataset(quick: bool = False, seed: int = 0):
    """The §6 stand-in: cardinalities scaled to CPU, signal structure tuned
    so the paper's qualitative orderings are generated into the data
    (attributes carry cold users, sequences carry P/H — see
    repro/data/synthetic.py)."""
    if quick:
        return make_implicit_dataset(
            n_users=800, n_items=1500, attr_strength=0.95,
            pop_strength=0.4, taste_strength=2.5, markov_strength=1.2,
            seed=seed,
        )
    return make_implicit_dataset(
        n_users=2500, n_items=3000, attr_strength=0.95,
        pop_strength=0.4, taste_strength=2.5, markov_strength=1.2,
        events_per_user=(8, 40), seed=seed,
    )


# ---------------------------------------------------------------------------
# feature building
# ---------------------------------------------------------------------------
def _merge_bag(items: Sequence[int], length: int) -> Tuple[np.ndarray, np.ndarray]:
    """Last ``length`` items as a unique-id weighted bag (merge repeats)."""
    recent = list(items)[-length:]
    if not recent:
        return np.zeros(length, np.int64), np.zeros(length, np.float32)
    w = 1.0 / len(recent)
    acc: Dict[int, float] = defaultdict(float)
    for it in recent:
        acc[it] += w
    ids = np.zeros(length, np.int64)
    ws = np.zeros(length, np.float32)
    for j, (it, weight) in enumerate(acc.items()):
        ids[j] = it
        ws[j] = weight
    return ids, ws


@dataclasses.dataclass
class CtxRow:
    user: int
    prev: int                  # item id + 1; NO_PREV if none
    hist: Tuple[np.ndarray, np.ndarray]
    age: int
    country: int
    gender: int
    device: int


def _row_from_state(ds, user: int, history: Sequence[int]) -> CtxRow:
    return CtxRow(
        user=user,
        prev=(history[-1] + 1) if history else NO_PREV,
        hist=_merge_bag([h + 1 for h in history], HIST_LEN),
        age=int(ds.age[user]), country=int(ds.country[user]),
        gender=int(ds.gender[user]), device=int(ds.device[user]),
    )


def build_ctx_design(ds, rows: List[CtxRow], features: str) -> Design:
    """features: subset string of 'A', 'P', 'U', 'H'."""
    specs = []
    n = len(rows)
    if "A" in features:
        specs += [
            dict(name="age", ids=np.array([r.age for r in rows]), vocab=ds.n_age),
            dict(name="country", ids=np.array([r.country for r in rows]),
                 vocab=ds.n_country),
            dict(name="gender", ids=np.array([r.gender for r in rows]),
                 vocab=ds.n_gender),
            dict(name="device", ids=np.array([r.device for r in rows]),
                 vocab=ds.n_device),
        ]
    if "P" in features:
        specs.append(dict(name="prev", ids=np.array([r.prev for r in rows]),
                          vocab=ds.n_items + 1))
    if "U" in features:
        specs.append(dict(name="user", ids=np.array([r.user for r in rows]),
                          vocab=ds.n_users))
    if "H" in features:
        ids = np.stack([r.hist[0] for r in rows])
        ws = np.stack([r.hist[1] for r in rows])
        specs.append(dict(name="hist", ids=ids, vocab=ds.n_items + 1, weights=ws))
    assert specs, f"empty feature set {features!r}"
    return make_design(specs, n)


def build_item_design(ds) -> Design:
    return make_design(
        [dict(name="item", ids=np.arange(ds.n_items), vocab=ds.n_items)],
        ds.n_items,
    )


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------
def popularity_scores(train_events: np.ndarray, n_items: int) -> np.ndarray:
    return np.bincount(train_events[:, 1], minlength=n_items).astype(np.float64)


def coview_matrix(train_events: np.ndarray, n_items: int) -> np.ndarray:
    """count[i, j] = #(j follows i) per user, fallback handled by caller."""
    count = np.zeros((n_items, n_items), np.float64)
    by_user: Dict[int, List[int]] = defaultdict(list)
    for u, i, t in train_events:
        by_user[u].append(i)
    for seq in by_user.values():
        for a, b in zip(seq[:-1], seq[1:]):
            count[a, b] += 1
    return count


# ---------------------------------------------------------------------------
# training wrappers
# ---------------------------------------------------------------------------
def train_icd_mf(ds, train_events, k=16, epochs=20, alpha0=0.5, l2=0.05, seed=0):
    pairs = np.unique(train_events[:, :2], axis=0)
    data = build_interactions(
        pairs[:, 0], pairs[:, 1], np.ones(len(pairs)),
        np.full(len(pairs), alpha0 + 4.0), ds.n_users, ds.n_items, alpha0=alpha0,
    )
    hp = mf.MFHyperParams(k=k, alpha0=alpha0, l2=l2)
    params = mf.init(jax.random.PRNGKey(seed), ds.n_users, ds.n_items, k)
    return mf.fit(params, data, hp, epochs), hp


def train_icd_fm(ds, ctx_design: Design, pairs: np.ndarray, n_ctx: int,
                 k=32, epochs=25, alpha0=0.5, l2=0.05, seed=0):
    """pairs: (nnz, 2) = (ctx_row_index, item)."""
    item_design = build_item_design(ds)
    data = build_interactions(
        pairs[:, 0], pairs[:, 1], np.ones(len(pairs)),
        np.full(len(pairs), alpha0 + 4.0), n_ctx, ds.n_items, alpha0=alpha0,
    )
    hp = fm.FMHyperParams(k=k, alpha0=alpha0, l2=l2, l2_lin=l2)
    params = fm.init(jax.random.PRNGKey(seed), ctx_design.p, item_design.p, k)
    params = fm.fit(params, ctx_design, item_design, data, hp, epochs)
    return params, hp, item_design


def fm_eval_scores(ds, params, hp, eval_design: Design, item_design: Design):
    pe = fm.phi_ext(params, eval_design, hp)
    se = fm.psi_ext(params, item_design, hp)
    return np.asarray(pe @ se.T)


# ---------------------------------------------------------------------------
# protocols
# ---------------------------------------------------------------------------
def split_cold_start(ds, frac=0.2, seed=0):
    rng = np.random.default_rng(seed)
    users = rng.permutation(ds.n_users)
    cold = set(users[: int(frac * ds.n_users)].tolist())
    train = ds.events[~np.isin(ds.events[:, 0], list(cold))]
    held: Dict[int, List[int]] = defaultdict(list)
    for u, i, t in ds.events:
        if u in cold:
            held[u].append(i)
    return train, held


def run_cold_start(ds=None, quick=False, seed=0) -> Dict[str, Dict[str, float]]:
    ds = ds or make_implicit_dataset(seed=seed)
    train, held = split_cold_start(ds, seed=seed)
    cold_users = sorted(held)
    truth = [sorted(set(held[u])) for u in cold_users]
    n_items = ds.n_items
    results = {}

    pop = popularity_scores(train, n_items)
    pop_scores = np.tile(pop, (len(cold_users), 1))
    results["popularity"] = _metrics(pop_scores, truth)

    # coview: cold users have no history → popularity fallback (paper: no
    # better than most-popular)
    results["coview"] = dict(results["popularity"])

    # iCD-MF: unseen users have no embedding → mean-embedding fallback
    params_mf, hp_mf = train_icd_mf(ds, train, epochs=6 if quick else 20, seed=seed)
    mean_w = np.asarray(params_mf.w).mean(axis=0, keepdims=True)
    mf_scores = np.tile(mean_w @ np.asarray(params_mf.h).T, (len(cold_users), 1))
    results["icd-mf"] = _metrics(mf_scores, truth)

    # iCD-FM A: attribute contexts (one row per TRAIN user)
    train_users = sorted(set(train[:, 0].tolist()))
    rows = [_row_from_state(ds, u, []) for u in train_users]
    design = build_ctx_design(ds, rows, "A")
    user_to_row = {u: r for r, u in enumerate(train_users)}
    pairs = np.array([[user_to_row[u], i] for u, i, t in train])
    pairs = np.unique(pairs, axis=0)
    params_fm, hp_fm, item_design = train_icd_fm(
        ds, design, pairs, len(train_users), epochs=5 if quick else 25, seed=seed)
    cold_rows = [_row_from_state(ds, u, []) for u in cold_users]
    eval_design = build_ctx_design(ds, cold_rows, "A")
    fm_scores = fm_eval_scores(ds, params_fm, hp_fm, eval_design, item_design)
    results["icd-fm A"] = _metrics(fm_scores, truth)
    return results


def split_offline(ds):
    """Hold out each user's last event."""
    last_idx = {}
    for idx, (u, i, t) in enumerate(ds.events):
        last_idx[u] = idx
    held_set = set(last_idx.values())
    train = ds.events[[i for i in range(len(ds.events)) if i not in held_set]]
    held = {int(ds.events[idx][0]): int(ds.events[idx][1])
            for idx in held_set}
    return train, held


def _event_rows_and_pairs(ds, events, features: str):
    """One context row per event, built from the user's state BEFORE it."""
    hist: Dict[int, List[int]] = defaultdict(list)
    rows, pairs = [], []
    for u, i, t in events:
        rows.append(_row_from_state(ds, u, hist[u]))
        pairs.append((len(rows) - 1, i))
        hist[u].append(i)
    return rows, np.asarray(pairs), hist


def run_offline(ds=None, quick=False, seed=0) -> Dict[str, Dict[str, float]]:
    ds = ds or make_implicit_dataset(seed=seed)
    train, held = split_offline(ds)
    users = sorted(held)
    truth = [[held[u]] for u in users]
    results = {}

    pop = popularity_scores(train, ds.n_items)
    results["popularity"] = _metrics(np.tile(pop, (len(users), 1)), truth)

    cov = coview_matrix(train, ds.n_items)
    state_hist: Dict[int, List[int]] = defaultdict(list)
    for u, i, t in train:
        state_hist[u].append(i)
    cov_scores = np.stack([
        cov[state_hist[u][-1]] if state_hist[u] else pop for u in users
    ])
    cov_scores = cov_scores + 1e-9 * pop  # popularity tiebreak
    results["coview"] = _metrics(cov_scores, truth)

    params_mf, _ = train_icd_mf(ds, train, epochs=6 if quick else 20, seed=seed)
    w, h = np.asarray(params_mf.w), np.asarray(params_mf.h)
    results["icd-mf"] = _metrics(w[users] @ h.T, truth)

    epochs = 5 if quick else 25
    for feats, label in (("A", "icd-fm A"), ("P", "icd-fm P"),
                         ("APU", "icd-fm A+P+U")):
        rows, pairs, _ = _event_rows_and_pairs(ds, train, feats)
        design = build_ctx_design(ds, rows, feats)
        params_fm, hp_fm, item_design = train_icd_fm(
            ds, design, pairs, len(rows), epochs=epochs, seed=seed)
        eval_rows = [_row_from_state(ds, u, state_hist[u]) for u in users]
        eval_design = build_ctx_design(ds, eval_rows, feats)
        scores = fm_eval_scores(ds, params_fm, hp_fm, eval_design, item_design)
        results[label] = _metrics(scores, truth)
    return results


def run_instant(ds=None, quick=False, seed=0, cutoff_frac=0.8):
    ds = ds or make_implicit_dataset(seed=seed)
    cutoff = int(cutoff_frac * len(ds.events))
    train, future = ds.events[:cutoff], ds.events[cutoff:]
    results = {}

    pop = popularity_scores(train, ds.n_items)

    # evaluate EVERY post-cutoff event; features update, params frozen
    hist: Dict[int, List[int]] = defaultdict(list)
    for u, i, t in train:
        hist[u].append(i)

    eval_states, truth = [], []
    run_hist = {u: list(v) for u, v in hist.items()}
    for u, i, t in future:
        eval_states.append((u, list(run_hist.get(u, []))))
        truth.append([int(i)])
        run_hist.setdefault(u, []).append(i)
    if quick:
        eval_states, truth = eval_states[:400], truth[:400]

    results["popularity"] = _metrics(
        np.tile(pop, (len(truth), 1)), truth)

    epochs = 5 if quick else 25
    for feats, label in (("A", "icd-fm A"), ("P", "icd-fm P"),
                         ("H", "icd-fm H"), ("APH", "icd-fm A+P+H")):
        rows, pairs, _ = _event_rows_and_pairs(ds, train, feats)
        design = build_ctx_design(ds, rows, feats)
        params_fm, hp_fm, item_design = train_icd_fm(
            ds, design, pairs, len(rows), epochs=epochs, seed=seed)
        eval_rows = [_row_from_state(ds, u, h) for u, h in eval_states]
        eval_design = build_ctx_design(ds, eval_rows, feats)
        scores = fm_eval_scores(ds, params_fm, hp_fm, eval_design, item_design)
        results[label] = _metrics(scores, truth)
    return results


def _metrics(scores: np.ndarray, truth) -> Dict[str, float]:
    r, n = recall_ndcg_multi(scores, truth, K_EVAL)
    return {"recall@100": r, "ndcg@100": n}


def relative_to_popularity(results: Dict[str, Dict[str, float]]):
    base = results["popularity"]
    return {
        name: {m: (v[m] / base[m] if base[m] > 0 else float("inf"))
               for m in v}
        for name, v in results.items()
    }


# ---------------------------------------------------------------------------
# experiments grid: model × confidence × context
# ---------------------------------------------------------------------------
# The grid trains every (model, confidence) cell on ONE MovieLens-class log
# (loaded through data/loader.load_movielens → the same parse path a real
# u.data file takes), evaluates each cell with the streaming ranking
# harness (eval/ranking.ranking_eval), and hard-gates:
#   * weighted_parity — weights=None is bit-identical to weights=1 and
#     weights=w equals training on premultiplied α (the Lemma-1 fold);
#   * frequency confidence (Hu et al.) beats the uniform MF baseline;
#   * the context-aware mode (ctxmf: GFF seasonal buckets) beats it too.
# Results land in results/experiments/grid.json (via benchmarks.run) and a
# ``quality`` section of the tracked BENCH_cd_sweep.json.

K_GRID = 10
GRID_PERIOD = 16  # events per season bucket in the planted log


def make_grid_log(path: str, n_users=48, n_items=64, n_buckets=4, n_groups=4,
                  events_per_user=40, p_noise=0.35, seed=0) -> str:
    """Write a ``u.data``-style ratings file with PLANTED frequency and
    seasonal structure, so the grid's gates test mechanisms the data is
    known to contain (the §6 functions above play the same game with
    attribute/sequence signal):

      * taste groups — each user repeatedly consumes a SMALL in-group item
        pool (repeat counts carry signal → frequency confidence helps),
        plus one-off uniform noise events (which it should discount);
      * seasons — the global clock cycles through ``n_buckets`` buckets
        (``GRID_PERIOD`` events each); in-pool items are strongly preferred
        while their own season bucket is active (bucket-at-query-time
        carries signal → the ctxmf context mode helps).
    """
    rng = np.random.default_rng(seed)
    item_group = rng.integers(0, n_groups, n_items)
    item_bucket = rng.integers(0, n_buckets, n_items)
    user_group = rng.integers(0, n_groups, n_users)
    total = n_users * events_per_user
    lines = []
    for t in range(total):
        u = int(rng.integers(0, n_users))
        bucket = (t // GRID_PERIOD) % n_buckets
        if rng.random() < p_noise:
            i = int(rng.integers(0, n_items))          # one-off noise
        else:
            pool = np.flatnonzero(
                (item_group == user_group[u]) & (item_bucket == bucket)
            )
            if pool.size == 0:
                pool = np.flatnonzero(item_group == user_group[u])
            i = int(rng.choice(pool))                  # small pool → repeats
        lines.append(f"{u}\t{i}\t1\t{t}\n")
    import os

    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        f.writelines(lines)
    return path


def _grid_weighted_parity(train_log) -> Dict[str, bool]:
    """Hard gate: the weighted program collapses correctly at w=1 (bit-
    identical to w=None) and at general w (equal to premultiplying α)."""
    from repro.core.models import ctxmf
    from repro.data.loader import frequency_interactions

    out = {}
    data, weights, _ = frequency_interactions(train_log, alpha0=0.5)
    hp = mf.MFHyperParams(k=6, alpha0=0.5, l2=0.05)
    params = mf.init(jax.random.PRNGKey(0), train_log.n_users,
                     train_log.n_items, 6)
    e = mf.residuals(params, data)
    ones = jax.numpy.ones(data.nnz, jax.numpy.float32)
    p_none, _ = mf.epoch(params, data, e, hp)
    p_ones, _ = mf.epoch(params, data, e, hp, None, 0, ones)
    out["mf_ones_bitequal_none"] = all(
        bool(np.array_equal(np.asarray(getattr(p_ones, f)),
                            np.asarray(getattr(p_none, f))))
        for f in params._fields
    )
    w = jax.numpy.asarray(weights)
    data_pre = dataclasses.replace(data, alpha=data.alpha * w)
    p_w, _ = mf.epoch(params, data, e, hp, None, 0, w)
    p_pre, _ = mf.epoch(params, data_pre, e, hp)
    out["mf_weighted_equals_premultiplied"] = all(
        bool(np.array_equal(np.asarray(getattr(p_w, f)),
                            np.asarray(getattr(p_pre, f))))
        for f in params._fields
    )

    bucket = ctxmf.seasonal_buckets(
        train_log.t, 4, period=float(4 * GRID_PERIOD))
    tc, pair = ctxmf.build_context(train_log.user, bucket,
                                   train_log.n_users, 4)
    from repro.data.loader import ImplicitLog

    pair_log = ImplicitLog(user=pair, item=train_log.item,
                           value=train_log.value, t=train_log.t,
                           n_users=int(tc.c1.shape[0]),
                           n_items=train_log.n_items)
    cdata, cweights, _ = frequency_interactions(pair_log, alpha0=0.5)
    chp = ctxmf.CtxMFHyperParams(k=6, alpha0=0.5, l2=0.05)
    cparams = ctxmf.init(jax.random.PRNGKey(1), tc.n_c1, tc.n_c2,
                         train_log.n_items, 6)
    ce = ctxmf.residuals(cparams, tc, cdata)
    cones = jax.numpy.ones(cdata.nnz, jax.numpy.float32)
    c_none, _ = ctxmf.epoch(cparams, tc, cdata, ce, chp)
    c_ones, _ = ctxmf.epoch(cparams, tc, cdata, ce, chp, None, 0, cones)
    out["ctxmf_ones_bitequal_none"] = all(
        bool(np.array_equal(np.asarray(getattr(c_ones, f)),
                            np.asarray(getattr(c_none, f))))
        for f in cparams._fields
    )
    out["ok"] = all(out.values())
    assert out["ok"], f"weighted parity gate FAILED: {out}"
    return out


def run_grid(quick: bool = True, seed: int = 0,
             out_path: str = None) -> Dict[str, object]:
    """Train the model × confidence (× context) grid and gate quality.

    Cells: ``mf``/``ctxmf`` × ``uniform``/``freq`` confidence. ``mf`` is
    context-blind; ``ctxmf`` queries with the seasonal bucket active at
    each test event's timestamp. Evaluation: time-cutoff holdout, streamed
    full-catalogue Recall@K / NDCG@K per held-out event."""
    import os

    from repro.core.models import ctxmf
    from repro.data.loader import (
        ImplicitLog, frequency_interactions, load_movielens, split_by_time,
    )
    from repro.eval.ranking import ranking_eval

    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if out_path is None:
        out_path = os.path.join(
            repo_root,
            "BENCH_cd_sweep.json" if quick else "BENCH_cd_sweep_full.json",
        )
    n_users, n_items = (48, 64) if quick else (96, 128)
    n_buckets, alpha0, k = 4, 0.5, 8
    epochs = 8 if quick else 16
    grid_file = os.path.join(repo_root, "results", "experiments",
                             "grid_events.data")
    make_grid_log(grid_file, n_users=n_users, n_items=n_items,
                  n_buckets=n_buckets, seed=seed)
    log = load_movielens(grid_file)   # the real parse path
    train, test = split_by_time(log, holdout_fraction=0.25)

    parity = _grid_weighted_parity(train)

    # shared training tensors; ONE phase origin for train and test buckets
    # (anchoring each window to its own t.min() would shift the test ids)
    data, weights, _ = frequency_interactions(train, alpha0=alpha0)
    t0 = float(log.t.min())
    test_bucket = ctxmf.seasonal_buckets(
        test.t, n_buckets, period=float(n_buckets * GRID_PERIOD), t0=t0)
    train_bucket = ctxmf.seasonal_buckets(
        train.t, n_buckets, period=float(n_buckets * GRID_PERIOD), t0=t0)
    tc, pair = ctxmf.build_context(train.user, train_bucket,
                                   train.n_users, n_buckets)
    pair_log = ImplicitLog(user=pair, item=train.item, value=train.value,
                           t=train.t, n_users=int(tc.c1.shape[0]),
                           n_items=train.n_items)
    cdata, cweights, _ = frequency_interactions(pair_log, alpha0=alpha0)

    cells: Dict[str, Dict[str, float]] = {}
    for model_name in ("mf", "ctxmf"):
        for conf in ("uniform", "freq"):
            if model_name == "mf":
                hp = mf.MFHyperParams(k=k, alpha0=alpha0, l2=0.05)
                params = mf.init(jax.random.PRNGKey(seed), log.n_users,
                                 log.n_items, k)
                params = mf.fit(
                    params, data, hp, epochs,
                    weights=(jax.numpy.asarray(weights)
                             if conf == "freq" else None),
                )
                phi = mf.build_phi(params, jax.numpy.asarray(test.user))
                psi = mf.export_psi(params)
            else:
                chp = ctxmf.CtxMFHyperParams(k=k, alpha0=alpha0, l2=0.05)
                params = ctxmf.init(jax.random.PRNGKey(seed), tc.n_c1,
                                    tc.n_c2, log.n_items, k)
                params = ctxmf.fit(
                    params, tc, cdata, chp, epochs,
                    weights=(jax.numpy.asarray(cweights)
                             if conf == "freq" else None),
                )
                phi = ctxmf.build_phi(params,
                                      jax.numpy.asarray(test.user),
                                      jax.numpy.asarray(test_bucket))
                psi = ctxmf.export_psi(params)
            res = ranking_eval(phi, psi, jax.numpy.asarray(test.item),
                               k=K_GRID)
            cells[f"{model_name}/{conf}"] = {
                f"recall@{K_GRID}": res[f"recall@{K_GRID}"],
                f"ndcg@{K_GRID}": res[f"ndcg@{K_GRID}"],
            }

    rk = f"recall@{K_GRID}"
    base = cells["mf/uniform"][rk]
    quality = {
        "cells": cells,
        "table": grid_table(cells),
        "weighted_parity": parity,
        "uniform_mf_recall": base,
        "freq_gain": cells["mf/freq"][rk] / max(base, 1e-9),
        "ctx_gain": cells["ctxmf/uniform"][rk] / max(base, 1e-9),
        "recall_floor": 0.15,
        "n_eval": test.n_events,
        "target": (
            "weighted_parity all-bitequal; frequency confidence AND the "
            "ctxmf context mode each beat the uniform MF baseline on "
            f"{rk}; baseline above the floor"
        ),
    }
    quality["met"] = bool(
        parity["ok"]
        and cells["mf/freq"][rk] > base
        and cells["ctxmf/uniform"][rk] > base
        and base >= quality["recall_floor"]
    )
    assert quality["met"], f"experiments grid quality gate FAILED: {quality}"

    doc = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            doc = json.load(f)
    doc["quality"] = quality
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=1)
    return quality


def grid_table(cells: Dict[str, Dict[str, float]]) -> str:
    """Markdown Recall/NDCG table for results/experiments + EXPERIMENTS.md."""
    rk, nk = f"recall@{K_GRID}", f"ndcg@{K_GRID}"
    lines = [f"| model | confidence | {rk} | {nk} |", "|---|---|---|---|"]
    for name in sorted(cells):
        model_name, conf = name.split("/")
        lines.append(f"| {model_name} | {conf} | {cells[name][rk]:.4f} "
                     f"| {cells[name][nk]:.4f} |")
    return "\n".join(lines)
