"""(architecture × input-shape) cell builders for the multi-pod dry-run.

A cell packages everything ``dryrun.py`` needs to lower+compile one entry of
the assignment matrix: a step closure, abstract inputs (ShapeDtypeStruct —
never allocated), and in/out PartitionSpec trees for the given mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_shapes
from repro.launch import sharding as sh
from repro.launch.mesh import dp_axes
from repro.optim import adamw
from repro.train.train_step import build_train_step, init_state


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    step_fn: Callable
    abstract_args: Tuple[Any, ...]
    in_specs: Tuple[Any, ...]
    out_specs: Any
    skip: Optional[str] = None
    notes: str = ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _pad512(n: int) -> int:
    """Round up to a multiple of 512 so a dim shards on every production
    mesh. The data pipeline pads with sentinels (dummy candidate ids /
    self-edges at a dummy node) that the losses mask out."""
    return -(-n // 512) * 512


def _abstract(fn, *args, **kwargs):
    return jax.eval_shape(fn, *args, **kwargs)


# ===========================================================================
# LM cells
# ===========================================================================
def _lm_cell(arch: str, shape_spec, mesh, cfg_override=None, probe=False) -> Cell:
    from repro.models import transformer as T

    cfg = cfg_override or get_config(arch)
    dp = dp_axes(mesh)
    b, s = shape_spec.global_batch, shape_spec.seq_len

    params_abs = _abstract(lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    p_specs = sh.lm_param_specs(cfg, params_abs)

    if shape_spec.kind == "train":
        opt = adamw(1e-4)
        step = build_train_step(
            lambda p, batch: T.loss_fn(cfg, p, batch["tokens"], batch["targets"]),
            opt, num_microbatches=cfg.num_microbatches,
            unroll_microbatches=probe,
        )
        state_abs = _abstract(lambda: init_state(
            T.init_params(jax.random.PRNGKey(0), cfg), opt))
        batch_abs = {"tokens": _sds((b, s), jnp.int32),
                     "targets": _sds((b, s), jnp.int32)}
        st_specs = sh.train_state_specs(p_specs)
        return Cell(
            arch, shape_spec.name, "train", step,
            (state_abs, batch_abs),
            (st_specs, sh.lm_batch_specs(mesh)),
            (st_specs, {"loss": P(), "grad_norm": P()}),
        )

    if shape_spec.kind == "prefill":
        def step(params, tokens):
            return T.forward(cfg, params, tokens, last_only=True)[0]

        return Cell(
            arch, shape_spec.name, "prefill", step,
            (params_abs, _sds((b, s), jnp.int32)),
            (p_specs, P(dp, None)),
            P(dp, None, "model"),
        )

    # decode: one new token against a seq_len KV cache
    long_ctx = s >= 100_000
    cache_abs = _abstract(lambda: T.init_cache(cfg, b, s))
    c_specs = sh.lm_cache_specs(cfg, cache_abs, mesh,
                                shard_seq_over_dp=long_ctx)
    tok_abs = _sds((b, 1), jnp.int32)
    pos_abs = _sds((), jnp.int32)
    tok_spec = P(None, None) if long_ctx else P(dp, None)

    def step(params, cache, tok, pos):
        return T.decode_step(cfg, params, cache, tok, pos)

    logits_spec = P(None, None, "model") if long_ctx else P(dp, None, "model")
    return Cell(
        arch, shape_spec.name, "decode", step,
        (params_abs, cache_abs, tok_abs, pos_abs),
        (p_specs, c_specs, tok_spec, P()),
        (logits_spec, c_specs),
        skip=shape_spec.skip,
        notes="rolling local cache bounds half the layers" if
              cfg.local_global_alternating else "",
    )


# ===========================================================================
# recsys cells
# ===========================================================================
def _recsys_module(cfg):
    from repro.models import bst, dcn, din, dlrm

    return {"dlrm": dlrm, "dcn": dcn, "din": din, "bst": bst}[cfg.kind]


def _recsys_batch_abs(cfg, b):
    if cfg.kind in ("dlrm", "dcn"):
        return {
            "dense": _sds((b, cfg.n_dense), jnp.float32),
            "sparse": _sds((b, cfg.n_sparse), jnp.int32),
            "label": _sds((b,), jnp.float32),
        }
    return {
        "hist": _sds((b, cfg.seq_len), jnp.int32),
        "mask": _sds((b, cfg.seq_len), jnp.float32),
        "target": _sds((b,), jnp.int32),
        "label": _sds((b,), jnp.float32),
    }


def _recsys_cell(arch: str, shape_spec, mesh) -> Cell:
    cfg = get_config(arch)
    mod = _recsys_module(cfg)
    dp = dp_axes(mesh)
    b = shape_spec.global_batch

    params_abs = _abstract(lambda: mod.init_params(jax.random.PRNGKey(0), cfg))
    p_specs = sh.recsys_param_specs(cfg, params_abs)

    if shape_spec.kind == "train":
        opt = adamw(1e-3)
        step = build_train_step(lambda p, batch: mod.loss_fn(cfg, p, batch), opt)
        state_abs = _abstract(lambda: init_state(
            mod.init_params(jax.random.PRNGKey(0), cfg), opt))
        st_specs = sh.train_state_specs(p_specs)
        return Cell(
            arch, shape_spec.name, "train", step,
            (state_abs, _recsys_batch_abs(cfg, b)),
            (st_specs, sh.recsys_batch_specs(cfg, mesh)),
            (st_specs, {"loss": P(), "grad_norm": P()}),
        )

    if shape_spec.kind == "serve":
        def step(params, batch):
            if cfg.kind in ("dlrm", "dcn"):
                return mod.forward(cfg, params, batch["dense"], batch["sparse"])
            return mod.forward(cfg, params, batch["hist"], batch["mask"],
                               batch["target"])

        batch_abs = _recsys_batch_abs(cfg, b)
        batch_abs.pop("label")
        batch_specs = sh.recsys_batch_specs(cfg, mesh)
        batch_specs.pop("label")
        return Cell(
            arch, shape_spec.name, "serve", step,
            (params_abs, batch_abs), (p_specs, batch_specs), P(dp),
        )

    # retrieval: 1 context vs n_candidates
    n_cand = _pad512(shape_spec.extra("n_candidates"))
    cand_axes = dp + ("model",)
    if cfg.kind in ("dlrm", "dcn"):
        args_abs = (
            params_abs,
            _sds((1, cfg.n_dense), jnp.float32),
            _sds((1, cfg.n_sparse), jnp.int32),
            _sds((n_cand,), jnp.int32),
        )
        in_specs = (p_specs, P(None, None), P(None, None), P(cand_axes))

        def step(params, dense, user_sparse, cand):
            return mod.score_candidates(cfg, params, dense, user_sparse, cand)
    else:
        args_abs = (
            params_abs,
            _sds((1, cfg.seq_len), jnp.int32),
            _sds((1, cfg.seq_len), jnp.float32),
            _sds((n_cand,), jnp.int32),
        )
        in_specs = (p_specs, P(None, None), P(None, None), P(cand_axes))

        def step(params, hist, mask, cand):
            return mod.score_candidates(cfg, params, hist, mask, cand)

    return Cell(
        arch, shape_spec.name, "retrieval", step, args_abs, in_specs,
        P(cand_axes),
    )


# ===========================================================================
# GNN cells
# ===========================================================================
def _gnn_cell(arch: str, shape_spec, mesh) -> Cell:
    from repro.models import graphsage as G

    cfg = get_config(arch)
    dp = dp_axes(mesh)
    all_axes = dp + ("model",)
    mode = shape_spec.extra("mode")
    d_feat = shape_spec.extra("d_feat")
    opt = adamw(1e-3)

    params_abs = _abstract(lambda: G.init_params(jax.random.PRNGKey(0), cfg, d_feat))
    p_specs = sh.gnn_param_specs(params_abs)
    state_abs = _abstract(lambda: init_state(
        G.init_params(jax.random.PRNGKey(0), cfg, d_feat), opt))
    st_specs = sh.train_state_specs(p_specs)

    if mode == "full":
        # +1 dummy node absorbs the sentinel padding edges; e padded to 512
        n = shape_spec.extra("n_nodes") + 1
        e = _pad512(shape_spec.extra("n_edges"))

        def loss(p, batch):
            logits, _ = G.forward_full(cfg, p, batch["feats"], batch["edges"])
            return G.ce_loss(logits, batch["labels"], batch["mask"])

        batch_abs = {
            "feats": _sds((n, d_feat), jnp.float32),
            "edges": _sds((e, 2), jnp.int32),
            "labels": _sds((n,), jnp.int32),
            "mask": _sds((n,), jnp.float32),
        }
        batch_specs = {"feats": P(None, None), "edges": P(all_axes, None),
                       "labels": P(None), "mask": P(None)}
        notes = "edges sharded over all axes; node states all-reduced"
    elif mode == "minibatch":
        bn = shape_spec.extra("batch_nodes")
        fanout = shape_spec.extra("fanout")
        n_nodes = shape_spec.extra("n_nodes")
        sizes = [bn]
        for f in fanout:
            sizes.append(sizes[-1] * f)

        def loss(p, batch):
            feats = [jnp.take(batch["table"], idx, axis=0)
                     for idx in batch["frontiers"]]
            logits, _ = G.forward_minibatch(cfg, p, feats)
            return G.ce_loss(logits, batch["labels"])

        batch_abs = {
            "table": _sds((n_nodes, d_feat), jnp.float32),
            "frontiers": [_sds((sz,), jnp.int32) for sz in sizes],
            "labels": _sds((bn,), jnp.int32),
        }
        batch_specs = {"table": P(None, None),
                       "frontiers": [P(dp) for _ in sizes],
                       "labels": P(dp)}
        notes = "host-side neighbor sampler feeds frontier indices"
    else:  # batched molecules
        bsz = shape_spec.extra("batch")
        n = shape_spec.extra("n_nodes")

        def loss(p, batch):
            logits, _ = G.forward_batched(cfg, p, batch["feats"], batch["adj"])
            return G.ce_loss(logits, batch["labels"])

        batch_abs = {
            "feats": _sds((bsz, n, d_feat), jnp.float32),
            "adj": _sds((bsz, n, n), jnp.float32),
            "labels": _sds((bsz,), jnp.int32),
        }
        batch_specs = {"feats": P(dp, None, None), "adj": P(dp, None, None),
                       "labels": P(dp)}
        notes = ""

    step = build_train_step(loss, opt)
    return Cell(
        arch, shape_spec.name, "train", step,
        (state_abs, batch_abs), (st_specs, batch_specs),
        (st_specs, {"loss": P(), "grad_norm": P()}),
        notes=notes,
    )


# ===========================================================================
# iCD cells — the paper's own model at production scale
# ===========================================================================
def _icd_cell(arch: str, shape_spec, mesh) -> Cell:
    from repro.core.models import mf
    from repro.sparse.interactions import Interactions

    cfg = get_config(arch)
    dp = dp_axes(mesh)

    if shape_spec.kind == "retrieval":
        n_cand = shape_spec.extra("n_candidates")
        bq = shape_spec.global_batch

        def step(w_users, h_items):
            scores = w_users @ h_items.T
            vals, idx = jax.lax.top_k(scores, 100)
            return vals, idx

        return Cell(
            arch, shape_spec.name, "retrieval", step,
            (_sds((bq, cfg.k), jnp.float32), _sds((n_cand, cfg.k), jnp.float32)),
            (P(dp, None), P("model", None)),
            (P(dp, None), P(dp, None)),
            notes="paper-native separable retrieval: one matvec per query",
        )

    n_ctx = shape_spec.extra("n_ctx")
    n_items = shape_spec.extra("n_items")
    nnz = shape_spec.extra("nnz")
    # unroll=True: exact HLO cost accounting (XLA counts while bodies once)
    # and better cross-column pipelining on TPU
    hp = mf.MFHyperParams(k=cfg.k, alpha0=cfg.alpha0, l2=cfg.l2, unroll=True)

    params_abs = mf.MFParams(
        w=_sds((n_ctx, cfg.k), jnp.float32),
        h=_sds((n_items, cfg.k), jnp.float32),
    )
    data_abs = Interactions(
        ctx=_sds((nnz,), jnp.int32), item=_sds((nnz,), jnp.int32),
        y=_sds((nnz,), jnp.float32), alpha=_sds((nnz,), jnp.float32),
        t_ctx=_sds((nnz,), jnp.int32), t_item=_sds((nnz,), jnp.int32),
        t_perm=_sds((nnz,), jnp.int32),
        n_ctx=n_ctx, n_items=n_items,
    )
    e_abs = _sds((nnz,), jnp.float32)

    p_specs, d_spec_dict = sh.icd_mf_specs(mesh)
    data_specs = Interactions(
        ctx=d_spec_dict["ctx"], item=d_spec_dict["item"], y=d_spec_dict["y"],
        alpha=d_spec_dict["alpha"], t_ctx=d_spec_dict["t_ctx"],
        t_item=d_spec_dict["t_item"], t_perm=d_spec_dict["t_perm"],
        n_ctx=n_ctx, n_items=n_items,
    )

    def step(params, data, e):
        return mf.epoch(params, data, e, hp)

    return Cell(
        arch, shape_spec.name, "train", step,
        (params_abs, data_abs, e_abs),
        (p_specs, data_specs, P(dp)),
        (p_specs, P(dp)),
        notes="one full iCD epoch; cross-shard traffic = k² Gram all-reduce",
    )


# ===========================================================================
# registry
# ===========================================================================
# The seed-template LM/RecSys/GNN configs were removed in PR 4 (unrelated
# to this paper); the cell builders above stay generic, but only the iCD
# archs are registered.
LM_ARCHS = ()
RECSYS_ARCHS = ()
GNN_ARCHS = ()
ICD_ARCHS = ("icd-mf",)


def all_cell_ids(include_icd: bool = True):
    out = []
    for arch in LM_ARCHS + GNN_ARCHS + RECSYS_ARCHS + (ICD_ARCHS if include_icd else ()):
        for shape_name in get_shapes(arch):
            out.append((arch, shape_name))
    return out


def build_cell(arch: str, shape_name: str, mesh, cfg_override=None,
               probe: bool = False, shape_override=None) -> Cell:
    shape_spec = shape_override or get_shapes(arch)[shape_name]
    if arch in LM_ARCHS:
        return _lm_cell(arch, shape_spec, mesh, cfg_override, probe)
    if arch in RECSYS_ARCHS:
        return _recsys_cell(arch, shape_spec, mesh)
    if arch in GNN_ARCHS:
        return _gnn_cell(arch, shape_spec, mesh)
    if arch in ICD_ARCHS or arch.startswith("icd"):
        return _icd_cell(arch, shape_spec, mesh)
    raise KeyError(arch)
