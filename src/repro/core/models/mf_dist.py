"""Explicitly-distributed iCD-MF (shard_map) — the paper's complexity bound
realized on a pod.

The naive pjit epoch (repro/launch/cells.py, baseline in EXPERIMENTS.md
§Roofline) lets GSPMD guess: it all-gathers observation arrays and
all-reduces full context-sized segment outputs, making the epoch
collective-bound. But Lemma 2/3 say the ONLY cross-shard state iCD needs is

  * the k×k Gram of the opposite side           → one k² psum per sweep
  * the opposite side's current column ψ_f / w_f → one column all-gather
  * residuals re-grouped ctx-major ↔ item-major → one nnz all-to-all

Everything else (segment reductions, Newton steps, residual patches) is
LOCAL once contexts, items and their observations are partitioned by owner.

Layout (built host-side by ``shard_interactions``): contexts are
range-partitioned over the D shards and so are items; each shard stores its
ctx-major observation block, its item-major observation block, and the
routing indices that move the residual cache between the two groupings with
one ``lax.all_to_all``. All blocks are padded to uniform size (α=0 padding).

Per-epoch wire traffic per device (C contexts, I items, nnz observations):
  2·k² (Grams) + k·(C+I)·4B (column all-gathers) + 2·(nnz/D)·4B (routing)
— compare GSPMD baseline: see EXPERIMENTS.md §Perf hillclimb #1.

The per-shard f*-loops route through ``core.sweeps.sweep_columns`` with the
same Newton body as ``mf._side_sweep`` (``sweeps.newton_delta`` — incl. the
denominator clamp that keeps l2=0 empty contexts finite); only the
opposite-column delivery (all-gather / all-to-all route) is distributed.
Parity vs ``mf.epoch`` is pinned by tests/test_mf_dist.py.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sweeps
from repro.core.models.mf import MFHyperParams, MFParams
from repro.sparse.interactions import Interactions


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShardedMF:
    """Per-shard blocks; every array has leading dim D (the shard axis)."""

    # ctx-major observations (D, p_c): local ctx row, global item, targets
    ctx_l: jax.Array
    item_g: jax.Array
    y_c: jax.Array
    alpha_c: jax.Array
    # item-major observations (D, p_i)
    item_l: jax.Array
    ctx_g: jax.Array
    y_i: jax.Array
    alpha_i: jax.Array
    # routing: ctx-major → item-major residual exchange
    send_idx: jax.Array   # (D, D, blk) positions into ctx-major block, -1 pad
    recv_pos: jax.Array   # (D, D, blk) positions into item-major block, -1 pad
    c_per: int = dataclasses.field(metadata=dict(static=True))
    i_per: int = dataclasses.field(metadata=dict(static=True))
    n_shards: int = dataclasses.field(metadata=dict(static=True))


def shard_interactions(data: Interactions, n_shards: int,
                       weights=None) -> ShardedMF:
    """Host-side partitioner: range-partition contexts and items, pad blocks,
    precompute the all-to-all routing.

    ``weights`` (optional, (nnz,) ctx-major) folds per-interaction
    confidence into both blocked α layouts exactly (α is purely
    multiplicative in the explicit loss parts); padding stays α=0."""
    d = n_shards
    c_per = -(-data.n_ctx // d)
    i_per = -(-data.n_items // d)
    ctx = np.asarray(data.ctx)
    item = np.asarray(data.item)
    y = np.asarray(data.y)
    alpha = np.asarray(data.alpha)
    if weights is not None:
        alpha = alpha * np.asarray(weights, alpha.dtype)
    nnz = len(ctx)
    ctx_shard = ctx // c_per
    item_shard = item // i_per

    # --- ctx-major blocks -------------------------------------------------
    order_c = np.lexsort((item, ctx))  # already sorted, but be safe
    by_c = [order_c[ctx_shard[order_c] == s] for s in range(d)]
    p_c = max(1, max(len(b) for b in by_c))
    ctx_l = np.zeros((d, p_c), np.int32)
    item_g = np.zeros((d, p_c), np.int32)
    y_c = np.zeros((d, p_c), np.float32)
    alpha_c = np.zeros((d, p_c), np.float32)
    pos_in_ctx_block = np.empty(nnz, np.int64)
    for s, idx in enumerate(by_c):
        n = len(idx)
        ctx_l[s, :n] = ctx[idx] - s * c_per
        item_g[s, :n] = item[idx]
        y_c[s, :n] = y[idx]
        alpha_c[s, :n] = alpha[idx]
        pos_in_ctx_block[idx] = np.arange(n)

    # --- item-major blocks ------------------------------------------------
    order_i = np.lexsort((ctx, item))
    by_i = [order_i[item_shard[order_i] == s] for s in range(d)]
    p_i = max(1, max(len(b) for b in by_i))
    item_l = np.zeros((d, p_i), np.int32)
    ctx_g = np.zeros((d, p_i), np.int32)
    y_i = np.zeros((d, p_i), np.float32)
    alpha_i = np.zeros((d, p_i), np.float32)
    pos_in_item_block = np.empty(nnz, np.int64)
    for s, idx in enumerate(by_i):
        n = len(idx)
        item_l[s, :n] = item[idx] - s * i_per
        ctx_g[s, :n] = ctx[idx]
        y_i[s, :n] = y[idx]
        alpha_i[s, :n] = alpha[idx]
        pos_in_item_block[idx] = np.arange(n)

    # --- routing ctx-shard → item-shard ------------------------------------
    counts = np.zeros((d, d), np.int64)
    for j in range(nnz):
        counts[ctx_shard[j], item_shard[j]] += 1
    blk = max(1, int(counts.max()))
    send_idx = -np.ones((d, d, blk), np.int64)
    recv_pos = -np.ones((d, d, blk), np.int64)
    fill = np.zeros((d, d), np.int64)
    for j in range(nnz):
        cs, its = ctx_shard[j], item_shard[j]
        slot = fill[cs, its]
        send_idx[cs, its, slot] = pos_in_ctx_block[j]
        # receiver `its` sees this entry in its block from source `cs`
        recv_pos[its, cs, slot] = pos_in_item_block[j]
        fill[cs, its] = slot + 1

    return ShardedMF(
        ctx_l=jnp.asarray(ctx_l), item_g=jnp.asarray(item_g),
        y_c=jnp.asarray(y_c), alpha_c=jnp.asarray(alpha_c),
        item_l=jnp.asarray(item_l), ctx_g=jnp.asarray(ctx_g),
        y_i=jnp.asarray(y_i), alpha_i=jnp.asarray(alpha_i),
        send_idx=jnp.asarray(send_idx, jnp.int32),
        recv_pos=jnp.asarray(recv_pos, jnp.int32),
        c_per=c_per, i_per=i_per, n_shards=d,
    )


def shard_params(params: MFParams, sd: ShardedMF) -> MFParams:
    """Pad + block the factor matrices to (D, rows_per_shard, k)."""
    d, k = sd.n_shards, params.w.shape[1]
    w = jnp.zeros((d * sd.c_per, k), params.w.dtype).at[: params.w.shape[0]].set(params.w)
    h = jnp.zeros((d * sd.i_per, k), params.h.dtype).at[: params.h.shape[0]].set(params.h)
    return MFParams(w=w.reshape(d, sd.c_per, k), h=h.reshape(d, sd.i_per, k))


def unshard_params(params: MFParams, n_ctx: int, n_items: int) -> MFParams:
    k = params.w.shape[-1]
    return MFParams(
        w=params.w.reshape(-1, k)[:n_ctx], h=params.h.reshape(-1, k)[:n_items]
    )


def _route(e_src, src_idx, dst_pos, p_dest, axis_name):
    """Move per-observation values between groupings with one all_to_all.
    src_idx (D, blk): positions in e_src per destination shard; dst_pos
    (D, blk): where each received value lands locally (-1 = padding)."""
    send = jnp.where(src_idx >= 0, jnp.take(e_src, jnp.maximum(src_idx, 0)), 0.0)
    recv = jax.lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0,
                              tiled=True)
    flat_pos = dst_pos.reshape(-1)
    flat_val = recv.reshape(-1)
    out = jnp.zeros((p_dest,), e_src.dtype)
    return out.at[jnp.maximum(flat_pos, 0)].add(
        jnp.where(flat_pos >= 0, flat_val, 0.0))


def make_shard_mesh(n_shards: int):
    """One flat shard axis over all chips — the optimized iCD layout (the
    hillclimb's alternative to the baseline (data, model) GSPMD layout)."""
    return jax.make_mesh((n_shards,), ("shards",))


def build_epoch(mesh, hp: MFHyperParams, sd_template: ShardedMF,
                variant: str = "gather", wire_dtype=jnp.float32):
    """Returns a jitted shard_map epoch over the flat shard axis.

    variant:
      'gather' — iteration 1: the opposite column is ALL-GATHERED per dim
                 (wire/device per sweep: k·rows_other·4B).
      'route'  — iteration 2: the owner shard evaluates its column at the
                 observations and ROUTES per-nnz values (all_to_all) —
                 k·(nnz/D) values instead of k·rows_other; wins whenever
                 nnz/D ≪ opposite-side rows (epoch_web: 5.1×).
    wire_dtype — iteration 3: bf16 on the wire for routed/gathered values
                 (Newton math stays fp32; quantizing ψ/φ inputs only).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axes = mesh.axis_names[0]

    def epoch_shard(w_loc, h_loc, sd: ShardedMF, e_loc):
        # leading shard dim is 1 inside shard_map → squeeze
        w_loc = w_loc[0]
        h_loc = h_loc[0]
        e_loc = e_loc[0]
        blkof = lambda a: a[0]
        ctx_l, item_g = blkof(sd.ctx_l), blkof(sd.item_g)
        alpha_c = blkof(sd.alpha_c)
        item_l, ctx_g = blkof(sd.item_l), blkof(sd.ctx_g)
        alpha_i = blkof(sd.alpha_i)
        send_idx, recv_pos = blkof(sd.send_idx), blkof(sd.recv_pos)

        k = w_loc.shape[1]

        def gram_psum(m):
            mf32 = m.astype(jnp.float32)
            return jax.lax.psum(mf32.T @ mf32, axes)

        def opposite_vals(side_col, local_rows_of_entries, out_idx, in_idx,
                          p_dest):
            """ψ/φ of the opposite column at MY observations.

            'gather': all-gather the column, take at global ids (caller
            passes global ids as local_rows_of_entries with the gathered
            column). 'route': evaluate locally on the owner side at its
            entries and all_to_all per-nnz values into place."""
            vals_owner = jnp.take(side_col, local_rows_of_entries)
            return _route(vals_owner.astype(wire_dtype), out_idx, in_idx,
                          p_dest, axes).astype(jnp.float32)

        def side_sweep(side_m, other_m, j_o, rows_l, alpha_l, e_l, n_per,
                       opp_global, opp_local, out_idx, in_idx):
            """One side's k-column sweep through ``sweeps.sweep_columns``:
            the same per-column Newton body as ``mf._side_sweep`` (incl. the
            ``newton_delta`` denominator clamp), with the opposite column
            delivered over the wire per dimension."""

            def body(f, carry):
                side_m, e = carry
                o_col = sweeps.take_col(other_m, f)
                if variant == "gather":
                    col = jax.lax.all_gather(
                        o_col.astype(wire_dtype), axes, tiled=True
                    ).astype(jnp.float32)
                    o_vals = jnp.take(col, opp_global)
                else:  # owners evaluate at their entries, route per-nnz
                    o_vals = opposite_vals(o_col, opp_local, out_idx, in_idx,
                                           alpha_l.shape[0])
                s_col = sweeps.take_col(side_m, f)
                lp = jax.ops.segment_sum(alpha_l * e * o_vals, rows_l, n_per)
                lpp = jax.ops.segment_sum(alpha_l * o_vals * o_vals, rows_l,
                                          n_per)
                rp = side_m @ sweeps.take_col(j_o, f)
                rpp = jnp.take(sweeps.take_col(j_o, f), f)
                delta = sweeps.newton_delta(
                    sweeps.NewtonParts(lp + hp.alpha0 * rp,
                                       lpp + hp.alpha0 * rpp),
                    s_col, hp.l2, hp.eta,
                )
                e = e + jnp.take(delta, rows_l) * o_vals
                return sweeps.put_col(side_m, f, s_col + delta), e

            return sweeps.sweep_columns(k, body, (side_m, e_l),
                                        unroll=hp.unroll)

        # ---------------- context sweep ----------------
        j_i = gram_psum(h_loc)
        w_loc, e_loc = side_sweep(
            w_loc, h_loc, j_i, ctx_l, alpha_c, e_loc, sd.c_per,
            item_g, item_l, recv_pos, send_idx,
        )

        # ---------------- residuals: ctx-major → item-major ----------------
        e_item = _route(e_loc, send_idx, recv_pos, alpha_i.shape[0], axes)

        # ---------------- item sweep ----------------
        j_c = gram_psum(w_loc)
        h_loc, e_item = side_sweep(
            h_loc, w_loc, j_c, item_l, alpha_i, e_item, sd.i_per,
            ctx_g, ctx_l, send_idx, recv_pos,
        )

        # ---------------- residuals back ----------------
        e_loc = _route(e_item, recv_pos, send_idx, alpha_c.shape[0], axes)

        return w_loc[None], h_loc[None], e_loc[None]

    specs = P(axes)
    sd_specs = ShardedMF(
        ctx_l=specs, item_g=specs, y_c=specs, alpha_c=specs,
        item_l=specs, ctx_g=specs, y_i=specs, alpha_i=specs,
        send_idx=specs, recv_pos=specs,
        c_per=sd_template.c_per, i_per=sd_template.i_per,
        n_shards=sd_template.n_shards,
    )
    try:
        fn = shard_map(
            epoch_shard, mesh=mesh,
            in_specs=(specs, specs, sd_specs, specs),
            out_specs=(specs, specs, specs),
            check_vma=False,
        )
    except TypeError:  # older jax spells it check_rep
        fn = shard_map(
            epoch_shard, mesh=mesh,
            in_specs=(specs, specs, sd_specs, specs),
            out_specs=(specs, specs, specs),
            check_rep=False,
        )
    return jax.jit(fn)


def residuals_blocked(params_blocked: MFParams, sd: ShardedMF) -> jax.Array:
    """Initial ctx-major residual blocks (D, p_c): ŷ − ȳ (α=0 padding)."""
    d, _, k = params_blocked.w.shape
    h_flat = params_blocked.h.reshape(-1, k)
    w = params_blocked.w                     # (D, c_per, k)
    scores = jnp.einsum(
        "dpk,dpk->dp",
        jnp.take_along_axis(w, sd.ctx_l[..., None], axis=1),
        jnp.take(h_flat, sd.item_g, axis=0),
    )
    return scores - sd.y_c
