"""Shared test helper: tiny (φ, ψ) exports for every k-separable model —
one implementation in ``repro.core.models.zoo``, re-exported for the test
suites (the serve bench imports it from the package directly)."""
from repro.core.models.zoo import (  # noqa: F401
    ZOO,
    model_phi_psi,
    rand_f32 as _rand,
)
