"""Jit'd public wrapper for the gram kernel."""
from functools import partial

import jax

from repro.kernels import use_interpret
from repro.kernels.gram.kernel import gram_pallas


@partial(jax.jit, static_argnames=("block_rows",))
def gram(x: jax.Array, block_rows: int = 1024) -> jax.Array:
    return gram_pallas(x, block_rows=block_rows, interpret=use_interpret())
