"""Roofline table builder: joins the dry-run JSONs with analytic
MODEL_FLOPS (6·N·D for dense LM training / 6·N_active·D for MoE; forward
variants use the 2·N·D factor) and emits the EXPERIMENTS.md §Roofline table.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, Optional

import jax

from repro.configs import get_config, get_shapes
from repro.launch.hlo_analysis import HBM_BW, LINK_BW, PEAK_FLOPS


def _lm_param_counts(cfg) -> Dict[str, float]:
    """total and ACTIVE parameter counts (active: MoE experts scaled by
    top_k/n_experts; embeddings excluded from the 6ND rule-of-thumb)."""
    d, v = cfg.d_model, cfg.vocab
    attn = cfg.n_layers * (
        d * cfg.q_dim * 2 + d * cfg.kv_dim * 2
    )
    embed = v * d * (1 if cfg.tie_embeddings else 2)
    if cfg.moe is None:
        ffn_total = ffn_active = cfg.n_layers * 3 * d * cfg.d_ff
    else:
        m = cfg.moe
        n_moe = cfg.n_layers - m.first_k_dense
        dense = m.first_k_dense * 3 * d * m.d_ff_dense
        shared = n_moe * 3 * d * (m.n_shared * m.d_expert)
        routed_total = n_moe * m.n_experts * 3 * d * m.d_expert
        routed_active = n_moe * m.top_k * 3 * d * m.d_expert
        ffn_total = dense + shared + routed_total
        ffn_active = dense + shared + routed_active
    return {
        "total": attn + ffn_total + embed,
        "active": attn + ffn_active,      # matmul-active, sans embedding
        "embed": embed,
    }


def model_flops(arch: str, shape_name: str, chips: int) -> Optional[float]:
    """Per-device useful model FLOPs for one step of this cell."""
    shape = get_shapes(arch)[shape_name]
    cfg = get_config(arch)
    if arch.startswith(("gemma", "qwen", "deepseek", "olmoe")):
        counts = _lm_param_counts(cfg)
        n_act = counts["active"]
        vocab_flops_tok = 2 * cfg.d_model * cfg.vocab
        # causal attention: qk + av over an average context of S/2
        #   fwd per token = 2 dots × 2 MACs × (S/2) × h × hd = 2·S·h·hd
        attn_fwd_tok = 2 * shape.seq_len * cfg.n_heads * cfg.head_dim * cfg.n_layers
        if shape.kind == "train":
            tokens = shape.global_batch * shape.seq_len
            per_tok = 6 * n_act + 3 * vocab_flops_tok + 3 * attn_fwd_tok
        elif shape.kind == "prefill":
            tokens = shape.global_batch * shape.seq_len
            per_tok = 2 * n_act + attn_fwd_tok + vocab_flops_tok / shape.seq_len
        else:  # decode: one token per sequence + KV-cache attention reads
            tokens = shape.global_batch
            kv_flops = 4 * cfg.n_layers * shape.seq_len * cfg.n_heads * cfg.head_dim
            per_tok = 2 * n_act + vocab_flops_tok + kv_flops
        return tokens * per_tok / chips
    if arch == "graphsage-reddit":
        d_feat = shape.extra("d_feat")
        d = cfg.d_hidden
        if shape.extra("mode") == "full":
            n, e = shape.extra("n_nodes"), shape.extra("n_edges")
            fwd = 2 * (n * (d_feat + d) * d * 2 + e * (d_feat + d))
        elif shape.extra("mode") == "minibatch":
            bn = shape.extra("batch_nodes")
            f1, f2 = shape.extra("fanout")
            rows = bn * (1 + f1 + f1 * f2)
            fwd = 2 * rows * (d_feat + d) * d * 2
        else:
            fwd = 2 * shape.extra("batch") * shape.extra("n_nodes") * (
                shape.extra("d_feat") + d) * d * 2
        return 3 * fwd / chips  # fwd + bwd
    if arch in ("dlrm-rm2", "dcn-v2", "din", "bst"):
        b = shape.global_batch if shape.kind != "retrieval" else shape.extra("n_candidates")
        mlp_params = {
            "dlrm": 13 * 512 + 512 * 256 + 256 * 64 + 415 * 512 + 512 * 512 + 512 * 256 + 256,
            "dcn": 3 * 429 * 429 + 429 * 1024 + 1024 * 1024 + 1024 * 512 + 512,
            "din": 72 * 80 + 80 * 40 + 40 + 36 * 200 + 200 * 80 + 80,
            "bst": 4 * 32 * 32 + 2 * 32 * 128 + 21 * 32 * 1024 + 1024 * 512 + 512 * 256 + 256,
        }[cfg.kind]
        factor = 3 if shape.kind == "train" else 1
        return factor * 2 * b * mlp_params / chips
    if arch.startswith("icd"):
        if shape.kind == "retrieval":
            return 2 * shape.global_batch * shape.extra("n_candidates") * cfg.k / chips
        c, i = shape.extra("n_ctx"), shape.extra("n_items")
        nnz = shape.extra("nnz")
        k = cfg.k
        return 2.0 * (k * k * (c + i) + 6 * k * nnz) / chips
    return None


def load_table(dryrun_dir: str = "results/dryrun"):
    rows = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        r = json.load(open(f))
        chips = r.get("chips", 256)
        row = {
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": r["status"],
        }
        if r["status"] == "ok":
            ro = r["roofline"]
            mf_ = model_flops(r["arch"], r["shape"], chips)
            row.update(
                dominant=ro["dominant"],
                compute_s=ro["compute_s"], memory_s=ro["memory_s"],
                collective_s=ro["collective_s"],
                roofline_fraction=ro["roofline_fraction"],
                hlo_flops=ro["flops_per_device"],
                model_flops=mf_,
                useful_ratio=(mf_ / ro["flops_per_device"])
                if mf_ and ro["flops_per_device"] else None,
            )
        elif r["status"] == "skipped":
            row["skip_reason"] = r["skip_reason"]
        else:
            row["error"] = r.get("error", "")[:120]
        rows.append(row)
    return rows


def markdown_table(rows, mesh="16x16") -> str:
    lines = [
        "| arch | shape | dominant | compute s | memory s | collective s | "
        "roofline frac | MODEL/HLO flops |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | SKIP | — | — | — | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | ERROR | — | — | — | — | — |")
            continue
        ur = f"{r['useful_ratio']:.2f}" if r.get("useful_ratio") else "—"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['dominant']} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | {r['roofline_fraction']:.3f} | {ur} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    rows = load_table()
    print(markdown_table(rows))
