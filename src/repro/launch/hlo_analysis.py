"""HLO post-processing: collective-byte accounting + roofline terms.

``compiled.cost_analysis()`` reports per-device FLOPs and bytes for the SPMD
module, but no collective traffic — we parse ``compiled.as_text()`` and sum
operand sizes of every all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute.

Wire-cost model per op (ring algorithms, per-device bytes):
  all-reduce       2 × payload        (reduce-scatter + all-gather phases)
  all-gather       1 × result bytes
  reduce-scatter   1 × operand bytes
  all-to-all       1 × payload
  collective-permute 1 × payload
where payload = the largest tensor in the op line (per-device SPMD shapes).

Roofline terms (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
  compute    = device_flops / peak_flops
  memory     = device_bytes / hbm_bw
  collective = device_collective_bytes / link_bw
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-collective-kind wire bytes (per device) from an SPMD HLO dump."""
    out = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        head = stripped.split("metadata=")[0]
        # op instructions look like: %x = f32[...] all-reduce(%y), ...
        kind = None
        for k in _COLLECTIVES:
            if f" {k}(" in head or f" {k}-start(" in head:
                kind = k
                break
        if kind is None:
            continue
        shapes = _SHAPE_RE.findall(head)
        if not shapes:
            continue
        payload = max(_shape_bytes(dt, dims) for dt, dims in shapes)
        mult = 2.0 if kind == "all-reduce" else 1.0
        if kind == "all-to-all":
            # HLO prints the per-peer SLICE shape; per-device wire bytes are
            # slice × group size (the op exchanges one slice with every peer)
            mult = float(_group_size(stripped))
        out[kind] += mult * payload
        counts[kind] += 1
    out["_counts"] = counts
    return out


def _group_size(line: str) -> int:
    """Replica group size from 'replica_groups={{0,1,..}},..' or
    'replica_groups=[G,N]<=[...]' (G groups of N)."""
    m = re.search(r"replica_groups=\[\d+,(\d+)\]", line)
    if m:
        return int(m.group(1))
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", line)
    if m:
        return m.group(1).count(",") + 1
    return 1


@dataclasses.dataclass
class Roofline:
    flops: float              # per device
    bytes_accessed: float     # per device
    coll_bytes: float         # per device (wire model above)
    coll_breakdown: Dict[str, float]
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def fraction_of_roofline(self) -> float:
        """How much of the bound time is the compute term — 1.0 means pure
        compute-bound (ideal); lower means memory/collective dominate."""
        return self.compute_s / max(self.bound_s, 1e-30)

    def to_dict(self):
        return {
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes_accessed,
            "collective_bytes_per_device": self.coll_bytes,
            "collective_breakdown": self.coll_breakdown,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "roofline_fraction": self.fraction_of_roofline(),
        }


def normalize_cost_analysis(ca) -> Dict[str, float]:
    """``Compiled.cost_analysis()`` returns a dict on current jax but a
    one-dict-per-computation list on older releases; normalize to a dict."""
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def roofline_from_compiled(compiled) -> Roofline:
    ca = normalize_cost_analysis(compiled.cost_analysis())
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    cb = collective_bytes(compiled.as_text())
    counts = cb.pop("_counts")
    total_coll = sum(cb.values())
    return Roofline(
        flops=flops,
        bytes_accessed=bytes_accessed,
        coll_bytes=total_coll,
        coll_breakdown={**cb, "counts": counts},
        compute_s=flops / PEAK_FLOPS,
        memory_s=bytes_accessed / HBM_BW,
        collective_s=total_coll / LINK_BW,
    )


def memory_stats(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    return {
        "argument_bytes": float(ma.argument_size_in_bytes),
        "output_bytes": float(ma.output_size_in_bytes),
        "temp_bytes": float(ma.temp_size_in_bytes),
        "alias_bytes": float(ma.alias_size_in_bytes),
        "peak_hbm_estimate": float(
            ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes
        ),
    }
