"""Sharded retrieval cluster: bit-exact parity with the single-device
engine and the dense oracle at every shard count, cross-shard merge edges,
live publish/refresh, and the shard_map execution path."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _zoo import ZOO, model_phi_psi, _rand

from repro.core.models import mf
from repro.kernels import vmem
from repro.kernels.topk_score import topk_score_ref
from repro.serve.cluster import (
    ShardedRetrievalCluster,
    cluster_topk,
    resolve_cluster_block_items,
    shard_psi,
)
from repro.serve.engine import (
    RetrievalEngine,
    exclude_ids_from_lists,
    exclude_mask_from_lists,
)


@pytest.mark.parametrize("n_shards", [1, 2, 3, 4])
def test_cluster_bit_identical_to_engine_any_shard_count(n_shards):
    """The acceptance criterion: ids AND scores bit-identical to the
    single-device engine and the dense lax.top_k oracle, with and without
    exclusion, at shard counts that do and don't divide n_items (101)."""
    rng = np.random.default_rng(0)
    phi, psi = _rand((9, 16), 1), _rand((101, 16), 2)
    engine = RetrievalEngine(psi, lambda p=phi: p, k=13, block_items=32)
    cl = ShardedRetrievalCluster(
        lambda p=phi: p, n_shards=n_shards, k=13, block_items=32,
        psi_table=psi,
    )
    es, ei = engine.topk()
    cs, ci = cl.topk()
    np.testing.assert_array_equal(np.asarray(ci), np.asarray(ei))
    assert bool((np.asarray(cs) == np.asarray(es)).all())  # BIT-identical
    ds, di = jax.lax.top_k(phi @ psi.T, 13)
    np.testing.assert_array_equal(np.asarray(ci), np.asarray(di))

    lists = [rng.choice(101, size=int(rng.integers(0, 8)), replace=False)
             for _ in range(9)]
    mask = exclude_mask_from_lists(lists, 101)
    eids = exclude_ids_from_lists(lists)
    es2, ei2 = engine.topk(exclude_mask=mask)
    for kwargs in (dict(exclude_mask=mask), dict(exclude_ids=eids)):
        cs2, ci2 = cl.topk(**kwargs)
        np.testing.assert_array_equal(np.asarray(ci2), np.asarray(ei2))
        assert bool((np.asarray(cs2) == np.asarray(es2)).all())


@pytest.mark.parametrize("name", ZOO)
def test_cluster_parity_all_models(name):
    """Every k-separable model through its export contract, sharded 3 ways
    (37 items ⇒ non-divisible), vs the dense oracle."""
    rng = np.random.default_rng(42)
    phi, psi = model_phi_psi(name, rng)
    cl = ShardedRetrievalCluster(
        lambda p=phi: p, n_shards=3, k=12, block_items=32, psi_table=psi
    )
    s, i = cl.topk()
    rs, ri = topk_score_ref(phi, psi, 12)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(s), np.asarray(rs), rtol=1e-5,
                               atol=1e-6)
    lists = [rng.choice(psi.shape[0], size=5, replace=False)
             for _ in range(phi.shape[0])]
    s2, i2 = cl.topk(exclude_ids=exclude_ids_from_lists(lists))
    rs2, ri2 = topk_score_ref(
        phi, psi, 12, exclude_mask_from_lists(lists, psi.shape[0])
    )
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(ri2))


def test_k_larger_than_one_shards_item_count():
    """K exceeding rows_per: every shard returns its whole range and the
    merge still ranks the global catalogue exactly."""
    phi, psi = _rand((4, 8), 3), _rand((10, 8), 4)
    table = shard_psi(psi, 3)  # rows_per=4 < K
    assert table.rows_per < 7
    s, i = cluster_topk(table, phi, 7, block_items=32)
    rs, ri = topk_score_ref(phi, psi, 7)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    # K even beyond n_items: inadmissible tail is (−inf, −1)
    s2, i2 = cluster_topk(table, phi, 15, block_items=32)
    assert bool((np.asarray(i2)[:, 10:] == -1).all())
    assert bool(np.isneginf(np.asarray(s2)[:, 10:]).all())


def test_global_tie_stability_across_shard_boundaries():
    """Duplicated ψ rows land in DIFFERENT shards ⇒ exact cross-shard score
    ties; the merged ranking must still be ascending-global-id."""
    base = _rand((30, 6), 5)
    psi = jnp.concatenate([base, base], axis=0)  # ids i and i+30 tie
    phi = _rand((5, 6), 6)
    rs, ri = topk_score_ref(phi, psi, 25)
    for n_shards in (2, 3, 4):  # boundaries split the tie pairs differently
        table = shard_psi(psi, n_shards)
        s, i = cluster_topk(table, phi, 25, block_items=32)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


def test_fully_excluded_shard_returns_neginf_slots():
    """A shard whose whole row range is excluded contributes only
    (−inf, −1) candidates; the merge must fill from the other shards and
    a fully-excluded CATALOGUE row must come back all (−inf, −1)."""
    phi, psi = _rand((3, 8), 7), _rand((24, 8), 8)
    table = shard_psi(psi, 3)  # shard 1 owns ids [8, 16)
    lists = [np.arange(8, 16), np.arange(8, 16), np.arange(24)]
    eids = exclude_ids_from_lists(lists)
    s, i = cluster_topk(table, phi, 24, exclude_ids=eids, block_items=32)
    got_i, got_s = np.asarray(i), np.asarray(s)
    # rows 0/1: shard 1's ids never appear; 16 admissible slots then −inf
    for r in (0, 1):
        real = got_i[r][got_i[r] >= 0]
        assert real.size == 16 and not np.isin(real, np.arange(8, 16)).any()
    # row 2: everything excluded — no id leaks at all
    assert bool((got_i[2] == -1).all()) and bool(np.isneginf(got_s[2]).all())
    rs, ri = topk_score_ref(
        phi, psi, 24, exclude_mask_from_lists(lists, 24)
    )
    np.testing.assert_array_equal(got_i, np.asarray(ri))


def test_publish_versioning_and_live_refresh():
    """fit(callback=PsiPublisher) refreshes the serving table per epoch:
    version bumps, results track the LATEST params, and a snapshot grabbed
    pre-publish still serves the old table (double buffer)."""
    from repro.serve.publish import PsiPublisher
    from repro.sparse.interactions import build_interactions

    rng = np.random.default_rng(9)
    n_ctx, n_items, k = 30, 50, 6
    params = mf.init(jax.random.PRNGKey(0), n_ctx, n_items, k)
    cl = ShardedRetrievalCluster(
        lambda ctx: mf.build_phi(params, ctx), n_shards=2, k=10,
        block_items=32,
    )
    with pytest.raises(RuntimeError, match="publish"):
        _ = cl.table  # serving before any publish must fail loudly
    pub = PsiPublisher(cl, mf.export_psi, every=1)

    nnz = 200
    cells = rng.choice(n_ctx * n_items, nnz, replace=False)
    data = build_interactions(
        cells // n_items, cells % n_items, rng.integers(1, 4, nnz),
        1.0 + rng.random(nnz), n_ctx, n_items, alpha0=0.3,
    )
    hp = mf.MFHyperParams(k=k, alpha0=0.3, l2=0.05)
    fitted = mf.fit(params, data, hp, n_epochs=2, callback=pub)
    assert [v for _, v in pub.versions] == [1, 2]
    assert cl.version == 2

    # the live table is epoch-2's ψ: cluster == fresh engine on the export
    phi = mf.build_phi(fitted, jnp.arange(8))
    engine = RetrievalEngine(mf.export_psi(fitted),
                             lambda ctx: mf.build_phi(fitted, ctx),
                             k=10, block_items=32)
    cs, ci = cl.topk_phi(phi)
    es, ei = engine.topk_phi(phi)
    np.testing.assert_array_equal(np.asarray(ci), np.asarray(ei))
    assert bool((np.asarray(cs) == np.asarray(es)).all())

    # double buffer: a snapshot held across a publish keeps serving v2
    old_table = cl.table
    cl.publish(jnp.zeros((n_items, k)))  # v3: degenerate table
    assert cl.version == 3 and old_table.version == 2
    s_old, i_old = cluster_topk(old_table, phi, 10, block_items=32)
    np.testing.assert_array_equal(np.asarray(i_old), np.asarray(ei))


def test_cluster_block_items_resolution_raises_not_shrinks(monkeypatch):
    """The merge scratch (S·K rows) busting the budget must surface as
    VmemBudgetError from the cluster's resolution — never a silent tile
    below one ψ block."""
    phi, psi = _rand((8, 16), 10), _rand((64, 16), 11)
    table = shard_psi(psi, 4)
    monkeypatch.setattr(vmem, "VMEM_BUDGET_BYTES", 200_000)
    with pytest.raises(vmem.VmemBudgetError):
        resolve_cluster_block_items(table, b=8, k=1024)
    with pytest.raises(vmem.VmemBudgetError):
        cluster_topk(table, phi, 1024)
    # an explicit block_items pin (the operator override) still works
    s, i = cluster_topk(table, phi, 8, block_items=128)
    rs, ri = topk_score_ref(phi, psi, 8)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


SHARD_MAP_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ["REPRO_PALLAS_INTERPRET"] = "1"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np

    from repro.kernels.topk_score import topk_score_ref
    from repro.serve.cluster import shard_map_topk, shard_psi
    from repro.serve.engine import exclude_ids_from_lists

    rng = np.random.default_rng(0)
    phi = jnp.asarray(rng.normal(size=(9, 16)), jnp.float32)
    psi = jnp.asarray(rng.normal(size=(101, 16)), jnp.float32)
    table = shard_psi(psi, 4, devices=jax.devices())
    mesh = jax.make_mesh((4,), ("shards",))
    s, i = shard_map_topk(mesh, table, phi, 13, block_items=32)
    rs, ri = topk_score_ref(phi, psi, 13)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    assert (np.asarray(s) == np.asarray(rs)).all()
    lists = [rng.choice(101, size=6, replace=False) for _ in range(9)]
    eids = exclude_ids_from_lists(lists)
    s2, i2 = shard_map_topk(mesh, table, phi, 13, exclude_ids=eids,
                            block_items=32)
    rs2, ri2 = topk_score_ref(phi, psi, 13, exclude_ids=eids)
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(ri2))
    print("SHARD-MAP-TOPK-OK")
    """
)


@pytest.mark.slow
def test_shard_map_path_matches_oracle():
    """One shard_map over 4 forced host devices == the dense oracle (the
    pod-scale execution path; offsets from lax.axis_index)."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", SHARD_MAP_SCRIPT],
        capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(__file__)),
        env={**env, "PYTHONPATH": "src"}, timeout=600,
    )
    assert "SHARD-MAP-TOPK-OK" in proc.stdout, (
        proc.stdout[-2000:] + proc.stderr[-3000:]
    )


def test_multi_device_placement_single_host():
    """devices= places shards round-robin (degenerate single-device here —
    the placement plumbing must still be parity-clean)."""
    phi, psi = _rand((5, 8), 12), _rand((40, 8), 13)
    cl = ShardedRetrievalCluster(
        lambda p=phi: p, n_shards=3, k=9, block_items=32,
        devices=jax.devices(), psi_table=psi,
    )
    s, i = cl.topk()
    rs, ri = topk_score_ref(phi, psi, 9)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
