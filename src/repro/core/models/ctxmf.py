"""Context-aware MF: seasonal/session context as an extra k-separable mode.

Hidasi & Tikk's *General Factorization Framework* (GFF) observes that any
context dimension can join a factorization model as one more k-separable
mode. This module realizes their seasonality-style "MF + context" scenario
on top of the paper's CD framework:

    ŷ(u, c, i) = Σ_f u_{u,f} · s_{c,f} · w_{i,f}

with user factors U, context-bucket factors S (one row per season/session
bucket), and item factors W — which is EXACTLY the PARAFAC tensor model
with ``(c1, c2) = (user, bucket)``. Every sweep therefore delegates to
:mod:`repro.core.models.parafac` unchanged: the flat path, and the fused
padded path whose context-mode sweeps run the ``cd_block_sweep_rowpatch``
kernel (per-row R'/R'' patch tensors — the context mode's regularizer
coupling is row-dependent, eqs. 37–38). Fused-vs-flat parity on ctxmf
instances is pinned by ``tests/test_ctxmf.py``.

What this module adds on top of the delegation is the GFF plumbing that
makes the mode reachable from a raw implicit event log ``(user, item, t)``:

  * :func:`seasonal_buckets` / :func:`session_buckets` — derive the context
    bucket id per event from timestamps (phase within a season period, or
    gap-split session index capped to a bucket vocabulary);
  * :func:`build_context` — dedupe ``(user, bucket)`` pairs into the
    :class:`~repro.core.models.parafac.TensorContext` pair list plus the
    per-event pair index that ``Interactions.ctx`` expects.

Serving contract: ``export_psi`` is the item table W; a query address is a
``(user_ids, bucket_ids)`` pair and ``build_phi`` returns φ = U[u] ⊙ S[c],
so context-aware retrieval rides the existing engine unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import numpy as np

from repro.core.models import parafac
from repro.core.models.parafac import (  # re-exported: the delegation surface
    PARAFACParams as CtxMFParams,
    TensorContext,
    epoch,
    epoch_padded,
    pad_tensor_groups,
    residuals,
)

__all__ = ["CtxMFParams", "CtxMFHyperParams", "TensorContext",
           "seasonal_buckets", "session_buckets", "build_context", "init",
           "phi", "export_psi", "build_phi", "predict", "epoch",
           "epoch_padded", "pad_tensor_groups", "residuals", "objective",
           "fit"]


@dataclasses.dataclass(frozen=True)
class CtxMFHyperParams(parafac.PARAFACHyperParams):
    """PARAFAC hyperparams under the context-mode reading: ``dense_context``
    keeps its eq.-39 meaning (regularizer universe = users × buckets, the
    right default when every user can appear in every season)."""


def seasonal_buckets(t, n_buckets: int, period: float | None = None,
                     t0: float | None = None) -> np.ndarray:
    """Seasonal context bucket per event: the phase of ``t`` within
    ``period`` (default: the observed time span) quantized to
    ``n_buckets`` — GFF's seasonality dimension (hour-of-day, day-of-week,
    ... depending on the period chosen).

    ``t0`` is the phase origin; it defaults to ``t.min()`` of THIS call.
    When bucketing disjoint windows of one log (train vs a later test
    split), pass the same explicit ``t0`` to both calls — otherwise each
    window's phase is anchored to its own start and the bucket ids
    disagree."""
    t = np.asarray(t, np.float64)
    if t.size == 0:
        return np.zeros(0, np.int32)
    if t0 is None:
        t0 = float(t.min())
    if period is None:
        period = max(1.0, float(t.max() - t0 + 1))
    phase = np.mod(t - t0, period) / period
    return np.minimum((phase * n_buckets).astype(np.int32), n_buckets - 1)


def session_buckets(t, gap: float, n_buckets: int) -> np.ndarray:
    """Session context bucket per event: split the (sorted-per-caller)
    event times into sessions at gaps > ``gap``; session indices wrap into
    ``n_buckets`` so the bucket vocabulary stays bounded."""
    t = np.asarray(t, np.float64)
    if t.size == 0:
        return np.zeros(0, np.int32)
    order = np.argsort(t, kind="stable")
    new_session = np.r_[True, np.diff(t[order]) > gap]
    sess_sorted = np.cumsum(new_session) - 1
    sess = np.empty(t.size, np.int64)
    sess[order] = sess_sorted
    return (sess % n_buckets).astype(np.int32)


def build_context(
    user, bucket, n_users: int, n_buckets: int
) -> Tuple[TensorContext, np.ndarray]:
    """Dedupe per-event ``(user, bucket)`` into the tensor pair list.

    Returns ``(tc, pair_of_event)``: ``tc`` holds the unique pairs (the
    rows ``Interactions.ctx`` indexes) and ``pair_of_event`` maps each
    event to its pair row. Pairs are lexsorted (user, bucket) so the layout
    is deterministic."""
    user = np.asarray(user, np.int64)
    bucket = np.asarray(bucket, np.int64)
    if user.shape != bucket.shape:
        raise ValueError("user/bucket must have the same shape")
    if user.size and (user.min() < 0 or user.max() >= n_users):
        raise ValueError(f"user ids out of range [0, {n_users})")
    if bucket.size and (bucket.min() < 0 or bucket.max() >= n_buckets):
        raise ValueError(f"bucket ids out of range [0, {n_buckets})")
    key = user * n_buckets + bucket
    uniq, pair_of_event = np.unique(key, return_inverse=True)
    tc = TensorContext(
        c1=jax.numpy.asarray(uniq // n_buckets, jax.numpy.int32),
        c2=jax.numpy.asarray(uniq % n_buckets, jax.numpy.int32),
        n_c1=int(n_users), n_c2=int(n_buckets),
    )
    return tc, pair_of_event.astype(np.int64)


def init(key, n_users: int, n_buckets: int, n_items: int, k: int,
         sigma: float = 0.1) -> CtxMFParams:
    return parafac.init(key, n_users, n_buckets, n_items, k, sigma)


def phi(params: CtxMFParams, tc: TensorContext) -> jax.Array:
    return parafac.phi(params, tc)


def export_psi(params: CtxMFParams) -> jax.Array:
    """ψ table for the retrieval engine: the item factors W (n_items, k)."""
    return parafac.export_psi(params)


def build_phi(params: CtxMFParams, user: jax.Array, bucket: jax.Array) -> jax.Array:
    """φ rows for (user, context-bucket) queries: φ_f = u_{u,f}·s_{c,f}."""
    return parafac.build_phi(params, user, bucket)


def predict(params: CtxMFParams, user, bucket, item) -> jax.Array:
    return parafac.predict(params, user, bucket, item)


def objective(params, tc, data, hp) -> jax.Array:
    return parafac.objective(params, tc, data, hp)


def fit(params, tc, data, hp, n_epochs, callback=None, schedule=None,
        weights=None):
    return parafac.fit(params, tc, data, hp, n_epochs, callback=callback,
                       schedule=schedule, weights=weights)
