"""Production meshes.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the ``pod``
axis composes with ``data`` for batch/context sharding; ``model`` stays
intra-pod so tensor-parallel collectives never cross the slower inter-pod
links, and parameters are replicated across pods (gradient all-reduce is
the only cross-pod collective).

Functions, not module constants: importing this module never touches jax
device state (the dry-run sets the forced host-device count first).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> tuple:
    """The batch/context sharding axes for this mesh."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def n_chips(mesh) -> int:
    return int(mesh.devices.size)
