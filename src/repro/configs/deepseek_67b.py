"""DeepSeek 67B [arXiv:2401.02954; hf] — llama-arch dense, GQA kv=8."""
import dataclasses

from repro.configs.base import LMConfig, lm_shapes

CONFIG = LMConfig(
    name="deepseek-67b",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab=102_400,
    act="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=False,
    num_microbatches=16,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=160, vocab=128, num_microbatches=1,
)

SHAPES = lm_shapes(
    long_context_skip=(
        "pure full attention (95 layers × full 524k KV); long_500k is "
        "assigned to SSM/hybrid/linear-attn archs only (DESIGN.md §4)"
    )
)
