"""Generic padded nnz-grouping for the fused cd_sweep kernels.

``mf_padded`` hard-codes the two groupings MF needs (by context and by
item). The tensor and feature models sweep over OTHER groupings — by c1,
by c2, by item of the pair list — so this module factors the layout out:
a :class:`PaddedGroup` maps the flat observation list onto an
``(n_rows, d_pad)`` grid (one row per group, slots padded to the max group
degree rounded up to the TPU lane width), with α scattered once at build
time (0 on padding ⇒ padded slots are inert in every kernel reduction).

Scatter/gather stay in the ORIGINAL flat nnz order — no ``t_perm``
shuffles; transferring the residual cache between two groupings is
``g2.scatter(g1.gather(e_grid))``.

Pseudo-ψ routing: the fused tensor/field sweeps evaluate per-block
pseudo-ψ values on the FLAT nnz list (``(nnz, m)``) and the kernels need
them laid out per padded slot. Two routes exist:

  * ``flat_ids`` (default) — the precomputed ``(n_rows, d_pad)`` grid of
    flat nnz indices (padding → the sentinel row ``nnz``). The in-kernel
    gather variants of ``kernels/cd_sweep`` consume the flat ``(nnz+1, m)``
    slab (:func:`append_sentinel_row`) + this grid directly, so the
    ``(n_rows, m, d_pad)`` tile never exists in HBM.
  * :meth:`PaddedGroup.scatter_blk` (fallback) — host-side scatter into the
    ``(n_rows, m, d_pad)`` tile for the pre-gathered kernels. This is the
    capacity trade the gather route removes: the tile is ~m× the residual
    grid and must be materialized per block dispatch.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PaddedGroup:
    """One grouping of the flat observation list onto a padded grid."""

    rows: jax.Array       # (nnz,) int32 — group row per observation
    cols: jax.Array       # (nnz,) int32 — slot within the row
    alpha_pad: jax.Array  # (n_rows, d_pad) f32 — confidences, 0 on padding
    flat_ids: jax.Array   # (n_rows, d_pad) int32 — flat nnz index per slot;
    #                       padding slots hold the sentinel nnz (one past the
    #                       last observation — see append_sentinel_row)
    n_rows: int = dataclasses.field(metadata=dict(static=True))
    d_pad: int = dataclasses.field(metadata=dict(static=True))

    def scatter(self, vals: jax.Array, dtype=None) -> jax.Array:
        """Flat per-nnz vector → (n_rows, d_pad) grid (0 on padding)."""
        out = jnp.zeros((self.n_rows, self.d_pad), dtype or vals.dtype)
        return out.at[self.rows, self.cols].set(vals)

    def scatter_blk(self, vals_blk: jax.Array) -> jax.Array:
        """Flat (nnz, m) block → (n_rows, m, d_pad) pseudo-ψ tile.

        Pre-gathered fallback only: this materializes the ~m×-residual-grid
        HBM intermediate that the in-kernel gather route (``flat_ids`` +
        ``kernels/cd_sweep`` ``*_gather`` kernels) avoids."""
        m = vals_blk.shape[1]
        out = jnp.zeros((self.n_rows, self.d_pad, m), vals_blk.dtype)
        out = out.at[self.rows, self.cols, :].set(vals_blk)
        return jnp.moveaxis(out, -1, 1)

    def gather(self, grid: jax.Array) -> jax.Array:
        """(n_rows, d_pad) grid → flat per-nnz vector."""
        return grid[self.rows, self.cols]

    def with_alpha(self, alpha_flat: jax.Array) -> "PaddedGroup":
        """Rebuild the α grid from a flat per-nnz confidence vector (same
        nnz order the group was built with; padding stays 0) — how
        per-interaction weights fold into an existing padded layout without
        a host-side rebuild."""
        return dataclasses.replace(
            self, alpha_pad=self.scatter(alpha_flat, jnp.float32)
        )


def append_sentinel_row(vals_blk: jax.Array) -> jax.Array:
    """Flat (nnz, m) pseudo-ψ block → (nnz+1, m) slab whose last row is the
    zero sentinel ``PaddedGroup.flat_ids`` points padding slots at — the
    gather kernels then reproduce :meth:`PaddedGroup.scatter_blk`'s zeros
    exactly."""
    return jnp.pad(vals_blk, ((0, 1), (0, 0)))


def build_group(
    group_of_nnz, alpha, n_rows: int, lane: int = 128
) -> PaddedGroup:
    """Host-side builder: stable slot assignment per group (first
    occurrence → slot 0), slot dim rounded up to the TPU lane width.

    Vectorized cumcount — stable argsort groups equal rows into runs, the
    slot is the index within the run — so the build is O(nnz log nnz)
    NumPy, not a Python loop over tens of millions of observations."""
    group_of_nnz = np.asarray(group_of_nnz)
    alpha = np.asarray(alpha, np.float32)
    nnz = len(group_of_nnz)
    if nnz:
        order = np.argsort(group_of_nnz, kind="stable")
        sg = group_of_nnz[order]
        new_run = np.r_[True, sg[1:] != sg[:-1]]
        run_starts = np.flatnonzero(new_run)
        slot_sorted = np.arange(nnz) - run_starts[np.cumsum(new_run) - 1]
        slot = np.empty(nnz, np.int64)
        slot[order] = slot_sorted
        max_deg = int(slot_sorted.max()) + 1
    else:
        slot = np.zeros(0, np.int64)
        max_deg = 1
    d_pad = max(lane, int(-(-max(1, max_deg) // lane) * lane))
    alpha_pad = np.zeros((n_rows, d_pad), np.float32)
    alpha_pad[group_of_nnz, slot] = alpha
    flat_ids = np.full((n_rows, d_pad), nnz, np.int32)  # sentinel: zero row
    flat_ids[group_of_nnz, slot] = np.arange(nnz, dtype=np.int32)
    return PaddedGroup(
        rows=jnp.asarray(group_of_nnz, jnp.int32),
        cols=jnp.asarray(slot, jnp.int32),
        alpha_pad=jnp.asarray(alpha_pad),
        flat_ids=jnp.asarray(flat_ids),
        n_rows=int(n_rows),
        d_pad=d_pad,
    )
