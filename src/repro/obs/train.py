"""Training-spine observability: a ``fit(callback=...)`` adapter.

The model ``epoch`` functions are jitted (``static_argnames`` over hp /
schedule), so per-block Python hooks inside ``sweep_columns`` would fire
at trace time only. The host-visible cadence is the epoch boundary —
exactly where every model's ``fit`` already invokes its callback — so
that is where the registry gets fed:

  * ``train_epoch_seconds``        histogram of epoch wall time
                                   (boundary-to-boundary, registry clock)
  * ``train_loss``                 gauge; set when an ``objective`` fn is
                                   given (loss trajectory rides
                                   ``callback.history`` too)
  * ``train_epochs_total``         counter
  * ``train_block_visits_total``   per-``f0`` counter of SweepSchedule
                                   block visits (one side's plan; both
                                   sides sweep the same plan per epoch)
  * ``train_block_seconds_est``    histogram: epoch time / blocks visited
                                   — an ESTIMATE of per-k_b-block cost
                                   (jit hides true per-block times; the
                                   analytic cd_sweep cost below carries
                                   the modelled split)
  * ``kernel_*_total{kernel="cd_sweep"}`` — the analytic cost model
                                   (``obs/costs.py``) recorded per epoch
                                   when ``cd_shape=(C, D_pad, k)`` is
                                   given: 2 sides × the fused sweep bytes

Compose with the existing eval hook::

    cb = compose_callbacks(
        fit_metrics_callback(registry=reg, objective=obj,
                             schedule=sched, n_dims=k, block=k_b),
        model_eval_callback(model, query, truth, k=10),
    )
    model.fit(params, data, n_epochs=8, callback=cb)
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

from repro.obs.costs import KernelCostRecorder
from repro.obs.metrics import next_instance_id, resolve_registry

# epoch timing buckets: interpret-mode epochs run ~ms..minutes
_EPOCH_BUCKETS = (1e-3, 5e-3, 2.5e-2, 0.1, 0.5, 1.0, 2.5, 5.0, 10.0,
                  30.0, 60.0, 300.0)


def compose_callbacks(*callbacks) -> Callable:
    """One ``callback(epoch, params)`` fanning out to several (``None``
    entries skipped) — the glue between this module's metrics callback
    and ``eval.ranking.model_eval_callback``."""
    cbs = [cb for cb in callbacks if cb is not None]

    def composed(epoch: int, params) -> None:
        for cb in cbs:
            cb(epoch, params)

    composed.callbacks = cbs
    return composed


def fit_metrics_callback(
    *,
    registry=None,
    clock: Optional[Callable[[], float]] = None,
    objective: Optional[Callable] = None,
    schedule=None,
    n_dims: Optional[int] = None,
    block: int = 1,
    cd_shape: Optional[Tuple[int, int, int]] = None,
    sides: int = 2,
    labels: Optional[dict] = None,
) -> Callable:
    """Registry-backed ``fit`` callback (see module docstring).

    ``schedule``+``n_dims``+``block`` resolve each epoch's block plan via
    ``SweepSchedule.blocks`` (a pure host-side function of the epoch
    index — the same static plan the jitted epoch traced), feeding the
    block-visit counters. ``cd_shape=(C, D_pad, k)`` opts into the
    analytic cd_sweep cost accounting (``sides`` sweeps per epoch — 2
    for two-sided models like MF). ``objective(params) -> loss`` records
    the loss trajectory. The callback exposes ``history`` —
    ``[(epoch, seconds, loss | None), ...]``."""
    reg = resolve_registry(registry)
    clk = clock if clock is not None else reg.clock
    inst = dict(labels) if labels else {"instance": next_instance_id()}
    lnames = tuple(inst)
    epoch_h = reg.histogram(
        "train_epoch_seconds", "epoch wall time (fit callback cadence)",
        labels=lnames, buckets=_EPOCH_BUCKETS).labels(**inst)
    block_h = reg.histogram(
        "train_block_seconds_est",
        "epoch time / k_b blocks visited (estimate; jit hides true splits)",
        labels=lnames, buckets=_EPOCH_BUCKETS).labels(**inst)
    epochs_c = reg.counter(
        "train_epochs_total", "completed epochs", labels=lnames).labels(**inst)
    loss_g = reg.gauge(
        "train_loss", "objective(params) at the last epoch boundary",
        labels=lnames).labels(**inst)
    visits_f = reg.counter(
        "train_block_visits_total",
        "SweepSchedule k_b-block visits by starting dim f0 (one side)",
        labels=lnames + ("f0",))
    costs = KernelCostRecorder(reg)
    state = {"t": clk()}

    def callback(epoch: int, params) -> None:
        now = clk()
        dt = now - state["t"]
        state["t"] = now
        epoch_h.observe(dt)
        epochs_c.inc()
        plan: Sequence = ()
        if schedule is not None and n_dims:
            plan = schedule.blocks(n_dims, epoch, block)
        elif n_dims:
            plan = tuple(
                (f0, min(block, n_dims - f0))
                for f0 in range(0, n_dims, max(block, 1))
            )
        for f0, _size in plan:
            visits_f.labels(**inst, f0=str(f0)).inc()
        if plan:
            block_h.observe(dt / (sides * len(plan)))
        if cd_shape is not None:
            c_rows, d_pad, k = cd_shape
            costs.record_cd_sweep(
                c_rows, d_pad, k, max(block, 1), sweeps=sides)
        loss = None
        if objective is not None:
            loss = float(objective(params))
            loss_g.set(loss)
        callback.history.append((int(epoch), float(dt), loss))

    callback.history = []
    return callback
