"""Jit'd public wrapper for the gram kernel."""
import jax

from repro.kernels import kernel_jit
from repro.kernels.gram.kernel import gram_pallas


@kernel_jit(static_argnames=("block_rows",))
def gram(x: jax.Array, block_rows: int = 1024, *, interpret=None) -> jax.Array:
    return gram_pallas(x, block_rows=block_rows, interpret=interpret)
