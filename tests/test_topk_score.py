"""Fused score+top-K kernel: oracle parity, edge cases, and the engine
contract across the whole k-separable model zoo."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.design import make_design
from repro.core.models import fm, mf, mfsi, parafac, tucker
from repro.core.models.parafac import TensorContext
from repro.kernels.topk_score import topk_score, topk_score_ref
from repro.serve.engine import RetrievalEngine, exclude_mask_from_lists


def _rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape), jnp.float32)


def test_matches_ref_and_dense_topk_nondivisible_blocks():
    phi, psi = _rand((9, 24), 0), _rand((301, 24), 1)
    s, i = topk_score(phi, psi, 17, block_items=128)  # 301 % 128 != 0
    rs, ri = topk_score_ref(phi, psi, 17)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(s), np.asarray(rs), rtol=1e-6, atol=1e-6)
    ds, di = jax.lax.top_k(phi @ psi.T, 17)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(di))
    np.testing.assert_allclose(np.asarray(s), np.asarray(ds), rtol=1e-6, atol=1e-6)


def test_batch_larger_than_block_b():
    phi, psi = _rand((50, 8), 2), _rand((200, 8), 3)
    s, i = topk_score(phi, psi, 10, block_b=16, block_items=64)
    ds, di = jax.lax.top_k(phi @ psi.T, 10)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(di))
    np.testing.assert_allclose(np.asarray(s), np.asarray(ds), rtol=1e-6, atol=1e-6)


def test_tied_scores_rank_ascending_id():
    # duplicated ψ rows across different blocks ⇒ exact score ties
    base = _rand((40, 6), 4)
    psi = jnp.concatenate([base, base, base], axis=0)  # ids i, i+40, i+80 tie
    phi = _rand((5, 6), 5)
    s, i = topk_score(phi, psi, 30, block_items=64)
    rs, ri = topk_score_ref(phi, psi, 30)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    # dense lax.top_k over the id-ordered row is the documented tie policy
    ds, di = jax.lax.top_k(phi @ psi.T, 30)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(di))


def test_exclude_mask_and_fully_masked_row():
    rng = np.random.default_rng(6)
    phi, psi = _rand((7, 12), 6), _rand((90, 12), 7)
    excl = jnp.asarray(rng.random((7, 90)) < 0.4)
    excl = excl.at[2, :].set(True)  # row 2: nothing admissible
    s, i = topk_score(phi, psi, 12, excl, block_items=32)
    rs, ri = topk_score_ref(phi, psi, 12, excl)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    # excluded ids never leak; fully-masked row is all (−inf, −1)
    assert bool((np.asarray(i)[2] == -1).all())
    assert bool(np.isneginf(np.asarray(s)[2]).all())
    got = np.asarray(i)
    mask = np.asarray(excl)
    for r in range(7):
        real = got[r][got[r] >= 0]
        assert not mask[r, real].any()


def test_k_larger_than_n_items():
    phi, psi = _rand((3, 5), 8), _rand((11, 5), 9)
    s, i = topk_score(phi, psi, 20, block_items=128)
    rs, ri = topk_score_ref(phi, psi, 20)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    assert bool((np.asarray(i)[:, 11:] == -1).all())
    assert bool(np.isneginf(np.asarray(s)[:, 11:]).all())
    # the 11 real slots are the full catalogue, exactly ranked
    ds, di = jax.lax.top_k(phi @ psi.T, 11)
    np.testing.assert_array_equal(np.asarray(i)[:, :11], np.asarray(di))


def _model_phi_psi(name, rng):
    """Tiny instance of each zoo model; returns (phi (B, D), psi (I, D))."""
    n_ctx, n_items, b, k = 20, 37, 9, 6
    if name == "mf":
        params = mf.init(jax.random.PRNGKey(0), n_ctx, n_items, k)
        return mf.build_phi(params, jnp.arange(b)), mf.export_psi(params)
    if name == "parafac":
        params = parafac.init(jax.random.PRNGKey(1), 8, 7, n_items, k)
        c1 = jnp.asarray(rng.integers(0, 8, b), jnp.int32)
        c2 = jnp.asarray(rng.integers(0, 7, b), jnp.int32)
        return parafac.build_phi(params, c1, c2), parafac.export_psi(params)
    if name == "tucker":
        params = tucker.init(jax.random.PRNGKey(2), 8, 7, n_items, 4, 3, k)
        c1 = jnp.asarray(rng.integers(0, 8, b), jnp.int32)
        c2 = jnp.asarray(rng.integers(0, 7, b), jnp.int32)
        return tucker.build_phi(params, c1, c2), tucker.export_psi(params)
    x = make_design(
        [dict(name="id", ids=np.arange(n_ctx) % 11, vocab=11),
         dict(name="grp", ids=rng.integers(0, 5, n_ctx), vocab=5)], n_ctx)
    z = make_design(
        [dict(name="item_id", ids=np.arange(n_items), vocab=n_items),
         dict(name="genre", ids=rng.integers(0, 7, n_items), vocab=7)], n_items)
    if name == "mfsi":
        params = mfsi.init(jax.random.PRNGKey(3), x.p, z.p, k)
        return (mfsi.build_phi(params, x, jnp.arange(b)),
                mfsi.export_psi(params, z))
    hp = fm.FMHyperParams(k=k)
    params = fm.init(jax.random.PRNGKey(4), x.p, z.p, k)
    # break the all-zero linear/bias init so ψ_spec is a real column
    params = params._replace(
        b=jnp.asarray(0.3), w_lin=_rand((x.p,), 10), h_lin=_rand((z.p,), 11)
    )
    return (fm.build_phi(params, x, hp, jnp.arange(b)),
            fm.export_psi(params, z, hp))


@pytest.mark.parametrize("name", ["mf", "mfsi", "fm", "parafac", "tucker"])
def test_streaming_matches_dense_topk_all_models(name):
    """The acceptance check: fused kernel == dense lax.top_k for the zoo,
    with and without an exclude mask, through the RetrievalEngine."""
    rng = np.random.default_rng(42)
    phi, psi = _model_phi_psi(name, rng)
    # model predict ⇔ ⟨φ, ψ⟩ consistency is covered by each model's own
    # tests; here we pin streaming top-k to the dense path over Φ·Ψᵀ
    engine = RetrievalEngine(psi, lambda p=phi: p, k=12, block_items=32)
    s, i = engine.topk()
    ds, di = jax.lax.top_k(engine.scores(phi), 12)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(di))
    np.testing.assert_allclose(np.asarray(s), np.asarray(ds), rtol=1e-5, atol=1e-6)

    excl_lists = [rng.choice(psi.shape[0], size=5, replace=False)
                  for _ in range(phi.shape[0])]
    mask = exclude_mask_from_lists(excl_lists, psi.shape[0])
    s2, i2 = engine.topk(exclude_mask=mask)
    rs2, ri2 = topk_score_ref(phi, psi, 12, mask)
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(ri2))
    got = np.asarray(i2)
    m = np.asarray(mask)
    for r in range(got.shape[0]):
        real = got[r][got[r] >= 0]
        assert not m[r, real].any()
