"""Unified Model protocol (``core/models/api.py``): all five zoo models run
the SAME lifecycle loop — init → objective → fit → epoch → export_psi →
build_phi → fold_in_user/item — with zero per-model signature branches at
the call site; plus engine integration (``RetrievalEngine.from_model``) and
the Dataset.require error contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.design import make_design
from repro.core.models import fm, mf, mfsi, parafac, tucker
from repro.core.models.api import Dataset, Model, build_model
from repro.core.models.parafac import TensorContext
from repro.core.models.zoo import ZOO
from repro.serve.engine import RetrievalEngine
from repro.sparse.interactions import build_interactions

N_CTX, N_ITEMS, K = 10, 12, 4


def _interactions(rng, n_ctx=N_CTX, n_items=N_ITEMS, nnz=40, alpha0=0.3):
    cells = rng.choice(n_ctx * n_items, size=nnz, replace=False)
    return build_interactions(
        cells // n_items, cells % n_items, rng.integers(1, 4, nnz),
        alpha0 + 1.0 + rng.random(nnz), n_ctx, n_items, alpha0=alpha0,
    )


def _model(name):
    """(model, query): one construction recipe per zoo member; everything
    downstream of here is model-agnostic."""
    rng = np.random.default_rng(7)
    data = _interactions(rng)
    if name == "mf":
        return build_model(
            "mf", hp=mf.MFHyperParams(k=K, alpha0=0.3, l2=0.05),
            dataset=Dataset(data=data),
        ), jnp.arange(6)
    if name in ("mfsi", "fm"):
        x = make_design(
            [dict(name="id", ids=np.arange(N_CTX) % 7, vocab=7),
             dict(name="grp", ids=rng.integers(0, 3, N_CTX), vocab=3)], N_CTX)
        z = make_design(
            [dict(name="item_id", ids=np.arange(N_ITEMS), vocab=N_ITEMS),
             dict(name="genre", ids=rng.integers(0, 5, N_ITEMS), vocab=5)],
            N_ITEMS)
        hp = (mfsi.MFSIHyperParams(k=K, alpha0=0.3, l2=0.05) if name == "mfsi"
              else fm.FMHyperParams(k=K, alpha0=0.3, l2=0.05))
        return build_model(
            name, hp=hp, dataset=Dataset(data=data, x=x, z=z)
        ), jnp.arange(6)
    n_c1, n_c2 = 5, 4
    chosen = rng.choice(n_c1 * n_c2, size=N_CTX, replace=False)
    tc = TensorContext(c1=jnp.asarray(chosen // n_c2, jnp.int32),
                       c2=jnp.asarray(chosen % n_c2, jnp.int32),
                       n_c1=n_c1, n_c2=n_c2)
    hp = (parafac.PARAFACHyperParams(k=K, alpha0=0.3, l2=0.05)
          if name == "parafac"
          else tucker.TuckerHyperParams(k1=3, k2=2, k3=K, alpha0=0.3, l2=0.05))
    model = build_model(name, hp=hp, dataset=Dataset(data=data, tc=tc))
    q = (jnp.asarray([0, 1, 2, 3, 0, 1], jnp.int32),
         jnp.asarray([1, 0, 3, 2, 2, 0], jnp.int32))
    return model, q


@pytest.mark.parametrize("name", ZOO)
def test_uniform_lifecycle(name):
    model, query = _model(name)
    assert isinstance(model, Model)          # runtime_checkable protocol
    params = model.init(jax.random.PRNGKey(0))
    obj0 = float(model.objective(params))
    params = model.fit(params, n_epochs=3)
    assert float(model.objective(params)) < obj0
    # manual epoch with residual cache: same surface on every model
    e = model.residuals(params)
    params2, e2 = model.epoch(params, e)
    assert float(model.objective(params2)) <= float(model.objective(params))
    psi = model.export_psi(params)
    phi = model.build_phi(params, query)
    assert psi.shape[0] == N_ITEMS and phi.shape == (6, psi.shape[1])
    # fold-in rows live in the same export coordinates
    u = model.fold_in_user(params, [0, 3, 7])
    i = model.fold_in_item(params, [1, 2])
    assert u.shape == (psi.shape[1],) and i.shape == (psi.shape[1],)
    assert np.all(np.isfinite(u)) and np.all(np.isfinite(i))


@pytest.mark.parametrize("name", ZOO)
def test_engine_from_model(name):
    model, query = _model(name)
    params = model.fit(model.init(jax.random.PRNGKey(0)), n_epochs=2)
    eng = RetrievalEngine.from_model(model, params, k=5)
    res = eng.topk(query)
    assert res.ids.shape == (6, 5) and res.coverage == 1.0
    # parity with the hand-built engine
    ref = RetrievalEngine(model.export_psi(params),
                          lambda q: model.build_phi(params, q), k=5)
    ref_res = ref.topk_phi(model.build_phi(params, query))
    assert bool((res.ids == ref_res.ids).all())
    # request-time fold-in through the serving tier
    phi = eng.fold_in_phi([0, 2], n_sweeps=32)
    assert phi.shape == (1, eng.psi.shape[1])
    assert eng.topk_phi(phi).ids.shape == (1, 5)


def test_engine_without_model_refuses_fold_in():
    eng = RetrievalEngine(jnp.ones((4, 3)), lambda q: jnp.ones((1, 3)), k=2)
    with pytest.raises(RuntimeError, match="from_model"):
        eng.fold_in_phi([0])


def test_dataset_require_errors():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="missing"):
        build_model("mfsi", hp=mfsi.MFSIHyperParams(k=K), dataset=Dataset())
    with pytest.raises(ValueError, match="missing"):
        build_model("tucker", hp=tucker.TuckerHyperParams(k1=2, k2=2, k3=K),
                    dataset=Dataset(data=_interactions(rng)))
    with pytest.raises(ValueError, match="unknown model"):
        build_model("gcn", hp=None, dataset=Dataset())
    # data-less adapters exist (fold-in-only use) but training requires data
    m = build_model("mf", hp=mf.MFHyperParams(k=K), dataset=Dataset())
    with pytest.raises(ValueError, match="missing"):
        m.fit(mf.init(jax.random.PRNGKey(0), N_CTX, N_ITEMS, K), n_epochs=1)
