"""Pallas fused iCD Newton column update (the paper's Algorithm 2 inner loop).

One grid step processes a block of contexts for a fixed embedding dimension
f*. The padded observation layout (each context's interactions padded to
D_pad, α pre-zeroed on padding) makes every tensor dense:

  inputs  (per block): ψ tile (bc, D_pad) — pre-gathered ψ_{f*}(item)
                       α tile, e tile     — confidences / residual cache
                       w (bc, 1), r1 (bc, 1) — column + R'/2 ≡ (W·J[:,f*])
                       jff (1,1)          — J(f*,f*)
  compute: L'/2  = Σ_d α·e·ψ            (VPU row reduce)
           L''/2 = Σ_d α·ψ²
           Δ     = −η·(L'/2 + α₀·R'/2 + λw)/(L''/2 + α₀·J(f*,f*) + λ)
           e    += Δ·ψ                   (rank-1 residual patch)
  outputs: w_new (bc,1), e_new (bc,D_pad)

The fusion saves 4 HBM round-trips of (C, D_pad) intermediates versus the
XLA segment-sum path (gather → mul → reduce → newton → scatter as separate
ops).

Block-sweep kernel (lineage)
----------------------------
This per-column program still re-streams e and α from HBM once per
embedding dimension — k round-trips per sweep. ``kernels/cd_sweep`` is the
next step in the lineage: it processes k_b columns per grid step with e/α
VMEM-resident across the block and a Gauss–Seidel R' patch between columns,
cutting the sweep's (C, D_pad) traffic to ⌈k/k_b⌉ round-trips while
reproducing the per-column semantics exactly. Since the block kernel at
k_b=1 IS this program, the entry point below is a thin adapter over
``cd_block_sweep_pallas`` — one kernel body to maintain (clamps, dtype
policy, η handling live in one place). ``core/sweeps.sweep_columns``
dispatches between the two; this remains the k_b=1 / fallback path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.cd_sweep.kernel import cd_block_sweep_pallas


def cd_column_update_pallas(
    psi: jax.Array,     # (C, D_pad)
    alpha: jax.Array,   # (C, D_pad), 0 on padding
    e: jax.Array,       # (C, D_pad)
    w_col: jax.Array,   # (C,)
    r1: jax.Array,      # (C,)
    jff: jax.Array,     # scalar
    *,
    alpha0: float,
    l2: float,
    eta: float = 1.0,
    block_ctx: int = 256,
    interpret: bool = True,
):
    w_new, e_new = cd_block_sweep_pallas(
        psi[:, None, :], alpha, e, w_col[:, None], r1[:, None],
        jnp.reshape(jnp.asarray(jff, jnp.float32), (1, 1)),
        alpha0=alpha0, l2=l2, eta=eta, block_ctx=block_ctx,
        interpret=interpret,
    )
    return w_new[:, 0], e_new
