"""Qwen1.5 4B [hf:Qwen/Qwen1.5-4B] — QKV bias, MHA (kv == q heads)."""
import dataclasses

from repro.configs.base import LMConfig, lm_shapes

CONFIG = LMConfig(
    name="qwen1.5-4b",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab=151_936,
    act="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    num_microbatches=4,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, n_layers=3, d_model=48, n_heads=4, n_kv_heads=4, head_dim=12,
    d_ff=96, vocab=128, num_microbatches=1,
)

SHAPES = lm_shapes(
    long_context_skip=(
        "pure full attention: every layer's KV cache grows with the 524k "
        "context; per the brief long_500k runs only for SSM/hybrid/"
        "linear-attn archs (see DESIGN.md §4 — the sequence-sharded cache "
        "does lower, the skip is a policy choice)"
    )
)
