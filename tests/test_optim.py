"""Optimizers, schedules, clipping, int8 error-feedback compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    adafactor,
    adamw,
    apply_updates,
    clip_by_global_norm,
    cosine_decay,
    ef_compress_update,
    int8_compress,
    int8_decompress,
    linear_warmup_cosine,
    sgd,
)
from repro.train.train_step import build_train_step, init_state


def _quadratic_problem():
    target = {"a": jnp.asarray([1.0, -2.0, 3.0]), "b": jnp.asarray([[0.5, -0.5]])}
    params = jax.tree_util.tree_map(jnp.zeros_like, target)

    def loss(p, batch=None):
        return sum(
            jnp.sum((x - t) ** 2)
            for x, t in zip(jax.tree_util.tree_leaves(p),
                            jax.tree_util.tree_leaves(target))
        )

    return params, target, loss


@pytest.mark.parametrize("opt_name", ["sgd", "sgd_mom", "adamw", "adafactor"])
def test_optimizers_minimize_quadratic(opt_name):
    params, target, loss = _quadratic_problem()
    opt = {
        "sgd": sgd(0.1),
        "sgd_mom": sgd(0.05, momentum=0.9),
        "adamw": adamw(0.1),
        "adafactor": adafactor(lambda t: 0.3 / jnp.sqrt(t.astype(jnp.float32))),
    }[opt_name]
    state = opt.init(params)
    n = 600 if opt_name == "adafactor" else 200  # 1/sqrt(t) decay needs time
    for _ in range(n):
        grads = jax.grad(loss)(params)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    assert float(loss(params)) < 1e-2, float(loss(params))


def test_schedules():
    f = linear_warmup_cosine(1.0, 10, 100)
    assert float(f(jnp.int32(0))) == 0.0
    assert abs(float(f(jnp.int32(10))) - 1.0) < 0.11
    assert float(f(jnp.int32(100))) <= float(f(jnp.int32(50)))
    g = cosine_decay(2.0, 50)
    assert abs(float(g(jnp.int32(0))) - 2.0) < 1e-5


def test_clip_by_global_norm():
    grads = {"x": jnp.asarray([3.0, 4.0])}  # norm 5
    clipped, norm = clip_by_global_norm(grads, 1.0)
    np.testing.assert_allclose(float(norm), 5.0, rtol=1e-6)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(clipped["x"])), 1.0, rtol=1e-5
    )


def test_int8_roundtrip_error_bound():
    g = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    q, scale = int8_compress(g)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(int8_decompress(q, scale)) - np.asarray(g))
    assert err.max() <= float(scale) * 0.5 + 1e-7


def test_error_feedback_converges_quadratic():
    """EF-compressed gradient descent reaches the optimum of a quadratic —
    the compression-error accumulator guarantees asymptotic unbiasedness."""
    target = jnp.asarray([0.7, -1.3, 2.1, 0.0])
    x = jnp.zeros(4)
    err = jnp.zeros(4)
    for _ in range(300):
        g = 2 * (x - target)
        q, scale, err = ef_compress_update(g, err)
        x = x - 0.05 * int8_decompress(q, scale)
    np.testing.assert_allclose(np.asarray(x), np.asarray(target), atol=5e-3)


def test_train_step_microbatching_equivalence():
    """num_microbatches must not change the computed gradient (mean loss)."""
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (4, 3))
    batch = {
        "x": jax.random.normal(jax.random.PRNGKey(1), (8, 4)),
        "y": jax.random.normal(jax.random.PRNGKey(2), (8, 3)),
    }

    def loss(p, b):
        return jnp.mean((b["x"] @ p - b["y"]) ** 2)

    opt = sgd(0.1)
    s1, _ = build_train_step(loss, opt, num_microbatches=1)(
        init_state(w, opt), batch
    )
    s2, _ = build_train_step(loss, opt, num_microbatches=4)(
        init_state(w, opt), batch
    )
    np.testing.assert_allclose(s1.params, s2.params, rtol=1e-5, atol=1e-6)
