"""DLRM (Naumov et al., arXiv:1906.00091), RM2-scale configuration.

dense (B,13) → bottom MLP → (B,64); 26 sparse features → 26 embeddings
(B,26,64); dot-interaction over the 27 vectors (upper triangle, 351 pairs)
concat bottom → top MLP → logit.

The embedding lookup is the hot path (DESIGN.md §4: DLRM's top-MLP breaks
k-separability, so the paper's iCD does not train this ranker; the optional
retrieval twin is an iCD-MF/FM over the same tables).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.models.common import mlp_apply, mlp_init
from repro.models.recsys_common import binary_ce, init_tables, lookup, table_offsets


def init_params(key, cfg: RecsysConfig) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    table = init_tables(k1, cfg.table_vocabs, cfg.embed_dim)
    n_vec = cfg.n_sparse + 1
    n_pairs = n_vec * (n_vec - 1) // 2
    top_in = n_pairs + cfg.bot_mlp[-1]
    return {
        "table": table,
        "bot": mlp_init(k2, (cfg.n_dense,) + cfg.bot_mlp),
        "top": mlp_init(k3, (top_in,) + cfg.top_mlp),
    }


def forward(cfg: RecsysConfig, params, dense: jax.Array, sparse_ids: jax.Array):
    """dense (B, 13) f32, sparse_ids (B, 26) int32 → logits (B,)."""
    bot = mlp_apply(params["bot"], dense, final_act=jax.nn.relu)  # (B, 64)
    emb = lookup(params["table"], table_offsets(cfg.table_vocabs), sparse_ids)
    vecs = jnp.concatenate([bot[:, None, :], emb], axis=1)        # (B, 27, 64)
    inter = jnp.einsum("bnd,bmd->bnm", vecs, vecs)                # (B, 27, 27)
    iu, ju = jnp.triu_indices(vecs.shape[1], k=1)
    flat = inter[:, iu, ju]                                       # (B, 351)
    top_in = jnp.concatenate([bot, flat], axis=1)
    return mlp_apply(params["top"], top_in)[:, 0]


def loss_fn(cfg: RecsysConfig, params, batch) -> jax.Array:
    logits = forward(cfg, params, batch["dense"], batch["sparse"])
    return binary_ce(logits, batch["label"])


def score_candidates(cfg: RecsysConfig, params, dense: jax.Array,
                     user_sparse: jax.Array, cand_ids: jax.Array):
    """Retrieval cell: one context vs N candidates. The user-side bottom MLP
    and user-feature embeddings are computed ONCE; the candidate feature
    (table 0 by convention) is swept over ``cand_ids`` (N,)."""
    n = cand_ids.shape[0]
    bot = mlp_apply(params["bot"], dense, final_act=jax.nn.relu)        # (1, 64)
    user_emb = lookup(params["table"], table_offsets(cfg.table_vocabs), user_sparse)
    cand_emb = jnp.take(params["table"], cand_ids + table_offsets(cfg.table_vocabs)[0], axis=0)
    vecs = jnp.concatenate(
        [jnp.broadcast_to(bot[:, None], (n, 1, cfg.embed_dim)),
         cand_emb[:, None, :],
         jnp.broadcast_to(user_emb[:, 1:], (n, cfg.n_sparse - 1, cfg.embed_dim))],
        axis=1,
    )
    inter = jnp.einsum("bnd,bmd->bnm", vecs, vecs)
    iu, ju = jnp.triu_indices(vecs.shape[1], k=1)
    flat = inter[:, iu, ju]
    top_in = jnp.concatenate([jnp.broadcast_to(bot, (n, cfg.bot_mlp[-1])), flat], 1)
    return mlp_apply(params["top"], top_in)[:, 0]
