"""Continual-learning bench + hard gates: fold-in parity, schedule
equivalence, delta-publish semantics, and the subspace-scheduling
updates-to-quality curve.

Everything here is a GATE, not just a timing: each section hard-asserts
its acceptance criterion and the results are merged into the tracked
repo-root ``BENCH_cd_sweep.json`` under a ``continual`` key (the file's
other sections — the fused cd_sweep analytics — are preserved).

  * ``foldin_parity`` — every zoo model's closed-form fold-in row (user AND
    item side) matches the float64 normal-equations oracle; and a fold-in
    ψ row delta-published into a live fault-tolerant mesh is retrievable
    at the bumped version WITHOUT a full-table republish.
  * ``schedule_equivalence`` — a full SweepSchedule is bit-identical to
    the unscheduled epoch (same compiled program, not just same math).
  * ``delta_publish_ok`` — patch/append semantics, version-bump scope,
    append-hole refusal.
  * ``updates_to_quality`` — rotating single-block subspace steps reach a
    fixed MF loss target in STRICTLY fewer column updates than full
    epochs (the iALS++-style scheduling payoff: finer-grained stopping).
"""
from __future__ import annotations

import json
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import foldin
from repro.core.models import mf
from repro.core.models.zoo import ZOO, zoo_model
from repro.core.sweeps import FULL_SCHEDULE, SweepSchedule
from repro.serve.mesh import FaultTolerantRetrievalMesh
from repro.serve.publish import apply_delta, dense_table
from repro.sparse.interactions import build_interactions

_TOL = dict(rtol=2e-4, atol=2e-5)


def _assert_close(name, got, ref, rtol, atol):
    err = np.max(np.abs(np.asarray(got) - np.asarray(ref)), initial=0.0)
    bound = atol + rtol * np.max(np.abs(np.asarray(ref)), initial=0.0)
    assert err <= bound, f"{name}: fold-in parity FAILED (err={err:.3g})"
    return float(err)


def foldin_parity_gate() -> dict:
    """CD fold-in vs exact oracle on all five models, then the serving
    round-trip: fold an item, delta-publish it into a mesh, retrieve it."""
    out = {}
    rng = np.random.default_rng(11)
    for name in ZOO:
        model, params, _ = zoo_model(name, np.random.default_rng(3))
        hp = model._foldin_hp()
        psi_t = np.asarray(model.export_psi(params))
        phi_t = np.asarray(model.phi_table(params))
        ids_u = rng.choice(psi_t.shape[0], size=6, replace=False)
        ids_i = rng.choice(phi_t.shape[0], size=6, replace=False)
        u_free, u_init = model._user_free_init()
        i_free, i_init = model._item_free_init()
        row_u = model.fold_in_user(params, ids_u, n_sweeps=512, tol=1e-9)
        row_i = model.fold_in_item(params, ids_i, n_sweeps=512, tol=1e-9)
        err_u = _assert_close(
            f"{name}.fold_in_user", row_u,
            foldin.fold_in_exact(psi_t, ids_u, alpha0=hp["alpha0"],
                                 l2=hp["l2"], free=u_free, init=u_init),
            **_TOL)
        err_i = _assert_close(
            f"{name}.fold_in_item", row_i,
            foldin.fold_in_exact(phi_t, ids_i, alpha0=hp["alpha0"],
                                 l2=hp["l2"], free=i_free, init=i_init),
            **_TOL)
        out[name] = {"user_err": err_u, "item_err": err_i}

    # serving round-trip on MF: fold-in item → publish_delta → retrieve
    model, params, _ = zoo_model("mf", np.random.default_rng(3))
    psi = model.export_psi(params)
    mesh = FaultTolerantRetrievalMesh(
        lambda ctx: model.build_phi(params, ctx),
        n_shards=2, n_replicas=2, k=5, psi_table=psi,
    )
    v0, n0 = mesh.version, mesh.n_items
    row = model.fold_in_item(params, rng.choice(20, size=4, replace=False),
                             alpha=np.full(4, 8.0, np.float32))
    v1 = mesh.publish_delta(row, n0)
    assert v1 == v0 + 1 and mesh.n_items == n0 + 1, "delta version/shape"
    res = mesh.topk_phi(jnp.asarray(row, jnp.float32)[None, :] * 100.0)
    assert int(res.ids[0, 0]) == n0, (
        "fold-in-published item not retrievable through the mesh"
    )
    out["mesh_roundtrip"] = {
        "version": v1, "item_id": n0, "coverage": float(res.coverage),
    }
    out["ok"] = True
    return out


def schedule_equivalence_gate() -> dict:
    """FULL_SCHEDULE must be BIT-identical to schedule=None on an MF epoch."""
    rng = np.random.default_rng(0)
    n_ctx, n_items, k, nnz = 24, 18, 8, 120
    cells = rng.choice(n_ctx * n_items, size=nnz, replace=False)
    data = build_interactions(
        cells // n_items, cells % n_items, rng.integers(1, 4, nnz),
        1.3 + rng.random(nnz), n_ctx, n_items, alpha0=0.3,
    )
    hp = mf.MFHyperParams(k=k, alpha0=0.3, l2=0.05)
    params = mf.init(jax.random.PRNGKey(0), n_ctx, n_items, k)
    e = mf.residuals(params, data)
    p_ref, e_ref = mf.epoch(params, data, e, hp)
    p_sch, e_sch = mf.epoch(params, data, e, hp, FULL_SCHEDULE, 0)
    bit_equal = (bool((p_ref.w == p_sch.w).all())
                 and bool((p_ref.h == p_sch.h).all())
                 and bool((e_ref == e_sch).all()))
    assert bit_equal, "full schedule is not bit-identical to unscheduled"
    return {"ok": True, "bit_equal": bit_equal}


def delta_publish_gate() -> dict:
    """apply_delta patch/append semantics + hole refusal (pure layer)."""
    psi = np.random.default_rng(5).normal(size=(13, 4)).astype(np.float32)
    rows = np.arange(8, dtype=np.float32).reshape(2, 4)
    out = apply_delta(psi, rows, [2, 13])
    assert out.shape == (14, 4)
    assert np.array_equal(out[2], rows[0]) and np.array_equal(out[13], rows[1])
    hole_refused = False
    try:
        apply_delta(psi, rows[:1], 15)
    except ValueError:
        hole_refused = True
    assert hole_refused, "append hole must raise"
    # dense_table round-trips through the sharded representation
    from repro.serve.cluster import shard_psi
    ss = shard_psi(jnp.asarray(out), 3, version=1)
    assert np.array_equal(dense_table(ss), out), "dense_table round-trip"
    return {"ok": True, "hole_refused": hole_refused}


def updates_to_quality(quick: bool = True) -> dict:
    """Column-updates to reach a fixed loss target: full epochs vs rotating
    single-block subspace steps (iALS++-style scheduling).

    One FULL epoch spends 2k column updates (k per side); one scheduled
    step spends 2·k_b. The full path can only STOP at epoch granularity,
    so whenever the target falls mid-epoch the schedule's finer-grained
    trajectory crosses it with updates to spare. The gate requires the
    scheduled path to be STRICTLY cheaper."""
    rng = np.random.default_rng(2)
    n_ctx, n_items, k, k_b = 64, 48, 16, 4
    nnz = 600
    cells = rng.choice(n_ctx * n_items, size=nnz, replace=False)
    data = build_interactions(
        cells // n_items, cells % n_items, rng.integers(1, 4, nnz),
        1.3 + rng.random(nnz), n_ctx, n_items, alpha0=0.3,
    )
    hp = mf.MFHyperParams(k=k, alpha0=0.3, l2=0.05)
    params0 = mf.init(jax.random.PRNGKey(0), n_ctx, n_items, k)

    # full-epoch trajectory: objective after each epoch, 2k updates apiece
    n_epochs = 4 if quick else 8
    full_curve = []
    p, e = params0, mf.residuals(params0, data)
    for ep in range(n_epochs):
        p, e = mf.epoch(p, data, e, hp)
        full_curve.append((2 * k * (ep + 1), float(mf.objective(p, data, hp))))

    # target: the loss the full path reaches on its SECOND epoch boundary
    target = full_curve[1][1]
    full_updates = full_curve[1][0]

    # scheduled trajectory: one rotating k_b-block per step on both sides
    sched = SweepSchedule(kind="rotating", block=k_b, blocks_per_sweep=1)
    per_step = 2 * k_b
    sched_curve, sched_updates = [], None
    p, e = params0, mf.residuals(params0, data)
    max_steps = (full_updates // per_step) * 2
    for step in range(max_steps):
        p, e = mf.epoch(p, data, e, hp, sched, step)
        obj = float(mf.objective(p, data, hp))
        sched_curve.append((per_step * (step + 1), obj))
        if obj <= target:
            sched_updates = per_step * (step + 1)
            break
    assert sched_updates is not None, (
        f"scheduled sweeps never reached the target loss {target:.6f}"
    )
    assert sched_updates < full_updates, (
        f"subspace scheduling must be strictly cheaper: scheduled "
        f"{sched_updates} vs full {full_updates} column updates"
    )
    return {
        "shape": f"C={n_ctx}, I={n_items}, k={k}, k_b={k_b}, nnz={nnz}",
        "target_loss": target,
        "full_updates_to_target": full_updates,
        "scheduled_updates_to_target": sched_updates,
        "speedup_updates": full_updates / sched_updates,
        "full_curve": full_curve,
        "scheduled_curve": sched_curve,
        "ok": True,
    }


def continual_bench(quick: bool = True,
                    out_path: Optional[str] = None) -> dict:
    """Run all gates; merge results under ``continual`` in the tracked
    repo-root ``BENCH_cd_sweep.json`` (preserving its other sections)."""
    if out_path is None:
        repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        out_path = os.path.join(
            repo_root,
            "BENCH_cd_sweep.json" if quick else "BENCH_cd_sweep_full.json",
        )
    res = {
        "foldin_parity": foldin_parity_gate(),
        "schedule_equivalence": schedule_equivalence_gate(),
        "delta_publish_ok": delta_publish_gate(),
        "updates_to_quality": updates_to_quality(quick=quick),
    }
    res["gates"] = {
        g: bool(res[g].get("ok"))
        for g in ("foldin_parity", "schedule_equivalence", "delta_publish_ok")
    }
    res["gates"]["updates_to_quality"] = bool(res["updates_to_quality"]["ok"])
    doc = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            doc = json.load(f)
    doc["continual"] = res
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=1)
    return res


if __name__ == "__main__":
    out = continual_bench()
    print(json.dumps(out["gates"], indent=1))
    print(json.dumps(out["updates_to_quality"], indent=1))
