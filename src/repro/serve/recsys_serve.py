"""RecSys serving paths: p99 online batches, offline bulk, retrieval top-k.

``retrieval_topk`` covers the retrieval_cand cell: 10⁶ candidates scored in
chunks (batched-dot for separable scorers, chunked forward for rankers) and
reduced with a running top-k — never materializing all scores when chunked.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp


def bulk_score(forward: Callable, batch, chunk: int = 65536):
    """Offline scoring of a huge batch in fixed-size chunks (serve_bulk)."""
    n = jax.tree_util.tree_leaves(batch)[0].shape[0]
    outs = []
    for lo in range(0, n, chunk):
        piece = jax.tree_util.tree_map(lambda x: x[lo : lo + chunk], batch)
        outs.append(forward(piece))
    return jnp.concatenate(outs, axis=0)


def retrieval_topk(
    score_fn: Callable[[jax.Array], jax.Array],  # cand_ids → scores
    n_candidates: int,
    k: int = 100,
    chunk: int = 262144,
) -> Tuple[jax.Array, jax.Array]:
    """Top-k over ``n_candidates`` scored in chunks with a running reduce.

    ``score_fn(ids)`` may return ``(chunk,)`` (single query) or
    ``(B, chunk)`` (batched); the reduce carries matching ``(..., k)``
    state. Slots with no real candidate (``n_candidates < k``) stay at
    id −1 / score −inf — no placeholder item id ever leaks into the
    result. Ties resolve toward the smaller candidate id (``lax.top_k``
    positional stability + ascending chunk order), the same policy as the
    fused ``kernels/topk_score`` kernel, for which this chunked jnp path
    is the reference oracle.
    """
    best_scores = best_ids = None
    for lo in range(0, n_candidates, chunk):
        ids = jnp.arange(lo, min(lo + chunk, n_candidates), dtype=jnp.int32)
        scores = score_fn(ids)
        if best_scores is None:  # first chunk fixes the (optional) batch dim
            lead = scores.shape[:-1]
            best_scores = jnp.full(lead + (k,), -jnp.inf, scores.dtype)
            best_ids = jnp.full(lead + (k,), -1, jnp.int32)
        merged_s = jnp.concatenate([best_scores, scores], axis=-1)
        merged_i = jnp.concatenate(
            [best_ids, jnp.broadcast_to(ids, scores.shape).astype(jnp.int32)],
            axis=-1,
        )
        best_scores, idx = jax.lax.top_k(merged_s, k)
        best_ids = jnp.take_along_axis(merged_i, idx, axis=-1)
    if best_scores is None:  # n_candidates == 0
        best_scores = jnp.full((k,), -jnp.inf)
        best_ids = jnp.full((k,), -1, jnp.int32)
    return best_scores, best_ids


def mf_retrieval_score_fn(user_vec: jax.Array, item_table: jax.Array):
    """The paper-native separable retrieval: one (k)·(k,N) matvec per id
    chunk — or a (B, k)·(k, N) matmul when ``user_vec`` is a (B, k) batch."""

    def score(ids):
        s = jnp.take(item_table, ids, axis=0) @ user_vec.T  # (c,) | (c, B)
        return s.T if s.ndim == 2 else s

    return score
