"""Kernel-fused padded iCD-MF == reference iCD-MF, trajectory-level."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.models import mf, mf_padded
from repro.sparse.interactions import build_interactions


def make_problem(seed=0, n_ctx=40, n_items=25, nnz=200, alpha0=0.4,
                 empty_tail=0):
    """``empty_tail`` > 0 leaves the last contexts with NO observations —
    all-padding rows in the ctx-major grid (the gather kernels' sentinel/
    α=0 path)."""
    rng = np.random.default_rng(seed)
    cells = rng.choice((n_ctx - empty_tail) * n_items, size=nnz, replace=False)
    ctx, item = cells // n_items, cells % n_items
    y = rng.integers(1, 5, size=nnz).astype(np.float64)
    alpha = alpha0 + 1.0 + rng.random(nnz)
    return build_interactions(ctx, item, y, alpha, n_ctx, n_items, alpha0=alpha0)


def test_padded_epoch_matches_reference():
    data = make_problem()
    hp = mf.MFHyperParams(k=8, alpha0=0.4, l2=0.05)
    params = mf.init(jax.random.PRNGKey(0), data.n_ctx, data.n_items, 8)
    pdata = mf_padded.pad_interactions(data)

    p_ref, p_pad = params, params
    e_ref = mf.residuals(p_ref, data)
    e_pad = mf_padded.residuals(p_pad, pdata)
    for _ in range(3):
        p_ref, e_ref = mf.epoch(p_ref, data, e_ref, hp)
        p_pad, e_pad = mf_padded.epoch(p_pad, pdata, e_pad, hp)
        np.testing.assert_allclose(p_pad.w, p_ref.w, rtol=3e-4, atol=3e-5)
        np.testing.assert_allclose(p_pad.h, p_ref.h, rtol=3e-4, atol=3e-5)


@pytest.mark.parametrize("psi_dispatch", ["gather", "pregather"])
def test_padded_fused_dispatch_matches_reference(psi_dispatch):
    """Both fused Ψ routings (in-kernel gather / pre-gathered tile) track
    the flat reference at a non-divisible k=8/block_k=3 split, with
    empty-context rows (all-padding grid rows) in the data."""
    data = make_problem(seed=11, empty_tail=2)
    hp = mf.MFHyperParams(k=8, alpha0=0.4, l2=0.05, block_k=3,
                          psi_dispatch=psi_dispatch)
    params = mf.init(jax.random.PRNGKey(2), data.n_ctx, data.n_items, 8)
    pdata = mf_padded.pad_interactions(data)

    p_ref, p_pad = params, params
    e_ref = mf.residuals(p_ref, data)
    e_pad = mf_padded.residuals(p_pad, pdata)
    for _ in range(2):
        p_ref, e_ref = mf.epoch(p_ref, data, e_ref, hp)
        p_pad, e_pad = mf_padded.epoch(p_pad, pdata, e_pad, hp)
        np.testing.assert_allclose(p_pad.w, p_ref.w, rtol=3e-4, atol=3e-5)
        np.testing.assert_allclose(p_pad.h, p_ref.h, rtol=3e-4, atol=3e-5)


def test_padded_fused_gather_matches_pregather_exactly():
    """The two Ψ routings run the same FP program per Newton step — their
    trajectories must agree to float roundoff, not just model tolerance."""
    data = make_problem(seed=12, empty_tail=1)
    params = mf.init(jax.random.PRNGKey(3), data.n_ctx, data.n_items, 8)
    pdata = mf_padded.pad_interactions(data)
    finals = {}
    for disp in ("gather", "pregather"):
        hp = mf.MFHyperParams(k=8, alpha0=0.4, l2=0.05, block_k=3,
                              psi_dispatch=disp)
        p, e_pad = params, mf_padded.residuals(params, pdata)
        for _ in range(2):
            p, e_pad = mf_padded.epoch(p, pdata, e_pad, hp)
        finals[disp] = (p, e_pad)
    np.testing.assert_allclose(finals["gather"][0].w,
                               finals["pregather"][0].w, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(finals["gather"][0].h,
                               finals["pregather"][0].h, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(finals["gather"][1], finals["pregather"][1],
                               rtol=1e-6, atol=1e-7)


def test_padded_gather_falls_back_when_slab_too_big(monkeypatch):
    """When the ψ slab alone busts the (shrunken) VMEM budget the fused
    dispatch must silently fall back to the pre-gathered path — same
    numbers, no VmemBudgetError escaping epoch()."""
    from repro.kernels import vmem

    # large catalogue relative to the budget: the (n_items, k_b) slab is
    # what overflows, while the pre-gathered row tiles still fit
    data = make_problem(seed=13, n_ctx=30, n_items=2000, nnz=300)
    params = mf.init(jax.random.PRNGKey(4), data.n_ctx, data.n_items, 8)
    pdata = mf_padded.pad_interactions(data)
    hp = mf.MFHyperParams(k=8, alpha0=0.4, l2=0.05, block_k=3)

    p_ref, e_ref = params, mf_padded.residuals(params, pdata)
    p_ref, e_ref = mf_padded.epoch(p_ref, pdata, e_ref, hp)

    # budget too small for the resident ψ slab, still enough for row tiles
    monkeypatch.setattr(vmem, "VMEM_BUDGET_BYTES", 30_000)
    assert not vmem.resolve_cd_sweep_dispatch(
        pdata.alpha_c.shape[1], 3, data.n_items, n_rows=data.n_ctx
    )[0]
    hp2 = dataclasses.replace(hp, l2=0.05000001)  # new static hp ⇒ retrace
    p_fb, e_fb = params, mf_padded.residuals(params, pdata)
    p_fb, e_fb = mf_padded.epoch(p_fb, pdata, e_fb, hp2)
    np.testing.assert_allclose(p_fb.w, p_ref.w, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(p_fb.h, p_ref.h, rtol=1e-4, atol=1e-6)


def test_padded_layout_roundtrip():
    data = make_problem(seed=3)
    pdata = mf_padded.pad_interactions(data)
    # every observation lands exactly once in each grid
    assert int((np.asarray(pdata.alpha_c) > 0).sum()) == data.nnz
    assert int((np.asarray(pdata.alpha_i) > 0).sum()) == data.nnz
    a1 = np.asarray(pdata.alpha_c)[np.asarray(pdata.c_rows), np.asarray(pdata.c_cols)]
    a2 = np.asarray(pdata.alpha_i)[np.asarray(pdata.i_rows), np.asarray(pdata.i_cols)]
    np.testing.assert_allclose(a1, np.asarray(data.alpha))
    np.testing.assert_allclose(a2, np.asarray(data.alpha))
