"""Serving driver: fault-tolerant replicated retrieval with micro-batched
online requests.

  python -m repro.launch.serve --arch icd-mf --smoke --requests 64 \
      --shards 2 --replicas 2

Builds the model from the registry config, publishes its ψ table into a
:class:`~repro.serve.mesh.FaultTolerantRetrievalMesh` (each row-range on
``--replicas`` replica slabs, health-checked failover, graceful
degradation), and replays an open-loop single-row request trace through the
:class:`~repro.serve.batcher.MicroBatcher` (deadline/size flush), printing
throughput, queue-latency percentiles, coverage, and the mesh's failover
counters. The retry policy's deadline is wired to the batcher's
``--max-delay`` so a retrying shard can never blow the admission-queue
latency contract.

``--kill S:R`` arms a sticky injected fault on replica R of shard S before
the trace (repeatable) — the self-contained failover/degradation demo:
with ``--replicas 2`` a single kill is invisible in the results; killing
both replicas of a shard degrades coverage below 1.0 and the driver
reports the dead row ranges.

Observability (``repro.obs``): ``--metrics-out FILE`` exports the metrics
registry on exit (``.prom`` → Prometheus text, anything else → JSONL);
``--trace-out FILE`` exports the request trace as Chrome-trace JSON (open
in Perfetto / ``chrome://tracing``); ``--stats-every N`` prints a live
stats line from the registry every N requests.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else float("nan")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--policy", default="round_robin",
                    choices=("round_robin", "least_outstanding"))
    ap.add_argument("--topk", type=int, default=100)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-delay", type=float, default=2e-3)
    ap.add_argument("--kill", action="append", default=[], metavar="S:R",
                    help="inject a sticky fault on replica R of shard S "
                         "(repeatable), e.g. --kill 0:0 --kill 0:1")
    ap.add_argument("--continual", action="store_true",
                    help="after the trace: fold in an unseen user at "
                         "request time and delta-publish a fold-in item "
                         "(the continual-learning serving path)")
    ap.add_argument("--metrics-out", default=None, metavar="FILE",
                    help="export the metrics registry on exit (.prom -> "
                         "Prometheus text exposition, else JSONL)")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="export the request trace as Chrome-trace JSON "
                         "(Perfetto / chrome://tracing)")
    ap.add_argument("--stats-every", type=int, default=0, metavar="N",
                    help="print a live registry stats line every N requests")
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not args.arch.startswith("icd"):
        raise SystemExit(
            f"unknown serving arch {args.arch!r}: the serve driver hosts the "
            "k-separable retrieval registry (icd-*)"
        )

    from repro.core.models import mf
    from repro.obs import MetricsRegistry, Tracer, write_metrics, write_trace
    from repro.serve.batcher import MicroBatcher
    from repro.serve.mesh import (
        FaultInjector,
        FaultTolerantRetrievalMesh,
        RetryPolicy,
    )

    # one registry + tracer for the whole serving stack, on the SAME clock
    # as the batcher so queue latencies and span times line up
    registry = MetricsRegistry(clock=time.perf_counter)
    tracer = Tracer(clock=time.perf_counter) if args.trace_out else None

    params = mf.init(jax.random.PRNGKey(0), cfg.n_ctx, cfg.n_items, cfg.k)
    k = min(args.topk, cfg.n_items)
    injector = FaultInjector()
    mesh = FaultTolerantRetrievalMesh(
        lambda ctx: mf.build_phi(params, ctx),
        n_shards=args.shards, n_replicas=args.replicas, k=k,
        policy=args.policy, injector=injector,
        # a shard's retries share the batcher's latency bound: a request
        # can burn at most max_delay on backoff before degrading instead
        retry=RetryPolicy(max_attempts=3, deadline=args.max_delay),
        registry=registry, tracer=tracer,
    )
    version = mesh.publish(mf.export_psi(params))
    print(f"[serve] published psi v{version}: {cfg.n_items} items over "
          f"{args.shards} shard(s) x {args.replicas} replica(s), top-{k}")
    for spec in args.kill:
        s, r = (int(x) for x in spec.split(":"))
        injector.fail(s, r, "error")
        print(f"[serve] chaos: armed sticky fault on replica ({s}, {r})")

    batcher = MicroBatcher(
        lambda phi, eids: mesh.topk_phi(phi, exclude_ids=eids),
        max_batch=args.max_batch, max_delay=args.max_delay,
        # same clock as t0 below: completed_at − t0 must be well-defined
        clock=time.perf_counter,
        version_fn=lambda: mesh.version,
        registry=registry, tracer=tracer,
    )
    phi_all = np.asarray(mf.build_phi(params, np.arange(cfg.n_ctx)))
    rng = np.random.default_rng(0)
    users = rng.integers(0, cfg.n_ctx, size=args.requests)
    t0 = time.perf_counter()
    tickets = []
    for n, u in enumerate(users, start=1):
        tickets.append((u, batcher.submit(phi_all[u], key=("user", int(u)))))
        batcher.step()
        if args.stats_every and n % args.stats_every == 0:
            bs, ms = batcher.stats, mesh.stats
            print(f"[serve] stats @ {n}/{args.requests}: "
                  f"submitted={bs['submitted']} "
                  f"flushes={bs['flushes']} hits={bs['cache_hits']} "
                  f"dispatches={ms['dispatches']} faults={ms['faults']} "
                  f"failovers={ms['failovers']}")
    batcher.flush()  # retire the sub-batch tail
    dt = time.perf_counter() - t0
    lat, top_id, coverage, dead_ranges = [], None, 1.0, set()
    for u, t in tickets:
        done_at = batcher.completed_at(t)
        res = batcher.result(t)
        scores, ids = res
        assert ids.shape == (k,)
        if done_at is not None:
            lat.append(done_at - t0)
        coverage = min(coverage, res.coverage)
        dead_ranges.update(res.dead_ranges)
        if top_id is None:
            top_id = int(ids[0])
    leftovers = batcher.drain()  # close admission; nothing may be stranded
    assert not leftovers and batcher.closed
    print(f"[serve] {args.requests} requests in {dt:.3f}s "
          f"({args.requests / dt:.1f} req/s), "
          f"{batcher.stats['flushes']} flushes "
          f"(size={batcher.stats['flush_by_size']} "
          f"deadline={batcher.stats['flush_by_deadline']} "
          f"forced={batcher.stats['flush_forced']}), "
          f"cache_hits={batcher.stats['cache_hits']}")
    ms = mesh.stats
    print(f"[serve] mesh: {ms['dispatches']} dispatches, "
          f"{ms['faults']} faults, {ms['failovers']} failovers, "
          f"{ms['retries']} retries "
          f"(backoff {ms['backoff_slept_s'] * 1e3:.2f} ms, "
          f"gaveups={ms['deadline_gaveups']}), "
          f"{ms['degraded_queries']} degraded queries")
    if coverage < 1.0:
        print(f"[serve] DEGRADED: coverage={coverage:.4f}, dead item "
              f"ranges={sorted(dead_ranges)} — heal() or restart replicas")
    else:
        print("[serve] coverage=1.0000 (full catalogue served)")
    print(f"[serve] completion p50={_percentile(lat, 50):.4f}s "
          f"p99={_percentile(lat, 99):.4f}s after start; "
          f"top id for user {int(users[0])}: {top_id}")

    if args.continual:
        from repro.core.models.api import Dataset, build_model

        hp = mf.MFHyperParams(k=cfg.k, alpha0=cfg.alpha0, l2=cfg.l2)
        model = build_model("mf", hp=hp, dataset=Dataset())
        # unseen user: solve their φ row against the frozen ψ snapshot at
        # request time (closed-form fold-in) — no training state touched
        history = rng.integers(0, cfg.n_items, size=8)
        phi_new = np.asarray(model.fold_in_user(params, history))[None, :]
        res = mesh.topk_phi(jax.numpy.asarray(phi_new))
        print(f"[serve] fold-in user (|history|={history.size}): "
              f"top id {int(res.ids[0, 0])} at v{mesh.version}")
        # new item: fold its ψ row from early interactions, then go live
        # through an incremental delta publish — no full-table republish
        item_ctx = rng.integers(0, cfg.n_ctx, size=6)
        psi_row = model.fold_in_item(params, item_ctx)
        new_id = mesh.n_items
        v = mesh.publish_delta(psi_row, new_id)
        res = mesh.topk_phi(jax.numpy.asarray(psi_row, jax.numpy.float32)[None, :])
        print(f"[serve] fold-in item {new_id} delta-published as v{v}; "
              f"self-query top id {int(res.ids[0, 0])} "
              f"({mesh.n_items} items live)")

    if args.metrics_out:
        write_metrics(args.metrics_out, registry)
        print(f"[serve] metrics -> {args.metrics_out}")
    if args.trace_out:
        write_trace(args.trace_out, tracer)
        print(f"[serve] trace ({len(tracer.spans)} spans) -> "
              f"{args.trace_out}")


if __name__ == "__main__":
    main()
