"""Pure-jnp oracle for the fused score+top-K kernel: dense Φ·Ψᵀ, exclusion
mask to −inf, ``lax.top_k``, and the −1-id policy on inadmissible slots.

This is deliberately the "memory-naive" path — it materializes the full
``(B, n_items)`` score matrix the kernel exists to avoid — so it doubles
as the dense baseline in ``benchmarks/serve_bench``. For the same reason
``exclude_ids`` (the kernel's web-scale per-row id-list form) is expanded
to the dense (B, n_items) mask here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def exclude_ids_to_mask(exclude_ids, n_items: int):
    """Dense (B, n_items) bool mask from −1-padded per-row global id lists
    (oracle/test helper — the kernel never builds this)."""
    ids = jnp.asarray(exclude_ids, jnp.int32)
    onehot = (ids[:, :, None] == jnp.arange(n_items, dtype=jnp.int32)) & (
        ids[:, :, None] >= 0
    )
    return onehot.any(axis=1)


def topk_score_ref(phi, psi, k, exclude_mask=None, *, exclude_ids=None):
    """Dense reference with the kernel's exact semantics: tie-stable
    ascending-id order (``lax.top_k`` positional stability over the
    id-ordered row) and (−inf, −1) on slots with no admissible candidate."""
    n_items = psi.shape[0]
    scores = phi.astype(jnp.float32) @ psi.astype(jnp.float32).T
    if exclude_ids is not None:
        assert exclude_mask is None, "pass exclude_mask OR exclude_ids"
        exclude_mask = exclude_ids_to_mask(exclude_ids, n_items)
    if exclude_mask is not None:
        scores = jnp.where(exclude_mask != 0, -jnp.inf, scores)
    if k > n_items:  # dense top_k cannot rank more slots than exist
        pad = k - n_items
        scores = jnp.pad(scores, ((0, 0), (0, pad)), constant_values=-jnp.inf)
    top_s, top_i = jax.lax.top_k(scores, k)
    top_i = jnp.where(jnp.isneginf(top_s), -1, top_i).astype(jnp.int32)
    return top_s, top_i
