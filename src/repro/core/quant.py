"""Shared symmetric int8 quantization helpers (per-tensor and per-row).

Two consumers with one scale-fitting rule (absmax → ±127):

  * ``optim/compression.py`` — error-feedback int8 GRADIENT compression for
    the DP all-reduce (per-tensor scale: one gradient tensor, one dynamic
    range); re-exports these under its historical names.
  * ``serve/ann.py`` — quantized ψ SERVING storage. Catalogue rows span
    orders of magnitude in norm (head vs tail items), so one per-tensor
    scale would crush tail rows to zero: the per-ROW variant fits one scale
    per ψ row and the fused kernel dequantizes tiles in-VMEM
    (``q.astype(f32) · scale[row]``) with fp32 accumulation.

The int8 code is symmetric (no zero point): ``q = clip(round(x/scale))``,
``scale = absmax/127`` — dequantization is one multiply, which is what the
kernel inlines per ψ tile. ``bf16`` storage needs no helper (a dtype cast);
its capacity/accuracy trade sits between int8 and fp32.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

_EPS = 1e-12  # scale floor: keeps all-zero inputs from dividing by zero


def int8_quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization: ``(q int8, scale f32 ())``."""
    absmax = jnp.maximum(jnp.max(jnp.abs(x)), _EPS)
    scale = (absmax / 127.0).astype(jnp.float32)
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale), -127, 127
    ).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Per-tensor inverse: ``q·scale`` in fp32."""
    return q.astype(jnp.float32) * scale


def int8_quantize_rows(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-ROW int8 quantization of a 2-D table.

    Returns ``(q (n, d) int8, scales (n,) f32)`` with each row fitted to
    its own absmax — the ψ-table form: a tail row's small coefficients keep
    their full 8-bit resolution instead of inheriting the head rows' range.
    All-zero rows get the ``_EPS`` floor scale (quantize to zeros,
    dequantize to zeros)."""
    x = jnp.asarray(x, jnp.float32)
    if x.ndim != 2:
        raise ValueError(f"per-row quantization needs a 2-D table, got {x.shape}")
    absmax = jnp.maximum(jnp.max(jnp.abs(x), axis=1), _EPS)   # (n,)
    scales = (absmax / 127.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scales[:, None]), -127, 127).astype(jnp.int8)
    return q, scales


def int8_dequantize_rows(q: jax.Array, scales: jax.Array) -> jax.Array:
    """Per-row inverse: ``q · scales[:, None]`` in fp32 — the reference for
    what the fused kernel computes per ψ tile in-VMEM."""
    return q.astype(jnp.float32) * jnp.asarray(scales, jnp.float32)[:, None]
