"""Model-agnostic retrieval engine over the fused score+top-K kernel.

The φ/ψ export contract
-----------------------

Every k-separable model (paper §4–5) scores an item as
``ŷ = ⟨φ(context), ψ(item)⟩``, so ONE retrieval path serves the whole zoo.
Each model module exports two functions the engine is built from:

  ``export_psi(params, ...) -> (n_items, D)``  the catalogue ψ table
  ``build_phi(params, <query>) -> (B, D)``     φ rows for a query batch

with D and the column conventions per model:

  model    D     export_psi                build_phi            columns
  -------  ----  ------------------------  -------------------  ------------
  MF       k     ``params.h``              ``w[ctx]``           ψ_f = h_{i,f}
  MFSI     k     ``Z·H`` (item design)     ``(X·W)[rows]``      eq. 21
  FM       k+2   ``psi_ext``: [Ψ | 1 | ψ_spec]
                                           ``phi_ext``:
                                           [Φ | φ_spec | 1]     eqs. 27–31
  PARAFAC  k     ``params.w``              ``u[c1]·v[c2]``      eq. 35
  Tucker   k3    ``params.w``              ``Σ b·u[c1]·v[c2]``  eq. 40

The FM alignment is the one to watch: Ψe's column k is the constant 1
(paired with φ_spec — the context bias/linear/pairwise bundle) and column
k+1 is ψ_spec (paired with Φe's constant 1), so the plain inner product
reproduces the full FM score including both special components.

The engine itself is just (ψ table, φ builder, blocking policy): ``topk``
streams ψ blocks through the Pallas kernel (``kernels/topk_score``) with a
running in-VMEM top-K merge — the ``(B, n_items)`` score matrix is never
materialized — and supports per-row exclude masks for the
seen-items-filtered serving protocol. ``exclude_mask_from_lists`` builds
those masks from ragged per-row id lists (train histories).
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.topk_score.ops import topk_score


def exclude_mask_from_lists(
    item_lists: Sequence, n_items: int
) -> jax.Array:
    """(B, n_items) bool mask from ragged per-row item-id lists (host-side;
    rows are query-batch sized, NEVER the full eval set)."""
    mask = np.zeros((len(item_lists), n_items), dtype=bool)
    for r, ids in enumerate(item_lists):
        ids = np.asarray(ids, dtype=np.int64)
        if ids.size:
            mask[r, ids] = True
    return jnp.asarray(mask)


class RetrievalEngine:
    """Serve top-K retrieval for any k-separable model.

    Built from the model's exported ψ table and φ builder::

        engine = RetrievalEngine(mf.export_psi(params),
                                 lambda ctx: mf.build_phi(params, ctx))
        scores, ids = engine.topk(user_ids, k=100)

    ``topk`` semantics follow the kernel (see ``kernels/topk_score``):
    exact dense-``lax.top_k`` parity, ascending-id tie policy, (−inf, −1)
    on slots with no admissible candidate.
    """

    def __init__(
        self,
        psi_table: jax.Array,                      # (n_items, D)
        phi_fn: Callable[..., jax.Array],          # query -> (B, D)
        *,
        k: int = 100,
        block_items: Optional[int] = None,
    ):
        self.psi = jnp.asarray(psi_table, jnp.float32)
        self.phi_fn = phi_fn
        self.k = k
        self.block_items = block_items

    @property
    def n_items(self) -> int:
        return int(self.psi.shape[0])

    def phi(self, *query) -> jax.Array:
        """φ rows for a query batch — (B, D), D tiny; safe to materialize."""
        return jnp.asarray(self.phi_fn(*query), jnp.float32)

    def topk(
        self,
        *query,
        k: Optional[int] = None,
        exclude_mask: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, jax.Array]:
        """(scores, ids), both (B, k), for a query batch."""
        return self.topk_phi(self.phi(*query), k=k, exclude_mask=exclude_mask)

    def topk_phi(
        self,
        phi_rows: jax.Array,
        *,
        k: Optional[int] = None,
        exclude_mask: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, jax.Array]:
        """Like :meth:`topk` but from pre-built φ rows (the eval harness
        path, which batches a big φ matrix through here)."""
        return topk_score(
            phi_rows, self.psi, k or self.k, exclude_mask,
            block_items=self.block_items,
        )

    def scores(self, phi_rows: jax.Array) -> jax.Array:
        """Dense (B, n_items) scores — small batches / tests ONLY; serving
        and eval go through :meth:`topk`, which never materializes this."""
        return phi_rows @ self.psi.T
