"""Continual learning under traffic: the full loop this repo's serving tier
exists for — train a warm model on the head of an interaction log, go live,
then replay the tail as arriving traffic and absorb it WITHOUT retraining:

  * an unseen user gets a φ row at request time (closed-form fold-in of
    their history against the frozen ψ snapshot — ``core/foldin.py``),
  * a brand-new item gets a ψ row folded in from its first interactions and
    enters the live catalogue through an incremental ``publish_delta``
    (version bump, batcher-cache invalidation, no full-table republish),
  * the warm side keeps improving with subspace-scheduled sweeps
    (``SweepSchedule``): each refresh updates only a rotating k_b-column
    block — a fraction of a full epoch's column updates — and republishes
    with the fold-in rows composed on top.

Everything runs through the unified ``Model`` protocol
(``core/models/api.py``), so swapping MF for FM/MFSI/PARAFAC/Tucker is a
one-line change.

    PYTHONPATH=src python examples/continual_learning.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.models import mf
from repro.core.models.api import Dataset, build_model
from repro.core.sweeps import SweepSchedule
from repro.data.loader import interaction_stream
from repro.data.synthetic import make_implicit_dataset
from repro.eval.ranking import foldin_ranking_eval
from repro.serve.cluster import ShardedRetrievalCluster
from repro.serve.publish import PsiPublisher
from repro.sparse.interactions import build_interactions


def main():
    n_users, n_items, k = 300, 200, 16
    ds = make_implicit_dataset(n_users=n_users, n_items=n_items,
                               attr_strength=0.8, seed=0)
    events = ds.events                       # (nnz, 3) time-ordered
    split = int(0.8 * len(events))
    # the last 4 items are COLD: they never enter the warm training set
    head, n_warm_items = events[:split], n_items - 4
    hists = ds.user_histories()

    # --- warm phase: batch-train on the head of the log ------------------
    warm = head[head[:, 1] < n_warm_items]
    hp = mf.MFHyperParams(k=k, alpha0=0.3, l2=0.05)
    data = build_interactions(
        warm[:, 0], warm[:, 1], np.ones(len(warm)), np.full(len(warm), 2.0),
        n_users, n_warm_items, alpha0=hp.alpha0,
    )
    model = build_model("mf", hp=hp, dataset=Dataset(data=data))
    params = model.init(jax.random.PRNGKey(0))
    params = model.fit(params, n_epochs=6)
    print(f"warm: trained on {len(warm)} events, "
          f"{n_warm_items}/{n_items} items")

    # --- go live ---------------------------------------------------------
    # the published table composes the warm export with the fold-in rows,
    # so a full republish after a warm refresh keeps cold items live
    extra: dict = {}          # folded-in item id -> psi row

    def export(p):
        psi = np.asarray(model.export_psi(p))
        if extra:
            psi = np.concatenate(
                [psi, np.stack([extra[i] for i in sorted(extra)])]
            )
        return jnp.asarray(psi)

    cluster = ShardedRetrievalCluster(
        lambda ctx: model.build_phi(params, ctx), n_shards=2, k=10,
    )
    pub = PsiPublisher(cluster, export, every=1)
    pub(0, params)
    print(f"live: psi v{cluster.version}, {cluster.n_items} items")

    # --- continual phase: replay the tail as arriving traffic ------------
    # cold items were OBSERVED in the head (just excluded from training),
    # so their early interactions are available to fold from
    item_hist: dict = {}      # interactions of not-yet-served items
    for u, i in head[head[:, 1] >= n_warm_items][:, :2]:
        item_hist.setdefault(int(i), []).append(int(u))
    folded_items = 0

    def flush_cold():
        # delta-append every cold item whose id is next in line and has
        # any history — appends must stay hole-free (see apply_delta)
        nonlocal folded_items
        while item_hist.get(cluster.n_items):
            i = cluster.n_items
            row = np.asarray(model.fold_in_item(params, item_hist[i]))
            extra[i] = row
            pub.publish_delta(row, i)
            folded_items += 1

    folded_users = 0
    for batch in interaction_stream(ds, batch_events=64, start=split):
        for u, i in zip(batch["ctx"], batch["item"]):
            u, i = int(u), int(i)
            if i >= n_warm_items:
                # new item: buffer its interactions, then fold in a psi
                # row and delta-publish it (no full-table republish)
                item_hist.setdefault(i, []).append(u)
                flush_cold()
            else:
                # request-time φ for the arriving user: closed-form against
                # the frozen warm ψ — no training state touched
                hist = hists[u][hists[u] < n_warm_items][:3]
                phi = model.fold_in_user(params, hist)
                res = cluster.topk_phi(jnp.asarray(phi, jnp.float32)[None])
                assert res.ids.shape[1] == 10
                folded_users += 1
        # subspace-scheduled warm refresh: ONE rotating k_b-block per
        # publish — a k_b/k fraction of a full epoch's column updates
        sched = SweepSchedule(kind="rotating", block=4, blocks_per_sweep=1)
        params, _ = model.epoch(params, model.residuals(params),
                                schedule=sched, sweep_index=cluster.version)
        pub(cluster.version, params)
    print(f"continual: {folded_users} fold-in queries answered, "
          f"{folded_items} items delta-published "
          f"(versions {[v for v, _ in pub.deltas]}), now at "
          f"v{cluster.version} with {cluster.n_items} items")

    # --- cold-start eval: every eval user folded in from scratch ---------
    observed, true_items = [], []
    for h in hists:
        seen = np.unique(h[:-1])
        seen = seen[seen < n_warm_items]
        if len(seen) and h[-1] < n_warm_items:
            observed.append(seen)
            true_items.append(int(h[-1]))
    res = foldin_ranking_eval(model, params, observed, true_items, k=10)
    print(f"fold-in eval: recall@10={res['recall@10']:.4f} "
          f"ndcg@10={res['ndcg@10']:.4f} over {res['n_eval']} users")


if __name__ == "__main__":
    main()
