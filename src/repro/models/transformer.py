"""Decoder-only transformer LM covering the 5 assigned LM architectures.

Config-driven features:
  * GQA (any n_kv_heads | n_heads), separate head_dim (Gemma-2's 256)
  * RoPE, configurable theta
  * QKV bias (Qwen1.5)
  * sliding-window local attention + local/global ALTERNATION (Gemma-2):
    layers are scanned in groups of two (local, global) so every window is
    static inside the scan body
  * attention & final logit soft-capping (Gemma-2)
  * pre+post block RMSNorms (Gemma-2) or plain pre-norm (LLaMA-family)
  * MoE FFN: top-k routing with capacity, shared experts and leading dense
    layers (OLMoE, DeepSeekMoE)
  * scan-over-layers with optional remat — keeps the 95-layer deepseek-67b
    HLO compact for the multi-pod dry-run
  * KV-cache decode step (one token) for the decode_32k / long_500k cells

Pure functions over explicit param pytrees; dtype policy: parameters are
stored in ``param_dtype`` (fp32 masters in the trainer) and cast to
``compute_dtype`` (bf16) inside the step.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig, MoEConfig
from repro.models.common import dense_init, rms_norm, rope, softcap


# ===========================================================================
# init
# ===========================================================================
def _init_block(key, cfg: LMConfig, moe_layer: bool) -> Dict[str, Any]:
    ks = jax.random.split(key, 12)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    p: Dict[str, Any] = {
        "wq": dense_init(ks[0], (d, qd)),
        "wk": dense_init(ks[1], (d, kvd)),
        "wv": dense_init(ks[2], (d, kvd)),
        "wo": dense_init(ks[3], (qd, d), fan_in=qd),
        "pre_attn": jnp.zeros((d,)),
        "pre_ffn": jnp.zeros((d,)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((qd,))
        p["bk"] = jnp.zeros((kvd,))
        p["bv"] = jnp.zeros((kvd,))
    if cfg.post_norms:
        p["post_attn"] = jnp.zeros((d,))
        p["post_ffn"] = jnp.zeros((d,))
    if moe_layer:
        m = cfg.moe
        p["router"] = dense_init(ks[4], (d, m.n_experts))
        p["e_gate"] = dense_init(ks[5], (m.n_experts, d, m.d_expert), fan_in=d)
        p["e_up"] = dense_init(ks[6], (m.n_experts, d, m.d_expert), fan_in=d)
        p["e_down"] = dense_init(ks[7], (m.n_experts, m.d_expert, d), fan_in=m.d_expert)
        if m.n_shared:
            fs = m.n_shared * m.d_expert
            p["s_gate"] = dense_init(ks[8], (d, fs))
            p["s_up"] = dense_init(ks[9], (d, fs))
            p["s_down"] = dense_init(ks[10], (fs, d), fan_in=fs)
    else:
        ff = cfg.d_ff if cfg.moe is None else cfg.moe.d_ff_dense
        p["w_gate"] = dense_init(ks[4], (d, ff))
        p["w_up"] = dense_init(ks[5], (d, ff))
        p["w_down"] = dense_init(ks[6], (ff, d), fan_in=ff)
    return p


def group_size(cfg: LMConfig) -> int:
    return 2 if cfg.local_global_alternating else 1


def n_dense_head_layers(cfg: LMConfig) -> int:
    return cfg.moe.first_k_dense if cfg.moe else 0


def init_params(key, cfg: LMConfig) -> Dict[str, Any]:
    g = group_size(cfg)
    n_head_dense = n_dense_head_layers(cfg)
    n_scanned = cfg.n_layers - n_head_dense
    assert n_scanned % g == 0, "layer count must divide the scan group"
    n_steps = n_scanned // g

    keys = jax.random.split(key, 3 + n_head_dense)
    params: Dict[str, Any] = {
        "embed": 0.02 * jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)),
        "final_norm": jnp.zeros((cfg.d_model,)),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(keys[1], (cfg.d_model, cfg.vocab))
    params["head_dense"] = [
        _init_block(keys[3 + i], cfg, moe_layer=False) for i in range(n_head_dense)
    ]

    def init_stack(key, moe_layer):
        sub_keys = jax.random.split(key, n_steps)
        blocks = [_init_block(k, cfg, moe_layer) for k in sub_keys]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)

    stack_keys = jax.random.split(keys[2], g)
    moe_layer = cfg.moe is not None
    params["layers"] = tuple(init_stack(k, moe_layer) for k in stack_keys)
    return params


# ===========================================================================
# blocks
# ===========================================================================
def _attention_xla(cfg, q, k, v, *, window, q_offset, kv_len):
    """(B,Sq,Hq,hd) × (B,Skv,Hkv,hd) → (B,Sq,Hq,hd); fp32 softmax."""
    b, sq, hq, hd = q.shape
    skv = k.shape[1]
    groups = hq // cfg.n_kv_heads
    qg = q.reshape(b, sq, cfg.n_kv_heads, groups, hd)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    s = s / jnp.sqrt(jnp.float32(hd))
    s = softcap(s, cfg.attn_softcap)
    q_pos = q_offset + jnp.arange(sq)[:, None]
    kv_pos = jnp.arange(skv)[None, :]
    mask = (q_pos >= kv_pos) & (kv_pos < kv_len)
    if window is not None:
        mask = mask & (kv_pos > q_pos - window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p.astype(v.dtype), v)
    return out.reshape(b, sq, hq, hd)


def _attn_block(cfg, p, x, *, window, positions, cache=None):
    """Returns (out, new_cache). cache: (2, B, S_max, Hkv, hd) or None."""
    b, s, d = x.shape
    h = rms_norm(x, p["pre_attn"], cfg.norm_eps)
    q = h @ p["wq"].astype(h.dtype)
    k = h @ p["wk"].astype(h.dtype)
    v = h @ p["wv"].astype(h.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(h.dtype)
        k = k + p["bk"].astype(h.dtype)
        v = v + p["bv"].astype(h.dtype)
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if cache is None:
        out = _attention_xla(
            cfg, q, k, v, window=window, q_offset=0, kv_len=s
        )
        new_cache = None
    else:
        pos0 = positions[0, 0]  # decode: single new position, same per batch
        ck = jax.lax.dynamic_update_slice(cache[0], k, (0, pos0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache[1], v, (0, pos0, 0, 0))
        out = _attention_xla(
            cfg, q, ck, cv, window=window, q_offset=pos0, kv_len=pos0 + s
        )
        new_cache = jnp.stack([ck, cv])
    out = out.reshape(b, s, cfg.q_dim) @ p["wo"].astype(x.dtype)
    if cfg.post_norms:
        out = rms_norm(out, p["post_attn"], cfg.norm_eps)
    return x + out, new_cache


def _act(cfg, g):
    return jax.nn.silu(g) if cfg.act == "swiglu" else jax.nn.gelu(g)


def _dense_ffn(cfg, p, h):
    g = _act(cfg, h @ p["w_gate"].astype(h.dtype))
    u = h @ p["w_up"].astype(h.dtype)
    return (g * u) @ p["w_down"].astype(h.dtype)


def _moe_ffn(cfg, p, h2d):
    """Capacity-based top-k MoE over flattened tokens (T, D)."""
    from repro.models.hints import constrain

    m: MoEConfig = cfg.moe
    t, d = h2d.shape
    logits = (h2d @ p["router"].astype(h2d.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                       # (T, E)
    gate, eid = jax.lax.top_k(probs, m.top_k)                     # (T, K)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    cap = max(8, int(m.capacity_factor * t * m.top_k / m.n_experts))
    flat_e = eid.reshape(-1)                                      # (T·K,)
    flat_g = gate.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t), m.top_k)
    onehot = jax.nn.one_hot(flat_e, m.n_experts, dtype=jnp.int32)
    rank = jnp.sum((jnp.cumsum(onehot, axis=0) - onehot) * onehot, axis=1)
    keep = (rank < cap).astype(h2d.dtype)
    slot = jnp.minimum(rank, cap - 1)

    buf = jnp.zeros((m.n_experts, cap, d), h2d.dtype)
    buf = buf.at[flat_e, slot].add(h2d[flat_t] * keep[:, None])
    # the dispatch buffer is scatter-built, so GSPMD cannot infer a sharding
    # and replicates the expert GEMMs — constrain it (hillclimb #3)
    buf = constrain(buf, ("expert", "capacity", None))
    g = _act(cfg, jnp.einsum("ecd,edf->ecf", buf, p["e_gate"].astype(buf.dtype)))
    u = jnp.einsum("ecd,edf->ecf", buf, p["e_up"].astype(buf.dtype))
    eo = jnp.einsum("ecf,efd->ecd", g * u, p["e_down"].astype(buf.dtype))
    eo = constrain(eo, ("expert", "capacity", None))
    out = eo[flat_e, slot] * (keep * flat_g.astype(h2d.dtype))[:, None]
    out = jax.ops.segment_sum(out, flat_t, num_segments=t)

    if m.n_shared:
        sg = _act(cfg, h2d @ p["s_gate"].astype(h2d.dtype))
        su = h2d @ p["s_up"].astype(h2d.dtype)
        out = out + (sg * su) @ p["s_down"].astype(h2d.dtype)

    # Switch-style load-balance loss
    top1 = jax.nn.one_hot(eid[:, 0], m.n_experts, dtype=jnp.float32)
    frac = jnp.mean(top1, axis=0)
    imp = jnp.mean(probs, axis=0)
    aux = m.n_experts * jnp.sum(frac * imp)
    return out, aux


def _ffn_block(cfg, p, x, moe_layer):
    b, s, d = x.shape
    h = rms_norm(x, p["pre_ffn"], cfg.norm_eps)
    if moe_layer:
        out2d, aux = _moe_ffn(cfg, p, h.reshape(b * s, d))
        out = out2d.reshape(b, s, d)
    else:
        out, aux = _dense_ffn(cfg, p, h), 0.0
    if cfg.post_norms:
        out = rms_norm(out, p["post_ffn"], cfg.norm_eps)
    return x + out, aux


def _block(cfg, p, x, *, window, positions, moe_layer, cache=None):
    x, new_cache = _attn_block(cfg, p, x, window=window, positions=positions,
                               cache=cache)
    if cfg.wire_barriers:
        x = jax.lax.optimization_barrier(x)
    x, aux = _ffn_block(cfg, p, x, moe_layer)
    if cfg.wire_barriers:
        x = jax.lax.optimization_barrier(x)
    return x, aux, new_cache


def _windows(cfg) -> Tuple[Optional[int], ...]:
    """Per-sublayer static windows inside one scan group."""
    if cfg.local_global_alternating:
        return (cfg.attn_window, None)   # Gemma-2: local, then global
    return (cfg.attn_window,)


# ===========================================================================
# forward / loss
# ===========================================================================
def forward(cfg: LMConfig, params, tokens: jax.Array,
            compute_dtype=jnp.bfloat16,
            last_only: bool = False) -> Tuple[jax.Array, jax.Array]:
    """tokens (B, S) → logits (B, S, V), aux_loss (scalar).

    ``last_only`` slices the residual stream to the final position BEFORE
    the unembedding — the prefill serving path (a (B,S,V) logits tensor at
    vocab 256k would be absurd; only the next-token logits are needed)."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(compute_dtype)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), compute_dtype)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    windows = _windows(cfg)
    moe_layer = cfg.moe is not None

    for p_dense in params["head_dense"]:
        x, _, _ = _block(cfg, p_dense, x, window=windows[-1],
                         positions=positions, moe_layer=False)

    def step(carry, layer_group):
        x, aux = carry
        for sub, window in zip(layer_group, windows):
            x, a, _ = _block(cfg, sub, x, window=window, positions=positions,
                             moe_layer=moe_layer)
            aux = aux + a
        return (x, aux), None

    step_fn = jax.checkpoint(step) if cfg.remat else step
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(step_fn, (x, jnp.float32(0.0)), params["layers"])
    else:
        n_steps = jax.tree_util.tree_leaves(params["layers"][0])[0].shape[0]
        carry = (x, jnp.float32(0.0))
        for i in range(n_steps):
            group = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            carry, _ = step_fn(carry, group)
        x, aux = carry

    if last_only:
        x = x[:, -1:]
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    unembed = (
        params["embed"].T if cfg.tie_embeddings else params["unembed"]
    ).astype(compute_dtype)
    logits = x @ unembed
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits, aux


def loss_fn(cfg: LMConfig, params, tokens, targets,
            compute_dtype=jnp.bfloat16) -> jax.Array:
    logits, aux = forward(cfg, params, tokens, compute_dtype)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_loss_weight * aux
    return loss


# ===========================================================================
# decode (KV cache)
# ===========================================================================
def init_cache(cfg: LMConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> Dict[str, Any]:
    g = group_size(cfg)
    n_head_dense = n_dense_head_layers(cfg)
    n_steps = (cfg.n_layers - n_head_dense) // g

    def one(length):
        return jnp.zeros((2, batch, length, cfg.n_kv_heads, cfg.head_dim), dtype)

    def stack(length):
        return jnp.zeros(
            (n_steps, 2, batch, length, cfg.n_kv_heads, cfg.head_dim), dtype
        )

    # local layers only ever need `window` cache rows — exploited by the
    # long_500k cell (half of Gemma-2's cache is window-bounded)
    lengths = [
        min(max_seq, cfg.attn_window) if w is not None else max_seq
        for w in _windows(cfg)
    ]
    return {
        "head_dense": [one(max_seq) for _ in range(n_head_dense)],
        "layers": tuple(stack(l) for l in lengths),
        "max_seq": max_seq,
    }


def decode_step(cfg: LMConfig, params, cache, tokens: jax.Array,
                position: jax.Array, compute_dtype=jnp.bfloat16):
    """One-token decode: tokens (B, 1), position scalar → (logits, cache).

    Local-window layers use a rolling cache of size `window` (position taken
    modulo window); RoPE phases stay correct because positions are absolute.
    """
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(compute_dtype)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), compute_dtype)
    positions = jnp.broadcast_to(position[None, None], (b, s)).astype(jnp.int32)
    windows = _windows(cfg)
    moe_layer = cfg.moe is not None

    new_head = []
    for p_dense, c in zip(params["head_dense"], cache["head_dense"]):
        x, _, nc = _block(cfg, p_dense, x, window=windows[-1],
                          positions=positions, moe_layer=False, cache=c)
        new_head.append(nc)

    def step(x, scanned):
        layer_group, cache_group = scanned
        new_caches = []
        for sub, c, window in zip(layer_group, cache_group, windows):
            if window is not None and c.shape[2] <= window:
                # rolling local cache: write at absolute position mod window
                roll_pos = jnp.broadcast_to(
                    (position % c.shape[2])[None, None], (b, s)
                ).astype(jnp.int32)
                h = rms_norm(x, sub["pre_attn"], cfg.norm_eps)
                q = h @ sub["wq"].astype(h.dtype)
                k = h @ sub["wk"].astype(h.dtype)
                v = h @ sub["wv"].astype(h.dtype)
                if cfg.qkv_bias:
                    q = q + sub["bq"].astype(h.dtype)
                    k = k + sub["bk"].astype(h.dtype)
                    v = v + sub["bv"].astype(h.dtype)
                q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
                k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
                v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
                q = rope(q, positions, cfg.rope_theta)
                k = rope(k, positions, cfg.rope_theta)
                ck = jax.lax.dynamic_update_slice(c[0], k, (0, roll_pos[0, 0], 0, 0))
                cv = jax.lax.dynamic_update_slice(c[1], v, (0, roll_pos[0, 0], 0, 0))
                # all cache rows < window behind the current position are valid
                valid = jnp.minimum(position + 1, c.shape[2])
                out = _attention_rolling(cfg, q, ck, cv, valid)
                out = out.reshape(b, s, cfg.q_dim) @ sub["wo"].astype(x.dtype)
                if cfg.post_norms:
                    out = rms_norm(out, sub["post_attn"], cfg.norm_eps)
                x2 = x + out
                x2, _ = _ffn_block(cfg, sub, x2, moe_layer)
                x = x2
                new_caches.append(jnp.stack([ck, cv]))
            else:
                x, _, nc = _block(cfg, sub, x, window=window,
                                  positions=positions, moe_layer=moe_layer,
                                  cache=c)
                new_caches.append(nc)
        return x, tuple(new_caches)

    if cfg.scan_layers:
        x, new_layer_caches = jax.lax.scan(
            step, x, (params["layers"], cache["layers"])
        )
    else:  # unrolled (cost-probe path: exact HLO cost accounting)
        n_steps = jax.tree_util.tree_leaves(params["layers"][0])[0].shape[0]
        caches = []
        for i in range(n_steps):
            group = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            cgroup = tuple(c[i] for c in cache["layers"])
            x, nc = step(x, (group, cgroup))
            caches.append(nc)
        new_layer_caches = tuple(
            jnp.stack([c[g] for c in caches]) for g in range(len(windows))
        )

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    unembed = (
        params["embed"].T if cfg.tie_embeddings else params["unembed"]
    ).astype(compute_dtype)
    logits = softcap((x @ unembed).astype(jnp.float32), cfg.final_softcap)
    new_cache = {
        "head_dense": new_head,
        "layers": new_layer_caches,
        "max_seq": cache["max_seq"],
    }
    return logits, new_cache


def _attention_rolling(cfg, q, ck, cv, valid):
    """Decode attention over a rolling window cache: every populated row is
    attendable (positions are within the window by construction)."""
    b, s, hq, hd = q.shape
    groups = hq // cfg.n_kv_heads
    qg = q.reshape(b, s, cfg.n_kv_heads, groups, hd)
    sc = jnp.einsum("bskgd,btkd->bkgst", qg, ck).astype(jnp.float32)
    sc = sc / jnp.sqrt(jnp.float32(hd))
    sc = softcap(sc, cfg.attn_softcap)
    kv_pos = jnp.arange(ck.shape[1])[None, :]
    mask = kv_pos < valid
    sc = jnp.where(mask[None, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p.astype(cv.dtype), cv)
    return out.reshape(b, s, hq, hd)
