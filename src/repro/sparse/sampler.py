"""Uniform neighbor sampling for GNN minibatch training (GraphSAGE).

The ``minibatch_lg`` shape (Reddit: 233k nodes / 115M edges, fanout 15-10)
requires a real sampler: seeds → fanout-1 neighbors → fanout-2 neighbors.
Sampling is uniform-with-replacement from each node's CSR adjacency row
(the GraphSAGE default); isolated nodes self-loop.

Everything here is jit-compatible: fixed fanout shapes, no host round trips.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse.csr import CSR, coo_to_csr


def build_adjacency(
    src: np.ndarray, dst: np.ndarray, n_nodes: int, symmetrize: bool = True
) -> CSR:
    """Host-side: edge list → CSR adjacency (optionally symmetrized)."""
    if symmetrize:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    return coo_to_csr(src, dst, None, n_nodes, n_nodes)


def sample_neighbors(
    key: jax.Array, adj: CSR, seeds: jax.Array, fanout: int
) -> jax.Array:
    """Sample ``fanout`` neighbors per seed, uniform with replacement.

    Args:
      key: PRNG key.
      adj: CSR adjacency.
      seeds: (n_seeds,) int32 node ids.
      fanout: static neighbors per seed.

    Returns:
      (n_seeds, fanout) int32 neighbor ids. Isolated nodes sample themselves.
    """
    starts = jnp.take(adj.indptr, seeds)
    degrees = jnp.take(adj.indptr, seeds + 1) - starts
    offs = jax.random.randint(
        key, (seeds.shape[0], fanout), minval=0, maxval=jnp.iinfo(jnp.int32).max
    )
    # modulo degree; guard deg==0 with self loops
    safe_deg = jnp.maximum(degrees, 1)
    offs = offs % safe_deg[:, None]
    neigh = jnp.take(adj.indices, starts[:, None] + offs)
    return jnp.where(degrees[:, None] > 0, neigh, seeds[:, None])


def neighbor_sampler(
    key: jax.Array, adj: CSR, seeds: jax.Array, fanouts: Sequence[int]
) -> Tuple[jax.Array, ...]:
    """Multi-hop GraphSAGE frontier sampling.

    Returns a tuple ``(layer_0, layer_1, ..., layer_L)`` where ``layer_0`` is
    the seeds and ``layer_h`` has shape ``(n_seeds * prod(fanouts[:h]),)`` —
    the flattened h-hop frontier. ``layer_h[i*fanout_h + j]`` is the j-th
    sampled neighbor of ``layer_{h-1}[i]``, so mean-aggregation is a reshape
    + mean along the fanout axis.
    """
    frontiers = [seeds]
    frontier = seeds
    for h, fanout in enumerate(fanouts):
        key, sub = jax.random.split(key)
        neigh = sample_neighbors(sub, adj, frontier, fanout)  # (n, fanout)
        frontier = neigh.reshape(-1)
        frontiers.append(frontier)
    return tuple(frontiers)
