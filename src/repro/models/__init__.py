"""Sharding-hint DSL (``models/hints.py``): constraint/hint annotations
usable by any model code. The LM/RecSys/GNN architecture zoo that used
to live here was retired — the paper's own k-separable models are
``repro.core.models``.
"""
