"""Tiny reference instances of every k-separable model.

One helper, shared by the kernel/engine/cluster parity tests and the serve
bench, that builds a small (φ, ψ) export pair per model through the real
``build_phi``/``export_psi`` contract (``serve/engine.py``) — so every
consumer exercises the same five models and a new zoo member only has to
be added HERE.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.design import make_design
from repro.core.models import fm, mf, mfsi, parafac, tucker

ZOO = ("mf", "mfsi", "fm", "parafac", "tucker")


def rand_f32(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape),
                       jnp.float32)


def model_phi_psi(name, rng, *, n_ctx=20, n_items=37, b=9, k=6):
    """A small instance of zoo model ``name``; returns (phi (B, D),
    psi (n_items, D)) through the model's export contract."""
    if name == "mf":
        params = mf.init(jax.random.PRNGKey(0), n_ctx, n_items, k)
        return mf.build_phi(params, jnp.arange(b)), mf.export_psi(params)
    if name == "parafac":
        params = parafac.init(jax.random.PRNGKey(1), 8, 7, n_items, k)
        c1 = jnp.asarray(rng.integers(0, 8, b), jnp.int32)
        c2 = jnp.asarray(rng.integers(0, 7, b), jnp.int32)
        return parafac.build_phi(params, c1, c2), parafac.export_psi(params)
    if name == "tucker":
        params = tucker.init(jax.random.PRNGKey(2), 8, 7, n_items, 4, 3, k)
        c1 = jnp.asarray(rng.integers(0, 8, b), jnp.int32)
        c2 = jnp.asarray(rng.integers(0, 7, b), jnp.int32)
        return tucker.build_phi(params, c1, c2), tucker.export_psi(params)
    x = make_design(
        [dict(name="id", ids=np.arange(n_ctx) % 11, vocab=11),
         dict(name="grp", ids=rng.integers(0, 5, n_ctx), vocab=5)], n_ctx)
    z = make_design(
        [dict(name="item_id", ids=np.arange(n_items), vocab=n_items),
         dict(name="genre", ids=rng.integers(0, 7, n_items), vocab=7)], n_items)
    if name == "mfsi":
        params = mfsi.init(jax.random.PRNGKey(3), x.p, z.p, k)
        return (mfsi.build_phi(params, x, jnp.arange(b)),
                mfsi.export_psi(params, z))
    if name != "fm":
        raise ValueError(f"unknown zoo model {name!r}")
    hp = fm.FMHyperParams(k=k)
    params = fm.init(jax.random.PRNGKey(4), x.p, z.p, k)
    # break the all-zero linear/bias init so ψ_spec is a real column
    params = params._replace(
        b=jnp.asarray(0.3), w_lin=rand_f32((x.p,), 10),
        h_lin=rand_f32((z.p,), 11),
    )
    return (fm.build_phi(params, x, hp, jnp.arange(b)),
            fm.export_psi(params, z, hp))
