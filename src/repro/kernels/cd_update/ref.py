"""Pure-jnp oracle for the fused CD column update."""
import jax.numpy as jnp


def cd_column_update_ref(psi, alpha, e, w_col, r1, jff, *, alpha0, l2, eta=1.0):
    lp = jnp.sum(alpha * e * psi, axis=1)
    lpp = jnp.sum(alpha * psi * psi, axis=1)
    num = lp + alpha0 * r1 + l2 * w_col
    den = lpp + alpha0 * jff + l2
    delta = -eta * num / jnp.maximum(den, 1e-12)
    return w_col + delta, e + delta[:, None] * psi
