"""AdamW with decoupled weight decay and bias correction."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.base import OptimizerDef


def adamw(lr, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0) -> OptimizerDef:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads,
        )
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads,
        )
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m_, v_, p):
            u = -(lr_t * (m_ / c1) / (jnp.sqrt(v_ / c2) + eps))
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u

        return (
            jax.tree_util.tree_map(upd, m, v, params),
            {"step": step, "m": m, "v": v},
        )

    return OptimizerDef(init, update)
