"""Delta ψ publish (``serve/publish.py`` + cluster/mesh): pure
``apply_delta`` semantics (patch, append, hole/dup/negative validation),
version-bump invalidation scope (batcher cache keyed on version), stale
refusal across a delta bump, and the canary-staged refusal on the mesh."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve.batcher import MicroBatcher
from repro.serve.cluster import ShardedRetrievalCluster
from repro.serve.mesh import FaultTolerantRetrievalMesh
from repro.serve.publish import PsiPublisher, apply_delta, dense_table


def _psi(n=17, d=6, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


# ------------------------------------------------------------ apply_delta
def test_apply_delta_patch_and_append():
    psi = _psi()
    rows = np.arange(12, dtype=np.float32).reshape(2, 6)
    out = apply_delta(psi, rows, [3, 17])          # one patch, one append
    assert out.shape == (18, 6)
    np.testing.assert_array_equal(out[3], rows[0])
    np.testing.assert_array_equal(out[17], rows[1])
    # untouched rows unchanged; input not mutated
    np.testing.assert_array_equal(out[:3], psi[:3])
    assert psi.shape == (17, 6)
    # a single (D,) row auto-reshapes
    out2 = apply_delta(psi, np.ones(6, np.float32), 0)
    np.testing.assert_array_equal(out2[0], np.ones(6))


def test_apply_delta_validation():
    psi = _psi()
    row = np.ones(6, np.float32)
    with pytest.raises(ValueError, match="hole"):
        apply_delta(psi, row, 19)                  # skips id 17, 18
    with pytest.raises(ValueError, match="duplicate"):
        apply_delta(psi, np.stack([row, row]), [3, 3])
    with pytest.raises(ValueError, match="negative"):
        apply_delta(psi, row, -1)
    with pytest.raises(ValueError, match="rows must be"):
        apply_delta(psi, np.ones((2, 6), np.float32), [0])
    # contiguous multi-append is fine, any order
    out = apply_delta(psi, np.stack([row, 2 * row]), [18, 17])
    assert out.shape == (19, 6)
    np.testing.assert_array_equal(out[18], row)


# ------------------------------------------------- cluster version + rows
def test_cluster_delta_patch_append_retrievable():
    psi = _psi()
    cl = ShardedRetrievalCluster(
        lambda ctx: jnp.ones((len(ctx), 6)), n_shards=3, k=5, psi_table=psi
    )
    v0 = cl.version
    # large magnitude ⇒ the self inner product dominates every cross score
    new_row = 10 * np.random.default_rng(1).normal(size=6).astype(np.float32)
    v1 = cl.publish_delta(new_row, 17)             # append
    assert v1 == v0 + 1 and cl.n_items == 18
    np.testing.assert_allclose(dense_table(cl.table)[17], new_row)
    # the appended item must be retrievable: probe with its own row
    res = cl.topk_phi(jnp.asarray(new_row)[None, :])
    assert int(res[1][0, 0]) == 17
    v2 = cl.publish_delta(2 * new_row, 3)          # patch
    assert v2 == v1 + 1 and cl.n_items == 18
    np.testing.assert_allclose(dense_table(cl.table)[3], 2 * new_row)


def test_publisher_delta_records_versions():
    psi = _psi()
    cl = ShardedRetrievalCluster(
        lambda ctx: jnp.ones((len(ctx), 6)), n_shards=2, k=5, psi_table=psi
    )
    pub = PsiPublisher(cl, lambda p: p)
    row = np.ones(6, np.float32)
    v = pub.publish_delta(row, 17)
    assert pub.deltas == [(v, 1)] and cl.version == v


# ------------------------------------------- batcher invalidation scope
def test_delta_bump_invalidates_batcher_cache():
    psi = _psi()
    cl = ShardedRetrievalCluster(
        lambda ctx: jnp.ones((len(ctx), 6)), n_shards=2, k=5, psi_table=psi
    )
    batcher = MicroBatcher(
        lambda phi, eids: cl.topk_phi(phi, exclude_ids=eids),
        max_batch=4, version_fn=lambda: cl.version,
    )
    phi = psi[5]
    t1 = batcher.submit(phi, key=("user", 5))
    batcher.flush()
    t2 = batcher.submit(phi, key=("user", 5))      # same key, same version
    batcher.flush()
    assert batcher.stats["cache_hits"] == 1
    ids_before = np.asarray(batcher.result(t2)[1])
    # delta publish bumps the version: the SAME key must recompute
    cl.publish_delta(10 * psi[5], 17)    # aligned with the probe φ ⇒ top-1
    t3 = batcher.submit(phi, key=("user", 5))
    batcher.flush()
    assert batcher.stats["cache_hits"] == 1        # no new hit
    ids_after = np.asarray(batcher.result(t3)[1])
    assert 17 in ids_after and 17 not in ids_before
    assert batcher.result(t1) is not None


# --------------------------------------------------- mesh: stale + canary
def test_mesh_delta_publish_and_canary_refusal():
    psi = _psi()
    mesh = FaultTolerantRetrievalMesh(
        lambda ctx: jnp.ones((len(ctx), 6)), n_shards=2, n_replicas=2, k=5,
        psi_table=jnp.asarray(psi),
    )
    row = np.random.default_rng(2).normal(size=6).astype(np.float32)
    v = mesh.publish_delta(row, 17)
    assert v == 2 and mesh.n_items == 18
    res = mesh.topk_phi(jnp.asarray(row)[None, :])
    assert int(res.ids[0, 0]) == 17 and res.coverage == 1.0
    # every replica was rebuilt at the new version (stale-refusal invariant)
    rs = mesh.replica_set
    for shard_replicas in rs.replicas:
        for rep in shard_replicas:
            assert rep.version == mesh.version
    # a staged canary blocks delta publishes until resolved
    mesh.begin_canary(jnp.asarray(dense_table(mesh.table)))
    with pytest.raises(RuntimeError, match="canary"):
        mesh.publish_delta(row, 3)
    mesh.rollback_canary()
    v2 = mesh.publish_delta(2 * row, 3)
    assert v2 == v + 1
    np.testing.assert_allclose(dense_table(mesh.table)[3], 2 * row)
