"""Config dataclasses for the paper's own iCD models and the dry-run.

The LM/RecSys/GNN zoo dataclasses left with the unused architecture zoo
(PR 8 retirement).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One (arch × input-shape) cell of the assignment."""

    name: str
    kind: str                    # 'train' | 'prefill' | 'decode' | 'serve' | ...
    seq_len: int = 0
    global_batch: int = 0
    extras: Tuple[Tuple[str, object], ...] = ()
    skip: Optional[str] = None   # reason string ⇒ documented skip

    def extra(self, key, default=None):
        return dict(self.extras).get(key, default)


@dataclasses.dataclass(frozen=True)
class ICDConfig:
    """Production config for the paper's own models."""

    name: str
    model: str            # 'mf' | 'fm'
    n_ctx: int
    n_items: int
    k: int
    alpha0: float = 1.0
    l2: float = 0.1
    # fm extras
    p_ctx: int = 0
    p_item: int = 0


ICD_SHAPES = {
    "epoch_youtube": ShapeSpec(
        "epoch_youtube", "train",
        extras=(("n_ctx", 200_000), ("n_items", 68_000), ("nnz", 20_000_000)),
    ),
    "epoch_web": ShapeSpec(
        "epoch_web", "train",
        extras=(("n_ctx", 10_000_000), ("n_items", 1_000_000),
                ("nnz", 500_000_000)),
    ),
    "retrieval": ShapeSpec(
        "retrieval", "retrieval", global_batch=4096,
        extras=(("n_candidates", 1_000_000),),
    ),
}
