"""Conventional CD over the FULL implicit matrix — the paper's strawman.

This is the O(|C||I|k) per-epoch solver of §3.2 applied directly to
``S_impl`` (eq. 5): every context-item cell, including all zeros, enters the
loss. It exists for two reasons:

1. **Exactness oracle** — iCD (Lemma 1 + Lemma 2) must produce *identical*
   parameter trajectories: same init, same sweep order ⇒ same Newton steps.
   ``tests/test_icd_exact.py`` asserts this to ~1e-5.
2. **Figure 8** — the 4-orders-of-magnitude cost gap between conventional CD
   and iCD is reproduced by ``benchmarks/fig8_cost.py`` using the FLOP
   counts of these two implementations.

Only feasible for tiny |C|,|I|; guarded accordingly.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import sweeps
from repro.core.models.mf import MFHyperParams, MFParams


def dense_from_observed(
    ctx, item, y, alpha, n_ctx: int, n_items: int, alpha0: float
) -> Tuple[jax.Array, jax.Array]:
    """Materialize (Y, A) of S_impl: zeros with confidence α₀ everywhere
    except the observed cells (y with confidence α)."""
    y_dense = jnp.zeros((n_ctx, n_items), jnp.float32).at[ctx, item].set(y)
    a_dense = (
        jnp.full((n_ctx, n_items), alpha0, jnp.float32).at[ctx, item].set(alpha)
    )
    return y_dense, a_dense


@partial(jax.jit, static_argnames=("hp",))
def epoch_dense(
    params: MFParams, y_dense: jax.Array, a_dense: jax.Array, hp: MFHyperParams
) -> MFParams:
    """One conventional-CD epoch on the dense objective, with the same
    column-major sweep order as ``repro.core.models.mf.epoch``."""
    w, h = params

    def w_body(f, w):
        err = w @ h.T - y_dense                      # (C, I) — the O(|C||I|) part
        h_col = sweeps.take_col(h, f)
        w_col = sweeps.take_col(w, f)
        num = (a_dense * err) @ h_col + hp.l2 * w_col
        den = a_dense @ (h_col * h_col) + hp.l2
        return sweeps.put_col(w, f, w_col - hp.eta * num / den)

    w = jax.lax.fori_loop(0, w.shape[1], w_body, w)

    def h_body(f, h):
        err = w @ h.T - y_dense
        w_col = sweeps.take_col(w, f)
        h_col = sweeps.take_col(h, f)
        num = (a_dense * err).T @ w_col + hp.l2 * h_col
        den = a_dense.T @ (w_col * w_col) + hp.l2
        return sweeps.put_col(h, f, h_col - hp.eta * num / den)

    h = jax.lax.fori_loop(0, h.shape[1], h_body, h)
    return MFParams(w, h)


def epoch_dense_mfsi(
    params,
    x_dense: jax.Array,   # (C, p)  materialized context design
    z_dense: jax.Array,   # (I, p') materialized item design
    field_slices,         # tuple of (offset, vocab) per context field
    field_slices_item,    # same for item fields
    y_dense: jax.Array,
    a_dense: jax.Array,
    hp,
):
    """Conventional CD for MFSI on the dense implicit matrix, sweeping in the
    same order as ``repro.core.models.mfsi.epoch`` (dim-major, fields
    sequential, one-hot features vectorized). Oracle for exactness tests."""
    w, h = params
    k = w.shape[1]

    for f in range(k):
        for (off, voc) in field_slices:
            x_g = x_dense[:, off : off + voc]              # (C, vocab)
            err = (x_dense @ w) @ (z_dense @ h).T - y_dense
            psi_col = z_dense @ h[:, f]
            num = x_g.T @ ((a_dense * err) @ psi_col) + hp.l2 * w[off : off + voc, f]
            den = (x_g * x_g).T @ (a_dense @ (psi_col * psi_col)) + hp.l2
            w = w.at[off : off + voc, f].add(-hp.eta * num / jnp.maximum(den, 1e-12))

    for f in range(k):
        for (off, voc) in field_slices_item:
            z_g = z_dense[:, off : off + voc]
            err = (x_dense @ w) @ (z_dense @ h).T - y_dense
            phi_col = x_dense @ w[:, f]
            num = z_g.T @ ((a_dense * err).T @ phi_col) + hp.l2 * h[off : off + voc, f]
            den = (z_g * z_g).T @ (a_dense.T @ (phi_col * phi_col)) + hp.l2
            h = h.at[off : off + voc, f].add(-hp.eta * num / jnp.maximum(den, 1e-12))

    return type(params)(w, h)


def flops_per_epoch_dense(n_ctx: int, n_items: int, k: int) -> float:
    """Conventional CD: each of the 2k column updates recomputes the dense
    error (|C||I|k) and reduces over |C||I|. ≈ 2k·(|C||I|(k+4))."""
    return 2.0 * k * (n_ctx * n_items * (k + 4.0))


def flops_per_epoch_icd(n_ctx: int, n_items: int, nnz: int, k: int) -> float:
    """iCD (paper §5.1): O((|C|+|I|)k² + |S|k) per epoch.
    Grams: (|C|+|I|)k² MACs; sweeps: per column ~6·nnz + (|C|+|I|)·k."""
    return 2.0 * ((n_ctx + n_items) * k * k) + 2.0 * k * (
        6.0 * nnz + (n_ctx + n_items) * k
    )
