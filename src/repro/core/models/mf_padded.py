"""Kernel-fused iCD-MF over the padded observation layout.

Mathematically identical to ``repro.core.models.mf`` (same Newton steps, same
sweep order) but laid out for the Pallas kernels:

  * observations padded per row to the max degree (α=0 on padding) so the
    explicit reductions become dense (bc, D_pad) VPU tiles — no segment ops;
  * J via the ``gram`` MXU kernel;
  * the dimension sweep dispatched through ``sweeps.sweep_columns``: blocks
    of ``hp.block_k`` columns run in the fused ``cd_sweep`` kernel (e/α
    VMEM-resident across the block, ⌈k/k_b⌉ HBM round-trips per sweep
    instead of k); ``hp.block_k=1`` falls back to the per-column
    ``cd_update`` kernel.

CAPACITY: the fused path defaults to the IN-KERNEL GATHER kernels
(``hp.psi_dispatch='gather'``): each block dispatch ships the `(n_items,
k_b)` ψ slab plus the `(C, D_pad)` item-id grid and the kernel gathers Ψ
rows itself, so the `(C, k_b, D_pad)` pre-gathered tile (~k_b× the
residual grid, the PR 1–2 capacity trade) never exists in HBM. The
pre-gathered path remains as ``hp.psi_dispatch='pregather'`` and as the
automatic fallback when the ψ slab alone busts the VMEM budget
(``kernels/vmem.resolve_cd_sweep_dispatch``).

This is the "beyond-paper optimized" §Perf variant; the equivalence test
(tests/test_mf_padded.py) pins it to the reference epoch. Degree-skewed data
should be degree-bucketed before padding (see EXPERIMENTS.md §Perf for the
measured padding overhead; the bucketing hook is ``degree_cap``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sweeps
from repro.core.models.mf import MFHyperParams, MFParams
from repro.kernels import vmem
from repro.kernels.cd_sweep.ops import cd_block_sweep, cd_block_sweep_gather
from repro.kernels.cd_update.ops import cd_column_update
from repro.kernels.gram.ops import gram as gram_kernel
from repro.sparse.interactions import Interactions


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PaddedInteractions:
    """Dual padded layout of the rescaled observed set S̄."""

    # context-major: (n_ctx, d_ctx)
    item_ids: jax.Array
    alpha_c: jax.Array   # 0 on padding
    y_c: jax.Array
    # item-major: (n_items, d_item)
    ctx_ids: jax.Array
    alpha_i: jax.Array
    y_i: jax.Array
    # flat(ctx-major nnz) <-> padded coordinates, for residual transfer
    c_rows: jax.Array    # (nnz,) row in ctx-major padded grid
    c_cols: jax.Array    # (nnz,) slot in ctx-major padded grid
    i_rows: jax.Array    # (nnz,) row in item-major padded grid (ctx-major order)
    i_cols: jax.Array
    n_ctx: int = dataclasses.field(metadata=dict(static=True))
    n_items: int = dataclasses.field(metadata=dict(static=True))


def pad_interactions(data: Interactions, lane: int = 128) -> PaddedInteractions:
    """Host-side: build the dual padded layout (degrees padded to the max,
    slot dim rounded up to the TPU lane width)."""
    ctx = np.asarray(data.ctx)
    item = np.asarray(data.item)
    alpha = np.asarray(data.alpha)
    y = np.asarray(data.y)
    nnz = len(ctx)

    def build(rows, n_rows):
        deg = np.bincount(rows, minlength=n_rows)
        d_pad = max(lane, int(-(-max(1, deg.max()) // lane) * lane))
        slot = np.zeros(nnz, np.int64)
        counter = np.zeros(n_rows, np.int64)
        for j, r in enumerate(rows):  # rows are sorted; cheap slot assignment
            slot[j] = counter[r]
            counter[r] += 1
        return d_pad, slot

    d_c, slot_c = build(ctx, data.n_ctx)
    order_i = np.lexsort((ctx, item))
    d_i, slot_i_sorted = build(item[order_i], data.n_items)
    slot_i = np.empty(nnz, np.int64)
    slot_i[order_i] = slot_i_sorted

    def scatter(shape, rows, cols, vals, dtype, fill=0):
        out = np.full(shape, fill, dtype)
        out[rows, cols] = vals
        return out

    item_ids = scatter((data.n_ctx, d_c), ctx, slot_c, item, np.int32)
    alpha_c = scatter((data.n_ctx, d_c), ctx, slot_c, alpha, np.float32)
    y_c = scatter((data.n_ctx, d_c), ctx, slot_c, y, np.float32)
    ctx_ids = scatter((data.n_items, d_i), item, slot_i, ctx, np.int32)
    alpha_i = scatter((data.n_items, d_i), item, slot_i, alpha, np.float32)
    y_i = scatter((data.n_items, d_i), item, slot_i, y, np.float32)

    return PaddedInteractions(
        item_ids=jnp.asarray(item_ids), alpha_c=jnp.asarray(alpha_c),
        y_c=jnp.asarray(y_c),
        ctx_ids=jnp.asarray(ctx_ids), alpha_i=jnp.asarray(alpha_i),
        y_i=jnp.asarray(y_i),
        c_rows=jnp.asarray(ctx, dtype=jnp.int32),
        c_cols=jnp.asarray(slot_c, dtype=jnp.int32),
        i_rows=jnp.asarray(item, dtype=jnp.int32),
        i_cols=jnp.asarray(slot_i, dtype=jnp.int32),
        n_ctx=data.n_ctx, n_items=data.n_items,
    )


def scatter_ctx_major(pdata: PaddedInteractions, e_flat: jax.Array) -> jax.Array:
    """Flat per-nnz vector (ctx-major order) → ctx-major padded grid."""
    return jnp.zeros_like(pdata.alpha_c).at[pdata.c_rows, pdata.c_cols].set(e_flat)


def transfer_ctx_to_item(pdata: PaddedInteractions, e_pad: jax.Array) -> jax.Array:
    """Residual grid ctx-major → item-major through the flat nnz order."""
    e_flat = e_pad[pdata.c_rows, pdata.c_cols]
    return jnp.zeros_like(pdata.alpha_i).at[pdata.i_rows, pdata.i_cols].set(e_flat)


def transfer_item_to_ctx(pdata: PaddedInteractions, e_pad_i: jax.Array) -> jax.Array:
    """Inverse of :func:`transfer_ctx_to_item`."""
    e_flat = e_pad_i[pdata.i_rows, pdata.i_cols]
    return jnp.zeros_like(pdata.alpha_c).at[pdata.c_rows, pdata.c_cols].set(e_flat)


def _padded_side_sweep(side, other, other_j, ids_pad, alpha_pad, e_pad, hp):
    k = side.shape[1]
    k_b = sweeps.resolve_block_k(hp.block_k, k)
    n = side.shape[0]
    use_block = k_b > 1 and not hp.unroll  # unroll = explicit per-column ask

    # Ψ routing + row tile of the cd_sweep dispatches (shared VMEM budget):
    # in-kernel gather by default, pre-gathered tile when pinned or when the
    # ψ slab alone does not fit VMEM.
    use_gather, block_ctx = vmem.resolve_cd_sweep_dispatch(
        ids_pad.shape[1], k_b, other.shape[0], n_rows=n,
        prefer_gather=sweeps.resolve_psi_dispatch(hp.psi_dispatch),
    )

    if use_block:
        # Pad rows to the kernel tile ONCE per sweep — otherwise every block
        # dispatch would pad/slice the full (C, D_pad) grids itself,
        # re-introducing the per-dispatch HBM copies the fused kernel
        # removes (and breaking the e→e_out alias, which would then point
        # at a padded temp). Padding rows have α=0 ⇒ Δ=0, so they are inert.
        n_pad = -(-n // block_ctx) * block_ctx
        if n_pad != n:
            rows = ((0, n_pad - n), (0, 0))
            ids_pad = jnp.pad(ids_pad, rows)
            alpha_pad = jnp.pad(alpha_pad, rows)
            e_pad = jnp.pad(e_pad, rows)
            side = jnp.pad(side, rows)

    def body(f, carry):
        side_m, e_pad = carry
        psi_pad = jnp.take(sweeps.take_col(other, f), ids_pad)   # (n, d_pad)
        r1 = side_m @ sweeps.take_col(other_j, f)
        w_new, e_pad = cd_column_update(
            psi_pad, alpha_pad, e_pad, sweeps.take_col(side_m, f), r1,
            other_j[f, f], alpha0=hp.alpha0, l2=hp.l2, eta=hp.eta,
        )
        return sweeps.put_col(side_m, f, w_new), e_pad

    def block_body(f0, kb, carry):
        side_m, e_pad = carry
        r1_blk = side_m @ other_j[:, f0:f0 + kb]                 # R'/2 slab
        if use_gather:
            # ψ slab (n_items, kb) + id grid — the kernel gathers Ψ rows
            w_new, e_pad = cd_block_sweep_gather(
                other[:, f0:f0 + kb], ids_pad, alpha_pad, e_pad,
                side_m[:, f0:f0 + kb], r1_blk,
                other_j[f0:f0 + kb, f0:f0 + kb],
                alpha0=hp.alpha0, l2=hp.l2, eta=hp.eta,
                block_ctx=block_ctx,
            )
        else:
            # pre-gathered Ψ tile (n, kb, d_pad) — the capacity fallback
            psi_blk = jnp.moveaxis(
                jnp.take(other[:, f0:f0 + kb], ids_pad, axis=0), -1, 1
            )
            w_new, e_pad = cd_block_sweep(
                psi_blk, alpha_pad, e_pad, side_m[:, f0:f0 + kb], r1_blk,
                other_j[f0:f0 + kb, f0:f0 + kb],
                alpha0=hp.alpha0, l2=hp.l2, eta=hp.eta,
                block_ctx=block_ctx,
            )
        return side_m.at[:, f0:f0 + kb].set(w_new), e_pad

    side, e_pad = sweeps.sweep_columns(
        k, body, (side, e_pad), unroll=hp.unroll,
        block=k_b, block_body=block_body if use_block else None,
    )
    return side[:n], e_pad[:n]


def reweight_padded(pdata: PaddedInteractions, weights: jax.Array) -> PaddedInteractions:
    """Fold per-interaction weights (flat nnz, ctx-major order) into both
    padded α grids: α_eff = α·w on real slots, padding stays α=0 (the w grid
    defaults to 1 where no observation lands)."""
    w_c = jnp.ones_like(pdata.alpha_c).at[pdata.c_rows, pdata.c_cols].set(weights)
    w_i = jnp.ones_like(pdata.alpha_i).at[pdata.i_rows, pdata.i_cols].set(weights)
    return dataclasses.replace(
        pdata, alpha_c=pdata.alpha_c * w_c, alpha_i=pdata.alpha_i * w_i
    )


@partial(jax.jit, static_argnames=("hp",), donate_argnums=(2,))
def epoch(
    params: MFParams, pdata: PaddedInteractions, e_pad: jax.Array,
    hp: MFHyperParams, weights: jax.Array | None = None,
) -> Tuple[MFParams, jax.Array]:
    """Kernel-fused iCD epoch; carries the ctx-major padded residual grid.

    ``e_pad`` is donated — it is the largest tensor carried ACROSS epochs
    and is replaced every call, so on donation-capable backends the
    caller's buffer is reused instead of holding a second (C, D_pad) fp32
    grid across the call. (Within an epoch the fused path's Ψ tile is
    bigger — see the module docstring's capacity note.) Callers must
    rebind (``params, e_pad = epoch(...)``), which every sweep/fit loop
    already does.

    ``weights`` (optional, flat nnz ctx-major) folds per-interaction
    confidence into both α grids exactly (α is purely multiplicative in the
    explicit loss parts); ``None`` traces the identical unweighted program."""
    if weights is not None:
        pdata = reweight_padded(pdata, weights)
    w, h = params

    j_i = gram_kernel(h)
    w, e_pad = _padded_side_sweep(w, h, j_i, pdata.item_ids, pdata.alpha_c, e_pad, hp)

    e_pad_i = transfer_ctx_to_item(pdata, e_pad)

    j_c = gram_kernel(w)
    h, e_pad_i = _padded_side_sweep(h, w, j_c, pdata.ctx_ids, pdata.alpha_i, e_pad_i, hp)

    e_pad = transfer_item_to_ctx(pdata, e_pad_i)
    return MFParams(w, h), e_pad


def residuals(params: MFParams, pdata: PaddedInteractions) -> jax.Array:
    """ŷ−ȳ on the ctx-major padded grid (garbage on padding, α=0 kills it)."""
    scores = jnp.sum(
        params.w[:, None, :] * jnp.take(params.h, pdata.item_ids, axis=0), axis=-1
    )
    return scores - pdata.y_c


def fit(params, pdata, hp, n_epochs, weights=None):
    e_pad = residuals(params, pdata)
    for _ in range(n_epochs):
        params, e_pad = epoch(params, pdata, e_pad, hp, weights)
    return params
