"""GraphSAGE-Reddit [arXiv:1706.02216] — 2 layers, mean agg, fanout 25-10."""
import dataclasses

from repro.configs.base import GNN_SHAPES, GNNConfig

CONFIG = GNNConfig(
    name="graphsage-reddit",
    n_layers=2,
    d_hidden=128,
    aggregator="mean",
    sample_sizes=(25, 10),
    n_classes=41,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, d_hidden=16, sample_sizes=(4, 3), n_classes=5,
)

SHAPES = GNN_SHAPES
