"""Config dataclasses shared by the zoo, launcher and dry-run."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden dim
    n_shared: int = 0             # always-on shared experts (DeepSeekMoE)
    first_k_dense: int = 0        # leading dense layers (DeepSeekMoE)
    d_ff_dense: int = 0           # hidden dim of those dense layers
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    act: str = "swiglu"                   # 'swiglu' | 'geglu'
    qkv_bias: bool = False                # Qwen1.5
    attn_window: Optional[int] = None     # sliding window (local layers)
    local_global_alternating: bool = False  # Gemma-2
    attn_softcap: Optional[float] = None  # Gemma-2: 50.0
    final_softcap: Optional[float] = None # Gemma-2: 30.0
    post_norms: bool = False              # Gemma-2 post-block RMSNorm
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    moe: Optional[MoEConfig] = None
    # performance knobs (per-arch defaults, overridable by the launcher)
    num_microbatches: int = 1
    remat: bool = True
    sequence_parallel: bool = True
    scan_layers: bool = True
    wire_barriers: bool = False  # optimization_barrier at block boundaries:
    # stops XLA hoisting the rms_norm fp32 upcast through the activation
    # collectives (measured 2× wire inflation — EXPERIMENTS.md §Perf #2)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One (arch × input-shape) cell of the assignment."""

    name: str
    kind: str                    # 'train' | 'prefill' | 'decode' | 'serve' | ...
    seq_len: int = 0
    global_batch: int = 0
    extras: Tuple[Tuple[str, object], ...] = ()
    skip: Optional[str] = None   # reason string ⇒ documented skip

    def extra(self, key, default=None):
        return dict(self.extras).get(key, default)


LM_SHAPES = (
    ShapeSpec("train_4k", "train", seq_len=4096, global_batch=256),
    ShapeSpec("prefill_32k", "prefill", seq_len=32768, global_batch=32),
    ShapeSpec("decode_32k", "decode", seq_len=32768, global_batch=128),
    ShapeSpec("long_500k", "decode", seq_len=524288, global_batch=1),
)


def lm_shapes(long_context_skip: Optional[str] = None):
    out = []
    for s in LM_SHAPES:
        if s.name == "long_500k" and long_context_skip:
            s = dataclasses.replace(s, skip=long_context_skip)
        out.append(s)
    return {s.name: s for s in out}


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    kind: str                      # 'dlrm' | 'din' | 'dcn' | 'bst'
    n_dense: int = 0
    n_sparse: int = 0
    embed_dim: int = 0
    table_vocabs: Tuple[int, ...] = ()
    bot_mlp: Tuple[int, ...] = ()
    top_mlp: Tuple[int, ...] = ()
    n_cross_layers: int = 0
    mlp: Tuple[int, ...] = ()
    seq_len: int = 0
    attn_mlp: Tuple[int, ...] = ()
    n_blocks: int = 0
    n_heads: int = 0
    item_vocab: int = 0


RECSYS_SHAPES = {
    "train_batch": ShapeSpec("train_batch", "train", global_batch=65536),
    "serve_p99": ShapeSpec("serve_p99", "serve", global_batch=512),
    "serve_bulk": ShapeSpec("serve_bulk", "serve", global_batch=262144),
    "retrieval_cand": ShapeSpec(
        "retrieval_cand", "retrieval", global_batch=1,
        extras=(("n_candidates", 1_000_000),),
    ),
}


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int
    d_hidden: int
    aggregator: str
    sample_sizes: Tuple[int, ...]
    n_classes: int = 41


GNN_SHAPES = {
    "full_graph_sm": ShapeSpec(
        "full_graph_sm", "train",
        extras=(("n_nodes", 2708), ("n_edges", 10556), ("d_feat", 1433),
                ("mode", "full")),
    ),
    "minibatch_lg": ShapeSpec(
        "minibatch_lg", "train",
        extras=(("n_nodes", 232_965), ("n_edges", 114_615_892),
                ("batch_nodes", 1024), ("fanout", (15, 10)), ("d_feat", 602),
                ("mode", "minibatch")),
    ),
    "ogb_products": ShapeSpec(
        "ogb_products", "train",
        extras=(("n_nodes", 2_449_029), ("n_edges", 61_859_140),
                ("d_feat", 100), ("mode", "full")),
    ),
    "molecule": ShapeSpec(
        "molecule", "train",
        extras=(("n_nodes", 30), ("n_edges", 64), ("batch", 128),
                ("d_feat", 16), ("mode", "batched")),
    ),
}


@dataclasses.dataclass(frozen=True)
class ICDConfig:
    """Production config for the paper's own models."""

    name: str
    model: str            # 'mf' | 'fm'
    n_ctx: int
    n_items: int
    k: int
    alpha0: float = 1.0
    l2: float = 0.1
    # fm extras
    p_ctx: int = 0
    p_item: int = 0


ICD_SHAPES = {
    "epoch_youtube": ShapeSpec(
        "epoch_youtube", "train",
        extras=(("n_ctx", 200_000), ("n_items", 68_000), ("nnz", 20_000_000)),
    ),
    "epoch_web": ShapeSpec(
        "epoch_web", "train",
        extras=(("n_ctx", 10_000_000), ("n_items", 1_000_000),
                ("nnz", 500_000_000)),
    ),
    "retrieval": ShapeSpec(
        "retrieval", "retrieval", global_batch=4096,
        extras=(("n_candidates", 1_000_000),),
    ),
}
