"""MFSI iCD: exactness vs dense conventional CD, and multi-hot convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import naive_cd
from repro.core.design import make_design, to_dense
from repro.core.models import mfsi
from repro.sparse.interactions import build_interactions


def make_problem(seed=0, n_ctx=14, n_items=10, nnz=40, alpha0=0.3, with_bag=False):
    rng = np.random.default_rng(seed)
    # context fields: user-country (4), age-bucket (3), optional history bag
    fields = [
        dict(name="country", ids=rng.integers(0, 4, n_ctx), vocab=4),
        dict(name="age", ids=rng.integers(0, 3, n_ctx), vocab=3),
    ]
    if with_bag:
        fields.append(
            dict(
                name="hist",
                ids=np.stack([rng.choice(6, 3, replace=False) for _ in range(n_ctx)]),
                vocab=6,
                weights=np.full((n_ctx, 3), 1 / 3, np.float32),
            )
        )
    x = make_design(fields, n_ctx)
    z = make_design(
        [
            dict(name="item_id", ids=np.arange(n_items), vocab=n_items),
            dict(name="genre", ids=rng.integers(0, 5, n_items), vocab=5),
        ],
        n_items,
    )
    pairs = rng.choice(n_ctx * n_items, size=nnz, replace=False)
    ctx, item = pairs // n_items, pairs % n_items
    y = rng.integers(1, 5, size=nnz).astype(np.float64)
    alpha = alpha0 + 1.0 + rng.random(nnz)
    data = build_interactions(ctx, item, y, alpha, n_ctx, n_items, alpha0=alpha0)
    y_dense, a_dense = naive_cd.dense_from_observed(
        jnp.asarray(ctx), jnp.asarray(item), jnp.asarray(y, jnp.float32),
        jnp.asarray(alpha, jnp.float32), n_ctx, n_items, alpha0,
    )
    return x, z, data, y_dense, a_dense


@pytest.mark.parametrize("k", [2, 5])
def test_mfsi_matches_naive_cd_one_hot(k):
    x, z, data, y_dense, a_dense = make_problem()
    hp = mfsi.MFSIHyperParams(k=k, alpha0=0.3, l2=0.05)
    params = mfsi.init(jax.random.PRNGKey(1), x.p, z.p, k)
    params_naive = params

    x_dense, z_dense = to_dense(x), to_dense(z)
    fs = tuple((f.offset, f.vocab) for f in x.fields)
    fsi = tuple((f.offset, f.vocab) for f in z.fields)

    e = mfsi.residuals(params, x, z, data)
    for _ in range(2):
        params, e = mfsi.epoch(params, x, z, data, e, hp)
        params_naive = naive_cd.epoch_dense_mfsi(
            params_naive, x_dense, z_dense, fs, fsi, y_dense, a_dense, hp
        )
        np.testing.assert_allclose(params.w, params_naive.w, rtol=3e-4, atol=3e-5)
        np.testing.assert_allclose(params.h, params_naive.h, rtol=3e-4, atol=3e-5)


def test_mfsi_residual_cache_consistency():
    x, z, data, _, _ = make_problem(seed=2)
    hp = mfsi.MFSIHyperParams(k=3, alpha0=0.3, l2=0.1)
    params = mfsi.init(jax.random.PRNGKey(2), x.p, z.p, 3)
    e = mfsi.residuals(params, x, z, data)
    for _ in range(2):
        params, e = mfsi.epoch(params, x, z, data, e, hp)
    np.testing.assert_allclose(
        e, mfsi.residuals(params, x, z, data), rtol=2e-4, atol=2e-5
    )


# ------------------------------------------ fused (padded) block parity ----
# fast gate: one representative (multi-hot jacobi, non-divisible k=5/k_b=3);
# the full (mode × block_k) matrix rides the slow suite.
_MFSI_FUSED_CASES = [
    pytest.param(w, m, bk, marks=() if (w, m, bk) == (True, "jacobi", 3)
                 else pytest.mark.slow)
    for w, m in ((False, "jacobi"), (True, "jacobi"), (True, "slot"))
    for bk in (1, 3, 5)
]


@pytest.mark.parametrize("with_bag,mode,block_k", _MFSI_FUSED_CASES)
def test_mfsi_fused_matches_per_column(with_bag, mode, block_k):
    """epoch_padded (cd_slab_reduce + cd_resid_patch blocks) must track the
    per-dimension epoch trajectory — one-hot exact, both multi-hot modes,
    incl. the non-divisible k=5/block_k=3 split and block_k=1."""
    x, z, data, _, _ = make_problem(seed=6, with_bag=with_bag)
    k = 5
    hp = mfsi.MFSIHyperParams(k=k, alpha0=0.3, l2=0.05, multi_hot_mode=mode,
                              block_k=block_k)
    params = mfsi.init(jax.random.PRNGKey(5), x.p, z.p, k)
    pdata = mfsi.pad_interactions(data)
    ref, got = params, params
    e = mfsi.residuals(params, x, z, data)
    e_pad = mfsi.residuals_padded(params, x, z, data, pdata)
    for _ in range(2):
        ref, e = mfsi.epoch(ref, x, z, data, e, hp)
        got, e_pad = mfsi.epoch_padded(got, x, z, pdata, e_pad, hp)
    np.testing.assert_allclose(got.w, ref.w, rtol=5e-4, atol=1e-5)
    np.testing.assert_allclose(got.h, ref.h, rtol=5e-4, atol=1e-5)
    # the padded residual grid stays consistent with the flat cache
    np.testing.assert_allclose(
        e_pad[pdata.c_rows, pdata.c_cols], e, rtol=5e-4, atol=5e-5
    )


def test_mfsi_fused_gather_matches_pregather():
    """The in-kernel-gather Ψ routing (default) must reproduce the
    pre-gathered routing to reduction roundoff (the gather kernel's einsum
    contracts in (d, m) layout) — non-divisible k=5/block_k=3, multi-hot
    bags included."""
    import dataclasses

    x, z, data, _, _ = make_problem(seed=9, with_bag=True)
    k = 5
    base = mfsi.MFSIHyperParams(k=k, alpha0=0.3, l2=0.05, block_k=3)
    params = mfsi.init(jax.random.PRNGKey(8), x.p, z.p, k)
    pdata = mfsi.pad_interactions(data)
    finals = {}
    for disp in ("gather", "pregather"):
        hp = dataclasses.replace(base, psi_dispatch=disp)
        p, e_pad = params, mfsi.residuals_padded(params, x, z, data, pdata)
        for _ in range(2):
            p, e_pad = mfsi.epoch_padded(p, x, z, pdata, e_pad, hp)
        finals[disp] = (p, e_pad)
    np.testing.assert_allclose(finals["gather"][0].w, finals["pregather"][0].w,
                               rtol=5e-5, atol=1e-5)
    np.testing.assert_allclose(finals["gather"][0].h, finals["pregather"][0].h,
                               rtol=5e-5, atol=1e-5)
    np.testing.assert_allclose(finals["gather"][1], finals["pregather"][1],
                               rtol=5e-5, atol=1e-5)


def test_mfsi_fused_matches_naive_cd():
    """Fused padded epoch ≡ conventional CD on the dense implicit matrix
    (one-hot fields — exact CD on both sides)."""
    x, z, data, y_dense, a_dense = make_problem(seed=7)
    k = 4
    hp = mfsi.MFSIHyperParams(k=k, alpha0=0.3, l2=0.05, block_k=3)
    params = mfsi.init(jax.random.PRNGKey(6), x.p, z.p, k)
    params_naive = params
    x_dense, z_dense = to_dense(x), to_dense(z)
    fs = tuple((f.offset, f.vocab) for f in x.fields)
    fsi = tuple((f.offset, f.vocab) for f in z.fields)
    pdata = mfsi.pad_interactions(data)
    e_pad = mfsi.residuals_padded(params, x, z, data, pdata)
    for _ in range(2):
        params, e_pad = mfsi.epoch_padded(params, x, z, pdata, e_pad, hp)
        params_naive = naive_cd.epoch_dense_mfsi(
            params_naive, x_dense, z_dense, fs, fsi, y_dense, a_dense, hp
        )
        np.testing.assert_allclose(params.w, params_naive.w, rtol=3e-4, atol=3e-5)
        np.testing.assert_allclose(params.h, params_naive.h, rtol=3e-4, atol=3e-5)


@pytest.mark.parametrize("mode", ["jacobi", "slot"])
def test_mfsi_multi_hot_converges(mode):
    x, z, data, _, _ = make_problem(seed=4, with_bag=True)
    hp = mfsi.MFSIHyperParams(k=3, alpha0=0.3, l2=0.05, multi_hot_mode=mode)
    params = mfsi.init(jax.random.PRNGKey(3), x.p, z.p, 3)
    start = float(mfsi.objective(params, x, z, data, hp))
    e = mfsi.residuals(params, x, z, data)
    prev = start
    for _ in range(8):
        params, e = mfsi.epoch(params, x, z, data, e, hp)
        cur = float(mfsi.objective(params, x, z, data, hp))
        if mode == "jacobi":  # damped parallel steps are monotone in practice
            assert cur <= prev + 1e-3
        prev = cur
    # both modes must clearly reduce the objective overall
    assert prev < 0.7 * start
