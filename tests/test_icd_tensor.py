"""PARAFAC + Tucker iCD: exactness vs autodiff-Newton on the dense implicit
objective, dense-context decomposition (eq. 39), and convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.models import parafac, tucker
from repro.core.models.parafac import TensorContext
from repro.sparse.interactions import build_interactions


def make_problem(seed=0, n_c1=5, n_c2=4, n_items=6, n_pairs=12, nnz=25,
                 alpha0=0.3, dense_ctx=False):
    rng = np.random.default_rng(seed)
    if dense_ctx:
        n_pairs = n_c1 * n_c2
        pair_list = np.stack(
            [np.repeat(np.arange(n_c1), n_c2), np.tile(np.arange(n_c2), n_c1)], 1
        )
    else:
        chosen = rng.choice(n_c1 * n_c2, size=n_pairs, replace=False)
        pair_list = np.stack([chosen // n_c2, chosen % n_c2], 1)
    tc = TensorContext(
        c1=jnp.asarray(pair_list[:, 0], jnp.int32),
        c2=jnp.asarray(pair_list[:, 1], jnp.int32),
        n_c1=n_c1, n_c2=n_c2,
    )
    cells = rng.choice(n_pairs * n_items, size=nnz, replace=False)
    ctx, item = cells // n_items, cells % n_items
    y = rng.integers(1, 4, size=nnz).astype(np.float64)
    alpha = alpha0 + 1.0 + rng.random(nnz)
    data = build_interactions(ctx, item, y, alpha, n_pairs, n_items, alpha0=alpha0)
    # dense grids over the (pair, item) universe for the oracle
    y_dense = np.zeros((n_pairs, n_items), np.float32)
    a_dense = np.full((n_pairs, n_items), alpha0, np.float32)
    y_dense[ctx, item] = y
    a_dense[ctx, item] = alpha
    return tc, data, jnp.asarray(y_dense), jnp.asarray(a_dense)


def _newton_layer(loss_fn, params, path, mask, eta=1.0):
    theta = getattr(params, path)

    def f(t):
        return loss_fn(params._replace(**{path: t}))

    g = jax.grad(f)(theta)
    basis = jnp.eye(theta.size, dtype=theta.dtype).reshape((theta.size,) + theta.shape)
    diag = jax.vmap(lambda v: jnp.vdot(v, jax.jvp(jax.grad(f), (theta,), (v,))[1]))(basis)
    step = jnp.where(mask, -eta * g / jnp.maximum(diag.reshape(theta.shape), 1e-12), 0.0)
    return params._replace(**{path: theta + step})


@pytest.mark.parametrize("dense_ctx", [False, True])
def test_parafac_matches_autodiff_newton(dense_ctx):
    tc, data, y_dense, a_dense = make_problem(seed=1, dense_ctx=dense_ctx)
    k = 3
    hp = parafac.PARAFACHyperParams(k=k, alpha0=0.3, l2=0.05, dense_context=dense_ctx)
    params = parafac.init(jax.random.PRNGKey(0), tc.n_c1, tc.n_c2, data.n_items, k)

    def dense_loss(p):
        phi = jnp.take(p.u, tc.c1, axis=0) * jnp.take(p.v, tc.c2, axis=0)
        s = phi @ p.w.T
        reg = hp.l2 * sum(jnp.sum(q**2) for q in p)
        return jnp.sum(a_dense * (s - y_dense) ** 2) + reg

    oracle = params
    for f in range(k):
        m = jnp.zeros((tc.n_c1, k), bool).at[:, f].set(True)
        oracle = _newton_layer(dense_loss, oracle, "u", m)
    for f in range(k):
        m = jnp.zeros((tc.n_c2, k), bool).at[:, f].set(True)
        oracle = _newton_layer(dense_loss, oracle, "v", m)
    for f in range(k):
        m = jnp.zeros((data.n_items, k), bool).at[:, f].set(True)
        oracle = _newton_layer(dense_loss, oracle, "w", m)

    e = parafac.residuals(params, tc, data)
    got, _ = parafac.epoch(params, tc, data, e, hp)
    np.testing.assert_allclose(got.u, oracle.u, rtol=5e-4, atol=5e-5)
    np.testing.assert_allclose(got.v, oracle.v, rtol=5e-4, atol=5e-5)
    np.testing.assert_allclose(got.w, oracle.w, rtol=5e-4, atol=5e-5)


def test_parafac_dense_context_gram_identity():
    """eq. 39: with C = C1×C2, Gram(Φ) == Gram(U) ⊙ Gram(V)."""
    tc, data, _, _ = make_problem(seed=2, dense_ctx=True)
    params = parafac.init(jax.random.PRNGKey(1), tc.n_c1, tc.n_c2, data.n_items, 4)
    from repro.core.gram import gram

    full = gram(parafac.phi(params, tc))
    fast = gram(params.u) * gram(params.v)
    np.testing.assert_allclose(full, fast, rtol=1e-4, atol=1e-5)


def test_parafac_objective_decreases():
    tc, data, _, _ = make_problem(seed=3, n_pairs=15, nnz=40)
    hp = parafac.PARAFACHyperParams(k=3, alpha0=0.3, l2=0.05)
    params = parafac.init(jax.random.PRNGKey(2), tc.n_c1, tc.n_c2, data.n_items, 3)
    start = float(parafac.objective(params, tc, data, hp))
    prev = start
    e = parafac.residuals(params, tc, data)
    for _ in range(8):
        params, e = parafac.epoch(params, tc, data, e, hp)
        cur = float(parafac.objective(params, tc, data, hp))
        assert cur <= prev + 1e-4
        prev = cur
    assert prev < 0.8 * start


def test_tucker_matches_autodiff_newton():
    tc, data, y_dense, a_dense = make_problem(seed=4)
    k1, k2, k3 = 2, 3, 2
    hp = tucker.TuckerHyperParams(k1=k1, k2=k2, k3=k3, alpha0=0.3, l2=0.05, l2_core=0.02)
    params = tucker.init(
        jax.random.PRNGKey(3), tc.n_c1, tc.n_c2, data.n_items, k1, k2, k3
    )

    def dense_loss(p):
        up = jnp.take(p.u, tc.c1, axis=0)
        vp = jnp.take(p.v, tc.c2, axis=0)
        phi = jnp.einsum("na,nb,abf->nf", up, vp, p.b)
        s = phi @ p.w.T
        reg = hp.l2 * (jnp.sum(p.u**2) + jnp.sum(p.v**2) + jnp.sum(p.w**2))
        reg += hp.l2_core * jnp.sum(p.b**2)
        return jnp.sum(a_dense * (s - y_dense) ** 2) + reg

    oracle = params
    for f in range(k1):
        m = jnp.zeros((tc.n_c1, k1), bool).at[:, f].set(True)
        oracle = _newton_layer(dense_loss, oracle, "u", m)
    for f in range(k2):
        m = jnp.zeros((tc.n_c2, k2), bool).at[:, f].set(True)
        oracle = _newton_layer(dense_loss, oracle, "v", m)
    for f1 in range(k1):          # core: strictly sequential scalar steps
        for f2 in range(k2):
            for f3 in range(k3):
                m = jnp.zeros((k1, k2, k3), bool).at[f1, f2, f3].set(True)
                oracle = _newton_layer(dense_loss, oracle, "b", m)
    for f in range(k3):
        m = jnp.zeros((data.n_items, k3), bool).at[:, f].set(True)
        oracle = _newton_layer(dense_loss, oracle, "w", m)

    e = tucker.residuals(params, tc, data)
    got, _ = tucker.epoch(params, tc, data, e, hp)
    np.testing.assert_allclose(got.u, oracle.u, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(got.v, oracle.v, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(got.b, oracle.b, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(got.w, oracle.w, rtol=1e-3, atol=1e-4)


def test_tucker_objective_decreases():
    tc, data, _, _ = make_problem(seed=5, n_pairs=15, nnz=40)
    hp = tucker.TuckerHyperParams(k1=2, k2=2, k3=3, alpha0=0.3, l2=0.05)
    params = tucker.init(jax.random.PRNGKey(4), tc.n_c1, tc.n_c2, data.n_items, 2, 2, 3)
    start = float(tucker.objective(params, tc, data, hp))
    params = tucker.fit(params, tc, data, hp, n_epochs=8)
    assert float(tucker.objective(params, tc, data, hp)) < 0.85 * start
