"""Quickstart: train iCD-MF on synthetic implicit feedback and evaluate.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core.metrics import recall_at_k
from repro.core.models import mf
from repro.data.synthetic import make_implicit_dataset
from repro.sparse.interactions import build_interactions


def main():
    ds = make_implicit_dataset(n_users=400, n_items=800, pop_strength=0.4,
                               taste_strength=2.5, seed=0)
    events = ds.events

    # leave-one-out split
    last = {}
    for idx, (u, i, t) in enumerate(events):
        last[u] = idx
    held = set(last.values())
    train = events[[i for i in range(len(events)) if i not in held]]

    # Lemma 1: rescale observed feedback, keep α₀ for the implicit zeros
    alpha0 = 0.5
    pairs = np.unique(train[:, :2], axis=0)
    data = build_interactions(
        pairs[:, 0], pairs[:, 1], np.ones(len(pairs)),
        np.full(len(pairs), alpha0 + 4.0),
        ds.n_users, ds.n_items, alpha0=alpha0,
    )

    hp = mf.MFHyperParams(k=16, alpha0=alpha0, l2=0.05)
    params = mf.init(jax.random.PRNGKey(0), ds.n_users, ds.n_items, 16)

    def log(ep, p):
        if (ep + 1) % 5 == 0:
            print(f"epoch {ep + 1:3d}  objective {float(mf.objective(p, data, hp)):.2f}")

    params = mf.fit(params, data, hp, n_epochs=20, callback=log)

    # evaluate Recall@10 on the held-out last items
    users = np.asarray(sorted(last))
    truth = np.asarray([events[last[u]][1] for u in users])
    scores = mf.scores_all(params)[users]
    r = float(recall_at_k(scores, truth, 10))
    pop = np.bincount(train[:, 1], minlength=ds.n_items)
    r_pop = float(recall_at_k(np.tile(pop, (len(users), 1)), truth, 10))
    print(f"\nRecall@10: iCD-MF {r:.3f}  vs popularity {r_pop:.3f}")
    assert r > r_pop, "iCD-MF should beat popularity on this data"


if __name__ == "__main__":
    main()
