"""Gradient clipping."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.base import global_norm


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), norm
