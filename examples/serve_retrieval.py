"""Serving: the sharded online retrieval service end-to-end — train an
iCD-MF model, publish its ψ table into a multi-shard cluster at every epoch
boundary (double-buffered, versioned), answer micro-batched single-row
queries through the admission queue, run the streaming leave-one-out
ranking eval over the same sharded table, then harden it: replicate the
shards into a fault-tolerant mesh, kill replicas mid-traffic (bit-identical
failover under R=2, labeled degradation when a range loses every copy),
heal, and gate a ψ publish behind the canary staged rollout.

Every path is the paper-native k-separable product ⟨φ(context), ψ(item)⟩
(§5.1): per shard the fused Pallas score+top-k kernel streams ψ-table
blocks through VMEM with a running top-K merge (the (B, n_items) score
matrix is never materialized), and the cross-shard K-way merge reproduces
the single-device engine bit-for-bit.

    PYTHONPATH=src python examples/serve_retrieval.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.models import mf
from repro.eval.ranking import ranking_eval
from repro.serve.batcher import MicroBatcher
from repro.serve.cluster import ShardedRetrievalCluster
from repro.serve.engine import RetrievalEngine
from repro.serve.mesh import (
    FaultInjector,
    FaultTolerantRetrievalMesh,
    RetryPolicy,
)
from repro.serve.publish import PsiPublisher, StagedRollout
from repro.sparse.interactions import build_interactions


def main():
    n_users, n_items, k, n_shards = 1000, 50_000, 64, 4
    rng = np.random.default_rng(0)
    params = mf.init(jax.random.PRNGKey(0), n_users, n_items, k)

    # --- train → publish: live ψ refresh at every epoch boundary ---------
    nnz = 20_000
    cells = rng.choice(n_users * n_items, size=nnz, replace=False)
    data = build_interactions(
        cells // n_items, cells % n_items, rng.integers(1, 5, nnz),
        1.0 + rng.random(nnz), n_users, n_items, alpha0=0.1,
    )
    cluster = ShardedRetrievalCluster(
        lambda ctx: mf.build_phi(params, ctx), n_shards=n_shards, k=100
    )
    pub = PsiPublisher(cluster, mf.export_psi, every=1)
    hp = mf.MFHyperParams(k=k, alpha0=0.1, l2=0.05)
    params = mf.fit(params, data, hp, n_epochs=2, callback=pub)
    cluster.phi_fn = lambda ctx: mf.build_phi(params, ctx)
    print(f"published versions {[v for _, v in pub.versions]}: "
          f"{n_items} items over {n_shards} shards "
          f"(rows_per={cluster.table.rows_per})")

    # --- batched online queries over the sharded table -------------------
    for batch in (8, 64):
        ctx = jnp.arange(batch)
        _, warm_ids = cluster.topk(ctx)  # warmup (trace+compile)
        jax.block_until_ready(warm_ids)
        t0 = time.perf_counter()
        scores, ids = cluster.topk(ctx)
        jax.block_until_ready(ids)
        dt = time.perf_counter() - t0
        print(f"batch={batch:3d}: {dt * 1e3:7.2f} ms "
              f"({batch * n_items / dt / 1e6:.1f} M cand/s over "
              f"{n_shards} shards)")

    # --- sharded cluster vs single-device engine vs dense lax.top_k ------
    engine = RetrievalEngine(
        mf.export_psi(params), lambda ctx: mf.build_phi(params, ctx), k=100
    )
    cs, ci = cluster.topk(jnp.arange(8))
    es, ei = engine.topk(jnp.arange(8))
    assert bool((ci == ei).all()) and bool((cs == es).all())
    dense = jax.lax.top_k(params.w[:8] @ params.h.T, 100)[1]
    assert bool((ci == dense).all())
    print("cluster top-k == engine top-k == dense top-k ✓")

    # --- micro-batched single-row requests (the online p99 path) ---------
    batcher = MicroBatcher(
        lambda phi, eids: cluster.topk_phi(phi, exclude_ids=eids),
        max_batch=16, max_delay=2e-3,
        version_fn=lambda: cluster.version,
    )
    users = rng.integers(0, n_users, size=48)
    phi_all = np.asarray(mf.build_phi(params, jnp.arange(n_users)))
    t0 = time.perf_counter()
    tickets = [
        batcher.submit(phi_all[u], exclude=rng.choice(n_items, size=5),
                       key=("user", int(u)))
        for u in users
    ]
    batcher.flush()
    dt = time.perf_counter() - t0
    assert all(batcher.result(t) is not None for t in tickets)
    print(f"batcher: {len(users)} single-row requests in {dt * 1e3:.1f} ms, "
          f"{batcher.stats['flushes']} flushes "
          f"(size={batcher.stats['flush_by_size']} "
          f"forced={batcher.stats['flush_forced']}), "
          f"cache_hits={batcher.stats['cache_hits']} ✓")

    # --- streaming sharded eval: full catalogue, no (n_eval, n_items) ----
    n_eval = 512
    true_items = rng.integers(0, n_items, size=n_eval)
    res = ranking_eval(
        mf.build_phi(params, jnp.arange(n_eval)), None, true_items,
        k=100, batch_rows=256, cluster=cluster,
        exclude=[rng.choice(n_items, size=20, replace=False)
                 for _ in range(n_eval)],
    )
    print(f"streaming sharded eval: recall@100={res['recall@100']:.4f} "
          f"ndcg@100={res['ndcg@100']:.4f} over {res['n_eval']} contexts")

    # --- fault tolerance: replication, failover, graceful degradation ----
    # The mesh is the hardened superset of the cluster: each ψ row-range on
    # R=2 replicas; retries share the batcher's max_delay latency budget.
    inj = FaultInjector()
    mesh = FaultTolerantRetrievalMesh(
        lambda ctx: mf.build_phi(params, ctx), n_shards=n_shards,
        n_replicas=2, k=100, injector=inj,
        retry=RetryPolicy(max_attempts=3, deadline=2e-3),
    )
    mesh.publish(mf.export_psi(params))
    base = mesh.topk(jnp.arange(8))
    inj.fail(1, 0, "error")  # kill one replica of shard 1 mid-traffic
    ft = mesh.topk(jnp.arange(8))
    assert ft.coverage == 1.0
    assert bool((ft.ids == base.ids).all())
    assert bool((ft.scores == base.scores).all())
    print("replica kill under R=2: failover bit-identical ✓")
    inj.fail(1, 1, "error")  # kill the other copy: the row range is gone
    deg = mesh.topk(jnp.arange(8))
    print(f"both replicas dead: query still completes, "
          f"coverage={deg.coverage:.4f}, dead item ranges={deg.dead_ranges}")
    inj.heal()
    mesh.heal()  # re-place the orphaned range from the authoritative copy
    healed = mesh.topk(jnp.arange(8))
    assert healed.coverage == 1.0 and bool((healed.ids == base.ids).all())
    print("heal(): replicas re-placed, full coverage restored ✓")

    # --- staged rollout: canary + mirrored traffic gate the ψ publish ----
    rollout = StagedRollout(
        mesh, mirror_phi=mf.build_phi(params, jnp.arange(16))
    )
    ok, _ = rollout.publish(mf.export_psi(params))
    bad = jnp.full((n_items, k), jnp.nan, jnp.float32)  # a broken export
    ok_bad, report = rollout.publish(bad)
    assert ok and not ok_bad and mesh.version == 2
    print(f"staged rollout: good table promoted (v{mesh.version}), NaN "
          f"table rolled back (checks={report['checks']}) ✓")

    # --- IVF approximate tier + quantized ψ (serve/ann.py) ---------------
    # Centroid pruning in front of the same fused kernel: n_probe of
    # n_clusters ψ blocks are exactly re-ranked; n_probe = n_clusters is
    # bit-identical to the exact path, and int8 per-row-scale storage
    # multiplies rows-per-shard while keeping relative score error small.
    from repro.eval.ranking import ann_recall_curve, overlap_recall
    from repro.serve.ann import AnnConfig

    n_c = 32
    ivf = RetrievalEngine(
        mf.export_psi(params), lambda ctx: mf.build_phi(params, ctx),
        k=100, retrieval="ivf",
        ann=AnnConfig(n_clusters=n_c, n_probe=n_c, quant="none"),
    )
    os_, oi = ivf.topk(jnp.arange(8))
    assert bool((oi == ei).all()) and bool((os_ == es).all())
    print(f"ivf oracle (n_probe=n_clusters={n_c}): bit-identical to exact ✓")
    curve = ann_recall_curve(
        ivf.index, mf.build_phi(params, jnp.arange(8)),
        mf.export_psi(params), k=100, n_probes=(2, 4, 8, n_c),
    )
    print("ivf recall-vs-probe:",
          {pt["n_probe"]: round(pt["recall@100"], 3) for pt in curve})
    q8 = RetrievalEngine(
        mf.export_psi(params), lambda ctx: mf.build_phi(params, ctx),
        k=100, retrieval="ivf",
        ann=AnnConfig(n_clusters=n_c, n_probe=n_c, quant="int8"),
    )
    _, qi = q8.topk(jnp.arange(8))
    print(f"int8 ψ (per-row scales): id recall vs exact = "
          f"{overlap_recall(np.asarray(qi), np.asarray(ei)):.3f}, "
          f"~3.9x rows per shard at D=128 ✓")


if __name__ == "__main__":
    main()
