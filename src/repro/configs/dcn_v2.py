"""DCN-v2 [arXiv:2008.13535] — 3 full-rank cross layers + deep MLP."""
import dataclasses

from repro.configs.base import RECSYS_SHAPES, RecsysConfig

CONFIG = RecsysConfig(
    name="dcn-v2",
    kind="dcn",
    n_dense=13,
    n_sparse=26,
    embed_dim=16,
    table_vocabs=tuple([10_000_000] * 4 + [100_000] * 22),
    n_cross_layers=3,
    mlp=(1024, 1024, 512),
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, table_vocabs=tuple([40] * 4 + [12] * 22), embed_dim=4,
    mlp=(32, 16), n_cross_layers=2,
)

SHAPES = RECSYS_SHAPES
