"""Closed-form fold-in: single-row iCD solves for rows that arrive after
training (Rendle 2021, *Item Recommendation from Implicit Feedback*, §serving).

Every zoo model scores through the k-separable product ŷ = ⟨φ(ctx), ψ(item)⟩,
so a NEW user (or item) is one unknown D-vector θ against the FROZEN other
side's export table T — exactly the per-row subproblem the training sweeps
solve, restricted to one row:

    minimize_θ   Σ_j α_j (θ·t_j − y_j)²  +  α₀ θᵀGθ  +  λ‖θ‖²,   G = TᵀT

:func:`fold_in_row` runs the same per-coordinate Newton updates as
``mf._side_sweep`` (same residual cache, same Gram contraction, same
``newton_delta`` denominator clamp — λ=0 with an empty history stays finite)
iterated to convergence; :func:`fold_in_exact` solves the normal equations
directly and is the oracle the parity tests/bench gates compare against.

Feature/extended models reuse this in their export coordinates: FM's
``φ_ext``/``ψ_ext`` carry structurally-fixed columns (the constant-1 slots),
so the solver takes a ``free`` mask — fixed coordinates keep their ``init``
value and only ride along in the residuals and the Gram coupling.

The per-model entry points (which side is frozen, which coordinates are
free) live on the :class:`repro.core.models.api.Model` adapters as
``fold_in_user`` / ``fold_in_item``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np


class FoldInResult(NamedTuple):
    row: np.ndarray       # (D,) solved embedding row, float32
    n_sweeps: int         # CD sweeps actually run
    delta_max: float      # last sweep's max |Δθ| (convergence certificate)


def _prepare(table, ids, y, alpha, free, init, weights=None):
    table = np.asarray(table, np.float32)
    n, d = table.shape
    ids = np.asarray(ids, np.int64).reshape(-1)
    if ids.size and (ids.min() < 0 or ids.max() >= n):
        raise ValueError(f"fold-in ids out of range [0, {n}) : {ids!r}")
    y = np.ones(ids.shape, np.float32) if y is None else np.asarray(y, np.float32)
    alpha = (
        np.ones(ids.shape, np.float32) if alpha is None
        else np.asarray(alpha, np.float32)
    )
    if y.shape != ids.shape or alpha.shape != ids.shape:
        raise ValueError("y/alpha must match ids shape")
    if weights is not None:
        # per-interaction confidence folds into α exactly (α is purely
        # multiplicative in the explicit parts of the row subproblem)
        weights = np.asarray(weights, np.float32)
        if weights.shape != ids.shape:
            raise ValueError("weights must match ids shape")
        alpha = alpha * weights
    free = np.ones(d, bool) if free is None else np.asarray(free, bool)
    if free.shape != (d,):
        raise ValueError(f"free mask must be ({d},), got {free.shape}")
    theta = np.zeros(d, np.float32) if init is None else np.asarray(
        init, np.float32
    ).copy()
    if theta.shape != (d,):
        raise ValueError(f"init must be ({d},), got {theta.shape}")
    return table, ids, y, alpha, free, theta


def fold_in_row(
    table,
    ids,
    y=None,
    alpha=None,
    *,
    alpha0: float,
    l2: float,
    eta: float = 1.0,
    weights=None,
    free=None,
    init=None,
    gram: Optional[np.ndarray] = None,
    n_sweeps: int = 64,
    tol: float = 1e-6,
) -> FoldInResult:
    """Solve one embedding row by coordinate descent against a frozen table.

    ``table`` (n, D)
        the frozen other side in export coordinates (``export_psi`` output
        for a user fold-in; the full φ table for an item fold-in).
    ``ids`` (m,)
        table rows the new entity interacted with (may be empty: the pure
        implicit-prior solve, which with l2=0 relies on the Newton clamp).
    ``y`` / ``alpha`` (m,)
        targets and confidences; default 1 (plain implicit feedback). Feed
        Lemma-1 rescaled values to match a specific training objective.
    ``weights`` (m,)
        optional per-interaction confidence weights — multiplied into α
        (exact: α is purely multiplicative in the explicit parts), the same
        semantics as the ``weights=`` training epochs.
    ``free`` (D,) bool
        solvable coordinates; fixed ones keep their ``init`` value (FM's
        constant-1 extended columns).
    ``gram``
        optional precomputed TᵀT — pass it when folding many rows against
        the same frozen table.

    Iterates full free-coordinate sweeps (η-damped Newton per coordinate,
    rank-1 residual patch — the ``mf._side_sweep`` math with n_rows=1) until
    ``max|Δθ| < tol·(1 + max|θ|)`` or ``n_sweeps`` is hit.
    """
    table, ids, y, alpha, free, theta = _prepare(
        table, ids, y, alpha, free, init, weights
    )
    g = (table.T @ table).astype(np.float32) if gram is None else np.asarray(
        gram, np.float32
    )
    t_rows = table[ids]                      # (m, D)
    e = t_rows @ theta - y                   # residual cache ŷ − ȳ
    free_dims = np.flatnonzero(free)
    sweeps_run, delta_max = 0, 0.0
    for s in range(max(1, n_sweeps)):
        delta_max = 0.0
        for f in free_dims:
            t_f = t_rows[:, f]
            lp = float(np.dot(alpha * e, t_f))          # L'/2
            lpp = float(np.dot(alpha * t_f, t_f))       # L''/2
            rp = float(theta @ g[:, f])                 # R'/2  (Lemma 3)
            rpp = float(g[f, f])                        # R''/2
            num = lp + alpha0 * rp + l2 * theta[f]
            den = lpp + alpha0 * rpp + l2
            delta = -eta * num / max(den, 1e-12)        # newton_delta clamp
            theta[f] += np.float32(delta)
            e += np.float32(delta) * t_f
            delta_max = max(delta_max, abs(delta))
        sweeps_run = s + 1
        if delta_max < tol * (1.0 + float(np.max(np.abs(theta), initial=0.0))):
            break
    return FoldInResult(theta, sweeps_run, float(delta_max))


def fold_in_exact(
    table,
    ids,
    y=None,
    alpha=None,
    *,
    alpha0: float,
    l2: float,
    weights=None,
    free=None,
    init=None,
) -> np.ndarray:
    """Normal-equations oracle for :func:`fold_in_row` (float64 direct solve).

    Solves ``(A + α₀G + λI)[free,free] θ_free = b_free − M[free,fixed]·θ_fixed``
    with ``A = Σ α t tᵀ`` and ``b = Σ α y t``; the unique minimizer the CD
    iteration converges to. Uses ``lstsq`` so the λ=0 empty-history corner
    (singular system) returns the minimum-norm solution instead of raising.
    ``weights`` multiplies α like :func:`fold_in_row`.
    """
    table, ids, y, alpha, free, theta = _prepare(
        table, ids, y, alpha, free, init, weights
    )
    t64 = table.astype(np.float64)
    g = t64.T @ t64
    t_rows = t64[ids]
    a64 = alpha.astype(np.float64)
    m = t_rows.T @ (a64[:, None] * t_rows) + alpha0 * g + l2 * np.eye(t64.shape[1])
    b = t_rows.T @ (a64 * y.astype(np.float64))
    fr = np.flatnonzero(free)
    fx = np.flatnonzero(~free)
    rhs = b[fr] - m[np.ix_(fr, fx)] @ theta[fx].astype(np.float64)
    sol, *_ = np.linalg.lstsq(m[np.ix_(fr, fr)], rhs, rcond=None)
    out = theta.astype(np.float64)
    out[fr] = sol
    return out.astype(np.float32)
