"""Serving/eval bench for the fused score+top-K retrieval subsystem.

Tracks ``BENCH_topk_score.json`` at the repo root:

  * analytic HBM-traffic model — fused ``kernels/topk_score`` (ψ read once,
    scores never leave VMEM) vs the dense path (ψ read + (B, n_items)
    score matrix written AND re-read by ``lax.top_k``), plus the CLUSTER
    model: per-shard ψ reads + the cross-shard merge's S·K candidate
    traffic (the sharding overhead is the tiny merge term, not the ψ
    stream — sharding is ~free in bytes while multiplying HBM capacity);
  * measured CPU comparison of the two paths (interpret-mode kernels, so
    wall-clock is emulation-bound and informational only);
  * batcher p50/p99 queue+service latency under a synthetic open-loop
    arrival trace (simulated clock; service time from the analytic model
    so the numbers are not emulation-bound), with every routed result
    HARD-asserted against the per-row dense oracle;
  * HARD parity asserts — streaming kernel vs dense ``lax.top_k`` ids for
    every k-separable model, with and without exclude masks, the sharded
    cluster vs the single-device engine (ids AND scores bit-identical at
    shard counts {1,2,3,4}), plus the streaming ranking-eval harness vs
    dense metrics. A broken kernel, merge, or export contract fails the
    whole bench (the CI serve-smoke gate);
  * HARD fault-tolerance asserts (``serve/mesh.py``) — replica kills under
    R=2 bit-identical to the healthy oracle, unreplicated kills complete
    with the coverage/dead-range contract, retry backoff bounded by the
    deadline budget;
  * HARD IVF/quantization asserts (``serve/ann.py``) — n_probe=n_clusters
    bit-identical to exact, recall@K >= 0.95 at >= 4x analytic byte
    reduction on the probe sweep, int8-per-row-scale ψ within 5% relative
    score error and >= 3x rows per HBM shard;
  * HARD observability asserts (``repro.obs``) — the kernel cost counters
    recorded at dispatch sites reproduce the ``kernels/vmem.py`` byte
    model exactly, instrumented-vs-bare overhead < 3%, and one batched
    request under an injected replica kill exports a single
    ticket-correlated trace (request → queue → flush → dispatch →
    failover → merge) without changing a bit of the results.

Run: ``python -m benchmarks.run --quick`` (serve section) or
``python -m benchmarks.serve_bench --smoke``.
"""
from __future__ import annotations

import gc
import json
import os
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import HBM_BW


def topk_traffic_bytes(b: int, n_items: int, d: int, k: int) -> Dict[str, float]:
    """Analytic HBM bytes for one query batch (fp32). Dense: ψ table + φ +
    score-matrix write + score-matrix re-read (top_k). Fused: ψ table + φ
    + the final (B, K_pad) score/id blocks (running state rides VMEM)."""
    k_pad = -(-k // 128) * 128
    psi = 4.0 * n_items * d
    phi = 4.0 * b * d
    dense = psi + phi + 2 * 4.0 * b * n_items
    fused = psi + phi + 2 * 4.0 * b * k_pad
    return {
        "dense_bytes": dense,
        "fused_bytes": fused,
        "bytes_ratio": dense / fused,
        "dense_memory_s": dense / HBM_BW,
        "fused_memory_s": fused / HBM_BW,
    }


def cluster_traffic_bytes(
    b: int, n_items: int, d: int, k: int, n_shards: int
) -> Dict[str, float]:
    """Analytic HBM bytes for the SHARDED path: every shard streams its ψ
    slab once (total = one ψ read), φ replicates to S shards, and the
    cross-shard merge writes + re-reads the S·K_pad candidate score/id
    rows before the final (B, K_pad) result. Per-shard bytes bound the
    per-device time (shards run concurrently)."""
    k_pad = -(-k // 128) * 128
    psi = 4.0 * n_items * d                       # summed over shards
    phi = 4.0 * b * d * n_shards                  # replicated
    cand = 2 * 2 * 4.0 * b * k_pad * n_shards     # candidates: write + read
    final = 2 * 4.0 * b * k_pad
    total = psi + phi + cand + final
    single = topk_traffic_bytes(b, n_items, d, k)["fused_bytes"]
    per_shard = psi / n_shards + 4.0 * b * d + 2 * 4.0 * b * k_pad
    return {
        "cluster_bytes": total,
        "single_fused_bytes": single,
        "shard_overhead_ratio": total / single,
        "per_shard_bytes": per_shard,
        "per_shard_memory_s": per_shard / HBM_BW,
        "capacity_x": float(n_shards),  # ψ rows servable vs one device's HBM
    }


def _zoo_models(quick: bool):
    """Tiny (φ, ψ) exports for every k-separable model (the one shared
    builder in ``repro.core.models.zoo`` at bench shapes — used by the
    kernel-parity and cluster-parity sections)."""
    from repro.core.models.zoo import ZOO, model_phi_psi

    rng = np.random.default_rng(0)
    n_ctx, n_items, b, k = (24, 40, 8, 6) if quick else (128, 512, 32, 16)
    return {
        name: model_phi_psi(name, rng, n_ctx=n_ctx, n_items=n_items, b=b, k=k)
        for name in ZOO
    }


def _assert_topk_parity(name, phi, psi, k, exclude_mask=None, block_items=32):
    """Streaming kernel vs dense lax.top_k/oracle: ids exact, scores close."""
    from repro.kernels.topk_score import topk_score, topk_score_ref

    s, i = topk_score(phi, psi, k, exclude_mask, block_items=block_items)
    rs, ri = topk_score_ref(phi, psi, k, exclude_mask)
    if not (np.asarray(i) == np.asarray(ri)).all():
        raise AssertionError(f"serve bench parity FAILED for {name}: top-k ids "
                             "diverge from the dense oracle")
    finite = np.isfinite(np.asarray(rs))
    if not np.allclose(np.asarray(s)[finite], np.asarray(rs)[finite],
                       rtol=1e-5, atol=1e-6):
        raise AssertionError(f"serve bench parity FAILED for {name}: top-k "
                             "scores diverge from the dense oracle")
    if exclude_mask is None:
        ds, di = jax.lax.top_k(phi @ psi.T, min(k, psi.shape[0]))
        if not (np.asarray(i)[:, : di.shape[1]] == np.asarray(di)).all():
            raise AssertionError(f"serve bench parity FAILED for {name}: ids "
                                 "diverge from dense lax.top_k")


def _zoo_parity(quick: bool) -> Dict[str, dict]:
    """Every model through its export_psi/build_phi contract, masked and
    unmasked, against the dense path."""
    from repro.serve.engine import exclude_mask_from_lists

    rng = np.random.default_rng(0)
    topk = 10 if quick else 100
    out = {}
    for name, (phi, psi) in _zoo_models(quick).items():
        excl = exclude_mask_from_lists(
            [rng.choice(psi.shape[0], size=min(5, psi.shape[0] // 2),
                        replace=False) for _ in range(phi.shape[0])],
            psi.shape[0],
        )
        kk = min(topk, psi.shape[0])
        _assert_topk_parity(name, phi, psi, kk)
        _assert_topk_parity(f"{name}+mask", phi, psi, kk, excl)
        out[name] = {"parity_ok": True, "d": int(phi.shape[1]),
                     "n_items": int(psi.shape[0]), "k": kk}
    return out


def _cluster_parity(quick: bool) -> Dict[str, dict]:
    """Sharded cluster vs single-device engine vs dense oracle: ids AND
    scores BIT-identical for every model at shard counts {1, 2, 3, 4},
    with and without per-row exclusion — the acceptance gate of the
    sharded serving tier."""
    from repro.kernels.topk_score import topk_score_ref
    from repro.serve.cluster import ShardedRetrievalCluster
    from repro.serve.engine import (
        RetrievalEngine,
        exclude_ids_from_lists,
        exclude_mask_from_lists,
    )

    rng = np.random.default_rng(7)
    topk = 10 if quick else 100
    out = {}
    for name, (phi, psi) in _zoo_models(quick).items():
        kk = min(topk, psi.shape[0])
        engine = RetrievalEngine(psi, lambda p=phi: p, k=kk, block_items=32)
        es, ei = engine.topk_phi(phi)
        lists = [rng.choice(psi.shape[0], size=min(5, psi.shape[0] // 2),
                            replace=False) for _ in range(phi.shape[0])]
        eids = exclude_ids_from_lists(lists)
        es2, ei2 = engine.topk_phi(phi, exclude_ids=eids)
        rs2, ri2 = topk_score_ref(
            phi, psi, kk, exclude_mask_from_lists(lists, psi.shape[0])
        )
        for n_shards in (1, 2, 3, 4):
            cl = ShardedRetrievalCluster(
                lambda p=phi: p, n_shards=n_shards, k=kk, block_items=32,
                psi_table=psi,
            )
            cs, ci = cl.topk_phi(phi)
            if not ((np.asarray(ci) == np.asarray(ei)).all()
                    and (np.asarray(cs) == np.asarray(es)).all()):
                raise AssertionError(
                    f"serve bench parity FAILED for {name}: cluster "
                    f"(n_shards={n_shards}) is not bit-identical to the "
                    "single-device engine"
                )
            cs2, ci2 = cl.topk_phi(phi, exclude_ids=eids)
            if not ((np.asarray(ci2) == np.asarray(ri2)).all()
                    and (np.asarray(ci2) == np.asarray(ei2)).all()
                    and (np.asarray(cs2) == np.asarray(es2)).all()):
                raise AssertionError(
                    f"serve bench parity FAILED for {name}: sharded "
                    f"exclude path (n_shards={n_shards}) diverges"
                )
        out[name] = {"parity_ok": True, "shard_counts": [1, 2, 3, 4],
                     "k": kk, "n_items": int(psi.shape[0])}
    return out


def _batcher_bench(quick: bool) -> dict:
    """Open-loop single-row arrival trace through the micro-batcher over a
    sharded cluster (simulated clock). Queue wait comes from the flush
    policy; service time from the analytic per-shard traffic model (NOT
    interpret-mode wall clock). Every routed result is hard-asserted
    against the per-row dense oracle — the out-of-order-routing gate."""
    from repro.core.models import mf
    from repro.kernels.topk_score import topk_score_ref
    from repro.serve.batcher import MicroBatcher
    from repro.serve.cluster import ShardedRetrievalCluster
    from repro.serve.engine import exclude_ids_from_lists

    rng = np.random.default_rng(11)
    n_ctx, n_items, k, kk = (64, 40, 8, 10) if quick else (512, 4096, 32, 100)
    n_requests = 64 if quick else 512
    n_shards, max_batch, max_delay = 2, 8, 2e-3
    params = mf.init(jax.random.PRNGKey(6), n_ctx, n_items, k)
    cluster = ShardedRetrievalCluster(
        lambda ctx: mf.build_phi(params, ctx), n_shards=n_shards,
        k=min(kk, n_items), block_items=32,
        psi_table=mf.export_psi(params),
    )
    clock = {"t": 0.0}
    batcher = MicroBatcher(
        lambda phi, eids: cluster.topk_phi(phi, exclude_ids=eids),
        max_batch=max_batch, max_delay=max_delay, pad_to=8,
        clock=lambda: clock["t"], version_fn=lambda: cluster.version,
    )
    phi_all = np.asarray(mf.build_phi(params, jnp.arange(n_ctx)))
    psi = np.asarray(mf.export_psi(params))
    # analytic per-flush service time: per-shard stream + merge
    service_s = cluster_traffic_bytes(
        max_batch, n_items, phi_all.shape[1], min(kk, n_items), n_shards
    )["per_shard_memory_s"]

    # open-loop arrivals: exponential inter-arrival, mean = max_delay/4 ⇒
    # size flushes dominate, deadline bounds the tail
    arrivals = np.cumsum(rng.exponential(max_delay / 4, size=n_requests))
    users = rng.integers(0, n_ctx, size=n_requests)
    excls = [rng.choice(n_items, size=int(rng.integers(0, 4)), replace=False)
             for _ in range(n_requests)]
    submit_t, tickets = {}, []
    for t_arr, u, ex in zip(arrivals, users, excls):
        clock["t"] = float(t_arr)
        tk = batcher.submit(
            phi_all[u], exclude=ex,
            key=("user", int(u), tuple(np.sort(ex).tolist())),
        )
        submit_t[tk] = float(t_arr)
        tickets.append((tk, int(u), ex))
    clock["t"] = float(arrivals[-1]) + max_delay
    batcher.step()
    batcher.flush()

    lat = []
    for tk, u, ex in tickets:
        done = batcher.completed_at(tk)
        scores, ids = batcher.result(tk)
        # HARD routing assert: this ticket's rows == ITS user's oracle row
        rs, ri = topk_score_ref(
            phi_all[u : u + 1], psi, min(kk, n_items),
            exclude_ids=exclude_ids_from_lists([ex]),
        )
        if not (ids == np.asarray(ri)[0]).all():
            raise AssertionError(
                "serve bench FAILED: batcher routed the wrong result to a "
                f"ticket (user {u})"
            )
        lat.append(done - submit_t[tk] + service_s)
    lat = np.asarray(lat)
    return {
        "routing_ok": True,
        "trace": {
            "n_requests": n_requests, "n_shards": n_shards,
            "max_batch": max_batch, "max_delay_s": max_delay,
            "mean_interarrival_s": float(max_delay / 4),
        },
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p99_s": float(np.percentile(lat, 99)),
        "queue_p99_s": float(np.percentile(lat - service_s, 99)),
        "service_s_analytic": float(service_s),
        "stats": dict(batcher.stats),
        "note": "queue wait simulated-clock exact; service time analytic "
                "(interpret-mode wall clock is emulation-bound)",
    }


def _failover_bench(quick: bool) -> dict:
    """Fault-tolerance acceptance gate (serve/mesh.py), all HARD asserts:

      * R=2, kill each replica in turn mid-traffic ⇒ every answer stays
        BIT-identical (ids AND scores) to the healthy single-device oracle
        — failover must be invisible in results;
      * R=1, kill a shard ⇒ the query COMPLETES, reports coverage < 1 plus
        the exact dead row range, and the surviving ids equal the oracle
        restricted to the surviving ranges;
      * sticky timeouts under a deadline budget ⇒ total backoff never
        exceeds the budget (the batcher max_delay contract)."""
    from repro.kernels.topk_score import topk_score_ref
    from repro.serve.mesh import (
        FaultInjector,
        FaultTolerantRetrievalMesh,
        RetryPolicy,
    )

    rng = np.random.default_rng(17)
    n_ctx, n_items, d, kk = (9, 101, 16, 13) if quick else (32, 2048, 32, 50)
    n_shards, n_replicas = 4, 2
    phi = jnp.asarray(rng.normal(size=(n_ctx, d)), jnp.float32)
    psi = jnp.asarray(rng.normal(size=(n_items, d)), jnp.float32)
    rs_ref, ri_ref = topk_score_ref(phi, psi, kk)

    inj = FaultInjector()
    mesh = FaultTolerantRetrievalMesh(
        lambda p=phi: p, n_shards=n_shards, n_replicas=n_replicas, k=kk,
        block_items=32, injector=inj,
        retry=RetryPolicy(max_attempts=3, backoff_base=1e-4),
    )
    mesh.publish(psi)
    base = mesh.topk()
    if not (np.asarray(base.ids) == np.asarray(ri_ref)).all():
        raise AssertionError("serve bench FAILED: healthy mesh diverges "
                             "from the dense oracle")
    kills = 0
    for s in range(n_shards):
        for r in range(n_replicas):
            inj.fail(s, r, "error")
            # two queries: round-robin guarantees the kill is routed to
            for _ in range(2):
                res = mesh.topk()
                if res.coverage != 1.0 or not (
                    (np.asarray(res.ids) == np.asarray(base.ids)).all()
                    and (np.asarray(res.scores)
                         == np.asarray(base.scores)).all()
                ):
                    raise AssertionError(
                        "serve bench FAILED: failover parity — killing "
                        f"replica ({s},{r}) under R=2 changed the results"
                    )
            kills += 1
            inj.heal(s, r)
            mesh.replica_set.mark_live(s, r)
    failover_parity = True

    # unreplicated kill: labeled degradation, survivors oracle-exact
    inj2 = FaultInjector()
    mesh1 = FaultTolerantRetrievalMesh(
        lambda p=phi: p, n_shards=n_shards, n_replicas=1, k=kk,
        block_items=32, injector=inj2,
        retry=RetryPolicy(max_attempts=2, backoff_base=1e-4),
    )
    mesh1.publish(psi)
    inj2.fail(1, 0, "error")
    deg = mesh1.topk()
    table = mesh1.table
    lo, hi = table.rows_per, min(2 * table.rows_per, n_items)
    mask = np.zeros((n_ctx, n_items), bool)
    mask[:, lo:hi] = True
    ds_ref, di_ref = topk_score_ref(phi, psi, kk, jnp.asarray(mask))
    if (deg.coverage >= 1.0 or deg.dead_ranges != ((lo, hi),)
            or not (np.asarray(deg.ids) == np.asarray(di_ref)).all()):
        raise AssertionError(
            "serve bench FAILED: degraded-query contract — unreplicated "
            "shard kill must complete with coverage < 1, the dead row "
            "range, and oracle-exact survivors"
        )
    degraded_contract_ok = True

    # deadline budget: sticky timeouts may never sleep past the budget
    budget = 2e-3
    inj3 = FaultInjector()
    mesh3 = FaultTolerantRetrievalMesh(
        lambda p=phi: p, n_shards=2, n_replicas=2, k=kk, block_items=32,
        injector=inj3,
        retry=RetryPolicy(max_attempts=5, backoff_base=1e-3,
                          deadline=budget),
    )
    mesh3.publish(psi)
    inj3.fail(0, 0, "timeout", latency=1.5e-3)
    inj3.fail(0, 1, "timeout", latency=1.5e-3)
    mesh3.topk()
    if mesh3.stats["backoff_slept_s"] > budget:
        raise AssertionError(
            "serve bench FAILED: retry backoff "
            f"({mesh3.stats['backoff_slept_s']}s) exceeded the deadline "
            f"budget ({budget}s) — the batcher max_delay contract is broken"
        )
    deadline_ok = True
    return {
        "failover_parity": failover_parity,
        "degraded_contract_ok": degraded_contract_ok,
        "deadline_ok": deadline_ok,
        "replica_kills": kills,
        "mesh_stats": {k2: v for k2, v in mesh.stats.items()},
        "degraded_coverage": float(deg.coverage),
        "degraded_dead_ranges": [list(r) for r in deg.dead_ranges],
        "deadline_budget_s": budget,
        "backoff_slept_s": float(mesh3.stats["backoff_slept_s"]),
        "deadline_gaveups": int(mesh3.stats["deadline_gaveups"]),
    }


def _ann_clustered(n, d, n_centers, seed=0, spread=6.0):
    """Clustered ψ + centroid-seeking queries — the regime the IVF tier is
    built for. Fixed seeds: the recall gate must be deterministic."""
    rng = np.random.default_rng(seed)
    cents = rng.normal(size=(n_centers, d)) * spread
    per = -(-n // n_centers)
    rows = np.concatenate(
        [cents[i] + rng.normal(size=(per, d)) for i in range(n_centers)]
    )[:n]
    rng.shuffle(rows)
    return jnp.asarray(rows, jnp.float32), cents, rng


def _ann_bench(quick: bool) -> dict:
    """IVF + quantized-ψ acceptance gates (serve/ann.py), all HARD asserts:

      * ``ann_exact_parity`` — n_probe = n_clusters is BIT-identical (ids
        AND scores) to the exact fused kernel: the approximate tier
        degrades to exact, never to almost-exact;
      * ``ann_recall_floor`` — some point on the probe sweep reaches
        recall@K >= 0.95 against the exact oracle while the analytic
        HBM-byte model (centroid read + probed quantized blocks vs the
        full fp32 ψ stream) shows >= 4x fewer bytes;
      * ``quant_parity`` — the int8-per-row-scale index at oracle probe
        count returns >= 90% of the exact ids with scores within 5%
        RELATIVE error (per-row scales bound relative, not absolute,
        error — rows of very different norms are the point);
      * ``int8_capacity_x`` — ``vmem.shard_capacity_rows``: int8+scale
        rows per HBM byte >= 3x fp32 rows (the shard-capacity gate).
    """
    from repro.eval.ranking import ann_recall_curve, overlap_recall
    from repro.kernels.topk_score import topk_score
    from repro.kernels.vmem import psi_row_bytes, shard_capacity_rows
    from repro.serve.ann import AnnConfig, PsiIndex

    n, d, n_c, b, kk = (4096, 32, 16, 12, 100) if quick else (16384, 64, 32, 32, 100)
    psi, cents, rng = _ann_clustered(n, d, n_c, seed=23)
    phi = jnp.asarray(
        cents[rng.integers(0, n_c, size=b)] * 0.5
        + rng.normal(size=(b, d)) * 0.5,
        jnp.float32,
    )
    exact_s, exact_i = topk_score(phi, psi, kk)

    # --- exact-parity gate: oracle probe count, fp32 storage -------------
    idx32 = PsiIndex.build(psi, AnnConfig(n_clusters=n_c, seed=3))
    s, i = idx32.topk(phi, kk, n_probe=n_c)
    if not ((np.asarray(i) == np.asarray(exact_i)).all()
            and (np.asarray(s) == np.asarray(exact_s)).all()):
        raise AssertionError(
            "serve bench FAILED: IVF with n_probe=n_clusters is not "
            "bit-identical to the exact kernel"
        )
    ann_exact_parity = True

    # --- recall-vs-bytes sweep on the SHIPPED config (int8 + scales) -----
    idx8 = PsiIndex.build(psi, AnnConfig(n_clusters=n_c, quant="int8", seed=3))
    probes = sorted({1, 2, 4, max(1, n_c // 2), n_c})
    curve = ann_recall_curve(idx8, phi, psi, k=kk, n_probes=probes)
    exact_bytes = float(n * psi_row_bytes(d))            # full fp32 ψ stream
    sweep = []
    for pt in curve:
        p = pt["n_probe"]
        ivf_bytes = (
            float(n_c * d * 4)                           # centroid scoring
            + float(p * idx8.block_rows
                    * psi_row_bytes(d, psi_bytes=1, per_row_scale=True))
        )
        sweep.append({
            **pt,
            "ivf_bytes": ivf_bytes,
            "bytes_reduction_x": exact_bytes / ivf_bytes,
        })
    floor_pts = [pt for pt in sweep
                 if pt[f"recall@{kk}"] >= 0.95 and pt["bytes_reduction_x"] >= 4.0]
    if not floor_pts:
        raise AssertionError(
            "serve bench FAILED: no probe count reaches recall@"
            f"{kk} >= 0.95 at >= 4x analytic byte reduction; sweep={sweep}"
        )
    ann_recall_floor = True

    # --- quantized-score parity at oracle probes -------------------------
    s8, i8 = idx8.topk(phi, kk, n_probe=n_c)
    id_recall = overlap_recall(np.asarray(i8), np.asarray(exact_i))
    hit = np.asarray(i8) == np.asarray(exact_i)
    rel = (np.abs(np.asarray(s8) - np.asarray(exact_s))[hit]
           / np.maximum(np.abs(np.asarray(exact_s))[hit], 1e-3))
    if id_recall < 0.9 or rel.max() >= 0.05:
        raise AssertionError(
            "serve bench FAILED: int8 ψ quant parity — id recall "
            f"{id_recall:.3f} (need >= 0.9) / max relative score error "
            f"{rel.max():.4f} (need < 0.05)"
        )
    quant_parity = True

    # --- capacity gate: int8+scale rows per shard vs fp32 ----------------
    hbm = 16 * 2**30
    cap32 = shard_capacity_rows(hbm, 128)
    cap8 = shard_capacity_rows(hbm, 128, psi_bytes=1, per_row_scale=True)
    capacity_x = cap8 / cap32
    if capacity_x < 3.0:
        raise AssertionError(
            f"serve bench FAILED: int8 shard capacity {capacity_x:.2f}x "
            "fp32 (need >= 3x)"
        )
    return {
        "shape": dict(n_items=n, d=d, n_clusters=n_c, b=b, k=kk,
                      block_rows=int(idx8.block_rows)),
        "ann_exact_parity": ann_exact_parity,
        "ann_recall_floor": ann_recall_floor,
        "quant_parity": quant_parity,
        "recall_bytes_sweep": sweep,
        "best_floor_point": max(floor_pts, key=lambda p: p["bytes_reduction_x"]),
        "quant_id_recall": float(id_recall),
        "quant_max_rel_err": float(rel.max()),
        "int8_capacity_x": float(capacity_x),
        "capacity_rows": {"f32_D128_16GiB": cap32, "int8_D128_16GiB": cap8},
        "note": "bytes analytic (centroids + probed quantized blocks vs "
                "full fp32 stream); recall measured vs the exact kernel "
                "on fixed-seed clustered data",
    }


def _eval_harness_parity(quick: bool) -> dict:
    """Streaming ranking_eval (never a (n_eval, n_items) array) vs dense
    metrics over the same exclusion protocol — single-table AND sharded."""
    from repro.core.metrics import ndcg_at_k, recall_at_k
    from repro.core.models import mf
    from repro.eval.ranking import ranking_eval
    from repro.serve.cluster import ShardedRetrievalCluster
    from repro.serve.engine import exclude_mask_from_lists

    rng = np.random.default_rng(1)
    n_eval, n_items, k, topk = (32, 80, 8, 10) if quick else (256, 2048, 32, 100)
    params = mf.init(jax.random.PRNGKey(5), n_eval, n_items, k)
    truth = rng.integers(0, n_items, size=n_eval)
    excl = [rng.choice(n_items, size=4, replace=False) for _ in range(n_eval)]
    phi = mf.build_phi(params, jnp.arange(n_eval))
    psi = mf.export_psi(params)
    res = ranking_eval(phi, psi, truth, k=topk, batch_rows=max(8, n_eval // 3),
                       exclude=excl, block_items=32)
    mask = exclude_mask_from_lists(excl, n_items)
    dense = phi @ psi.T
    r = float(recall_at_k(dense, jnp.asarray(truth), topk, mask))
    n = float(ndcg_at_k(dense, jnp.asarray(truth), topk, mask))
    ok = (abs(res[f"recall@{topk}"] - r) < 1e-5
          and abs(res[f"ndcg@{topk}"] - n) < 1e-5)
    if not ok:
        raise AssertionError(
            f"serve bench parity FAILED for ranking_eval: streaming "
            f"({res}) vs dense (recall={r}, ndcg={n})"
        )
    # sharded eval over the cluster: same metrics past one device's HBM
    cl = ShardedRetrievalCluster(n_shards=3, k=topk, block_items=32,
                                 psi_table=psi)
    res_sh = ranking_eval(phi, None, truth, k=topk,
                          batch_rows=max(8, n_eval // 3), exclude=excl,
                          cluster=cl)
    sharded_ok = (abs(res_sh[f"recall@{topk}"] - r) < 1e-5
                  and abs(res_sh[f"ndcg@{topk}"] - n) < 1e-5)
    if not sharded_ok:
        raise AssertionError(
            f"serve bench parity FAILED for SHARDED ranking_eval: "
            f"({res_sh}) vs dense (recall={r}, ndcg={n})"
        )
    return {"parity_ok": True, "sharded_parity_ok": True, **res}


def _obs_bench(quick: bool) -> dict:
    """Observability acceptance gates (repro.obs), all HARD asserts:

      * ``obs_cost_model_ok`` — the kernel cost counters recorded at the
        engine dispatch site reproduce the ``kernels/vmem.py`` analytic
        byte model EXACTLY on the benched shapes (same closed form this
        bench has always priced with: ψ stream at ``psi_row_bytes`` + φ +
        2·(B, K_pad) result blocks);
      * ``obs_overhead_ok`` — instrumented (live registry + tracer) vs
        bare (NULL_REGISTRY, no tracer) wall time over the same
        batcher→mesh traffic stays within 3% (median of interleaved
        rounds);
      * ``obs_trace_ok`` — one batched request under an injected replica
        kill yields a single ticket-correlated trace containing the whole
        story: request → queue → flush → dispatch → failover → merge —
        AND instrumentation is bit-invisible (ids and scores identical to
        the bare run).
    """
    from repro.obs import MetricsRegistry, Tracer, trace_for_ticket
    from repro.obs.costs import topk_score_cost
    from repro.obs.metrics import NULL_REGISTRY
    from repro.kernels.vmem import psi_row_bytes
    from repro.serve.batcher import MicroBatcher
    from repro.serve.engine import RetrievalEngine
    from repro.serve.mesh import (
        FaultInjector,
        FaultTolerantRetrievalMesh,
        RetryPolicy,
    )

    rng = np.random.default_rng(29)
    b, n_items, d, kk = (8, 96, 16, 10) if quick else (32, 2048, 32, 100)
    phi = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    psi = jnp.asarray(rng.normal(size=(n_items, d)), jnp.float32)

    # --- cost-counter parity vs the vmem byte model ----------------------
    reg = MetricsRegistry()
    engine = RetrievalEngine(psi, lambda p=phi: p, k=kk, block_items=32,
                             registry=reg)
    n_calls = 3
    for _ in range(n_calls):
        engine.topk_phi(phi)
    counted_calls = reg.get("kernel_calls_total", kernel="topk_score")
    counted_bytes = reg.get("kernel_hbm_bytes_total", kernel="topk_score")
    model = topk_score_cost(b, n_items, d, kk)
    # the same closed form, recomputed inline from kernels/vmem.py
    k_pad = -(-kk // 128) * 128
    inline = (n_items * psi_row_bytes(d) + 4.0 * b * d
              + 2 * 4.0 * b * k_pad)
    if not (counted_calls == n_calls
            and counted_bytes == n_calls * model["hbm_bytes"]
            and model["hbm_bytes"] == inline):
        raise AssertionError(
            "serve bench FAILED: kernel cost counters diverge from the "
            f"vmem byte model — counted {counted_bytes} over "
            f"{counted_calls} calls, model {model['hbm_bytes']}/call, "
            f"inline {inline}/call"
        )
    obs_cost_model_ok = True

    # --- overhead gate: instrumented vs bare, same traffic ---------------
    # sized so the measurement is kernel-bound (production-shaped ψ, small
    # flush batches): per-request shard-kernel work is a few hundred µs
    # while the instrumentation hot path (span begin/end ≈ 2 µs, counter
    # inc ≈ 0.2 µs) is single-digit µs — the gate then measures the real
    # steady-state ratio instead of timer noise on a trivial workload
    n_requests = 48 if quick else 96
    n_rounds = 9
    n_items_o, d_o = (2048, 64) if quick else (4096, 64)
    phi_o = jnp.asarray(rng.normal(size=(b, d_o)), jnp.float32)
    psi_o = jnp.asarray(rng.normal(size=(n_items_o, d_o)), jnp.float32)
    phi_req = np.asarray(rng.normal(size=(n_requests, d_o)), np.float32)

    def build(registry, tracer):
        clock = {"t": 0.0}
        mesh = FaultTolerantRetrievalMesh(
            lambda p=phi_o: p, n_shards=2, n_replicas=2, k=kk,
            block_items=128, retry=RetryPolicy(max_attempts=2),
            registry=registry, tracer=tracer,
        )
        mesh.publish(psi_o)
        batcher = MicroBatcher(
            lambda rows, eids: mesh.topk_phi(rows, exclude_ids=eids),
            max_batch=4, max_delay=1e-3, pad_to=4,
            clock=lambda: clock["t"], version_fn=lambda: mesh.version,
            registry=registry, tracer=tracer,
        )
        return clock, batcher

    def run_requests(clock, batcher, base_t):
        tickets = []
        for r in range(n_requests):
            clock["t"] = base_t + r * 1e-4
            tickets.append(batcher.submit(phi_req[r]))
            batcher.step()
        clock["t"] = base_t + 1.0
        batcher.flush()
        return [np.asarray(batcher.result(t).ids) for t in tickets]

    # construction is one-time (family/child creation); the gate is the
    # STEADY-STATE per-request cost, so only the request loop is timed.
    # Rounds are INTERLEAVED bare/instrumented so both variants sample
    # the same noise environment (interpret-mode kernel jitter here is
    # ±10% per round — far larger than the instrumentation cost), and the
    # comparison statistic is the TRIMMED MEAN OF PAIRED DELTAS: the
    # adjacent bare/instrumented pair cancels slow drift, the min/max
    # delta pair is dropped to shed scheduler outliers, and averaging the
    # rest shrinks the fast jitter. Round 0 warms jit + child caches and
    # is discarded; GC is parked so a collection landing in one variant's
    # rounds doesn't masquerade as instrumentation cost. The measurement
    # (not the workload) is retried up to 3 attempts: true overhead is a
    # fraction of a percent, so one clean attempt under the gate is the
    # expected outcome and repeated failures mean a real regression.
    def measure_overhead():
        bare_cl, bare_b = build(NULL_REGISTRY, None)
        inst_cl, inst_b = build(MetricsRegistry(), Tracer())
        run_requests(bare_cl, bare_b, base_t=0.0)
        ins_ids = run_requests(inst_cl, inst_b, base_t=0.0)
        br_ids = None
        bare_ts, inst_ts = [], []
        gc.collect()
        gc.disable()
        try:
            for r in range(1, n_rounds + 1):
                t0 = time.perf_counter()
                br_ids = run_requests(bare_cl, bare_b, base_t=10.0 * r)
                bare_ts.append(time.perf_counter() - t0)
                t0 = time.perf_counter()
                ins_ids = run_requests(inst_cl, inst_b, base_t=10.0 * r)
                inst_ts.append(time.perf_counter() - t0)
        finally:
            gc.enable()
        deltas = sorted(i - b3 for b3, i in zip(bare_ts, inst_ts))[1:-1]
        bare_mean = sum(bare_ts) / len(bare_ts)
        extra = sum(deltas) / len(deltas)
        return extra / bare_mean, bare_mean, bare_mean + extra, br_ids, ins_ids

    for attempt in range(3):
        overhead, bare_s, instr_s, bare_ids, instr_ids = measure_overhead()
        if overhead < 0.03:
            break
    if overhead >= 0.03:
        raise AssertionError(
            f"serve bench FAILED: observability overhead {overhead:.2%} "
            f"(instrumented {instr_s:.4f}s vs bare {bare_s:.4f}s per "
            "round, 3 attempts; gate < 3%)"
        )
    obs_overhead_ok = True
    if any((a != b2).any() for a, b2 in zip(bare_ids, instr_ids)):
        raise AssertionError(
            "serve bench FAILED: instrumentation changed result ids — "
            "observability must be bit-invisible"
        )

    # --- trace gate: one correlated story through a replica kill ---------
    treg, tracer = MetricsRegistry(), Tracer()
    inj = FaultInjector()
    clock = {"t": 0.0}
    mesh = FaultTolerantRetrievalMesh(
        lambda p=phi: p, n_shards=2, n_replicas=2, k=kk, block_items=32,
        injector=inj, retry=RetryPolicy(max_attempts=2),
        registry=treg, tracer=tracer,
    )
    mesh.publish(psi)
    inj.fail(0, 0, "error")
    batcher = MicroBatcher(
        lambda rows, eids: mesh.topk_phi(rows, exclude_ids=eids),
        max_batch=4, max_delay=1e-3, pad_to=4,
        clock=lambda: clock["t"], version_fn=lambda: mesh.version,
        registry=treg, tracer=tracer,
    )
    phi_small = np.asarray(rng.normal(size=(4, d)), np.float32)
    tickets = [batcher.submit(phi_small[r]) for r in range(4)]
    batcher.flush()
    killed = mesh.topk_phi(phi)
    names = {s.name for s in trace_for_ticket(tracer, tickets[0])}
    need = {"request", "queue", "flush", "dispatch", "failover", "merge"}
    if not need <= names:
        raise AssertionError(
            f"serve bench FAILED: ticket trace spans {sorted(names)} miss "
            f"{sorted(need - names)}"
        )
    healthy = RetrievalEngine(psi, lambda p=phi: p, k=kk,
                              block_items=32).topk_phi(phi)
    if not ((np.asarray(killed.ids) == np.asarray(healthy.ids)).all()
            and (np.asarray(killed.scores)
                 == np.asarray(healthy.scores)).all()):
        raise AssertionError(
            "serve bench FAILED: traced+killed mesh diverges from the "
            "healthy engine — failover must stay bit-invisible under "
            "instrumentation"
        )
    obs_trace_ok = True
    return {
        "obs_cost_model_ok": obs_cost_model_ok,
        "obs_overhead_ok": obs_overhead_ok,
        "obs_trace_ok": obs_trace_ok,
        "cost_parity": {
            "shape": dict(b=b, n_items=n_items, d=d, k=kk),
            "counted_calls": int(counted_calls),
            "counted_hbm_bytes": float(counted_bytes),
            "model_hbm_bytes_per_call": float(model["hbm_bytes"]),
        },
        "overhead": {
            "bare_s": float(bare_s),
            "instrumented_s": float(instr_s),
            "overhead_frac": float(overhead),
            "gate": "< 0.03",
            "n_requests": n_requests,
            "n_rounds": n_rounds,
            "attempts": attempt + 1,
        },
        "trace": {
            "ticket_span_names": sorted(names),
            "n_spans": len(tracer.spans),
            "fault_burned_s": float(mesh.stats["fault_burned_s"]),
        },
    }


def _measure_cpu(quick: bool, n_rounds: int = 3) -> dict:
    """Wall-clock of dense matmul+top_k vs the streaming kernel (interpret
    mode on CPU ⇒ emulation-bound; informational, never gated)."""
    from repro.kernels.topk_score import topk_score

    rng = np.random.default_rng(2)
    b, n_items, d, k = (16, 4096, 16, 10) if quick else (64, 65536, 64, 100)
    phi = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    psi = jnp.asarray(rng.normal(size=(n_items, d)), jnp.float32)

    dense = jax.jit(lambda p, q: jax.lax.top_k(p @ q.T, k))
    jax.block_until_ready(dense(phi, psi))
    t0 = time.perf_counter()
    for _ in range(n_rounds):
        jax.block_until_ready(dense(phi, psi))
    t_dense = (time.perf_counter() - t0) / n_rounds

    jax.block_until_ready(topk_score(phi, psi, k))
    t0 = time.perf_counter()
    for _ in range(n_rounds):
        jax.block_until_ready(topk_score(phi, psi, k))
    t_fused = (time.perf_counter() - t0) / n_rounds
    return {
        "shape": dict(b=b, n_items=n_items, d=d, k=k),
        "dense_s": t_dense,
        "fused_s": t_fused,
        "note": "interpret-mode emulation; HBM advantage is the analytic row",
    }


def serve_topk_bench(quick: bool = True, out_path: Optional[str] = None) -> dict:
    """Fused retrieval vs dense baseline + the sharded cluster tier; writes
    BENCH_topk_score.json.

    The tracked repo-root JSON is always the quick-mode (CI smoke) shape;
    ``--full`` runs land in BENCH_topk_score_full.json."""
    if out_path is None:
        repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        out_path = os.path.join(
            repo_root,
            "BENCH_topk_score.json" if quick else "BENCH_topk_score_full.json",
        )
    from repro.kernels import use_interpret

    analytic = {
        f"B={b}": topk_traffic_bytes(b=b, n_items=10_000_000, d=128, k=100)
        for b in (8, 64, 256, 1024)
    }
    analytic_cluster = {
        f"S={s}": cluster_traffic_bytes(
            b=256, n_items=10_000_000, d=128, k=100, n_shards=s
        )
        for s in (2, 4, 8, 16)
    }
    models = _zoo_parity(quick)
    cluster = _cluster_parity(quick)
    batcher = _batcher_bench(quick)
    failover = _failover_bench(quick)
    ann = _ann_bench(quick)
    eval_parity = _eval_harness_parity(quick)
    obs = _obs_bench(quick)
    measured = _measure_cpu(quick)
    results = {
        "kernel": "kernels/topk_score (fused score+top-K) vs dense "
                  "(B,n_items) matmul + lax.top_k; serve/cluster sharded "
                  "tier on top",
        "mode": "quick" if quick else "full",
        "backend": "interpret" if use_interpret() else "compiled",
        "analytic_web_scale": {
            "shape": "n_items=10M catalogue, D=128, K=100, fp32",
            **analytic,
        },
        "analytic_cluster": {
            "shape": "B=256, n_items=10M, D=128, K=100, fp32; per-shard ψ "
                     "stream + S·K merge candidates",
            **analytic_cluster,
        },
        "measured_cpu": measured,
        "models": models,
        "cluster": cluster,
        "batcher": batcher,
        "failover": failover,
        "ann": ann,
        "eval_harness": eval_parity,
        "obs": obs,
        "acceptance": {
            "bytes_ratio_at_B256": analytic["B=256"]["bytes_ratio"],
            "shard_overhead_at_S4": analytic_cluster["S=4"][
                "shard_overhead_ratio"
            ],
            "model_parity": {m: r["parity_ok"] for m, r in models.items()},
            "cluster_parity": all(r["parity_ok"] for r in cluster.values()),
            "batcher_routing_ok": batcher["routing_ok"],
            "failover_parity": failover["failover_parity"],
            "degraded_contract_ok": failover["degraded_contract_ok"],
            "retry_deadline_ok": failover["deadline_ok"],
            "eval_parity": eval_parity["parity_ok"],
            "sharded_eval_parity": eval_parity["sharded_parity_ok"],
            "ann_exact_parity": ann["ann_exact_parity"],
            "ann_recall_floor": ann["ann_recall_floor"],
            "quant_parity": ann["quant_parity"],
            "int8_capacity_x": ann["int8_capacity_x"],
            "obs_cost_model_ok": obs["obs_cost_model_ok"],
            "obs_overhead_ok": obs["obs_overhead_ok"],
            "obs_trace_ok": obs["obs_trace_ok"],
            "target":">= 2x fewer HBM bytes per retrieval batch at B >= 256 "
                      "(analytic; scores never leave VMEM); streaming top-K "
                      "== dense lax.top_k ids for every k-separable model "
                      "incl. exclude masks; sharded cluster bit-identical "
                      "to the single-device engine at shard counts 1-4 "
                      "(<= 1.05x byte overhead at S=4); batcher routes "
                      "out-of-order requests exactly; streaming ranking-eval "
                      "== dense metrics without a (n_eval, n_items) array, "
                      "single-table and sharded; replica kill under R=2 "
                      "bit-identical (failover invisible), unreplicated kill "
                      "completes with coverage < 1 + dead ranges, retry "
                      "backoff never exceeds the deadline budget; IVF tier "
                      "n_probe=n_clusters bit-identical to exact, recall@K "
                      ">= 0.95 at >= 4x analytic byte reduction, int8 ψ "
                      "scores within 5% relative + >= 3x rows per shard; "
                      "observability: kernel cost counters == the vmem "
                      "byte model, instrumented vs bare < 3% overhead, "
                      "one ticket-correlated trace through an injected "
                      "kill (request/queue/flush/dispatch/failover/merge) "
                      "with bit-invisible instrumentation",
            "met": analytic["B=256"]["bytes_ratio"] >= 2.0
                   and analytic_cluster["S=4"]["shard_overhead_ratio"] <= 1.05
                   and all(r["parity_ok"] for r in models.values())
                   and all(r["parity_ok"] for r in cluster.values())
                   and batcher["routing_ok"]
                   and failover["failover_parity"]
                   and failover["degraded_contract_ok"]
                   and failover["deadline_ok"]
                   and eval_parity["parity_ok"]
                   and eval_parity["sharded_parity_ok"]
                   and ann["ann_exact_parity"]
                   and ann["ann_recall_floor"]
                   and ann["quant_parity"]
                   and ann["int8_capacity_x"] >= 3.0
                   and obs["obs_cost_model_ok"]
                   and obs["obs_overhead_ok"]
                   and obs["obs_trace_ok"],
        },
    }
    if out_path:
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--smoke", action="store_true",
                      help="quick shapes + hard parity gate (CI; the default)")
    mode.add_argument("--full", action="store_true")
    args = ap.parse_args()
    res = serve_topk_bench(quick=not args.full)
    print(json.dumps(res["acceptance"], indent=1))
    assert res["acceptance"]["met"], "serve bench acceptance gate not met"
