"""iCD for PARAFAC tensor factorization (paper §5.3.1).

Model (eq. 34): ŷ(c1,c2,i) = Σ_f u_{c1,f} v_{c2,f} w_{i,f}, the 3-mode
extension of MF. k-separable with φ_f(c1,c2) = u_{c1,f}·v_{c2,f} and
ψ_f(i) = w_{i,f} (eq. 35). The regularizer derivatives (eqs. 37–38) reduce
to per-c1 reductions over that context's *partner* c2 values:

    R'(u_{c1*,f*})  = 2 Σ_f J_I(f,f*) u_{c1*,f} K_{c1*}(f,f*)
    R''(u_{c1*,f*}) = 2 J_I(f*,f*) K_{c1*}(f*,f*)
    K_{c1}(f,f*)    = Σ_{c2:(c1,c2)∈C} v_{c2,f} v_{c2,f*}

Context modes (paper's distinction):
  * ``sparse``  — C ⊂ C1×C2 is exactly the provided pair list; K is a
    segment-reduce over pairs. O((|C|+|I|)k²) per epoch.
  * ``dense``   — C = C1×C2; K decomposes to J_{C2} (eq. 39), identical for
    every c1, and J_C = J_{C1} ⊙ J_{C2} for the item sweep.
    O((|C1|+|C2|+|I|)k²) per epoch — no pair materialization.

The item sweep is exactly MF's (§5.1): "The item side is equivalent to
matrix factorization."

Fused padded path (``epoch_padded``, dispatched by ``hp.block_k`` exactly
like ``mf_padded``): each side's sweep runs on a :class:`PaddedGroup` grid
(nnz grouped by c1 / c2 / item) through ``sweeps.sweep_columns`` block
bodies. The context modes use the ``cd_block_sweep_rowpatch`` kernel —
their R'/R'' coupling is ROW-dependent (P[r, j, f] = J(j,f)·K_r(j,f),
eqs. 37–38) so the Gauss–Seidel patch slab rides per row; the item sweep is
MF-like and reuses the shared-Gram ``cd_block_sweep``. The residual cache
and α stay VMEM-resident across the ``k_b`` columns of each block.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sweeps
from repro.core.gram import gram
from repro.core.implicit import explicit_loss
from repro.core.padded import PaddedGroup, append_sentinel_row, build_group
from repro.kernels import vmem
from repro.kernels.cd_sweep.ops import (
    cd_block_sweep,
    cd_block_sweep_gather,
    cd_block_sweep_rowpatch,
    cd_block_sweep_rowpatch_gather,
)
from repro.sparse.interactions import Interactions
from repro.sparse.segment import segment_sum


class PARAFACParams(NamedTuple):
    u: jax.Array  # (n_c1, k)
    v: jax.Array  # (n_c2, k)
    w: jax.Array  # (n_items, k)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TensorContext:
    """Observed context pairs C ⊆ C1×C2. ``Interactions.ctx`` indexes rows
    of this pair list."""

    c1: jax.Array  # (n_ctx,) int32
    c2: jax.Array  # (n_ctx,) int32
    n_c1: int = dataclasses.field(metadata=dict(static=True))
    n_c2: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_ctx(self) -> int:
        return int(self.c1.shape[0])


@dataclasses.dataclass(frozen=True)
class PARAFACHyperParams:
    k: int
    alpha0: float = 1.0
    l2: float = 0.1
    eta: float = 1.0
    dense_context: bool = False  # True ⇒ regularizer universe is C1×C2
    implementation: str = "xla"
    block_k: int = 0  # columns per fused cd_sweep dispatch on the padded
    #                   layout (epoch_padded): 0 = auto (min(k, 8)),
    #                   1 = per-column baseline through the block path
    psi_dispatch: str = "gather"  # fused-path Ψ routing: 'gather' =
    #                   in-kernel gather of the flat pseudo-ψ slab (no
    #                   (n, k_b, D_pad) scatter_blk intermediate; auto-
    #                   fallback on VMEM overflow), 'pregather' = host-side
    #                   scatter/pre-gather (the PR 2 path)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TensorPadded:
    """Padded layouts for the fused tensor-model sweeps: the flat nnz list
    grouped by c1, by c2, and by item, plus the item-major pair-id grid the
    MF-like item sweep gathers Φ columns through."""

    g1: PaddedGroup
    g2: PaddedGroup
    gi: PaddedGroup
    pair_ids_item: jax.Array  # (n_items, gi.d_pad) int32; garbage on padding


def pad_tensor_groups(tc: TensorContext, data: Interactions, lane: int = 128) -> TensorPadded:
    """Host-side: build the three padded groupings of the observed set."""
    pair_of_nnz = np.asarray(data.ctx)
    alpha = np.asarray(data.alpha)
    g1 = build_group(np.asarray(tc.c1)[pair_of_nnz], alpha, tc.n_c1, lane)
    g2 = build_group(np.asarray(tc.c2)[pair_of_nnz], alpha, tc.n_c2, lane)
    gi = build_group(np.asarray(data.item), alpha, data.n_items, lane)
    pair_ids_item = np.zeros((data.n_items, gi.d_pad), np.int32)
    pair_ids_item[np.asarray(gi.rows), np.asarray(gi.cols)] = pair_of_nnz
    return TensorPadded(g1=g1, g2=g2, gi=gi,
                        pair_ids_item=jnp.asarray(pair_ids_item))


def init(key, n_c1: int, n_c2: int, n_items: int, k: int, sigma: float = 0.1) -> PARAFACParams:
    k1, k2, k3 = jax.random.split(key, 3)
    return PARAFACParams(
        u=sigma * jax.random.normal(k1, (n_c1, k), jnp.float32),
        v=sigma * jax.random.normal(k2, (n_c2, k), jnp.float32),
        w=sigma * jax.random.normal(k3, (n_items, k), jnp.float32),
    )


def phi(params: PARAFACParams, tc: TensorContext) -> jax.Array:
    """Φ over the observed pair list (sparse-context materialization)."""
    return jnp.take(params.u, tc.c1, axis=0) * jnp.take(params.v, tc.c2, axis=0)


def psi(params: PARAFACParams) -> jax.Array:
    return params.w


def export_psi(params: PARAFACParams) -> jax.Array:
    """ψ table for the retrieval engine: (n_items, k)."""
    return params.w


def build_phi(params: PARAFACParams, c1: jax.Array, c2: jax.Array) -> jax.Array:
    """φ rows for query context pairs: φ_f = u_{c1,f}·v_{c2,f} (eq. 35)."""
    return jnp.take(params.u, c1, axis=0) * jnp.take(params.v, c2, axis=0)


def predict(params: PARAFACParams, c1, c2, item) -> jax.Array:
    return jnp.sum(
        jnp.take(params.u, c1, axis=0)
        * jnp.take(params.v, c2, axis=0)
        * jnp.take(params.w, item, axis=0),
        axis=-1,
    )


def _context_mode_sweep(
    side: jax.Array,          # (n_side, k): U (group by c1) or V (group by c2)
    partner: jax.Array,       # (n_partner, k): V or U
    group_of_pair: jax.Array,     # (n_ctx,) c1 or c2 per pair
    partner_of_pair: jax.Array,   # (n_ctx,) c2 or c1 per pair
    j_i: jax.Array,
    data: Interactions,
    w_items: jax.Array,
    e: jax.Array,
    n_side: int,
    hp: PARAFACHyperParams,
    schedule=None,
    sweep_index: int = 0,
) -> Tuple[jax.Array, jax.Array]:
    """Sweep one context mode (U or V). Sparse-context K via segment sums;
    dense-context K via the partner Gram (eq. 39)."""
    pair_of_nnz = data.ctx

    def body(f, carry):
        side_m, e = carry
        s_col = sweeps.take_col(side_m, f)
        p_col_pair = jnp.take(sweeps.take_col(partner, f), partner_of_pair)  # (n_ctx,)
        w_col_nnz = jnp.take(sweeps.take_col(w_items, f), data.item)
        other_nnz = jnp.take(p_col_pair, pair_of_nnz) * w_col_nnz  # ∂ŷ per nnz

        grp_nnz = jnp.take(group_of_pair, pair_of_nnz)
        lp = segment_sum(data.alpha * e * other_nnz, grp_nnz, n_side)
        lpp = segment_sum(data.alpha * other_nnz * other_nnz, grp_nnz, n_side)

        if hp.dense_context:
            # K_{c1}(·,f*) = J_partner[:, f*] — identical for every group row.
            j_p_col = partner.T @ sweeps.take_col(partner, f)        # (k,)
            kmat = jnp.broadcast_to(j_p_col[None, :], side_m.shape)  # (n_side, k)
        else:
            pp = jnp.take(partner, partner_of_pair, axis=0)          # (n_ctx, k)
            kmat = segment_sum(pp * p_col_pair[:, None], group_of_pair, n_side)
        rp = jnp.sum(kmat * side_m * sweeps.take_col(j_i, f)[None, :], axis=1)
        rpp = j_i[f, f] * sweeps.take_col(kmat, f)

        delta = sweeps.newton_delta(
            sweeps.NewtonParts(lp + hp.alpha0 * rp, lpp + hp.alpha0 * rpp),
            s_col, hp.l2, hp.eta,
        )
        e = e + jnp.take(delta, grp_nnz) * other_nnz
        return sweeps.put_col(side_m, f, s_col + delta), e

    return sweeps.sweep_columns(
        hp.k, body, (side, e), schedule=schedule, sweep_index=sweep_index
    )


def _item_sweep(params_w, j_c, phi_cols_nnz, data, e_t, alpha_t, hp,
                schedule=None, sweep_index=0):
    """MF item sweep (paper: identical to §5.1)."""

    def body(f, carry):
        w_m, e_t = carry
        o_col = phi_cols_nnz(f)
        w_col = sweeps.take_col(w_m, f)
        lp = segment_sum(alpha_t * e_t * o_col, data.t_item, data.n_items)
        lpp = segment_sum(alpha_t * o_col * o_col, data.t_item, data.n_items)
        rp = w_m @ sweeps.take_col(j_c, f)
        rpp = j_c[f, f]
        delta = sweeps.newton_delta(
            sweeps.NewtonParts(lp + hp.alpha0 * rp, lpp + hp.alpha0 * rpp),
            w_col, hp.l2, hp.eta,
        )
        e_t = e_t + jnp.take(delta, data.t_item) * o_col
        return sweeps.put_col(w_m, f, w_col + delta), e_t

    return sweeps.sweep_columns(
        hp.k, body, (params_w, e_t), schedule=schedule, sweep_index=sweep_index
    )


def _context_mode_sweep_padded(
    side: jax.Array,          # (n_side, k): U or V
    partner: jax.Array,       # (n_partner, k): V or U (fixed this sweep)
    group_of_pair: jax.Array,
    partner_of_pair: jax.Array,
    j_i: jax.Array,
    data: Interactions,
    w_items: jax.Array,
    pg: PaddedGroup,          # nnz grouped by this side's context mode
    e_pad: jax.Array,         # (n_side, d_pad) residual grid
    n_side: int,
    hp: PARAFACHyperParams,
    k_b: int,
) -> Tuple[jax.Array, jax.Array]:
    """Fused context-mode sweep: ``k_b`` columns per ``cd_block_sweep_rowpatch``
    dispatch. Slab state per block — R'/2 ``(n, k_b)`` via Φ·J over pairs and
    the per-row patch tensor P = J ⊙ K (diag = R''/2, eqs. 37–38); the
    kernel's Gauss–Seidel r1 patch keeps later block columns exact.

    Ψ routing: the flat per-nnz pseudo-ψ ``s_nnz (nnz, k_b)`` rides into
    the gather kernel as a slab (+ zero sentinel row) with ``pg.flat_ids``
    by default — ``scatter_blk``'s ``(n, k_b, d_pad)`` intermediate only
    exists on the ``'pregather'``/VMEM-overflow fallback."""
    pair_of_nnz = data.ctx
    w_nnz = jnp.take(w_items, data.item, axis=0)               # (nnz, k)
    use_gather, _ = vmem.resolve_cd_sweep_dispatch(
        pg.d_pad, k_b, data.nnz + 1, n_rows=n_side,
        prefer_gather=sweeps.resolve_psi_dispatch(hp.psi_dispatch),
    )

    j_p = partner.T @ partner if hp.dense_context else None  # eq. 39 K

    def block_body(f0, kb, carry):
        side_m, e_pad = carry
        blk = slice(f0, f0 + kb)
        v_pair = jnp.take(partner[:, blk], partner_of_pair, axis=0)  # (n_pairs, kb)
        if hp.dense_context:
            # K = J_partner for EVERY row (regularizer universe C1×C2, even
            # when the observed pair list is sparse): R'_f = Σ_f' J(f',f)
            # K(f',f) θ_{·,f'} collapses to a dense matmul, matching the
            # flat path's broadcast kmat.
            r1_blk = side_m @ (j_p[:, blk] * j_i[:, blk])            # R'/2 slab
            k_blk = jnp.broadcast_to(j_p[blk, blk][None], (n_side, kb, kb))
        else:
            phi_full = jnp.take(side_m, group_of_pair, axis=0) * jnp.take(
                partner, partner_of_pair, axis=0
            )                                                        # (n_pairs, k)
            r1_blk = segment_sum(
                v_pair * (phi_full @ j_i[:, blk]), group_of_pair, n_side
            )                                                        # R'/2 slab
            k_blk = segment_sum(
                v_pair[:, :, None] * v_pair[:, None, :], group_of_pair, n_side
            )
        p_blk = k_blk * j_i[blk, blk][None, :, :]                    # J ⊙ K
        s_nnz = jnp.take(v_pair, pair_of_nnz, axis=0) * w_nnz[:, blk]
        if use_gather:
            w_new, e_pad = cd_block_sweep_rowpatch_gather(
                append_sentinel_row(s_nnz), pg.flat_ids, pg.alpha_pad,
                e_pad, side_m[:, blk], r1_blk, p_blk,
                alpha0=hp.alpha0, l2=hp.l2, eta=hp.eta,
            )
        else:
            psi_blk = pg.scatter_blk(s_nnz)                          # (n, kb, d_pad)
            w_new, e_pad = cd_block_sweep_rowpatch(
                psi_blk, pg.alpha_pad, e_pad, side_m[:, blk], r1_blk, p_blk,
                alpha0=hp.alpha0, l2=hp.l2, eta=hp.eta,
            )
        return side_m.at[:, blk].set(w_new), e_pad

    return sweeps.sweep_columns(
        hp.k, None, (side, e_pad), block=k_b, block_body=block_body
    )


def _item_sweep_padded(
    w_m: jax.Array,
    j_c: jax.Array,
    phi_pairs: jax.Array,     # (n_pairs, k) materialized Φ over the pair list
    padded: TensorPadded,
    e_pad: jax.Array,         # (n_items, d_pad) item-major residual grid
    hp,
    k_b: int,
) -> Tuple[jax.Array, jax.Array]:
    """MF-like fused item sweep (shared-Gram ``cd_block_sweep``): ψ columns
    gathered from Φ through the item-major pair-id grid — in-kernel by
    default (the Φ slab is the ψ table), pre-gathered on fallback."""
    use_gather, _ = vmem.resolve_cd_sweep_dispatch(
        padded.gi.d_pad, k_b, phi_pairs.shape[0], n_rows=w_m.shape[0],
        prefer_gather=sweeps.resolve_psi_dispatch(hp.psi_dispatch),
    )

    def block_body(f0, kb, carry):
        w_m, e_pad = carry
        blk = slice(f0, f0 + kb)
        r1_blk = w_m @ j_c[:, blk]
        if use_gather:
            w_new, e_pad = cd_block_sweep_gather(
                phi_pairs[:, blk], padded.pair_ids_item, padded.gi.alpha_pad,
                e_pad, w_m[:, blk], r1_blk, j_c[blk, blk],
                alpha0=hp.alpha0, l2=hp.l2, eta=hp.eta,
            )
        else:
            psi_blk = jnp.moveaxis(
                jnp.take(phi_pairs[:, blk], padded.pair_ids_item, axis=0), -1, 1
            )                                                        # (n, kb, d_pad)
            w_new, e_pad = cd_block_sweep(
                psi_blk, padded.gi.alpha_pad, e_pad, w_m[:, blk], r1_blk,
                j_c[blk, blk],
                alpha0=hp.alpha0, l2=hp.l2, eta=hp.eta,
            )
        return w_m.at[:, blk].set(w_new), e_pad

    return sweeps.sweep_columns(
        hp.k, None, (w_m, e_pad), block=k_b, block_body=block_body
    )


@partial(jax.jit, static_argnames=("hp", "schedule", "sweep_index"))
def epoch(
    params: PARAFACParams,
    tc: TensorContext,
    data: Interactions,
    e: jax.Array,
    hp: PARAFACHyperParams,
    schedule=None,
    sweep_index: int = 0,
    weights=None,
) -> Tuple[PARAFACParams, jax.Array]:
    """One iCD epoch: U sweep → V sweep → item (W) sweep (scheduled
    columns; ``schedule=None`` = full pass).

    ``weights`` (optional, (nnz,) ctx-major) folds per-interaction
    confidence into α exactly; ``None`` traces the identical program."""
    if weights is not None:
        data = dataclasses.replace(data, alpha=data.alpha * weights)
    u, v, w = params
    j_i = gram(w, implementation=hp.implementation)

    u, e = _context_mode_sweep(
        u, v, tc.c1, tc.c2, j_i, data, w, e, u.shape[0], hp,
        schedule, sweep_index,
    )
    v, e = _context_mode_sweep(
        v, u, tc.c2, tc.c1, j_i, data, w, e, v.shape[0], hp,
        schedule, sweep_index,
    )

    if hp.dense_context:
        j_c = gram(u) * gram(v)  # eq. (39): J_C = J_{C1} ⊙ J_{C2}
    else:
        j_c = gram(jnp.take(u, tc.c1, axis=0) * jnp.take(v, tc.c2, axis=0))
    e_t = sweeps.to_item_major(e, data.t_perm)
    alpha_t = sweeps.to_item_major(data.alpha, data.t_perm)
    phi_cols = lambda f: jnp.take(
        jnp.take(sweeps.take_col(u, f), tc.c1) * jnp.take(sweeps.take_col(v, f), tc.c2),
        data.t_ctx,
    )
    w, e_t = _item_sweep(
        w, j_c, phi_cols, data, e_t, alpha_t, hp, schedule, sweep_index
    )
    e = sweeps.to_ctx_major(e_t, data.t_perm)
    return PARAFACParams(u, v, w), e


@partial(jax.jit, static_argnames=("hp",), donate_argnums=(4,))
def epoch_padded(
    params: PARAFACParams,
    tc: TensorContext,
    data: Interactions,
    padded: TensorPadded,
    e: jax.Array,
    hp: PARAFACHyperParams,
    weights=None,
) -> Tuple[PARAFACParams, jax.Array]:
    """Fused-kernel iCD epoch on the padded layouts; same sweep order and
    fixed point as :func:`epoch` (parity-tested). The flat residual cache is
    re-grouped per sweep (scatter in, gather out — O(nnz), amortized over
    the ⌈k/k_b⌉ VMEM-resident block dispatches of the sweep).
    ``weights`` rebuilds all three group α grids via
    :meth:`~repro.core.padded.PaddedGroup.with_alpha`."""
    if weights is not None:
        a_eff = data.alpha * weights
        data = dataclasses.replace(data, alpha=a_eff)
        padded = dataclasses.replace(
            padded, g1=padded.g1.with_alpha(a_eff),
            g2=padded.g2.with_alpha(a_eff), gi=padded.gi.with_alpha(a_eff),
        )
    u, v, w = params
    k_b = sweeps.resolve_block_k(hp.block_k, hp.k)
    j_i = gram(w, implementation=hp.implementation)

    e_g = padded.g1.scatter(e)
    u, e_g = _context_mode_sweep_padded(
        u, v, tc.c1, tc.c2, j_i, data, w, padded.g1, e_g, u.shape[0], hp, k_b
    )
    e = padded.g1.gather(e_g)

    e_g = padded.g2.scatter(e)
    v, e_g = _context_mode_sweep_padded(
        v, u, tc.c2, tc.c1, j_i, data, w, padded.g2, e_g, v.shape[0], hp, k_b
    )
    e = padded.g2.gather(e_g)

    phi_pairs = jnp.take(u, tc.c1, axis=0) * jnp.take(v, tc.c2, axis=0)
    if hp.dense_context:
        j_c = gram(u) * gram(v)  # eq. (39): J_C = J_{C1} ⊙ J_{C2}
    else:
        j_c = gram(phi_pairs)
    e_g = padded.gi.scatter(e)
    w, e_g = _item_sweep_padded(w, j_c, phi_pairs, padded, e_g, hp, k_b)
    e = padded.gi.gather(e_g)
    return PARAFACParams(u, v, w), e


def residuals(params: PARAFACParams, tc: TensorContext, data: Interactions) -> jax.Array:
    return sweeps.residuals_from_factors(
        phi(params, tc), params.w, data.ctx, data.item, data.y
    )


def objective(params: PARAFACParams, tc: TensorContext, data: Interactions,
              hp: PARAFACHyperParams) -> jax.Array:
    e = residuals(params, tc, data)
    if hp.dense_context:
        reg = jnp.sum(gram(params.u) * gram(params.v) * gram(params.w))
    else:
        reg = jnp.sum(gram(phi(params, tc)) * gram(params.w))
    sq = sum(jnp.sum(p**2) for p in params)
    return explicit_loss(e, data.alpha) + hp.alpha0 * reg + hp.l2 * sq


def fit(params, tc, data, hp, n_epochs, callback=None, schedule=None,
        weights=None):
    e = residuals(params, tc, data)
    for ep in range(n_epochs):
        params, e = epoch(params, tc, data, e, hp, schedule, ep, weights)
        if callback is not None:
            callback(ep, params)
    return params
