"""Jit'd public wrappers for the fused score+top-K retrieval kernel family.

Two ops:

  * :func:`topk_score` — the fused streaming kernel over one ψ table (or
    one row-range shard of it, via ``id_offset``/``n_valid``);
  * :func:`topk_merge_shards` — the cross-shard K-way merge that combines
    per-shard top-K candidate lists (already carrying GLOBAL ids) into the
    final (B, k), reproducing the kernel's exact tie-stable
    ascending-global-id policy. The serving cluster (``serve/cluster.py``)
    is ``S × topk_score  →  topk_merge_shards``.
"""
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import kernel_jit
from repro.kernels.topk_score.kernel import topk_score_pallas


@kernel_jit(static_argnames=("k", "block_b", "block_items"))
def topk_score(phi, psi, k, exclude_mask=None, *, exclude_ids=None,
               psi_scale=None, id_offset=0, n_valid=None, block_b=128,
               block_items=None, interpret=None):
    """Fused streaming top-K over the ψ table: ``(scores, ids) (B, k)``.

    ``exclude_mask`` (B, n_rows), nonzero ⇒ never recommend; the web-scale
    alternative ``exclude_ids`` (B, L) is a −1-padded per-row list of
    GLOBAL excluded ids — the admissibility tile is built in-kernel per ψ
    block, so no (B, n_items) mask is ever materialized. Inadmissible
    slots come back as (−inf, −1). ``id_offset``/``n_valid`` (traced
    scalars allowed) serve a row-range ψ shard with global output ids; see
    ``kernel.py`` for the tie policy.

    ``psi`` may be quantized serving storage: bf16, or int8 with the
    per-row ``psi_scale`` from ``core.quant.int8_quantize_rows`` —
    dequantized in-kernel per tile, fp32 accumulate (``serve/ann.py``)."""
    return topk_score_pallas(
        phi, psi, k, exclude_mask, exclude_ids=exclude_ids,
        psi_scale=psi_scale, id_offset=id_offset, n_valid=n_valid,
        block_b=block_b, block_items=block_items, interpret=interpret,
    )


@partial(jax.jit, static_argnames=("k",))
def topk_merge_shards(shard_scores, shard_ids, k):
    """Cross-shard K-way merge: ``(S, B, Ks) → (B, k)`` scores and ids.

    Inputs are the stacked per-shard results of :func:`topk_score` with
    per-shard ``id_offset`` — ids are GLOBAL and the shards' row ranges are
    disjoint, so the merge is a pure rank: sort the S·Ks candidates per row
    by ``(−score, id)`` lexicographically (two-key ``lax.sort``) and take
    the first k. That reproduces the kernel's documented policy exactly:

      * descending score, ties in ASCENDING global id — identical to dense
        ``lax.top_k`` over the id-ordered full-catalogue row (shards emit
        id-sorted ties, but their top-K lists are score-ordered, so a
        positional concat-and-top_k would NOT be tie-stable; the explicit
        id key is what makes the merge shard-count-invariant);
      * (−inf, −1) on slots with no admissible candidate anywhere — the
        per-shard kernels already return −inf slots as id −1, and any slot
        still at −inf after the merge is forced to id −1.

    The (B, S·Ks) candidate scratch is the ``S·K`` term in the cluster's
    VMEM footprint model (:func:`repro.kernels.vmem.cluster_block_items`).
    """
    s, b, ks = shard_scores.shape
    flat_s = jnp.swapaxes(shard_scores, 0, 1).reshape(b, s * ks)
    flat_i = jnp.swapaxes(shard_ids, 0, 1).reshape(b, s * ks)
    if k > s * ks:  # fewer candidates than requested: pad inadmissible
        pad = k - s * ks
        flat_s = jnp.pad(flat_s, ((0, 0), (0, pad)), constant_values=-jnp.inf)
        flat_i = jnp.pad(flat_i, ((0, 0), (0, pad)), constant_values=-1)
    neg_sorted, ids_sorted = jax.lax.sort(
        (-flat_s, flat_i), dimension=1, num_keys=2
    )
    scores = -neg_sorted[:, :k]
    ids = jnp.where(jnp.isneginf(scores), -1, ids_sorted[:, :k])
    return scores, ids.astype(jnp.int32)
