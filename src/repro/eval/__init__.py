"""Offline evaluation harnesses (paper §6 protocols at serving scale)."""
from repro.eval.ranking import (  # noqa: F401
    fit_eval_callback,
    foldin_ranking_eval,
    model_eval_callback,
    ranking_eval,
)
