"""Request micro-batching for the online retrieval p99 path.

The fused ``topk_score`` kernel (and a TPU generally) is efficient at
kernel-shaped batches and terrible at B=1: a single-row query pays the whole
ψ-table stream by itself. Online traffic, however, ARRIVES one row at a
time. The :class:`MicroBatcher` closes that gap with the standard serving
trick — an admission queue that coalesces single-row queries into one padded
batch per kernel dispatch:

  flush policy (deadline/size):
    * SIZE — the queue reaching ``max_batch`` rows flushes immediately
      (admission of the triggering request included);
    * DEADLINE — otherwise a flush happens once ``now`` passes
      ``oldest.t_submit + max_delay``: no request waits longer than
      ``max_delay`` in the queue, bounding the batching-induced latency
      (the p99 knob);
    * callers drive time explicitly via :meth:`step` (or implicitly on
      every :meth:`submit`) — the batcher never sleeps or spawns threads,
      so tests run it under a SIMULATED clock.

  batch shaping: flushed rows are stacked and padded up to a multiple of
  ``pad_to`` φ rows (zero rows; results discarded), and the per-request
  exclude-id lists are right-padded with −1 to the widest list in the batch
  — exactly the (B, L) global-id form the kernel's exclude variant takes,
  so no (B, n_items) mask is built per request.

  routing: every request gets a ticket id at admission; after the flush the
  (k,) score/id rows are routed back to their tickets, so out-of-order
  submission, mixed flushes, and pad rows can never cross results between
  requests (parity-pinned in tests under a simulated clock).

  caching: an LRU φ→result cache keyed on ``(key, table_version,
  exclude_list)``. The version comes from the serving table
  (``cluster.version`` — bumped by every ``publish``), so a live ψ refresh
  implicitly invalidates the whole cache without any flush traffic; on the
  first admission AFTER a version bump every entry keyed on a superseded
  version is EVICTED outright (dead weight would otherwise squat in the
  LRU until capacity pressure aged it out, evicting live entries first).
  The exclude list is folded in by the batcher itself, so a caller key
  only has to identify the φ row. Only requests that carry an explicit
  hashable ``key`` participate (an unkeyed φ row has no cheap identity),
  and only full-coverage results are cached — a degraded answer
  (``coverage < 1``, see below) must not outlive the failure that caused
  it.

  degraded results: when the backing executor is the fault-tolerant mesh
  (``serve/mesh.py``), a flush's results may carry ``coverage < 1.0`` and
  dead item ranges. The batcher forwards that contract per ticket: each
  routed result is a single-row :class:`~repro.serve.cluster.TopKResult`
  (still unpackable as ``(scores, ids)``) tagged with the flush's
  coverage/dead ranges — a caller can always tell a full answer from a
  partial one.

  shutdown: :meth:`drain` flushes everything queued and closes the
  batcher — queued requests are never stranded; admissions after close
  raise. The serving driver calls it on the way out (and on SIGTERM in a
  real deployment).
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.serve.cluster import TopKResult


@dataclasses.dataclass
class _Pending:
    ticket: int
    phi_row: np.ndarray            # (D,)
    exclude: Optional[np.ndarray]  # (L,) global ids or None
    key: Optional[object]
    t_submit: float


class MicroBatcher:
    """Coalesce single-row top-K queries into kernel-shaped batches.

    ``topk_phi(phi_rows (B, D), exclude_ids (B, L) | None) -> (scores, ids)``
    is the backing batch executor — typically
    ``cluster.topk_phi`` / ``engine.topk_phi`` with exclusion passed through.

    ::

        batcher = MicroBatcher(
            lambda phi, eids: cluster.topk_phi(phi, exclude_ids=eids),
            max_batch=32, max_delay=2e-3, version_fn=lambda: cluster.version)
        t1 = batcher.submit(phi_row, exclude=[3, 7], key=("user", 17))
        ...
        batcher.step()            # deadline check; flush if due
        scores, ids = batcher.result(t1)   # None until flushed

    The batcher is deliberately single-threaded and clock-injected: the
    serving loop owns the cadence (call ``step`` between admissions), and
    the unit tests replay traces under a simulated clock.
    """

    def __init__(
        self,
        topk_phi: Callable,
        *,
        max_batch: int = 64,
        max_delay: float = 2e-3,
        pad_to: int = 8,
        clock: Callable[[], float] = time.monotonic,
        cache_size: int = 4096,
        version_fn: Optional[Callable[[], int]] = None,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.topk_phi = topk_phi
        self.max_batch = int(max_batch)
        self.max_delay = float(max_delay)
        self.pad_to = int(pad_to)
        self.clock = clock
        self.version_fn = version_fn or (lambda: 0)
        self._queue: List[_Pending] = []
        self._results: Dict[int, TopKResult] = {}
        self._completed_at: Dict[int, float] = {}
        self._next_ticket = 0
        self._cache: OrderedDict = OrderedDict()
        self._cache_size = int(cache_size)
        self._cache_version = self.version_fn()
        self._closed = False
        self.stats = {
            "submitted": 0, "flushes": 0, "flushed_rows": 0,
            "flush_by_size": 0, "flush_by_deadline": 0, "flush_forced": 0,
            "cache_hits": 0, "cache_misses": 0, "cache_evicted_stale": 0,
            "degraded_results": 0,
        }

    # ----------------------------------------------------------- admission
    def submit(
        self,
        phi_row,
        *,
        exclude=None,
        key: Optional[object] = None,
        now: Optional[float] = None,
    ) -> int:
        """Admit one single-row query; returns its ticket id.

        ``exclude`` is this request's global excluded-id list (seen items).
        ``key`` opts into the result cache and only has to identify the φ
        row (e.g. the user id): the exclude list and the table version are
        folded into the cache key here, so a request with a different
        exclusion set or against a newer ψ table can never be served a
        stale cached result."""
        if self._closed:
            raise RuntimeError(
                "batcher is closed (drained); no new admissions"
            )
        now = self.clock() if now is None else now
        self._evict_superseded()
        ticket = self._next_ticket
        self._next_ticket += 1
        self.stats["submitted"] += 1
        excl = None
        if exclude is not None:
            excl = np.asarray(exclude, np.int32).reshape(-1)
        if key is not None:
            hit = self._cache_get(self._cache_key(key, excl))
            if hit is not None:
                self.stats["cache_hits"] += 1
                self._results[ticket] = hit
                self._completed_at[ticket] = now
                self.step(now)  # a hit must still retire queue deadlines
                return ticket
            self.stats["cache_misses"] += 1
        self._queue.append(_Pending(
            ticket=ticket,
            phi_row=np.asarray(phi_row, np.float32).reshape(-1),
            exclude=excl, key=key, t_submit=now,
        ))
        if len(self._queue) >= self.max_batch:
            self._flush(now, "flush_by_size")
        else:
            self.step(now)  # admission also retires an overdue deadline
        return ticket

    # ---------------------------------------------------------------- time
    def step(self, now: Optional[float] = None) -> bool:
        """Flush iff the oldest queued request's deadline has passed.
        Returns whether a flush happened."""
        if not self._queue:
            return False
        now = self.clock() if now is None else now
        if now - self._queue[0].t_submit >= self.max_delay:
            self._flush(now, "flush_by_deadline")
            return True
        return False

    def flush(self, now: Optional[float] = None) -> None:
        """Force-flush everything queued."""
        now = self.clock() if now is None else now
        while self._queue:
            self._flush(now, "flush_forced")

    # ------------------------------------------------------------- shutdown
    def drain(self, now: Optional[float] = None) -> Dict[int, TopKResult]:
        """Graceful shutdown: flush every queued request so none is
        stranded, CLOSE the batcher (subsequent ``submit`` raises), and
        return all still-unclaimed results keyed by ticket so the caller
        can deliver them before exiting. Idempotent."""
        self.flush(now)
        self._closed = True
        out = dict(self._results)
        self._results.clear()
        self._completed_at.clear()
        return out

    @property
    def closed(self) -> bool:
        return self._closed

    # -------------------------------------------------------------- results
    def result(
        self, ticket: int, *, pop: bool = True
    ) -> Optional[TopKResult]:
        """Single-row :class:`~repro.serve.cluster.TopKResult` for a ticket
        (unpacks as ``scores (k,), ids (k,)``; carries the flush's
        ``coverage``/``dead_ranges``), or None while queued."""
        if ticket not in self._results:
            return None
        out = self._results.pop(ticket) if pop else self._results[ticket]
        if pop:
            self._completed_at.pop(ticket, None)
        return out

    def completed_at(self, ticket: int) -> Optional[float]:
        """Completion timestamp of a finished ticket (latency accounting)."""
        return self._completed_at.get(ticket)

    @property
    def n_queued(self) -> int:
        return len(self._queue)

    # ------------------------------------------------------------ internals
    def _flush(self, now: float, reason: str) -> None:
        batch, self._queue = self._queue[: self.max_batch], self._queue[self.max_batch:]
        b = len(batch)
        b_pad = -(-b // self.pad_to) * self.pad_to
        phi = np.zeros((b_pad, batch[0].phi_row.shape[0]), np.float32)
        for r, req in enumerate(batch):
            phi[r] = req.phi_row
        excl_ids = None
        l_max = max((req.exclude.shape[0] for req in batch
                     if req.exclude is not None), default=0)
        if l_max > 0:
            excl_ids = np.full((b_pad, l_max), -1, np.int32)
            for r, req in enumerate(batch):
                if req.exclude is not None:
                    excl_ids[r, : req.exclude.shape[0]] = req.exclude
            excl_ids = jnp.asarray(excl_ids)
        res = self.topk_phi(jnp.asarray(phi), excl_ids)
        scores, ids = res  # TopKResult or a bare (scores, ids) tuple
        coverage = float(getattr(res, "coverage", 1.0))
        dead_ranges = tuple(getattr(res, "dead_ranges", ()))
        scores = np.asarray(scores)
        ids = np.asarray(ids)
        if coverage < 1.0:
            self.stats["degraded_results"] += len(batch)
        for r, req in enumerate(batch):  # route rows back to their tickets
            out = TopKResult(scores[r], ids[r], coverage, dead_ranges)
            self._results[req.ticket] = out
            self._completed_at[req.ticket] = now
            # degraded answers are never cached: the hole they carry must
            # not outlive the replica failure that caused it
            if req.key is not None and coverage == 1.0:
                self._cache_put(self._cache_key(req.key, req.exclude), out)
        self.stats["flushes"] += 1
        self.stats["flushed_rows"] += b
        self.stats[reason] += 1
        if self._queue:  # drain backlog left by a size-capped flush
            self.step(now)

    def _cache_key(self, key, excl: Optional[np.ndarray]):
        """(caller key, table version, exclude list) — version comes from
        the live table so a publish implicitly invalidates every entry."""
        excl_key = () if excl is None else tuple(excl.tolist())
        return (key, self.version_fn(), excl_key)

    def _evict_superseded(self) -> None:
        """Drop cache entries keyed on a superseded table version the
        moment a publish is observed — they can never hit again (the key
        embeds the version), so letting them age out of the LRU would only
        crowd out live entries."""
        version = self.version_fn()
        if version == self._cache_version:
            return
        self._cache_version = version
        stale = [k for k in self._cache if k[1] != version]
        for k in stale:
            del self._cache[k]
        self.stats["cache_evicted_stale"] += len(stale)

    def _cache_get(self, key):
        if key not in self._cache:
            return None
        self._cache.move_to_end(key)
        return self._cache[key]

    def _cache_put(self, key, value) -> None:
        self._cache[key] = value
        self._cache.move_to_end(key)
        while len(self._cache) > self._cache_size:
            self._cache.popitem(last=False)
