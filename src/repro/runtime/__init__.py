from repro.runtime.elastic import ElasticMeshManager  # noqa: F401
from repro.runtime.health import StragglerWatchdog  # noqa: F401
