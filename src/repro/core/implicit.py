"""Lemma 1: the implicit objective as explicit loss + implicit regularizer.

``L(Θ|S_impl) = L(Θ|S̄) + α₀·R(Θ) + const`` where ``S̄`` rescales the
observed feedback (ȳ = α/(α−α₀)·y, ᾱ = α−α₀; paper eq. 7–8) and
``R(Θ) = Σ_{c∈C} Σ_{i∈I} ŷ(c,i)²`` penalizes non-zero predictions anywhere.

This module provides both the efficient (Lemma 2 / Gram) evaluation and the
brute-force O(|C||I|) oracle used by the equivalence tests and the Figure 8
cost benchmark.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.gram import gram
from repro.sparse.interactions import Interactions


def rescale_observed(y: jax.Array, alpha: jax.Array, alpha0: float) -> Tuple[jax.Array, jax.Array]:
    """Eq. (8): collapse each (c,i,y,α) ∈ S⁺ with its (c,i,0,−α₀) counterpart."""
    return alpha / (alpha - alpha0) * y, alpha - alpha0


def frequency_confidence(
    count, *, beta: float = 1.0, mode: str = "linear", eps: float = 1.0
):
    """Hu et al. 2008 confidence from interaction frequency.

    ``linear``: α = 1 + β·count       (eq. 2 of Hu et al.)
    ``log``:    α = 1 + β·log(1 + count/ε)   (their eq. 3 variant)

    Returns the RAW observed confidence α (α > 1 for count > 0) — feed it to
    :func:`~repro.sparse.interactions.build_interactions` which applies the
    Lemma-1 rescale (ᾱ = α−α₀) for any α₀ < 1; or divide by a baseline α to
    obtain a relative per-interaction weight for the ``weights=`` epoch
    paths.
    """
    count = jnp.asarray(count, jnp.float32)
    if mode == "linear":
        return 1.0 + beta * count
    if mode == "log":
        return 1.0 + beta * jnp.log1p(count / eps)
    raise ValueError(f"unknown frequency confidence mode {mode!r}")


def confidence_weights(alpha_raw, *, base: float = 1.0):
    """Per-interaction weights w = α/base for the ``weights=`` epoch paths:
    training with ``(alpha=base·1, weights=w)`` equals training with
    ``alpha=α`` directly (α is purely multiplicative in the explicit loss
    parts — see the kernel ops docstrings)."""
    return jnp.asarray(alpha_raw, jnp.float32) / base


def implicit_regularizer_gram(phi: jax.Array, psi: jax.Array) -> jax.Array:
    """Lemma 2: R(Θ) = Σ_{f,f'} J_C(f,f')·J_I(f,f') in O((|C|+|I|)k²)."""
    j_c = gram(phi)
    j_i = gram(psi)
    return jnp.sum(j_c * j_i)


def implicit_regularizer_naive(phi: jax.Array, psi: jax.Array) -> jax.Array:
    """Brute force O(|C||I|): R(Θ) = Σ_c Σ_i ⟨φ(c),ψ(i)⟩². Oracle/benchmark."""
    scores = phi.astype(jnp.float32) @ psi.astype(jnp.float32).T
    return jnp.sum(scores * scores)


def explicit_loss(e: jax.Array, alpha: jax.Array) -> jax.Array:
    """Rescaled explicit part Σ ᾱ·(ŷ−ȳ)² given cached residuals e = ŷ−ȳ."""
    return jnp.sum(alpha * e * e)


def implicit_objective(
    phi: jax.Array,
    psi: jax.Array,
    e: jax.Array,
    data: Interactions,
    alpha0: float,
    l2: float,
    params_sq_norm: jax.Array,
) -> jax.Array:
    """Full Lemma-1 objective (up to the additive constant of the proof):

    Σ_S̄ ᾱ(ŷ−ȳ)² + α₀·R(Θ) + λ‖Θ‖².
    """
    return (
        explicit_loss(e, data.alpha)
        + alpha0 * implicit_regularizer_gram(phi, psi)
        + l2 * params_sq_norm
    )


def dense_implicit_objective(
    scores: jax.Array,
    y_dense: jax.Array,
    alpha_dense: jax.Array,
    l2: float,
    params_sq_norm: jax.Array,
) -> jax.Array:
    """The original, pre-Lemma-1 objective over the FULL |C|×|I| grid
    (eq. 1 over S_impl). Used by the exactness tests: iCD on the rescaled
    form must reach the same optimum as naive CD on this objective."""
    diff = scores - y_dense
    return jnp.sum(alpha_dense * diff * diff) + l2 * params_sq_norm
