"""Straggler detection: per-step timing watchdog.

On a pod each host reports step wall-times through the coordinator; hosts
whose p50 exceeds the fleet p50 by ``threshold``× for ``patience``
consecutive windows are flagged, triggering either (a) checkpoint + elastic
re-mesh without them, or (b) scheduler eviction. In this container the same
logic runs over injected timings (tests) and the trainer's real step times.
"""
from __future__ import annotations

import collections
from typing import Dict, List


class StragglerWatchdog:
    def __init__(self, threshold: float = 2.0, patience: int = 3, window: int = 16):
        self.threshold = threshold
        self.patience = patience
        self.histories: Dict[int, collections.deque] = {}
        self.strikes: Dict[int, int] = collections.defaultdict(int)
        self.window = window

    def report(self, host_id: int, step_time: float) -> None:
        self.histories.setdefault(
            host_id, collections.deque(maxlen=self.window)
        ).append(step_time)

    def _median(self, xs: List[float]) -> float:
        s = sorted(xs)
        return s[len(s) // 2]

    def check(self) -> List[int]:
        """Returns host ids currently flagged as stragglers."""
        if len(self.histories) < 2:
            return []
        medians = {h: self._median(list(v)) for h, v in self.histories.items()
                   if len(v) >= 3}
        if len(medians) < 2:
            return []
        fleet = self._median(list(medians.values()))
        flagged = []
        for h, m in medians.items():
            if m > self.threshold * fleet:
                self.strikes[h] += 1
            else:
                self.strikes[h] = 0
            if self.strikes[h] >= self.patience:
                flagged.append(h)
        return flagged
