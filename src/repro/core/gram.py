"""Gram matrices J = MᵀM — the engine of Lemma 2.

For any k-separable model the implicit regularizer collapses to
``R(Θ) = Σ_{f,f'} J_C(f,f') · J_I(f,f')`` (paper eq. 12) with
``J_C = ΦᵀΦ`` and ``J_I = ΨᵀΨ``. Both are tall-skinny matmuls
(|C| or |I| rows, k ≤ a few hundred columns) whose k×k results are tiny —
this is what makes implicit CD communication-trivial when the rows are
sharded: each shard computes a partial Gram and a k² all-reduce (64 KB at
k=128 fp32) combines them.

``gram`` dispatches to the Pallas TPU kernel (``repro.kernels.gram``) when
requested; the pure-XLA path is the default and the oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gram(m: jax.Array, *, implementation: str = "xla",
         weights: jax.Array | None = None) -> jax.Array:
    """J = mᵀm (or mᵀ·diag(w)·m) with fp32 accumulation. m: (rows, k) → (k, k).

    ``weights=None`` is a trace-time branch: the unweighted program is
    untouched on every backend."""
    if implementation == "pallas":
        from repro.kernels.gram import ops as gram_ops

        return gram_ops.gram(m, weights=weights)
    if weights is not None:
        return weighted_gram(m, weights)
    mf = m.astype(jnp.float32)
    return jnp.dot(mf.T, mf, preferred_element_type=jnp.float32)


def gram_pair(phi: jax.Array, psi: jax.Array, *, implementation: str = "xla"):
    """(J_C, J_I) for the two sides of a k-separable model."""
    return (
        gram(phi, implementation=implementation),
        gram(psi, implementation=implementation),
    )


def sharded_gram(m: jax.Array, axis_name: str) -> jax.Array:
    """Per-shard partial Gram + all-reduce over ``axis_name``.

    To be called inside ``shard_map`` with rows of ``m`` sharded over
    ``axis_name``. The all-reduced payload is k² floats — independent of the
    number of rows. This op realizes the paper's O((|C|+|I|)k²) bound in the
    distributed setting: compute scales with local rows, communication is
    constant.
    """
    local = gram(m)
    return jax.lax.psum(local, axis_name)


def weighted_gram(m: jax.Array, w: jax.Array) -> jax.Array:
    """J = mᵀ diag(w) m — used for confidence-weighted variants. w: (rows,)."""
    mf = m.astype(jnp.float32)
    return jnp.dot(mf.T * w[None, :].astype(jnp.float32), mf,
                   preferred_element_type=jnp.float32)
