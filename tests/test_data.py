"""Data pipeline: synthetic generator structure + hosted loaders + design."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis; CI installs it
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.design import design_matmul, make_design, to_dense
from repro.data.loader import lm_token_batches
from repro.data.synthetic import make_implicit_dataset


def test_synthetic_dataset_structure():
    ds = make_implicit_dataset(n_users=50, n_items=40, seed=3)
    assert ds.events.shape[1] == 3
    assert ds.events[:, 0].max() < 50 and ds.events[:, 1].max() < 40
    # time-ordered
    assert np.all(np.diff(ds.events[:, 2]) > 0)
    # every user has events within the configured range
    hists = ds.user_histories()
    assert len(hists) == 50
    assert all(len(h) >= 1 for h in hists)
    # attributes in range
    assert ds.age.max() < ds.n_age and ds.country.max() < ds.n_country


def test_attribute_signal_exists():
    """Users sharing attributes must have more similar item distributions
    than random pairs — the mechanism behind the Figure-7 reproduction."""
    ds = make_implicit_dataset(n_users=300, n_items=200, attr_strength=0.95,
                               pop_strength=0.3, taste_strength=2.5, seed=0)
    hists = ds.user_histories()

    def dist(u):
        v = np.bincount(hists[u], minlength=200).astype(float)
        return v / max(v.sum(), 1)

    key = [(a, c) for a, c in zip(ds.age, ds.country)]
    same, diff = [], []
    rng = np.random.default_rng(0)
    for _ in range(3000):
        u, v = rng.integers(0, 300, 2)
        if u == v:
            continue
        sim = float(dist(u) @ dist(v))
        (same if key[u] == key[v] else diff).append(sim)
    if len(same) > 10:
        assert np.mean(same) > np.mean(diff)


def test_lm_token_batches_learnable_structure():
    it = lm_token_batches(vocab=64, global_batch=8, seq_len=32, seed=0)
    b = next(it)
    assert b["tokens"].shape == (8, 32)
    np.testing.assert_array_equal(b["targets"][:, :-1], b["tokens"][:, 1:])
    # bigram structure: next-token entropy given current token is reduced
    tok, tgt = b["tokens"].ravel(), b["targets"].ravel()
    pairs = {}
    for a, c in zip(tok, tgt):
        pairs.setdefault(int(a), []).append(int(c))
    # most contexts concentrate on ≤ 5 successors (4 choices + noise)
    concentrated = [len(set(v)) <= 6 for v in pairs.values() if len(v) >= 4]
    assert np.mean(concentrated) > 0.5


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 500), n=st.integers(1, 12))
def test_design_matmul_matches_dense(seed, n):
    rng = np.random.default_rng(seed)
    design = make_design(
        [
            dict(name="a", ids=rng.integers(0, 5, n), vocab=5),
            dict(name="b", ids=rng.integers(0, 3, n), vocab=3,
                 weights=rng.normal(size=n).astype(np.float32)),
        ],
        n,
    )
    w = jnp.asarray(rng.normal(size=(design.p, 4)), jnp.float32)
    np.testing.assert_allclose(
        design_matmul(design, w), to_dense(design) @ w, rtol=2e-4, atol=2e-5
    )
