"""Hillclimb #3 — olmoe-1b-7b × train_4k (worst useful-compute ratio).

Baseline: compute 46 s vs MODEL_FLOPS/HLO ≈ 0.00, collective 91 s.
Diagnosis: the MoE dispatch buffer (E, C, D) is scatter-built, GSPMD cannot
infer a sharding for it and partially REPLICATES the expert GEMMs (the
einsum only picks up the expert-axis sharding of the weights, not a token
sharding of the buffer): per-device expert flops ≈ global/16 instead of
/256.

Iteration 1 — dispatch sharding constraint (repro.models.hints):
    buf, eo constrained to P("model" on experts, "data" on capacity).
    Napkin: expert GEMMs 1.3e17 global per step → /256 = 5.2e14/device
    → ≈ 2.6 s compute (from 46 s); the scatter/gather becomes a real
    all-to-all (token redistribution), small payload (T·D·2B / device).

Iteration 2 — + ZeRO-1/bf16 params (borrowed from hillclimb #2): kills the
    per-microbatch expert-weight re-gathers (64 experts × 3 × 2048×1024
    × 28 layers ≈ 22 GB bf16 re-gathered ×4 µb in the baseline).

Run:  PYTHONPATH=src:. python -m benchmarks.hillclimb_moe
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.launch import hlo_analysis, sharding as sh  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.sharding import named  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.models.hints import sharding_hints  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.optim.mixed import mixed_precision  # noqa: E402
from repro.train.train_step import build_train_step, init_state  # noqa: E402

ARCH = "olmoe-1b-7b"
B, S = 256, 4096
COMPONENTS = ("flops", "bytes", "all-gather", "all-reduce", "reduce-scatter",
              "all-to-all", "collective-permute")


def _vector(compiled):
    ca = compiled.cost_analysis() or {}
    cb = hlo_analysis.collective_bytes(compiled.as_text())
    cb.pop("_counts")
    return np.array([float(ca.get("flops", 0)), float(ca.get("bytes accessed", 0))]
                    + [cb[k] for k in COMPONENTS[2:]])


def compile_probe(mesh, n_layers, microbatches, hints: bool, zero1: bool, batch=None):
    cfg = dataclasses.replace(
        get_config(ARCH), n_layers=n_layers, scan_layers=False,
        num_microbatches=microbatches,
    )
    params_abs = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    if zero1:
        params_abs = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16), params_abs
        )
        opt = mixed_precision(adamw(1e-4))
    else:
        opt = adamw(1e-4)
    state_abs = jax.eval_shape(lambda p: init_state(p, opt), params_abs)
    fsdp_specs = sh.lm_param_specs(cfg, params_abs)
    st_specs = (sh.zero1_state_specs(fsdp_specs)[0] if zero1
                else sh.train_state_specs(fsdp_specs))
    step = build_train_step(
        lambda p, b: T.loss_fn(cfg, p, b["tokens"], b["targets"]),
        opt, num_microbatches=microbatches, unroll_microbatches=True,
    )
    bsz = batch or B
    batch_abs = {"tokens": jax.ShapeDtypeStruct((bsz, S), jnp.int32),
                 "targets": jax.ShapeDtypeStruct((bsz, S), jnp.int32)}
    from jax.sharding import PartitionSpec as P

    import contextlib

    hint_ctx = (sharding_hints(expert="model", capacity=("data",))
                if hints else contextlib.nullcontext())
    with mesh, hint_ctx:
        compiled = jax.jit(
            step,
            in_shardings=(named(mesh, st_specs), named(mesh, sh.lm_batch_specs(mesh))),
            out_shardings=(named(mesh, st_specs),
                           named(mesh, {"loss": P(), "grad_norm": P()})),
        ).lower(state_abs, batch_abs).compile()
    return _vector(compiled)


def measure(hints, zero1, mesh, l_full=16, m_full=4, label=""):
    from benchmarks.probe_common import combine
    t0 = time.time()
    u11 = compile_probe(mesh, 1, 1, hints, zero1)
    u21 = compile_probe(mesh, 2, 1, hints, zero1)
    u11h = compile_probe(mesh, 1, 1, hints, zero1, batch=B // 2)
    u21h = compile_probe(mesh, 2, 1, hints, zero1, batch=B // 2)
    u12 = compile_probe(mesh, 1, 2, hints, zero1)
    full, split = combine(u11, u21, u11h, u21h, u12, l_full, m_full)
    comp = dict(zip(COMPONENTS, full.tolist()))
    comp["_split"] = split
    total_coll = sum(comp[k] for k in COMPONENTS[2:])
    return {
        "variant": label,
        "compile_s": round(time.time() - t0, 1),
        "compute_s": comp["flops"] / hlo_analysis.PEAK_FLOPS,
        "memory_s": comp["bytes"] / hlo_analysis.HBM_BW,
        "collective_s": total_coll / hlo_analysis.LINK_BW,
        "collective_breakdown": {k: comp[k] for k in COMPONENTS[2:]},
        "per_layer_split": comp.get("_split"),
    }


def main():
    mesh = make_production_mesh(multi_pod=False)
    results = {"cell": f"{ARCH} × train_4k", "mesh": "16x16"}
    try:
        results["baseline_roofline"] = json.load(
            open(f"results/dryrun/{ARCH}__train_4k__sp.json"))["roofline"]
    except FileNotFoundError:
        pass
    results["iterations"] = []
    for hints, zero1, label in ((False, False, "baseline(remeasured)"),
                                (True, False, "dispatch-constraint"),
                                (True, True, "dispatch-constraint + zero1/bf16")):
        r = measure(hints, zero1, mesh, label=label)
        results["iterations"].append(r)
        print(f"{label}: compute={r['compute_s']:.3e}s "
              f"memory={r['memory_s']:.3e}s coll={r['collective_s']:.3e}s",
              flush=True)
    os.makedirs("results/perf", exist_ok=True)
    with open("results/perf/hillclimb_moe.json", "w") as f:
        json.dump(results, f, indent=1, default=float)


if __name__ == "__main__":
    main()
