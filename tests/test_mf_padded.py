"""Kernel-fused padded iCD-MF == reference iCD-MF, trajectory-level."""
import jax
import numpy as np

from repro.core.models import mf, mf_padded
from repro.sparse.interactions import build_interactions


def make_problem(seed=0, n_ctx=40, n_items=25, nnz=200, alpha0=0.4):
    rng = np.random.default_rng(seed)
    cells = rng.choice(n_ctx * n_items, size=nnz, replace=False)
    ctx, item = cells // n_items, cells % n_items
    y = rng.integers(1, 5, size=nnz).astype(np.float64)
    alpha = alpha0 + 1.0 + rng.random(nnz)
    return build_interactions(ctx, item, y, alpha, n_ctx, n_items, alpha0=alpha0)


def test_padded_epoch_matches_reference():
    data = make_problem()
    hp = mf.MFHyperParams(k=8, alpha0=0.4, l2=0.05)
    params = mf.init(jax.random.PRNGKey(0), data.n_ctx, data.n_items, 8)
    pdata = mf_padded.pad_interactions(data)

    p_ref, p_pad = params, params
    e_ref = mf.residuals(p_ref, data)
    e_pad = mf_padded.residuals(p_pad, pdata)
    for _ in range(3):
        p_ref, e_ref = mf.epoch(p_ref, data, e_ref, hp)
        p_pad, e_pad = mf_padded.epoch(p_pad, pdata, e_pad, hp)
        np.testing.assert_allclose(p_pad.w, p_ref.w, rtol=3e-4, atol=3e-5)
        np.testing.assert_allclose(p_pad.h, p_ref.h, rtol=3e-4, atol=3e-5)


def test_padded_layout_roundtrip():
    data = make_problem(seed=3)
    pdata = mf_padded.pad_interactions(data)
    # every observation lands exactly once in each grid
    assert int((np.asarray(pdata.alpha_c) > 0).sum()) == data.nnz
    assert int((np.asarray(pdata.alpha_i) > 0).sum()) == data.nnz
    a1 = np.asarray(pdata.alpha_c)[np.asarray(pdata.c_rows), np.asarray(pdata.c_cols)]
    a2 = np.asarray(pdata.alpha_i)[np.asarray(pdata.i_rows), np.asarray(pdata.i_cols)]
    np.testing.assert_allclose(a1, np.asarray(data.alpha))
    np.testing.assert_allclose(a2, np.asarray(data.alpha))
