"""Jit'd public wrapper for the fused CD column update.

``e`` is donated: the (C, D_pad) fp32 residual cache is consumed and
replaced on every column, so an eager caller's buffer is reused instead of
copied. (Inside an outer jit — the ``mf_padded.epoch`` path — nested-jit
donation is inert; there the copy elimination comes from the kernel's
e→e_out ``input_output_aliases`` plus ``epoch`` donating ``e_pad`` at the
top level.) Callers must treat their ``e`` as dead after the call.
"""
from repro.kernels import kernel_jit
from repro.kernels.cd_update.kernel import cd_column_update_pallas


@kernel_jit(static_argnames=("alpha0", "l2", "eta", "block_ctx"),
            donate_argnums=(2,))
def cd_column_update(psi, alpha, e, w_col, r1, jff, *, alpha0, l2, eta=1.0,
                     block_ctx=256, weights=None, interpret=None):
    # alpha enters the fused update purely multiplicatively (explicit loss
    # parts only; the implicit/Gram part is uniform alpha0), so per-
    # interaction weights fold exactly into alpha_eff = alpha·w here, outside
    # the pallas call. weights=None is a trace-time branch: identical program.
    if weights is not None:
        alpha = alpha * weights
    return cd_column_update_pallas(
        psi, alpha, e, w_col, r1, jff,
        alpha0=alpha0, l2=l2, eta=eta, block_ctx=block_ctx,
        interpret=interpret,
    )
