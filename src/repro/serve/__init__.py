"""Online retrieval serving: single-device engine, sharded cluster,
request micro-batching, and live ψ publish from training."""
from repro.serve.batcher import MicroBatcher  # noqa: F401
from repro.serve.cluster import (  # noqa: F401
    PsiShardSet,
    ShardedRetrievalCluster,
    cluster_topk,
    shard_map_topk,
    shard_psi,
)
from repro.serve.engine import (  # noqa: F401
    RetrievalEngine,
    exclude_ids_from_lists,
    exclude_mask_from_lists,
)
from repro.serve.publish import PsiPublisher, VersionedTable  # noqa: F401
from repro.serve.recsys_serve import bulk_score, retrieval_topk  # noqa: F401
