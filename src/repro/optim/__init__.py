"""Optimizers & distributed-optimization utilities (no optax dependency).

Functional design: ``opt.init(params) -> state``,
``opt.update(grads, state, params) -> (updates, state)``; apply with
``apply_updates``. Includes AdamW, Adafactor (the memory-frugal choice for
the 67B config), SGD+momentum, LR schedules, global-norm clipping, and the
int8 error-feedback gradient compressor for the DP all-reduce.
"""

from repro.optim.base import OptimizerDef, apply_updates, global_norm
from repro.optim.sgd import sgd
from repro.optim.adam import adamw
from repro.optim.adafactor import adafactor
from repro.optim.schedules import constant, cosine_decay, linear_warmup_cosine
from repro.optim.clip import clip_by_global_norm
from repro.optim.compression import int8_compress, int8_decompress, ef_compress_update

__all__ = [
    "OptimizerDef", "apply_updates", "global_norm",
    "sgd", "adamw", "adafactor",
    "constant", "cosine_decay", "linear_warmup_cosine",
    "clip_by_global_norm",
    "int8_compress", "int8_decompress", "ef_compress_update",
]
