"""Elastic scaling: rebuild the mesh on a changed device set and reshard.

Node failures / additions on a real pod surface as a changed
``jax.devices()`` list after the coordinator barrier. The recovery protocol
implemented here (and exercised in tests with host devices):

  1. watchdog / coordinator reports failed hosts
  2. pick the largest (data, model)-factorizable device subset
  3. rebuild the mesh
  4. restore the latest checkpoint with the NEW shardings (the
     checkpointer's resharding path) — parameters never need an
     all-to-all repartition step of their own
  5. re-lower the step functions (jit cache keys include shardings)

The data pipeline re-shards by host id (``repro.data.loader``), so a resize
changes only per-host batch slices.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np


def largest_mesh_shape(n_devices: int, model_axis: int) -> Tuple[int, int]:
    """Largest (data, model) grid with model ≤ ``model_axis`` that tiles the
    surviving device count exactly. Keeps TP groups as large as possible
    (model stays intra-host on real pods); sheds whole DP replicas instead.

    The model axis shrinks to the LARGEST DIVISOR of ``n_devices`` that is
    ≤ ``model_axis`` — not just a halving chain, which skips every
    non-power-of-two divisor (e.g. ``n_devices=8, model_axis=6`` must give
    ``(2, 4)``, and ``n_devices=250, model_axis=16`` gives ``(25, 10)``,
    not the halving chain's ``(125, 2)``)."""
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    cap = max(1, min(model_axis, n_devices))
    model = max(d for d in range(1, cap + 1) if n_devices % d == 0)
    return n_devices // model, model


class ElasticMeshManager:
    def __init__(self, axis_names=("data", "model"), model_axis: int = 1):
        self.axis_names = axis_names
        self.model_axis = model_axis
        self.mesh: Optional[jax.sharding.Mesh] = None

    def build(self, devices: Optional[Sequence] = None) -> jax.sharding.Mesh:
        devices = list(devices if devices is not None else jax.devices())
        data, model = largest_mesh_shape(len(devices), self.model_axis)
        grid = np.asarray(devices[: data * model]).reshape(data, model)
        self.mesh = jax.sharding.Mesh(grid, self.axis_names)
        return self.mesh

    def on_failure(self, failed_ids: Sequence[int]) -> jax.sharding.Mesh:
        """Rebuild excluding failed device ids (simulated failure in tests;
        on a pod the runtime supplies the surviving set)."""
        alive = [d for d in jax.devices() if d.id not in set(failed_ids)]
        return self.build(alive)

    def shardings(self, spec_tree, params_like):
        mesh = self.mesh
        return jax.tree_util.tree_map(
            lambda spec: jax.sharding.NamedSharding(mesh, spec),
            spec_tree,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
        )
