"""Logical-axis sharding hints for model internals.

Model code stays mesh-agnostic: it annotates intermediates with LOGICAL axes
(``constrain(x, ("expert", "tokens", None))``); the launch layer activates a
mapping from logical axes to mesh axes for the duration of a trace. With no
active mapping every call is a no-op, so tests/CPU paths are unaffected.

This is the mechanism behind the MoE-dispatch hillclimb (EXPERIMENTS.md
§Perf #3): GSPMD fails to propagate a useful sharding through the
scatter-built (E, C, D) dispatch buffer and replicates the expert GEMMs;
one constraint on the buffer fixes it.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()

Axis = Union[str, Tuple[str, ...], None]


def _current() -> Optional[Dict[str, Axis]]:
    return getattr(_state, "mapping", None)


@contextlib.contextmanager
def sharding_hints(**mapping: Axis):
    """Activate logical→mesh axis mapping, e.g.
    ``sharding_hints(expert="model", tokens=("data",))``."""
    prev = _current()
    _state.mapping = dict(mapping)
    try:
        yield
    finally:
        _state.mapping = prev


def constrain(x: jax.Array, logical_axes: Tuple[Optional[str], ...]) -> jax.Array:
    mapping = _current()
    if mapping is None:
        return x
    spec = P(*[mapping.get(a) if a is not None else None for a in logical_axes])
    return jax.lax.with_sharding_constraint(x, spec)
