"""iCD config registry smoke tests.

The seed-template LM/RecSys/GNN zoo (configs, models, smoke tests) was
retired — the registry carries only the paper's own iCD configs.
"""
import pytest

from repro.configs import ARCH_IDS, get_config, get_shapes, get_smoke_config


# ------------------------------------------------------------- iCD own ----
@pytest.mark.parametrize("arch", ["icd-mf", "icd-fm"])
def test_icd_config_smoke(arch):
    cfg = get_smoke_config(arch)
    assert cfg.model in ("mf", "fm")
    assert get_config(arch).n_ctx >= 1000 * cfg.n_ctx / 1000  # full is bigger


def test_registry_complete():
    assert len(ARCH_IDS) == 2  # only the paper's own configs remain
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        shapes = get_shapes(arch)
        assert cfg.name == arch
        assert len(shapes) >= 3
