"""repro — production multi-pod JAX framework for the iCD paper.

Implements "A Generic Coordinate Descent Framework for Learning from
Implicit Feedback" (Bayer, Kanagal, He, Rendle, 2016) as a first-class
feature of a framework-scale training/inference system:

- ``repro.core``       — k-separable models, implicit regularizer, iCD solver
- ``repro.sparse``     — CSR / segment ops / EmbeddingBag / neighbor sampler
- ``repro.models``     — sharding-hint DSL for the model zoo (models/hints.py)
- ``repro.kernels``    — Pallas TPU kernels (gram, cd_update, cd_sweep,
                         topk_score) with pure-jnp oracles
- ``repro.optim``      — optimizers, schedules, gradient compression
- ``repro.train``      — train-step builders, remat, microbatching
- ``repro.serve``      — retrieval serving: engine / sharded cluster /
                         fault-tolerant mesh / IVF approximate tier
- ``repro.checkpoint`` — fault-tolerant sharded checkpointing
- ``repro.runtime``    — elastic mesh management, straggler watchdog
- ``repro.configs``    — assigned architecture configs + the paper's own
- ``repro.launch``     — production meshes, multi-pod dry-run, drivers
"""

__version__ = "1.0.0"
