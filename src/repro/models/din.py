"""DIN — Deep Interest Network (Zhou et al., arXiv:1706.06978).

Target attention over the user behaviour sequence: per history item,
attention MLP on [hist, target, hist−target, hist⊙target] → scalar weight →
weighted-sum user interest vector → concat [interest, target] → final MLP.
embed_dim=18, seq_len=100, attn MLP 80-40, final MLP 200-80 (paper config).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.models.common import mlp_apply, mlp_init
from repro.models.recsys_common import binary_ce


def init_params(key, cfg: RecsysConfig) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.embed_dim
    return {
        "items": 0.01 * jax.random.normal(k1, (cfg.item_vocab, d)),
        "attn": mlp_init(k2, (4 * d,) + cfg.attn_mlp + (1,)),
        "mlp": mlp_init(k3, (2 * d,) + cfg.mlp + (1,)),
    }


def _interest(cfg, params, hist_emb, mask, target_emb):
    """(B,L,d) history, (B,L) mask, (B,d) target → (B,d) interest."""
    t = jnp.broadcast_to(target_emb[:, None, :], hist_emb.shape)
    feats = jnp.concatenate(
        [hist_emb, t, hist_emb - t, hist_emb * t], axis=-1
    )  # (B, L, 4d)
    w = mlp_apply(params["attn"], feats, act=jax.nn.sigmoid)[..., 0]  # (B, L)
    w = jnp.where(mask > 0, w, 0.0)  # DIN: no softmax, raw masked weights
    return jnp.einsum("bl,bld->bd", w, hist_emb)


def forward(cfg: RecsysConfig, params, hist_ids, hist_mask, target_ids):
    """hist_ids (B, L), hist_mask (B, L), target_ids (B,) → logits (B,)."""
    hist = jnp.take(params["items"], hist_ids, axis=0)
    target = jnp.take(params["items"], target_ids, axis=0)
    interest = _interest(cfg, params, hist, hist_mask, target)
    x = jnp.concatenate([interest, target], axis=-1)
    return mlp_apply(params["mlp"], x)[:, 0]


def loss_fn(cfg: RecsysConfig, params, batch) -> jax.Array:
    logits = forward(cfg, params, batch["hist"], batch["mask"], batch["target"])
    return binary_ce(logits, batch["label"])


def score_candidates(cfg: RecsysConfig, params, hist_ids, hist_mask, cand_ids):
    """Retrieval: the target is the attention QUERY, so attention re-runs per
    candidate — the honest cost of target-attention retrieval. The history
    embedding gather happens once; candidates sweep in one batched pass."""
    hist = jnp.take(params["items"], hist_ids, axis=0)       # (1, L, d)
    n = cand_ids.shape[0]
    cands = jnp.take(params["items"], cand_ids, axis=0)      # (N, d)
    hist_n = jnp.broadcast_to(hist, (n,) + hist.shape[1:])
    mask_n = jnp.broadcast_to(hist_mask, (n,) + hist_mask.shape[1:])
    interest = _interest(cfg, params, hist_n, mask_n, cands)
    x = jnp.concatenate([interest, cands], axis=-1)
    return mlp_apply(params["mlp"], x)[:, 0]
