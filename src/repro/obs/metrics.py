"""Label-aware metrics registry: Counter / Gauge / Histogram families.

The measurement spine every serving and training layer reports through
(ISSUE: the ROADMAP's "make a hot path measurably faster" and "survive
real traffic" arcs both presuppose signals we collect here). Design
constraints, in the order they shaped the module:

  * **Injectable clock** — like ``MicroBatcher`` and the mesh, the
    registry never calls ``time.*`` behind the caller's back: the clock
    is a constructor argument, so the simulated-clock tests drive
    histograms and staleness gauges deterministically.
  * **Label children resolved once** — ``family.labels(**kv)`` returns a
    cached child whose ``inc``/``observe``/``set`` are plain attribute
    ops; hot paths (the batcher admission loop, the mesh retry loop)
    resolve their children at construction and pay ~a float add per
    event. The instrumented-vs-bare overhead gate in
    ``benchmarks/serve_bench.py`` holds this to < 3% of serve latency.
  * **Per-instance isolation on a process-global default** — components
    default to the process registry (so drivers get metrics for free)
    but label every family with a unique ``instance`` id, so two
    batchers in one process (or two tests in one session) never bleed
    counters into each other. Tests can also inject a private
    :class:`MetricsRegistry`, and :data:`NULL_REGISTRY` is the zero-cost
    bare mode (every op a no-op — the baseline side of the overhead
    gate).
  * **Fixed-bucket histograms** — cumulative-bucket counts with
    p50/p90/p99 estimates by linear interpolation inside the owning
    bucket (the Prometheus estimation rule), so quantiles need no
    sample retention and export is O(buckets).

Exposition (JSONL + Prometheus text) lives in ``obs/export.py``; spans
and request tracing in ``obs/trace.py``.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections.abc import Mapping
from contextlib import contextmanager
from typing import Callable, Dict, Iterable, Optional, Sequence, Tuple

# default latency buckets (seconds): ~10us .. 10s, roughly 2.5x steps —
# wide enough for interpret-mode kernels AND sub-ms simulated clocks
DEFAULT_BUCKETS: Tuple[float, ...] = (
    1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3,
    1e-2, 2.5e-2, 5e-2, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)

_instance_ids = itertools.count()


def next_instance_id() -> str:
    """Process-unique ``instance`` label value. Components stamp their
    families with it so a global default registry still gives every
    batcher/mesh/publisher object its own counters."""
    return str(next(_instance_ids))


class Counter:
    """Monotonically increasing float value."""

    __slots__ = ("labels_kv", "_value")

    def __init__(self, labels_kv: Tuple[Tuple[str, str], ...]):
        self.labels_kv = labels_kv
        self._value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter increment must be >= 0, got {v}")
        self._value += v

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Settable value (versions, queue depths, timestamps)."""

    __slots__ = ("labels_kv", "_value")

    def __init__(self, labels_kv: Tuple[Tuple[str, str], ...]):
        self.labels_kv = labels_kv
        self._value = 0.0

    def set(self, v: float) -> None:
        self._value = float(v)

    def inc(self, v: float = 1.0) -> None:
        self._value += v

    def dec(self, v: float = 1.0) -> None:
        self._value -= v

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Fixed-bucket cumulative histogram with interpolated quantiles.

    ``buckets`` are the upper bucket EDGES (ascending); one overflow
    bucket past the last edge is implicit. Quantile estimation follows
    the Prometheus rule: find the bucket holding rank ``q·count`` and
    interpolate linearly inside it (the overflow bucket clamps to the
    last finite edge — a known, documented bias; pick edges that cover
    the signal). No samples are retained."""

    __slots__ = ("labels_kv", "edges", "counts", "_sum", "_count")

    def __init__(
        self,
        labels_kv: Tuple[Tuple[str, str], ...],
        buckets: Sequence[float] = DEFAULT_BUCKETS,
    ):
        edges = tuple(float(e) for e in buckets)
        if not edges or list(edges) != sorted(edges):
            raise ValueError(f"bucket edges must be ascending, got {edges}")
        self.labels_kv = labels_kv
        self.edges = edges
        self.counts = [0] * (len(edges) + 1)  # +1: overflow bucket
        self._sum = 0.0
        self._count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self._sum += v
        self._count += 1
        for i, edge in enumerate(self.edges):
            if v <= edge:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def value(self) -> float:
        """Mean observation — the scalar a stats view reports."""
        return self._sum / self._count if self._count else 0.0

    def quantile(self, q: float) -> float:
        """Estimated q-quantile (q in [0, 1]); NaN on an empty histogram."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if self._count == 0:
            return float("nan")
        rank = q * self._count
        cum = 0
        for i, n in enumerate(self.counts):
            if n == 0:
                continue
            if cum + n >= rank:
                if i >= len(self.edges):       # overflow: clamp to last edge
                    return self.edges[-1]
                lo = 0.0 if i == 0 else self.edges[i - 1]
                hi = self.edges[i]
                return lo + (hi - lo) * max(rank - cum, 0.0) / n
            cum += n
        return self.edges[-1]

    def percentiles(self) -> Dict[str, float]:
        return {"p50": self.quantile(0.5), "p90": self.quantile(0.9),
                "p99": self.quantile(0.99)}


class Family:
    """One named metric family; ``labels(**kv)`` returns the cached child
    for that label combination (creating it on first use)."""

    def __init__(self, name: str, kind: str, help_text: str,
                 labelnames: Tuple[str, ...], make: Callable):
        self.name = name
        self.kind = kind
        self.help = help_text
        self.labelnames = labelnames
        self._make = make
        self._children: Dict[Tuple[str, ...], object] = {}

    def labels(self, **kv):
        if tuple(sorted(kv)) != tuple(sorted(self.labelnames)):
            raise ValueError(
                f"metric {self.name!r} takes labels {self.labelnames}, "
                f"got {tuple(kv)}"
            )
        key = tuple(str(kv[ln]) for ln in self.labelnames)
        child = self._children.get(key)
        if child is None:
            child = self._make(tuple(zip(self.labelnames, key)))
            self._children[key] = child
        return child

    # label-less convenience: proxy the child API on the family itself
    def _default(self):
        return self.labels()

    def inc(self, v: float = 1.0) -> None:
        self._default().inc(v)

    def dec(self, v: float = 1.0) -> None:
        self._default().dec(v)

    def set(self, v: float) -> None:
        self._default().set(v)

    def observe(self, v: float) -> None:
        self._default().observe(v)

    @property
    def value(self) -> float:
        return self._default().value

    def children(self) -> Iterable:
        return self._children.values()


class MetricsRegistry:
    """Process- or test-scoped home for metric families.

    ::

        reg = MetricsRegistry(clock=lambda: clock["t"])   # simulated time
        flushes = reg.counter("serve_batcher_flushes_total",
                              "flushes by reason", labels=("reason",))
        flushes.labels(reason="deadline").inc()
        lat = reg.histogram("queue_latency_seconds", "submit->flush wait")
        lat.observe(0.0013); lat.quantile(0.99)
    """

    def __init__(self, clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self._families: Dict[str, Family] = {}
        self._lock = threading.Lock()

    def _family(self, name: str, kind: str, help_text: str,
                labelnames: Tuple[str, ...], make: Callable) -> Family:
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.kind != kind or fam.labelnames != labelnames:
                    raise ValueError(
                        f"metric {name!r} already registered as {fam.kind} "
                        f"with labels {fam.labelnames}; requested {kind} "
                        f"with {labelnames}"
                    )
                return fam
            fam = Family(name, kind, help_text, labelnames, make)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help_text: str = "",
                labels: Sequence[str] = ()) -> Family:
        return self._family(name, "counter", help_text, tuple(labels), Counter)

    def gauge(self, name: str, help_text: str = "",
              labels: Sequence[str] = ()) -> Family:
        return self._family(name, "gauge", help_text, tuple(labels), Gauge)

    def histogram(self, name: str, help_text: str = "",
                  labels: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Family:
        return self._family(
            name, "histogram", help_text, tuple(labels),
            lambda kv: Histogram(kv, buckets),
        )

    def families(self) -> Iterable[Family]:
        return list(self._families.values())

    def get(self, name: str, **kv) -> float:
        """Test/inspection convenience: the scalar value of one child
        (counter/gauge value; histogram mean). Raises on unknown name."""
        return self._families[name].labels(**kv).value

    @contextmanager
    def timer(self, hist):
        """Observe the wall time of a ``with`` block into ``hist`` (a
        histogram child or family), using THIS registry's clock."""
        t0 = self.clock()
        try:
            yield
        finally:
            hist.observe(self.clock() - t0)


# -------------------------------------------------------------- null mode
class _NullMetric:
    """Absorbs the whole child/family API as no-ops — the bare-mode
    singleton behind :data:`NULL_REGISTRY` (and the baseline side of the
    serve-bench overhead gate)."""

    def labels(self, **kv):
        return self

    def inc(self, v: float = 1.0) -> None:
        pass

    def dec(self, v: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def quantile(self, q: float) -> float:
        return float("nan")

    def percentiles(self) -> Dict[str, float]:
        nan = float("nan")
        return {"p50": nan, "p90": nan, "p99": nan}

    @property
    def value(self) -> float:
        return 0.0

    @property
    def count(self) -> int:
        return 0

    @property
    def sum(self) -> float:
        return 0.0

    def children(self) -> tuple:
        return ()


_NULL_METRIC = _NullMetric()


class NullRegistry:
    """Every family it hands out is the shared no-op metric; instrumented
    code runs unchanged with zero bookkeeping. ``bool(NULL_REGISTRY)`` is
    False so call sites can gate optional work (span/recording setup)."""

    clock = staticmethod(time.monotonic)

    def counter(self, name: str, help_text: str = "",
                labels: Sequence[str] = ()):
        return _NULL_METRIC

    def gauge(self, name: str, help_text: str = "",
              labels: Sequence[str] = ()):
        return _NULL_METRIC

    def histogram(self, name: str, help_text: str = "",
                  labels: Sequence[str] = (), buckets=DEFAULT_BUCKETS):
        return _NULL_METRIC

    def families(self) -> tuple:
        return ()

    def get(self, name: str, **kv) -> float:
        return 0.0

    @contextmanager
    def timer(self, hist):
        yield

    def __bool__(self) -> bool:
        return False


NULL_REGISTRY = NullRegistry()

# ----------------------------------------------------------- default wiring
_default_registry: Optional[MetricsRegistry] = None
_default_lock = threading.Lock()


def default_registry() -> MetricsRegistry:
    """The lazily created process-global registry (what components use
    when no explicit registry is injected)."""
    global _default_registry
    with _default_lock:
        if _default_registry is None:
            _default_registry = MetricsRegistry()
        return _default_registry


def set_default_registry(reg: Optional[MetricsRegistry]) -> None:
    """Swap (or with ``None`` reset) the process-global registry."""
    global _default_registry
    with _default_lock:
        _default_registry = reg


def resolve_registry(registry=None):
    """``None`` → the process default; anything else passes through
    (including :data:`NULL_REGISTRY` for bare mode)."""
    return default_registry() if registry is None else registry


class StatsView(Mapping):
    """Live read-only mapping over registry-backed counters.

    The back-compat shim for ``MicroBatcher.stats`` / ``mesh.stats``:
    every read (``stats["flushes"]``, ``dict(stats)``, ``.items()``)
    pulls the CURRENT registry values, so code written against the old
    plain-dict stats keeps working while the registry is the single
    source of truth."""

    def __init__(self, readers: Dict[str, Callable[[], float]]):
        self._readers = dict(readers)

    def __getitem__(self, key: str) -> float:
        return self._readers[key]()

    def __iter__(self):
        return iter(self._readers)

    def __len__(self) -> int:
        return len(self._readers)

    def __repr__(self) -> str:
        return f"StatsView({dict(self)!r})"
