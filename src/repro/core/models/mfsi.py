"""iCD for Matrix Factorization with Side Information (paper §5.2.1, Alg. 3).

Model (eq. 20): ŷ(c,i) = x_c W (z_i H)ᵀ with feature embeddings
W ∈ R^{p×k}, H ∈ R^{p'×k}. k-separable via φ_f(c) = Σ_l x_{c,l} w_{l,f}
(eq. 21); gradients sparse in f (eq. 22), so

    R'(w_{l*,f*})  = 2 Σ_f J_I(f,f*) Σ_c x_{c,l*} φ_f(c)        (eq. 23)
    R''(w_{l*,f*}) = 2 J_I(f*,f*) Σ_c x_{c,l*}²                 (eq. 24)

and Φ is kept in sync with the eq. (25) incremental update. Per-epoch cost
O(k²(N_Z(X)+N_Z(Z))) for the implicit part — the paper's bound.

TPU sweep layout (DESIGN.md §3): coordinates of a one-hot field never share
a row, so a whole field × one dimension updates as a single vectorized
Newton step. The explicit part uses three per-context caches that are
patched incrementally instead of recomputed:

    q_c  = Σ_{i∈S_c} ᾱ e ψ_{f*}(i)     (patched: Δq = Δφ_{f*}·p2)
    p2_c = Σ_{i∈S_c} ᾱ ψ_{f*}(i)²      (constant during the side sweep)
    r_c  = Σ_f J(f,f*) φ_f(c)          (patched: Δr = Δφ_{f*}·J(f*,f*))

One-hot (categorical) fields update EXACTLY — no two features of such a
field share a context row, so the vectorized step equals scalar CD. Features
of a multi-hot (bag) field DO share rows; updating them in parallel is not
scalar CD. Two documented modes (the one deliberate deviation from the
paper, forced by TPU parallelism — DESIGN.md §3):

  - ``jacobi`` (default): one damped (η≈0.5) parallel Newton step per field
    with full row sums — parallel-CD à la Bradley et al.; converges in all
    our experiments and is the production mode.
  - ``slot``: sequential over bag slots; each slot update uses only the rows
    where the feature occupies that slot (fresh residuals between slots) —
    a mini-batched CD flavour that tolerates η=1.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import sweeps
from repro.core.design import Design, design_matmul
from repro.core.gram import gram
from repro.core.implicit import implicit_objective
from repro.sparse.interactions import Interactions
from repro.sparse.segment import segment_sum


class MFSIParams(NamedTuple):
    w: jax.Array  # (p_ctx, k)  stacked context-feature embeddings
    h: jax.Array  # (p_item, k) stacked item-feature embeddings


@dataclasses.dataclass(frozen=True)
class MFSIHyperParams:
    k: int
    alpha0: float = 1.0
    l2: float = 0.1
    eta: float = 1.0
    multi_hot_mode: str = "jacobi"  # 'jacobi' | 'slot'
    jacobi_eta: float = 0.5
    implementation: str = "xla"


def init(key: jax.Array, p_ctx: int, p_item: int, k: int, sigma: float = 0.1) -> MFSIParams:
    kw, kh = jax.random.split(key)
    return MFSIParams(
        w=sigma * jax.random.normal(kw, (p_ctx, k), dtype=jnp.float32),
        h=sigma * jax.random.normal(kh, (p_item, k), dtype=jnp.float32),
    )


def phi(params: MFSIParams, x: Design) -> jax.Array:
    return design_matmul(x, params.w)


def psi(params: MFSIParams, z: Design) -> jax.Array:
    return design_matmul(z, params.h)


def predict(params: MFSIParams, x: Design, z: Design, ctx, item) -> jax.Array:
    ph, ps = phi(params, x), psi(params, z)
    return jnp.sum(jnp.take(ph, ctx, axis=0) * jnp.take(ps, item, axis=0), axis=-1)


def _field_layer_update(
    table_col, phi_col, e, q, r_vec, p2, jff,
    ids_g, xw, rows, vocab, offset, other_nnz, rows_nnz, alpha, n_rows, hp, eta,
):
    """One vectorized Newton update of a one-hot layer (field or bag slot).

    ids_g:  (n,) global feature ids for this layer (offset applied)
    xw:     (n,) feature values x_{c,l} (0 ⇒ row inactive in this layer)
    rows:   (n,) context row per entry (identity for bag=1 fields)
    """
    w_layer = table_col[offset : offset + vocab]
    lp = segment_sum(xw * jnp.take(q, rows), ids_g - offset, vocab)
    lpp = segment_sum(xw * xw * jnp.take(p2, rows), ids_g - offset, vocab)
    rp = segment_sum(xw * jnp.take(r_vec, rows), ids_g - offset, vocab)
    rpp = jff * segment_sum(xw * xw, ids_g - offset, vocab)
    num = lp + hp.alpha0 * rp + hp.l2 * w_layer
    den = lpp + hp.alpha0 * rpp + hp.l2
    delta = -eta * num / jnp.maximum(den, 1e-12)

    # scatter the step back + incremental patches (eq. 25 and DESIGN.md §3)
    table_col = table_col.at[offset : offset + vocab].add(delta)
    dphi_rows = segment_sum(xw * jnp.take(delta, ids_g - offset), rows, q.shape[0])
    phi_col = phi_col + dphi_rows
    q = q + dphi_rows * p2
    r_vec = r_vec + dphi_rows * jff
    e = e + jnp.take(dphi_rows, rows_nnz) * other_nnz
    return table_col, phi_col, e, q, r_vec


def _side_sweep(
    table: jax.Array,       # (p, k) this side's feature embeddings
    phi_m: jax.Array,       # (n_rows, k) this side's Φ (kept in sync)
    other_psi: jax.Array,   # (n_other, k) opposite side's Ψ (fixed)
    other_j: jax.Array,     # (k, k) Gram of Ψ
    design: Design,
    rows_nnz: jax.Array,    # (nnz,) this-side row per observation
    other_nnz_ids: jax.Array,  # (nnz,) opposite-side row per observation
    alpha: jax.Array,
    e: jax.Array,
    hp: MFSIHyperParams,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    n_rows = design.n_rows
    row_idx = jnp.arange(n_rows, dtype=jnp.int32)

    def dim_body(f, carry):
        table, phi_m, e = carry
        psi_col = sweeps.take_col(other_psi, f)
        psi_nnz = jnp.take(psi_col, other_nnz_ids)
        p2 = segment_sum(alpha * psi_nnz * psi_nnz, rows_nnz, n_rows)
        q = segment_sum(alpha * e * psi_nnz, rows_nnz, n_rows)
        r_vec = phi_m @ sweeps.take_col(other_j, f)
        jff = other_j[f, f]
        table_col = sweeps.take_col(table, f)
        phi_col = sweeps.take_col(phi_m, f)

        for field in design.fields:
            gids = design.global_ids(field)
            if field.one_hot or hp.multi_hot_mode == "slot":
                # one-hot: EXACT (features never share a row); multi-hot
                # 'slot': sequential slot layers with fresh residuals.
                for j in range(field.bag):
                    table_col, phi_col, e, q, r_vec = _field_layer_update(
                        table_col, phi_col, e, q, r_vec, p2, jff,
                        gids[:, j], field.weights[:, j], row_idx,
                        field.vocab, field.offset,
                        psi_nnz, rows_nnz, alpha, n_rows, hp, hp.eta,
                    )
            else:  # jacobi: whole bag in one damped parallel step
                flat_rows = jnp.repeat(row_idx, field.bag)
                table_col, phi_col, e, q, r_vec = _field_layer_update(
                    table_col, phi_col, e, q, r_vec, p2, jff,
                    gids.reshape(-1), field.weights.reshape(-1), flat_rows,
                    field.vocab, field.offset,
                    psi_nnz, rows_nnz, alpha, n_rows, hp, hp.jacobi_eta,
                )

        table = sweeps.put_col(table, f, table_col)
        phi_m = sweeps.put_col(phi_m, f, phi_col)
        return table, phi_m, e

    table, phi_m, e = sweeps.sweep_columns(hp.k, dim_body, (table, phi_m, e))
    return table, phi_m, e


@partial(jax.jit, static_argnames=("hp",))
def epoch(
    params: MFSIParams,
    x: Design,
    z: Design,
    data: Interactions,
    e: jax.Array,
    hp: MFSIHyperParams,
) -> Tuple[MFSIParams, jax.Array]:
    """One iCD epoch: full context-feature sweep, then item-feature sweep."""
    w, h = params
    phi_m = design_matmul(x, w)
    psi_m = design_matmul(z, h)

    j_i = gram(psi_m, implementation=hp.implementation)
    w, phi_m, e = _side_sweep(
        w, phi_m, psi_m, j_i, x, data.ctx, data.item, data.alpha, e, hp
    )

    j_c = gram(phi_m, implementation=hp.implementation)
    e_t = sweeps.to_item_major(e, data.t_perm)
    alpha_t = sweeps.to_item_major(data.alpha, data.t_perm)
    h, psi_m, e_t = _side_sweep(
        h, psi_m, phi_m, j_c, z, data.t_item, data.t_ctx, alpha_t, e_t, hp
    )
    e = sweeps.to_ctx_major(e_t, data.t_perm)
    return MFSIParams(w, h), e


def residuals(params: MFSIParams, x: Design, z: Design, data: Interactions) -> jax.Array:
    return sweeps.residuals_from_factors(
        phi(params, x), psi(params, z), data.ctx, data.item, data.y
    )


def objective(params: MFSIParams, x: Design, z: Design, data: Interactions, hp: MFSIHyperParams) -> jax.Array:
    e = residuals(params, x, z, data)
    sq = jnp.sum(params.w**2) + jnp.sum(params.h**2)
    return implicit_objective(phi(params, x), psi(params, z), e, data, hp.alpha0, hp.l2, sq)


def fit(params, x, z, data, hp, n_epochs, callback=None):
    e = residuals(params, x, z, data)
    for ep in range(n_epochs):
        params, e = epoch(params, x, z, data, e, hp)
        if callback is not None:
            callback(ep, params)
    return params
