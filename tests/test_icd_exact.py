"""Exactness of iCD vs conventional CD on the full implicit matrix.

The paper's central claim (Lemma 1 + Lemma 2 + Lemma 3) is that iCD performs
the SAME Newton coordinate steps as conventional CD over all |C|·|I|
implicit examples, at a fraction of the cost. We verify trajectory-level
equality: same init + same sweep order ⇒ same parameters after each epoch.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import naive_cd
from repro.core.models import mf
from repro.sparse.interactions import build_interactions

jax.config.update("jax_enable_x64", False)


def make_problem(seed=0, n_ctx=13, n_items=9, nnz=37, alpha0=0.4):
    rng = np.random.default_rng(seed)
    pairs = rng.choice(n_ctx * n_items, size=nnz, replace=False)
    ctx, item = pairs // n_items, pairs % n_items
    y = rng.integers(1, 5, size=nnz).astype(np.float64)
    alpha = alpha0 + 1.0 + rng.random(nnz)  # α > α₀
    data = build_interactions(ctx, item, y, alpha, n_ctx, n_items, alpha0=alpha0)
    y_dense, a_dense = naive_cd.dense_from_observed(
        jnp.asarray(ctx), jnp.asarray(item), jnp.asarray(y, jnp.float32),
        jnp.asarray(alpha, jnp.float32), n_ctx, n_items, alpha0,
    )
    return data, y_dense, a_dense


@pytest.mark.parametrize("k", [1, 3, 8])
def test_mf_icd_matches_naive_cd_trajectory(k):
    data, y_dense, a_dense = make_problem()
    hp = mf.MFHyperParams(k=k, alpha0=0.4, l2=0.05, eta=1.0)
    params = mf.init(jax.random.PRNGKey(1), data.n_ctx, data.n_items, k)
    params_naive = params

    e = mf.residuals(params, data)
    for _ in range(3):
        params, e = mf.epoch(params, data, e, hp)
        params_naive = naive_cd.epoch_dense(params_naive, y_dense, a_dense, hp)
        np.testing.assert_allclose(params.w, params_naive.w, rtol=2e-4, atol=2e-5)
        np.testing.assert_allclose(params.h, params_naive.h, rtol=2e-4, atol=2e-5)


def test_mf_objective_monotone_decreasing():
    data, y_dense, a_dense = make_problem(seed=3, n_ctx=20, n_items=15, nnz=60)
    hp = mf.MFHyperParams(k=4, alpha0=0.4, l2=0.05)
    params = mf.init(jax.random.PRNGKey(2), data.n_ctx, data.n_items, 4)
    e = mf.residuals(params, data)
    prev = float(mf.objective(params, data, hp))
    for _ in range(6):
        params, e = mf.epoch(params, data, e, hp)
        cur = float(mf.objective(params, data, hp))
        assert cur <= prev + 1e-4, (cur, prev)
        prev = cur


def test_residual_cache_consistency():
    """The maintained residual cache must equal freshly computed residuals."""
    data, _, _ = make_problem(seed=5)
    hp = mf.MFHyperParams(k=5, alpha0=0.4, l2=0.1)
    params = mf.init(jax.random.PRNGKey(3), data.n_ctx, data.n_items, 5)
    e = mf.residuals(params, data)
    for _ in range(2):
        params, e = mf.epoch(params, data, e, hp)
    np.testing.assert_allclose(e, mf.residuals(params, data), rtol=1e-4, atol=1e-5)


def test_damped_step_also_converges():
    data, _, _ = make_problem(seed=7)
    hp = mf.MFHyperParams(k=3, alpha0=0.4, l2=0.05, eta=0.5)
    params = mf.init(jax.random.PRNGKey(4), data.n_ctx, data.n_items, 3)
    e = mf.residuals(params, data)
    start = float(mf.objective(params, data, hp))
    for _ in range(8):
        params, e = mf.epoch(params, data, e, hp)
    assert float(mf.objective(params, data, hp)) < start
