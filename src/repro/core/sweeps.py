"""Shared machinery for iCD column sweeps.

The TPU adaptation of Algorithm 1/2/3 (see DESIGN.md §3): for a fixed
embedding dimension ``f*`` the Newton updates of all coordinates on one side
are independent, so each inner loop of the paper becomes ONE vectorized
column update:

    gather → segment-reduce (explicit part from the residual cache)
    k-vector contraction with the opposite Gram (implicit part, Lemma 3)
    fused Newton step  θ ← θ − η·(L'/2 + α₀R'/2 + λθ)/(L''/2 + α₀R''/2 + λ)
    rank-1 residual patch

All helpers are jit-friendly; the f* loop goes through
:func:`sweep_columns`, which runs either the per-column path (a
``lax.fori_loop`` / unrolled host loop with the parameter matrix as carry)
or, when the model provides one, a fused multi-column block body backed by
the ``kernels/cd_sweep`` Pallas kernel that keeps the residual cache
VMEM-resident across the columns of a block.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np


class NewtonParts(NamedTuple):
    """Halved derivative pieces; the common factor 2 of eqs. (2,3,13,14)
    cancels in the Newton ratio so we carry L'/2 etc. throughout."""

    grad: jax.Array  # L'/2 + α₀·R'/2   (no L2 term yet)
    hess: jax.Array  # L''/2 + α₀·R''/2 (no L2 term yet)


def newton_delta(
    parts: NewtonParts, theta: jax.Array, l2: float, eta: float
) -> jax.Array:
    """η-damped Newton step on the 1-D quadratic (exact at η=1 for
    multilinear models, paper §3.2). Returns Δθ.

    The denominator is clamped like the Pallas kernels do: with l2=0 an
    empty context has L''=R''=0 and the unguarded ratio NaNs."""
    num = parts.grad + l2 * theta
    den = parts.hess + l2
    return -eta * num / jnp.maximum(den, 1e-12)


@dataclasses.dataclass(frozen=True)
class SweepSchedule:
    """Subspace schedule for :func:`sweep_columns` (iALS++-style).

    A fused ``k_b``-block update is already a subspace step, so a "sweep" no
    longer has to be one full pass over all ``n_dims`` columns: a schedule
    names WHICH blocks run this sweep, in WHAT order, and HOW OFTEN.

    ``kind``
      * ``'full'``      — every block, ascending ``f0`` order. With default
        ``block``/``repeats`` this reproduces the unscheduled sweep exactly
        (bit-for-bit; see ``tests/test_schedule.py``).
      * ``'rotating'``  — every block, order rotated by ``sweep_index`` so
        successive sweeps start from a different subspace.
      * ``'randomized'``— every block, order drawn from a deterministic
        permutation seeded by ``(seed, sweep_index)``.

    ``block``            columns per scheduled block (the subspace size
                         ``k_b``); 0 = inherit the caller's ``block`` arg.
    ``blocks_per_sweep`` truncate the ordered block list to this many blocks
                         per sweep (0 = all): the partial-pass mode that
                         makes updates-to-quality scheduling possible —
                         ``rotating`` + ``blocks_per_sweep=1`` visits one
                         ``k_b`` subspace per sweep, cycling through all.
    ``repeats``          per-block repeat counts: an int applied to every
                         block, or a tuple indexed by the block's ordinal
                         ``f0 // block`` (cycled when shorter).
    ``seed``             base seed for ``'randomized'``.

    Frozen + hashable so it can ride as a jit static argument; all schedule
    resolution happens on the host at trace time (static ``(f0, size)``).
    """

    kind: str = "full"
    block: int = 0
    blocks_per_sweep: int = 0
    repeats: Union[int, Tuple[int, ...]] = 1
    seed: int = 0

    def __post_init__(self):
        if self.kind not in ("full", "rotating", "randomized"):
            raise ValueError(
                "SweepSchedule.kind must be 'full' | 'rotating' | "
                f"'randomized', got {self.kind!r}"
            )
        reps = self.repeats if isinstance(self.repeats, tuple) else (self.repeats,)
        if not reps or any(int(r) < 1 for r in reps):
            raise ValueError(f"repeats must be >= 1, got {self.repeats!r}")

    def _repeat(self, ordinal: int) -> int:
        if isinstance(self.repeats, tuple):
            return int(self.repeats[ordinal % len(self.repeats)])
        return int(self.repeats)

    def blocks(
        self, n_dims: int, sweep_index: int = 0, block: int = 0
    ) -> Tuple[Tuple[int, int], ...]:
        """Resolve to a static ``((f0, size), ...)`` sequence for one sweep."""
        b = self.block if self.block >= 1 else (block if block >= 1 else n_dims)
        b = min(b, n_dims)
        base = [(f0, min(b, n_dims - f0)) for f0 in range(0, n_dims, b)]
        if self.kind == "rotating" and base:
            r = sweep_index % len(base)
            order = base[r:] + base[:r]
        elif self.kind == "randomized":
            rng = np.random.default_rng((self.seed, sweep_index))
            order = [base[i] for i in rng.permutation(len(base))]
        else:
            order = base
        if self.blocks_per_sweep >= 1:
            order = order[: self.blocks_per_sweep]
        out = []
        for f0, size in order:
            out.extend([(f0, size)] * self._repeat(f0 // b))
        return tuple(out)

    def n_column_updates(
        self, n_dims: int, sweep_index: int = 0, block: int = 0
    ) -> int:
        """Column-updates this sweep performs (the updates-to-quality unit)."""
        return sum(size for _, size in self.blocks(n_dims, sweep_index, block))


FULL_SCHEDULE = SweepSchedule()


def sweep_columns(
    n_dims: int,
    body: Callable,
    carry,
    *,
    unroll: bool = False,
    block: int = 1,
    block_body: Optional[Callable] = None,
    schedule: Optional[SweepSchedule] = None,
    sweep_index: int = 0,
):
    """Single entry point for the f*-sweep of Algorithms 2/3.

    ``body(f, carry) -> carry`` is the per-column Newton update (any model).
    ``block_body(f0, size, carry) -> carry`` is an optional fused update
    covering columns ``[f0, f0+size)`` in one dispatch (the
    ``kernels/cd_sweep`` path). Dispatch rule: when a block body is
    supplied (and ``block >= 1``), blocks of ``block`` columns run fused
    with a shorter fused tail for non-divisible ``n_dims`` — ``block=1``
    degenerates to a per-column loop THROUGH the block path (static column
    indices; how the padded models express their per-column baseline).
    Otherwise the per-column ``body`` runs (``lax.fori_loop``, or a host
    loop when ``unroll`` — exact HLO costs / cross-column XLA fusion).
    ``unroll=True`` is an explicit request for the per-column unrolled
    program, so it takes precedence over the fused path.

    Block-body contract (slab state): ``f0``/``size`` are STATIC, so the
    body may slice parameter slabs ``θ[:, f0:f0+size]`` and build
    model-specific R'/R'' slab state for the kernels —

      * MF-style (one-hot φ-gradients): an R'/2 slab ``(n, size)`` plus the
        SHARED Gram block ``J[f0:f0+size, f0:f0+size]`` (``cd_block_sweep``);
      * tensor modes (PARAFAC/Tucker): an R'/2 slab plus a PER-ROW patch
        tensor ``P (n, size, size)`` whose diagonal is R''/2 — row-dependent
        curvature, eqs. 37–41 (``cd_block_sweep_rowpatch``);
      * feature models (MFSI/FM): per-field slab moments Q/P from
        ``cd_slab_reduce``, field-level Newton steps in XLA, then one
        rank-``size`` ``cd_resid_patch``.

    Everything the NEXT block needs (θ, e grid, Φ caches) must ride in
    ``carry``; intra-block coupling is the body's own responsibility (the
    kernels' Gauss–Seidel patches / the Q-slab cross-dim patches).

    ``n_dims`` and ``block`` are static, so the fused loop is a host loop of
    ⌈n_dims/block⌉ dispatches with static slab sizes.

    ``schedule`` (a :class:`SweepSchedule`) generalizes the sweep from "one
    full ascending pass" to an arbitrary static sequence of ``(f0, size)``
    subspace blocks for this ``sweep_index``: the fused ``block_body`` runs
    one dispatch per scheduled block, and the per-column ``body`` runs a
    host loop over the scheduled columns (static indices). ``schedule=None``
    is the unscheduled fast path, bit-identical to the pre-schedule code.
    """
    if schedule is not None:
        plan = schedule.blocks(n_dims, sweep_index, block)
        # a plan that is one plain in-order full pass IS the unscheduled
        # sweep — fall through to the canonical paths below so a full
        # schedule stays bit-identical to schedule=None (same compiled
        # program, not just the same math)
        trivial = [f for f0, size in plan for f in range(f0, f0 + size)]
        if trivial == list(range(n_dims)) and (
            block_body is None or plan == SweepSchedule(block=block).blocks(n_dims)
        ):
            schedule = None
    if schedule is not None:
        if block_body is not None and not unroll:
            for f0, size in plan:
                carry = block_body(f0, size, carry)
            return carry
        for f0, size in plan:
            for f in range(f0, f0 + size):
                carry = body(f, carry)
        return carry
    if block_body is not None and block >= 1 and not unroll:
        f0 = 0
        while f0 < n_dims:
            size = min(block, n_dims - f0)
            carry = block_body(f0, size, carry)
            f0 += size
        return carry
    if unroll:
        for f in range(n_dims):
            carry = body(f, carry)
        return carry
    return jax.lax.fori_loop(0, n_dims, body, carry)


def resolve_block_k(block_k: int, k: int) -> int:
    """Shared ``hp.block_k`` policy for every padded/fused epoch:
    0 = auto (min(k, 8)), otherwise clamp to [1, k]."""
    return min(k, 8) if block_k == 0 else max(1, min(block_k, k))


def resolve_psi_dispatch(psi_dispatch: str) -> bool:
    """Shared ``hp.psi_dispatch`` policy: returns ``prefer_gather`` for
    ``kernels.vmem.resolve_cd_sweep_dispatch``. Anything outside the two
    known routings raises — a typo silently selecting the k_b×-peak-HBM
    pre-gathered path would defeat the dispatch's whole point."""
    if psi_dispatch not in ("gather", "pregather"):
        raise ValueError(
            f"psi_dispatch must be 'gather' or 'pregather', got {psi_dispatch!r}"
        )
    return psi_dispatch == "gather"


def take_col(m: jax.Array, f) -> jax.Array:
    """m[:, f] with a traced index."""
    return jax.lax.dynamic_slice_in_dim(m, f, 1, axis=1)[:, 0]


def put_col(m: jax.Array, f, col: jax.Array) -> jax.Array:
    """m with column f replaced (traced index)."""
    return jax.lax.dynamic_update_slice_in_dim(m, col[:, None], f, axis=1)


def residuals_from_factors(
    phi: jax.Array, psi: jax.Array, ctx: jax.Array, item: jax.Array, y: jax.Array
) -> jax.Array:
    """e = ŷ − ȳ on observed pairs: Σ_f φ_f(c)ψ_f(i) − ȳ, per nnz."""
    scores = jnp.sum(
        jnp.take(phi, ctx, axis=0) * jnp.take(psi, item, axis=0), axis=-1
    )
    return scores - y


def to_item_major(e_ctx_major: jax.Array, t_perm: jax.Array) -> jax.Array:
    """Permute a per-nnz vector from context-major to item-major order."""
    return jnp.take(e_ctx_major, t_perm)


def to_ctx_major(e_item_major: jax.Array, t_perm: jax.Array) -> jax.Array:
    """Inverse permutation of :func:`to_item_major`."""
    return jnp.zeros_like(e_item_major).at[t_perm].set(e_item_major)
