from repro.data.synthetic import (  # noqa: F401
    SyntheticImplicitDataset,
    make_implicit_dataset,
)
from repro.data.loader import (  # noqa: F401
    ImplicitLog,
    frequency_interactions,
    interaction_stream,
    load_movielens,
    sharded_batches,
    split_by_time,
)
