"""Roofline table builder: joins the dry-run JSONs with analytic
MODEL_FLOPS (6·N·D for dense LM training / 6·N_active·D for MoE; forward
variants use the 2·N·D factor) and emits the EXPERIMENTS.md §Roofline table.

Also hosts the fused-vs-per-column iCD sweep bench (``cd_sweep_bench``):
analytic HBM-bytes model for the ``kernels/cd_sweep`` block kernel against
the per-column ``kernels/cd_update`` baseline, plus a measured epoch
comparison of the two ``mf_padded`` dispatch paths. Emits
``BENCH_cd_sweep.json`` at the repo root so the perf trajectory of the hot
sweep is tracked PR-over-PR.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_shapes
from repro.launch.hlo_analysis import HBM_BW


def model_flops(arch: str, shape_name: str, chips: int) -> Optional[float]:
    """Per-device useful model FLOPs for one step of this cell.

    Only the paper's own iCD archs remain (the seed-template LM/GNN/RecSys
    analytic branches left with their configs in PR 4); stale dry-run JSONs
    for removed archs resolve to None instead of raising."""
    try:
        shape = get_shapes(arch)[shape_name]
        cfg = get_config(arch)
    except KeyError:  # removed/unknown arch (old results/dryrun artifacts)
        return None
    if arch.startswith("icd"):
        if shape.kind == "retrieval":
            return 2 * shape.global_batch * shape.extra("n_candidates") * cfg.k / chips
        c, i = shape.extra("n_ctx"), shape.extra("n_items")
        nnz = shape.extra("nnz")
        k = cfg.k
        return 2.0 * (k * k * (c + i) + 6 * k * nnz) / chips
    return None


def load_table(dryrun_dir: str = "results/dryrun"):
    rows = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        r = json.load(open(f))
        chips = r.get("chips", 256)
        row = {
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": r["status"],
        }
        if r["status"] == "ok":
            ro = r["roofline"]
            mf_ = model_flops(r["arch"], r["shape"], chips)
            row.update(
                dominant=ro["dominant"],
                compute_s=ro["compute_s"], memory_s=ro["memory_s"],
                collective_s=ro["collective_s"],
                roofline_fraction=ro["roofline_fraction"],
                hlo_flops=ro["flops_per_device"],
                model_flops=mf_,
                useful_ratio=(mf_ / ro["flops_per_device"])
                if mf_ and ro["flops_per_device"] else None,
            )
        elif r["status"] == "skipped":
            row["skip_reason"] = r["skip_reason"]
        else:
            row["error"] = r.get("error", "")[:120]
        rows.append(row)
    return rows


def markdown_table(rows, mesh="16x16") -> str:
    lines = [
        "| arch | shape | dominant | compute s | memory s | collective s | "
        "roofline frac | MODEL/HLO flops |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | SKIP | — | — | — | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | ERROR | — | — | — | — | — |")
            continue
        ur = f"{r['useful_ratio']:.2f}" if r.get("useful_ratio") else "—"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['dominant']} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | {r['roofline_fraction']:.3f} | {ur} |"
        )
    return "\n".join(lines)


# ------------------------------------------------- fused cd_sweep bench ----
def psi_peak_capacity_bytes(
    c: int, d_pad: int, k_b: int, n_src: int
) -> Dict[str, float]:
    """Peak HBM CAPACITY of the per-dispatch Ψ routing (fp32).

    The pre-gathered path materializes a `(C, k_b, D_pad)` Ψ tile per block
    dispatch — ~k_b× the residual grid. The in-kernel gather path ships the
    `(n_src, k_b)` ψ slab instead (the `(C, D_pad)` id grid is the padded
    layout itself and exists in both paths), so the intermediate is gone."""
    pregathered = 4.0 * c * k_b * d_pad
    gathered = 4.0 * n_src * k_b
    return {
        "pregathered_intermediate_bytes": pregathered,
        "gathered_slab_bytes": gathered,
        "capacity_ratio": pregathered / max(gathered, 1.0),
    }


def cd_sweep_sweep_bytes(c: int, d_pad: int, k: int, k_b: int) -> Dict[str, float]:
    """Analytic HBM bytes for ONE side's k-column sweep over the padded
    layout. Per column the per-column kernel reads ψ, α, e and writes e
    (4 (C, D_pad) round-trips) plus (C,) w/r1 vectors; the fused kernel
    still reads ψ once per column (irreducible) but amortizes α/e over the
    k_b columns of a block."""
    cd = 4.0 * c * d_pad                      # one (C, D_pad) fp32 trip
    col = 4.0 * c
    n_blocks = float(-(-k // k_b))
    per_column = k * (4 * cd + 3 * col)
    fused = k * cd + 3 * n_blocks * cd + 3 * k * col + n_blocks * 4 * k_b * k_b
    return {
        "per_column_bytes": per_column,
        "fused_bytes": fused,
        "bytes_ratio": per_column / fused,
        "per_column_memory_s": per_column / HBM_BW,
        "fused_memory_s": fused / HBM_BW,
    }


def rowpatch_sweep_bytes(c: int, d_pad: int, k: int, k_b: int) -> Dict[str, float]:
    """Analytic HBM bytes for one mode's k-column sweep of a TENSOR model
    (PARAFAC/Tucker) on the padded layout: like the MF model but the fused
    kernel additionally streams the per-row patch tensor P (C, k_b, k_b)
    and the r1/w slabs per block (the per-column path reads per-row r1/r''
    vectors per column instead)."""
    cd = 4.0 * c * d_pad
    col = 4.0 * c
    n_blocks = float(-(-k // k_b))
    per_column = k * (4 * cd + 4 * col)          # ψ,α,e×2 + w,r1,r'',w_out
    fused = (
        k * cd + 3 * n_blocks * cd               # ψ per column; α + 2·e per block
        + 3 * k * col                            # w, r1, w_out slabs
        + n_blocks * c * k_b * k_b * 4.0         # per-row patch tensor P
    )
    return {
        "per_column_bytes": per_column,
        "fused_bytes": fused,
        "bytes_ratio": per_column / fused,
        "per_column_memory_s": per_column / HBM_BW,
        "fused_memory_s": fused / HBM_BW,
    }


def slab_sweep_bytes(c: int, d_pad: int, k: int, k_b: int) -> Dict[str, float]:
    """Analytic HBM bytes for one side's k-dimension sweep of a FIELD model
    (MFSI/FM) on the padded layout. Per dimension the per-column path
    streams ψ, α and e twice (q/p2 slab compute + residual patch); the
    fused path still reads ψ once per dimension but amortizes α and the two
    e streams over the k_b dimensions of a block (one ``cd_slab_reduce`` +
    one ``cd_resid_patch``)."""
    cd = 4.0 * c * d_pad
    n_blocks = float(-(-k // k_b))
    per_column = k * 5.0 * cd            # ψ + α + e_read + (e_read + e_write)
    fused = k * cd + 4.0 * n_blocks * cd  # ψ per column; α + 3·e per block
    return {
        "per_column_bytes": per_column,
        "fused_bytes": fused,
        "bytes_ratio": per_column / fused,
        "per_column_memory_s": per_column / HBM_BW,
        "fused_memory_s": fused / HBM_BW,
    }


def _time_epochs(step, state, n_epochs):
    state = step(state)  # warmup (trace+compile)
    jax.block_until_ready(state)
    t0 = time.perf_counter()
    for _ in range(n_epochs):
        state = step(state)
    jax.block_until_ready(state)
    return (time.perf_counter() - t0) / n_epochs, state


def _assert_parity(name, got, ref, rtol=5e-4, atol=5e-5):
    import numpy as np

    got, ref = np.asarray(got), np.asarray(ref)
    if not np.allclose(got, ref, rtol=rtol, atol=atol):
        gap = float(np.max(np.abs(got - ref)))
        raise AssertionError(
            f"cd_sweep bench parity FAILED for {name}: fused vs per-column "
            f"max|Δ|={gap:.3e} (rtol={rtol}, atol={atol})"
        )


def _fused_tensor_measure(model_name, quick, n_epochs=2):
    """Fused-vs-per-column epoch comparison for PARAFAC / Tucker, with a
    hard parity assertion (the CI bench-smoke gate)."""
    import numpy as np

    from repro.core.models import parafac, tucker
    from repro.core.models.parafac import TensorContext
    from repro.sparse.interactions import build_interactions

    rng = np.random.default_rng(0)
    if quick:
        n_c1, n_c2, n_items, n_pairs, nnz, k, k_b = 16, 12, 20, 48, 320, 6, 3
    else:
        n_c1, n_c2, n_items, n_pairs, nnz, k, k_b = 64, 48, 96, 512, 4096, 16, 8
    chosen = rng.choice(n_c1 * n_c2, size=n_pairs, replace=False)
    tc = TensorContext(
        c1=jnp.asarray(chosen // n_c2, jnp.int32),
        c2=jnp.asarray(chosen % n_c2, jnp.int32),
        n_c1=n_c1, n_c2=n_c2,
    )
    cells = rng.choice(n_pairs * n_items, size=nnz, replace=False)
    ctx, item = cells // n_items, cells % n_items
    y = rng.integers(1, 5, size=nnz).astype(np.float64)
    alpha = 1.4 + rng.random(nnz)
    data = build_interactions(ctx, item, y, alpha, n_pairs, n_items, alpha0=0.4)

    if model_name == "parafac":
        mod = parafac
        hp_pc = parafac.PARAFACHyperParams(k=k, alpha0=0.4, l2=0.05, block_k=1)
        hp_f = dataclasses.replace(hp_pc, block_k=k_b)
        params0 = parafac.init(jax.random.PRNGKey(0), n_c1, n_c2, n_items, k)
    else:
        mod = tucker
        hp_pc = tucker.TuckerHyperParams(k1=k, k2=max(2, k // 2), k3=k,
                                         alpha0=0.4, l2=0.05, block_k=1)
        hp_f = dataclasses.replace(hp_pc, block_k=k_b)
        params0 = tucker.init(jax.random.PRNGKey(0), n_c1, n_c2, n_items,
                              hp_pc.k1, hp_pc.k2, hp_pc.k3)
    padded = mod.pad_tensor_groups(tc, data)

    out = {}
    finals = {}
    variants = (
        ("per_column", hp_pc),
        ("fused", hp_f),  # default Ψ routing: in-kernel gather
        ("fused_pregather", dataclasses.replace(hp_f, psi_dispatch="pregather")),
    )
    for label, hp in variants:
        if label == "per_column":
            def step(state, hp=hp):
                p, e = state
                return mod.epoch(p, tc, data, e, hp)
        else:
            def step(state, hp=hp):
                p, e = state
                return mod.epoch_padded(p, tc, data, padded, e, hp)
        s, (p_fin, _) = _time_epochs(
            step, (params0, mod.residuals(params0, tc, data)), n_epochs
        )
        out[label] = {"s_per_epoch": s}
        finals[label] = p_fin
    for field in finals["fused"]._fields:
        _assert_parity(f"{model_name}.{field}",
                       getattr(finals["fused"], field),
                       getattr(finals["per_column"], field))
        _assert_parity(f"{model_name}.{field} (gather vs pregather)",
                       getattr(finals["fused"], field),
                       getattr(finals["fused_pregather"], field))
    out["parity_ok"] = True
    out["wallclock_speedup"] = (
        out["per_column"]["s_per_epoch"] / out["fused"]["s_per_epoch"]
    )
    d_pad = max(padded.g1.d_pad, padded.gi.d_pad)
    out["analytic_web_scale"] = rowpatch_sweep_bytes(
        c=10_000_000, d_pad=1024, k=max(k, 64), k_b=8
    )
    out["shape"] = dict(n_c1=n_c1, n_c2=n_c2, n_items=n_items,
                        n_pairs=n_pairs, nnz=nnz, k=k, k_b=k_b, d_pad=d_pad)
    return out


def _fused_field_measure(model_name, quick, n_epochs=2):
    """Fused-vs-per-column epoch comparison for MFSI / FM (hard parity)."""
    import numpy as np

    from repro.core.design import make_design
    from repro.core.models import fm, mfsi
    from repro.sparse.interactions import build_interactions

    rng = np.random.default_rng(1)
    if quick:
        n_ctx, n_items, nnz, k, k_b = 48, 32, 480, 6, 3
    else:
        n_ctx, n_items, nnz, k, k_b = 256, 128, 8192, 16, 8
    x = make_design(
        [
            dict(name="id", ids=np.arange(n_ctx) % 11, vocab=11),
            dict(name="grp", ids=rng.integers(0, 5, n_ctx), vocab=5),
        ],
        n_ctx,
    )
    z = make_design(
        [
            dict(name="item_id", ids=np.arange(n_items), vocab=n_items),
            dict(name="genre", ids=rng.integers(0, 7, n_items), vocab=7),
        ],
        n_items,
    )
    cells = rng.choice(n_ctx * n_items, size=nnz, replace=False)
    ctx, item = cells // n_items, cells % n_items
    y = rng.integers(1, 5, size=nnz).astype(np.float64)
    alpha = 1.4 + rng.random(nnz)
    data = build_interactions(ctx, item, y, alpha, n_ctx, n_items, alpha0=0.4)

    mod = mfsi if model_name == "mfsi" else fm
    if model_name == "mfsi":
        hp_pc = mfsi.MFSIHyperParams(k=k, alpha0=0.4, l2=0.05, block_k=1)
    else:
        hp_pc = fm.FMHyperParams(k=k, alpha0=0.4, l2=0.05, block_k=1)
    hp_f = dataclasses.replace(hp_pc, block_k=k_b)
    params0 = mod.init(jax.random.PRNGKey(1), x.p, z.p, k)
    pdata = mod.pad_interactions(data)

    out = {}
    finals = {}
    variants = (
        ("per_column", hp_pc),
        ("fused", hp_f),  # default Ψ routing: in-kernel gather
        ("fused_pregather", dataclasses.replace(hp_f, psi_dispatch="pregather")),
    )
    for label, hp in variants:
        if model_name == "mfsi":
            e0 = mod.residuals(params0, x, z, data)
        else:
            e0 = mod.residuals(params0, x, z, data, hp)
        if label == "per_column":
            def step(state, hp=hp):
                p, e = state
                return mod.epoch(p, x, z, data, e, hp)
            state0 = (params0, e0)
        else:
            from repro.core.models.mf_padded import scatter_ctx_major

            def step(state, hp=hp):
                p, e = state
                return mod.epoch_padded(p, x, z, pdata, e, hp)
            state0 = (params0, scatter_ctx_major(pdata, e0))
        s, (p_fin, _) = _time_epochs(step, state0, n_epochs)
        out[label] = {"s_per_epoch": s}
        finals[label] = p_fin
    for field in finals["fused"]._fields:
        _assert_parity(f"{model_name}.{field}",
                       getattr(finals["fused"], field),
                       getattr(finals["per_column"], field))
        _assert_parity(f"{model_name}.{field} (gather vs pregather)",
                       getattr(finals["fused"], field),
                       getattr(finals["fused_pregather"], field))
    out["parity_ok"] = True
    out["wallclock_speedup"] = (
        out["per_column"]["s_per_epoch"] / out["fused"]["s_per_epoch"]
    )
    out["analytic_web_scale"] = slab_sweep_bytes(
        c=10_000_000, d_pad=1024, k=max(k, 64), k_b=8
    )
    out["shape"] = dict(n_ctx=n_ctx, n_items=n_items, nnz=nnz, k=k, k_b=k_b,
                        d_pad=pdata.alpha_c.shape[1])
    return out


def _cd_sweep_measure(c, n_items, nnz, k, k_b, n_epochs=2):
    """Measured CPU comparison of the two mf_padded dispatch paths (same
    math, parity-tested): wall-clock per epoch + XLA cost-analysis bytes."""
    import numpy as np

    from repro.core.models import mf, mf_padded
    from repro.sparse.interactions import build_interactions

    rng = np.random.default_rng(0)
    cells = rng.choice(c * n_items, size=nnz, replace=False)
    ctx, item = cells // n_items, cells % n_items
    y = rng.integers(1, 5, size=nnz).astype(np.float64)
    alpha = 1.4 + rng.random(nnz)
    data = build_interactions(ctx, item, y, alpha, c, n_items, alpha0=0.4)
    pdata = mf_padded.pad_interactions(data)
    params0 = mf.init(jax.random.PRNGKey(0), c, n_items, k)

    out = {}
    finals = {}
    # per-column runs unrolled so XLA's cost analysis sees all k column
    # bodies (a fori_loop body is counted once) — the fused block loop is
    # a host loop and therefore always unrolled.
    variants = (
        ("per_column", 1, "gather"),
        ("fused", k_b, "gather"),           # default Ψ routing
        ("fused_pregather", k_b, "pregather"),
    )
    for label, block_k, disp in variants:
        hp = mf.MFHyperParams(k=k, alpha0=0.4, l2=0.05, block_k=block_k,
                              unroll=(block_k == 1), psi_dispatch=disp)
        e0 = mf_padded.residuals(params0, pdata)
        lowered = mf_padded.epoch.lower(params0, pdata, e0, hp)
        compiled = lowered.compile()
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):  # older jax: one dict per computation
            ca = ca[0] if ca else {}
        # reuse the AOT executable — re-invoking the jitted epoch would pay
        # the (unrolled, interpret-mode) trace+compile a second time
        params, e_pad = compiled(params0, pdata, e0)  # warmup
        jax.block_until_ready(e_pad)
        t0 = time.perf_counter()
        for _ in range(n_epochs):
            params, e_pad = compiled(params, pdata, e_pad)
        jax.block_until_ready(e_pad)
        out[label] = {
            "s_per_epoch": (time.perf_counter() - t0) / n_epochs,
            "cost_analysis_bytes": float(ca.get("bytes accessed", 0.0)),
        }
        finals[label] = params
    for field in finals["fused"]._fields:
        _assert_parity(f"mf.{field}",
                       getattr(finals["fused"], field),
                       getattr(finals["per_column"], field))
        _assert_parity(f"mf.{field} (gather vs pregather)",
                       getattr(finals["fused"], field),
                       getattr(finals["fused_pregather"], field))
    out["parity_ok"] = True
    out["wallclock_speedup"] = (
        out["per_column"]["s_per_epoch"] / out["fused"]["s_per_epoch"]
    )
    if out["fused"]["cost_analysis_bytes"]:
        out["measured_bytes_ratio"] = (
            out["per_column"]["cost_analysis_bytes"]
            / out["fused"]["cost_analysis_bytes"]
        )
    # What the default dispatch ACTUALLY chose for this shape (ctx-side
    # sweep: gather from the (n_items, k_b) ψ slab) — the capacity gate
    # asserts on this, not just on closed-form byte arithmetic.
    from repro.kernels import vmem

    out["d_pad"] = int(pdata.alpha_c.shape[1])
    out["default_dispatch_is_gather"] = bool(
        vmem.resolve_cd_sweep_dispatch(
            out["d_pad"], k_b, n_items, n_rows=c
        )[0]
    )
    return out


def cd_sweep_bench(quick: bool = True, out_path: Optional[str] = None):
    """Fused block-sweep vs per-column baseline; writes BENCH_cd_sweep.json.

    The analytic table is the acceptance tracker (≥2× fewer HBM bytes per
    sweep at k ≥ 64); the measured section is a CPU sanity run of the real
    ``mf_padded.epoch`` on both dispatch paths (interpret-mode kernels, so
    wall-clock mostly reflects dispatch count + XLA memory traffic, not TPU
    time).

    The tracked repo-root ``BENCH_cd_sweep.json`` is always the quick-mode
    (CI smoke) shape so its measured section stays comparable PR-over-PR;
    ``--full`` runs land in ``BENCH_cd_sweep_full.json``. Paths are
    anchored to the repo root, not the process cwd."""
    if out_path is None:
        repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        out_path = os.path.join(
            repo_root,
            "BENCH_cd_sweep.json" if quick else "BENCH_cd_sweep_full.json",
        )
    from repro.kernels import use_interpret

    k_b = 8
    analytic = {
        f"k={k}": cd_sweep_sweep_bytes(c=10_000_000, d_pad=1024, k=k, k_b=k_b)
        for k in (32, 64, 128, 256)
    }
    # Peak HBM capacity of the per-dispatch Ψ routing at k_b=8 (PR 4: the
    # in-kernel gather removes the (C, k_b, D_pad) intermediate; today's
    # interpret-safe form keeps the ψ slab VMEM-resident, so past
    # ~VMEM_BUDGET/4/k_b source rows the dispatch falls back to pre-gather —
    # the HBM-resident slab + per-row pltpu DMA lowering is the compiled-TPU
    # follow-up).
    peak_capacity = {
        "web_scale_mf": psi_peak_capacity_bytes(
            c=10_000_000, d_pad=1024, k_b=k_b, n_src=1_000_000
        ),
        "youtube_scale_mf": psi_peak_capacity_bytes(
            c=200_000, d_pad=1024, k_b=k_b, n_src=68_000
        ),
    }
    if quick:
        shapes = dict(c=256, n_items=128, nnz=2_000, k=16, k_b=4)
    else:
        shapes = dict(c=1024, n_items=512, nnz=16_000, k=64, k_b=8)
    measured = _cd_sweep_measure(**shapes)
    # The shape that actually ran (its real d_pad/k_b), plus the dispatch
    # the default routing chose for it — the capacity gate below requires
    # the gather path to have been LIVE here, not just cheaper on paper.
    peak_capacity["measured_shape"] = {
        **psi_peak_capacity_bytes(
            c=shapes["c"], d_pad=measured["d_pad"], k_b=shapes["k_b"],
            n_src=shapes["n_items"],
        ),
        "k_b": shapes["k_b"],
        "d_pad": measured["d_pad"],
        "default_dispatch_is_gather": measured["default_dispatch_is_gather"],
    }
    # per-model fused-vs-per-column sections — each carries a HARD parity
    # assertion, so a broken kernel path fails the whole bench (CI gate)
    models = {
        "parafac": _fused_tensor_measure("parafac", quick),
        "tucker": _fused_tensor_measure("tucker", quick),
        "mfsi": _fused_field_measure("mfsi", quick),
        "fm": _fused_field_measure("fm", quick),
    }
    # None ⇒ cost_analysis had no byte counts (jax/backend dependent):
    # record null and gate on the analytic model alone rather than
    # reporting a phantom regression.
    measured_ratio = measured.get("measured_bytes_ratio")
    results = {
        "kernel": "kernels/cd_sweep (block) vs kernels/cd_update (per-column)",
        "mode": "quick" if quick else "full",
        "backend": "interpret" if use_interpret() else "compiled",
        "analytic_block_k": k_b,
        "analytic_web_scale": {
            "shape": "C=10M, D_pad=1024, one side sweep, fp32",
            **analytic,
        },
        "peak_capacity": {
            "shape": "per block dispatch at k_b=8, fp32; gathered = resident "
                     "psi slab (n_src, k_b), pregathered = (C, k_b, D_pad) "
                     "intermediate",
            **peak_capacity,
        },
        "measured_cpu": {"shape": shapes, **measured},
        "models": models,
        "acceptance": {
            "bytes_ratio_at_k64": analytic["k=64"]["bytes_ratio"],
            # measured floor is loose: interpret-mode emulation adds block
            # copies to both paths, but a fused path that stopped saving
            # traffic (ratio <= ~1) still trips the gate.
            "measured_bytes_ratio": measured_ratio,
            "model_parity": {m: r["parity_ok"] for m, r in models.items()},
            "model_analytic_bytes_ratio": {
                m: r["analytic_web_scale"]["bytes_ratio"]
                for m, r in models.items()
            },
            # PR 4: the gathered dispatch must hold a strict peak-HBM-
            # capacity advantage over the pre-gathered fallback — the
            # (C, k_b, D_pad) intermediate is gone — AND must have been the
            # LIVE default routing for the measured shape (so the gate
            # fails if the dispatch ever silently falls back to pregather,
            # not just if the closed-form arithmetic changes). Every
            # model's measure above also hard-asserts gather-vs-pregather
            # parity.
            "peak_capacity_gathered_lt_pregathered": all(
                v["gathered_slab_bytes"] < v["pregathered_intermediate_bytes"]
                for v in peak_capacity.values()
            ) and measured["default_dispatch_is_gather"],
            "target": ">= 2x fewer HBM bytes per sweep at k >= 64 "
                      "(analytic) and measured XLA bytes ratio > 1.2 "
                      "(when available); every model's fused path "
                      "parity-checked against its per-column path AND "
                      "gathered vs pre-gathered; gathered peak capacity "
                      "strictly below pre-gathered at k_b=8",
            "met": analytic["k=64"]["bytes_ratio"] >= 2.0
                   and (measured_ratio is None or measured_ratio > 1.2)
                   and all(r["parity_ok"] for r in models.values())
                   and measured.get("parity_ok", False)
                   and measured["default_dispatch_is_gather"]
                   and all(
                       v["gathered_slab_bytes"]
                       < v["pregathered_intermediate_bytes"]
                       for v in peak_capacity.values()
                   ),
        },
    }
    if out_path:
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    rows = load_table()
    print(markdown_table(rows))
    print(json.dumps(cd_sweep_bench(quick=True)["acceptance"], indent=1))
