"""Pallas fused iCD Newton column update (the paper's Algorithm 2 inner loop).

One grid step processes a block of contexts for a fixed embedding dimension
f*. The padded observation layout (each context's interactions padded to
D_pad, α pre-zeroed on padding) makes every tensor dense:

  inputs  (per block): ψ tile (bc, D_pad) — pre-gathered ψ_{f*}(item)
                       α tile, e tile     — confidences / residual cache
                       w (bc, 1), r1 (bc, 1) — column + R'/2 ≡ (W·J[:,f*])
                       jff (1,1)          — J(f*,f*)
  compute: L'/2  = Σ_d α·e·ψ            (VPU row reduce)
           L''/2 = Σ_d α·ψ²
           Δ     = −η·(L'/2 + α₀·R'/2 + λw)/(L''/2 + α₀·J(f*,f*) + λ)
           e    += Δ·ψ                   (rank-1 residual patch)
  outputs: w_new (bc,1), e_new (bc,D_pad)

The fusion saves 4 HBM round-trips of (C, D_pad) intermediates versus the
XLA segment-sum path (gather → mul → reduce → newton → scatter as separate
ops). VMEM per step: 3·bc·D_pad·4 B ≈ 3 MiB at bc=256, D_pad=1024.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cd_kernel(alpha0, l2, eta, psi_ref, alpha_ref, e_ref, w_ref, r1_ref,
               jff_ref, w_out_ref, e_out_ref):
    psi = psi_ref[...].astype(jnp.float32)
    alpha = alpha_ref[...].astype(jnp.float32)
    e = e_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)          # (bc, 1)
    r1 = r1_ref[...].astype(jnp.float32)        # (bc, 1)
    jff = jff_ref[0, 0]

    ae = alpha * e
    lp = jnp.sum(ae * psi, axis=1, keepdims=True)            # L'/2
    lpp = jnp.sum(alpha * psi * psi, axis=1, keepdims=True)  # L''/2
    num = lp + alpha0 * r1 + l2 * w
    den = lpp + alpha0 * jff + l2
    delta = -eta * num / jnp.maximum(den, 1e-12)

    w_out_ref[...] = w + delta
    e_out_ref[...] = e + delta * psi


def cd_column_update_pallas(
    psi: jax.Array,     # (C, D_pad)
    alpha: jax.Array,   # (C, D_pad), 0 on padding
    e: jax.Array,       # (C, D_pad)
    w_col: jax.Array,   # (C,)
    r1: jax.Array,      # (C,)
    jff: jax.Array,     # scalar
    *,
    alpha0: float,
    l2: float,
    eta: float = 1.0,
    block_ctx: int = 256,
    interpret: bool = True,
):
    c, d_pad = psi.shape
    c_pad = -(-c // block_ctx) * block_ctx
    if c_pad != c:
        pad = ((0, c_pad - c), (0, 0))
        psi, alpha, e = (jnp.pad(a, pad) for a in (psi, alpha, e))
        w_col = jnp.pad(w_col, (0, c_pad - c))
        r1 = jnp.pad(r1, (0, c_pad - c))

    w2 = w_col[:, None]
    r2 = r1[:, None]
    jff2 = jnp.reshape(jff.astype(jnp.float32), (1, 1))

    grid = (c_pad // block_ctx,)
    w_new, e_new = pl.pallas_call(
        partial(_cd_kernel, alpha0, l2, eta),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_ctx, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_ctx, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, d_pad), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((c_pad, d_pad), jnp.float32),
        ],
        interpret=interpret,
    )(psi, alpha, e, w2, r2, jff2)
    return w_new[:c, 0], e_new[:c]
