"""The paper's own iCD-FM (§6: A+P+H features over the YouTube-like set).

Context features: user id (200k) + age (8) + country (64) + gender (3) +
device (16) + previous video (68k) + watch history (bag over 68k).
Item features: video id (68k).
"""
import dataclasses

from repro.configs.base import ICD_SHAPES, ICDConfig

CONFIG = ICDConfig(
    name="icd-fm",
    model="fm",
    n_ctx=200_000,
    n_items=68_000,
    k=128,
    alpha0=1.0,
    l2=0.1,
    p_ctx=200_000 + 8 + 64 + 3 + 16 + 68_000 + 68_000,
    p_item=68_000,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, n_ctx=50, n_items=30, k=6, p_ctx=50 + 4 + 3 + 30 + 30, p_item=30
)

SHAPES = ICD_SHAPES
