"""FM iCD: (k+2)-separability identity, autodiff-Newton exactness, convergence.

The exactness oracle replays our exact sweep order (dims × fields → linear →
bias, context side then item side) but computes every Newton step from the
FULL dense implicit objective via autodiff — gradients through eq. (1) over
S_impl directly, no Lemma 1/2/3. iCD must match coordinate-for-coordinate.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import naive_cd
from repro.core.design import make_design, to_dense
from repro.core.models import fm
from repro.sparse.interactions import build_interactions


def make_problem(seed=0, n_ctx=8, n_items=6, nnz=20, alpha0=0.3, with_bag=False):
    rng = np.random.default_rng(seed)
    fields = [
        dict(name="country", ids=rng.integers(0, 3, n_ctx), vocab=3),
        dict(name="age", ids=rng.integers(0, 2, n_ctx), vocab=2),
    ]
    if with_bag:
        bag_ids = np.stack([rng.choice(5, 2, replace=False) for _ in range(n_ctx)])
        fields.append(
            dict(name="hist", ids=bag_ids, vocab=5,
                 weights=np.full((n_ctx, 2), 0.5, np.float32))
        )
    x = make_design(fields, n_ctx)
    z = make_design([dict(name="item_id", ids=np.arange(n_items), vocab=n_items)], n_items)
    pairs = rng.choice(n_ctx * n_items, size=nnz, replace=False)
    ctx, item = pairs // n_items, pairs % n_items
    y = rng.integers(1, 4, size=nnz).astype(np.float64)
    alpha = alpha0 + 1.0 + rng.random(nnz)
    data = build_interactions(ctx, item, y, alpha, n_ctx, n_items, alpha0=alpha0)
    y_dense, a_dense = naive_cd.dense_from_observed(
        jnp.asarray(ctx), jnp.asarray(item), jnp.asarray(y, jnp.float32),
        jnp.asarray(alpha, jnp.float32), n_ctx, n_items, alpha0,
    )
    return x, z, data, y_dense, a_dense


def fm_dense_scores(params, x_dense, z_dense, hp):
    """Direct eq. (26) evaluation on materialized features."""
    phi = x_dense @ params.w
    psi = z_dense @ params.h
    ctx_pair = 0.5 * (jnp.sum(phi**2, 1) - jnp.sum((x_dense**2) @ (params.w**2), 1))
    item_pair = 0.5 * (jnp.sum(psi**2, 1) - jnp.sum((z_dense**2) @ (params.h**2), 1))
    s = phi @ psi.T + ctx_pair[:, None] + item_pair[None, :]
    if hp.use_linear:
        s = s + (x_dense @ params.w_lin)[:, None] + (z_dense @ params.h_lin)[None, :]
    if hp.use_bias:
        s = s + params.b
    return s


def test_fm_separability_identity():
    """⟨Φe(c), Ψe(i)⟩ must equal the direct FM formula — Def. 1 / eqs. 27–31."""
    x, z, data, _, _ = make_problem(seed=1, with_bag=True)
    hp = fm.FMHyperParams(k=3, alpha0=0.3)
    params = fm.init(jax.random.PRNGKey(0), x.p, z.p, 3)
    params = params._replace(
        b=jnp.float32(0.7),
        w_lin=0.1 * jnp.arange(x.p, dtype=jnp.float32),
        h_lin=0.05 * jnp.arange(z.p, dtype=jnp.float32),
    )
    sep = fm.phi_ext(params, x, hp) @ fm.psi_ext(params, z, hp).T
    direct = fm_dense_scores(params, to_dense(x), to_dense(z), hp)
    np.testing.assert_allclose(sep, direct, rtol=1e-5, atol=1e-5)


def _newton_layer(loss_fn, params, path, mask, eta):
    """Parallel Newton step on the masked coordinates of params[path]."""
    theta = getattr(params, path)

    def f(t):
        return loss_fn(params._replace(**{path: t}))

    g = jax.grad(f)(theta)
    basis = jnp.eye(theta.size, dtype=theta.dtype).reshape((theta.size,) + theta.shape)
    diag = jax.vmap(lambda v: jnp.vdot(v, jax.jvp(jax.grad(f), (theta,), (v,))[1]))(basis)
    diag = diag.reshape(theta.shape)
    step = jnp.where(mask, -eta * g / jnp.maximum(diag, 1e-12), 0.0)
    return params._replace(**{path: theta + step})


@pytest.mark.parametrize("use_linear,use_bias", [(False, False), (True, True)])
def test_fm_matches_autodiff_newton_trajectory(use_linear, use_bias):
    x, z, data, y_dense, a_dense = make_problem(seed=2)
    k = 2
    hp = fm.FMHyperParams(
        k=k, alpha0=0.3, l2=0.05, l2_lin=0.02,
        use_linear=use_linear, use_bias=use_bias,
    )
    params = fm.init(jax.random.PRNGKey(1), x.p, z.p, k)
    x_dense, z_dense = to_dense(x), to_dense(z)

    def dense_loss(p):
        s = fm_dense_scores(p, x_dense, z_dense, hp)
        reg = hp.l2 * (jnp.sum(p.w**2) + jnp.sum(p.h**2))
        reg += hp.l2_lin * (jnp.sum(p.w_lin**2) + jnp.sum(p.h_lin**2))
        return jnp.sum(a_dense * (s - y_dense) ** 2) + reg

    # --- oracle: replay the sweep order with autodiff Newton steps --------
    oracle = params
    for f in range(k):
        for fld in x.fields:
            m = jnp.zeros((x.p, k), bool).at[fld.offset : fld.offset + fld.vocab, f].set(True)
            oracle = _newton_layer(dense_loss, oracle, "w", m, hp.eta)
    if use_linear:
        for fld in x.fields:
            m = jnp.zeros((x.p,), bool).at[fld.offset : fld.offset + fld.vocab].set(True)
            oracle = _newton_layer(dense_loss, oracle, "w_lin", m, hp.eta)
    if use_bias:
        oracle = _newton_layer(dense_loss, oracle, "b", jnp.array(True), hp.eta)
    for f in range(k):
        for fld in z.fields:
            m = jnp.zeros((z.p, k), bool).at[fld.offset : fld.offset + fld.vocab, f].set(True)
            oracle = _newton_layer(dense_loss, oracle, "h", m, hp.eta)
    if use_linear:
        for fld in z.fields:
            m = jnp.zeros((z.p,), bool).at[fld.offset : fld.offset + fld.vocab].set(True)
            oracle = _newton_layer(dense_loss, oracle, "h_lin", m, hp.eta)

    # --- iCD ---------------------------------------------------------------
    e = fm.residuals(params, x, z, data, hp)
    got, _ = fm.epoch(params, x, z, data, e, hp)

    np.testing.assert_allclose(got.w, oracle.w, rtol=5e-4, atol=5e-5)
    np.testing.assert_allclose(got.h, oracle.h, rtol=5e-4, atol=5e-5)
    if use_linear:
        np.testing.assert_allclose(got.w_lin, oracle.w_lin, rtol=5e-4, atol=5e-5)
        np.testing.assert_allclose(got.h_lin, oracle.h_lin, rtol=5e-4, atol=5e-5)
    if use_bias:
        np.testing.assert_allclose(got.b, oracle.b, rtol=5e-4, atol=5e-5)


def test_fm_objective_decreases():
    x, z, data, _, _ = make_problem(seed=3, n_ctx=12, n_items=9, nnz=30, with_bag=True)
    hp = fm.FMHyperParams(k=3, alpha0=0.3, l2=0.05)
    params = fm.init(jax.random.PRNGKey(2), x.p, z.p, 3)
    start = float(fm.objective(params, x, z, data, hp))
    params = fm.fit(params, x, z, data, hp, n_epochs=8)
    assert float(fm.objective(params, x, z, data, hp)) < 0.8 * start


def test_fm_residual_cache_consistency_one_hot():
    x, z, data, _, _ = make_problem(seed=4)
    hp = fm.FMHyperParams(k=2, alpha0=0.3, l2=0.05)
    params = fm.init(jax.random.PRNGKey(3), x.p, z.p, 2)
    e = fm.residuals(params, x, z, data, hp)
    for _ in range(2):
        params, e = fm.epoch(params, x, z, data, e, hp)
    np.testing.assert_allclose(
        e, fm.residuals(params, x, z, data, hp), rtol=2e-4, atol=2e-5
    )


# ------------------------------------------ fused (padded) block parity ----
# fast gate: one representative (multi-hot jacobi, non-divisible k=3/k_b=2);
# the full (mode × block_k) matrix rides the slow suite.
_FM_FUSED_CASES = [
    pytest.param(w, m, bk, marks=() if (w, m, bk) == (True, "jacobi", 2)
                 else pytest.mark.slow)
    for w, m in ((False, "jacobi"), (True, "jacobi"), (True, "slot"))
    for bk in (1, 2, 3)
]


def test_fm_fused_gather_matches_pregather():
    """The in-kernel-gather Ψ routing (default; slab = [Ψ_blk | ψ_spec])
    must reproduce the pre-gathered routing to reduction roundoff (the
    gather kernel's einsum contracts in (d, m) layout) — non-divisible
    k=3/block_k=2, linear weights + bias included."""
    import dataclasses

    x, z, data, _, _ = make_problem(seed=9, with_bag=True)
    k = 3
    base = fm.FMHyperParams(k=k, alpha0=0.3, l2=0.05, block_k=2)
    params = fm.init(jax.random.PRNGKey(8), x.p, z.p, k)
    params = params._replace(w_lin=0.01 * jnp.arange(x.p, dtype=jnp.float32))
    pdata = fm.pad_interactions(data)
    finals = {}
    for disp in ("gather", "pregather"):
        hp = dataclasses.replace(base, psi_dispatch=disp)
        p, e_pad = params, fm.residuals_padded(params, x, z, data, pdata, hp)
        for _ in range(2):
            p, e_pad = fm.epoch_padded(p, x, z, pdata, e_pad, hp)
        finals[disp] = (p, e_pad)
    for field in finals["gather"][0]._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(finals["gather"][0], field)),
            np.asarray(getattr(finals["pregather"][0], field)),
            rtol=5e-5, atol=1e-5,
        )
    np.testing.assert_allclose(finals["gather"][1], finals["pregather"][1],
                               rtol=5e-5, atol=1e-5)


@pytest.mark.parametrize("with_bag,mode,block_k", _FM_FUSED_CASES)
def test_fm_fused_matches_per_column(with_bag, mode, block_k):
    """epoch_padded (slab-reduce over [ψ_blk | ψ_spec] + rank-(k_b+1)
    resid patch) must track the per-dimension epoch — dims, linear weights
    and global bias — incl. the non-divisible k=3/block_k=2 split."""
    x, z, data, _, _ = make_problem(seed=6, with_bag=with_bag)
    k = 3
    hp = fm.FMHyperParams(k=k, alpha0=0.3, l2=0.05, multi_hot_mode=mode,
                          block_k=block_k)
    params = fm.init(jax.random.PRNGKey(5), x.p, z.p, k)
    params = params._replace(w_lin=0.01 * jnp.arange(x.p, dtype=jnp.float32))
    pdata = fm.pad_interactions(data)
    ref, got = params, params
    e = fm.residuals(params, x, z, data, hp)
    e_pad = fm.residuals_padded(params, x, z, data, pdata, hp)
    for _ in range(2):
        ref, e = fm.epoch(ref, x, z, data, e, hp)
        got, e_pad = fm.epoch_padded(got, x, z, pdata, e_pad, hp)
    np.testing.assert_allclose(got.b, ref.b, rtol=5e-4, atol=1e-5)
    np.testing.assert_allclose(got.w_lin, ref.w_lin, rtol=5e-4, atol=1e-5)
    np.testing.assert_allclose(got.w, ref.w, rtol=5e-4, atol=1e-5)
    np.testing.assert_allclose(got.h_lin, ref.h_lin, rtol=5e-4, atol=1e-5)
    np.testing.assert_allclose(got.h, ref.h, rtol=5e-4, atol=1e-5)
    np.testing.assert_allclose(
        e_pad[pdata.c_rows, pdata.c_cols], e, rtol=5e-4, atol=5e-5
    )
