"""core.metrics coverage: from-topk helpers, tie behavior, exclusion edge
cases, and dense/host-path consistency."""
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import (
    ndcg_at_k,
    ndcg_from_topk,
    recall_at_k,
    recall_from_topk,
    recall_ndcg_multi,
    topk_items,
)


def test_recall_ndcg_from_topk_hand_example():
    top = jnp.asarray([[3, 1, 2], [5, 4, 0]])
    truth = jnp.asarray([1, 9])
    # row 0 hits at rank 2 → DCG = 1/log2(3); row 1 misses
    assert float(recall_from_topk(top, truth)) == 0.5
    np.testing.assert_allclose(
        float(ndcg_from_topk(top, truth)), 0.5 * (1.0 / np.log2(3.0)), rtol=1e-6
    )


def test_at_k_equals_from_topk_composition():
    rng = np.random.default_rng(0)
    scores = jnp.asarray(rng.normal(size=(8, 30)), jnp.float32)
    truth = jnp.asarray(rng.integers(0, 30, size=8), jnp.int32)
    top = topk_items(scores, 5)
    np.testing.assert_allclose(
        float(recall_at_k(scores, truth, 5)),
        float(recall_from_topk(top, truth)),
    )
    np.testing.assert_allclose(
        float(ndcg_at_k(scores, truth, 5)),
        float(ndcg_from_topk(top, truth)),
    )


def test_tied_scores_rank_ascending_id():
    # all-equal scores: lax.top_k stability ⇒ ids 0..k-1
    scores = jnp.ones((2, 10))
    top = topk_items(scores, 4)
    np.testing.assert_array_equal(np.asarray(top), [[0, 1, 2, 3]] * 2)
    # a tie group straddling the k boundary keeps the smaller ids
    scores = jnp.asarray([[1.0, 2.0, 2.0, 2.0, 0.5]])
    top = topk_items(scores, 2)
    np.testing.assert_array_equal(np.asarray(top), [[1, 2]])


def test_exclude_mask_drops_excluded_ids():
    scores = jnp.asarray([[5.0, 4.0, 3.0, 2.0, 1.0]])
    mask = jnp.asarray([[True, False, True, False, False]])
    top = topk_items(scores, 2, mask)
    np.testing.assert_array_equal(np.asarray(top), [[1, 3]])
    # excluded true item can never be a hit (its score is −inf, and at
    # least k admissible items outrank it here)
    assert float(recall_at_k(scores, jnp.asarray([0]), 2, mask)) == 0.0


def test_fully_excluded_row_dense_caveat_vs_streaming_policy():
    """Dense top_k over a fully-masked row returns arbitrary REAL ids (the
    documented caveat) — the streaming path's −1 policy is what makes such
    rows guaranteed misses. from_topk treats −1 correctly."""
    top_streaming = jnp.full((1, 3), -1)
    assert float(recall_from_topk(top_streaming, jnp.asarray([2]))) == 0.0
    assert float(ndcg_from_topk(top_streaming, jnp.asarray([2]))) == 0.0


def test_recall_ndcg_multi_matches_single_item_path():
    rng = np.random.default_rng(1)
    scores = rng.normal(size=(6, 40)).astype(np.float32)
    truth = rng.integers(0, 40, size=6)
    r_multi, n_multi = recall_ndcg_multi(scores, [[t] for t in truth], 7)
    r = float(recall_at_k(jnp.asarray(scores), jnp.asarray(truth), 7))
    n = float(ndcg_at_k(jnp.asarray(scores), jnp.asarray(truth), 7))
    np.testing.assert_allclose(r_multi, r, rtol=1e-6)
    np.testing.assert_allclose(n_multi, n, rtol=1e-6)


def test_recall_ndcg_multi_exclude_and_empty_truth():
    scores = np.asarray([[3.0, 2.0, 1.0, 0.0]] * 2, np.float32)
    # row 0: truth {0} but 0 excluded ⇒ miss; row 1 empty truth ⇒ skipped
    r, n = recall_ndcg_multi(
        scores, [[0], []], 2,
        exclude_mask=np.asarray([[True, False, False, False]] * 2),
    )
    assert r == 0.0 and n == 0.0
