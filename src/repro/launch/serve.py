"""Serving driver: LM decode or recsys retrieval with batched requests.

  python -m repro.launch.serve --arch icd-mf --smoke --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config


def _lm_serve(cfg, args):
    from repro.models import transformer as T
    from repro.serve.decode import generate

    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (args.batch, 8), 0,
                                cfg.vocab)
    t0 = time.perf_counter()
    out = generate(cfg, params, prompt, max_new_tokens=args.tokens,
                   compute_dtype=jnp.float32)
    dt = time.perf_counter() - t0
    print(f"[serve] generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.tokens / dt:.1f} tok/s)")
    print(out[0, :16].tolist())


def _icd_serve(cfg, args):
    from repro.core.models import mf
    from repro.serve.recsys_serve import mf_retrieval_score_fn, retrieval_topk

    params = mf.init(jax.random.PRNGKey(0), cfg.n_ctx, cfg.n_items, cfg.k)
    t0 = time.perf_counter()
    for r in range(args.requests):
        score = mf_retrieval_score_fn(params.w[r], params.h)
        scores, ids = retrieval_topk(score, cfg.n_items, k=min(100, cfg.n_items),
                                     chunk=max(1024, cfg.n_items // 4))
    dt = time.perf_counter() - t0
    print(f"[serve] {args.requests} retrieval requests in {dt:.3f}s "
          f"(p50 ≈ {dt / args.requests * 1e3:.2f} ms); top id {int(ids[0])}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.arch.startswith("icd"):
        _icd_serve(cfg, args)
    else:
        _lm_serve(cfg, args)


if __name__ == "__main__":
    main()
