"""Fault-tolerant checkpointing.

Design (what a 1000-node deployment needs, scaled to this container):

  * **Atomicity** — writes go to ``step_N.tmp/`` and are renamed to
    ``step_N/`` only after the manifest fsyncs; a crash mid-write can never
    corrupt the latest valid checkpoint.
  * **Manifest** — JSON with step, pytree structure, per-leaf dtype/shape
    and a content checksum per shard file; restore validates before use.
  * **Async** — ``save(...)`` returns immediately (device→host copy happens
    synchronously to snapshot the state, file IO on a writer thread);
    ``wait()`` joins. On a pod this thread becomes the per-host shard
    writer, one file per (host, leaf).
  * **Retention** — keep the newest ``keep`` checkpoints, delete older ones
    after a successful save.
  * **Resharding restore** — leaves are loaded as host arrays and
    ``jax.device_put`` onto the *target* sharding, so a checkpoint written
    on a (16,16) mesh restores onto (8,16) or (2,16,16) — this is the
    elastic-scaling path (``repro.runtime.elastic``).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(p) for p in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save ----
    def save(self, step: int, state: Any, blocking: bool = False) -> None:
        """Snapshot ``state`` (device→host now) and write asynchronously."""
        paths, leaves, _ = _flatten_with_paths(state)
        host_leaves = [np.asarray(x) for x in leaves]  # snapshot
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, paths, host_leaves), daemon=True
        )
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, paths, host_leaves) -> None:
        final = os.path.join(self.directory, f"step_{step:010d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": []}
        for i, (path, arr) in enumerate(zip(paths, host_leaves)):
            fname = f"leaf_{i:05d}.npy"
            fpath = os.path.join(tmp, fname)
            np.save(fpath, arr)
            with open(fpath, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            manifest["leaves"].append(
                {"path": path, "file": fname, "dtype": str(arr.dtype),
                 "shape": list(arr.shape), "sha256": digest}
            )
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:010d}"), ignore_errors=True
            )

    # ---------------------------------------------------------- restore ----
    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.directory, name, "manifest.json")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: Any, shardings: Any = None) -> Any:
        """Restore into the structure of ``target``; optional same-structure
        ``shardings`` pytree device_puts each leaf (elastic resharding)."""
        d = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        paths, leaves, treedef = _flatten_with_paths(target)
        by_path = {e["path"]: e for e in manifest["leaves"]}
        if set(paths) != set(by_path):
            missing = set(paths) ^ set(by_path)
            raise ValueError(f"checkpoint structure mismatch: {sorted(missing)[:5]}")

        shard_leaves = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None
            else [None] * len(leaves)
        )
        out = []
        for path, ref_leaf, shard in zip(paths, leaves, shard_leaves):
            entry = by_path[path]
            fpath = os.path.join(d, entry["file"])
            with open(fpath, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            if digest != entry["sha256"]:
                raise IOError(f"checksum mismatch in {fpath}")
            arr = np.load(fpath)
            if list(arr.shape) != list(ref_leaf.shape):
                raise ValueError(
                    f"{path}: shape {arr.shape} != target {ref_leaf.shape}"
                )
            out.append(
                jax.device_put(arr, shard) if shard is not None else jax.device_put(arr)
            )
        return treedef.unflatten(out)

    def restore_latest(self, target: Any, shardings: Any = None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, target, shardings)
