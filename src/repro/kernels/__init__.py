"""Pallas TPU kernels for the compute hot spots.

Each kernel package ships three layers:
  kernel.py — ``pl.pallas_call`` body with explicit BlockSpec VMEM tiling
  ops.py    — jit'd public wrapper (padding, dtype policy, interpret switch)
  ref.py    — pure-jnp oracle used by the allclose test sweeps

Kernels:
  gram            — tall-skinny AᵀA (Lemma 2's J matrices): row-blocked MXU
                    accumulation in VMEM. The iCD inner product engine.
  cd_update       — fused iCD Newton column update over the padded-CSR
                    observation layout (explicit+implicit parts + residual
                    patch in one VMEM pass).
  embedding_bag   — multi-hot EmbeddingBag as one-hot×table MXU matmuls,
                    vocab-block streamed (recsys hot path).
  flash_attention — online-softmax attention (causal / sliding-window /
                    logit-softcap) for the LM zoo's prefill shapes.

This container is CPU-only: kernels are validated with ``interpret=True``
(the Pallas interpreter executes the same BlockSpec program in Python).
On TPU the same code path sets ``interpret=False``.
"""

INTERPRET = True  # flipped to False on real TPU backends by launch/mesh.py


def use_interpret() -> bool:
    import jax

    return jax.default_backend() != "tpu"
