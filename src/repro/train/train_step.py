"""Train-step builders: grads (+microbatch accumulation), clip, optimizer.

The returned step is a pure function (state, batch) → (state, metrics),
jit/pjit-able with the shardings supplied by the launch layer. Microbatch
accumulation is a ``lax.scan`` over leading batch splits — the standard way
to fit the train_4k activation footprint (remat happens inside the model's
layer scan).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.optim import apply_updates, clip_by_global_norm
from repro.optim.base import OptimizerDef


class TrainState(NamedTuple):
    params: Any
    opt: Any
    step: jax.Array


def init_state(params, optimizer: OptimizerDef) -> TrainState:
    return TrainState(params, optimizer.init(params), jnp.zeros((), jnp.int32))


def build_train_step(
    loss_fn: Callable[[Any, Dict], jax.Array],
    optimizer: OptimizerDef,
    num_microbatches: int = 1,
    clip_norm: float = 1.0,
    unroll_microbatches: bool = False,
) -> Callable[[TrainState, Dict], Tuple[TrainState, Dict]]:
    """loss_fn(params, batch) → scalar. Batch leaves have leading dim B,
    split into ``num_microbatches`` equal chunks when > 1.
    ``unroll_microbatches`` replaces the accumulation scan with a python
    loop (cost-probe path: exact HLO cost accounting)."""

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        params = state.params
        if num_microbatches > 1:
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape((num_microbatches, -1) + x.shape[1:]), batch
            )

            def mb_body(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = grads_of(params, mb)
                grad_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), grad_acc, grads
                )
                return (loss_acc + loss, grad_acc), None

            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            carry = (jnp.float32(0.0), zero)
            if unroll_microbatches:
                for i in range(num_microbatches):
                    mb = jax.tree_util.tree_map(lambda x: x[i], mbs)
                    carry, _ = mb_body(carry, mb)
                loss, grads = carry
            else:
                (loss, grads), _ = jax.lax.scan(mb_body, carry, mbs)
            loss = loss / num_microbatches
            grads = jax.tree_util.tree_map(lambda g: g / num_microbatches, grads)
        else:
            loss, grads = grads_of(params, batch)

        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, opt = optimizer.update(grads, state.opt, params)
        params = apply_updates(params, updates)
        new_state = TrainState(params, opt, state.step + 1)
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return step
