"""Sharded online retrieval: multi-device ψ shards + cross-shard top-K merge.

The single-device :class:`repro.serve.engine.RetrievalEngine` serves the
whole k-separable zoo from ONE ψ table — which stops working the moment the
catalogue outgrows one device's HBM. This module is the serving mirror of
the ``mf_dist`` training shard story: the ψ table is ROW-RANGE partitioned
over a device mesh (shard s owns global ids ``[s·rows_per, (s+1)·rows_per)``,
every shard padded to the uniform ``rows_per = ⌈n_items/S⌉`` so one compiled
program serves them all), each shard runs the fused ``kernels/topk_score``
kernel over its local slab — emitting GLOBAL candidate ids via the kernel's
``id_offset``/``n_valid`` meta — and a cross-shard K-way merge
(``kernels.topk_score.topk_merge_shards``) ranks the S·K candidates into
the final (B, k). The merge's two-key sort reproduces the engine's exact
tie-stable ascending-global-id policy, so cluster results are BIT-IDENTICAL
to the single-device engine and the dense ``lax.top_k`` oracle at any shard
count (pinned by tests and the CI bench gate).

Three execution paths over the same shard layout:

  * host loop (default) — one fused-kernel dispatch per shard; with
    ``devices=`` the shards live on distinct devices and jax's async
    dispatch overlaps them (the single-process serving path);
  * :func:`shard_map_topk` — all shards in one ``shard_map`` over a flat
    mesh axis, the per-shard offset derived from ``lax.axis_index`` (the
    pod-scale path; same kernel program, traced offset);
  * per-shard exclude: dense masks are SLICED to the shard's row range, the
    web-scale ``exclude_ids`` form is passed through whole (global ids — a
    shard simply never matches ids outside its range).

ψ-table refresh is versioned and double-buffered (``serve/publish.py``):
``publish`` builds the next shard set off to the side and flips it in with
one atomic reference swap, so an in-flight ``topk`` keeps reading the
snapshot it grabbed and never sees a half-written table.

VMEM footprint: per-shard blocking resolves through
:func:`repro.kernels.vmem.cluster_block_items`, which charges the merge
scratch (S·K candidate score+id rows) on top of the kernel's φ/top-K state
and RAISES :class:`~repro.kernels.vmem.VmemBudgetError` instead of silently
shrinking below one ψ block — re-shard coarser or lower K.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import vmem
from repro.kernels.topk_score.ops import topk_merge_shards, topk_score

_LANE = 128


@dataclasses.dataclass(frozen=True)
class TopKResult:
    """Top-K results plus the degraded-service contract.

    Unpacks like the bare ``(scores, ids)`` tuple every pre-existing call
    site expects (``scores, ids = cluster.topk(...)``), and additionally
    carries:

      * ``coverage`` — fraction of the catalogue's items that were actually
        searched (1.0 on a healthy cluster). A dead, unreplicated shard
        lowers it; results are then exact over the SURVIVING row ranges
        but items in the dead ranges can never appear.
      * ``dead_ranges`` — the global item-id ranges ``(lo, hi)`` that were
        unavailable, coalesced and clipped to ``n_items``. Empty when
        ``coverage == 1.0``.

    The contract: a degraded query COMPLETES (never hangs, never raises at
    the query layer) and says so — it must never return a full-looking
    top-K that silently omits part of the catalogue.
    """

    scores: jax.Array                               # (B, k)
    ids: jax.Array                                  # (B, k)
    coverage: float = 1.0
    dead_ranges: Tuple[Tuple[int, int], ...] = ()

    def __iter__(self):
        # (scores, ids) tuple-compat: `s, i = cluster_topk(...)` still works
        return iter((self.scores, self.ids))

    def __getitem__(self, i):
        # positional tuple-compat: result[0] / result[1]
        return (self.scores, self.ids)[i]

    def __len__(self) -> int:
        return 2

    @property
    def degraded(self) -> bool:
        return self.coverage < 1.0


def dead_item_ranges(
    table: PsiShardSet, dead_shards
) -> Tuple[Tuple[int, int], ...]:
    """Coalesced global item-id ranges owned by ``dead_shards``, clipped to
    the real catalogue (a dead LAST shard's padding rows don't count)."""
    ranges = []
    for s in sorted(set(dead_shards)):
        lo = s * table.rows_per
        hi = min(lo + table.rows_per, table.n_items)
        if hi <= lo:
            continue
        if ranges and ranges[-1][1] == lo:
            ranges[-1] = (ranges[-1][0], hi)
        else:
            ranges.append((lo, hi))
    return tuple(ranges)


def coverage_fraction(table: PsiShardSet, dead_shards) -> float:
    """Fraction of real catalogue rows in surviving shards."""
    if table.n_items == 0:
        return 1.0
    dead = sum(hi - lo for lo, hi in dead_item_ranges(table, dead_shards))
    return 1.0 - dead / table.n_items


def empty_topk(b: int, k: int) -> Tuple[jax.Array, jax.Array]:
    """The no-admissible-candidates result: (−inf, −1) everywhere — what a
    query against zero surviving shards degrades to."""
    return (jnp.full((b, k), -jnp.inf, jnp.float32),
            jnp.full((b, k), -1, jnp.int32))


def colocate_parts(parts: List[jax.Array]) -> List[jax.Array]:
    """Per-shard results are committed to their shard's (or replica's)
    device; ``jnp.stack`` refuses a cross-device concatenate, so the merge
    input must first land on one device. No-op in the single-device case."""
    devs = {getattr(p, "device", None) for p in parts}
    if len(devs) <= 1:
        return parts
    dev = jax.devices()[0]
    return [jax.device_put(p, dev) for p in parts]


def shard_topk(
    table: PsiShardSet,
    s: int,
    phi_rows: jax.Array,
    k: int,
    *,
    slab: Optional[jax.Array] = None,
    exclude_mask: Optional[jax.Array] = None,
    exclude_ids: Optional[jax.Array] = None,
    block_items: int,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """One shard's fused-kernel dispatch: (B, k) candidates with GLOBAL
    ids. ``slab`` overrides the table's own copy of shard ``s`` — the
    replication layer (``serve/mesh.py``) routes the same row range to any
    replica slab through here, so every replica runs the identical program
    the unreplicated cluster does."""
    lo = s * table.rows_per
    shard = table.shards[s] if slab is None else slab
    mask_s = None
    if exclude_mask is not None:
        mask_s = _shard_exclude_mask(exclude_mask, lo, table.rows_per)
    dev = getattr(shard, "device", None)
    phi_s = phi_rows if dev is None else jax.device_put(phi_rows, dev)
    return topk_score(
        phi_s, shard, k, mask_s, exclude_ids=exclude_ids,
        id_offset=lo, n_valid=table.valid_rows(s),
        block_items=block_items, interpret=interpret,
    )


@dataclasses.dataclass(frozen=True)
class PsiShardSet:
    """One immutable, versioned row-range partition of a ψ table.

    ``shards[s]`` is the (rows_per, D) slab owning global item ids
    ``[s·rows_per, (s+1)·rows_per)``; only the LAST shard carries padding
    rows (global id ≥ n_items), which the kernel's ``n_valid`` meta keeps
    inadmissible. ``version`` is the publish counter the serving cache keys
    on (``serve/batcher.py``).
    """

    shards: Tuple[jax.Array, ...]   # S × (rows_per, D)
    n_items: int
    rows_per: int
    version: int = 0

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def d(self) -> int:
        return int(self.shards[0].shape[1])

    @property
    def offsets(self) -> Tuple[int, ...]:
        return tuple(s * self.rows_per for s in range(self.n_shards))

    def valid_rows(self, s: int) -> int:
        """Admissible rows of shard ``s`` (< rows_per only on the last)."""
        return max(0, min(self.rows_per, self.n_items - s * self.rows_per))

    def stacked(self) -> jax.Array:
        """(S, rows_per, D) — the shard_map layout. Shards committed to
        distinct devices cannot be concatenated in place, so this stages
        through host memory once and memoizes on the snapshot (immutable:
        a publish makes a NEW shard set), so serving traffic through the
        shard_map path pays it per published table, not per query."""
        cached = getattr(self, "_stacked_cache", None)
        if cached is None:
            cached = jnp.asarray(np.stack([np.asarray(s) for s in self.shards]))
            object.__setattr__(self, "_stacked_cache", cached)
        return cached


def shard_psi(
    psi_table: jax.Array,
    n_shards: int,
    *,
    devices: Optional[Sequence] = None,
    version: int = 0,
) -> PsiShardSet:
    """Row-range-partition ``psi_table`` into ``n_shards`` uniform slabs.

    ``devices`` (optional) places shard s on ``devices[s % len(devices)]``
    — the multi-device layout; without it all shards share the default
    device (the parity-test / single-host layout)."""
    psi_table = jnp.asarray(psi_table, jnp.float32)
    n_items, _ = psi_table.shape
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    rows_per = -(-n_items // n_shards)
    shards = []
    for s in range(n_shards):
        lo = s * rows_per
        blk = psi_table[lo : lo + rows_per]
        if blk.shape[0] < rows_per:  # last shard: pad to the uniform size
            blk = jnp.pad(blk, ((0, rows_per - blk.shape[0]), (0, 0)))
        if devices is not None:
            blk = jax.device_put(blk, devices[s % len(devices)])
        shards.append(blk)
    return PsiShardSet(
        shards=tuple(shards), n_items=n_items, rows_per=rows_per,
        version=version,
    )


def resolve_cluster_block_items(
    table: PsiShardSet,
    b: int,
    k: int,
    *,
    excl_l: int = 0,
    block_b: int = 128,
) -> int:
    """Per-shard ``block_items`` from the shared VMEM budget, charging the
    S·K merge scratch. Raises :class:`vmem.VmemBudgetError` (never shrinks
    below one ψ block) — see :func:`vmem.cluster_block_items`."""
    d_pad = -(-table.d // _LANE) * _LANE
    k_pad = -(-k // _LANE) * _LANE
    l_pad = -(-max(1, excl_l) // _LANE) * _LANE if excl_l else 0
    block_b = min(block_b, -(-b // 8) * 8)
    return vmem.cluster_block_items(
        block_b, d_pad, k_pad, table.n_shards,
        shard_items=table.rows_per, excl_l_pad=l_pad,
    )


def _shard_exclude_mask(exclude_mask, lo: int, rows_per: int):
    """Slice a dense (B, n_items) mask to one shard's row range, padded to
    the uniform shard size — the ψ-block-aligned sliced form; the slice is
    what crosses to the shard's device, never the full-catalogue row set."""
    blk = exclude_mask[:, lo : lo + rows_per]
    short = rows_per - blk.shape[1]
    if short > 0:
        blk = jnp.pad(jnp.asarray(blk, jnp.int8), ((0, 0), (0, short)))
    return blk


def cluster_topk(
    table: PsiShardSet,
    phi_rows: jax.Array,
    k: int,
    *,
    exclude_mask: Optional[jax.Array] = None,
    exclude_ids: Optional[jax.Array] = None,
    block_items: Optional[int] = None,
    interpret: Optional[bool] = None,
    dead_shards: Sequence[int] = (),
) -> TopKResult:
    """Sharded top-K over one table snapshot: S fused-kernel dispatches +
    the cross-shard merge. Functional core of the cluster — callers that
    need snapshot consistency grab ``table`` ONCE and pass it here.

    ``dead_shards`` is the graceful-degradation hook (the failure detector
    in ``serve/mesh.py`` supplies it): those shards are skipped, the query
    completes over the survivors, and the result reports ``coverage < 1``
    plus the dead global-id ranges instead of hanging or silently serving
    a full-looking top-K."""
    phi_rows = jnp.asarray(phi_rows, jnp.float32)
    b = phi_rows.shape[0]
    if block_items is None:
        excl_l = 0 if exclude_ids is None else int(exclude_ids.shape[1])
        block_items = resolve_cluster_block_items(table, b, k, excl_l=excl_l)
    dead = set(dead_shards)
    parts_s, parts_i = [], []
    for s in range(table.n_shards):
        if s in dead:
            continue
        ss, ii = shard_topk(
            table, s, phi_rows, k, exclude_mask=exclude_mask,
            exclude_ids=exclude_ids, block_items=block_items,
            interpret=interpret,
        )
        parts_s.append(ss)
        parts_i.append(ii)
    coverage = coverage_fraction(table, dead)
    ranges = dead_item_ranges(table, dead)
    if not parts_s:  # every shard dead: complete, loudly empty
        es, ei = empty_topk(b, k)
        return TopKResult(es, ei, coverage, ranges)
    if len(parts_s) == 1:  # nothing to merge; skip the sort
        return TopKResult(parts_s[0], parts_i[0], coverage, ranges)
    ms, mi = topk_merge_shards(
        jnp.stack(colocate_parts(parts_s)),
        jnp.stack(colocate_parts(parts_i)), k,
    )
    return TopKResult(ms, mi, coverage, ranges)


def shard_map_topk(
    mesh,
    table: PsiShardSet,
    phi_rows: jax.Array,
    k: int,
    *,
    exclude_ids: Optional[jax.Array] = None,
    block_items: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> TopKResult:
    """All per-shard kernels in ONE ``shard_map`` over ``mesh``'s flat axis
    (one ψ shard per device; φ and the exclude-id lists replicate), then the
    cross-shard merge on the gathered (S, B, K) candidates.

    The per-shard global-id offset is ``lax.axis_index·rows_per`` — a traced
    scalar through the kernel's meta input, so every shard runs the SAME
    compiled program. Exclusion here is the web-scale ``exclude_ids`` form
    only (a dense mask would have to be resharded; the id list is global and
    shard-agnostic)."""
    if mesh.devices.size != table.n_shards:
        raise ValueError(
            f"mesh has {mesh.devices.size} devices but table has "
            f"{table.n_shards} shards"
        )
    phi_rows = jnp.asarray(phi_rows, jnp.float32)
    if block_items is None:
        excl_l = 0 if exclude_ids is None else int(exclude_ids.shape[1])
        block_items = resolve_cluster_block_items(
            table, phi_rows.shape[0], k, excl_l=excl_l
        )
    fn = _shard_map_program(
        mesh, table.rows_per, table.n_items, k,
        block_items, exclude_ids is not None, interpret,
    )
    args = (table.stacked(), phi_rows)
    if exclude_ids is not None:
        args += (jnp.asarray(exclude_ids, jnp.int32),)
    ss, ii = fn(*args)
    ms, mi = topk_merge_shards(ss, ii, k)
    return TopKResult(ms, mi)


@functools.lru_cache(maxsize=64)
def _shard_map_program(mesh, rows_per, n_items, k, block_items, has_eids,
                       interpret):
    """Build + memoize the jitted shard_map program for one (mesh, table
    geometry, k) — ``jax.jit``'s cache keys on function identity, so a
    per-call closure would retrace and recompile on EVERY query; this
    cache makes repeat queries hit the compiled program."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axis = mesh.axis_names[0]

    def local(psi_blk, phi_rep, *eids):
        off = jax.lax.axis_index(axis).astype(jnp.int32) * rows_per
        nv = jnp.clip(n_items - off, 0, rows_per)
        ss, ii = topk_score(
            phi_rep, psi_blk[0], k,
            exclude_ids=eids[0] if eids else None,
            id_offset=off, n_valid=nv,
            block_items=block_items, interpret=interpret,
        )
        return ss[None], ii[None]

    n_in = 2 + bool(has_eids)
    in_specs = (P(axis),) + (P(),) * (n_in - 1)
    out_specs = (P(axis), P(axis))
    try:
        fn = shard_map(local, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    except TypeError:  # older jax spells it check_rep
        fn = shard_map(local, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
    return jax.jit(fn)


class ShardedRetrievalCluster:
    """Multi-device retrieval service: versioned ψ shards + merge + refresh.

    The sharded counterpart of :class:`repro.serve.engine.RetrievalEngine`::

        cluster = ShardedRetrievalCluster(
            lambda ctx: mf.build_phi(params, ctx), n_shards=4, k=100)
        cluster.publish(mf.export_psi(params))      # version 1 live
        scores, ids = cluster.topk(user_ids)        # == engine, bit-exact
        ...
        cluster.publish(mf.export_psi(new_params))  # version 2; in-flight
                                                    # queries finish on v1

    ``publish`` is double-buffered and versioned (``serve/publish.py``):
    each ``topk`` grabs the active :class:`PsiShardSet` once and serves the
    whole request from that snapshot. ``devices=`` spreads shards across
    devices; ``mesh=`` on the query methods switches to the one-program
    ``shard_map`` path.
    """

    def __init__(
        self,
        phi_fn: Optional[Callable[..., jax.Array]] = None,
        *,
        n_shards: int = 2,
        k: int = 100,
        block_items: Optional[int] = None,
        devices: Optional[Sequence] = None,
        psi_table: Optional[jax.Array] = None,
        retrieval: str = "exact",
        ann=None,                                  # serve.ann.AnnConfig
        registry=None,
    ):
        from repro.obs.costs import KernelCostRecorder
        from repro.obs.metrics import next_instance_id, resolve_registry
        from repro.serve.publish import VersionedTable

        self.phi_fn = phi_fn
        self.n_shards = int(n_shards)
        self.k = int(k)
        self.block_items = block_items
        self.devices = devices
        if retrieval not in ("exact", "ivf"):
            raise ValueError(f"retrieval must be 'exact' or 'ivf', got {retrieval!r}")
        self.retrieval = retrieval
        self.ann = ann
        self._ivf: dict = {}      # table version → per-shard PsiIndex tuple
        self._table = VersionedTable()
        self.registry = resolve_registry(registry)
        self._costs = KernelCostRecorder(self.registry)
        self._m_queries = self.registry.counter(
            "serve_cluster_queries_total", "cluster topk_phi requests",
            labels=("instance",)).labels(instance=next_instance_id())
        if psi_table is not None:
            self.publish(psi_table)

    # ------------------------------------------------------------- publish
    def publish(self, psi_table: jax.Array) -> int:
        """Shard + version a fresh ψ snapshot and flip it live; returns the
        new version. Never disturbs in-flight readers (double buffer)."""
        return self._table.publish(
            lambda version: shard_psi(
                psi_table, self.n_shards, devices=self.devices,
                version=version,
            )
        )

    def publish_delta(self, rows, ids) -> int:
        """Incremental publish: patch/append ψ ``rows`` at global item
        ``ids`` (fold-in output) onto the active table and flip the result
        live under a normal version bump — no model re-export, in-flight
        readers keep their snapshot, and the version key invalidates the
        request cache exactly like a full publish. Appends (ids ≥ n_items)
        grow the catalogue. Returns the new version.

        With ``retrieval='ivf'`` the delta also FOLDS into the live
        per-shard indexes (each changed row re-quantizes in place; each
        appended row joins its nearest cluster) instead of re-running
        k-means per delta; every fold bumps the index staleness counter and
        a shard past ``ann.reindex_after`` rebuilds from the new table
        (``serve.ann.fold_delta_indexes``). A delta that changes the shard
        GEOMETRY (rows_per growth) falls back to lazy full reindex."""
        from repro.serve.publish import apply_delta, dense_table

        old_table = self.table
        old_indexes = self._ivf.get(old_table.version)
        base = dense_table(old_table)
        version = self.publish(jnp.asarray(apply_delta(base, rows, ids)))
        if self.retrieval == "ivf" and old_indexes is not None:
            from repro.serve.ann import fold_delta_indexes

            new_table = self.table
            if (new_table.rows_per == old_table.rows_per
                    and new_table.n_shards == old_table.n_shards):
                self._ivf = {version: fold_delta_indexes(
                    old_indexes, new_table, rows, ids, self._ann_cfg(),
                    registry=self.registry,
                )}
        return version

    def _ann_cfg(self):
        from repro.serve.ann import AnnConfig

        return self.ann or AnnConfig()

    def _ivf_indexes(self, table: PsiShardSet):
        """Per-shard IVF indexes for one table snapshot, built lazily and
        memoized on the publish version (an index is a pure function of
        its snapshot; a publish invalidates implicitly, like the request
        cache). Only the latest version's indexes are retained."""
        cached = self._ivf.get(table.version)
        if cached is None:
            from repro.serve.ann import build_shard_indexes

            cached = build_shard_indexes(table, self._ann_cfg())
            self._ivf = {table.version: cached}
        return cached

    @property
    def table(self) -> PsiShardSet:
        """The active (latest published) shard set."""
        return self._table.active

    @property
    def version(self) -> int:
        return self._table.version

    @property
    def n_items(self) -> int:
        return self.table.n_items

    # -------------------------------------------------------------- query
    def phi(self, *query) -> jax.Array:
        return jnp.asarray(self.phi_fn(*query), jnp.float32)

    def topk(
        self,
        *query,
        k: Optional[int] = None,
        exclude_mask: Optional[jax.Array] = None,
        exclude_ids: Optional[jax.Array] = None,
        mesh=None,
    ) -> TopKResult:
        """(scores, ids) :class:`TopKResult`, both (B, k), for a query
        batch (coverage always 1.0 here — the unreplicated cluster has no
        failure detector; see ``serve/mesh.py`` for the degraded path)."""
        return self.topk_phi(
            self.phi(*query), k=k, exclude_mask=exclude_mask,
            exclude_ids=exclude_ids, mesh=mesh,
        )

    def topk_phi(
        self,
        phi_rows: jax.Array,
        *,
        k: Optional[int] = None,
        exclude_mask: Optional[jax.Array] = None,
        exclude_ids: Optional[jax.Array] = None,
        mesh=None,
    ) -> TopKResult:
        """Like :meth:`topk` from pre-built φ rows (batcher / eval path).

        ``retrieval='ivf'`` routes through the per-shard IVF indexes
        (``serve/ann.py``): each shard prunes to its configured ``n_probe``
        cluster blocks and re-ranks them with the exact fused kernel; the
        cross-shard merge is unchanged. The shard_map path stays exact-only
        (an index is host-driven block dispatch, not a flat-mesh program)."""
        table = self.table  # ONE snapshot: version-consistent whole request
        k = k or self.k
        self._m_queries.inc()
        if mesh is not None:
            if exclude_mask is not None:
                raise ValueError(
                    "the shard_map path takes exclude_ids (global id lists),"
                    " not a dense exclude_mask"
                )
            if self.retrieval == "ivf":
                raise ValueError(
                    "retrieval='ivf' serves through the host-loop path; "
                    "the shard_map path is exact-only"
                )
            return shard_map_topk(
                mesh, table, phi_rows, k, exclude_ids=exclude_ids,
                block_items=self.block_items,
            )
        if self.retrieval == "ivf":
            if exclude_mask is not None:
                raise ValueError(
                    "retrieval='ivf' takes exclude_ids (global id lists), "
                    "not a dense exclude_mask"
                )
            from repro.serve.ann import ivf_cluster_topk

            return ivf_cluster_topk(
                table, self._ivf_indexes(table), phi_rows, k,
                exclude_ids=exclude_ids, registry=self.registry,
            )
        from repro.obs.costs import topk_score_cost

        b = int(jnp.shape(phi_rows)[0])
        excl_l = 0 if exclude_ids is None else int(exclude_ids.shape[1])
        cost = topk_score_cost(b, table.rows_per, int(table.shards[0].shape[1]),
                               k, excl_l=excl_l)
        # one per-shard kernel dispatch each: S× the streams, same tile
        self._costs.record("topk_score", {
            "hbm_bytes": cost["hbm_bytes"] * table.n_shards,
            "flops": cost["flops"] * table.n_shards,
            "vmem_tile_bytes": cost["vmem_tile_bytes"],
        }, calls=table.n_shards)
        return cluster_topk(
            table, phi_rows, k, exclude_mask=exclude_mask,
            exclude_ids=exclude_ids, block_items=self.block_items,
        )
