"""OLMoE 1B-7B [arXiv:2409.02060; hf] — 64 experts, top-8, no shared."""
import dataclasses

from repro.configs.base import LMConfig, MoEConfig, lm_shapes

CONFIG = LMConfig(
    name="olmoe-1b-7b",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,  # per-expert hidden
    vocab=50_304,
    act="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=False,
    moe=MoEConfig(n_experts=64, top_k=8, d_expert=1024),
    num_microbatches=4,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, n_layers=2, d_model=32, n_heads=4, n_kv_heads=4, head_dim=8,
    d_ff=32, vocab=64, num_microbatches=1,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32),
)

SHAPES = lm_shapes(
    long_context_skip=(
        "pure full attention MoE; long_500k is assigned to SSM/hybrid/"
        "linear-attn archs only (DESIGN.md §4)"
    )
)
