"""Per-interaction confidence weights as a first-class training citizen.

Three contracts, each pinned hard:

1. ``weights=None`` is a trace-time branch — the unweighted program is the
   IDENTICAL program, so ``weights=ones`` must be bit-equal to
   ``weights=None`` on every zoo model (flat adapter path AND the fused
   padded paths).
2. α is purely multiplicative in the explicit loss parts, so
   ``weights=w`` must equal training on premultiplied ``alpha·w`` exactly.
3. The weighted epoch is still the paper's Lemma-1/2/3 machinery: weighted
   iCD on the rescaled ``(ȳ, ᾱ·w)`` must track conventional dense CD on the
   equivalent dense objective ``α' = α₀ + ᾱ·w``, ``y' = ȳ·ᾱw/α'`` — the
   same trajectory-level oracle as ``test_icd_exact``, now per-cell
   weighted. Plus: the weighted Gram kernel vs the float64 oracle, and
   weighted closed-form fold-in vs the ``fold_in_exact`` normal-equations
   oracle on all five zoo models.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import foldin, naive_cd
from repro.core.gram import gram, weighted_gram
from repro.core.models import fm, mf, mf_padded
from repro.core.models.zoo import ZOO, zoo_model
from repro.sparse.interactions import build_interactions

jax.config.update("jax_enable_x64", False)


def _interactions(n_ctx, n_items, nnz, alpha0, seed=0):
    rng = np.random.default_rng(seed)
    cells = rng.choice(n_ctx * n_items, size=nnz, replace=False)
    ctx, item = cells // n_items, cells % n_items
    y = rng.integers(1, 5, size=nnz).astype(np.float64)
    alpha = alpha0 + 1.0 + rng.random(nnz)  # α > α₀
    return build_interactions(ctx, item, y, alpha, n_ctx, n_items,
                              alpha0=alpha0)


def _zoo_interactions(name, model, params, seed=0):
    """Interactions in the zoo instance's own (ctx, item) address space:
    mf/mfsi/fm contexts are the 20 rows, parafac/tucker contexts are the
    zoo's 9 (c1, c2) pair rows; items are the 37 catalogue rows."""
    n_ctx = (int(model.dataset.tc.c1.shape[0])
             if name in ("parafac", "tucker") else 20)
    return _interactions(n_ctx, 37, nnz=min(60, n_ctx * 37 // 2),
                         alpha0=float(model.hp.alpha0), seed=seed)


def _rand_weights(nnz, seed=5, lo=0.5, hi=2.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(lo, hi, size=nnz), jnp.float32)


# ------------------------------------------------------------------ zoo ---
@pytest.mark.parametrize("name", ZOO)
def test_zoo_epoch_weighted_exact(name):
    """Every zoo model through the unified adapter: weights=ones bit-equal
    weights=None, and weights=w exactly the premultiplied-α epoch."""
    model, params, _ = zoo_model(name, np.random.default_rng(0))
    data = _zoo_interactions(name, model, params)
    w = _rand_weights(data.nnz)
    data_pre = dataclasses.replace(data, alpha=data.alpha * w)

    def run(d, weights):
        e = model.residuals(params, data=d)  # fresh: epochs may donate e
        return model.epoch(params, e, data=d, weights=weights)

    p_none, e_none = run(data, None)
    p_ones, e_ones = run(data, jnp.ones(data.nnz, jnp.float32))
    p_w, e_w = run(data, w)
    p_pre, e_pre = run(data_pre, None)
    for f in p_none._fields:
        np.testing.assert_array_equal(np.asarray(getattr(p_ones, f)),
                                      np.asarray(getattr(p_none, f)))
        np.testing.assert_array_equal(np.asarray(getattr(p_w, f)),
                                      np.asarray(getattr(p_pre, f)))
    np.testing.assert_array_equal(np.asarray(e_ones), np.asarray(e_none))
    np.testing.assert_array_equal(np.asarray(e_w), np.asarray(e_pre))


# --------------------------------------------------------- padded paths ---
def test_mf_padded_weighted_exact():
    """The fused padded MF epoch (``reweight_padded`` grids): ones≡None
    bit-equal, weights=w ≡ padding the premultiplied interactions."""
    data = _interactions(13, 9, nnz=37, alpha0=0.4, seed=2)
    hp = mf.MFHyperParams(k=5, alpha0=0.4, l2=0.05)
    params = mf.init(jax.random.PRNGKey(1), data.n_ctx, data.n_items, 5)
    w = _rand_weights(data.nnz, seed=6)
    pdata = mf_padded.pad_interactions(data)
    pdata_pre = mf_padded.pad_interactions(
        dataclasses.replace(data, alpha=data.alpha * w))

    def run(pd, weights):
        e_pad = mf_padded.residuals(params, pd)  # fresh: e_pad is donated
        return mf_padded.epoch(params, pd, e_pad, hp, weights)

    p_none, e_none = run(pdata, None)
    p_ones, e_ones = run(pdata, jnp.ones(data.nnz, jnp.float32))
    p_w, e_w = run(pdata, w)
    p_pre, e_pre = run(pdata_pre, None)
    for f in p_none._fields:
        np.testing.assert_array_equal(np.asarray(getattr(p_ones, f)),
                                      np.asarray(getattr(p_none, f)))
        np.testing.assert_array_equal(np.asarray(getattr(p_w, f)),
                                      np.asarray(getattr(p_pre, f)))
    np.testing.assert_array_equal(np.asarray(e_ones), np.asarray(e_none))
    np.testing.assert_array_equal(np.asarray(e_w), np.asarray(e_pre))


def test_fm_padded_weighted_exact():
    """The fused FM epoch (slab-reduce + rank patch): the weighted program
    must keep both exactness contracts on the padded path too."""
    model, params, _ = zoo_model("fm", np.random.default_rng(1))
    x, z, hp = model.dataset.x, model.dataset.z, model.hp
    data = _zoo_interactions("fm", model, params, seed=3)
    w = _rand_weights(data.nnz, seed=7)
    pdata = fm.pad_interactions(data)
    data_pre = dataclasses.replace(data, alpha=data.alpha * w)
    pdata_pre = fm.pad_interactions(data_pre)

    def run(d, pd, weights):
        e_pad = fm.residuals_padded(params, x, z, d, pd, hp)
        return fm.epoch_padded(params, x, z, pd, e_pad, hp, weights)

    p_none, _ = run(data, pdata, None)
    p_ones, _ = run(data, pdata, jnp.ones(data.nnz, jnp.float32))
    p_w, _ = run(data, pdata, w)
    p_pre, _ = run(data_pre, pdata_pre, None)
    for f in p_none._fields:
        np.testing.assert_array_equal(np.asarray(getattr(p_ones, f)),
                                      np.asarray(getattr(p_none, f)))
        np.testing.assert_array_equal(np.asarray(getattr(p_w, f)),
                                      np.asarray(getattr(p_pre, f)))


# ------------------------------------------------------- dense CD oracle ---
@pytest.mark.parametrize("k", [1, 4])
def test_weighted_mf_matches_naive_cd_trajectory(k):
    """Weighted iCD is still exact Newton CD on a dense objective: training
    on ``(ȳ, ᾱ·w)`` must track conventional dense CD with per-cell
    confidence ``α' = α₀ + ᾱ·w`` and target ``y' = ȳ·ᾱw/α'`` (the Lemma-1
    rescaling inverted at the new confidence)."""
    n_ctx, n_items, nnz, alpha0 = 13, 9, 37, 0.4
    rng = np.random.default_rng(4)
    # ctx-major event order up front: build_interactions lexsorts its
    # events, and w must address the SAME interactions on both sides
    cells = np.sort(rng.choice(n_ctx * n_items, size=nnz, replace=False))
    ctx, item = cells // n_items, cells % n_items
    y = rng.integers(1, 5, size=nnz).astype(np.float64)
    alpha = alpha0 + 1.0 + rng.random(nnz)
    w = rng.uniform(0.5, 2.0, size=nnz)

    data = build_interactions(ctx, item, y, alpha, n_ctx, n_items,
                              alpha0=alpha0)
    abar = alpha - alpha0
    ybar = alpha / abar * y
    alpha_p = alpha0 + abar * w
    y_p = ybar * (abar * w) / alpha_p
    y_dense, a_dense = naive_cd.dense_from_observed(
        jnp.asarray(ctx), jnp.asarray(item), jnp.asarray(y_p, jnp.float32),
        jnp.asarray(alpha_p, jnp.float32), n_ctx, n_items, alpha0,
    )

    hp = mf.MFHyperParams(k=k, alpha0=alpha0, l2=0.05, eta=1.0)
    params = mf.init(jax.random.PRNGKey(1), n_ctx, n_items, k)
    params_naive = params
    w_jnp = jnp.asarray(w, jnp.float32)
    e = mf.residuals(params, data)
    for _ in range(3):
        params, e = mf.epoch(params, data, e, hp, None, 0, w_jnp)
        params_naive = naive_cd.epoch_dense(params_naive, y_dense, a_dense, hp)
        np.testing.assert_allclose(params.w, params_naive.w,
                                   rtol=2e-4, atol=2e-5)
        np.testing.assert_allclose(params.h, params_naive.h,
                                   rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------- gram ---
@pytest.mark.parametrize("implementation", ["xla", "pallas"])
def test_weighted_gram_matches_oracle(implementation):
    rng = np.random.default_rng(9)
    m = jnp.asarray(rng.normal(size=(50, 6)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.25, 4.0, size=50), jnp.float32)
    got = gram(m, implementation=implementation, weights=w)
    m64 = np.asarray(m, np.float64)
    expect = m64.T @ (np.asarray(w, np.float64)[:, None] * m64)
    np.testing.assert_allclose(np.asarray(got), expect, rtol=1e-5, atol=1e-6)
    # weights=None / weights absent: the untouched unweighted program
    np.testing.assert_array_equal(
        np.asarray(gram(m, implementation=implementation,
                        weights=jnp.ones(50, jnp.float32))),
        np.asarray(gram(m, implementation=implementation)),
    )


def test_weighted_gram_oracle_consistency():
    rng = np.random.default_rng(10)
    m = jnp.asarray(rng.normal(size=(17, 4)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 2.0, size=17), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(weighted_gram(m, w)),
        np.asarray(gram(m * jnp.sqrt(w)[:, None])), rtol=1e-5, atol=1e-6,
    )


# ------------------------------------------------------------- fold-in ---
@pytest.mark.parametrize("name", ZOO)
def test_weighted_fold_in_user_matches_exact_oracle(name):
    """Non-uniform per-interaction weights through the single-row CD solve
    vs the float64 normal-equations oracle, every zoo model."""
    model, params, _ = zoo_model(name, np.random.default_rng(3))
    rng = np.random.default_rng(29)
    table = np.asarray(model.export_psi(params))
    ids = rng.choice(table.shape[0], size=7, replace=False)
    y = rng.integers(1, 4, ids.size).astype(np.float32)
    alpha = (1.0 + rng.random(ids.size)).astype(np.float32)
    w = rng.uniform(0.25, 4.0, ids.size).astype(np.float32)
    row = model.fold_in_user(params, ids, y, alpha, weights=w,
                             n_sweeps=512, tol=1e-9)
    free, init = model._user_free_init()
    hp = model._foldin_hp()
    exact = foldin.fold_in_exact(
        table, ids, y, alpha, alpha0=hp["alpha0"], l2=hp["l2"],
        weights=w, free=free, init=init,
    )
    np.testing.assert_allclose(row, exact, rtol=2e-4, atol=2e-5)
    # weights=ones reproduces the unweighted solve exactly
    ones = np.ones(ids.size, np.float32)
    np.testing.assert_array_equal(
        model.fold_in_user(params, ids, y, alpha, weights=ones,
                           n_sweeps=512, tol=1e-9),
        model.fold_in_user(params, ids, y, alpha, n_sweeps=512, tol=1e-9),
    )


def test_weighted_fold_in_item_matches_exact_oracle():
    model, params, _ = zoo_model("mf", np.random.default_rng(3))
    rng = np.random.default_rng(31)
    table = np.asarray(model.phi_table(params))
    ids = rng.choice(table.shape[0], size=6, replace=False)
    y = (1.0 + rng.random(ids.size)).astype(np.float32)
    alpha = (1.0 + rng.random(ids.size)).astype(np.float32)
    w = rng.uniform(0.25, 4.0, ids.size).astype(np.float32)
    row = model.fold_in_item(params, ids, y, alpha, weights=w,
                             n_sweeps=512, tol=1e-9)
    free, init = model._item_free_init()
    hp = model._foldin_hp()
    exact = foldin.fold_in_exact(
        table, ids, y, alpha, alpha0=hp["alpha0"], l2=hp["l2"],
        weights=w, free=free, init=init,
    )
    np.testing.assert_allclose(row, exact, rtol=2e-4, atol=2e-5)


def test_weighted_fold_in_row_is_premultiplied_alpha():
    """``weights`` multiplies α before the solve — bit-identical to handing
    the premultiplied confidences in directly."""
    rng = np.random.default_rng(12)
    table = rng.normal(size=(15, 5)).astype(np.float32)
    ids = [1, 4, 9, 11]
    y = rng.random(4).astype(np.float32)
    alpha = (1.0 + rng.random(4)).astype(np.float32)
    w = rng.uniform(0.5, 2.0, 4).astype(np.float32)
    a = foldin.fold_in_row(table, ids, y, alpha, weights=w,
                           alpha0=0.3, l2=0.05)
    b = foldin.fold_in_row(table, ids, y, alpha * w, alpha0=0.3, l2=0.05)
    np.testing.assert_array_equal(a.row, b.row)
