"""Shared inline smoke-scale configs for the model-zoo tests.

The seed-template registry configs were removed in PR 4; these reduced
same-family configs (built from the shared ``configs.base`` dataclasses)
are the single source the smoke/property/serve suites import, so "the
gemma smoke config" cannot silently desynchronize across files. "gemma"
in a name keeps the Gemma-specific forward branches (embed scaling,
softcaps) exercised.
"""
from repro.configs.base import GNNConfig, LMConfig, MoEConfig, RecsysConfig

LM_SMOKE = {
    "gemma2-smoke": LMConfig(
        name="gemma2-smoke", n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab=256, act="geglu", attn_window=8,
        local_global_alternating=True, attn_softcap=50.0, final_softcap=30.0,
        post_norms=True, tie_embeddings=True,
    ),
    "qwen-smoke": LMConfig(
        name="qwen-smoke", n_layers=3, d_model=48, n_heads=4, n_kv_heads=4,
        head_dim=12, d_ff=96, vocab=128, qkv_bias=True,
        rope_theta=1_000_000.0, tie_embeddings=False,
    ),
    "gqa-smoke": LMConfig(
        name="gqa-smoke", n_layers=3, d_model=64, n_heads=8, n_kv_heads=2,
        head_dim=8, d_ff=160, vocab=128, tie_embeddings=False,
    ),
    "moe-smoke": LMConfig(
        name="moe-smoke", n_layers=2, d_model=32, n_heads=4, n_kv_heads=4,
        head_dim=8, d_ff=32, vocab=64, tie_embeddings=False,
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=32),
    ),
    "moe-shared-smoke": LMConfig(
        name="moe-shared-smoke", n_layers=3, d_model=32, n_heads=4,
        n_kv_heads=4, head_dim=8, d_ff=24, vocab=64, tie_embeddings=False,
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=24, n_shared=1,
                      first_k_dense=1, d_ff_dense=64),
    ),
}

GEMMA_SMOKE = LM_SMOKE["gemma2-smoke"]
QWEN_SMOKE = LM_SMOKE["qwen-smoke"]
GQA_SMOKE = LM_SMOKE["gqa-smoke"]

RECSYS_SMOKE = {
    "dlrm": RecsysConfig(
        name="dlrm-smoke", kind="dlrm", n_dense=13, n_sparse=26, embed_dim=8,
        table_vocabs=tuple([50] * 8 + [10] * 18), bot_mlp=(16, 8),
        top_mlp=(16, 8, 1),
    ),
    "dcn": RecsysConfig(
        name="dcn-smoke", kind="dcn", n_dense=13, n_sparse=26, embed_dim=4,
        table_vocabs=tuple([40] * 4 + [12] * 22), n_cross_layers=2,
        mlp=(32, 16),
    ),
    "din": RecsysConfig(
        name="din-smoke", kind="din", embed_dim=6, seq_len=12,
        attn_mlp=(16, 8), mlp=(24, 12), item_vocab=200,
    ),
    "bst": RecsysConfig(
        name="bst-smoke", kind="bst", embed_dim=16, seq_len=6, n_blocks=1,
        n_heads=4, mlp=(32, 16), item_vocab=100,
    ),
}

GNN_SMOKE = GNNConfig(
    name="graphsage-smoke", n_layers=2, d_hidden=16, aggregator="mean",
    sample_sizes=(4, 3), n_classes=5,
)
