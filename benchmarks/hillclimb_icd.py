"""Hillclimb #1 — icd-mf × epoch_web (the paper-representative cell).

Baseline (GSPMD auto-sharded mf.epoch, from results/dryrun):
    collective-dominant, coll 1.42 s, memory 1.22 s, compute 1.8 ms.

Iterations (hypothesis → change → measure; see EXPERIMENTS.md §Perf):
  1 'gather'       owner-computes shard_map layout: the only collectives are
                   2 k² Gram psums + k column all-gathers + 2 nnz routings.
                   Napkin: k·(C+I)·4B ≈ 5.6 GB/device → ~0.11 s (13×).
  2 'route'        per-nnz value routing replaces column all-gathers:
                   k·(nnz/D)·4B ≈ 2·128·7.8 MB ≈ 2.0 GB → ~0.04 s (2.8×).
  3 'route'+bf16   wire dtype bf16 for routed ψ/φ values → ~0.02 s (2×),
                   Newton math stays fp32 (accuracy checked in
                   tests/test_mf_dist.py).
  4 'fused-sweep'  (projection) the kernels/cd_sweep block kernel keeps α/e
                   VMEM-resident over k_b=8 columns, so local sweep HBM
                   traffic drops from 4k to k+3·k/k_b (C|nnz)-trips per
                   side — ~2.9× less memory time; measured kernel-level in
                   BENCH_cd_sweep.json (benchmarks/roofline_bench.py).

Run:  PYTHONPATH=src:. python -m benchmarks.hillclimb_icd
(sets the forced host device count; run as its own process)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=256")

import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.models import mf, mf_dist  # noqa: E402
from repro.launch import hlo_analysis  # noqa: E402

D = 256
C, I, NNZ, K = 10_000_000, 1_000_000, 500_000_000, 128


def abstract_sharded(d=D):
    c_per = -(-C // d)
    i_per = -(-I // d)
    p_c = p_i = -(-NNZ // d)
    blk = -(-NNZ // (d * d))
    sds = jax.ShapeDtypeStruct
    return mf_dist.ShardedMF(
        ctx_l=sds((d, p_c), jnp.int32), item_g=sds((d, p_c), jnp.int32),
        y_c=sds((d, p_c), jnp.float32), alpha_c=sds((d, p_c), jnp.float32),
        item_l=sds((d, p_i), jnp.int32), ctx_g=sds((d, p_i), jnp.int32),
        y_i=sds((d, p_i), jnp.float32), alpha_i=sds((d, p_i), jnp.float32),
        send_idx=sds((d, d, blk), jnp.int32),
        recv_pos=sds((d, d, blk), jnp.int32),
        c_per=c_per, i_per=i_per, n_shards=d,
    )


def _components(variant, wire_dtype, k_probe) -> "np.ndarray":
    import numpy as np

    mesh = mf_dist.make_shard_mesh(D)
    sd = abstract_sharded()
    hp = mf.MFHyperParams(k=k_probe, alpha0=1.0, l2=0.1)
    epoch = mf_dist.build_epoch(mesh, hp, sd, variant=variant,
                                wire_dtype=wire_dtype)
    sds = jax.ShapeDtypeStruct
    w = sds((D, sd.c_per, k_probe), jnp.float32)
    h = sds((D, sd.i_per, k_probe), jnp.float32)
    e = sds((D, sd.ctx_l.shape[1]), jnp.float32)
    compiled = epoch.lower(w, h, sd, e).compile()
    ca = compiled.cost_analysis() or {}
    cb = hlo_analysis.collective_bytes(compiled.as_text())
    cb.pop("_counts")
    return np.array([float(ca.get("flops", 0)),
                     float(ca.get("bytes accessed", 0)),
                     sum(cb.values())])


def measure(variant: str, wire_dtype) -> dict:
    """Compile at k ∈ {4,8,16} (unrolled columns) and fit cost(k) =
    a + b·k + c·k² per component — exact for this program family (identical
    per-column bodies + k² Grams); evaluate at k=128. The full-k compile is
    only a compile-TIME problem, not a correctness one (the k=128 epoch is
    jit-compiled fine at runtime with hp.unroll=False)."""
    import numpy as np

    t0 = time.time()
    ks = np.array([4, 8, 16], float)
    vals = np.stack([_components(variant, wire_dtype, int(k)) for k in ks])
    vander = np.stack([np.ones_like(ks), ks, ks * ks], axis=1)
    coef = np.linalg.solve(vander, vals)      # (3 coeffs, 3 components)
    full = np.maximum(coef.T @ np.array([1.0, K, K * K]), 0.0)
    flops, bytes_, coll = full.tolist()
    return {
        "variant": f"{variant}+{wire_dtype.__name__}",
        "compile_s": round(time.time() - t0, 1),
        "flops_per_device": flops,
        "bytes_per_device": bytes_,
        "collective_bytes_per_device": coll,
        "compute_s": flops / hlo_analysis.PEAK_FLOPS,
        "memory_s": bytes_ / hlo_analysis.HBM_BW,
        "collective_s": coll / hlo_analysis.LINK_BW,
    }


def _tpu_true_route_correction(route_row: dict, gather_row: dict, wire_bytes: int):
    """XLA's CPU SPMD lowers lax.all_to_all into per-peer select chains —
    a TPU executes it natively on ICI. The measured route-variant bytes and
    flops are therefore inflated by the decomposition (thousands of
    (D, blk)-sized selects/compares that do not exist on TPU), and the
    collective parser sees only slice shapes. Correction (documented in
    EXPERIMENTS.md §Perf #1):
      collective := (2k + 2) × per-device a2a buffer (analytic wire count)
      memory     := gather variant's memory (upper bound: route does
                    strictly LESS local work — nnz-sized routing instead of
                    (C|I)-sized column gathers)
      compute    := gather variant's compute (identical Newton math)."""
    n_a2a = 2 * K + 2
    buf_f32 = D * (-(-NNZ // (D * D))) * 4
    coll = (2 * K) * wire_bytes + 2 * buf_f32  # e-routing stays f32
    route_row = dict(route_row)
    route_row["collective_bytes_per_device"] = coll
    route_row["collective_s"] = coll / hlo_analysis.LINK_BW
    route_row["memory_s"] = gather_row["memory_s"]
    route_row["bytes_per_device"] = gather_row["bytes_per_device"]
    route_row["compute_s"] = gather_row["compute_s"]
    route_row["flops_per_device"] = gather_row["flops_per_device"]
    route_row["tpu_true_corrected"] = (
        f"a2a wire = {n_a2a} ops × buffer; CPU select-chain artifact removed"
    )
    return route_row


def fused_sweep_projection(base_row: dict, k_b: int = 8) -> dict:
    """Iteration 4 (analytic): apply the cd_sweep traffic model to this
    cell's per-device SWEEP bytes only. The local column update streams
    ψ, α, e (+ e writeback) per column — 4k nnz-sized trips per side; the
    fused block kernel amortizes α/e over k_b columns → k + 3·⌈k/k_b⌉
    trips. Gram/gather/routing bytes and collectives are untouched, so
    only the sweep share of memory_s shrinks. Kernel-level parity +
    measured numbers: BENCH_cd_sweep.json."""
    nnz_per = -(-NNZ // D)
    sweep_bytes = 2 * 4.0 * K * nnz_per * 4.0       # both sides, 4 trips/col
    sweep_bytes = min(sweep_bytes, base_row["bytes_per_device"])
    scale = (K + 3.0 * (-(-K // k_b))) / (4.0 * K)
    saved = sweep_bytes * (1.0 - scale)
    row = dict(base_row)
    row["variant"] = base_row["variant"].replace("route", "route+fused-sweep")
    row["bytes_per_device"] = base_row["bytes_per_device"] - saved
    row["memory_s"] = row["bytes_per_device"] / hlo_analysis.HBM_BW
    row["fused_sweep"] = (
        f"analytic: sweep (C|nnz)-trips 4k -> k + 3*ceil(k/{k_b}) "
        f"(x{1 / scale:.2f} less sweep traffic, applied to the sweep share "
        f"{sweep_bytes:.3g} B only); see BENCH_cd_sweep.json"
    )
    return row


def main():
    results = {"cell": "icd-mf × epoch_web", "mesh": "256 chips (flat)",
               "baseline": "see results/dryrun/icd-mf__epoch_web__sp.json"}
    try:
        base = json.load(open("results/dryrun/icd-mf__epoch_web__sp.json"))
        results["baseline_roofline"] = base["roofline"]
    except FileNotFoundError:
        pass
    results["iterations"] = []
    buf_f32 = D * (-(-NNZ // (D * D))) * 4
    for variant, wire in (("gather", jnp.float32), ("route", jnp.float32),
                          ("route", jnp.bfloat16)):
        r = measure(variant, wire)
        if variant == "route":
            wire_bytes = buf_f32 // (2 if wire == jnp.bfloat16 else 1)
            r = _tpu_true_route_correction(r, results["iterations"][0],
                                           wire_bytes)
        results["iterations"].append(r)
        print(f"{r['variant']}: compute={r['compute_s']:.3e}s "
              f"memory={r['memory_s']:.3e}s coll={r['collective_s']:.3e}s "
              f"(compile {r['compile_s']}s)", flush=True)
    r = fused_sweep_projection(results["iterations"][-1])
    results["iterations"].append(r)
    print(f"{r['variant']}: memory={r['memory_s']:.3e}s (projection)",
          flush=True)
    os.makedirs("results/perf", exist_ok=True)
    with open("results/perf/hillclimb_icd.json", "w") as f:
        json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
