"""iALS baseline — Hu, Koren, Volinsky [5], vector-wise ALS for implicit MF.

Where iCD updates one coordinate at a time (k scalar Newton steps per
embedding), iALS solves each k-vector in closed form:

    w_c = (α₀ HᵀH + Σ_{i∈S_c} ᾱ_ci h_i h_iᵀ + λI)⁻¹ (Σ_{i∈S_c} ᾱ_ci ȳ_ci h_i)

using the same Lemma-1 "α₀·Gram + sparse correction" structure (Hu et al.'s
original trick, which Lemma 1/2 generalize). Included because the paper
positions iCD against CD/ALS-family solvers [5,10,23]; both must converge to
comparable optima on MF problems (see tests/test_baselines.py).

Vectorized: per-observation outer products ᾱ h hᵀ are segment-summed into
per-context (k,k) systems and solved batched. Memory O(|C|k² + nnz·k²-free)
— we build (nnz,k,k) lazily per epoch chunk if needed; fine at test scale.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.gram import gram
from repro.core.models.mf import MFParams
from repro.sparse.interactions import Interactions
from repro.sparse.segment import segment_sum


@dataclasses.dataclass(frozen=True)
class IALSHyperParams:
    k: int
    alpha0: float = 1.0
    l2: float = 0.1


def _solve_side(
    other: jax.Array,       # (m, k) fixed factors
    rows: jax.Array,        # (nnz,) this side's row per observation
    cols: jax.Array,        # (nnz,) other side's row per observation
    y: jax.Array,
    alpha: jax.Array,
    n_rows: int,
    hp: IALSHyperParams,
) -> jax.Array:
    k = other.shape[1]
    h_nnz = jnp.take(other, cols, axis=0)                      # (nnz, k)
    outer = h_nnz[:, :, None] * h_nnz[:, None, :]              # (nnz, k, k)
    a_sys = segment_sum(alpha[:, None, None] * outer, rows, n_rows)
    a_sys = a_sys + hp.alpha0 * gram(other)[None] + hp.l2 * jnp.eye(k)[None]
    rhs = segment_sum((alpha * y)[:, None] * h_nnz, rows, n_rows)
    return jnp.linalg.solve(a_sys, rhs[..., None])[..., 0]


@partial(jax.jit, static_argnames=("hp",))
def epoch(params: MFParams, data: Interactions, hp: IALSHyperParams) -> MFParams:
    w = _solve_side(
        params.h, data.ctx, data.item, data.y, data.alpha, data.n_ctx, hp
    )
    y_t = jnp.take(data.y, data.t_perm)
    a_t = jnp.take(data.alpha, data.t_perm)
    h = _solve_side(w, data.t_item, data.t_ctx, y_t, a_t, data.n_items, hp)
    return MFParams(w, h)


def fit(params: MFParams, data: Interactions, hp: IALSHyperParams, n_epochs: int) -> MFParams:
    for _ in range(n_epochs):
        params = epoch(params, data, hp)
    return params
