"""Core iCD library — the paper's contribution as composable JAX modules.

- ``gram``        — Lemma 2 Gram machinery (incl. sharded all-reduce form)
- ``implicit``    — Lemma 1 rescaling + implicit regularizer/objective
- ``sweeps``      — vectorized Newton column-sweep building blocks
- ``models``      — MF / MFSI / FM / PARAFAC / Tucker iCD (paper §5)
- ``naive_cd``    — conventional dense-CD oracle (§3.2 strawman, Fig. 8)
- ``bpr``         — BPR-SGD baseline (the paper's main competitor)
- ``ials``        — iALS vector-wise ALS baseline (Hu et al. [5])
- ``metrics``     — Recall@K / NDCG@K evaluation (paper §6)
"""

from repro.core import gram, implicit, sweeps  # noqa: F401
