"""Serving: batched retrieval requests against an iCD-MF model — the
paper-native separable path (one matvec per request, paper §5.1) plus the
chunked top-k reducer used by the retrieval_cand dry-run cell.

    PYTHONPATH=src python examples/serve_retrieval.py
"""
import time

import jax
import numpy as np

from repro.core.models import mf
from repro.serve.recsys_serve import mf_retrieval_score_fn, retrieval_topk


def main():
    n_users, n_items, k = 1000, 50_000, 64
    params = mf.init(jax.random.PRNGKey(0), n_users, n_items, k)

    @jax.jit
    def score_batch(user_vecs, items):
        return user_vecs @ items.T  # (B, n_items) — k-separable retrieval

    # batched online requests
    for batch in (8, 64):
        u = params.w[:batch]
        score_batch(u, params.h).block_until_ready()
        t0 = time.perf_counter()
        s = score_batch(u, params.h)
        top = jax.lax.top_k(s, 100)[1]
        top.block_until_ready()
        dt = time.perf_counter() - t0
        print(f"batch={batch:3d}: {dt * 1e3:7.2f} ms "
              f"({batch * n_items / dt / 1e6:.1f} M cand/s)")

    # chunked reducer (memory-bounded scoring of huge candidate sets)
    score = mf_retrieval_score_fn(params.w[0], params.h)
    scores, ids = retrieval_topk(score, n_items, k=100, chunk=8192)
    full = np.asarray(params.h @ params.w[0])
    assert set(np.asarray(ids).tolist()) == set(np.argsort(-full)[:100].tolist())
    print("chunked top-k == exact top-k ✓")


if __name__ == "__main__":
    main()
