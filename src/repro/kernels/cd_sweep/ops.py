"""Jit'd public wrapper for the fused multi-column CD block-sweep.

``e`` is donated: the residual cache is the largest carried tensor in the
sweep and is consumed/replaced on every dispatch, so an eager caller's
buffer is reused in place on backends that support donation. Inside an
outer jit (the ``mf_padded.epoch`` path) nested-jit donation is inert —
there the in-place update comes from the kernel's e→e_out
``input_output_aliases`` and from ``epoch`` donating ``e_pad`` at the top
level.
"""
from repro.kernels import kernel_jit
from repro.kernels.cd_sweep.kernel import cd_block_sweep_pallas


@kernel_jit(static_argnames=("alpha0", "l2", "eta", "block_ctx"),
            donate_argnums=(2,))
def cd_block_sweep(psi_blk, alpha, e, w_blk, r1_blk, j_blk, *, alpha0, l2,
                   eta=1.0, block_ctx=128, interpret=None):
    return cd_block_sweep_pallas(
        psi_blk, alpha, e, w_blk, r1_blk, j_blk,
        alpha0=alpha0, l2=l2, eta=eta, block_ctx=block_ctx,
        interpret=interpret,
    )
