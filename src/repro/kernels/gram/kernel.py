"""Pallas gram kernel: J = XᵀX (optionally Xᵀ·diag(w)·X) for tall-skinny X.

Grid: 1-D over row blocks. Each step DMAs a (block_rows, k_pad) tile
HBM→VMEM, runs one (k_pad × block_rows)·(block_rows × k_pad) MXU matmul, and
accumulates into the persistent (k_pad, k_pad) output block (same output
tile revisited every step ⇒ VMEM-resident accumulator).

The weighted variant carries a (block_rows, 1) per-row weight tile and
scales one matmul operand in VMEM before the contraction — the weighted
Gram J_w = Σ_r w_r·x_r x_rᵀ used by confidence-weighted fold-in and the
weighted implicit regularizer.

VMEM budget per step: block_rows·k_pad·4 B (input tile, fp32)
                    + block_rows·128·4 B  (weight tile, weighted path only)
                    + k_pad²·4 B          (accumulator).
Defaults (block_rows=1024, k_pad≤512): ≤ 2 MiB + 1 MiB ≪ 16 MiB VMEM.
MXU alignment: k padded to a lane multiple (128); rows padded to the block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(x_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    o_ref[...] += jax.lax.dot_general(
        x, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def _gram_weighted_kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    wx = x * w_ref[:, 0:1].astype(jnp.float32)  # (block_rows, 1) broadcast
    o_ref[...] += jax.lax.dot_general(
        x, wx, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def gram_pallas(
    x: jax.Array,
    w: jax.Array | None = None,
    *,
    block_rows: int = 1024,
    interpret: bool = True,
) -> jax.Array:
    """J = xᵀx (or xᵀ·diag(w)·x) with fp32 accumulation; x: (rows, k) any
    float dtype, w: optional (rows,) per-row weights (row padding gets w=0,
    which zeroes padded contributions exactly)."""
    rows, k = x.shape
    k_pad = max(128, -(-k // 128) * 128)
    rows_pad = -(-rows // block_rows) * block_rows
    if (rows_pad, k_pad) != (rows, k):
        x = jnp.pad(x, ((0, rows_pad - rows), (0, k_pad - k)))

    if w is None:
        out = pl.pallas_call(
            _gram_kernel,
            grid=(rows_pad // block_rows,),
            in_specs=[pl.BlockSpec((block_rows, k_pad), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((k_pad, k_pad), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((k_pad, k_pad), jnp.float32),
            interpret=interpret,
        )(x)
        return out[:k, :k]

    # weight column lane-padded to 128 (lane alignment; kernel reads col 0)
    w2 = jnp.pad(w.reshape(rows, 1), ((0, rows_pad - rows), (0, 127)))
    out = pl.pallas_call(
        _gram_weighted_kernel,
        grid=(rows_pad // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, k_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 128), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((k_pad, k_pad), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k_pad, k_pad), jnp.float32),
        interpret=interpret,
    )(x, w2)
    return out[:k, :k]
