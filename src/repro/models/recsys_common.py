"""Shared recsys machinery: stacked embedding tables + lookup paths.

Tables are stacked into one (total_rows, dim) matrix with per-feature
offsets so the whole embedding state is a single row-shardable array
(`P("model", None)` on pods). Lookup = jnp.take (+ segment-sum for bags);
the Pallas ``embedding_bag`` kernel covers the dense-formulation hot path
for small/mid vocab fields.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np



def init_tables(key, vocabs: Sequence[int], dim: int) -> jax.Array:
    """Stacked embedding table (Σvocab, dim)."""
    total = int(sum(vocabs))
    return 0.01 * jax.random.normal(key, (total, dim), jnp.float32)


def table_offsets(vocabs: Sequence[int]) -> jax.Array:
    """Row offset per feature in the stacked table — config-derived constant
    (NOT a parameter: int arrays must stay out of the grad tree)."""
    return jnp.asarray(np.concatenate([[0], np.cumsum(vocabs)[:-1]]), jnp.int32)


def lookup(table: jax.Array, offsets: jax.Array, ids: jax.Array) -> jax.Array:
    """ids (B, n_features) local per-feature ids → (B, n_features, dim)."""
    return jnp.take(table, ids + offsets[None, :], axis=0)


def binary_ce(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean(
        jax.nn.softplus(-logits) * labels + jax.nn.softplus(logits) * (1 - labels)
    )
