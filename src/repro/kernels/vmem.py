"""Shared VMEM-budget blocking policy for the Pallas kernel wrappers.

Every kernel in this package streams `(rows, lanes)` tiles through VMEM
(~16 MiB/core); the row-tile size is the knob that trades grid steps
against VMEM pressure. Before this module each call site carried its own
constant (``mf_padded._SWEEP_BLOCK_CTX = 128``, ``block_ctx=128`` defaults
in the cd_sweep ops, ...). Now there is ONE declared budget and one
fitting rule; the per-kernel helpers below encode each kernel's bytes/row
so wrappers can resolve ``block_ctx``/``block_items`` from the actual tile
shapes at trace time (shapes are static under jit, so the choice bakes
into the compiled program).

The ``k_b`` (columns per fused cd_sweep dispatch) side of the trade lives
in ``core.sweeps.resolve_block_k``: its auto policy ``min(k, 8)`` is the
bandwidth knee of the analytic model in ``benchmarks/roofline_bench`` —
beyond k_b≈8 the amortized α/e traffic saving flattens while the Ψ tile's
VMEM (and HBM capacity) cost keeps growing linearly, so the budget here
only has to fit the row tile given that k_b.

Two cd_sweep footprint models coexist:

  * pre-gathered (:func:`cd_sweep_block_ctx`) — the caller materializes a
    `(C, k_b, D_pad)` Ψ tile, so the Ψ cost is PER ROW;
  * in-kernel gather (:func:`cd_sweep_gather_block_ctx`) — the kernel holds
    the whole `(n_src, m)` ψ slab resident and gathers rows through an id
    grid, so the ψ cost is FIXED and per-row cost drops to the id/α/e
    streams (plus, for the slab-reduce variant, the gathered tile itself).

A tile request whose ``fixed_bytes`` alone busts the budget raises
:class:`VmemBudgetError` instead of silently returning the ``lo`` floor
(which used to overflow VMEM); callers with a shrinkable fixed dimension
catch it and shrink (``topk_score`` halves ``block_b``; the cd_sweep model
dispatch falls back to the pre-gathered path).
"""
from __future__ import annotations

VMEM_BYTES = 16 * 1024 * 1024
# Working budget: half the core's VMEM, leaving headroom for the pipeline's
# double buffering and the compiler's own temporaries.
VMEM_BUDGET_BYTES = VMEM_BYTES // 2


class VmemBudgetError(ValueError):
    """The requested tile cannot fit the VMEM budget at any row count."""


def fit_block_rows(
    per_row_bytes: int,
    *,
    fixed_bytes: int = 0,
    n_rows: int | None = None,
    budget: int | None = None,
    multiple: int = 8,
    lo: int = 8,
    hi: int = 2048,
    overflow: str = "raise",
) -> int:
    """Largest row-tile (multiple of ``multiple``, in [lo, hi]) whose VMEM
    footprint ``fixed_bytes + rows·per_row_bytes`` fits the budget.

    ``n_rows`` (when known) caps the tile at the padded problem size so a
    small problem is one grid step instead of being padded up to a huge
    tile. ``budget`` defaults to :data:`VMEM_BUDGET_BYTES` (resolved at
    call time so tests can shrink it).

    When even the minimal ``lo``-row tile overflows the budget (e.g.
    ``fixed_bytes`` alone exceeds it), ``overflow='raise'`` (default)
    raises :class:`VmemBudgetError` — callers must shrink their fixed
    dimension or dispatch another kernel variant rather than silently
    overflow VMEM. ``overflow='floor'`` returns the ``lo`` floor instead:
    the escape hatch for a LAST-RESORT fit with no fixed dimension left to
    shrink (the budget is a soft target there — interpret mode runs fine,
    and a compiled caller is expected to lower k_b / re-bucket degrees).
    """
    if budget is None:
        budget = VMEM_BUDGET_BYTES
    if fixed_bytes + lo * per_row_bytes > budget and overflow == "raise":
        raise VmemBudgetError(
            f"minimal {lo}-row tile does not fit VMEM budget: "
            f"fixed_bytes={fixed_bytes} + {lo} rows * {per_row_bytes} B/row "
            f"= {fixed_bytes + lo * per_row_bytes} > budget={budget}"
        )
    rows = max(lo, (budget - fixed_bytes) // max(1, per_row_bytes))
    rows = min(rows, hi)
    if n_rows is not None:
        rows = min(rows, -(-n_rows // multiple) * multiple)
    return max(lo, (rows // multiple) * multiple)


def cd_sweep_block_ctx(d_pad: int, k_b: int, *, n_rows: int | None = None) -> int:
    """Row tile for the PRE-GATHERED ``cd_sweep`` kernel family.

    Per row the block kernels hold the Ψ tile (k_b, d_pad), α and e
    (d_pad each, plus the aliased e output) and the small (k_b,) slabs in
    VMEM — ≈ (k_b + 3)·d_pad·4 B/row (the rowpatch variant adds k_b²·4,
    folded into the same bound).

    This is the dispatch of last resort (the gather variant falls back
    HERE), so it floors at the minimal ``lo``-row tile instead of raising
    when a pathological ``d_pad`` (one enormous context degree) busts the
    soft budget — matching the pre-PR-4 behavior; such data should be
    degree-bucketed before padding."""
    per_row = 4 * ((k_b + 3) * d_pad + k_b * k_b + 4 * k_b)
    return fit_block_rows(per_row, n_rows=n_rows, overflow="floor")


def cd_sweep_gather_block_ctx(
    d_pad: int,
    m: int,
    n_src: int,
    *,
    n_rows: int | None = None,
    hold_tile: bool = False,
) -> int:
    """Row tile for the IN-KERNEL-GATHER ``cd_sweep`` variants.

    The whole `(n_src, m)` ψ slab is VMEM-resident per dispatch — a FIXED
    cost — and the per-row cost is the id grid (int32 d_pad), α, e (plus
    the aliased e output) and a one-column gather temporary:
    ≈ 5·d_pad·4 B/row. ``hold_tile=True`` models the slab-reduce variant,
    which gathers the full `(m, d_pad)` tile per row before its einsums —
    ≈ (m + 4)·d_pad·4 B/row (same per-row bound as pre-gathered, but the
    `(C, m, D_pad)` HBM intermediate is gone).

    Raises :class:`VmemBudgetError` when the ψ slab alone busts the budget
    (huge catalogues) — callers fall back to the pre-gathered dispatch."""
    fixed = 4 * n_src * m
    if hold_tile:
        per_row = 4 * ((m + 4) * d_pad + m * m + 4 * m)
    else:
        per_row = 4 * (5 * d_pad + m * m + 4 * m)
    return fit_block_rows(per_row, fixed_bytes=fixed, n_rows=n_rows)


def resolve_cd_sweep_dispatch(
    d_pad: int,
    m: int,
    n_src: int,
    *,
    n_rows: int | None = None,
    hold_tile: bool = False,
    prefer_gather: bool = True,
    interpret: bool | None = None,
) -> tuple[bool, int]:
    """Pick the cd_sweep dispatch for one fused sweep: ``(use_gather,
    block_ctx)``.

    Gather is preferred (no `(C, m, D_pad)` HBM intermediate); the
    pre-gathered tile is the fallback when the ψ slab alone busts the VMEM
    budget, when the caller pinned ``psi_dispatch='pregather'``, or when
    the kernels COMPILE for real (``interpret=None`` resolves via
    ``repro.kernels.use_interpret()``): the gather kernels' value-level
    ``jnp.take`` is interpret-safe only — the Mosaic/``pltpu``-DMA lowering
    is the ROADMAP follow-up, so a compiled backend must not default onto a
    path that cannot lower."""
    if interpret is None:
        from repro.kernels import use_interpret

        interpret = use_interpret()
    if prefer_gather and interpret:
        try:
            return True, cd_sweep_gather_block_ctx(
                d_pad, m, n_src, n_rows=n_rows, hold_tile=hold_tile
            )
        except VmemBudgetError:
            pass
    return False, cd_sweep_block_ctx(d_pad, m, n_rows=n_rows)


def topk_block_items(
    block_b: int,
    d_pad: int,
    k_pad: int,
    *,
    n_items: int | None = None,
    excl_l_pad: int = 0,
    psi_bytes: int = 4,
    per_row_scale: bool = False,
) -> int:
    """ψ-table row tile for the ``topk_score`` kernel.

    Per ψ row: the STORED ψ tile lane (``d_pad·psi_bytes`` — 4 for fp32,
    2 for bf16, 1 for int8 serving storage) plus this row's column in the
    (block_b, block_items) score tile and the concat/merge temporaries
    (≈3 score-tile copies: scores + concatenated scores/ids). Fixed: the
    resident φ tile and the running top-k_pad score/id blocks.

    ``psi_bytes < 4`` models the quantized-ψ variants: the kernel holds the
    narrow stored tile AND its in-VMEM fp32 dequantization (``+4·d_pad``
    per row, plus the f32 per-row scale column when ``per_row_scale``), so
    the VMEM block for int8 is NOT 4× the fp32 one — the capacity win of
    quantized ψ is the HBM/shard-residency side
    (:func:`psi_row_bytes` / :func:`shard_capacity_rows`), while the VMEM
    fit only has to keep working under the same budget.

    ``excl_l_pad`` models the exclude-ID variant: the resident (block_b,
    L_pad) id tile is FIXED and the in-kernel membership compare adds a
    (block_b, L_pad) bool column per candidate row.

    Raises :class:`VmemBudgetError` at large ``block_b·k_pad`` (the fixed
    φ/top-k state alone busts the budget); ``topk_score_pallas`` catches
    it and halves ``block_b``."""
    stored = psi_bytes * d_pad + (4 * d_pad if psi_bytes < 4 else 0)
    per_row = stored + 16 * block_b + block_b * excl_l_pad
    if per_row_scale:
        per_row += 4
    fixed = 4 * (block_b * d_pad + 4 * block_b * k_pad + block_b * excl_l_pad)
    return fit_block_rows(
        per_row, fixed_bytes=fixed, n_rows=n_items, multiple=128, lo=128, hi=4096
    )


def psi_row_bytes(d: int, *, psi_bytes: int = 4,
                  per_row_scale: bool = False) -> int:
    """HBM bytes one ψ catalogue row occupies in serving storage:
    ``d·psi_bytes`` plus the fp32 per-row scale (int8 form). The analytic
    basis for the quantized-capacity and ANN traffic models
    (``benchmarks/serve_bench`` ``ann`` section)."""
    return d * psi_bytes + (4 if per_row_scale else 0)


def shard_capacity_rows(hbm_bytes: int, d: int, *, psi_bytes: int = 4,
                        per_row_scale: bool = False) -> int:
    """ψ rows one shard device can hold in ``hbm_bytes`` of slab budget.
    int8 (+ per-row scale) at D=128 fits ``512/132 ≈ 3.9×`` the fp32 rows —
    the "≥ 3× rows per shard" capacity gate in the serve bench asserts this
    model while :func:`topk_block_items` proves the same tile still fits
    the unchanged VMEM budget."""
    return hbm_bytes // psi_row_bytes(
        d, psi_bytes=psi_bytes, per_row_scale=per_row_scale
    )


def cluster_block_items(
    block_b: int,
    d_pad: int,
    k_pad: int,
    n_shards: int,
    *,
    shard_items: int | None = None,
    excl_l_pad: int = 0,
) -> int:
    """Per-shard ψ row tile for the sharded cluster (``serve/cluster.py``).

    Same footprint as :func:`topk_block_items` plus the cross-shard merge
    scratch: merging S shards' top-K lists holds the (block_b, S·K_pad)
    candidate score AND id rows (``ops.topk_merge_shards``) — a FIXED cost
    of 2·4·block_b·S·K_pad bytes that grows with the shard count.

    Raises :class:`VmemBudgetError` when even one minimal ψ block (128
    rows) cannot fit next to the merge scratch — the cluster PROPAGATES it
    (re-shard coarser, or lower K) instead of silently shrinking the tile
    below one ψ block and overflowing VMEM."""
    merge_scratch = 2 * 4 * block_b * n_shards * k_pad
    per_row = 4 * (d_pad + 4 * block_b) + block_b * excl_l_pad
    fixed = (
        4 * (block_b * d_pad + 4 * block_b * k_pad + block_b * excl_l_pad)
        + merge_scratch
    )
    return fit_block_rows(
        per_row, fixed_bytes=fixed, n_rows=shard_items, multiple=128, lo=128,
        hi=4096,
    )
