"""Model-agnostic retrieval engine over the fused score+top-K kernel.

The φ/ψ export contract
-----------------------

Every k-separable model (paper §4–5) scores an item as
``ŷ = ⟨φ(context), ψ(item)⟩``, so ONE retrieval path serves the whole zoo.
The uniform surface is the :class:`repro.core.models.api.Model` protocol
(``RetrievalEngine.from_model(model, params)`` is the one-call construction
path, and also enables request-time user fold-in); underneath, each model
module exports two functions the engine is built from:

  ``export_psi(params, ...) -> (n_items, D)``  the catalogue ψ table
  ``build_phi(params, <query>) -> (B, D)``     φ rows for a query batch

with D and the column conventions per model:

  model    D     export_psi                build_phi            columns
  -------  ----  ------------------------  -------------------  ------------
  MF       k     ``params.h``              ``w[ctx]``           ψ_f = h_{i,f}
  MFSI     k     ``Z·H`` (item design)     ``(X·W)[rows]``      eq. 21
  FM       k+2   ``psi_ext``: [Ψ | 1 | ψ_spec]
                                           ``phi_ext``:
                                           [Φ | φ_spec | 1]     eqs. 27–31
  PARAFAC  k     ``params.w``              ``u[c1]·v[c2]``      eq. 35
  Tucker   k3    ``params.w``              ``Σ b·u[c1]·v[c2]``  eq. 40

The FM alignment is the one to watch: Ψe's column k is the constant 1
(paired with φ_spec — the context bias/linear/pairwise bundle) and column
k+1 is ψ_spec (paired with Φe's constant 1), so the plain inner product
reproduces the full FM score including both special components.

The engine itself is just (ψ table, φ builder, blocking policy): ``topk``
streams ψ blocks through the Pallas kernel (``kernels/topk_score``) with a
running in-VMEM top-K merge — the ``(B, n_items)`` score matrix is never
materialized — and supports the seen-items-filtered serving protocol via
either exclusion form (below).

Exclusion forms
---------------

  * ``exclude_ids`` (B, L) int32, −1-padded per-row GLOBAL id lists
    (:func:`exclude_ids_from_lists`) — the web-scale form. The kernel
    builds each ψ-block-aligned (block_b, block_items) admissibility slice
    in-VMEM by comparing candidate ids against the row's list, so an
    exclude mask never materializes a full-catalogue row anywhere, and the
    same (global-id) lists serve every shard of a sharded table unchanged.
  * ``exclude_mask`` (B, n_items) bool (:func:`exclude_mask_from_lists`) —
    the legacy dense form; fine for query-batch-sized B at test scale and
    kept as the oracle-side representation.

Scaling past one device (serve/cluster.py, serve/batcher.py, serve/publish.py)
------------------------------------------------------------------------------

  * shard layout — the ψ table row-range partitions over S devices: shard
    s owns global ids [s·rows_per, (s+1)·rows_per), rows_per = ⌈n_items/S⌉,
    all shards padded to the uniform rows_per (only the last has padding;
    the kernel's ``n_valid`` meta keeps pad rows inadmissible). Each shard
    runs THIS engine's kernel with ``id_offset = s·rows_per`` so candidate
    ids come out global, and ``kernels.topk_score.topk_merge_shards`` ranks
    the S·K candidates by (−score, id) — reproducing the single-device
    tie-stable ascending-id policy bit-exactly at any shard count.
  * table versioning — serving tables are immutable, versioned snapshots
    (:class:`~repro.serve.cluster.PsiShardSet`); ``publish`` double-buffers
    the next snapshot and flips it live with one atomic reference swap, so
    a query reads one consistent version end-to-end and caches key on
    ``(query, version)`` — a publish invalidates them implicitly.
  * batcher flush protocol — single-row online queries are admitted to a
    queue and coalesced into kernel-shaped batches; a flush fires when the
    queue reaches ``max_batch`` rows (SIZE) or the oldest admission ages
    past ``max_delay`` (DEADLINE), whichever first; batches pad φ rows to a
    multiple of ``pad_to`` and right-pad per-request exclude-id lists with
    −1; results route back by ticket (``serve/batcher.py``).
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.topk_score.ops import topk_score
from repro.obs.costs import KernelCostRecorder
from repro.obs.metrics import resolve_registry
from repro.serve.cluster import TopKResult


def exclude_ids_from_lists(
    item_lists: Sequence, *, min_width: int = 1
) -> jax.Array:
    """(B, L) int32, −1-padded: ragged per-row GLOBAL excluded-id lists
    (train histories) in the kernel's exclude form. L is the widest row
    (≥ ``min_width``); host cost is O(Σ|list|) — never O(B·n_items)."""
    width = max(min_width, max((len(ids) for ids in item_lists), default=0))
    out = np.full((len(item_lists), width), -1, np.int32)
    for r, ids in enumerate(item_lists):
        ids = np.asarray(ids, np.int64).reshape(-1)
        out[r, : ids.size] = ids
    return jnp.asarray(out)


def exclude_mask_from_lists(
    item_lists: Sequence, n_items: int
) -> jax.Array:
    """(B, n_items) bool mask from ragged per-row item-id lists — the DENSE
    form: each row IS a full-catalogue row, so this is for query-batch-sized
    test/oracle use only; serving and eval pass
    :func:`exclude_ids_from_lists` instead."""
    mask = np.zeros((len(item_lists), n_items), dtype=bool)
    for r, ids in enumerate(item_lists):
        ids = np.asarray(ids, dtype=np.int64)
        if ids.size:
            mask[r, ids] = True
    return jnp.asarray(mask)


class RetrievalEngine:
    """Serve top-K retrieval for any k-separable model.

    Built from the model's exported ψ table and φ builder::

        engine = RetrievalEngine(mf.export_psi(params),
                                 lambda ctx: mf.build_phi(params, ctx))
        scores, ids = engine.topk(user_ids, k=100)

    ``topk`` semantics follow the kernel (see ``kernels/topk_score``):
    exact dense-``lax.top_k`` parity, ascending-id tie policy, (−inf, −1)
    on slots with no admissible candidate. The multi-device mirror with
    the same semantics (bit-exact) is
    :class:`repro.serve.cluster.ShardedRetrievalCluster`.
    """

    def __init__(
        self,
        psi_table: jax.Array,                      # (n_items, D)
        phi_fn: Callable[..., jax.Array],          # query -> (B, D)
        *,
        k: int = 100,
        block_items: Optional[int] = None,
        retrieval: str = "exact",
        ann=None,                                  # serve.ann.AnnConfig
        registry=None,
    ):
        self.psi = jnp.asarray(psi_table, jnp.float32)
        self.phi_fn = phi_fn
        self.k = k
        self.block_items = block_items
        self.model = None   # set by from_model: enables fold_in_phi
        self._params = None
        # kernel cost accounting (obs/costs.py): every topk_phi dispatch
        # records the analytic HBM/FLOP/VMEM model at this host call site
        # (the kernel itself is jitted — see the costs module docstring)
        self.registry = resolve_registry(registry)
        self._costs = KernelCostRecorder(self.registry)
        if retrieval not in ("exact", "ivf"):
            raise ValueError(f"retrieval must be 'exact' or 'ivf', got {retrieval!r}")
        self.retrieval = retrieval
        self.index = None
        if retrieval == "ivf":
            # the engine's ψ is fixed at construction, so the IVF tier
            # (serve/ann.py) indexes it once, eagerly
            from repro.serve.ann import AnnConfig, PsiIndex

            self.ann = ann or AnnConfig()
            self.index = PsiIndex.build(self.psi, self.ann)
        else:
            self.ann = ann

    @classmethod
    def from_model(
        cls,
        model,
        params,
        *,
        k: int = 100,
        block_items: Optional[int] = None,
        retrieval: str = "exact",
        ann=None,
    ) -> "RetrievalEngine":
        """Build an engine from a :class:`repro.core.models.api.Model`
        adapter — the unified construction path (no per-model signature
        branches)::

            engine = RetrievalEngine.from_model(model, params, k=100)
            res = engine.topk(query)                  # model's query space
            phi = engine.fold_in_phi(unseen_history)  # request-time fold-in

        The engine keeps (model, params) so the serving tier can fold in
        an UNSEEN user at request time (:meth:`fold_in_phi`): the user's
        history rows are solved to a φ row against the frozen ψ table
        (closed-form single-row CD, ``core/foldin.py``) without touching
        training state.
        """
        eng = cls(
            model.export_psi(params),
            lambda *query: model.build_phi(
                params, query[0] if len(query) == 1 else query
            ),
            k=k, block_items=block_items, retrieval=retrieval, ann=ann,
        )
        eng.model = model
        eng._params = params
        return eng

    def fold_in_phi(self, item_ids, y=None, alpha=None, **kw) -> jax.Array:
        """(1, D) φ row for an unseen user folded in from their item
        history — closed-form, against the frozen ψ snapshot. Only
        available on engines built with :meth:`from_model`."""
        if self.model is None:
            raise RuntimeError(
                "fold_in_phi needs a Model adapter — build the engine with "
                "RetrievalEngine.from_model(model, params)"
            )
        row = self.model.fold_in_user(self._params, item_ids, y, alpha, **kw)
        return jnp.asarray(row, jnp.float32)[None, :]

    @property
    def n_items(self) -> int:
        return int(self.psi.shape[0])

    def phi(self, *query) -> jax.Array:
        """φ rows for a query batch — (B, D), D tiny; safe to materialize."""
        return jnp.asarray(self.phi_fn(*query), jnp.float32)

    def topk(
        self,
        *query,
        k: Optional[int] = None,
        exclude_mask: Optional[jax.Array] = None,
        exclude_ids: Optional[jax.Array] = None,
    ) -> TopKResult:
        """(scores, ids) :class:`~repro.serve.cluster.TopKResult`, both
        (B, k), for a query batch. A single-device engine has no failure
        modes to degrade over, so ``coverage`` is always 1.0 — the field
        exists so every serving tier (engine, cluster, mesh, batcher
        tickets, sharded eval) answers with ONE result contract."""
        return self.topk_phi(
            self.phi(*query), k=k, exclude_mask=exclude_mask,
            exclude_ids=exclude_ids,
        )

    def topk_phi(
        self,
        phi_rows: jax.Array,
        *,
        k: Optional[int] = None,
        exclude_mask: Optional[jax.Array] = None,
        exclude_ids: Optional[jax.Array] = None,
    ) -> TopKResult:
        """Like :meth:`topk` but from pre-built φ rows (the eval harness
        path, which batches a big φ matrix through here).

        ``retrieval='ivf'`` routes through the engine's
        :class:`~repro.serve.ann.PsiIndex` (centroid pruning + exact fused
        re-rank over the probed blocks); with ``ann.n_probe >=
        ann.n_clusters`` the index's oracle gate makes this bit-identical
        to the exact path. The IVF tier takes the web-scale ``exclude_ids``
        form only — the dense mask is indexed by catalogue position, which
        an approximate tier must not depend on."""
        if self.retrieval == "ivf":
            if exclude_mask is not None:
                raise ValueError(
                    "retrieval='ivf' takes exclude_ids (global id lists), "
                    "not a dense exclude_mask"
                )
            s, i = self.index.topk(
                phi_rows, k or self.k, exclude_ids=exclude_ids,
                block_items=self.block_items, registry=self.registry,
            )
            return TopKResult(s, i)
        b = int(jnp.shape(phi_rows)[0])
        excl_l = 0 if exclude_ids is None else int(exclude_ids.shape[1])
        self._costs.record_topk(
            b, self.n_items, int(self.psi.shape[1]), k or self.k,
            excl_l=excl_l,
        )
        s, i = topk_score(
            phi_rows, self.psi, k or self.k, exclude_mask,
            exclude_ids=exclude_ids, block_items=self.block_items,
        )
        return TopKResult(s, i)

    def scores(self, phi_rows: jax.Array) -> jax.Array:
        """Dense (B, n_items) scores — small batches / tests ONLY; serving
        and eval go through :meth:`topk`, which never materializes this."""
        return phi_rows @ self.psi.T


def bulk_score(forward: Callable, batch, chunk: int = 65536):
    """Offline scoring of a huge batch in fixed-size chunks (serve_bulk)."""
    n = jax.tree_util.tree_leaves(batch)[0].shape[0]
    outs = []
    for lo in range(0, n, chunk):
        piece = jax.tree_util.tree_map(lambda x: x[lo : lo + chunk], batch)
        outs.append(forward(piece))
    return jnp.concatenate(outs, axis=0)


def mf_retrieval_score_fn(user_vec: jax.Array, item_table: jax.Array):
    """The paper-native separable retrieval: one (k)·(k,N) matvec per id
    chunk — or a (B, k)·(k, N) matmul when ``user_vec`` is a (B, k) batch."""

    def score(ids):
        s = jnp.take(item_table, ids, axis=0) @ user_vec.T  # (c,) | (c, B)
        return s.T if s.ndim == 2 else s

    return score
