from repro.kernels.gram.ops import gram  # noqa: F401
