"""Jit'd public wrapper: batched multi-head (GQA) flash attention."""
import jax

from repro.kernels import kernel_jit
from repro.kernels.flash_attention.kernel import flash_attention_pallas


@kernel_jit(
    static_argnames=("causal", "window", "softcap", "q_offset", "kv_len",
                     "block_q", "block_kv"),
)
def flash_attention(
    q: jax.Array,   # (batch, n_q_heads, Sq, d)
    k: jax.Array,   # (batch, n_kv_heads, Skv, d)
    v: jax.Array,   # (batch, n_kv_heads, Skv, d)
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    q_offset: int = 0,
    kv_len: int | None = None,
    block_q: int = 512,
    block_kv: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, "GQA requires n_q_heads % n_kv_heads == 0"
    groups = hq // hkv

    def one_head(qh, kh, vh):
        return flash_attention_pallas(
            qh, kh, vh,
            causal=causal, window=window, softcap=softcap,
            q_offset=q_offset, kv_len=kv_len,
            block_q=block_q, block_kv=block_kv,
            interpret=interpret,
        )

    q5 = q.reshape(b, hkv, groups, sq, d)
    out = jax.vmap(            # batch
        jax.vmap(              # kv head
            jax.vmap(one_head, in_axes=(0, None, None)),  # group
            in_axes=(0, 0, 0),
        ),
        in_axes=(0, 0, 0),
    )(q5, k, v)
    return out.reshape(b, hq, sq, d)
