"""Baselines: iALS and iCD-MF reach comparable optima; BPR learns ranking."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bpr, ials
from repro.core.metrics import ndcg_at_k, recall_at_k
from repro.core.models import mf
from repro.sparse.interactions import build_interactions


def make_problem(seed=0, n_ctx=40, n_items=30, k_true=4, nnz=300, alpha0=0.5):
    """Synthetic low-rank implicit data: consumption where ⟨w,h⟩ is large."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(n_ctx, k_true))
    h = rng.normal(size=(n_items, k_true))
    s = w @ h.T
    flat = np.argsort(-s.ravel())[:nnz]
    ctx, item = flat // n_items, flat % n_items
    y = np.ones(nnz)
    alpha = np.full(nnz, alpha0 + 2.0)
    data = build_interactions(ctx, item, y, alpha, n_ctx, n_items, alpha0=alpha0)
    return data, ctx, item


def test_ials_and_icd_reach_similar_objective():
    data, _, _ = make_problem()
    k = 6
    hp_cd = mf.MFHyperParams(k=k, alpha0=0.5, l2=0.1)
    hp_als = ials.IALSHyperParams(k=k, alpha0=0.5, l2=0.1)
    p0 = mf.init(jax.random.PRNGKey(0), data.n_ctx, data.n_items, k)

    p_cd = mf.fit(p0, data, hp_cd, n_epochs=25)
    p_als = ials.fit(p0, data, hp_als, n_epochs=25)

    o_cd = float(mf.objective(p_cd, data, hp_cd))
    o_als = float(mf.objective(p_als, data, hp_cd))
    # same model family/objective — optima must be close (CD is coordinate-
    # wise, ALS block-wise; both monotone on the same convex-per-block loss)
    assert abs(o_cd - o_als) / max(o_als, 1e-9) < 0.05, (o_cd, o_als)


def test_bpr_learns_ranking_better_than_random():
    data, ctx, item = make_problem(seed=1)
    hp = bpr.BPRHyperParams(k=8, lr=0.1, batch=512)
    params = bpr.init(jax.random.PRNGKey(1), data.n_ctx, data.n_items, 8)
    pairs = np.stack([ctx, item], 1)
    params = bpr.fit(params, pairs, data.n_items, hp, n_steps=400, seed=2)

    scores = mf.scores_all(params)
    # training positives should outrank random cells on average
    pos_scores = np.asarray(scores)[ctx, item]
    rng = np.random.default_rng(3)
    rnd_scores = np.asarray(scores)[
        rng.integers(0, data.n_ctx, 500), rng.integers(0, data.n_items, 500)
    ]
    assert pos_scores.mean() > rnd_scores.mean() + 0.3


def test_metrics_sanity():
    scores = jnp.asarray(
        [[0.9, 0.1, 0.5, 0.0], [0.0, 0.2, 0.1, 0.7], [0.3, 0.8, 0.2, 0.1]]
    )
    truth = jnp.asarray([0, 3, 2])
    np.testing.assert_allclose(float(recall_at_k(scores, truth, 1)), 2 / 3, rtol=1e-6)
    np.testing.assert_allclose(float(recall_at_k(scores, truth, 3)), 1.0, rtol=1e-6)
    n1 = float(ndcg_at_k(scores, truth, 4))
    assert 0.0 < n1 <= 1.0
    # perfect ranking ⇒ NDCG@1 == recall@1 == 1
    assert float(ndcg_at_k(scores, jnp.asarray([0, 3, 1]), 1)) == 1.0
