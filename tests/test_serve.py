"""Serving paths: chunked retrieval top-k, bulk scoring, and the request
micro-batcher (deadline/size flush, out-of-order routing, LRU cache keyed
on the published table version) under a simulated clock."""
import jax.numpy as jnp
import numpy as np

from repro.core.models import mf
from repro.kernels.topk_score import topk_score_ref
from repro.serve.batcher import MicroBatcher
from repro.serve.cluster import ShardedRetrievalCluster
from repro.serve.engine import exclude_ids_from_lists
from repro.serve import bulk_score, mf_retrieval_score_fn, retrieval_topk

import jax


def test_retrieval_topk_exact():
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(5000, 16)), jnp.float32)
    user = jnp.asarray(rng.normal(size=16), jnp.float32)
    scores, ids = retrieval_topk(mf_retrieval_score_fn(user, table), 5000,
                                 k=50, chunk=777)
    full = np.asarray(table @ user)
    expect = set(np.argsort(-full)[:50].tolist())
    assert set(np.asarray(ids).tolist()) == expect
    np.testing.assert_allclose(np.sort(np.asarray(scores))[::-1],
                               np.sort(full[np.asarray(ids)])[::-1], rtol=1e-5)


def test_retrieval_topk_batched_matches_per_row():
    rng = np.random.default_rng(2)
    table = jnp.asarray(rng.normal(size=(3000, 8)), jnp.float32)
    users = jnp.asarray(rng.normal(size=(5, 8)), jnp.float32)
    scores, ids = retrieval_topk(mf_retrieval_score_fn(users, table), 3000,
                                 k=20, chunk=512)
    assert scores.shape == (5, 20) and ids.shape == (5, 20)
    full = np.asarray(users @ table.T)
    for r in range(5):
        s1, i1 = retrieval_topk(mf_retrieval_score_fn(users[r], table), 3000,
                                k=20, chunk=512)
        np.testing.assert_array_equal(np.asarray(ids)[r], np.asarray(i1))
        np.testing.assert_array_equal(
            np.asarray(ids)[r], np.argsort(-full[r], kind="stable")[:20])


def test_retrieval_topk_short_catalogue_no_placeholder_leak():
    table = jnp.asarray(np.random.default_rng(3).normal(size=(7, 4)), jnp.float32)
    user = jnp.asarray([1.0, 0.0, 0.0, 0.0])
    scores, ids = retrieval_topk(mf_retrieval_score_fn(user, table), 7, k=12)
    # first 7 slots are the real catalogue, exactly ranked
    np.testing.assert_array_equal(
        np.asarray(ids)[:7], np.argsort(-np.asarray(table @ user), kind="stable")[:7])
    # tail is (−inf, −1): id 0 never leaks as a fake recommendation
    assert bool((np.asarray(ids)[7:] == -1).all())
    assert bool(np.isneginf(np.asarray(scores)[7:]).all())


def test_bulk_score_chunking():
    w = jnp.asarray([0.5, -1.0, 2.0, 0.25])

    def fwd(batch):
        return batch["x"] @ w  # arbitrary linear scorer

    x = jnp.asarray(np.random.default_rng(1).normal(size=(1000, 4)), jnp.float32)
    got = bulk_score(fwd, {"x": x}, chunk=128)
    np.testing.assert_allclose(got, x @ w, rtol=1e-5)


# --------------------------------------------------------------- batcher ---
def _serving_stack(n_shards=2, k=10, n_ctx=40, n_items=77, seed=0):
    params = mf.init(jax.random.PRNGKey(seed), n_ctx, n_items, 8)
    cluster = ShardedRetrievalCluster(
        lambda ctx: mf.build_phi(params, ctx), n_shards=n_shards, k=k,
        block_items=32, psi_table=mf.export_psi(params),
    )
    clock = {"t": 0.0}
    batcher = MicroBatcher(
        lambda phi, eids: cluster.topk_phi(phi, exclude_ids=eids),
        max_batch=4, max_delay=1.0, pad_to=8,
        clock=lambda: clock["t"], version_fn=lambda: cluster.version,
    )
    phi_all = np.asarray(mf.build_phi(params, jnp.arange(n_ctx)))
    psi = np.asarray(mf.export_psi(params))
    return params, cluster, clock, batcher, phi_all, psi


def test_batcher_routes_out_of_order_requests_under_simulated_clock():
    """The acceptance criterion: single-row requests submitted out of
    order, flushed in mixed batches, must each get THEIR OWN top-K back —
    pinned against the per-row dense oracle."""
    rng = np.random.default_rng(1)
    _, cluster, clock, batcher, phi_all, psi = _serving_stack()
    users = [31, 4, 17, 2, 25, 9, 11]  # deliberately unsorted
    excls = {u: rng.choice(77, size=int(rng.integers(1, 6)), replace=False)
             for u in users}
    tickets = {}
    for j, u in enumerate(users[:3]):  # under max_batch: queued, no result
        clock["t"] = 0.01 * j
        tickets[u] = batcher.submit(phi_all[u], exclude=excls[u])
    assert batcher.n_queued == 3
    assert all(batcher.result(t, pop=False) is None for t in tickets.values())

    clock["t"] = 5.0  # deadline passes → flush the 3
    assert batcher.step()
    assert batcher.stats["flush_by_deadline"] == 1

    for u in users[3:]:  # 4 more → size flush at max_batch=4
        tickets[u] = batcher.submit(phi_all[u], exclude=excls[u])
    assert batcher.stats["flush_by_size"] == 1 and batcher.n_queued == 0

    for u in users:  # every ticket got ITS row's result
        scores, ids = batcher.result(tickets[u])
        eids = exclude_ids_from_lists([excls[u]])
        rs, ri = topk_score_ref(phi_all[u : u + 1], psi, 10, exclude_ids=eids)
        np.testing.assert_array_equal(ids, np.asarray(ri)[0])
        np.testing.assert_allclose(scores, np.asarray(rs)[0], rtol=1e-5)
        assert not np.isin(ids[ids >= 0], excls[u]).any()


def test_batcher_deadline_bounds_queue_wait():
    """No queued request waits past max_delay: a lone sub-batch request is
    flushed as soon as the clock passes its deadline, not starved until
    max_batch fills."""
    _, _, clock, batcher, phi_all, psi = _serving_stack(seed=2)
    clock["t"] = 10.0
    t = batcher.submit(phi_all[0])
    assert batcher.result(t, pop=False) is None
    clock["t"] = 10.5  # < max_delay=1.0: still queued
    assert not batcher.step()
    clock["t"] = 11.0  # deadline hit
    assert batcher.step()
    scores, ids = batcher.result(t)
    rs, ri = topk_score_ref(phi_all[:1], psi, 10)
    np.testing.assert_array_equal(ids, np.asarray(ri)[0])
    assert batcher.completed_at(t) is None  # popped with the result


def test_batcher_cache_hits_and_version_invalidation():
    """The LRU result cache serves repeats without a kernel dispatch and a
    ψ publish (new table version) invalidates it implicitly."""
    _, cluster, clock, batcher, phi_all, _ = _serving_stack(seed=3)
    key = ("user", 7)
    t1 = batcher.submit(phi_all[7], key=key)
    batcher.flush()
    s1, i1 = batcher.result(t1)
    t2 = batcher.submit(phi_all[7], key=key)  # same key, same version
    assert batcher.stats["cache_hits"] == 1 and batcher.n_queued == 0
    s2, i2 = batcher.result(t2)
    np.testing.assert_array_equal(i1, i2)

    cluster.publish(jnp.zeros((77, 8)))  # version bump: all-zero ψ
    t3 = batcher.submit(phi_all[7], key=key)
    assert batcher.result(t3, pop=False) is None  # miss → queued again
    batcher.flush()
    s3, i3 = batcher.result(t3)
    # zero table: every score 0, ranking degenerates to ascending id
    np.testing.assert_array_equal(i3, np.arange(10))
    assert batcher.stats["cache_misses"] >= 2


def test_batcher_cache_folds_exclude_list_into_key():
    """Same caller key, different exclude list ⇒ MISS: the batcher folds
    the exclusion set into the cache key itself, so a cached result can
    never leak items another request excluded (and a cache-hit admission
    still retires queue deadlines)."""
    _, _, clock, batcher, phi_all, psi = _serving_stack(seed=5)
    t1 = batcher.submit(phi_all[3], exclude=[0, 1], key=("user", 3))
    batcher.flush()
    _, i1 = batcher.result(t1)
    t2 = batcher.submit(phi_all[3], exclude=[int(i1[0])], key=("user", 3))
    assert batcher.result(t2, pop=False) is None  # miss, not the stale hit
    batcher.flush()
    _, i2 = batcher.result(t2)
    assert int(i1[0]) not in i2.tolist()
    # identical key AND exclude list ⇒ hit, and the hit path still flushes
    # an overdue queued request (deadline honored under pure cache traffic)
    clock["t"] = 100.0
    t3 = batcher.submit(phi_all[9])  # queued, uncached
    clock["t"] = 200.0  # way past max_delay: next admission must flush it
    t4 = batcher.submit(phi_all[3], exclude=[0, 1], key=("user", 3))
    assert batcher.stats["cache_hits"] == 1
    assert batcher.result(t4) is not None
    got3 = batcher.result(t3)  # t3 flushed by the hit admission
    assert got3 is not None
    rs, ri = topk_score_ref(phi_all[9:10], psi, 10)
    np.testing.assert_array_equal(got3[1], np.asarray(ri)[0])


def test_batcher_drain_flushes_queue_and_closes():
    """Shutdown must flush (not strand) queued requests: drain() completes
    everything, hands back unclaimed results, and closes admission."""
    import pytest

    _, _, clock, batcher, phi_all, psi = _serving_stack(seed=6)
    t1 = batcher.submit(phi_all[2])
    t2 = batcher.submit(phi_all[8])
    claimed = batcher.result(t1)
    assert claimed is None  # still queued (under max_batch, under deadline)
    leftovers = batcher.drain()
    assert set(leftovers) == {t1, t2}  # nothing stranded
    rs, ri = topk_score_ref(phi_all[8:9], psi, 10)
    np.testing.assert_array_equal(leftovers[t2].ids, np.asarray(ri)[0])
    assert batcher.closed and batcher.n_queued == 0
    with pytest.raises(RuntimeError, match="closed"):
        batcher.submit(phi_all[0])
    assert batcher.drain() == {}  # idempotent


def test_batcher_evicts_superseded_version_cache_entries():
    """A publish must EVICT entries keyed on the old table version (they
    can never hit again), not leave them squatting in the LRU."""
    _, cluster, clock, batcher, phi_all, _ = _serving_stack(seed=7)
    for u in (1, 2, 3):
        batcher.submit(phi_all[u], key=("user", u))
    batcher.flush()
    assert len(batcher._cache) == 3
    cluster.publish(jnp.zeros((77, 8)))  # version bump supersedes all 3
    batcher.submit(phi_all[4], key=("user", 4))  # first post-publish admission
    assert batcher.stats["cache_evicted_stale"] == 3
    assert all(k[1] == cluster.version for k in batcher._cache)
    batcher.flush()
    assert len(batcher._cache) == 1  # only the new-version entry


def test_batcher_pads_batch_and_discards_pad_rows():
    """3 requests pad to pad_to=8 kernel rows; pad rows never produce
    tickets or pollute results."""
    _, _, clock, batcher, phi_all, psi = _serving_stack(seed=4)
    ts = [batcher.submit(phi_all[u]) for u in (5, 6, 7)]
    batcher.flush()
    assert batcher.stats["flushed_rows"] == 3 and batcher.stats["flushes"] == 1
    for u, t in zip((5, 6, 7), ts):
        _, ids = batcher.result(t)
        rs, ri = topk_score_ref(phi_all[u : u + 1], psi, 10)
        np.testing.assert_array_equal(ids, np.asarray(ri)[0])
    assert batcher.result(999) is None  # unknown ticket: no leak
