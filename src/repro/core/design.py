"""Fielded design matrices for feature-based models (paper §5.2).

The paper writes X ∈ R^{|C|×p} as a generic sparse matrix. Production
feature pipelines are *fielded*: p columns partition into fields (user id,
age bucket, country, device, previous video, watch history, ...), and each
row activates a bounded number of features per field — exactly one for
categorical fields, a variable-length bag for history fields.

Fieldedness is what makes CD parallelizable on TPU: within a ONE-HOT field
no two features share a row, so their coordinate updates touch disjoint
residuals and can run as one vectorized Newton step (exact CD). Multi-hot
fields share rows; for those the solver offers
  - ``exact``  — sequential scan over bag slots (slot j of every row forms a
                 one-hot-like layer; still vectorized across rows), or
  - ``jacobi`` — damped parallel update over the whole bag (η < 1).

A ``Design`` stacks all field vocabularies into one (p, k) parameter matrix
with per-field row offsets, matching the paper's flat W ∈ R^{p×k}.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Field:
    """One feature field.

    ids:     (n_rows, bag) int32 — local feature ids (0..vocab-1); padded
             slots may hold any id but must be zero-weighted.
    weights: (n_rows, bag) f32 — x values; 0 for padding. One-hot categorical
             fields have bag == 1 and weight 1 (or a real value for dense
             scalar features, which are vocab-1 fields).
    vocab:   static — number of features in this field.
    offset:  static — row offset of this field inside the stacked table.
    one_hot: static — True when no two rows share... (precisely: when bag==1,
             so per-column updates within the field are exact).
    """

    ids: jax.Array
    weights: jax.Array
    vocab: int = dataclasses.field(metadata=dict(static=True))
    offset: int = dataclasses.field(metadata=dict(static=True))
    one_hot: bool = dataclasses.field(metadata=dict(static=True))
    name: str = dataclasses.field(default="", metadata=dict(static=True))

    @property
    def bag(self) -> int:
        return int(self.ids.shape[1])


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Design:
    fields: Tuple[Field, ...]
    n_rows: int = dataclasses.field(metadata=dict(static=True))
    p: int = dataclasses.field(metadata=dict(static=True))  # total features

    def global_ids(self, field: Field) -> jax.Array:
        return field.ids + field.offset


def make_design(fields_spec: Sequence[dict], n_rows: int) -> Design:
    """Host-side builder.

    Each spec: {name, ids (n_rows,) or (n_rows, bag), vocab,
                weights optional same shape}.
    """
    fields = []
    offset = 0
    for spec in fields_spec:
        ids = np.asarray(spec["ids"], dtype=np.int32)
        if ids.ndim == 1:
            ids = ids[:, None]
        weights = spec.get("weights")
        if weights is None:
            weights = np.ones_like(ids, dtype=np.float32)
        else:
            weights = np.asarray(weights, dtype=np.float32)
            if weights.ndim == 1:
                weights = weights[:, None]
        vocab = int(spec["vocab"])
        assert ids.shape == weights.shape and ids.shape[0] == n_rows
        if ids.shape[1] > 1:
            # Invariant: within a row, non-zero-weighted slots carry DISTINCT
            # feature ids (bag = set semantics). FM's pairwise identity
            # Σ_{l<l'} relies on it; duplicates must be pre-merged by the
            # data pipeline (sum their weights into one slot).
            for r in range(ids.shape[0]):
                active = ids[r][weights[r] != 0]
                if len(np.unique(active)) != len(active):
                    raise ValueError(
                        f"field {spec.get('name')}: duplicate ids in row {r}; "
                        "merge duplicate bag entries before make_design"
                    )
        fields.append(
            Field(
                ids=jnp.asarray(ids),
                weights=jnp.asarray(weights),
                vocab=vocab,
                offset=offset,
                one_hot=ids.shape[1] == 1,
                name=spec.get("name", f"field{len(fields)}"),
            )
        )
        offset += vocab
    return Design(fields=tuple(fields), n_rows=n_rows, p=offset)


def take_rows(design: Design, rows: jax.Array) -> Design:
    """Row-subset view of a design: the B query rows of every field.

    The serving path (``build_phi(..., rows)``) gathers rows BEFORE the
    Φ = X·W matmul so a query batch costs O(B·k), not a full-design
    matmul over all contexts."""
    fields = tuple(
        dataclasses.replace(
            f,
            ids=jnp.take(f.ids, rows, axis=0),
            weights=jnp.take(f.weights, rows, axis=0),
        )
        for f in design.fields
    )
    return Design(fields=fields, n_rows=int(rows.shape[0]), p=design.p)


def design_matmul(design: Design, table: jax.Array) -> jax.Array:
    """Φ = X·W for the stacked table W (p, k): fielded embedding-bag sum."""
    out = jnp.zeros((design.n_rows, table.shape[1]), dtype=jnp.float32)
    for field in design.fields:
        gathered = jnp.take(table, design.global_ids(field), axis=0)  # (n,bag,k)
        out = out + jnp.sum(gathered * field.weights[..., None], axis=1)
    return out


def design_col_sq_sums(design: Design) -> jax.Array:
    """Σ_c x_{c,l}² per feature l — the R'' weights of eq. (24). (p,)"""
    out = jnp.zeros((design.p,), dtype=jnp.float32)
    for field in design.fields:
        flat_ids = design.global_ids(field).reshape(-1)
        flat_w = field.weights.reshape(-1)
        out = out.at[flat_ids].add(flat_w * flat_w)
    return out


def to_dense(design: Design) -> jax.Array:
    """Materialize X (n_rows, p) — tests only."""
    x = jnp.zeros((design.n_rows, design.p), dtype=jnp.float32)
    rows = jnp.arange(design.n_rows)
    for field in design.fields:
        for j in range(field.bag):
            x = x.at[rows, field.offset + field.ids[:, j]].add(field.weights[:, j])
    return x
