"""Ranking metrics: Recall@K and NDCG@K (paper §6 evaluates top-100).

Two entry layers: the ``*_at_k`` functions take a dense
(n_eval_ctx, n_items) score matrix (small-scale tests / baselines), while
the ``*_from_topk`` functions take already-ranked (n, k) top-k id lists —
the contract of the streaming retrieval path (``kernels/topk_score`` via
``eval.ranking``), which never materializes the dense matrix. Training
items can be masked out, matching the standard offline protocol.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def topk_items(
    scores: jax.Array, k: int, exclude_mask: Optional[jax.Array] = None
) -> jax.Array:
    """Top-k item ids per row; ``exclude_mask`` True ⇒ never recommend.

    NOTE: ``lax.top_k`` over a −inf-masked dense row still returns real
    item ids for the −inf tail (a row with fewer than k admissible items
    "recommends" excluded ids). The streaming path
    (``kernels/topk_score`` / ``eval.ranking``) returns id −1 for those
    slots instead; both count as misses in the *_from_topk metrics below
    as long as the true item itself is admissible."""
    if exclude_mask is not None:
        scores = jnp.where(exclude_mask, -jnp.inf, scores)
    return jax.lax.top_k(scores, k)[1]


def recall_from_topk(top_ids: jax.Array, true_items: jax.Array) -> jax.Array:
    """Recall@K from (n, k) top-k ids, single held-out item per row.

    Works for both the dense and the streaming top-k (−1 filler ids never
    match a real item id)."""
    return jnp.mean(
        jnp.any(top_ids == true_items[:, None], axis=1).astype(jnp.float32)
    )


def ndcg_from_topk(top_ids: jax.Array, true_items: jax.Array) -> jax.Array:
    """NDCG@K from (n, k) top-k ids, single relevant item ⇒
    DCG = 1/log2(rank+1), IDCG = 1."""
    k = top_ids.shape[1]
    hits = top_ids == true_items[:, None]  # (n, k)
    ranks = jnp.arange(1, k + 1, dtype=jnp.float32)
    gains = jnp.where(hits, 1.0 / jnp.log2(ranks + 1.0)[None, :], 0.0)
    return jnp.mean(jnp.sum(gains, axis=1))


def recall_at_k(
    scores: jax.Array,
    true_items: jax.Array,
    k: int,
    exclude_mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Recall@K for a single held-out item per context (leave-one-out)."""
    return recall_from_topk(topk_items(scores, k, exclude_mask), true_items)


def ndcg_at_k(
    scores: jax.Array,
    true_items: jax.Array,
    k: int,
    exclude_mask: Optional[jax.Array] = None,
) -> jax.Array:
    """NDCG@K, single relevant item ⇒ DCG = 1/log2(rank+1), IDCG = 1."""
    return ndcg_from_topk(topk_items(scores, k, exclude_mask), true_items)


def recall_ndcg_multi(
    scores: np.ndarray,
    held_out: list,
    k: int,
    exclude_mask: Optional[np.ndarray] = None,
) -> Tuple[float, float]:
    """Host-side metrics with a SET of held-out items per context (instant /
    cold-start protocols hold out whole user histories)."""
    if exclude_mask is not None:
        scores = np.where(exclude_mask, -np.inf, scores)
    top = np.argpartition(-scores, min(k, scores.shape[1] - 1), axis=1)[:, :k]
    # sort the partitioned top-k by score for NDCG
    order = np.argsort(-np.take_along_axis(scores, top, axis=1), axis=1)
    top = np.take_along_axis(top, order, axis=1)
    recalls, ndcgs = [], []
    discounts = 1.0 / np.log2(np.arange(2, k + 2))
    for row, truth in enumerate(held_out):
        truth = set(int(t) for t in truth)
        if not truth:
            continue
        hits = np.fromiter((int(t) in truth for t in top[row]), bool, k)
        recalls.append(hits.sum() / len(truth))
        idcg = discounts[: min(len(truth), k)].sum()
        ndcgs.append((hits * discounts).sum() / idcg)
    return float(np.mean(recalls)), float(np.mean(ndcgs))
