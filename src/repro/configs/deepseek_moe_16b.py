"""DeepSeekMoE 16B [arXiv:2401.06066; hf] — 2 shared + 64 routed top-6,
fine-grained experts, first layer dense."""
import dataclasses

from repro.configs.base import LMConfig, MoEConfig, lm_shapes

CONFIG = LMConfig(
    name="deepseek-moe-16b",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,  # per-expert hidden
    vocab=102_400,
    act="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=False,
    moe=MoEConfig(
        n_experts=64, top_k=6, d_expert=1408, n_shared=2,
        first_k_dense=1, d_ff_dense=10944,
    ),
    num_microbatches=8,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, n_layers=3, d_model=32, n_heads=4, n_kv_heads=4, head_dim=8,
    d_ff=24, vocab=64, num_microbatches=1,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=24, n_shared=1,
                  first_k_dense=1, d_ff_dense=64),
)

SHAPES = lm_shapes(
    long_context_skip=(
        "pure full attention MoE; long_500k is assigned to SSM/hybrid/"
        "linear-attn archs only (DESIGN.md §4)"
    )
)
