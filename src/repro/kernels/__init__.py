"""Pallas TPU kernels for the compute hot spots.

Each kernel package ships three layers:
  kernel.py — ``pl.pallas_call`` body with explicit BlockSpec VMEM tiling
  ops.py    — jit'd public wrapper (padding, dtype policy, interpret switch)
  ref.py    — pure-jnp oracle used by the allclose test sweeps

Kernels:
  gram            — tall-skinny AᵀA (Lemma 2's J matrices): row-blocked MXU
                    accumulation in VMEM. The iCD inner product engine.
  cd_update       — fused iCD Newton column update over the padded-CSR
                    observation layout (explicit+implicit parts + residual
                    patch in one VMEM pass).
  cd_sweep        — block-sweep generalization of cd_update: k_b embedding
                    dimensions per grid step with the residual cache and α
                    VMEM-resident across the block (Gauss–Seidel R' patch
                    between columns). Cuts the sweep's (C, D_pad) HBM
                    traffic from k round-trips to ⌈k/k_b⌉. Four entry
                    points cover the k-separable zoo: shared-Gram sweep
                    (MF), per-row-patch sweep (PARAFAC/Tucker modes), and
                    the slab-reduce + resid-patch pair (MFSI/FM field
                    models).
  topk_score      — fused retrieval/eval sweep: streams ψ-table blocks
                    through VMEM, fuses the (B, block_items) score matmul
                    with a running per-row top-K merge (exclude-mask or
                    per-row exclude-ID-list support); the (B, n_items)
                    score matrix never exists. A traced (id_offset,
                    n_valid) meta serves row-range ψ shards with global
                    output ids, and the ops-layer ``topk_merge_shards``
                    K-way-merges per-shard candidates tie-stably — the
                    serving/eval mirror of cd_sweep and the kernel under
                    ``serve/cluster``. Accepts quantized ψ storage (int8
                    with per-row scales, bf16) dequantized in-VMEM with
                    fp32 accumulate — the storage side of the IVF tier
                    (``serve/ann.py``).

Blocking policy: row-tile sizes (``block_ctx``/``block_items``) resolve
from the shared VMEM budget in ``kernels/vmem.py`` when not pinned by the
caller.

On CPU (CI) kernels are validated with ``interpret=True`` (the Pallas
interpreter executes the same BlockSpec program in Python); on TPU/GPU the
same code path compiles for real. ``REPRO_PALLAS_INTERPRET=0/1`` overrides
the backend detection either way.
"""
import os

_COMPILED_BACKENDS = ("tpu", "gpu")


def use_interpret() -> bool:
    """Interpret-mode policy for every Pallas kernel wrapper.

    Priority: the ``REPRO_PALLAS_INTERPRET`` env var ("1"/"true" forces the
    interpreter, "0"/"false" forces compiled kernels), then backend
    detection — compiled on TPU/GPU, interpret elsewhere (CPU CI).
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "").strip().lower()
    if env in ("0", "false", "no"):
        return False
    if env in ("1", "true", "yes"):
        return True
    import jax

    return jax.default_backend() not in _COMPILED_BACKENDS


def kernel_jit(*, static_argnames=(), donate_argnums=()):
    """Shared jit wrapper for the kernel ops layer.

    The decorated function must accept a keyword-only ``interpret`` arg and
    forward it to its ``pallas_call`` wrapper. When the caller leaves it
    ``None``, it is resolved via :func:`use_interpret` OUTSIDE the jit
    boundary on every call and passed as a static arg, so the jit cache is
    keyed on it and — for direct eager kernel calls — a mid-process
    ``REPRO_PALLAS_INTERPRET`` change takes effect instead of silently
    hitting a stale trace. (Composed entry points that jit over these
    wrappers, e.g. ``mf_padded.epoch``, bake the flag at their own trace
    time; restart the process or clear their caches to re-key.) An explicit
    ``interpret=True/False`` from the caller always wins.
    """
    import functools

    def deco(fn):
        import jax

        jitted = jax.jit(
            fn,
            static_argnames=tuple(static_argnames) + ("interpret",),
            donate_argnums=donate_argnums,
        )

        @functools.wraps(fn)
        def call(*args, **kwargs):
            if kwargs.get("interpret") is None:
                kwargs["interpret"] = use_interpret()
            return jitted(*args, **kwargs)

        return call

    return deco
