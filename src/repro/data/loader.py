"""Host-sharded batch iterators.

Each host yields only its slice of the global batch (slice index =
``jax.process_index()``); on a pod the per-host arrays are assembled into
globally-sharded jax.Arrays by the launcher via
``jax.make_array_from_process_local_data``. In this single-process container
the iterator degenerates to the full batch, same code path.
"""
from __future__ import annotations

from typing import Dict, Iterator

import jax
import numpy as np


def _host_slice(global_batch: int) -> slice:
    n_hosts = jax.process_count()
    per_host = global_batch // n_hosts
    lo = jax.process_index() * per_host
    return slice(lo, lo + per_host)


def lm_token_batches(
    vocab: int, global_batch: int, seq_len: int, seed: int = 0
) -> Iterator[Dict[str, np.ndarray]]:
    """Synthetic LM batches with a learnable bigram structure (so loss
    actually decreases in the e2e example)."""
    rng = np.random.default_rng(seed)
    sl = _host_slice(global_batch)
    # fixed random bigram table → next-token structure
    trans = rng.integers(0, vocab, size=(vocab, 4))
    while True:
        b = sl.stop - sl.start
        toks = np.empty((b, seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, vocab, b)
        for t in range(seq_len):
            choice = rng.integers(0, 4, b)
            nxt = trans[toks[:, t], choice]
            noise = rng.random(b) < 0.1
            toks[:, t + 1] = np.where(noise, rng.integers(0, vocab, b), nxt)
        yield {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def sharded_batches(
    make_batch, global_batch: int, seed: int = 0
) -> Iterator[Dict[str, np.ndarray]]:
    """Generic host-sharded iterator: make_batch(rng, n) → dict of arrays."""
    rng = np.random.default_rng(seed + jax.process_index())
    sl = _host_slice(global_batch)
    n = sl.stop - sl.start
    while True:
        yield make_batch(rng, n)
