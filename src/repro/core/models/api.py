"""Unified ``Model`` protocol over the k-separable zoo (MF/MFSI/FM/PARAFAC/
Tucker).

The five model modules grew drifted entry points (``mf.fit(params, data,
hp, ...)`` vs ``fm.fit(params, x, z, data, hp, ...)`` vs ``tucker.fit(
params, tc, data, hp, ...)``; ``build_phi`` takes ctx ids / a Design / a
``(c1, c2)`` pair depending on the model). This module routes them through
ONE surface so the serving engine, ranking eval, zoo helpers, and the
continual-learning tier never branch on per-model signatures:

    ds = Dataset(data=interactions, x=x, z=z)          # per-model bundle
    model = build_model("fm", hp=hp, dataset=ds)
    params = model.init(jax.random.PRNGKey(0))
    params = model.fit(params, n_epochs=5)             # data keyword-only
    psi = model.export_psi(params)                     # (n_items, D)
    phi = model.build_phi(params, query)               # (B, D) query rows
    phi_new = model.fold_in_user(params, item_ids)     # closed-form, no epoch
    psi_new = model.fold_in_item(params, ctx_ids)      # → serve publish_delta

``query`` is the model's natural address: context ids (MF), context-design
row ids (MFSI/FM), or a ``(c1, c2)`` pair tuple (PARAFAC/Tucker). Everything
else — which designs/tensor-context a model needs, FM's extended-column
conventions, which fold-in coordinates are structurally fixed — lives inside
the adapter.

Fold-in (the continual-learning path) solves ONE embedding row in export
coordinates against the frozen other side via :mod:`repro.core.foldin`:
``fold_in_user`` returns a φ row ready for ``RetrievalEngine.topk_phi``;
``fold_in_item`` returns a ψ row ready for the serving tier's
``publish_delta``. FM's constant-1 extended columns are held fixed
automatically (the ``free`` mask).

The module-level functions in ``mf.py``/``mfsi.py``/... remain the public
low-level API (existing tests/benches use them unmodified); the adapters
are thin delegates, not reimplementations.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Protocol, runtime_checkable

import jax
import numpy as np

from repro.core import foldin
from repro.core.design import Design
from repro.core.models import fm, mf, mfsi, parafac, tucker
from repro.core.models.parafac import TensorContext
from repro.sparse.interactions import Interactions

__all__ = [
    "Dataset", "Model", "build_model", "MODEL_TYPES",
    "MFModel", "MFSIModel", "FMModel", "PARAFACModel", "TuckerModel",
    "CtxMFModel",
]


@dataclasses.dataclass(frozen=True)
class Dataset:
    """Per-model data bundle: everything a model consumes besides params.

    ``data``  training interactions (always; fold-in works without it)
    ``x``/``z`` context/item feature designs (MFSI, FM)
    ``tc``    tensor context pair lists (PARAFAC, Tucker, CtxMF)
    ``confidence`` optional (nnz,) per-interaction confidence weights in
    ctx-major nnz order (e.g. from
    :func:`repro.core.implicit.frequency_confidence` /
    :func:`~repro.core.implicit.confidence_weights`); threaded as
    ``weights=`` through every adapter's ``fit``/``epoch`` unless the call
    overrides it. ``None`` keeps every training program bit-identical to
    the unweighted one.
    """

    data: Optional[Interactions] = None
    x: Optional[Design] = None
    z: Optional[Design] = None
    tc: Optional[TensorContext] = None
    confidence: Optional[jax.Array] = None

    def require(self, *fields: str) -> "Dataset":
        missing = [f for f in fields if getattr(self, f) is None]
        if missing:
            raise ValueError(f"Dataset is missing required field(s) {missing}")
        return self


@runtime_checkable
class Model(Protocol):
    """What every zoo adapter provides (see module docstring)."""

    name: str
    hp: object
    dataset: Dataset

    def init(self, key: jax.Array): ...
    def fit(self, params, *, n_epochs: int, data: Optional[Interactions] = None,
            callback: Optional[Callable] = None, schedule=None,
            weights=None): ...
    def epoch(self, params, e, *, data: Optional[Interactions] = None,
              schedule=None, sweep_index: int = 0, weights=None): ...
    def residuals(self, params, *, data: Optional[Interactions] = None): ...
    def objective(self, params, *, data: Optional[Interactions] = None): ...
    def export_psi(self, params): ...
    def build_phi(self, params, query): ...
    def phi_table(self, params): ...
    def fold_in_user(self, params, item_ids, y=None, alpha=None, *,
                     weights=None, n_sweeps: int = 64, tol: float = 1e-6): ...
    def fold_in_item(self, params, ctx_ids, y=None, alpha=None, *,
                     weights=None, n_sweeps: int = 64, tol: float = 1e-6): ...


class _ModelBase:
    """Shared adapter plumbing; subclasses bind one model module."""

    name = "?"

    def __init__(self, hp, dataset: Dataset):
        self.hp = hp
        self.dataset = dataset

    # -- data routing -----------------------------------------------------
    def _data(self, data: Optional[Interactions]) -> Interactions:
        if data is not None:
            return data
        self.dataset.require("data")
        return self.dataset.data

    def _weights(self, weights):
        """Per-interaction confidence for this call: an explicit ``weights``
        argument wins; otherwise the Dataset's ``confidence`` (None = the
        bit-identical unweighted program)."""
        return weights if weights is not None else self.dataset.confidence

    # -- fold-in ----------------------------------------------------------
    # Free/fixed masks over the D export coordinates; None = all free.
    def _user_free_init(self):
        return None, None

    def _item_free_init(self):
        return None, None

    def _foldin_hp(self):
        return dict(alpha0=self.hp.alpha0, l2=self.hp.l2, eta=self.hp.eta)

    def fold_in_user(self, params, item_ids, y=None, alpha=None, *,
                     weights=None, n_sweeps: int = 64,
                     tol: float = 1e-6) -> np.ndarray:
        """Closed-form φ row for an UNSEEN user from its item interactions:
        single-row CD against the frozen ψ export table. Returns (D,).
        ``weights`` (per-interaction confidence, e.g. frequency-derived)
        multiplies α in the single-row solve — continual learning inherits
        confidence."""
        free, init = self._user_free_init()
        table = np.asarray(self.export_psi(params))
        res = foldin.fold_in_row(
            table, item_ids, y, alpha, weights=weights, free=free, init=init,
            n_sweeps=n_sweeps, tol=tol, **self._foldin_hp(),
        )
        return res.row

    def fold_in_item(self, params, ctx_ids, y=None, alpha=None, *,
                     weights=None, n_sweeps: int = 64,
                     tol: float = 1e-6) -> np.ndarray:
        """Closed-form ψ row for a NEW item from the contexts that touched
        it (ids in the model's ``Interactions.ctx`` space): single-row CD
        against the frozen φ table. Returns (D,) — ready for the serving
        tier's ``publish_delta``. ``weights`` multiplies α like
        :meth:`fold_in_user`."""
        free, init = self._item_free_init()
        table = np.asarray(self.phi_table(params))
        res = foldin.fold_in_row(
            table, ctx_ids, y, alpha, weights=weights, free=free, init=init,
            n_sweeps=n_sweeps, tol=tol, **self._foldin_hp(),
        )
        return res.row


class MFModel(_ModelBase):
    name = "mf"

    def init(self, key):
        d = self._data(None)
        return mf.init(key, d.n_ctx, d.n_items, self.hp.k)

    def fit(self, params, *, n_epochs, data=None, callback=None, schedule=None,
            weights=None):
        return mf.fit(params, self._data(data), self.hp, n_epochs,
                      callback=callback, schedule=schedule,
                      weights=self._weights(weights))

    def epoch(self, params, e, *, data=None, schedule=None, sweep_index=0,
              weights=None):
        return mf.epoch(params, self._data(data), e, self.hp, schedule,
                        sweep_index, self._weights(weights))

    def residuals(self, params, *, data=None):
        return mf.residuals(params, self._data(data))

    def objective(self, params, *, data=None):
        return mf.objective(params, self._data(data), self.hp)

    def export_psi(self, params):
        return mf.export_psi(params)

    def build_phi(self, params, query):
        return mf.build_phi(params, query)

    def phi_table(self, params):
        return params.w


class MFSIModel(_ModelBase):
    name = "mfsi"

    def __init__(self, hp, dataset: Dataset):
        super().__init__(hp, dataset.require("x", "z"))

    def init(self, key):
        return mfsi.init(key, self.dataset.x.p, self.dataset.z.p, self.hp.k)

    def fit(self, params, *, n_epochs, data=None, callback=None, schedule=None,
            weights=None):
        ds = self.dataset
        return mfsi.fit(params, ds.x, ds.z, self._data(data), self.hp,
                        n_epochs, callback=callback, schedule=schedule,
                        weights=self._weights(weights))

    def epoch(self, params, e, *, data=None, schedule=None, sweep_index=0,
              weights=None):
        ds = self.dataset
        return mfsi.epoch(params, ds.x, ds.z, self._data(data), e, self.hp,
                          schedule, sweep_index, self._weights(weights))

    def residuals(self, params, *, data=None):
        ds = self.dataset
        return mfsi.residuals(params, ds.x, ds.z, self._data(data))

    def objective(self, params, *, data=None):
        ds = self.dataset
        return mfsi.objective(params, ds.x, ds.z, self._data(data), self.hp)

    def export_psi(self, params):
        return mfsi.export_psi(params, self.dataset.z)

    def build_phi(self, params, query):
        return mfsi.build_phi(params, self.dataset.x, query)

    def phi_table(self, params):
        return mfsi.phi(params, self.dataset.x)


class FMModel(_ModelBase):
    name = "fm"

    def __init__(self, hp, dataset: Dataset):
        super().__init__(hp, dataset.require("x", "z"))

    def init(self, key):
        return fm.init(key, self.dataset.x.p, self.dataset.z.p, self.hp.k)

    def fit(self, params, *, n_epochs, data=None, callback=None, schedule=None,
            weights=None):
        ds = self.dataset
        return fm.fit(params, ds.x, ds.z, self._data(data), self.hp,
                      n_epochs, callback=callback, schedule=schedule,
                      weights=self._weights(weights))

    def epoch(self, params, e, *, data=None, schedule=None, sweep_index=0,
              weights=None):
        ds = self.dataset
        return fm.epoch(params, ds.x, ds.z, self._data(data), e, self.hp,
                        schedule, sweep_index, self._weights(weights))

    def residuals(self, params, *, data=None):
        ds = self.dataset
        return fm.residuals(params, ds.x, ds.z, self._data(data), self.hp)

    def objective(self, params, *, data=None):
        ds = self.dataset
        return fm.objective(params, ds.x, ds.z, self._data(data), self.hp)

    def export_psi(self, params):
        return fm.export_psi(params, self.dataset.z, self.hp)

    def build_phi(self, params, query):
        return fm.build_phi(params, self.dataset.x, self.hp, query)

    def phi_table(self, params):
        return fm.phi_ext(params, self.dataset.x, self.hp)

    # FM extended columns: Φe = [Φ | φ_spec | 1], Ψe = [Ψ | 1 | ψ_spec].
    # A folded-in row solves the latent block plus ITS OWN spec column (it
    # meets the other side's constant-1) while the constant-1 column that
    # meets the other side's spec stays structurally fixed at 1.
    def _user_free_init(self):
        k = self.hp.k
        free = np.ones(k + 2, bool)
        free[k + 1] = False
        init = np.zeros(k + 2, np.float32)
        init[k + 1] = 1.0
        return free, init

    def _item_free_init(self):
        k = self.hp.k
        free = np.ones(k + 2, bool)
        free[k] = False
        init = np.zeros(k + 2, np.float32)
        init[k] = 1.0
        return free, init


class PARAFACModel(_ModelBase):
    name = "parafac"

    def __init__(self, hp, dataset: Dataset):
        super().__init__(hp, dataset.require("tc"))

    def init(self, key):
        d = self._data(None)
        tc = self.dataset.tc
        return parafac.init(key, tc.n_c1, tc.n_c2, d.n_items, self.hp.k)

    def fit(self, params, *, n_epochs, data=None, callback=None, schedule=None,
            weights=None):
        return parafac.fit(params, self.dataset.tc, self._data(data), self.hp,
                           n_epochs, callback=callback, schedule=schedule,
                           weights=self._weights(weights))

    def epoch(self, params, e, *, data=None, schedule=None, sweep_index=0,
              weights=None):
        return parafac.epoch(params, self.dataset.tc, self._data(data), e,
                             self.hp, schedule, sweep_index,
                             self._weights(weights))

    def residuals(self, params, *, data=None):
        return parafac.residuals(params, self.dataset.tc, self._data(data))

    def objective(self, params, *, data=None):
        return parafac.objective(params, self.dataset.tc, self._data(data),
                                 self.hp)

    def export_psi(self, params):
        return parafac.export_psi(params)

    def build_phi(self, params, query):
        c1, c2 = query
        return parafac.build_phi(params, c1, c2)

    def phi_table(self, params):
        return parafac.phi(params, self.dataset.tc)


class TuckerModel(_ModelBase):
    name = "tucker"

    def __init__(self, hp, dataset: Dataset):
        super().__init__(hp, dataset.require("tc"))

    def init(self, key):
        d = self._data(None)
        tc = self.dataset.tc
        return tucker.init(key, tc.n_c1, tc.n_c2, d.n_items,
                           self.hp.k1, self.hp.k2, self.hp.k3)

    def fit(self, params, *, n_epochs, data=None, callback=None, schedule=None,
            weights=None):
        return tucker.fit(params, self.dataset.tc, self._data(data), self.hp,
                          n_epochs, callback=callback, schedule=schedule,
                          weights=self._weights(weights))

    def epoch(self, params, e, *, data=None, schedule=None, sweep_index=0,
              weights=None):
        return tucker.epoch(params, self.dataset.tc, self._data(data), e,
                            self.hp, schedule, sweep_index,
                            self._weights(weights))

    def residuals(self, params, *, data=None):
        return tucker.residuals(params, self.dataset.tc, self._data(data))

    def objective(self, params, *, data=None):
        return tucker.objective(params, self.dataset.tc, self._data(data),
                                self.hp)

    def export_psi(self, params):
        return tucker.export_psi(params)

    def build_phi(self, params, query):
        c1, c2 = query
        return tucker.build_phi(params, c1, c2)

    def phi_table(self, params):
        return tucker.phi(params, self.dataset.tc)


class CtxMFModel(PARAFACModel):
    """Context-aware MF (GFF seasonal/session mode): PARAFAC with
    ``(c1, c2) = (user, context bucket)``. The query address is a
    ``(user_ids, bucket_ids)`` pair; ``tc``/``data.ctx`` come from
    :func:`repro.core.models.ctxmf.build_context`. All training and
    serving paths are the PARAFAC ones (incl. the fused rowpatch-kernel
    epoch) — only the naming and data-prep story differ."""

    name = "ctxmf"


MODEL_TYPES = {
    "mf": MFModel,
    "mfsi": MFSIModel,
    "fm": FMModel,
    "parafac": PARAFACModel,
    "tucker": TuckerModel,
    "ctxmf": CtxMFModel,
}


def build_model(name: str, *, hp, dataset: Dataset) -> Model:
    """Construct the adapter for zoo model ``name`` around its hyperparams
    and :class:`Dataset` bundle."""
    try:
        cls = MODEL_TYPES[name]
    except KeyError:
        raise ValueError(
            f"unknown model {name!r}; zoo = {tuple(MODEL_TYPES)}"
        ) from None
    return cls(hp, dataset)
