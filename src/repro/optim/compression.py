"""int8 error-feedback gradient compression for the DP all-reduce.

1-byte quantized all-reduce cuts the data-parallel collective term 4×
(fp32) / 2× (bf16). Error feedback (Seide et al.; Karimireddy et al.)
accumulates the quantization residual locally so the compressed SGD
trajectory converges to the uncompressed one.

The quantizer itself is the shared symmetric-int8 code in
:mod:`repro.core.quant` (one scale-fitting rule for gradients here and for
quantized ψ serving storage in ``serve/ann.py``); this module re-exports it
under the historical ``int8_compress``/``int8_decompress`` names and keeps
the error-feedback state machine.

Usage inside a shard_map'd step:

    g_q, scale = int8_compress(g + err)
    g_sum = jax.lax.psum(g_q.astype(jnp.float32), "data")   # wire: int8
    g_hat = g_sum * scale_combined
    err   = (g + err) - int8_decompress(g_q, scale)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import (  # noqa: F401  (re-exported compat names)
    int8_dequantize as int8_decompress,
    int8_dequantize_rows,
    int8_quantize as int8_compress,
    int8_quantize_rows,
)


def ef_compress_update(g: jax.Array, err: jax.Array):
    """One error-feedback step: quantize (g + err), return
    (q, scale, new_err)."""
    corrected = g.astype(jnp.float32) + err
    q, scale = int8_compress(corrected)
    new_err = corrected - int8_decompress(q, scale)
    return q, scale, new_err


def compressed_psum(g: jax.Array, err: jax.Array, axis_name: str):
    """Error-feedback int8 all-reduce over ``axis_name`` (call inside
    shard_map). Returns (g_hat_mean, new_err)."""
    q, scale, new_err = ef_compress_update(g, err)
    total = jax.lax.psum(q.astype(jnp.float32) * scale, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return total / n, new_err
