"""End-to-end training driver.

On a pod this is the per-host entry point (jax.distributed.initialize, then
identical SPMD code); in this container it runs the same path on the local
device mesh. Supports every ``--arch`` in the registry (since PR 4 that is
the paper's own iCD configs — the seed-template LM/RecSys/GNN drivers left
with their configs):

  python -m repro.launch.train --arch icd-mf --smoke --steps 30
  python -m repro.launch.train --arch icd-fm --smoke --steps 30
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config


def _icd_main(cfg, args):
    from repro.core.models import mf
    from repro.data.synthetic import make_implicit_dataset
    from repro.sparse.interactions import build_interactions

    ds = make_implicit_dataset(n_users=cfg.n_ctx, n_items=cfg.n_items,
                               seed=args.seed)
    ev = ds.events
    hp = mf.MFHyperParams(k=cfg.k, alpha0=cfg.alpha0, l2=cfg.l2)
    data = build_interactions(
        ev[:, 0], ev[:, 1], np.ones(len(ev)), np.full(len(ev), cfg.alpha0 + 2.0),
        cfg.n_ctx, cfg.n_items, alpha0=cfg.alpha0,
    )
    params = mf.init(jax.random.PRNGKey(args.seed), cfg.n_ctx, cfg.n_items, cfg.k)
    for ep in range(args.steps):
        params = mf.fit(params, data, hp, 1)
        if (ep + 1) % 5 == 0:
            obj = float(mf.objective(params, data, hp))
            print(f"[icd] epoch {ep + 1} objective {obj:.4f}")
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    name = getattr(cfg, "name", args.arch)
    print(f"[train] arch={name} smoke={args.smoke}")
    if not args.arch.startswith("icd"):
        raise SystemExit(f"no training driver for {args.arch!r}; "
                         "registered archs are the iCD configs")
    _icd_main(cfg, args)


if __name__ == "__main__":
    main()
