"""Sparse substrate: CSR, segment ops, EmbeddingBag, neighbor sampler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis; CI installs it
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.sparse import (
    build_adjacency,
    coo_to_csr,
    csr_row_ids,
    embedding_bag,
    multi_hot_lookup,
    neighbor_sampler,
)
from repro.sparse.csr import transpose_csr_host
from repro.sparse.sampler import sample_neighbors


def test_csr_roundtrip_and_row_ids():
    rng = np.random.default_rng(0)
    n_rows, n_cols, nnz = 7, 5, 12
    cells = rng.choice(n_rows * n_cols, nnz, replace=False)
    row, col = cells // n_cols, cells % n_cols
    data = rng.normal(size=nnz)
    csr = coo_to_csr(row, col, data, n_rows, n_cols)
    assert csr.nnz == nnz
    rid = np.asarray(csr_row_ids(csr))
    dense = np.zeros((n_rows, n_cols))
    dense[rid, np.asarray(csr.indices)] = np.asarray(csr.data)
    expect = np.zeros((n_rows, n_cols))
    expect[row, col] = data
    np.testing.assert_allclose(dense, expect)
    # transpose twice = identity (as dense)
    t2 = transpose_csr_host(transpose_csr_host(csr))
    dense2 = np.zeros((n_rows, n_cols))
    dense2[np.asarray(csr_row_ids(t2)), np.asarray(t2.indices)] = np.asarray(t2.data)
    np.testing.assert_allclose(dense2, expect)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), n_rows=st.integers(1, 10), vocab=st.integers(1, 12),
       dim=st.integers(1, 6), nnz=st.integers(1, 40))
def test_embedding_bag_matches_loop(seed, n_rows, vocab, dim, nnz):
    rng = np.random.default_rng(seed)
    table = rng.normal(size=(vocab, dim)).astype(np.float32)
    ids = rng.integers(0, vocab, nnz)
    rows = rng.integers(0, n_rows, nnz)
    weights = rng.normal(size=nnz).astype(np.float32)
    got = embedding_bag(jnp.asarray(table), jnp.asarray(ids), jnp.asarray(rows),
                        n_rows, jnp.asarray(weights))
    expect = np.zeros((n_rows, dim), np.float32)
    for i, r, w in zip(ids, rows, weights):
        expect[r] += w * table[i]
    np.testing.assert_allclose(got, expect, rtol=1e-4, atol=1e-5)


def test_multi_hot_lookup_mean():
    table = jnp.asarray(np.arange(12, dtype=np.float32).reshape(6, 2))
    ids = jnp.asarray([[0, 1, 2], [3, 3, 0]])
    mask = jnp.asarray([[1, 1, 0], [1, 0, 0]], jnp.float32)
    got = multi_hot_lookup(table, ids, mask, combiner="mean")
    expect = np.stack([(np.arange(2) * 0 + table[0] + table[1]) / 2, table[3]])
    np.testing.assert_allclose(got, np.asarray(expect))


def test_neighbor_sampler_validity():
    rng = np.random.default_rng(1)
    n_nodes, n_edges = 50, 400
    src = rng.integers(0, n_nodes, n_edges)
    dst = rng.integers(0, n_nodes, n_edges)
    adj = build_adjacency(src, dst, n_nodes)
    seeds = jnp.asarray(rng.integers(0, n_nodes, 16), jnp.int32)
    frontiers = neighbor_sampler(jax.random.PRNGKey(0), adj, seeds, [5, 3])
    assert frontiers[0].shape == (16,)
    assert frontiers[1].shape == (16 * 5,)
    assert frontiers[2].shape == (16 * 5 * 3,)
    # validity: every sampled neighbor must be a true neighbor (or self-loop
    # fallback for isolated nodes)
    indptr, indices = np.asarray(adj.indptr), np.asarray(adj.indices)
    neigh_sets = [set(indices[indptr[v]:indptr[v + 1]]) for v in range(n_nodes)]
    parents = np.asarray(frontiers[0])
    children = np.asarray(frontiers[1]).reshape(16, 5)
    for p, kids in zip(parents, children):
        for kid in kids:
            assert kid in neigh_sets[p] or (len(neigh_sets[p]) == 0 and kid == p)


def test_sampler_isolated_nodes_self_loop():
    adj = coo_to_csr(np.array([0]), np.array([1]), None, 4, 4)  # node 2,3 isolated
    seeds = jnp.asarray([2, 3, 0], jnp.int32)
    neigh = sample_neighbors(jax.random.PRNGKey(0), adj, seeds, 4)
    assert np.all(np.asarray(neigh[0]) == 2)
    assert np.all(np.asarray(neigh[1]) == 3)
    assert np.all(np.asarray(neigh[2]) == 1)
