"""Gemma-2 2B [arXiv:2408.00118; hf] — local+global alternating, softcaps."""
import dataclasses

from repro.configs.base import LMConfig, lm_shapes

CONFIG = LMConfig(
    name="gemma2-2b",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab=256_000,
    act="geglu",
    attn_window=4096,
    local_global_alternating=True,
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norms=True,
    rope_theta=10_000.0,
    tie_embeddings=True,
    num_microbatches=4,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, attn_window=8, num_microbatches=1,
)

# hybrid local/global ⇒ long_500k RUNS (half the cache is window-bounded;
# decode is O(L) per token)
SHAPES = lm_shapes(long_context_skip=None)
