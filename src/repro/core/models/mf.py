"""iCD for Matrix Factorization (paper §5.1, Algorithm 2).

Model: ŷ(c,i) = ⟨w_c, h_i⟩,  Θ = {W ∈ R^{C×k}, H ∈ R^{I×k}}.
Trivially k-separable with φ_f(c) = w_{c,f}, ψ_f(i) = h_{i,f} (eq. 16);
gradients are one-hot (eq. 17), so the regularizer derivatives collapse to

    R'(w_{c*,f*}) = 2 Σ_f J_I(f,f*)·w_{c*,f}       (eq. 18)
    R''(w_{c*,f*}) = 2 J_I(f*,f*)                  (eq. 19)

Per-epoch complexity O((|C|+|I|)k² + |S|k) — the paper's headline result.

TPU adaptation (DESIGN.md §3): the c*-loop of Algorithm 2 is vectorized into
one column update; the f*-loop and the W↔H alternation stay sequential
(that ordering is what CD convergence relies on). The fixed point is
identical to the scalar algorithm because coordinates within a column touch
disjoint residuals.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import sweeps
from repro.core.gram import gram
from repro.core.implicit import implicit_objective
from repro.sparse.interactions import Interactions
from repro.sparse.segment import segment_sum


class MFParams(NamedTuple):
    w: jax.Array  # (n_ctx, k)   context embeddings
    h: jax.Array  # (n_items, k) item embeddings


@dataclasses.dataclass(frozen=True)
class MFHyperParams:
    k: int
    alpha0: float = 1.0
    l2: float = 0.1
    eta: float = 1.0  # full Newton step — exact for bilinear models
    implementation: str = "xla"  # 'xla' | 'pallas' gram/cd kernels
    unroll: bool = False  # unroll the k-column loop (exact HLO costs; also
    #                       lets XLA pipeline/fuse across columns on TPU)
    block_k: int = 0  # columns per fused cd_sweep dispatch on the padded
    #                   layout: 0 = auto (min(k, 8)), 1 = per-column kernel
    psi_dispatch: str = "gather"  # fused-path Ψ routing: 'gather' = in-kernel
    #                   gather from the ψ table (no (C, k_b, D_pad) HBM
    #                   intermediate; falls back automatically when the ψ
    #                   slab busts the VMEM budget), 'pregather' = host-side
    #                   pre-gathered Ψ tile (the PR 1–2 path)


def init(key: jax.Array, n_ctx: int, n_items: int, k: int, sigma: float = 0.1) -> MFParams:
    kw, kh = jax.random.split(key)
    return MFParams(
        w=sigma * jax.random.normal(kw, (n_ctx, k), dtype=jnp.float32),
        h=sigma * jax.random.normal(kh, (n_items, k), dtype=jnp.float32),
    )


def phi(params: MFParams) -> jax.Array:
    return params.w


def psi(params: MFParams) -> jax.Array:
    return params.h


def export_psi(params: MFParams) -> jax.Array:
    """ψ table for the retrieval engine (serve/engine.py): (n_items, k)."""
    return params.h


def build_phi(params: MFParams, ctx: jax.Array) -> jax.Array:
    """φ rows for a batch of context ids: (B, k); ⟨φ, ψ_i⟩ = ŷ(c, i)."""
    return jnp.take(params.w, ctx, axis=0)


def predict(params: MFParams, ctx: jax.Array, item: jax.Array) -> jax.Array:
    return jnp.sum(
        jnp.take(params.w, ctx, axis=0) * jnp.take(params.h, item, axis=0), axis=-1
    )


def scores_all(params: MFParams) -> jax.Array:
    """Full |C|×|I| score matrix — only for tests / small-scale eval."""
    return params.w @ params.h.T


def _side_sweep(
    side: jax.Array,        # (n, k) parameters being updated
    other_j: jax.Array,     # (k, k) Gram of the fixed side  (J_I for ctx sweep)
    other_cols_nnz,         # callable f -> (nnz,) ψ_{f}(item of nnz)
    rows_nnz: jax.Array,    # (nnz,) row id (this side) per observation
    alpha: jax.Array,       # (nnz,)
    e: jax.Array,           # (nnz,) residual cache, this side's sort order
    n_rows: int,
    hp: MFHyperParams,
    schedule: Optional[sweeps.SweepSchedule] = None,
    sweep_index: int = 0,
) -> Tuple[jax.Array, jax.Array]:
    """One dimension sweep over one side; returns (new_side, new_e).

    With a ``schedule`` the sweep covers only the scheduled subspace blocks
    for this ``sweep_index`` (iALS++-style); ``None`` is a full pass."""

    def body(f, carry):
        side_m, e = carry
        o_col = other_cols_nnz(f)                      # (nnz,)
        s_col = sweeps.take_col(side_m, f)             # (n,)
        # explicit parts (L'/2, L''/2) from the residual cache
        lp = segment_sum(alpha * e * o_col, rows_nnz, n_rows)
        lpp = segment_sum(alpha * o_col * o_col, rows_nnz, n_rows)
        # implicit parts (R'/2, R''/2) via the opposite Gram — Lemma 3
        rp = side_m @ sweeps.take_col(other_j, f)      # Σ_f' J(f',f)·w_{·,f'}
        rpp = other_j[f, f]
        delta = sweeps.newton_delta(
            sweeps.NewtonParts(lp + hp.alpha0 * rp, lpp + hp.alpha0 * rpp),
            s_col,
            hp.l2,
            hp.eta,
        )
        e = e + jnp.take(delta, rows_nnz) * o_col      # rank-1 residual patch
        return sweeps.put_col(side_m, f, s_col + delta), e

    return sweeps.sweep_columns(
        side.shape[1], body, (side, e), unroll=hp.unroll,
        schedule=schedule, sweep_index=sweep_index,
    )


@partial(jax.jit, static_argnames=("hp", "schedule", "sweep_index"))
def epoch(
    params: MFParams,
    data: Interactions,
    e: jax.Array,
    hp: MFHyperParams,
    schedule: Optional[sweeps.SweepSchedule] = None,
    sweep_index: int = 0,
    weights: Optional[jax.Array] = None,
) -> Tuple[MFParams, jax.Array]:
    """One iCD epoch: W sweep then H sweep over the scheduled columns.

    ``e`` is the context-major residual cache (ŷ−ȳ per observation); callers
    obtain the initial one from :func:`residuals`. ``schedule=None`` is the
    classic full pass over all k columns on both sides; a
    :class:`~repro.core.sweeps.SweepSchedule` restricts/reorders the swept
    subspace blocks (``schedule``/``sweep_index`` are static — rotating or
    randomized schedules trace one program per distinct block plan).

    ``weights`` is an optional (nnz,) per-interaction confidence weight in
    ctx-major order: the observed confidence enters the sweep math purely
    multiplicatively, so a weighted epoch is EXACTLY an epoch over
    ``alpha·w`` (the implicit part stays uniform ``alpha0``). ``None`` is a
    trace-time branch — the unweighted program is byte-identical.
    """
    if weights is not None:
        data = dataclasses.replace(data, alpha=data.alpha * weights)
    w, h = params

    # --- context side: J_I from the fixed item factors -------------------
    j_i = gram(h, implementation=hp.implementation)
    h_cols = lambda f: jnp.take(sweeps.take_col(h, f), data.item)
    w, e = _side_sweep(
        w, j_i, h_cols, data.ctx, data.alpha, e, data.n_ctx, hp,
        schedule, sweep_index,
    )

    # --- item side: J_C from the (just-updated) context factors ----------
    j_c = gram(w, implementation=hp.implementation)
    e_t = sweeps.to_item_major(e, data.t_perm)
    alpha_t = sweeps.to_item_major(data.alpha, data.t_perm)
    w_cols = lambda f: jnp.take(sweeps.take_col(w, f), data.t_ctx)
    h, e_t = _side_sweep(
        h, j_c, w_cols, data.t_item, alpha_t, e_t, data.n_items, hp,
        schedule, sweep_index,
    )
    e = sweeps.to_ctx_major(e_t, data.t_perm)
    return MFParams(w, h), e


def residuals(params: MFParams, data: Interactions) -> jax.Array:
    return sweeps.residuals_from_factors(
        params.w, params.h, data.ctx, data.item, data.y
    )


def objective(params: MFParams, data: Interactions, hp: MFHyperParams) -> jax.Array:
    e = residuals(params, data)
    sq = jnp.sum(params.w**2) + jnp.sum(params.h**2)
    return implicit_objective(params.w, params.h, e, data, hp.alpha0, hp.l2, sq)


def fit(
    params: MFParams,
    data: Interactions,
    hp: MFHyperParams,
    n_epochs: int,
    callback=None,
    schedule: Optional[sweeps.SweepSchedule] = None,
    weights: Optional[jax.Array] = None,
) -> MFParams:
    """Run ``n_epochs`` iCD epochs (host loop; each epoch is one jit call).

    With a ``schedule``, epoch ``ep`` sweeps the schedule's blocks for
    ``sweep_index=ep`` — e.g. ``SweepSchedule('rotating',
    blocks_per_sweep=1)`` turns each "epoch" into one k_b subspace step."""
    e = residuals(params, data)
    for ep in range(n_epochs):
        params, e = epoch(params, data, e, hp, schedule, ep, weights)
        if callback is not None:
            callback(ep, params)
    return params
