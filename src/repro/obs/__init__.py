"""Observability spine: metrics, request tracing, kernel cost accounting.

  metrics.py  label-aware Counter/Gauge/Histogram registry (injectable
              clock, per-instance labels on a process-global default,
              NULL_REGISTRY bare mode, StatsView back-compat mapping)
  trace.py    spans (context-manager + explicit begin/end), parent/child
              links, batcher-ticket correlation
  export.py   JSONL + Prometheus text exposition; Chrome-trace JSON
  costs.py    dispatch-site shim over the kernels/vmem.py analytic cost
              models (HBM bytes / FLOPs / VMEM per kernel dispatch)
  train.py    fit-callback metrics for the training spine (epoch wall
              time, loss trajectory, SweepSchedule block visits)

Threaded through ``serve/`` (batcher, mesh, cluster, engine, publish,
ann), ``launch/serve.py`` (``--metrics-out``/``--trace-out``), the
benches (instrumented-vs-bare overhead hard-gated < 3%), and
``examples/observability.py`` (end-to-end train → serve-under-faults →
Perfetto trace). See ``serve/README.md`` § "Metrics & tracing" for the
metric catalogue and label conventions.
"""
from repro.obs.costs import KernelCostRecorder, cd_sweep_cost, topk_score_cost
from repro.obs.export import (
    chrome_trace,
    metrics_jsonl,
    prometheus_text,
    write_metrics,
    write_trace,
)
from repro.obs.metrics import (
    DEFAULT_BUCKETS,
    NULL_REGISTRY,
    MetricsRegistry,
    StatsView,
    default_registry,
    next_instance_id,
    resolve_registry,
    set_default_registry,
)
from repro.obs.trace import Span, Tracer, trace_for_ticket
from repro.obs.train import compose_callbacks, fit_metrics_callback

__all__ = [
    "DEFAULT_BUCKETS",
    "KernelCostRecorder",
    "MetricsRegistry",
    "NULL_REGISTRY",
    "Span",
    "StatsView",
    "Tracer",
    "cd_sweep_cost",
    "chrome_trace",
    "compose_callbacks",
    "default_registry",
    "fit_metrics_callback",
    "metrics_jsonl",
    "next_instance_id",
    "prometheus_text",
    "resolve_registry",
    "set_default_registry",
    "topk_score_cost",
    "trace_for_ticket",
    "write_metrics",
    "write_trace",
]
