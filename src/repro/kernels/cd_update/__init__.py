from repro.kernels.cd_update.ops import cd_column_update  # noqa: F401
