"""Streaming full-catalogue ranking evaluation (leave-one-out protocol).

Rendle's *Item Recommendation from Implicit Feedback* (2021) makes
sampled-free top-K ranking over the FULL catalogue the evaluation
standard: for every held-out (context, item) pair, rank all n_items and
score Recall@K / NDCG@K of the true item. The naive implementation is a
``(n_eval, n_items)`` score matrix — exactly the array that stops fitting
first at catalogue scale.

This harness never allocates it: evaluation contexts stream in batches of
``batch_rows`` φ rows through the fused ``kernels/topk_score`` kernel
(ψ-table blocks through VMEM, running top-K merge), so the largest live
arrays are the (batch_rows, D) φ tile, the (batch_rows, L) −1-padded
exclude-id tile, and the (batch_rows, K) results. Exclusion rides the
kernel's id-list form (``serve.engine.exclude_ids_from_lists``): the
ψ-block-aligned admissibility slices are built in-VMEM per block, so an
exclude mask never materializes a full-catalogue row — on host OR device —
at any ``n_items``. The per-row metric math is shared with the dense path
(``core.metrics.*_from_topk``), so streaming and dense evaluation are
numerically identical (parity-tested).

Past one device's HBM the same loop runs against a
``serve.cluster.ShardedRetrievalCluster`` (``cluster=``): per batch the
cluster fans the φ tile over the ψ shards and K-way-merges the candidates
— bit-identical top-K to the single-table path, so the metrics are too.

Per-epoch use from the sweep loops: every model's ``fit`` already takes a
``callback(epoch, params)``; :func:`fit_eval_callback` adapts this harness
to that hook so training loops get a Recall/NDCG trajectory without
touching the sweep code.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.metrics import ndcg_from_topk, recall_from_topk
from repro.kernels.topk_score.ops import topk_score
from repro.serve.engine import exclude_ids_from_lists


def ranking_eval(
    phi: jnp.ndarray,             # (n_eval, D) φ rows of the eval contexts
    psi: Optional[jnp.ndarray],   # (n_items, D) ψ table; None with cluster=
    true_items: jnp.ndarray,      # (n_eval,) held-out item per context
    *,
    k: int = 100,
    batch_rows: int = 256,
    exclude: Optional[Sequence] = None,  # per-row id lists to mask (train items)
    block_items: Optional[int] = None,
    cluster=None,                 # serve.cluster.ShardedRetrievalCluster
) -> Dict[str, float]:
    """Leave-one-out Recall@K / NDCG@K over the full catalogue, streamed.

    ``exclude`` is a length-``n_eval`` sequence of per-row item-id arrays
    (each row's training items); per batch they become the kernel's
    −1-padded (batch_rows, L) id tile — the full ``(n_eval, n_items)``
    mask, like the score matrix, never exists in any form.

    ``cluster=`` switches the top-K to a sharded table
    (``cluster.topk_phi``; ``psi`` may be None) — the path past one
    device's HBM, bit-identical results by the cluster's merge contract.
    The cluster may also be the fault-tolerant mesh (``serve/mesh.py``):
    the returned metrics then carry the degradation contract — ``coverage``
    (the minimum over eval batches) and the union of ``dead_ranges`` — so
    an eval that ran against a partially-dead catalogue can never be
    mistaken for a full-catalogue number.
    """
    n_eval = int(phi.shape[0])
    true_items = jnp.asarray(true_items, jnp.int32)
    recall_sum = 0.0
    ndcg_sum = 0.0
    coverage = 1.0
    dead_ranges: set = set()
    for lo in range(0, n_eval, batch_rows):
        hi = min(lo + batch_rows, n_eval)
        eids = None
        if exclude is not None:
            eids = exclude_ids_from_lists(exclude[lo:hi])
        if cluster is not None:
            res = cluster.topk_phi(phi[lo:hi], k=k, exclude_ids=eids)
            top_ids = res.ids if hasattr(res, "ids") else res[1]
            # degraded-cluster contract: metrics over a partially-dead
            # catalogue are labeled, never silently reported as full
            coverage = min(coverage, float(getattr(res, "coverage", 1.0)))
            dead_ranges.update(getattr(res, "dead_ranges", ()))
        else:
            _, top_ids = topk_score(
                phi[lo:hi], psi, k, exclude_ids=eids, block_items=block_items
            )
        truth = true_items[lo:hi]
        b = hi - lo
        recall_sum += float(recall_from_topk(top_ids, truth)) * b
        ndcg_sum += float(ndcg_from_topk(top_ids, truth)) * b
    return {
        f"recall@{k}": recall_sum / max(1, n_eval),
        f"ndcg@{k}": ndcg_sum / max(1, n_eval),
        "k": k,
        "n_eval": n_eval,
        "coverage": coverage,
        "dead_ranges": tuple(sorted(dead_ranges)),
    }


def overlap_recall(approx_ids, oracle_ids) -> float:
    """Mean fraction of the exact oracle's admissible top-K found by an
    approximate retriever — THE metric of the IVF tier (``serve/ann.py``):
    recall@K against the exact path, not against held-out truth. −1 slots
    (inadmissible) in the oracle are ignored; rows whose oracle list is
    empty count as perfectly recalled."""
    approx_ids = np.asarray(approx_ids)
    oracle_ids = np.asarray(oracle_ids)
    total, hit = 0, 0
    for r in range(oracle_ids.shape[0]):
        truth = set(int(i) for i in oracle_ids[r] if i >= 0)
        if not truth:
            continue
        total += len(truth)
        hit += len(truth & set(int(i) for i in approx_ids[r]))
    return hit / total if total else 1.0


def ann_recall_curve(
    index,                        # serve.ann.PsiIndex
    phi: jnp.ndarray,             # (B, D) query rows
    psi: jnp.ndarray,             # (n_items, D) exact oracle table
    *,
    k: int = 100,
    n_probes: Sequence[int] = (1, 2, 4, 8),
    exclude: Optional[Sequence] = None,
) -> list:
    """Recall-vs-probe curve for one :class:`~repro.serve.ann.PsiIndex`:
    for each ``n_probe``, :func:`overlap_recall` of the index's top-K
    against the exact fused kernel over the same ψ table (the oracle the
    ROADMAP's recall-vs-speedup figure plots; the serve bench pairs each
    point with the analytic HBM-byte model). ``exclude`` takes the same
    per-row id lists as :func:`ranking_eval`."""
    eids = exclude_ids_from_lists(exclude) if exclude is not None else None
    _, oracle = topk_score(phi, psi, k, exclude_ids=eids)
    out = []
    for p in n_probes:
        _, ids = index.topk(phi, k, n_probe=int(p), exclude_ids=eids)
        out.append({
            "n_probe": int(p),
            f"recall@{k}": overlap_recall(ids, oracle),
        })
    return out


def fit_eval_callback(
    export: Callable,             # params -> (phi_eval, psi_table)
    true_items,
    *,
    k: int = 100,
    every: int = 1,
    exclude: Optional[Sequence] = None,
    batch_rows: int = 256,
    log: Optional[Callable[[str], None]] = None,
):
    """Adapt :func:`ranking_eval` to the models' ``fit(callback=...)`` hook.

    ``export(params)`` rebuilds the eval-context φ rows and ψ table from
    the current parameters (each model's ``build_phi``/``export_psi``).
    The returned callback appends one metrics dict per evaluated epoch to
    its ``history`` attribute::

        cb = fit_eval_callback(
            lambda p: (mf.build_phi(p, eval_ctx), mf.export_psi(p)),
            true_items, k=100, exclude=train_lists)
        mf.fit(params, data, hp, n_epochs, callback=cb)
        cb.history  # [{'epoch': 0, 'recall@100': ..., 'ndcg@100': ...}, ...]
    """
    history: list = []

    def callback(epoch: int, params) -> None:
        if epoch % every:
            return
        phi_eval, psi_table = export(params)
        res = ranking_eval(
            phi_eval, psi_table, jnp.asarray(np.asarray(true_items)),
            k=k, exclude=exclude, batch_rows=batch_rows,
        )
        res = {"epoch": epoch, **res}
        history.append(res)
        if log is not None:
            log(f"epoch {epoch}: recall@{k}={res[f'recall@{k}']:.4f} "
                f"ndcg@{k}={res[f'ndcg@{k}']:.4f}")

    callback.history = history
    return callback


def model_eval_callback(model, query, true_items, **kw):
    """:func:`fit_eval_callback` through the unified
    :class:`repro.core.models.api.Model` protocol — no per-model export
    plumbing::

        cb = model_eval_callback(model, eval_query, true_items, k=100)
        model.fit(params, n_epochs=5, callback=cb)
    """
    return fit_eval_callback(
        lambda p: (model.build_phi(p, query), model.export_psi(p)),
        true_items, **kw,
    )


def foldin_ranking_eval(
    model,
    params,
    histories: Sequence,          # per-user item-id arrays (observed history)
    true_items,                   # (n_eval,) held-out item per user
    *,
    k: int = 100,
    alpha=None,                   # per-event confidence, broadcast per user
    exclude_history: bool = True,
    batch_rows: int = 256,
    cluster=None,
    **foldin_kw,
) -> Dict[str, float]:
    """Cold-start ranking eval: every user is UNSEEN — their φ row comes
    from the closed-form fold-in (``model.fold_in_user`` against the frozen
    ψ table), then ranks the full catalogue exactly like the warm path.

    This measures what the serving tier actually does for a user with no
    trained embedding (``RetrievalEngine.fold_in_phi``): solve the row
    from the observed ``histories[u]``, then retrieve. With
    ``exclude_history`` the folded-in items are masked at ranking time
    (the leave-one-out protocol — the true item must NOT be in the
    history).
    """
    phi_rows = np.stack([
        model.fold_in_user(
            params, np.asarray(h, np.int64),
            None if alpha is None else np.full(len(h), alpha, np.float32),
            **foldin_kw,
        )
        for h in histories
    ])
    psi = None if cluster is not None else model.export_psi(params)
    return ranking_eval(
        jnp.asarray(phi_rows), psi, jnp.asarray(np.asarray(true_items)),
        k=k, exclude=histories if exclude_history else None,
        batch_rows=batch_rows, cluster=cluster,
    )
