"""Pallas fused multi-column iCD block-sweeps (Algorithm 2/3's f*-loop, blocked).

Four entry points share the "residual cache VMEM-resident across a block of
embedding dimensions" idea; together they cover the whole k-separable model
zoo (paper §5). Each ships in TWO forms — pre-gathered (the caller
materializes a `(C, k_b, D_pad)` Ψ tile in HBM) and IN-KERNEL GATHER
(``*_gather_pallas``: the kernel takes the full `(n_src, m)` ψ slab plus an
`(C, D_pad)` id tile and gathers Ψ rows inside the kernel, so the
`(C, k_b, D_pad)` intermediate never exists):

  ``cd_block_sweep_pallas``          — MF-style block sweep: the R' slab is
        patched with a SHARED (k_b, k_b) Gram block (R'' is the scalar
        J(f,f)). Exact for models whose φ-gradient is one-hot (MF).
  ``cd_block_sweep_rowpatch_pallas`` — general block sweep: the R'/R''
        coupling is a PER-ROW (bc, k_b, k_b) patch tensor P with
        P[r, j, f] = ∂(R'_f/2)/∂θ_{r,j} and diagonal P[r, f, f] = R''_f/2.
        Exact for PARAFAC (P = J ⊙ K_row, eqs. 37–38) and Tucker
        (P = Σ_g D^f_g (D^j J)_g per row, eq. 41 regime).
  ``cd_slab_reduce_pallas``          — per-field slab moments for the
        feature-based models (MFSI/FM, Algorithm 3): one e/α stream yields
        Q[r, j] = Σ_d α e ψ_j and P[r, i, j] = Σ_d α ψ_i ψ_j for all block
        columns, the per-context caches (q, p2, p1, p0, cross-dim coupling)
        the field-level Newton steps consume.
  ``cd_resid_patch_pallas``          — rank-k_b residual patch
        e += Σ_j Δφ_j·ψ_j closing a feature-model block: one e stream
        instead of one per dimension.

Lineage: generalizes ``kernels/cd_update`` (one embedding dimension per
dispatch) to a block of ``k_b`` dimensions per grid step. The per-column
kernel re-streams the `(C, D_pad)` residual cache ``e`` and confidence
tensor ``α`` from HBM once per column — k round-trips per sweep — even
though the per-column compute is tiny. Here the `(block_ctx, D_pad)` tiles
of ``e`` and ``α`` are loaded into VMEM ONCE and stay resident while all
``k_b`` Newton steps run in an in-register ``lax.fori_loop``:

  inputs  (per block): Ψ tile  (bc, k_b, D_pad) — pre-gathered ψ_f(item)
                                                  for every column in block
                       α tile, e tile (bc, D_pad)
                       W slab  (bc, k_b), R' slab (bc, k_b) ≡ (W·J)[:, blk]
                       J block (k_b, k_b)       — diagonal block of the Gram
  compute, for j = 0..k_b−1 (sequential — exact Gauss–Seidel):
           L'/2  = Σ_d α·e·ψ_j            (VPU row reduce)
           L''/2 = Σ_d α·ψ_j²
           Δ     = −η·(L'/2 + α₀R'_j/2 + λw_j)/(L''/2 + α₀J(j,j) + λ)
           e    += Δ·ψ_j                  (rank-1 residual patch, in VMEM)
           R'   += Δ·J(j,·)               (Gauss–Seidel patch: later columns
                                           see the updated w_j through R')
  outputs: W slab (bc, k_b), e (bc, D_pad)

The R' patch is what preserves exact per-column semantics: recomputing
R'_f' = (W·J)[:, f'] after w_j moved by Δ adds exactly Δ·J(j, f'), so the
fused block reproduces the per-column path that recomputes R' from the
updated W before every column.

HBM traffic per sweep (vs per-column): ψ is still read once per column
(k·C·D_pad total, irreducible), but α/e drop from k reads (+k writes of e)
to ⌈k/k_b⌉ — the sweep's (C, D_pad) traffic shrinks ~4/(1+3/k_b)× (≈2.9×
at k_b=8). VMEM per step: (k_b+2)·bc·D_pad·4 B ≈ 5 MiB at bc=128,
D_pad=1024, k_b=8.

HBM capacity: the pre-gathered Ψ tile is a (C, k_b, D_pad) array — k_b×
the residual grid — that must be materialized per block dispatch, so peak
footprint grows ~k_b× over the per-column path. The ``*_gather`` variants
remove the intermediate: the ψ slab is a fixed `(n_src, m)` VMEM resident
(`n_src·m·4 B`, ≪ the `(C, m, D_pad)` tile whenever n_src ≪ C·D_pad) and
each column is gathered per row through the id tile —
``psi_j[r, d] = tab[ids[r, d], j]`` — in interpret-safe form (a value-level
``jnp.take``; the compiled-TPU lowering via ``pltpu`` per-row DMA is the
ROADMAP follow-up). Padding id convention: table callers point padding
slots at row 0 (α=0 keeps them inert, matching the pre-gathered tiles);
flat-nnz callers (the tensor/field pseudo-ψ paths) append a zero sentinel
row and point padding at it, reproducing ``PaddedGroup.scatter_blk``'s
zeros exactly.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import vmem


def _sweep_kernel(alpha0, l2, eta, k_b, psi_ref, alpha_ref, e_ref, w_ref,
                  r1_ref, jblk_ref, w_out_ref, e_out_ref):
    psi = psi_ref[...].astype(jnp.float32)      # (bc, k_b, d_pad)
    alpha = alpha_ref[...].astype(jnp.float32)  # (bc, d_pad)
    e = e_ref[...].astype(jnp.float32)          # (bc, d_pad)
    w = w_ref[...].astype(jnp.float32)          # (bc, k_b)
    r1 = r1_ref[...].astype(jnp.float32)        # (bc, k_b)
    jblk = jblk_ref[...].astype(jnp.float32)    # (k_b, k_b)

    def newton(j, carry):
        w, r1, e = carry
        psi_j = jax.lax.dynamic_index_in_dim(psi, j, axis=1, keepdims=False)
        w_j = jax.lax.dynamic_slice_in_dim(w, j, 1, axis=1)       # (bc, 1)
        r1_j = jax.lax.dynamic_slice_in_dim(r1, j, 1, axis=1)     # (bc, 1)
        j_row = jax.lax.dynamic_slice_in_dim(jblk, j, 1, axis=0)  # (1, k_b)
        jff = jax.lax.dynamic_slice_in_dim(j_row, j, 1, axis=1)   # (1, 1)

        lp = jnp.sum(alpha * e * psi_j, axis=1, keepdims=True)            # L'/2
        lpp = jnp.sum(alpha * psi_j * psi_j, axis=1, keepdims=True)       # L''/2
        num = lp + alpha0 * r1_j + l2 * w_j
        den = lpp + alpha0 * jff + l2
        delta = -eta * num / jnp.maximum(den, 1e-12)

        w = jax.lax.dynamic_update_slice_in_dim(w, w_j + delta, j, axis=1)
        e = e + delta * psi_j
        r1 = r1 + delta * j_row
        return w, r1, e

    w, r1, e = jax.lax.fori_loop(0, k_b, newton, (w, r1, e))
    w_out_ref[...] = w
    e_out_ref[...] = e


def cd_block_sweep_pallas(
    psi_blk: jax.Array,  # (C, k_b, D_pad) pre-gathered ψ, one slice per column
    alpha: jax.Array,    # (C, D_pad), 0 on padding
    e: jax.Array,        # (C, D_pad) residual cache
    w_blk: jax.Array,    # (C, k_b) parameter slab W[:, f0:f0+k_b]
    r1_blk: jax.Array,   # (C, k_b) R'/2 slab (W·J)[:, f0:f0+k_b]
    j_blk: jax.Array,    # (k_b, k_b) diagonal Gram block J[f0:f0+k_b, f0:f0+k_b]
    *,
    alpha0: float,
    l2: float,
    eta: float = 1.0,
    block_ctx: int | None = None,
    interpret: bool = True,
):
    c, k_b, d_pad = psi_blk.shape
    if block_ctx is None:  # shared VMEM-budget fit (kernels/vmem.py)
        block_ctx = vmem.cd_sweep_block_ctx(d_pad, k_b, n_rows=c)
    c_pad = -(-c // block_ctx) * block_ctx
    if c_pad != c:
        rows = (0, c_pad - c)
        psi_blk = jnp.pad(psi_blk, (rows, (0, 0), (0, 0)))
        alpha = jnp.pad(alpha, (rows, (0, 0)))
        e = jnp.pad(e, (rows, (0, 0)))
        w_blk = jnp.pad(w_blk, (rows, (0, 0)))
        r1_blk = jnp.pad(r1_blk, (rows, (0, 0)))

    e = e.astype(jnp.float32)  # exact dtype match for the e→e_out alias

    grid = (c_pad // block_ctx,)
    w_new, e_new = pl.pallas_call(
        partial(_sweep_kernel, alpha0, l2, eta, k_b),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_ctx, k_b, d_pad), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_ctx, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, k_b), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, k_b), lambda i: (i, 0)),
            pl.BlockSpec((k_b, k_b), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_ctx, k_b), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, d_pad), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c_pad, k_b), jnp.float32),
            jax.ShapeDtypeStruct((c_pad, d_pad), jnp.float32),
        ],
        input_output_aliases={2: 1},  # e updates in place — no fresh HBM copy
        interpret=interpret,
    )(psi_blk, alpha, e, w_blk, r1_blk, j_blk)
    return w_new[:c], e_new[:c]


def _sweep_rowpatch_kernel(alpha0, l2, eta, k_b, psi_ref, alpha_ref, e_ref,
                           w_ref, r1_ref, p_ref, w_out_ref, e_out_ref):
    """Block sweep with a per-row R' patch tensor (PARAFAC/Tucker modes)."""
    psi = psi_ref[...].astype(jnp.float32)      # (bc, k_b, d_pad)
    alpha = alpha_ref[...].astype(jnp.float32)  # (bc, d_pad)
    e = e_ref[...].astype(jnp.float32)          # (bc, d_pad)
    w = w_ref[...].astype(jnp.float32)          # (bc, k_b)
    r1 = r1_ref[...].astype(jnp.float32)        # (bc, k_b)
    p = p_ref[...].astype(jnp.float32)          # (bc, k_b, k_b)

    def newton(j, carry):
        w, r1, e = carry
        psi_j = jax.lax.dynamic_index_in_dim(psi, j, axis=1, keepdims=False)
        w_j = jax.lax.dynamic_slice_in_dim(w, j, 1, axis=1)       # (bc, 1)
        r1_j = jax.lax.dynamic_slice_in_dim(r1, j, 1, axis=1)     # (bc, 1)
        p_j = jax.lax.dynamic_index_in_dim(p, j, axis=1, keepdims=False)  # (bc, k_b)
        p_jj = jax.lax.dynamic_slice_in_dim(p_j, j, 1, axis=1)    # (bc, 1) = R''/2

        lp = jnp.sum(alpha * e * psi_j, axis=1, keepdims=True)            # L'/2
        lpp = jnp.sum(alpha * psi_j * psi_j, axis=1, keepdims=True)       # L''/2
        num = lp + alpha0 * r1_j + l2 * w_j
        den = lpp + alpha0 * p_jj + l2
        delta = -eta * num / jnp.maximum(den, 1e-12)

        w = jax.lax.dynamic_update_slice_in_dim(w, w_j + delta, j, axis=1)
        e = e + delta * psi_j
        r1 = r1 + delta * p_j     # Gauss–Seidel: row-local coupling patch
        return w, r1, e

    w, r1, e = jax.lax.fori_loop(0, k_b, newton, (w, r1, e))
    w_out_ref[...] = w
    e_out_ref[...] = e


def cd_block_sweep_rowpatch_pallas(
    psi_blk: jax.Array,  # (C, k_b, D_pad) pseudo-ψ per block column
    alpha: jax.Array,    # (C, D_pad), 0 on padding
    e: jax.Array,        # (C, D_pad) residual cache
    w_blk: jax.Array,    # (C, k_b) parameter slab θ[:, f0:f0+k_b]
    r1_blk: jax.Array,   # (C, k_b) R'/2 slab
    p_blk: jax.Array,    # (C, k_b, k_b) per-row patch tensor; diag = R''/2
    *,
    alpha0: float,
    l2: float,
    eta: float = 1.0,
    block_ctx: int | None = None,
    interpret: bool = True,
):
    """General k-separable block sweep: like :func:`cd_block_sweep_pallas`
    but the regularizer coupling between block columns is ROW-dependent —
    P[r, j, f] is both the Gauss–Seidel R' patch coefficient and (on the
    diagonal) the per-row R''/2 of eqs. (14/19/38)."""
    c, k_b, d_pad = psi_blk.shape
    if block_ctx is None:  # shared VMEM-budget fit (kernels/vmem.py)
        block_ctx = vmem.cd_sweep_block_ctx(d_pad, k_b, n_rows=c)
    c_pad = -(-c // block_ctx) * block_ctx
    if c_pad != c:
        rows = (0, c_pad - c)
        psi_blk = jnp.pad(psi_blk, (rows, (0, 0), (0, 0)))
        alpha = jnp.pad(alpha, (rows, (0, 0)))
        e = jnp.pad(e, (rows, (0, 0)))
        w_blk = jnp.pad(w_blk, (rows, (0, 0)))
        r1_blk = jnp.pad(r1_blk, (rows, (0, 0)))
        p_blk = jnp.pad(p_blk, (rows, (0, 0), (0, 0)))

    e = e.astype(jnp.float32)  # exact dtype match for the e→e_out alias

    grid = (c_pad // block_ctx,)
    w_new, e_new = pl.pallas_call(
        partial(_sweep_rowpatch_kernel, alpha0, l2, eta, k_b),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_ctx, k_b, d_pad), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_ctx, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, k_b), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, k_b), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, k_b, k_b), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_ctx, k_b), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, d_pad), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c_pad, k_b), jnp.float32),
            jax.ShapeDtypeStruct((c_pad, d_pad), jnp.float32),
        ],
        input_output_aliases={2: 1},
        interpret=interpret,
    )(psi_blk, alpha, e, w_blk, r1_blk, p_blk)
    return w_new[:c], e_new[:c]


def _slab_reduce_kernel(psi_ref, alpha_ref, e_ref, q_ref, p_ref):
    """Per-row moment slabs over a block of m pseudo-ψ columns."""
    psi = psi_ref[...].astype(jnp.float32)      # (bc, m, d_pad)
    alpha = alpha_ref[...].astype(jnp.float32)  # (bc, d_pad)
    e = e_ref[...].astype(jnp.float32)          # (bc, d_pad)
    q_ref[...] = jnp.einsum("bmd,bd->bm", psi, alpha * e)
    p_ref[...] = jnp.einsum("bmd,bnd->bmn", psi * alpha[:, None, :], psi)


def cd_slab_reduce_pallas(
    psi_blk: jax.Array,  # (C, m, D_pad) pseudo-ψ columns (incl. any special col)
    alpha: jax.Array,    # (C, D_pad), 0 on padding
    e: jax.Array,        # (C, D_pad) residual cache (read-only here)
    *,
    block_ctx: int | None = None,
    interpret: bool = True,
):
    """Field-model slab moments in ONE e/α stream (Algorithm 3 caches):

        Q[r, j]    = Σ_d α·e·ψ_j      (q / u caches per block column)
        P[r, i, j] = Σ_d α·ψ_i·ψ_j    (p2 on the diagonal, p1/p0 with a
                                       special column, cross-dim coupling
                                       for the within-block cache patches)

    The per-column path recomputes q (and u for FM) from HBM once per
    dimension; this fuses all m columns of a block into one pass."""
    c, m, d_pad = psi_blk.shape
    if block_ctx is None:  # shared VMEM-budget fit (kernels/vmem.py)
        block_ctx = vmem.cd_sweep_block_ctx(d_pad, m, n_rows=c)
    c_pad = -(-c // block_ctx) * block_ctx
    if c_pad != c:
        rows = (0, c_pad - c)
        psi_blk = jnp.pad(psi_blk, (rows, (0, 0), (0, 0)))
        alpha = jnp.pad(alpha, (rows, (0, 0)))
        e = jnp.pad(e, (rows, (0, 0)))

    grid = (c_pad // block_ctx,)
    q, p = pl.pallas_call(
        _slab_reduce_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_ctx, m, d_pad), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_ctx, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, d_pad), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_ctx, m), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, m, m), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c_pad, m), jnp.float32),
            jax.ShapeDtypeStruct((c_pad, m, m), jnp.float32),
        ],
        interpret=interpret,
    )(psi_blk, alpha, e)
    return q[:c], p[:c]


def _resid_patch_kernel(psi_ref, e_ref, dphi_ref, e_out_ref):
    psi = psi_ref[...].astype(jnp.float32)      # (bc, m, d_pad)
    e = e_ref[...].astype(jnp.float32)          # (bc, d_pad)
    dphi = dphi_ref[...].astype(jnp.float32)    # (bc, m)
    e_out_ref[...] = e + jnp.einsum("bm,bmd->bd", dphi, psi)


def cd_resid_patch_pallas(
    psi_blk: jax.Array,  # (C, m, D_pad)
    e: jax.Array,        # (C, D_pad) residual cache
    dphi_blk: jax.Array, # (C, m) per-row Δφ of each block column
    *,
    block_ctx: int | None = None,
    interpret: bool = True,
):
    """Rank-m residual patch e += Σ_j Δφ_j·ψ_j in one e stream (the closing
    half of a feature-model block; the per-column path pays one stream per
    dimension)."""
    c, m, d_pad = psi_blk.shape
    if block_ctx is None:  # shared VMEM-budget fit (kernels/vmem.py)
        block_ctx = vmem.cd_sweep_block_ctx(d_pad, m, n_rows=c)
    c_pad = -(-c // block_ctx) * block_ctx
    if c_pad != c:
        rows = (0, c_pad - c)
        psi_blk = jnp.pad(psi_blk, (rows, (0, 0), (0, 0)))
        e = jnp.pad(e, (rows, (0, 0)))
        dphi_blk = jnp.pad(dphi_blk, (rows, (0, 0)))

    e = e.astype(jnp.float32)  # exact dtype match for the e→e_out alias

    grid = (c_pad // block_ctx,)
    e_new = pl.pallas_call(
        _resid_patch_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_ctx, m, d_pad), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_ctx, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, m), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_ctx, d_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c_pad, d_pad), jnp.float32),
        input_output_aliases={1: 0},
        interpret=interpret,
    )(psi_blk, e, dphi_blk)
    return e_new[:c]


# ======================================================================
# In-kernel Ψ gather variants: the ψ slab (n_src, m) stays VMEM-resident
# per dispatch and rows are gathered through an (C, D_pad) id tile — the
# (C, m, D_pad) pre-gathered intermediate never exists in HBM.
# ======================================================================
def _pad_gather_operands(psi_tab, ids, row_arrays, block_ctx):
    """Pad the ψ slab to a sublane multiple and the row-major operands to
    the kernel row tile. Slab padding rows are zeros appended beyond every
    valid id, so gathers never see them; row padding has α=0 ⇒ inert."""
    n_src = psi_tab.shape[0]
    n_src_pad = max(8, -(-n_src // 8) * 8)
    if n_src_pad != n_src:
        psi_tab = jnp.pad(psi_tab, ((0, n_src_pad - n_src), (0, 0)))
    c = ids.shape[0]
    c_pad = -(-c // block_ctx) * block_ctx
    if c_pad != c:
        rows = (0, c_pad - c)
        ids = jnp.pad(ids, (rows, (0, 0)))
        row_arrays = [jnp.pad(a, (rows,) + ((0, 0),) * (a.ndim - 1))
                      for a in row_arrays]
    return psi_tab, ids, row_arrays, c_pad


def _sweep_gather_kernel(alpha0, l2, eta, k_b, tab_ref, ids_ref, alpha_ref,
                         e_ref, w_ref, r1_ref, jblk_ref, w_out_ref, e_out_ref):
    tab = tab_ref[...].astype(jnp.float32)      # (n_src_pad, k_b) ψ slab
    ids = ids_ref[...]                          # (bc, d_pad) int32
    alpha = alpha_ref[...].astype(jnp.float32)  # (bc, d_pad)
    e = e_ref[...].astype(jnp.float32)          # (bc, d_pad)
    w = w_ref[...].astype(jnp.float32)          # (bc, k_b)
    r1 = r1_ref[...].astype(jnp.float32)        # (bc, k_b)
    jblk = jblk_ref[...].astype(jnp.float32)    # (k_b, k_b)

    def newton(j, carry):
        w, r1, e = carry
        tab_j = jax.lax.dynamic_index_in_dim(tab, j, axis=1, keepdims=False)
        psi_j = jnp.take(tab_j, ids, mode="clip")  # per-row gather (bc, d_pad)
        w_j = jax.lax.dynamic_slice_in_dim(w, j, 1, axis=1)       # (bc, 1)
        r1_j = jax.lax.dynamic_slice_in_dim(r1, j, 1, axis=1)     # (bc, 1)
        j_row = jax.lax.dynamic_slice_in_dim(jblk, j, 1, axis=0)  # (1, k_b)
        jff = jax.lax.dynamic_slice_in_dim(j_row, j, 1, axis=1)   # (1, 1)

        lp = jnp.sum(alpha * e * psi_j, axis=1, keepdims=True)            # L'/2
        lpp = jnp.sum(alpha * psi_j * psi_j, axis=1, keepdims=True)       # L''/2
        num = lp + alpha0 * r1_j + l2 * w_j
        den = lpp + alpha0 * jff + l2
        delta = -eta * num / jnp.maximum(den, 1e-12)

        w = jax.lax.dynamic_update_slice_in_dim(w, w_j + delta, j, axis=1)
        e = e + delta * psi_j
        r1 = r1 + delta * j_row
        return w, r1, e

    w, r1, e = jax.lax.fori_loop(0, k_b, newton, (w, r1, e))
    w_out_ref[...] = w
    e_out_ref[...] = e


def cd_block_sweep_gather_pallas(
    psi_tab: jax.Array,  # (n_src, k_b) ψ slab — columns [f0, f0+k_b) of ψ
    ids: jax.Array,      # (C, D_pad) int32 row ids into psi_tab; pad → 0/α=0
    alpha: jax.Array,    # (C, D_pad), 0 on padding
    e: jax.Array,        # (C, D_pad) residual cache
    w_blk: jax.Array,    # (C, k_b) parameter slab W[:, f0:f0+k_b]
    r1_blk: jax.Array,   # (C, k_b) R'/2 slab (W·J)[:, f0:f0+k_b]
    j_blk: jax.Array,    # (k_b, k_b) diagonal Gram block
    *,
    alpha0: float,
    l2: float,
    eta: float = 1.0,
    block_ctx: int | None = None,
    interpret: bool = True,
):
    """:func:`cd_block_sweep_pallas` with the Ψ gather folded in-kernel."""
    c, d_pad = ids.shape
    n_src, k_b = psi_tab.shape
    if block_ctx is None:  # shared VMEM-budget fit (kernels/vmem.py)
        block_ctx = vmem.cd_sweep_gather_block_ctx(d_pad, k_b, n_src, n_rows=c)
    psi_tab, ids, (alpha, e, w_blk, r1_blk), c_pad = _pad_gather_operands(
        psi_tab, ids, [alpha, e, w_blk, r1_blk], block_ctx
    )
    n_src_pad = psi_tab.shape[0]

    e = e.astype(jnp.float32)  # exact dtype match for the e→e_out alias

    grid = (c_pad // block_ctx,)
    w_new, e_new = pl.pallas_call(
        partial(_sweep_gather_kernel, alpha0, l2, eta, k_b),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_src_pad, k_b), lambda i: (0, 0)),  # resident slab
            pl.BlockSpec((block_ctx, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, k_b), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, k_b), lambda i: (i, 0)),
            pl.BlockSpec((k_b, k_b), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_ctx, k_b), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, d_pad), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c_pad, k_b), jnp.float32),
            jax.ShapeDtypeStruct((c_pad, d_pad), jnp.float32),
        ],
        input_output_aliases={3: 1},  # e updates in place
        interpret=interpret,
    )(psi_tab, ids, alpha, e, w_blk, r1_blk, j_blk)
    return w_new[:c], e_new[:c]


def _sweep_rowpatch_gather_kernel(alpha0, l2, eta, k_b, tab_ref, ids_ref,
                                  alpha_ref, e_ref, w_ref, r1_ref, p_ref,
                                  w_out_ref, e_out_ref):
    tab = tab_ref[...].astype(jnp.float32)      # (n_src_pad, k_b) ψ slab
    ids = ids_ref[...]                          # (bc, d_pad) int32
    alpha = alpha_ref[...].astype(jnp.float32)  # (bc, d_pad)
    e = e_ref[...].astype(jnp.float32)          # (bc, d_pad)
    w = w_ref[...].astype(jnp.float32)          # (bc, k_b)
    r1 = r1_ref[...].astype(jnp.float32)        # (bc, k_b)
    p = p_ref[...].astype(jnp.float32)          # (bc, k_b, k_b)

    def newton(j, carry):
        w, r1, e = carry
        tab_j = jax.lax.dynamic_index_in_dim(tab, j, axis=1, keepdims=False)
        psi_j = jnp.take(tab_j, ids, mode="clip")  # per-row gather (bc, d_pad)
        w_j = jax.lax.dynamic_slice_in_dim(w, j, 1, axis=1)       # (bc, 1)
        r1_j = jax.lax.dynamic_slice_in_dim(r1, j, 1, axis=1)     # (bc, 1)
        p_j = jax.lax.dynamic_index_in_dim(p, j, axis=1, keepdims=False)  # (bc, k_b)
        p_jj = jax.lax.dynamic_slice_in_dim(p_j, j, 1, axis=1)    # (bc, 1) = R''/2

        lp = jnp.sum(alpha * e * psi_j, axis=1, keepdims=True)            # L'/2
        lpp = jnp.sum(alpha * psi_j * psi_j, axis=1, keepdims=True)       # L''/2
        num = lp + alpha0 * r1_j + l2 * w_j
        den = lpp + alpha0 * p_jj + l2
        delta = -eta * num / jnp.maximum(den, 1e-12)

        w = jax.lax.dynamic_update_slice_in_dim(w, w_j + delta, j, axis=1)
        e = e + delta * psi_j
        r1 = r1 + delta * p_j
        return w, r1, e

    w, r1, e = jax.lax.fori_loop(0, k_b, newton, (w, r1, e))
    w_out_ref[...] = w
    e_out_ref[...] = e


def cd_block_sweep_rowpatch_gather_pallas(
    psi_tab: jax.Array,  # (n_src, k_b) pseudo-ψ slab (flat nnz values + a
    #                      zero sentinel row for padding slots)
    ids: jax.Array,      # (C, D_pad) int32 rows into psi_tab
    alpha: jax.Array,    # (C, D_pad), 0 on padding
    e: jax.Array,        # (C, D_pad) residual cache
    w_blk: jax.Array,    # (C, k_b)
    r1_blk: jax.Array,   # (C, k_b) R'/2 slab
    p_blk: jax.Array,    # (C, k_b, k_b) per-row patch tensor; diag = R''/2
    *,
    alpha0: float,
    l2: float,
    eta: float = 1.0,
    block_ctx: int | None = None,
    interpret: bool = True,
):
    """:func:`cd_block_sweep_rowpatch_pallas` with the pseudo-ψ scatter
    (``PaddedGroup.scatter_blk``) folded in-kernel as a flat-nnz gather."""
    c, d_pad = ids.shape
    n_src, k_b = psi_tab.shape
    if block_ctx is None:  # shared VMEM-budget fit (kernels/vmem.py)
        block_ctx = vmem.cd_sweep_gather_block_ctx(d_pad, k_b, n_src, n_rows=c)
    psi_tab, ids, (alpha, e, w_blk, r1_blk, p_blk), c_pad = _pad_gather_operands(
        psi_tab, ids, [alpha, e, w_blk, r1_blk, p_blk], block_ctx
    )
    n_src_pad = psi_tab.shape[0]

    e = e.astype(jnp.float32)  # exact dtype match for the e→e_out alias

    grid = (c_pad // block_ctx,)
    w_new, e_new = pl.pallas_call(
        partial(_sweep_rowpatch_gather_kernel, alpha0, l2, eta, k_b),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_src_pad, k_b), lambda i: (0, 0)),  # resident slab
            pl.BlockSpec((block_ctx, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, k_b), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, k_b), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, k_b, k_b), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_ctx, k_b), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, d_pad), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c_pad, k_b), jnp.float32),
            jax.ShapeDtypeStruct((c_pad, d_pad), jnp.float32),
        ],
        input_output_aliases={3: 1},
        interpret=interpret,
    )(psi_tab, ids, alpha, e, w_blk, r1_blk, p_blk)
    return w_new[:c], e_new[:c]


def _slab_reduce_gather_kernel(tab_ref, ids_ref, alpha_ref, e_ref, q_ref, p_ref):
    tab = tab_ref[...].astype(jnp.float32)      # (n_src_pad, m) ψ slab
    ids = ids_ref[...]                          # (bc, d_pad) int32
    alpha = alpha_ref[...].astype(jnp.float32)  # (bc, d_pad)
    e = e_ref[...].astype(jnp.float32)          # (bc, d_pad)
    psi_t = jnp.take(tab, ids, axis=0, mode="clip")  # tile (bc, d_pad, m)
    q_ref[...] = jnp.einsum("bdm,bd->bm", psi_t, alpha * e)
    p_ref[...] = jnp.einsum("bdm,bdn->bmn", psi_t * alpha[:, :, None], psi_t)


def cd_slab_reduce_gather_pallas(
    psi_tab: jax.Array,  # (n_src, m) pseudo-ψ slab (incl. any special col)
    ids: jax.Array,      # (C, D_pad) int32 rows into psi_tab
    alpha: jax.Array,    # (C, D_pad), 0 on padding
    e: jax.Array,        # (C, D_pad) residual cache (read-only here)
    *,
    block_ctx: int | None = None,
    interpret: bool = True,
):
    """:func:`cd_slab_reduce_pallas` with the Ψ gather folded in-kernel.
    The gathered (bc, d_pad, m) tile is a kernel-internal temporary — it
    never lands in HBM (α=0 padding keeps gathered padding slots inert)."""
    c, d_pad = ids.shape
    n_src, m = psi_tab.shape
    if block_ctx is None:  # shared VMEM-budget fit (kernels/vmem.py)
        block_ctx = vmem.cd_sweep_gather_block_ctx(
            d_pad, m, n_src, n_rows=c, hold_tile=True
        )
    psi_tab, ids, (alpha, e), c_pad = _pad_gather_operands(
        psi_tab, ids, [alpha, e], block_ctx
    )
    n_src_pad = psi_tab.shape[0]

    grid = (c_pad // block_ctx,)
    q, p = pl.pallas_call(
        _slab_reduce_gather_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_src_pad, m), lambda i: (0, 0)),  # resident slab
            pl.BlockSpec((block_ctx, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, d_pad), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_ctx, m), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, m, m), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c_pad, m), jnp.float32),
            jax.ShapeDtypeStruct((c_pad, m, m), jnp.float32),
        ],
        interpret=interpret,
    )(psi_tab, ids, alpha, e)
    return q[:c], p[:c]


def _resid_patch_gather_kernel(m, tab_ref, ids_ref, e_ref, dphi_ref, e_out_ref):
    tab = tab_ref[...].astype(jnp.float32)      # (n_src_pad, m) ψ slab
    ids = ids_ref[...]                          # (bc, d_pad) int32
    e = e_ref[...].astype(jnp.float32)          # (bc, d_pad)
    dphi = dphi_ref[...].astype(jnp.float32)    # (bc, m)

    def add_col(j, e):
        tab_j = jax.lax.dynamic_index_in_dim(tab, j, axis=1, keepdims=False)
        psi_j = jnp.take(tab_j, ids, mode="clip")  # per-row gather (bc, d_pad)
        dphi_j = jax.lax.dynamic_slice_in_dim(dphi, j, 1, axis=1)  # (bc, 1)
        return e + dphi_j * psi_j

    e_out_ref[...] = jax.lax.fori_loop(0, m, add_col, e)


def cd_resid_patch_gather_pallas(
    psi_tab: jax.Array,  # (n_src, m) ψ slab
    ids: jax.Array,      # (C, D_pad) int32 rows into psi_tab
    e: jax.Array,        # (C, D_pad) residual cache
    dphi_blk: jax.Array, # (C, m) per-row Δφ of each block column
    *,
    block_ctx: int | None = None,
    interpret: bool = True,
):
    """:func:`cd_resid_patch_pallas` with the Ψ gather folded in-kernel
    (one column gathered at a time — no (bc, m, d_pad) temporary)."""
    c, d_pad = ids.shape
    n_src, m = psi_tab.shape
    if block_ctx is None:  # shared VMEM-budget fit (kernels/vmem.py)
        block_ctx = vmem.cd_sweep_gather_block_ctx(d_pad, m, n_src, n_rows=c)
    psi_tab, ids, (e, dphi_blk), c_pad = _pad_gather_operands(
        psi_tab, ids, [e, dphi_blk], block_ctx
    )
    n_src_pad = psi_tab.shape[0]

    e = e.astype(jnp.float32)  # exact dtype match for the e→e_out alias

    grid = (c_pad // block_ctx,)
    e_new = pl.pallas_call(
        partial(_resid_patch_gather_kernel, m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_src_pad, m), lambda i: (0, 0)),  # resident slab
            pl.BlockSpec((block_ctx, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_ctx, m), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_ctx, d_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c_pad, d_pad), jnp.float32),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(psi_tab, ids, e, dphi_blk)
    return e_new[:c]
