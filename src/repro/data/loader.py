"""Data loading: host-sharded batch iterators + the MovieLens-class loader.

Sharded iterators: each host yields only its slice of the global batch
(slice index = ``jax.process_index()``); on a pod the per-host arrays are
assembled into globally-sharded jax.Arrays by the launcher via
``jax.make_array_from_process_local_data``. In this single-process container
the iterators degenerate to the full batch, same code path.

MovieLens-class loading: :func:`load_movielens` reads a ``u.data``-style
ratings file (``user item value timestamp`` per line) from an explicit path
or the cache directory, falling back to a DETERMINISTIC synthetic event log
(written through the same cache file, so the parse path is always the one
exercised). :func:`frequency_interactions` collapses the event log into
unique ``(user, item)`` cells with Hu-et-al. frequency confidence — the
source of the per-interaction ``weights=`` vectors the training spine
threads end to end.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Iterator, Optional, Tuple

import jax
import numpy as np

from repro.core.implicit import confidence_weights, frequency_confidence
from repro.sparse.interactions import Interactions, build_interactions


def _host_slice(global_batch: int) -> slice:
    """This host's contiguous slice of a ``global_batch``-sized batch.

    Balanced split: host ``i`` takes ``[i·n//H, (i+1)·n//H)`` so the union
    over hosts covers every element even when ``H`` does not divide ``n``
    (the old ``n // H`` truncation silently dropped the tail of final
    partial batches — see ``test_host_slice_partial_batches``).
    """
    n_hosts = jax.process_count()
    i = jax.process_index()
    lo = (i * global_batch) // n_hosts
    hi = ((i + 1) * global_batch) // n_hosts
    return slice(lo, hi)


def interaction_stream(
    ds, *, batch_events: int = 1024, start: int = 0
) -> Iterator[Dict[str, np.ndarray]]:
    """Time-ordered replay of a
    :class:`~repro.data.synthetic.SyntheticImplicitDataset`: yields the
    ``(user, item, t)`` event log in arrival order, ``batch_events`` at a
    time — the traffic source for the continual-learning loop (fold-in +
    delta ψ publish; see ``examples/continual_learning.py``).

    Unlike the epoch loaders this iterator is FINITE (a log replay, not a
    sampler) and the final partial batch is yielded. Each host takes its
    contiguous slice of every batch; in a single-process container that
    degenerates to the full batch.
    """
    events = np.asarray(ds.events)
    for lo in range(int(start), len(events), int(batch_events)):
        chunk = events[lo : lo + batch_events]
        sl = _host_slice(len(chunk))
        part = chunk[sl] if jax.process_count() > 1 else chunk
        yield {
            "ctx": part[:, 0].astype(np.int32),
            "item": part[:, 1].astype(np.int32),
            "t": part[:, 2].astype(np.int64),
        }


def sharded_batches(
    make_batch, global_batch: int, seed: int = 0
) -> Iterator[Dict[str, np.ndarray]]:
    """Generic host-sharded iterator: make_batch(rng, n) → dict of arrays."""
    rng = np.random.default_rng(seed + jax.process_index())
    sl = _host_slice(global_batch)
    n = sl.stop - sl.start
    while True:
        yield make_batch(rng, n)


# ---------------------------------------------------- MovieLens-class -------

@dataclasses.dataclass(frozen=True)
class ImplicitLog:
    """Raw per-event implicit log, pre-:class:`Interactions`.

    ``value`` is the event's count increment (1 for a plain view; a rating
    parsed from a MovieLens file plays the same role — a frequency proxy
    for the confidence derivation).
    """

    user: np.ndarray    # (n_events,) int64
    item: np.ndarray    # (n_events,) int64
    value: np.ndarray   # (n_events,) float32
    t: np.ndarray       # (n_events,) int64 timestamps
    n_users: int
    n_items: int

    @property
    def n_events(self) -> int:
        return int(self.user.shape[0])


def _cache_path(cache_dir: Optional[str]) -> str:
    base = cache_dir or os.environ.get("REPRO_DATA_DIR") or os.path.join(
        os.path.expanduser("~"), ".cache", "repro-data"
    )
    return os.path.join(base, "ml-synth.data")


def _parse_ratings(path: str) -> ImplicitLog:
    """Parse ``user item value timestamp`` lines (tab/space separated —
    the ml-100k ``u.data`` layout). Ids are remapped to dense 0-based."""
    raw = np.loadtxt(path, dtype=np.float64, ndmin=2)
    if raw.shape[1] < 3:
        raise ValueError(f"{path}: expected ≥3 columns (user item value [t])")
    user_raw = raw[:, 0].astype(np.int64)
    item_raw = raw[:, 1].astype(np.int64)
    users, user = np.unique(user_raw, return_inverse=True)
    items, item = np.unique(item_raw, return_inverse=True)
    t = (raw[:, 3] if raw.shape[1] > 3 else np.arange(len(raw))).astype(np.int64)
    return ImplicitLog(
        user=user.astype(np.int64), item=item.astype(np.int64),
        value=raw[:, 2].astype(np.float32), t=t,
        n_users=int(len(users)), n_items=int(len(items)),
    )


def load_movielens(
    path: Optional[str] = None,
    *,
    cache_dir: Optional[str] = None,
    n_users: int = 400,
    n_items: int = 300,
    events_per_user: Tuple[int, int] = (4, 16),
    seed: int = 0,
) -> ImplicitLog:
    """Load a MovieLens-class ratings log.

    Resolution order:
      1. explicit ``path`` (must exist) — a real ``u.data``-style file;
      2. the cache file under ``cache_dir`` / ``$REPRO_DATA_DIR`` /
         ``~/.cache/repro-data`` if a previous call wrote it;
      3. deterministic synthetic fallback (seeded
         :func:`~repro.data.synthetic.make_implicit_dataset`), written
         through the cache file in the same format — so every load goes
         through :func:`_parse_ratings` and later calls hit the cache.
    """
    if path is not None:
        if not os.path.exists(path):
            raise FileNotFoundError(path)
        return _parse_ratings(path)
    cached = _cache_path(cache_dir)
    if not os.path.exists(cached):
        from repro.data.synthetic import make_implicit_dataset

        ds = make_implicit_dataset(
            n_users=n_users, n_items=n_items,
            events_per_user=events_per_user, seed=seed,
        )
        os.makedirs(os.path.dirname(cached), exist_ok=True)
        ev = np.asarray(ds.events)
        table = np.column_stack(
            [ev[:, 0], ev[:, 1], np.ones(len(ev), np.int64), ev[:, 2]]
        )
        tmp = cached + ".tmp"
        np.savetxt(tmp, table, fmt="%d", delimiter="\t")
        os.replace(tmp, cached)
    return _parse_ratings(cached)


def split_by_time(
    log: ImplicitLog, holdout_fraction: float = 0.2
) -> Tuple[ImplicitLog, ImplicitLog]:
    """Global-time-cutoff split (the paper's Instant protocol shape): the
    last ``holdout_fraction`` of events by timestamp become the test log.
    Vocabulary sizes are shared so ids stay aligned across the split."""
    if not 0.0 < holdout_fraction < 1.0:
        raise ValueError("holdout_fraction must be in (0, 1)")
    order = np.argsort(log.t, kind="stable")
    n_test = max(1, int(round(log.n_events * holdout_fraction)))
    tr, te = order[: log.n_events - n_test], order[log.n_events - n_test:]

    def take(idx):
        return ImplicitLog(
            user=log.user[idx], item=log.item[idx], value=log.value[idx],
            t=log.t[idx], n_users=log.n_users, n_items=log.n_items,
        )

    return take(tr), take(te)


def frequency_interactions(
    log: ImplicitLog,
    *,
    alpha0: float = 0.5,
    base_alpha: float = 2.0,
    beta: float = 1.0,
    mode: str = "log",
    eps: float = 1.0,
) -> Tuple[Interactions, np.ndarray, np.ndarray]:
    """Collapse an event log into unique ``(user, item)`` cells with
    Hu-et-al. frequency confidence.

    Returns ``(data, weights, counts)``:

    ``data``
        :class:`Interactions` over the deduped cells with UNIFORM
        confidence ``base_alpha`` (y=1) — the baseline objective.
    ``weights``
        (nnz,) per-interaction confidence weights α_raw/``base_alpha`` in
        ``data``'s ctx-major nnz order (cells are built pre-sorted, so the
        alignment is exact) — feed as ``weights=`` / ``Dataset.confidence``
        to train the frequency-confidence objective on the SAME compiled
        program; ``None`` keeps the uniform baseline bit-identical.
    ``counts``
        (nnz,) summed event values per cell (the α derivation input).
    """
    key = log.user * log.n_items + log.item
    uniq, inv = np.unique(key, return_inverse=True)
    counts = np.zeros(len(uniq), np.float64)
    np.add.at(counts, inv, log.value.astype(np.float64))
    user_u, item_u = uniq // log.n_items, uniq % log.n_items
    # np.unique returns keys sorted ⇒ (user-major, item within) — exactly
    # the ctx-major layout build_interactions sorts to, so weights align.
    data = build_interactions(
        user_u, item_u,
        np.ones(len(uniq), np.float64),
        np.full(len(uniq), float(base_alpha)),
        log.n_users, log.n_items, alpha0=alpha0,
    )
    alpha_raw = np.asarray(
        frequency_confidence(counts, beta=beta, mode=mode, eps=eps)
    )
    weights = np.asarray(
        confidence_weights(alpha_raw, base=float(base_alpha)), np.float32
    )
    return data, weights, counts.astype(np.float32)
