"""DLRM RM2 [arXiv:1906.00091] — dot interaction, 26 sparse features.

Table sizes follow the RM2 regime (a few huge user/item-id tables + many
small categorical ones); the total (41.8M rows × 64) is row-sharded over the
``model`` mesh axis in the dry run.
"""
import dataclasses

from repro.configs.base import RECSYS_SHAPES, RecsysConfig

CONFIG = RecsysConfig(
    name="dlrm-rm2",
    kind="dlrm",
    n_dense=13,
    n_sparse=26,
    embed_dim=64,
    table_vocabs=tuple([5_000_000] * 8 + [100_000] * 18),
    bot_mlp=(512, 256, 64),
    top_mlp=(512, 512, 256, 1),
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, table_vocabs=tuple([50] * 8 + [10] * 18),
    bot_mlp=(16, 8), top_mlp=(16, 8, 1), embed_dim=8,
)

SHAPES = RECSYS_SHAPES
